"""Pallas TPU kernel: block-matching motion estimation.

TPU adaptation of the paper's FPGA motion-estimation stage (§3: "dedicated
hardware blocks ... leverage FPGA's DSP slices for fast cross-correlation or
block matching").  The VPU plays the DSP-slice role: for one row of blocks per
grid step, all (2R+1)^2 candidate offsets are evaluated as full-row absolute
differences (8x128-lane friendly), reduced per block, and arg-minimized in a
single fori_loop.

Halo handling: the previous frame is padded by one *full block row* top and
bottom (edge replication) plus R columns left/right, and fetched as three
consecutive row-blocks (i, i+1, i+2 of the padded frame = i-1, i, i+1 of the
original).  The (block + 2R)-row search window is then a *static* slice of the
concatenated rows — no unsupported overlapping BlockSpecs.

All SAD arithmetic is int32 on integer luma: exact, tie-stable, bit-identical
to ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["block_motion_pallas"]


def _motion_kernel(cur_ref, ptop_ref, pmid_ref, pbot_ref, dy_ref, dx_ref, sad_ref, *,
                   block: int, radius: int, nbx: int):
    side = 2 * radius + 1
    W = nbx * block
    cur = cur_ref[...].astype(jnp.int32)  # (block, W)
    rows = jnp.concatenate(
        [ptop_ref[...], pmid_ref[...], pbot_ref[...]], axis=0
    ).astype(jnp.int32)  # (3*block, W + 2R)
    window = jax.lax.slice(
        rows, (block - radius, 0), (2 * block + radius, W + 2 * radius)
    )  # (block + 2R, W + 2R), static

    init = (
        jnp.full((nbx,), jnp.iinfo(jnp.int32).max, jnp.int32),
        jnp.zeros((nbx,), jnp.int32),
    )

    def body(o, carry):
        best_sad, best_o = carry
        dy = o // side
        dx = o % side
        cand = jax.lax.dynamic_slice(window, (dy, dx), (block, W))
        diff = jnp.abs(cur - cand)  # (block, W)
        sad = diff.reshape(block, nbx, block).sum(axis=(0, 2))  # (nbx,)
        take = sad < best_sad
        return jnp.where(take, sad, best_sad), jnp.where(take, o, best_o)

    best_sad, best_o = jax.lax.fori_loop(0, side * side, body, init)
    dy_ref[...] = (best_o // side - radius).astype(jnp.int32)[None, :]
    dx_ref[...] = (best_o % side - radius).astype(jnp.int32)[None, :]
    sad_ref[...] = best_sad[None, :]


def block_motion_pallas(
    cur: jax.Array,
    prev_padded: jax.Array,
    *,
    block: int = 16,
    radius: int = 8,
    interpret: bool = True,
):
    """cur: (H, W) int32 luma; prev_padded: (H + 2*block, W + 2*radius) int32
    (one block row of edge padding top/bottom, radius columns left/right —
    built by ops.py).  Returns (dy, dx, sad) each (nby, nbx) int32.
    """
    H, W = cur.shape
    if H % block or W % block:
        raise ValueError(f"frame {cur.shape} not a multiple of block {block}")
    if radius > block:
        raise ValueError(f"radius {radius} > block {block} unsupported by halo trick")
    nby, nbx = H // block, W // block
    Hp, Wp = prev_padded.shape
    if Hp != H + 2 * block or Wp != W + 2 * radius:
        raise ValueError(f"prev_padded {prev_padded.shape} != {(H + 2 * block, W + 2 * radius)}")

    kernel = functools.partial(
        _motion_kernel, block=block, radius=radius, nbx=nbx
    )
    grid = (nby,)
    out_shapes = [
        jax.ShapeDtypeStruct((nby, nbx), jnp.int32),
        jax.ShapeDtypeStruct((nby, nbx), jnp.int32),
        jax.ShapeDtypeStruct((nby, nbx), jnp.int32),
    ]
    row_spec = pl.BlockSpec((1, nbx), lambda i: (i, 0))
    dy, dx, sad = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, W), lambda i: (i, 0)),  # current block row
            pl.BlockSpec((block, Wp), lambda i: (i, 0)),  # prev row-block i-1 (padded)
            pl.BlockSpec((block, Wp), lambda i: (i + 1, 0)),  # prev row-block i
            pl.BlockSpec((block, Wp), lambda i: (i + 2, 0)),  # prev row-block i+1
        ],
        out_specs=[row_spec, row_spec, row_spec],
        out_shape=out_shapes,
        interpret=interpret,
    )(cur, prev_padded, prev_padded, prev_padded)
    return dy, dx, sad
