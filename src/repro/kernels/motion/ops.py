"""Public jit'd wrappers for block-matching motion estimation."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret as _use_interpret
from repro.kernels.motion import ref as _ref
from repro.kernels.motion.motion import block_motion_pallas

__all__ = ["estimate_motion", "to_luma255", "warp", "predict_frame"]


def to_luma255(frame):
    """(H, W, 3) float [0,1] or (H, W) -> int32 luma in [0, 255]."""
    if frame.ndim == 3:
        lum = (
            0.299 * frame[..., 0] + 0.587 * frame[..., 1] + 0.114 * frame[..., 2]
        )
    else:
        lum = frame
    if jnp.issubdtype(lum.dtype, jnp.floating):
        lum = jnp.round(jnp.clip(lum, 0.0, 1.0) * 255.0)
    return lum.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "radius", "use_kernel"))
def estimate_motion(cur, prev, *, block: int = 16, radius: int = 8, use_kernel=True):
    """cur, prev: (H, W[, 3]) frames -> (mv (nby, nbx, 2) int32, sad (nby, nbx) int32)."""
    cl = to_luma255(cur)
    pl_ = to_luma255(prev)
    if not use_kernel:
        return _ref.block_motion_ref(cl, pl_, block=block, radius=radius)
    prev_padded = jnp.pad(pl_, ((block, block), (radius, radius)), mode="edge")
    dy, dx, sad = block_motion_pallas(
        cl,
        prev_padded,
        block=block,
        radius=radius,
        interpret=_use_interpret(),
    )
    return jnp.stack([dy, dx], axis=-1), sad


def warp(prev, mv, block: int = 16):
    """predict(F_prev, M): works on (H, W) or (H, W, C) float frames."""
    return _ref.warp_blocks(prev, mv, block)


predict_frame = warp
