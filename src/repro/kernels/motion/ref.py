"""Pure-jnp oracle for block-matching motion estimation + warp (codec C1).

Semantics (Salient Store §3, "Motion Estimation" on DSP slices):
frames are split into BS x BS blocks; for each block of the *current* frame we
search the *previous* frame over integer offsets (dy, dx) in [-R, R]^2 and
pick the offset minimizing the SAD.  ``predict(F_prev, M)`` translates each
previous-frame block by its motion vector (the paper's macroblock-style
prediction); the residual is ``F_cur - predict``.

Tie-breaking: the smallest linear offset index wins (scan order), matching the
kernel exactly so the oracle is bit-identical.  SAD is computed on integer
luma (int32) so reduction order cannot perturb ties — both ref and kernel are
exact.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["block_motion_ref", "warp_blocks", "predict_frame"]


def _shift2d(img, dy, dx):
    """Shift with edge replication: out[y, x] = img[clip(y + dy), clip(x + dx)]."""
    H, W = img.shape[-2:]
    ys = jnp.clip(jnp.arange(H) + dy, 0, H - 1)
    xs = jnp.clip(jnp.arange(W) + dx, 0, W - 1)
    return img[..., ys, :][..., :, xs]


def block_motion_ref(cur, prev, block: int = 16, radius: int = 8):
    """cur, prev: (H, W) luma. Returns (mv, sad): (nby, nbx, 2) int32, (nby, nbx).

    mv[by, bx] = (dy, dx) into the previous frame minimizing SAD.
    """
    H, W = cur.shape
    assert H % block == 0 and W % block == 0, (H, W, block)
    nby, nbx = H // block, W // block
    side = 2 * radius + 1

    cur_b = cur.astype(jnp.int32).reshape(nby, block, nbx, block)
    best_sad = jnp.full((nby, nbx), jnp.iinfo(jnp.int32).max, jnp.int32)
    best_o = jnp.zeros((nby, nbx), jnp.int32)
    for o in range(side * side):
        dy, dx = o // side - radius, o % side - radius
        shifted = (
            _shift2d(prev, dy, dx).astype(jnp.int32).reshape(nby, block, nbx, block)
        )
        sad = jnp.abs(cur_b - shifted).sum(axis=(1, 3))
        take = sad < best_sad  # strict: first (smallest o) wins ties
        best_sad = jnp.where(take, sad, best_sad)
        best_o = jnp.where(take, o, best_o)
    mv = jnp.stack([best_o // side - radius, best_o % side - radius], axis=-1)
    return mv.astype(jnp.int32), best_sad


def warp_blocks(prev, mv, block: int = 16):
    """predict(F_prev, M): translate each block of prev by its motion vector.

    prev: (H, W) or (H, W, C); mv: (nby, nbx, 2) -> same shape as prev.
    """
    chan = prev.ndim == 3
    img = prev if chan else prev[..., None]
    H, W, C = img.shape
    nby, nbx = mv.shape[:2]
    block_y = jnp.arange(H) // block  # (H,)
    block_x = jnp.arange(W) // block  # (W,)
    dy = mv[..., 0][block_y[:, None], block_x[None, :]]  # (H, W)
    dx = mv[..., 1][block_y[:, None], block_x[None, :]]
    ys = jnp.clip(jnp.arange(H)[:, None] + dy, 0, H - 1)
    xs = jnp.clip(jnp.arange(W)[None, :] + dx, 0, W - 1)
    out = img[ys, xs]  # advanced indexing -> (H, W, C)
    return out if chan else out[..., 0]


def predict_frame(prev, mv, block: int = 16):
    return warp_blocks(prev, mv, block)
