"""Public jit'd wrappers for blockwise int8 quantization."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret as _use_interpret
from repro.kernels.quantize import ref as _ref
from repro.kernels.quantize.quantize import dequantize_pallas, quantize_pallas

__all__ = ["quantize_blockwise", "dequantize_blockwise"]


@functools.partial(jax.jit, static_argnames=("block", "use_kernel"))
def quantize_blockwise(x, block: int = 128, use_kernel: bool = True):
    """x: (..., N) float -> (int8 same shape, f32 scales (..., N/block))."""
    *lead, n = x.shape
    flat = x.reshape(-1, n)
    R = flat.shape[0]
    if not use_kernel or n % block or R % 8:
        q, s = _ref.quantize_ref(flat, block)
    else:
        q, s = quantize_pallas(flat, block, interpret=_use_interpret())
    return q.reshape(*lead, n), s.reshape(*lead, n // block)


@functools.partial(jax.jit, static_argnames=("block", "dtype", "use_kernel"))
def dequantize_blockwise(q, scales, block: int = 128, dtype=jnp.float32,
                         use_kernel: bool = True):
    *lead, n = q.shape
    flat_q = q.reshape(-1, n)
    flat_s = scales.reshape(-1, n // block)
    if not use_kernel or n % block or flat_q.shape[0] % 8:
        out = _ref.dequantize_ref(flat_q, flat_s, block, dtype)
    else:
        out = dequantize_pallas(flat_q, flat_s, block, dtype, interpret=_use_interpret())
    return out.reshape(*lead, n)
