"""Pure-jnp oracle for fused blockwise symmetric int8 quantization.

Semantics shared by the codec bitstream, the gradient compressor and the
int8 KV-cache: rows are quantized in blocks of ``block`` elements with one
f32 scale per block (absmax/127), values rounded-to-nearest-even and clipped
to [-127, 127].
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["quantize_ref", "dequantize_ref"]


def quantize_ref(x, block: int = 128):
    """x: (..., N) float, N % block == 0 ->
    (q (..., N) int8, scales (..., N/block) float32)."""
    *lead, n = x.shape
    assert n % block == 0, (n, block)
    xb = x.astype(jnp.float32).reshape(*lead, n // block, block)
    # explicit reciprocal multiply: XLA rewrites /127.0 to *(1/127) under
    # jit but not in eager mode, so a literal division would make the eager
    # oracle differ from the jitted kernel by 1 ULP (enough to flip a
    # round-half case).  The multiply is the same op in both modes.
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1), 1e-12) * jnp.float32(
        1.0 / 127.0
    )
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, n), scale


def dequantize_ref(q, scales, block: int = 128, dtype=jnp.float32):
    *lead, n = q.shape
    qb = q.reshape(*lead, n // block, block).astype(jnp.float32)
    return (qb * scales[..., None]).reshape(*lead, n).astype(dtype)
