"""Pallas TPU kernel: fused blockwise int8 quantization (+ dequantization).

Memory-bound VPU kernel: one pass over the input computes per-block absmax
scales and the rounded int8 payload in a single VMEM-resident tile — the HBM
traffic is exactly read-f32 + write-int8 (2.2 GB/s of effective compression
on the 819 GB/s v5e HBM roofline), where the unfused jnp version re-reads the
input for the reduction and the scaling.

Used by: codec bitstream packing, gradient compression (cross-pod hop), and
the int8 KV-cache decode option.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize_pallas", "dequantize_pallas"]

DEFAULT_ROWS = 8  # sublane-aligned row tile


def _quant_kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)  # (rows, N)
    rows, n = x.shape
    xb = x.reshape(rows, n // block, block)
    # reciprocal multiply, matching ref.quantize_ref (see comment there)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1), 1e-12) * jnp.float32(
        1.0 / 127.0
    )
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    q_ref[...] = q.reshape(rows, n).astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref, *, block: int):
    q = q_ref[...].astype(jnp.float32)
    rows, n = q.shape
    qb = q.reshape(rows, n // block, block)
    o_ref[...] = (qb * s_ref[...][..., None]).reshape(rows, n).astype(o_ref.dtype)


def quantize_pallas(x, block: int = 128, *, rows_per_step: int = DEFAULT_ROWS,
                    interpret: bool = True):
    """x: (R, N) float with N % block == 0, R % rows_per_step == 0 ->
    (q (R, N) int8, scales (R, N/block) f32)."""
    R, N = x.shape
    if N % block or R % rows_per_step:
        raise ValueError(f"shape {x.shape} not tileable by ({rows_per_step}, {block})")
    grid = (R // rows_per_step,)
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, block=block),
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_step, N), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows_per_step, N), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, N // block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, N), jnp.int8),
            jax.ShapeDtypeStruct((R, N // block), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def dequantize_pallas(q, scales, block: int = 128, dtype=jnp.float32,
                      *, rows_per_step: int = DEFAULT_ROWS, interpret: bool = True):
    R, N = q.shape
    grid = (R // rows_per_step,)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_step, N), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, N // block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_step, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, N), dtype),
        interpret=interpret,
    )(q, scales)
