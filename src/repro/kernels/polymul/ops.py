"""Public jit'd wrappers for negacyclic polynomial multiplication.

Two entry points:

* ``polymul_fixed(a, vecs, q)`` — one polynomial against many (the R-LWE bulk
  dataflow: a public/secret key against a batch of ciphertext polynomials).
  Routed to the Pallas MXU kernel.

* ``polymul(a, b, q)`` — general elementwise-batched product (matrices differ
  per pair).  Routed to the pure-jnp reference (a per-pair matrix build is the
  dominant cost either way; XLA fuses it well).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret as _use_interpret
from repro.kernels.polymul import ref as _ref
from repro.kernels.polymul.polymul import DEFAULT_TILE_B, negacyclic_matmul_pallas

__all__ = ["polymul_fixed", "polymul"]


@functools.partial(jax.jit, static_argnames=("q", "use_kernel", "tile_b"))
def polymul_fixed(
    a: jax.Array,
    vecs: jax.Array,
    q: int,
    *,
    use_kernel: bool = True,
    tile_b: int = DEFAULT_TILE_B,
) -> jax.Array:
    """(a * vecs[i]) mod (x^n + 1, q) for every row i.

    a: (n,) int32 in [0, q); vecs: (B, n) int32 in [0, q) -> (B, n).
    """
    a = jnp.mod(jnp.asarray(a, jnp.int32), q)
    vecs = jnp.mod(jnp.asarray(vecs, jnp.int32), q)
    B, n = vecs.shape
    if not use_kernel or q >= (1 << 14) or n % 8 != 0:
        return _ref.negacyclic_matmul_ref(a, vecs, q)
    nmat = _ref.negacyclic_matrix(a, q)
    tb = min(tile_b, _round_up(B, 8))
    pad = (-B) % tb
    vecs_t = jnp.pad(vecs, ((0, pad), (0, 0))).T  # (n, B + pad)
    out_t = negacyclic_matmul_pallas(
        nmat, vecs_t, q, tile_b=tb, interpret=_use_interpret()
    )
    return out_t.T[:B]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("q",))
def polymul(a: jax.Array, b: jax.Array, q: int) -> jax.Array:
    """General negacyclic product; a, b broadcastable (..., n) -> (..., n)."""
    return _ref.negacyclic_polymul_ref(a, b, q)
