"""Pallas TPU kernel: negacyclic polynomial multiplication on the MXU.

TPU-native re-derivation of the paper's HSPM/SDMM FPGA accelerator
(Salient Store §4, Fig. 3):

* HSPM streams polynomial ``b`` serially through a 128-lane MAC array while
  ``a``'s coefficients are broadcast.  On TPU the MAC array is the MXU, so we
  express the schoolbook product as the structured matmul
  ``C = N(a) @ B`` with the negacyclic matrix resident in VMEM ("loaded into
  the systolic array") and a tile of ``B`` columns streamed per grid step.

* SDMM packs *two* modular multiplies per DSP slice using a signed 6-bit
  sample representation.  The TPU analogue: split every 13/14-bit coefficient
  into two 7-bit limbs (``x = hi * 2^7 + lo``) so all four partial products
  are int8 x int8 -> int32 MXU ops, exact in the 32-bit accumulator:
  ``|sum| <= n * 96 * 127 < 2^22`` for n = 256, q = 12289.

* The paper's approximate modular-reduction unit (one shift + one conditional
  subtract, constant time) appears here as the recombination step: each
  partial matmul is reduced once, then
  ``c = ((2^14 mod q) * t_hh + 2^7 * t_mid + t_ll) mod q``
  which keeps every intermediate below ``q * 4224 < 2^26`` — a single final
  reduction, no wide arithmetic, constant time.

Requirements: ``q < 2^14`` (the paper's 13-bit samples satisfy this) and the
ring dimension ``n`` a multiple of 8 (MXU sublane); n = 256 is two 128-wide
systolic passes, exactly the paper's 128-MAC geometry doubled.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["negacyclic_matmul_pallas", "DEFAULT_TILE_B"]

DEFAULT_TILE_B = 256
_LIMB_BITS = 7
_LIMB_MASK = (1 << _LIMB_BITS) - 1


def _polymul_kernel(nmat_ref, b_ref, out_ref, *, q: int):
    """One grid step: (n, n) negacyclic matrix x (n, TILE_B) columns."""
    nmat = nmat_ref[...]  # int32, centered entries |.| <= q/2
    b = b_ref[...]  # int32 in [0, q)

    # --- SDMM analogue: two 7-bit limbs per int8 lane -----------------
    sign = jnp.where(nmat < 0, -1, 1).astype(jnp.int32)
    mag = jnp.abs(nmat)
    a_hi = (sign * (mag >> _LIMB_BITS)).astype(jnp.int8)  # |.| <= q/2^8 < 96
    a_lo = (sign * (mag & _LIMB_MASK)).astype(jnp.int8)  # |.| <= 127
    b_hi = (b >> _LIMB_BITS).astype(jnp.int8)  # < q/2^7 < 128
    b_lo = (b & _LIMB_MASK).astype(jnp.int8)

    dot = functools.partial(
        jax.lax.dot, precision=None, preferred_element_type=jnp.int32
    )
    # --- HSPM analogue: systolic passes over the MXU -------------------
    p_hh = dot(a_hi, b_hi)
    p_mid = dot(a_hi, b_lo) + dot(a_lo, b_hi)
    p_ll = dot(a_lo, b_lo)

    # --- approximate-MR analogue: per-partial single reduction ---------
    t_hh = jnp.mod(p_hh, q)
    t_mid = jnp.mod(p_mid, q)
    t_ll = jnp.mod(p_ll, q)
    two14 = (1 << (2 * _LIMB_BITS)) % q  # e.g. 4095 for q = 12289
    c = jnp.mod(two14 * t_hh + (1 << _LIMB_BITS) * t_mid + t_ll, q)
    out_ref[...] = c.astype(jnp.int32)


def negacyclic_matmul_pallas(
    nmat: jax.Array,
    vecs_t: jax.Array,
    q: int,
    *,
    tile_b: int = DEFAULT_TILE_B,
    interpret: bool = True,
) -> jax.Array:
    """C = (N(a) @ B) mod q on the MXU.

    nmat:   (n, n) int32 negacyclic matrix, centered entries (|.| <= q/2).
    vecs_t: (n, B) int32 columns in [0, q), B a multiple of ``tile_b``
            (callers pad; see ops.py).
    Returns (n, B) int32 in [0, q).
    """
    if q >= (1 << 14):
        raise ValueError(f"int8 limb path requires q < 2^14, got q={q}")
    n, n2 = nmat.shape
    if n != n2:
        raise ValueError(f"nmat must be square, got {nmat.shape}")
    nb, B = vecs_t.shape
    if nb != n:
        raise ValueError(f"vecs_t rows {nb} != ring dim {n}")
    if B % tile_b != 0:
        raise ValueError(f"B={B} not a multiple of tile_b={tile_b}")
    if n % 8 != 0:
        raise ValueError(f"ring dim n={n} must be a multiple of 8")

    grid = (B // tile_b,)
    return pl.pallas_call(
        functools.partial(_polymul_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # matrix resident in VMEM
            pl.BlockSpec((n, tile_b), lambda i: (0, i)),  # stream column tiles
        ],
        out_specs=pl.BlockSpec((n, tile_b), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, B), jnp.int32),
        interpret=interpret,
    )(nmat, vecs_t)
