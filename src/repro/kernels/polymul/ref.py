"""Pure-jnp oracle for negacyclic polynomial multiplication in Z_q[x]/(x^n + 1).

This is the reference semantics for the HSPM/SDMM hardware of the paper
(Salient Store §4, Fig. 3): schoolbook polynomial multiplication with
modular reduction.  The negacyclic product is

    c_k = sum_{i+j = k} a_i b_j  -  sum_{i+j = k+n} a_i b_j   (mod q)

which is exactly the mat-vec ``c = N(a) @ b`` with the negacyclic-circulant
matrix ``N(a)[k, j] = a_{k-j}`` for ``k >= j`` and ``-a_{n+k-j}`` otherwise.

All arithmetic here is exact in int32: operands are first mapped to the
centered representation ``|x| <= q/2`` and the contraction is accumulated in
chunks with a modular reduction between chunks, so no partial sum ever
exceeds ``chunk * (q/2)^2 < 2^31`` for the q used by the paper (13-bit).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "negacyclic_matrix",
    "negacyclic_polymul_ref",
    "negacyclic_matmul_ref",
    "center",
]


def center(x, q: int):
    """Map coefficients from [0, q) to the centered representation (-q/2, q/2]."""
    x = jnp.mod(jnp.asarray(x, jnp.int32), q)
    return jnp.where(x > q // 2, x - q, x)


def negacyclic_matrix(a, q: int):
    """Build N(a) with entries in the centered representation.

    a: (..., n) int32 in [0, q)  ->  (..., n, n) int32, |entries| <= q/2.
    ``c = N(a) @ b (mod q)`` is the negacyclic product ``a * b``.
    """
    a = center(a, q)
    n = a.shape[-1]
    k = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    idx = jnp.mod(k - j, n)
    sign = jnp.where(k >= j, 1, -1).astype(jnp.int32)
    return jnp.take(a, idx, axis=-1) * sign


def _safe_chunk(q: int, chunk: int, n: int) -> int:
    """Largest chunk <= requested with chunk * (q/2 + 1)^2 + q < 2^31 (exact)."""
    bound = (2**31 - q - 1) // ((q // 2 + 1) ** 2)
    return max(1, min(chunk, bound, n))


def _chunked_mod_matvec(mat, vec, q: int, chunk: int):
    """Exact (mat @ vec) mod q with int32-only arithmetic.

    mat: (..., n, n) centered entries; vec: (..., n) centered entries.
    The contraction dim is split into chunks with a mod-q between chunks so
    partial sums stay below 2^31 (chunk * (q/2)^2 bound, chunk auto-shrunk
    for large q).
    """
    n = mat.shape[-1]
    chunk = _safe_chunk(q, chunk, n)
    acc = jnp.zeros(mat.shape[:-1], jnp.int32)
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        part = jnp.einsum(
            "...kj,...j->...k", mat[..., lo:hi], vec[..., lo:hi]
        )
        acc = jnp.mod(acc + part, q)
    return acc.astype(jnp.int32)


def negacyclic_polymul_ref(a, b, q: int, *, chunk: int = 32):
    """Negacyclic product a*b mod (x^n+1, q). a, b: (..., n) -> (..., n)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    mat = negacyclic_matrix(a, q)
    vec = center(b, q)
    return _chunked_mod_matvec(mat, vec, q, chunk)


def negacyclic_matmul_ref(a, vecs, q: int, *, chunk: int = 32):
    """Fixed-a bulk product: a (n,), vecs (B, n) -> (B, n), all mod q.

    This is the R-LWE bulk dataflow (one public key / secret key against many
    ciphertext polynomials) and matches the Pallas kernel's contract.
    """
    a = jnp.asarray(a, jnp.int32)
    vecs = jnp.asarray(vecs, jnp.int32)
    mat = negacyclic_matrix(a, q)  # (n, n)
    vc = center(vecs, q)  # (B, n)
    n = mat.shape[-1]
    ch = _safe_chunk(q, chunk, n)
    acc = jnp.zeros((vc.shape[0], n), jnp.int32)
    for lo in range(0, n, ch):
        hi = min(lo + ch, n)
        part = jnp.einsum("kj,bj->bk", mat[:, lo:hi], vc[:, lo:hi])
        acc = jnp.mod(acc + part, q)
    return acc.astype(jnp.int32)
