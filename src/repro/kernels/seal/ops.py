"""Public wrappers for the fused seal datapath: padding, dispatch, accounting.

``seal_stripe`` / ``unseal_stripe`` accept ragged per-shard payloads, pad
them to the kernel's (R, 512)-int8 tile grid, and dispatch either the fused
Pallas kernel (one launch per stripe) or the staged jnp oracle
(``use_pallas=False``).  Both paths are bit-identical: same sealed bodies,
same P/Q parity, zero-padded tails.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archival.raid import gf_pow_gen
from repro.kernels import as_payload_list, use_interpret
from repro.kernels.seal import ref as _ref
from repro.kernels.seal.seal import (
    LANES,
    R_TILE,
    ROW_BYTES,
    seal_stripe_pallas,
    unseal_stripe_pallas,
)

__all__ = [
    "SealedStripe",
    "seal_stripe",
    "unseal_stripe",
    "pad_rows_for",
    "bucket_rows_for",
    "datapath_traffic",
]


class SealedStripe(NamedTuple):
    sealed: jax.Array            # (S, R, 128) uint32, zero-padded tails
    p: Optional[jax.Array]       # (R, 128) uint32 RAID-5 parity (or None)
    q: Optional[jax.Array]       # (R, 128) uint32 RAID-6 parity (or None)
    n_words: Tuple[int, ...]     # valid uint32 words per shard
    n_i8: Tuple[int, ...]        # valid int8 payload bytes per shard

    def body(self, s: int) -> jax.Array:
        """Exact-length flat uint32 sealed body of shard s."""
        return self.sealed[s].reshape(-1)[: self.n_words[s]]

    @property
    def pad_words(self) -> int:
        return self.sealed.shape[1] * LANES


def pad_rows_for(n_words: int) -> int:
    """Rows of 128 words covering n_words, rounded to the 8-row tile."""
    rows = max(1, -(-n_words // LANES))
    return -(-rows // R_TILE) * R_TILE


def bucket_rows_for(n_words: int) -> int:
    """Smallest power-of-two multiple of ``R_TILE`` rows covering n_words.

    ``_seal_core`` retraces per distinct (S, R) shape; bucketing stripe
    heights to pow2 tile counts bounds traces at log2(max_rows/R_TILE) for
    arbitrarily mixed GOP sizes (same idea as ``chacha.bucket_n_words``).
    """
    tiles = -(-pad_rows_for(n_words) // R_TILE)
    return R_TILE * (1 << (tiles - 1).bit_length())


# callers (distributed/archival, benches) reach this via the seal namespace
_as_payload_list = as_payload_list


def _stack_padded(
    flats: Sequence[jax.Array], pad_rows: Optional[int] = None
) -> Tuple[jax.Array, Tuple[int, ...], Tuple[int, ...]]:
    if not flats:
        raise ValueError("stripe must contain at least one shard payload")
    n_i8 = tuple(int(f.shape[0]) for f in flats)
    n_words = tuple(-(-n // 4) for n in n_i8)
    R = pad_rows_for(max(n_words))
    if pad_rows is not None:
        if pad_rows < R or pad_rows % R_TILE:
            raise ValueError(
                f"pad_rows={pad_rows} must be a multiple of {R_TILE} "
                f"covering the largest shard ({R} rows)"
            )
        R = pad_rows
    rows = [
        jnp.pad(f, (0, R * ROW_BYTES - f.shape[0])).reshape(R, ROW_BYTES)
        for f in flats
    ]
    return jnp.stack(rows), n_words, n_i8


def _meta_arrays(
    keys, nonces, n_words, shard_ids: Optional[Sequence[int]] = None
) -> Tuple[jax.Array, ...]:
    """Per-shard kernel operands.  ``shard_ids`` carries each row's GLOBAL
    stripe-shard index so the RAID-6 Q coefficient g^s stays correct when a
    subset read hands the kernel only some of a stripe's shards."""
    S = len(n_words)
    ids = range(S) if shard_ids is None else shard_ids
    keys = jnp.asarray(keys, jnp.uint32).reshape(S, 8)
    nonces = jnp.asarray(nonces, jnp.uint32).reshape(S, 3)
    n_valid = jnp.asarray(n_words, jnp.int32).reshape(S, 1)
    q_coef = jnp.asarray(
        [gf_pow_gen(int(s)) for s in ids], jnp.uint32
    ).reshape(S, 1)
    return keys, nonces, n_valid, q_coef


@functools.partial(
    jax.jit, static_argnames=("parity", "use_pallas", "interpret")
)
def _seal_core(codes, keys, nonces, n_valid, q_coef, *,
               parity: str, use_pallas: bool, interpret: bool):
    if use_pallas:
        return seal_stripe_pallas(
            codes, keys, nonces, n_valid, q_coef, parity=parity,
            interpret=interpret,
        )
    return _ref.seal_stripe_ref(
        codes, keys, nonces, n_valid, q_coef, parity=parity
    )


@functools.partial(
    jax.jit, static_argnames=("parity", "use_pallas", "interpret")
)
def _unseal_core(sealed, keys, nonces, n_valid, q_coef, *,
                 parity: str, use_pallas: bool, interpret: bool):
    if use_pallas:
        return unseal_stripe_pallas(
            sealed, keys, nonces, n_valid, q_coef, parity=parity,
            interpret=interpret,
        )
    return _ref.unseal_stripe_ref(
        sealed, keys, nonces, n_valid, q_coef, parity=parity
    )


def seal_stripe(payloads, keys, nonces, *, parity: str = "raid6",
                use_pallas: bool = True,
                interpret: Optional[bool] = None,
                pad_rows: Optional[int] = None) -> SealedStripe:
    """Seal all S shards of a stripe (+ parity) in one fused pass.

    payloads: list of flat int8 arrays (ragged ok) or an (S, N) int8 array.
    keys: (S, 8) uint32 ChaCha session keys; nonces: (S, 3) uint32.
    pad_rows: optional row-count override (multiple of ``R_TILE`` covering
    the largest shard).  Multi-stream coalescers pass a pow2 bucket here so
    mixed GOP sizes share one jit trace per bucket instead of one per
    distinct padded length.
    """
    flats = _as_payload_list(payloads)
    codes, n_words, n_i8 = _stack_padded(flats, pad_rows)
    meta = _meta_arrays(keys, nonces, n_words)
    sealed, p, q = _seal_core(
        codes, *meta, parity=parity, use_pallas=use_pallas,
        interpret=use_interpret(interpret),
    )
    return SealedStripe(sealed, p, q, n_words, n_i8)


def unseal_stripe(stripe: SealedStripe, keys, nonces, *,
                  parity: str = "raid6", use_pallas: bool = True,
                  interpret: Optional[bool] = None,
                  shard_ids: Optional[Sequence[int]] = None):
    """Fused decode: returns (payload list, P, Q) with parity recomputed
    from the stored bodies (compare against the seal-time parity to verify
    stripe integrity before trusting the decode).

    ``shard_ids``: global stripe-shard index per row, for SUBSET reads —
    a retrieval plan that wants shards {1, 3} of a 4-shard stripe stacks
    just those two bodies and passes ``shard_ids=(1, 3)``; parity recompute
    over a subset is meaningless, so such reads run ``parity="none"``.
    """
    if not stripe.n_words:
        raise ValueError("stripe must contain at least one shard payload")
    meta = _meta_arrays(keys, nonces, stripe.n_words, shard_ids)
    codes, p, q = _unseal_core(
        stripe.sealed, *meta, parity=parity, use_pallas=use_pallas,
        interpret=use_interpret(interpret),
    )
    flats = [
        codes[s].reshape(-1)[: stripe.n_i8[s]] for s in range(codes.shape[0])
    ]
    return flats, p, q


def datapath_traffic(S: int, n_words: int, parity: str = "raid6") -> dict:
    """Structural HBM-byte accounting per stripe: staged pipeline vs fused.

    n_words: padded uint32 words per shard.  The fused kernel touches each
    payload byte once on read (int8) and once on write (uint32), plus one
    parity write per parity output; every staged pass re-reads and/or
    re-writes the full stripe (see ``ref.STAGED_PASSES``).
    """
    body_u8 = 4 * n_words          # bytes of one shard's packed body
    stripe_u8 = S * body_u8
    n_par = {"none": 0, "raid5": 1, "raid6": 2}[parity]
    fused = stripe_u8 + stripe_u8 + n_par * body_u8  # read i8 + write u32 + parity
    staged = (
        2 * stripe_u8            # pack: read i8, write u32
        + stripe_u8              # keystream: write u32
        + 3 * stripe_u8          # xor: read payload + keystream, write
        + 2 * stripe_u8          # mask: read + write
        + (2 * stripe_u8 if n_par else 0)   # u8 bitcast: read + write
        + n_par * (stripe_u8 + body_u8)     # parity: read S shards per parity + write
    )
    return {
        "staged_bytes": staged,
        "fused_bytes": fused,
        "reduction": staged / fused,
        "staged_passes": _ref.N_STAGED_PASSES,
        "fused_launches": 1,
    }
