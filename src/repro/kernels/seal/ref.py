"""Staged pure-jnp oracle for the fused seal datapath (bit-exact target).

This is the pre-fusion pipeline kept as the reference and the
``use_pallas=False`` fallback: each stage is a separate device op over the
full stripe, i.e. a separate HBM round-trip on a real accelerator.  The
stage list below is what ``benchmarks/kernels_bench.py`` counts against the
fused kernel's single launch.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax.numpy as jnp

from repro.core.archival import raid
from repro.core.crypto.chacha import chacha20_block

__all__ = ["STAGED_PASSES", "N_STAGED_PASSES", "seal_stripe_ref", "unseal_stripe_ref"]

# One entry per full-payload HBM round-trip in the staged pipeline.
STAGED_PASSES = (
    "pack int8->u32 (read i8, write u32)",
    "ChaCha20 keystream (write u32)",
    "XOR-seal (read payload + keystream, write u32)",
    "valid-length mask (read + write u32)",
    "u32->u8 bitcast for GF math (read + write)",
    "RAID P/Q accumulation over S shards (S reads per parity)",
)
N_STAGED_PASSES = len(STAGED_PASSES)


def _pack_rows(codes: jnp.ndarray) -> jnp.ndarray:
    """(S, R, 512) int8 -> (S, R, 128) uint32, little-endian lanes."""
    S, R, C = codes.shape
    b = (codes.astype(jnp.int32) & 0xFF).astype(jnp.uint32).reshape(S, R, C // 4, 4)
    sh = jnp.arange(4, dtype=jnp.uint32) * 8
    return (b << sh).sum(-1, dtype=jnp.uint32)


def _unpack_rows(words: jnp.ndarray) -> jnp.ndarray:
    """(S, R, 128) uint32 -> (S, R, 512) int8 (two's complement)."""
    S, R, L = words.shape
    sh = jnp.arange(4, dtype=jnp.uint32) * 8
    v = ((words[..., None] >> sh) & jnp.uint32(0xFF)).astype(jnp.int32)
    signed = v - ((v & 0x80) << 1)
    return signed.reshape(S, R, 4 * L).astype(jnp.int8)


def _keystream_rows(keys, nonces, R: int) -> jnp.ndarray:
    """Per-shard ChaCha20 keystream shaped (S, R, 128), counter0 = 0."""
    n_blocks = R * 128 // 16
    counters = jnp.arange(n_blocks, dtype=jnp.uint32)
    rows = [
        chacha20_block(keys[s], counters, nonces[s]).reshape(R, 128)
        for s in range(keys.shape[0])
    ]
    return jnp.stack(rows)


def _mask_valid(words, n_valid) -> jnp.ndarray:
    S, R, L = words.shape
    widx = jnp.arange(R * L, dtype=jnp.int32).reshape(1, R, L)
    return jnp.where(widx < n_valid.reshape(S, 1, 1), words, jnp.uint32(0))


def _rows_u8(words: jnp.ndarray) -> jnp.ndarray:
    import jax

    return jax.lax.bitcast_convert_type(words, jnp.uint8).reshape(
        words.shape[0], -1
    )


def _u8_rows_to_u32(rows: jnp.ndarray, R: int) -> jnp.ndarray:
    import jax

    return jax.lax.bitcast_convert_type(
        rows.reshape(-1, 4), jnp.uint32
    ).reshape(R, 128)


def _parity(words, q_coef, parity: str):
    if parity == "none":
        return None, None
    data = _rows_u8(words)  # (S, R*512) uint8
    R = words.shape[1]
    p = _u8_rows_to_u32(raid.raid5_encode(data), R)
    if parity == "raid5":
        return p, None
    q = jnp.zeros_like(data[0])
    for s in range(data.shape[0]):
        q = q ^ raid.gf_mul(q_coef[s, 0].astype(jnp.uint8), data[s])
    return p, _u8_rows_to_u32(q, R)


def seal_stripe_ref(
    codes, keys, nonces, n_valid, q_coef, *, parity: str = "raid6"
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], Optional[jnp.ndarray]]:
    """Staged seal: same signature/outputs as ``seal_stripe_pallas``."""
    R = codes.shape[1]
    packed = _pack_rows(codes)                      # pass 1
    ks = _keystream_rows(keys, nonces, R)           # pass 2
    sealed = packed ^ ks                            # pass 3
    sealed = _mask_valid(sealed, n_valid)           # pass 4
    p, q = _parity(sealed, q_coef, parity)          # passes 5-6
    return sealed, p, q


def unseal_stripe_ref(
    sealed, keys, nonces, n_valid, q_coef, *, parity: str = "raid6"
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], Optional[jnp.ndarray]]:
    """Staged decode twin: same outputs as ``unseal_stripe_pallas``."""
    R = sealed.shape[1]
    ks = _keystream_rows(keys, nonces, R)
    words = _mask_valid(sealed ^ ks, n_valid)
    codes = _unpack_rows(words)
    p, q = _parity(sealed, q_coef, parity)
    return codes, p, q
