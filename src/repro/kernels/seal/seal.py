"""Pallas TPU kernel: fused archival seal datapath (and its unseal twin).

One grid step seals one (8, 512)-int8 tile of one shard: pack to uint32
lanes, generate the ChaCha20 keystream in-VMEM, XOR-seal, and fold the tile
into the stripe's RAID-5 P / RAID-6 Q parity accumulators.  The shard axis is
the innermost grid dimension, so the parity output block for a given tile
index stays resident while all S shards stream through it (classic Pallas
accumulation via a revisited output block).

Memory-bound VPU kernel: HBM traffic is read-int8 + write-uint32(+parity),
vs ~6 HBM round-trips for the staged jnp pipeline (flatten/pack, keystream,
XOR, mask, uint8 bitcast, per-shard parity loops) — the exact multi-pass
pattern the paper's CSD offload eliminates.

GF(256) (poly 0x11D, generator 2 — same field as ``core/archival/raid.py``)
is computed without tables: the per-shard coefficient g^s is a kernel operand
and the multiply is an 8-step SWAR shift/xor peasant product on 4 bytes
packed per uint32 lane, which is bit-identical to the log/antilog-table
reference and pure VPU work.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.crypto.chacha import CONSTANTS, chacha_rounds_planes

__all__ = ["seal_stripe_pallas", "unseal_stripe_pallas", "keystream_batch",
           "R_TILE", "LANES", "ROW_BYTES", "WORDS_PER_TILE"]

R_TILE = 8                        # sublane-aligned rows per grid step
LANES = 128                       # uint32 words per row
ROW_BYTES = 4 * LANES             # int8 payload bytes per row
WORDS_PER_TILE = R_TILE * LANES   # 1024 words / 64 ChaCha blocks per tile
_BLK_R, _BLK_C = 8, 8             # 64 block counters laid out 2-D for iota


def _keystream_tile(key_vec, nonce_vec, counter_base):
    """(R_TILE, LANES) uint32 keystream tile starting at block counter_base.

    Word w of the tile is word w%16 of ChaCha block counter_base + w//16 —
    the same contiguous mapping as ``chacha.keystream``, so the fused seal is
    bit-identical to the staged xor_stream path.
    """
    ctr = (
        counter_base
        + jax.lax.broadcasted_iota(jnp.uint32, (_BLK_R, _BLK_C), 0) * jnp.uint32(_BLK_C)
        + jax.lax.broadcasted_iota(jnp.uint32, (_BLK_R, _BLK_C), 1)
    )
    state = (
        [jnp.full((_BLK_R, _BLK_C), c, jnp.uint32) for c in CONSTANTS]
        + [jnp.broadcast_to(key_vec[i], (_BLK_R, _BLK_C)) for i in range(8)]
        + [ctr]
        + [jnp.broadcast_to(nonce_vec[i], (_BLK_R, _BLK_C)) for i in range(3)]
    )
    ks = jnp.stack(chacha_rounds_planes(state), axis=-1)  # (8, 8, 16)
    return ks.reshape(R_TILE, LANES)


def keystream_batch(keys, nonces, R: int):
    """(B, R, LANES) uint32 keystream for B shards, counter0 = 0 each.

    Row r lane l of shard b is word l%16 of ChaCha block r*8 + l//16 under
    key/nonce b — the same contiguous mapping as ``_keystream_tile`` and the
    staged ``_keystream_rows`` reference, with the shard axis batched as a
    third plane dimension so a whole stripe batch runs one fused elementwise
    ChaCha graph.  This is the keystream producer of the one-launch
    entropy+seal kernel (``repro.kernels.fused``).
    """
    B = keys.shape[0]
    shp = (B, R, _BLK_C)
    ctr = (
        jax.lax.broadcasted_iota(jnp.uint32, shp, 1) * jnp.uint32(_BLK_C)
        + jax.lax.broadcasted_iota(jnp.uint32, shp, 2)
    )
    state = (
        [jnp.full(shp, c, jnp.uint32) for c in CONSTANTS]
        + [jnp.broadcast_to(keys[:, i, None, None], shp) for i in range(8)]
        + [ctr]
        + [jnp.broadcast_to(nonces[:, i, None, None], shp) for i in range(3)]
    )
    ks = jnp.stack(chacha_rounds_planes(state), axis=-1)  # (B, R, 8, 16)
    return ks.reshape(B, R, LANES)


def _gf_mul_const_u32(x, coef):
    """GF(256) multiply of 4 packed bytes per uint32 lane by scalar coef.

    Peasant product over the 8 bits of coef; xtime is the SWAR shift/xor
    form of multiply-by-x mod 0x11D (0x1D = (1<<4)^(1<<3)^(1<<2)^1), so no
    byte ever carries into its neighbour.
    """
    res = jnp.zeros_like(x)
    for bit in range(8):
        lsb = (coef >> jnp.uint32(bit)) & jnp.uint32(1)
        res = res ^ (x & (jnp.uint32(0) - lsb))
        hi = (x >> jnp.uint32(7)) & jnp.uint32(0x01010101)
        red = (hi << jnp.uint32(4)) ^ (hi << jnp.uint32(3)) ^ (hi << jnp.uint32(2)) ^ hi
        x = ((x << jnp.uint32(1)) & jnp.uint32(0xFEFEFEFE)) ^ red
    return res


def _word_index_tile(tile_i):
    """Global word index of each (row, lane) position in tile tile_i."""
    return (
        tile_i * WORDS_PER_TILE
        + jax.lax.broadcasted_iota(jnp.int32, (R_TILE, LANES), 0) * LANES
        + jax.lax.broadcasted_iota(jnp.int32, (R_TILE, LANES), 1)
    )


def _accumulate_parity(sealed, p_ref, q_ref, qcoef, shard_id):
    first = shard_id == 0

    @pl.when(first)
    def _init_p():
        p_ref[...] = sealed

    @pl.when(jnp.logical_not(first))
    def _acc_p():
        p_ref[...] = p_ref[...] ^ sealed

    if q_ref is not None:
        contrib = _gf_mul_const_u32(sealed, qcoef)

        @pl.when(first)
        def _init_q():
            q_ref[...] = contrib

        @pl.when(jnp.logical_not(first))
        def _acc_q():
            q_ref[...] = q_ref[...] ^ contrib


def _seal_kernel(codes_ref, keys_ref, nonces_ref, nvalid_ref, qcoef_ref, *out_refs,
                 with_p: bool, with_q: bool):
    i = pl.program_id(0)  # tile index within the shard
    s = pl.program_id(1)  # shard index within the stripe
    sealed_ref = out_refs[0]
    p_ref = out_refs[1] if with_p else None
    q_ref = out_refs[2] if with_q else None

    # (a) pack: int8 x4 -> uint32 little-endian lanes
    codes = codes_ref[...].reshape(R_TILE, LANES, 4)
    b = (codes.astype(jnp.int32) & 0xFF).astype(jnp.uint32)
    packed = (
        b[..., 0]
        | (b[..., 1] << jnp.uint32(8))
        | (b[..., 2] << jnp.uint32(16))
        | (b[..., 3] << jnp.uint32(24))
    )

    # (b) in-kernel ChaCha20 keystream, (c) XOR-seal, masked to the shard's
    # valid length so padded tails stay zero (parity then matches a staged
    # zero-padded reference exactly).
    ks = _keystream_tile(
        keys_ref[0], nonces_ref[0], jnp.uint32(i * (WORDS_PER_TILE // 16))
    )
    valid = _word_index_tile(i) < nvalid_ref[0, 0]
    sealed = jnp.where(valid, packed ^ ks, jnp.uint32(0))
    sealed_ref[...] = sealed[None]

    # (d) RAID parity accumulated across the shard grid axis
    if with_p:
        _accumulate_parity(sealed, p_ref, q_ref, qcoef_ref[0, 0], s)


def _unseal_kernel(sealed_ref, keys_ref, nonces_ref, nvalid_ref, qcoef_ref, *out_refs,
                   with_p: bool, with_q: bool):
    i = pl.program_id(0)
    s = pl.program_id(1)
    codes_ref = out_refs[0]
    p_ref = out_refs[1] if with_p else None
    q_ref = out_refs[2] if with_q else None

    sealed = sealed_ref[...].reshape(R_TILE, LANES)

    ks = _keystream_tile(
        keys_ref[0], nonces_ref[0], jnp.uint32(i * (WORDS_PER_TILE // 16))
    )
    valid = _word_index_tile(i) < nvalid_ref[0, 0]
    words = jnp.where(valid, sealed ^ ks, jnp.uint32(0))

    # unpack uint32 lanes back to signed int8 codes (explicit two's
    # complement so the cast is backend-independent)
    v = jnp.stack(
        [((words >> jnp.uint32(8 * k)) & jnp.uint32(0xFF)).astype(jnp.int32)
         for k in range(4)],
        axis=-1,
    )
    signed = v - ((v & 0x80) << 1)
    codes_ref[...] = signed.reshape(1, R_TILE, ROW_BYTES).astype(jnp.int8)

    # parity recomputed over the sealed bodies *as stored* -> integrity check
    if with_p:
        _accumulate_parity(sealed, p_ref, q_ref, qcoef_ref[0, 0], s)


def _parity_flags(parity: str):
    if parity not in ("none", "raid5", "raid6"):
        raise ValueError(f"unknown parity mode {parity!r}")
    return parity != "none", parity == "raid6"


def _stripe_call(kernel_body, payload, keys, nonces, n_valid, q_coef,
                 payload_spec, out_spec, out_struct, parity, interpret):
    S, R = payload.shape[0], payload.shape[1]
    if R % R_TILE:
        raise ValueError(f"rows {R} not a multiple of {R_TILE}")
    with_p, with_q = _parity_flags(parity)
    T = R // R_TILE
    out_shape: List[jax.ShapeDtypeStruct] = [out_struct]
    out_specs: List[pl.BlockSpec] = [out_spec]
    if with_p:
        out_shape.append(jax.ShapeDtypeStruct((R, LANES), jnp.uint32))
        out_specs.append(pl.BlockSpec((R_TILE, LANES), lambda i, s: (i, 0)))
    if with_q:
        out_shape.append(jax.ShapeDtypeStruct((R, LANES), jnp.uint32))
        out_specs.append(pl.BlockSpec((R_TILE, LANES), lambda i, s: (i, 0)))
    outs = pl.pallas_call(
        functools.partial(kernel_body, with_p=with_p, with_q=with_q),
        grid=(T, S),  # shard innermost: parity block revisited S times
        in_specs=[
            payload_spec,
            pl.BlockSpec((1, 8), lambda i, s: (s, 0)),
            pl.BlockSpec((1, 3), lambda i, s: (s, 0)),
            pl.BlockSpec((1, 1), lambda i, s: (s, 0)),
            pl.BlockSpec((1, 1), lambda i, s: (s, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(payload, keys, nonces, n_valid, q_coef)
    sealed = outs[0]
    p = outs[1] if with_p else None
    q = outs[2] if with_q else None
    return sealed, p, q


def seal_stripe_pallas(codes, keys, nonces, n_valid, q_coef, *,
                       parity: str = "raid6", interpret: bool = True):
    """Fused seal of one stripe in a single kernel launch.

    codes: (S, R, 512) int8 codec payload, zero-padded per shard.
    keys: (S, 8) uint32 ChaCha session keys; nonces: (S, 3) uint32.
    n_valid: (S, 1) int32 valid uint32-word count per shard.
    q_coef: (S, 1) uint32 GF(256) RAID-6 coefficient g^s per shard.

    Returns (sealed (S, R, 128) uint32, P (R, 128) uint32 | None,
    Q (R, 128) uint32 | None) — P/Q per ``parity`` mode.
    """
    S, R, C = codes.shape
    if C != ROW_BYTES:
        raise ValueError(f"expected row width {ROW_BYTES}, got {C}")
    return _stripe_call(
        _seal_kernel, codes, keys, nonces, n_valid, q_coef,
        pl.BlockSpec((1, R_TILE, ROW_BYTES), lambda i, s: (s, i, 0)),
        pl.BlockSpec((1, R_TILE, LANES), lambda i, s: (s, i, 0)),
        jax.ShapeDtypeStruct((S, R, LANES), jnp.uint32),
        parity, interpret,
    )


def unseal_stripe_pallas(sealed, keys, nonces, n_valid, q_coef, *,
                         parity: str = "raid6", interpret: bool = True):
    """Fused decode twin: keystream + XOR + unpack + parity-recompute.

    sealed: (S, R, 128) uint32 bodies as stored (zero-padded tails).
    Returns (codes (S, R, 512) int8, P, Q) where P/Q are recomputed from the
    stored bodies so callers can verify stripe integrity against the parity
    written at seal time.
    """
    S, R, C = sealed.shape
    if C != LANES:
        raise ValueError(f"expected {LANES} lanes, got {C}")
    return _stripe_call(
        _unseal_kernel, sealed, keys, nonces, n_valid, q_coef,
        pl.BlockSpec((1, R_TILE, LANES), lambda i, s: (s, i, 0)),
        pl.BlockSpec((1, R_TILE, ROW_BYTES), lambda i, s: (s, i, 0)),
        jax.ShapeDtypeStruct((S, R, ROW_BYTES), jnp.int8),
        parity, interpret,
    )
