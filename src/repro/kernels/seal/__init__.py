"""Fused CSD seal datapath: one Pallas pass for pack + ChaCha20 + XOR + parity.

Salient Store's Fig. 1 runs the archival flow *on the storage device* so the
host link only ever carries compressed, sealed bytes.  This package is the
TPU analogue of that CSD flow — each stage of the paper's device-side
pipeline maps onto one step of a single VMEM-resident kernel pass:

======================  =======================================================
Paper Fig. 1 CSD stage  Kernel stage (one grid step, one VMEM tile)
======================  =======================================================
"compress" output       int8 codec codes stream in from HBM (read #1, the
                        only read of the payload)
bitstream packing       (a) int8 x4 -> uint32 lane pack (shift/or, VPU)
"encrypt"               (b) ChaCha20 keystream generated *in kernel* from the
                        per-shard session key (RFC 8439 double rounds on
                        uint32 planes — pure add/rotate/xor VPU work), then
                        (c) XOR-seal of the packed payload
"RAID" parity           (d) RAID-5 P (XOR) and RAID-6 Q (GF(256) multiply by
                        g^shard via SWAR shift/xor, no tables) accumulated
                        across the stripe's S shards in the revisited parity
                        output block
======================  =======================================================

HBM traffic per stripe tile is exactly read-int8 + write-uint32(+parity);
the staged jnp path (``ref.py``) makes ~6 separate HBM round-trips for the
same math.  ``ref.py`` is the bit-exact oracle, ``ops.py`` the padding /
dispatch layer (``use_pallas`` flag, interpret autodetect off-TPU).
"""

from repro.kernels.seal.ops import (  # noqa: F401
    SealedStripe,
    datapath_traffic,
    seal_stripe,
    unseal_stripe,
)
