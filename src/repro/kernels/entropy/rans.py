"""Pallas TPU kernel: interleaved-rANS byte coder for the archival datapath.

One grid step codes one shard of a stripe.  The shard's flat int8 payload is
laid out as (T, 128) rows, and the 128 columns are 128 *independent* rANS
lanes (lane l owns bytes l, 128+l, 256+l, ...), so every step of the
sequential coding loop is one (128,)-wide VPU vector op — the interleaved
layout from Giesen's SIMD rANS, with the lane axis mapped onto the TPU lane
dimension.

Per shard the kernel runs three fused stages without leaving VMEM:

  1. histogram pass over all T*128 bytes (scatter-add into 256 bins);
  2. static frequency-table build (:func:`build_freq_table`): integer-exact
     normalization to ``M = 2**PROB_BITS`` total, every present symbol kept
     >= 1 — the table is emitted as an output (it ships in the compressed
     stream header, so decode never re-derives it from data);
  3. the interleaved encode loop, processed in *reverse* row order (rANS
     encodes backwards so decode streams forwards), emitting at most one
     16-bit word per lane per row (32-bit states, 16-bit renormalization:
     state in [2^16, 2^32) means renorm fires at most once per symbol, which
     is what makes the loop branchlessly vectorizable).

All arithmetic is integer (uint32 states, shifts, masked compares, one u32
divide by the per-symbol frequency): there is no float anywhere in the
coder, so kernel-vs-reference bit-exactness cannot be broken by XLA float
rewrites (cf. the x/c -> x*(1/c) jit canonicalization that bites float
kernels).

The encoder does NOT compact its output: it writes a dense (T, 128) word
buffer plus an emission mask, and ``ops.py`` runs the (shared, order-free)
prefix-sum compaction into the final byte stream.  The decoder twin takes
the per-lane word streams re-gathered to (T, 128) plus the header tables
and states, and reproduces the exact input bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "N_LANES",
    "PROB_BITS",
    "PROB_SCALE",
    "RANS_L",
    "T_TILE",
    "build_freq_table",
    "slot_to_symbol",
    "rans_encode_pallas",
    "rans_decode_pallas",
]

N_LANES = 128                 # interleaved rANS lanes == TPU lane width
PROB_BITS = 12                # frequency table quantization: sum(freq) = 4096
PROB_SCALE = 1 << PROB_BITS
RANS_L = 1 << 16              # state lower bound; 16-bit renormalization
T_TILE = 8                    # sublane-aligned row granularity


def build_freq_table(counts: jax.Array) -> jax.Array:
    """(256,) int32 byte counts -> (256,) int32 freqs summing to PROB_SCALE.

    Integer-exact and overflow-safe in int32: counts are right-shifted until
    their total is < 2^19 (so count*budget < 2^31), every present symbol is
    reserved one slot up front, the remaining budget is floor-allocated
    proportionally, and the rounding remainder goes to the most frequent
    symbol.  Present symbols always get freq >= 1; the sum is exactly
    PROB_SCALE.  Shared verbatim by the Pallas kernel and the jnp reference
    (same role as ``chacha_rounds_planes`` in the seal kernel).
    """
    present = (counts > 0).astype(jnp.int32)
    total = counts.sum()
    # shift = #{k : total >= 2^(19+k)}  -- smallest shift with total>>shift < 2^19
    # (iota, not arange: materialized constants cannot be captured by a
    # pallas kernel body, computed iotas can)
    thresholds = 19 + jax.lax.broadcasted_iota(jnp.int32, (12,), 0)
    shift = (total >= (1 << thresholds)).sum()
    c2 = jnp.maximum(counts >> shift, present)
    n2 = jnp.maximum(c2.sum(), 1)
    budget = PROB_SCALE - present.sum()
    extra = (c2 * budget) // n2        # c2 < 2^19, budget < 2^12: no overflow
    freq = present + extra
    rem = budget - extra.sum()
    return freq.at[jnp.argmax(c2)].add(rem)


def slot_to_symbol(freq: jax.Array, slots: jax.Array) -> jax.Array:
    """Inverse cumulative lookup: slot in [0, PROB_SCALE) -> symbol id.

    ``side='right'`` on the inclusive cumsum skips zero-frequency symbols
    (their cumsum entries duplicate the predecessor).
    """
    return jnp.searchsorted(
        jnp.cumsum(freq), slots, side="right"
    ).astype(jnp.int32)


def _histogram(vals: jax.Array, vmask: jax.Array) -> jax.Array:
    """Exact byte histogram over the valid positions of a (T, 128) tile.

    Invalid (padding) positions are routed to a 257th overflow bin and
    dropped, so pad zeros cannot distort the frequency table.
    """
    idx = jnp.where(vmask, vals, 256)
    return jnp.zeros((257,), jnp.int32).at[idx.reshape(-1)].add(1)[:256]


def _enc_step(x, f, c):
    """One interleaved encode step: (states, freq, cum) -> (states', word, emit).

    Renorm-before-update with the 16-bit word convention: emit the low half
    when x >= f << 20 (written shift-compare so f = PROB_SCALE cannot
    overflow the uint32 threshold).
    """
    emit = (x >> jnp.uint32(20)) >= f
    word = (x & jnp.uint32(0xFFFF)).astype(jnp.uint16)
    x = jnp.where(emit, x >> jnp.uint32(16), x)
    # padding lanes can look up a zero-frequency symbol; their state update
    # is discarded by the caller, but the divide must still be defined on
    # every backend (clamping is a no-op for any real symbol: freq >= 1)
    f1 = jnp.maximum(f, jnp.uint32(1))
    x = ((x // f1) << jnp.uint32(PROB_BITS)) + (x % f1) + c
    return x, word, emit


def _dec_step(x, freq, cum_excl, slot2sym):
    """One interleaved decode step -> (pre-renorm states, symbols, need-word)."""
    slot = (x & jnp.uint32(PROB_SCALE - 1)).astype(jnp.int32)
    s = slot2sym[slot]
    f = freq[s].astype(jnp.uint32)
    c = cum_excl[s].astype(jnp.uint32)
    x = f * (x >> jnp.uint32(PROB_BITS)) + slot.astype(jnp.uint32) - c
    return x, s, x < jnp.uint32(RANS_L)


def _lane_iota() -> jax.Array:
    return jax.lax.broadcasted_iota(jnp.int32, (N_LANES,), 0)


def _encode_kernel(codes_ref, nvalid_ref, words_ref, mask_ref, freq_ref,
                   state_ref):
    vals = (codes_ref[0].astype(jnp.int32)) & 0xFF          # (T, 128)
    T = vals.shape[0]
    nv = nvalid_ref[0, 0]
    gidx = (
        jax.lax.broadcasted_iota(jnp.int32, (T, N_LANES), 0) * N_LANES
        + jax.lax.broadcasted_iota(jnp.int32, (T, N_LANES), 1)
    )

    freq = build_freq_table(_histogram(vals, gidx < nv))     # (256,)
    cum = jnp.cumsum(freq) - freq                            # exclusive
    f_u = freq.astype(jnp.uint32)
    c_u = cum.astype(jnp.uint32)

    def body(j, carry):
        x, words, mask = carry
        r = T - 1 - j                                        # reverse row order
        s = jax.lax.dynamic_index_in_dim(vals, r, 0, keepdims=False)
        valid = (r * N_LANES + _lane_iota()) < nv
        x2, w, m = _enc_step(x, f_u[s], c_u[s])
        x = jnp.where(valid, x2, x)                          # pad lanes: no-op
        m = m & valid
        words = jax.lax.dynamic_update_index_in_dim(words, w, r, 0)
        mask = jax.lax.dynamic_update_index_in_dim(
            mask, m.astype(jnp.uint8), r, 0
        )
        return x, words, mask

    x0 = jnp.full((N_LANES,), RANS_L, jnp.uint32)
    x, words, mask = jax.lax.fori_loop(
        0,
        T,
        body,
        (x0, jnp.zeros((T, N_LANES), jnp.uint16),
         jnp.zeros((T, N_LANES), jnp.uint8)),
    )
    words_ref[...] = words[None]
    mask_ref[...] = mask[None]
    freq_ref[...] = freq[None]
    state_ref[...] = x[None]


def _decode_kernel(stream_ref, freq_ref, state_ref, nvalid_ref, codes_ref):
    lane_words = stream_ref[0]                               # (T, 128) u16
    freq = freq_ref[0]                                       # (256,) int32
    T = lane_words.shape[0]
    nv = nvalid_ref[0, 0]
    cum_excl = jnp.cumsum(freq) - freq
    slot2sym = slot_to_symbol(
        freq, jax.lax.broadcasted_iota(jnp.int32, (PROB_SCALE,), 0)
    )

    def body(i, carry):
        x, ptr, out = carry
        valid = (i * N_LANES + _lane_iota()) < nv
        x2, s, need = _dec_step(x, freq, cum_excl, slot2sym)
        need = need & valid
        w = jnp.take_along_axis(
            lane_words, jnp.minimum(ptr, T - 1)[None, :], axis=0
        )[0].astype(jnp.uint32)
        x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w, x2)
        x = jnp.where(valid, x2, x)                          # pad lanes: no-op
        ptr = ptr + need.astype(jnp.int32)
        signed = jnp.where(
            valid, (s - ((s & 0x80) << 1)), 0
        ).astype(jnp.int8)                                   # two's complement
        out = jax.lax.dynamic_update_index_in_dim(out, signed, i, 0)
        return x, ptr, out

    x0 = state_ref[0]
    _, _, out = jax.lax.fori_loop(
        0,
        T,
        body,
        (x0, jnp.zeros((N_LANES,), jnp.int32),
         jnp.zeros((T, N_LANES), jnp.int8)),
    )
    codes_ref[...] = out[None]


def rans_encode_pallas(codes, n_valid, *, interpret: bool = True):
    """Encode all S shards of a stripe in one launch (grid over shards).

    codes: (S, T, 128) int8 payload rows, zero-padded; T % T_TILE == 0.
    n_valid: (S, 1) int32 valid byte count per shard — positions past it are
    padding and are excluded from both the histogram and the coding loop
    (their lanes idle, costing zero stream bytes).
    Returns (words (S, T, 128) uint16, mask (S, T, 128) uint8,
    freq (S, 256) int32, states (S, 128) uint32): the dense emission buffer +
    per-row emission mask (compacted by the caller), the per-shard frequency
    tables, and the final lane states the decoder starts from.
    """
    S, T, L = codes.shape
    if L != N_LANES:
        raise ValueError(f"expected {N_LANES} lanes, got {L}")
    if T % T_TILE:
        raise ValueError(f"rows {T} not a multiple of {T_TILE}")
    return pl.pallas_call(
        _encode_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, T, N_LANES), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, N_LANES), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, T, N_LANES), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, 256), lambda s: (s, 0)),
            pl.BlockSpec((1, N_LANES), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, T, N_LANES), jnp.uint16),
            jax.ShapeDtypeStruct((S, T, N_LANES), jnp.uint8),
            jax.ShapeDtypeStruct((S, 256), jnp.int32),
            jax.ShapeDtypeStruct((S, N_LANES), jnp.uint32),
        ],
        interpret=interpret,
    )(codes, n_valid)


def rans_decode_pallas(lane_words, freq, states, n_valid, *,
                       interpret: bool = True):
    """Decode twin: per-lane word streams + header tables -> original bytes.

    lane_words: (S, T, 128) uint16 — word j of lane l at [s, j, l] (the
    caller re-gathers the flat stream into this layout; tails past each
    lane's length are never consumed so their value is irrelevant).
    freq: (S, 256) int32 tables; states: (S, 128) uint32 initial lane states.
    n_valid: (S, 1) int32 — must equal the encoder's (the decoder skips the
    same padding positions the encoder skipped).
    Returns (S, T, 128) int8 decoded payload rows, zeros past n_valid.
    """
    S, T, L = lane_words.shape
    if L != N_LANES:
        raise ValueError(f"expected {N_LANES} lanes, got {L}")
    return pl.pallas_call(
        _decode_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, T, N_LANES), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, 256), lambda s: (s, 0)),
            pl.BlockSpec((1, N_LANES), lambda s: (s, 0)),
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, N_LANES), lambda s: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, T, N_LANES), jnp.int8),
        interpret=interpret,
    )(lane_words, freq, states, n_valid)
