"""Pallas TPU kernel: interleaved-rANS byte coder for the archival datapath.

One launch codes all S shards of a stripe: the stripe is the kernel block,
and the shards ride the batch axis of every vector op, so one loop step
feeds S x 128 lanes to the vector unit instead of idling per shard.  A
shard's flat int8 payload is laid out as (T, 128) rows whose 128 columns
are 128 *independent* rANS lanes (lane l owns bytes l, 128+l, 256+l, ...),
the interleaved layout from Giesen's SIMD rANS with the lane axis mapped
onto the TPU lane dimension.  The loop *schedule* is a static knob
(``rows_per_step``): on TPU each trip advances an (N_GROUPS=8, 128)
lane-group tile — one full sublane-by-lane vreg — cutting the sequential
trip count from T to T/8; under interpret (CPU CI) each trip advances one
row, because many tiny ops schedule ~5x cheaper there than few fat fused
bodies.  The schedule cannot change a single output bit — only which ops
compute them — and the suite asserts both schedules bit-identical.
(Widening the *state* interleave instead — G x 128 independent streams —
was measured and rejected: every extra rANS stream wastes >= 16 bits of
initial-state flush for zero entropy gain, ~2.7 KiB per 64 KiB shard,
about a 10% compression-ratio loss.)

Per shard the kernel runs three fused stages without leaving VMEM:

  1. histogram over all T*128 bytes as a one-hot *matmul*: the byte splits
     into hi/lo nibbles and hist.reshape(16, 16) = onehot(hi)^T @
     onehot(lo), an (N, 16) x (N, 16) f32 contraction — exact because
     every partial sum is an integer <= T*128 <= 2^24, below the f32
     mantissa — no scatter-add anywhere (``.at[...].add`` serializes on
     TPU and CPU alike; ``test_kernel_hygiene.py`` now bans it from
     kernel sources).  f32 operands hit the fast GEMM path on the CPU
     interpret backend, where the int8-accumulate-int32 form fell off to
     a naive loop and dominated the whole encode;
  2. static table build: :func:`build_freq_table` (integer-exact
     normalization to ``M = 2**PROB_BITS``, every present symbol >= 1)
     plus :func:`build_enc_tables`, which precomputes per-symbol
     reciprocals so the hot loop never divides: the Granlund-Montgomery
     (mprime, shift) fixed-point pair, and an f32 reciprocal for the
     error-repaired fast path.  The frequency table ships in the stream
     header; the reciprocals are *derived* state — decode is
     multiplication-only and provably never reads them, so shipping them
     would inflate every stream by 1.25 KiB for nothing;
  3. the coding loop, processed in *reverse* row order (rANS encodes
     backwards so decode streams forwards), emitting at most one 16-bit
     word per lane per row (32-bit states, 16-bit renormalization: state
     in [2^16, 2^32) means renorm fires at most once per symbol, which is
     what makes the loop branchlessly vectorizable).  Symbol tables are
     pregathered per position before the loop, so the hot path reads only
     aligned row slices; rows are coded in two phases split on the
     n_valid boundary — rows fully inside every shard's payload skip the
     per-lane valid masking entirely, and fully-empty padding rows (pow2
     bucketing leaves up to half) are never visited.

The per-symbol division x // freq runs as one of three exact,
bit-identical strategies (see :func:`_enc_step`): the hardware udiv
(interpret default), the error-repaired f32 reciprocal multiply (TPU
default — Mosaic has no integer division, which is what kept the PR-3
coder off real hardware), or the all-integer Granlund-Montgomery mulhi.
The f32 path is immune to the x/c -> x*(1/c) jit canonicalization that
breaks naive float kernels: the renorm invariant bounds the quotient by
2^20, so any faithful rounding stays within +-0.2 of the true quotient
and the integer repair makes the result exact.  Everything else in the
coder is u32/i32 (and the histogram's f32 counts are
exact-by-construction), so kernel-vs-reference bit-exactness survives
every backend.

Stream format (``STREAM_VERSION = 1``): the header layout is unchanged
from version 0 — freq u16[256] | lane_lens u32[128] | states u32[128] —
but the word area is packed in *row-major decoder-read order* (the global
order a forward decode consumes words: row by row, lanes in order within
a row) instead of version 0's per-lane-contiguous runs.  Row-major
packing is what the vectorized decoder wants: each step takes the next
popcount(need) words off the stream front with an in-register prefix
sum, so no per-lane offset table is parsed and no ``searchsorted`` exists
anywhere — the slot->symbol table is a direct cumulative-bucket fill
(:func:`slot_to_symbol`: scatter-max the symbol ids at their cumulative
start slots, then a running max).  The version bump never changes
``n_comp`` (same header bytes, same word count), so the compression ratio
is identical by construction; version 0 streams still decode through the
lane-major twin (``rans_decode_pallas_v0``), and the stream version rides
in the archive manifest next to the codec name.

The encoder does NOT compact its output: it writes a dense (T, 128) word
buffer plus an emission mask, and ``ops.py`` runs the (shared,
order-free) rank-select compaction into the final byte stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "N_LANES",
    "N_GROUPS",
    "PROB_BITS",
    "PROB_SCALE",
    "RANS_L",
    "T_TILE",
    "STREAM_VERSION",
    "build_freq_table",
    "build_enc_tables",
    "build_dec_table",
    "slot_to_symbol",
    "rans_encode_body",
    "rans_encode_pallas",
    "rans_decode_pallas",
    "rans_decode_pallas_v0",
]

N_LANES = 128                 # interleaved rANS lanes == TPU lane width
N_GROUPS = 8                  # lane-group rows per tile == TPU sublane width
PROB_BITS = 12                # frequency table quantization: sum(freq) = 4096
PROB_SCALE = 1 << PROB_BITS
RANS_L = 1 << 16              # state lower bound; 16-bit renormalization
T_TILE = 8                    # sublane-aligned row granularity (== N_GROUPS)
STREAM_VERSION = 1            # row-major word order; 0 = PR-4 lane-major

_SYM_MASK = 0x1FFF            # 13 bits: freq and cum both reach 4096


def build_freq_table(counts: jax.Array) -> jax.Array:
    """(256,) int32 byte counts -> (256,) int32 freqs summing to PROB_SCALE.

    Integer-exact and overflow-safe in int32: counts are right-shifted until
    their total is < 2^19 (so count*budget < 2^31), every present symbol is
    reserved one slot up front, the remaining budget is floor-allocated
    proportionally, and the rounding remainder goes to the most frequent
    symbol.  Present symbols always get freq >= 1; the sum is exactly
    PROB_SCALE.  Shared verbatim by the Pallas kernel and the jnp reference
    (same role as ``chacha_rounds_planes`` in the seal kernel).
    """
    present = (counts > 0).astype(jnp.int32)
    total = counts.sum()
    # shift = #{k : total >= 2^(19+k)}  -- smallest shift with total>>shift < 2^19
    # (iota, not arange: materialized constants cannot be captured by a
    # pallas kernel body, computed iotas can)
    thresholds = 19 + jax.lax.broadcasted_iota(jnp.int32, (12,), 0)
    shift = (total >= (1 << thresholds)).sum()
    c2 = jnp.maximum(counts >> shift, present)
    n2 = jnp.maximum(c2.sum(), 1)
    budget = PROB_SCALE - present.sum()
    extra = (c2 * budget) // n2        # c2 < 2^19, budget < 2^12: no overflow
    freq = present + extra
    rem = budget - extra.sum()
    # remainder to the most frequent symbol, scatter-free (one-hot select)
    sym = jax.lax.broadcasted_iota(jnp.int32, (256,), 0)
    return freq + jnp.where(sym == jnp.argmax(c2), rem, 0)


def build_enc_tables(freq: jax.Array):
    """(256,) int32 freqs -> per-symbol encode tables (packed, mprime, rcp).

    ``packed[s] = f | (shift-1) << 13 | cum_excl << 19`` (f clamped to
    >= 1: only padding lanes ever look up an absent symbol, their update
    is discarded, and the clamp keeps every division strategy defined).
    ``mprime[s]`` is the Granlund-Montgomery round-up integer reciprocal
    ``ceil(2^(32+shift)/f) - 2^32`` (fits u32), giving the exact quotient

        t = mulhi(x, mprime);  q = (t + ((x - t) >> 1)) >> (shift - 1)

    for every f in [2, PROB_SCALE] and x < 2^32 (f <= 1 short-circuits to
    q = x in :func:`_enc_step`; brute-verified over all f in the tests).
    ``rcp[s] = 1/f`` in f32 drives the fast error-repaired strategy (see
    ``division="rcp32"`` in :func:`_enc_step`).  Built once per shard right
    after :func:`build_freq_table` — the two table divides below run
    256-wide once per shard, not per symbol, and never appear in the hot
    loop.
    """
    f = freq.astype(jnp.uint32)
    cum = (jnp.cumsum(freq) - freq).astype(jnp.uint32)
    # shift = ceil_log2(f) = #{k in [0,13) : 2^k < f}
    pows = jnp.uint32(1) << jax.lax.broadcasted_iota(jnp.uint32, (256, 13), 1)
    shift = (pows < f[:, None]).astype(jnp.uint32).sum(axis=1)
    s1 = jnp.maximum(shift, jnp.uint32(1)) - jnp.uint32(1)
    # ceil(2^(32+shift)/f) - 2^32 via 16+16-bit long division, u32-only:
    # hi2 = 2^(16+shift) <= 2^28; q_hi in [2^16, 2^17) so q_hi - 2^16 < 2^16
    fq = jnp.maximum(f, jnp.uint32(1))
    hi2 = jnp.uint32(1) << (jnp.uint32(16) + shift)
    q_hi = hi2 // fq
    num = (hi2 - q_hi * fq) << jnp.uint32(16)
    q_lo = num // fq
    r2 = num - q_lo * fq
    mprime = (
        ((q_hi - jnp.uint32(1 << 16)) << jnp.uint32(16))
        + q_lo
        + (r2 != 0).astype(jnp.uint32)
    )
    packed = fq | (s1 << jnp.uint32(13)) | (cum << jnp.uint32(19))
    return packed, mprime, jnp.float32(1.0) / fq.astype(jnp.float32)


def build_dec_table(freq: jax.Array) -> jax.Array:
    """(256,) int32 freqs -> packed u32 decode table ``f | cum_excl << 13``."""
    f = freq.astype(jnp.uint32)
    cum = (jnp.cumsum(freq) - freq).astype(jnp.uint32)
    return f | (cum << jnp.uint32(13))


def slot_to_symbol(freq: jax.Array) -> jax.Array:
    """(256,) freqs -> (PROB_SCALE,) inverse cumulative table, slot -> symbol.

    Direct cumulative-bucket fill: scatter-max each symbol id at its
    cumulative start slot, then a running max floods it across the
    symbol's [cum, cum + freq) bucket.  Zero-frequency symbols share a
    start slot with their successor and lose the max (the last symbol at a
    slot always has freq > 0 while any slot < PROB_SCALE remains), so no
    ``searchsorted`` — a 4096-wide binary-search gather per table — is
    needed anywhere in the decoder.
    """
    cum_excl = jnp.cumsum(freq) - freq
    sym = jax.lax.broadcasted_iota(jnp.int32, (256,), 0)
    start = jnp.where(freq > 0, cum_excl, PROB_SCALE)  # absent: dropped
    marks = jnp.zeros((PROB_SCALE,), jnp.int32).at[start].max(sym, mode="drop")
    return jax.lax.cummax(marks)


def _mulhi_u32(a: jax.Array, b: jax.Array) -> jax.Array:
    """High 32 bits of the u32 x u32 product, from 16-bit partials (no u64:
    x64 stays off, and the VPU has no 64-bit lanes either)."""
    al = a & jnp.uint32(0xFFFF)
    ah = a >> jnp.uint32(16)
    bl = b & jnp.uint32(0xFFFF)
    bh = b >> jnp.uint32(16)
    ll = al * bl
    lh = al * bh
    hl = ah * bl
    mid = (ll >> jnp.uint32(16)) + (lh & jnp.uint32(0xFFFF)) + (hl & jnp.uint32(0xFFFF))
    return ah * bh + (lh >> jnp.uint32(16)) + (hl >> jnp.uint32(16)) + (
        mid >> jnp.uint32(16)
    )


def _histogram(vals: jax.Array, n_valid) -> jax.Array:
    """Exact byte histogram of a zero-padded (T, 128) shard -> (256,) int32.

    One-hot matmul form: hist.reshape(16, 16) = onehot(hi)^T @ onehot(lo),
    an (N, 16) x (N, 16) f32 contraction over N.  Exact by IEEE
    arithmetic, not by luck: every product is 0 or 1 and every partial
    sum is an integer bounded by N = T*128 <= MAX_ROWS*128 = 2^24, and
    integers up to 2^24 are exactly representable in f32, so any
    accumulation order yields the true count.  f32 operands matter on
    the CPU interpret backend, where the previous int8-accumulate-int32
    contraction missed the optimized GEMM and its naive fallback loop
    cost more than the entire coding loop (the MXU is indifferent — it
    eats f32 natively).  The one-hots are identity-row gathers (a serial
    gather materializes the operands cheaper than broadcast
    compare+convert, and the iota-equality identity is computed because
    pallas kernels cannot capture materialized constants).  Padding
    positions past ``n_valid`` are *zero bytes* by the ``ops.py``
    contract, so their whole contribution lands in bin 0 and is
    subtracted back out — exact, and cheaper than masking the one-hot.
    """
    n = vals.shape[0] * vals.shape[1]
    v = vals.reshape(n)
    eye16 = (
        jax.lax.broadcasted_iota(jnp.int32, (16, 16), 0)
        == jax.lax.broadcasted_iota(jnp.int32, (16, 16), 1)
    ).astype(jnp.float32)
    h2 = jax.lax.dot_general(
        eye16[v >> 4], eye16[v & 15], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    counts = h2.reshape(256).astype(jnp.int32)
    sym = jax.lax.broadcasted_iota(jnp.int32, (256,), 0)
    return counts - jnp.where(sym == 0, n - n_valid, 0)


def _enc_step(x, packed, aux, *, division: str = "divide"):
    """One interleaved encode step: (states, sym tables) -> states'.

    Renorm-before-update with the 16-bit word convention: shift out the low
    half when x >= f << 20 (written shift-compare so f = PROB_SCALE cannot
    overflow the uint32 threshold); the caller recovers the emitted words
    and the emission mask from the returned pre-renorm states, so the hot
    loop carries nothing else.  The state update divides by freq with one
    of three exact, bit-identical strategies (asserted in the tests):

      * ``"divide"`` — the hardware udiv.  LLVM scalarizes it on CPU but
        it is still the fewest ops there; Mosaic has no integer division
        at all (which is what kept the PR-3 kernel off real TPUs).
      * ``"rcp32"`` — f32 reciprocal multiply with a +-1 integer repair.
        The renorm invariant bounds the true quotient by 2^20, so the
        faithful-rounding error of f32(x) * (1/f) is < 0.2 quotient units
        and the two-sided repair makes the result exact under ANY IEEE
        rounding — in particular it is immune to the x/c -> x*(1/c) jit
        canonicalization that breaks naive float kernels.  ``aux`` is the
        f32 reciprocal table value.
      * ``"reciprocal"`` — the all-integer Granlund-Montgomery mulhi
        path; ``aux`` is ``mprime``.  More vector ops than ``rcp32`` but
        float-free, for backends where that matters.

    Padding lanes look up a clamped f = 1 table entry; their state update
    is discarded by the caller, so the math only has to stay defined.
    Returns (updated states, pre-renorm states, emission flags).
    """
    f = packed & jnp.uint32(_SYM_MASK)
    c = packed >> jnp.uint32(19)
    x_pre = x
    emit = (x >> jnp.uint32(20)) >= f
    x = jnp.where(emit, x >> jnp.uint32(16), x)
    if division == "divide":
        q = x // f
    elif division == "rcp32":
        qh = (x.astype(jnp.float32) * aux).astype(jnp.uint32)
        r = (x - qh * f).astype(jnp.int32)
        q = (
            qh
            + (r >= f.astype(jnp.int32)).astype(jnp.uint32)
            - (r < 0).astype(jnp.uint32)
        )
    else:  # "reciprocal"
        t = _mulhi_u32(x, aux)
        q = (t + ((x - t) >> jnp.uint32(1))) >> (
            (packed >> jnp.uint32(13)) & jnp.uint32(0x3F)
        )
        q = jnp.where(f <= jnp.uint32(1), x, q)
    # x' = (q << PROB_BITS) + (x mod f) + c, in ryg's mod-free arrangement
    x = x + q * (jnp.uint32(PROB_SCALE) - f) + c
    return x, x_pre, emit


def _dec_step(x, dec_packed, slot2sym):
    """One interleaved decode step -> (pre-renorm states, symbols, need-word).

    ``dec_packed``/``slot2sym`` are (..., 256) / (..., PROB_SCALE) tables
    indexed along their last axis (gathered by the caller so kernel and
    reference share one step body).
    """
    slot = (x & jnp.uint32(PROB_SCALE - 1)).astype(jnp.int32)
    s = jnp.take_along_axis(slot2sym, slot, axis=-1)
    p = jnp.take_along_axis(dec_packed, s, axis=-1)
    f = p & jnp.uint32(_SYM_MASK)
    c = (p >> jnp.uint32(13)) & jnp.uint32(_SYM_MASK)
    x = f * (x >> jnp.uint32(PROB_BITS)) + slot.astype(jnp.uint32) - c
    return x, s, x < jnp.uint32(RANS_L)


def _signed(s, valid):
    """Decoded symbol byte -> int8 two's complement, zeros on pad lanes."""
    return jnp.where(valid, s - ((s & 0x80) << 1), 0).astype(jnp.int8)


def _row_valid(r, nv):
    """(S, 128) global-index valid mask for row r vs n_valid (S, 1)."""
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, N_LANES), 1)
    return (r * N_LANES + lane) < nv


def rans_encode_body(vals, nv, *, division: str, rows_per_step: int):
    """Encode-stage dataflow shared by the standalone entropy kernel and the
    one-launch entropy+seal kernel (``repro.kernels.fused``): histogram ->
    freq tables -> pregather -> interleaved two-phase encode loop.  Pure jnp
    over values already loaded from refs, so both kernel bodies trace the
    exact same op sequence — fusing cannot change a single output bit.

    ``vals``: (S, T, 128) int32 symbol bytes in [0, 255]; ``nv``: (S, 1)
    int32 valid byte counts.  Returns ``(words (S, T, 128) u16, mask
    (S, T, 128) u8, freq (S, 256) int32, states (S, 128) u32)``.
    """
    S, T, _ = vals.shape

    # fused stage 1+2: per-shard matmul histogram -> tables (the stripe is
    # the block: shards ride the batch axis of every loop op, so one row
    # step feeds S x 128 lanes to the vector unit instead of idling per
    # shard)
    counts = jnp.stack(
        [_histogram(vals[s], nv[s, 0]) for s in range(S)]
    )
    freq = jax.vmap(build_freq_table)(counts)                # (S, 256)
    packed, mprime, rcp = jax.vmap(build_enc_tables)(freq)

    # pregather the per-position symbol tables once: the loop then reads
    # only aligned (rows_per_step, S, 128) slices, no gathers on the hot
    # path
    flat = vals.reshape(S, T * N_LANES)
    pk = jnp.moveaxis(
        jnp.take_along_axis(packed, flat, axis=1).reshape(S, T, N_LANES),
        0, 1,
    )                                                        # (T, S, 128)
    if division == "rcp32":
        aux = jnp.take_along_axis(rcp, flat, axis=1)
    elif division == "reciprocal":
        aux = jnp.take_along_axis(mprime, flat, axis=1)
    else:
        aux = None                                           # divide: unused
    aux = (
        jnp.moveaxis(aux.reshape(S, T, N_LANES), 0, 1)
        if aux is not None else pk
    )

    # two-phase row schedule on the n_valid boundary: rows fully inside
    # every shard's payload run an unmasked body (the common case — no
    # per-lane valid test at all), the boundary region runs the masked
    # body, and fully-empty rows (pow2 bucketing leaves up to half of
    # them) are never visited — their words/mask stay zero.  Each trip
    # advances ``rows_per_step`` rows: 1 under interpret (tiny ops beat
    # fat fused bodies on CPU), N_GROUPS on TPU (the (8, 128) sublane
    # tile is one vreg).  The schedule cannot change a single output bit
    # — only which ops compute them.
    R = rows_per_step
    n_full = (jnp.min(nv) // N_LANES) // R
    n_used = -(-(-(-jnp.max(nv) // N_LANES)) // R)

    def chunk(x, ch, masked):
        ws, ms = [None] * R, [None] * R
        for k in range(R - 1, -1, -1):
            r = ch * R + k
            p = jax.lax.dynamic_index_in_dim(pk, r, 0, keepdims=False)
            a = jax.lax.dynamic_index_in_dim(aux, r, 0, keepdims=False)
            x2, x_pre, emit = _enc_step(x, p, a, division=division)
            if masked:
                valid = _row_valid(r, nv)
                x = jnp.where(valid, x2, x)                  # pad lanes: no-op
                emit = emit & valid
            else:
                x = x2
            ws[k] = (x_pre & jnp.uint32(0xFFFF)).astype(jnp.uint16)
            ms[k] = emit.astype(jnp.uint8)
        return x, jnp.stack(ws), jnp.stack(ms)

    def body_masked(j, carry):
        x, words, mask = carry
        ch = n_used - 1 - j
        x, wt, mt = chunk(x, ch, True)
        words = jax.lax.dynamic_update_index_in_dim(words, wt, ch * R, 0)
        mask = jax.lax.dynamic_update_index_in_dim(mask, mt, ch * R, 0)
        return x, words, mask

    def body_full(j, carry):
        x, words, mask = carry
        ch = n_full - 1 - j
        x, wt, mt = chunk(x, ch, False)
        words = jax.lax.dynamic_update_index_in_dim(words, wt, ch * R, 0)
        mask = jax.lax.dynamic_update_index_in_dim(mask, mt, ch * R, 0)
        return x, words, mask

    carry = (
        jnp.full((S, N_LANES), RANS_L, jnp.uint32),
        jnp.zeros((T, S, N_LANES), jnp.uint16),
        jnp.zeros((T, S, N_LANES), jnp.uint8),
    )
    carry = jax.lax.fori_loop(0, n_used - n_full, body_masked, carry)
    x, words, mask = jax.lax.fori_loop(0, n_full, body_full, carry)
    return jnp.moveaxis(words, 1, 0), jnp.moveaxis(mask, 1, 0), freq, x


def _encode_kernel(codes_ref, nvalid_ref, words_ref, mask_ref, freq_ref,
                   state_ref, *, division: str, rows_per_step: int):
    vals = (codes_ref[...].astype(jnp.int32)) & 0xFF         # (S, T, 128)
    nv = nvalid_ref[...]                                     # (S, 1)
    words, mask, freq, states = rans_encode_body(
        vals, nv, division=division, rows_per_step=rows_per_step
    )
    words_ref[...] = words
    mask_ref[...] = mask
    freq_ref[...] = freq
    state_ref[...] = states


def _decode_kernel(stream_ref, freq_ref, state_ref, nvalid_ref, codes_ref,
                   *, rows_per_step: int):
    """Version-1 decode: row-major word stream, prefix-sum read pointer.

    Mirrors the encoder's two-phase row schedule (unmasked body for rows
    fully inside every shard's payload, masked body on the n_valid
    boundary, empty rows never visited) — the decode consumes rows
    forward, so the full phase runs first.
    """
    stream = stream_ref[...]                                 # (S, W) u16
    S, W = stream.shape
    freq = freq_ref[...]                                     # (S, 256) int32
    T = codes_ref.shape[1]
    nv = nvalid_ref[...]
    dec_packed = jax.vmap(build_dec_table)(freq)
    slot2sym = jax.vmap(slot_to_symbol)(freq)

    R = rows_per_step
    n_full = (jnp.min(nv) // N_LANES) // R
    n_used = -(-(-(-jnp.max(nv) // N_LANES)) // R)

    def chunk(x, base, ch, masked):
        rows = [None] * R
        for k in range(R):
            r = ch * R + k
            x2, sym, need = _dec_step(x, dec_packed, slot2sym)
            sgn = (sym - ((sym & 0x80) << 1)).astype(jnp.int8)
            if masked:
                valid = _row_valid(r, nv)
                need = need & valid
                sgn = jnp.where(valid, sgn, 0)
            csum = jnp.cumsum(need.astype(jnp.int32), axis=-1)
            pos = base[:, None] + csum - need.astype(jnp.int32)
            w = jnp.take_along_axis(
                stream, jnp.minimum(pos, W - 1), axis=1
            ).astype(jnp.uint32)
            x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w, x2)
            x = jnp.where(valid, x2, x) if masked else x2
            base = base + csum[:, N_LANES - 1]
            rows[k] = sgn
        return x, base, jnp.stack(rows)

    def body_full(j, carry):
        x, base, out = carry
        x, base, tile = chunk(x, base, j, False)
        return x, base, jax.lax.dynamic_update_index_in_dim(out, tile, j * R, 0)

    def body_masked(j, carry):
        x, base, out = carry
        ch = n_full + j
        x, base, tile = chunk(x, base, ch, True)
        return x, base, jax.lax.dynamic_update_index_in_dim(
            out, tile, ch * R, 0
        )

    carry = (state_ref[...], jnp.zeros((S,), jnp.int32),
             jnp.zeros((T, S, N_LANES), jnp.int8))
    carry = jax.lax.fori_loop(0, n_full, body_full, carry)
    _, _, out = jax.lax.fori_loop(0, n_used - n_full, body_masked, carry)
    codes_ref[...] = jnp.moveaxis(out, 1, 0)


def _decode_kernel_v0(stream_ref, freq_ref, state_ref, nvalid_ref, codes_ref,
                      *, rows_per_step: int):
    """Version-0 decode twin: lane-major words, per-lane read pointers."""
    lane_words = stream_ref[...]                             # (S, T, 128) u16
    S, T, _ = lane_words.shape
    freq = freq_ref[...]
    nv = nvalid_ref[...]
    dec_packed = jax.vmap(build_dec_table)(freq)
    slot2sym = jax.vmap(slot_to_symbol)(freq)

    R = rows_per_step
    n_full = (jnp.min(nv) // N_LANES) // R
    n_used = -(-(-(-jnp.max(nv) // N_LANES)) // R)

    def chunk(x, ptr, ch, masked):
        rows = [None] * R
        for k in range(R):
            r = ch * R + k
            x2, sym, need = _dec_step(x, dec_packed, slot2sym)
            sgn = (sym - ((sym & 0x80) << 1)).astype(jnp.int8)
            if masked:
                valid = _row_valid(r, nv)
                need = need & valid
                sgn = jnp.where(valid, sgn, 0)
            w = jnp.take_along_axis(
                lane_words, jnp.minimum(ptr, T - 1)[:, None, :], axis=1
            )[:, 0].astype(jnp.uint32)
            x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w, x2)
            x = jnp.where(valid, x2, x) if masked else x2
            ptr = ptr + need.astype(jnp.int32)
            rows[k] = sgn
        return x, ptr, jnp.stack(rows)

    def body_full(j, carry):
        x, ptr, out = carry
        x, ptr, tile = chunk(x, ptr, j, False)
        return x, ptr, jax.lax.dynamic_update_index_in_dim(out, tile, j * R, 0)

    def body_masked(j, carry):
        x, ptr, out = carry
        ch = n_full + j
        x, ptr, tile = chunk(x, ptr, ch, True)
        return x, ptr, jax.lax.dynamic_update_index_in_dim(
            out, tile, ch * R, 0
        )

    carry = (state_ref[...], jnp.zeros((S, N_LANES), jnp.int32),
             jnp.zeros((T, S, N_LANES), jnp.int8))
    carry = jax.lax.fori_loop(0, n_full, body_full, carry)
    _, _, out = jax.lax.fori_loop(0, n_used - n_full, body_masked, carry)
    codes_ref[...] = jnp.moveaxis(out, 1, 0)


def _rows_per_step(rows_per_step, interpret: bool, rows: int) -> int:
    """Static loop-schedule width: 1 row/trip under interpret (many tiny
    ops beat few fat fused bodies on CPU), an (N_GROUPS, 128) sublane tile
    per trip otherwise (one vreg per step on TPU).  Pure schedule — the
    output bits are identical for every choice."""
    if rows_per_step is None:
        rows_per_step = 1 if interpret else N_GROUPS
    if rows % rows_per_step:
        raise ValueError(f"{rows} rows not a multiple of {rows_per_step}")
    return rows_per_step


def rans_encode_pallas(codes, n_valid, *, division: str = "divide",
                       rows_per_step: int = None, interpret: bool = True):
    """Encode all S shards of a stripe in one launch (the stripe is the
    kernel block; shards stack on the batch axis of every vector op).

    codes: (S, T, 128) int8 payload rows, zero-padded (the histogram's
    pad correction requires the padding bytes to BE zero — ``ops.py``
    guarantees it); T % T_TILE == 0.
    n_valid: (S, 1) int32 valid byte count per shard — positions past it
    are padding and are excluded from both the histogram and the coding
    loop (their lanes idle, costing zero stream bytes).
    division: "divide" (hardware udiv — interpret/CPU default), "rcp32"
    (error-repaired f32 reciprocal — the TPU default; Mosaic has no
    integer divide) or "reciprocal" (all-integer Granlund-Montgomery
    mulhi); the streams are bit-identical in all three.
    Returns (words (S, T, 128) uint16, mask (S, T, 128) uint8,
    freq (S, 256) int32, states (S, 128) uint32): the dense emission buffer
    + per-row emission mask (rank-select compacted by the caller), the
    per-shard frequency tables, and the final lane states the decoder
    starts from.
    """
    S, T, L = codes.shape
    if L != N_LANES:
        raise ValueError(f"expected {N_LANES} lanes, got {L}")
    if T % T_TILE:
        raise ValueError(f"rows {T} not a multiple of {T_TILE}")
    if division not in ("divide", "rcp32", "reciprocal"):
        raise ValueError(f"unknown division strategy {division!r}")
    rps = _rows_per_step(rows_per_step, interpret, T)
    return pl.pallas_call(
        functools.partial(_encode_kernel, division=division,
                          rows_per_step=rps),
        out_shape=[
            jax.ShapeDtypeStruct((S, T, N_LANES), jnp.uint16),
            jax.ShapeDtypeStruct((S, T, N_LANES), jnp.uint8),
            jax.ShapeDtypeStruct((S, 256), jnp.int32),
            jax.ShapeDtypeStruct((S, N_LANES), jnp.uint32),
        ],
        interpret=interpret,
    )(codes, n_valid)


def rans_decode_pallas(stream, freq, states, n_valid, *, rows: int,
                       rows_per_step: int = None, interpret: bool = True):
    """Version-1 decode twin: flat row-major word streams -> original bytes.

    stream: (S, W) uint16 — each shard's words in global decoder-read order
    (tails past the shard's word count are never consumed).  The decoder
    advances a single per-shard stream pointer; per sub-step, the lanes
    that renormalize take the next popcount(need) words in lane order via
    an in-register prefix sum — no per-lane offset table is parsed.
    freq: (S, 256) int32 tables; states: (S, 128) uint32 initial lane
    states; n_valid: (S, 1) int32 — must equal the encoder's.
    Returns (S, rows, 128) int8 decoded payload rows, zeros past n_valid.
    """
    S, W = stream.shape
    if rows % T_TILE:
        raise ValueError(f"rows {rows} not a multiple of {T_TILE}")
    rps = _rows_per_step(rows_per_step, interpret, rows)
    return pl.pallas_call(
        functools.partial(_decode_kernel, rows_per_step=rps),
        out_shape=jax.ShapeDtypeStruct((S, rows, N_LANES), jnp.int8),
        interpret=interpret,
    )(stream, freq, states, n_valid)


def rans_decode_pallas_v0(lane_words, freq, states, n_valid, *,
                          rows_per_step: int = None, interpret: bool = True):
    """Version-0 decode twin: per-lane word streams + header tables.

    lane_words: (S, T, 128) uint16 — word j of lane l at [s, j, l] (the
    caller re-gathers the flat lane-major stream into this layout; tails
    past each lane's length are never consumed).  Kept so PR-4-era archives
    and checkpoints stay decodable across the row-major format change.
    """
    S, T, L = lane_words.shape
    if L != N_LANES:
        raise ValueError(f"expected {N_LANES} lanes, got {L}")
    if T % T_TILE:
        raise ValueError(f"rows {T} not a multiple of {T_TILE}")
    rps = _rows_per_step(rows_per_step, interpret, T)
    return pl.pallas_call(
        functools.partial(_decode_kernel_v0, rows_per_step=rps),
        out_shape=jax.ShapeDtypeStruct((S, T, N_LANES), jnp.int8),
        interpret=interpret,
    )(lane_words, freq, states, n_valid)
