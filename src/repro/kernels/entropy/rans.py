"""Pallas TPU kernel: interleaved-rANS byte coder for the archival datapath.

One launch codes all S shards of a stripe: the stripe is the kernel block,
and the shards ride the batch axis of every vector op, so one loop step
feeds S x 128 lanes to the vector unit instead of idling per shard.  A
shard's flat int8 payload is laid out as (T, 128) rows whose 128 columns
are 128 *independent* rANS lanes (lane l owns bytes l, 128+l, 256+l, ...),
the interleaved layout from Giesen's SIMD rANS with the lane axis mapped
onto the TPU lane dimension.

The coding loop is a *two-phase* encode with no ``fori_loop`` anywhere:

  phase 1 computes the whole row/lane schedule as batched tensor ops —
  the (T, S, 128) validity of every position against ``n_valid`` in one
  iota compare, and one select that swaps every invalid position's
  pregathered symbol table entry for the *identity sentinel*
  (f = PROB_SCALE, cum = 0: the state update collapses to
  x' = x + q*(M - f) + c = x and the renorm test (x >> 20) >= PROB_SCALE
  cannot fire for any 32-bit state, so the step is an exact no-op and the
  lane freezes).  That removes every per-lane mask, compare and select
  from the sequential region: boundary rows, fully-padded rows and
  ``n_valid = 0`` dummy shards all ride the same unmasked body;

  phase 2 is a minimal-carry ``lax.scan`` over the rows (reverse order —
  rANS encodes backwards so decode streams forwards) whose carry is ONLY
  the (S, 128) lane states; the per-row emitted words and emission masks
  leave through the scan's stacked outputs instead of a dense carry
  buffer threaded through a ``fori_loop``, which is what let XLA:CPU
  vectorize the row I/O instead of serializing a (T, S, 128)
  dynamic-update chain.  The per-lane word counts and exclusive stream
  offsets then fall out of the emission mask as batched prefix sums, and
  ``ops.py`` writes every output word with one rank-select gather pass
  against those precomputed offsets.

The scan *step width* stays a static knob (``rows_per_step``): each scan
trip advances that many rows, 1 under interpret (many tiny ops schedule
cheaper than few fat fused bodies on CPU) and an (N_GROUPS=8, 128)
sublane-by-lane vreg tile on TPU.  The schedule cannot change a single
output bit — only which ops compute them — and the suite asserts both
schedules bit-identical.  (Widening the *state* interleave instead —
G x 128 independent streams — was measured and rejected: every extra rANS
stream wastes >= 16 bits of initial-state flush for zero entropy gain,
~2.7 KiB per 64 KiB shard, about a 10% compression-ratio loss.)

Per shard the kernel runs three fused stages without leaving VMEM:

  1. histogram over all T*128 bytes, by one of two exact, bit-identical
     strategies (the ``histogram`` knob, defaulted per backend like
     ``division``): ``"dot"`` — the one-hot *matmul*: the byte splits
     into hi/lo nibbles and hist.reshape(16, 16) = onehot(hi)^T @
     onehot(lo), an (N, 16) x (N, 16) f32 contraction — exact because
     every partial sum is an integer <= T*128 <= 2^24, below the f32
     mantissa (the TPU default: the MXU eats it); or ``"swar"`` — pack
     bytes 4-per-u32, XOR against each candidate symbol's splatted
     pattern, SWAR zero-byte detect, ``population_count``, and an
     explicit halving-tree add reduction (the interpret/CPU default:
     ~3x the one-hot GEMM, whose 16-wide M/N tiles leave the CPU GEMM at
     a quarter of peak, and the *tree* matters — XLA:CPU's own reduce
     lowering over the word axis was measured 14x slower than the same
     adds spelled as a log-depth slice chain).  Neither path scatters
     (``.at[...].add`` serializes on TPU and CPU alike;
     ``test_kernel_hygiene.py`` bans it from kernel sources);
  2. static table build: :func:`build_freq_table` (integer-exact
     normalization to ``M = 2**PROB_BITS``, every present symbol >= 1)
     plus :func:`build_enc_tables`, which precomputes per-symbol
     reciprocals so the hot loop never divides: the Granlund-Montgomery
     (mprime, shift) fixed-point pair, and an f32 reciprocal for the
     error-repaired fast path.  The frequency table ships in the stream
     header; the reciprocals are *derived* state — decode is
     multiplication-only and provably never reads them, so shipping them
     would inflate every stream by 1.25 KiB for nothing;
  3. the two-phase coding loop described above, emitting at most one
     16-bit word per lane per row (32-bit states, 16-bit renormalization:
     state in [2^16, 2^32) means renorm fires at most once per symbol,
     which is what makes the loop branchlessly vectorizable).  Symbol
     tables are pregathered per position and sentinel-masked before the
     scan, so the sequential region reads only aligned row slices and
     carries only the lane states — no gathers, no masks, no dense
     output buffer on the hot path.

The per-symbol division x // freq runs as one of three exact,
bit-identical strategies (see :func:`_enc_step`): the all-integer
Granlund-Montgomery mulhi (interpret default — x86 has no vector u32
divide, so udiv scalarizes while mulhi stays SIMD), the error-repaired
f32 reciprocal multiply (TPU default — Mosaic has no integer division,
which is what kept the PR-3 coder off real hardware), or the hardware
udiv.
The f32 path is immune to the x/c -> x*(1/c) jit canonicalization that
breaks naive float kernels: the renorm invariant bounds the quotient by
2^20, so any faithful rounding stays within +-0.2 of the true quotient
and the integer repair makes the result exact.  Everything else in the
coder is u32/i32 (and the histogram's f32 counts are
exact-by-construction), so kernel-vs-reference bit-exactness survives
every backend.

Stream format (``STREAM_VERSION = 1``): the header layout is unchanged
from version 0 — freq u16[256] | lane_lens u32[128] | states u32[128] —
but the word area is packed in *row-major decoder-read order* (the global
order a forward decode consumes words: row by row, lanes in order within
a row) instead of version 0's per-lane-contiguous runs.  Row-major
packing is what the vectorized decoder wants: each step takes the next
popcount(need) words off the stream front with an in-register prefix
sum, so no per-lane offset table is parsed and no ``searchsorted`` exists
anywhere — the slot->symbol table is a direct cumulative-bucket fill
(:func:`slot_to_symbol`: scatter-max the symbol ids at their cumulative
start slots, then a running max).  The version bump never changes
``n_comp`` (same header bytes, same word count), so the compression ratio
is identical by construction; version 0 streams still decode through the
lane-major twin (``rans_decode_pallas_v0``), and the stream version rides
in the archive manifest next to the codec name.

The encoder does NOT compact its output: it writes a dense (T, 128) word
buffer plus an emission mask, and ``ops.py`` runs the (shared,
order-free) rank-select compaction into the final byte stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "N_LANES",
    "N_GROUPS",
    "PROB_BITS",
    "PROB_SCALE",
    "RANS_L",
    "T_TILE",
    "STREAM_VERSION",
    "build_freq_table",
    "build_enc_tables",
    "build_dec_table",
    "slot_to_symbol",
    "rans_encode_body",
    "rans_encode_pallas",
    "rans_decode_pallas",
    "rans_decode_pallas_v0",
]

N_LANES = 128                 # interleaved rANS lanes == TPU lane width
N_GROUPS = 8                  # lane-group rows per tile == TPU sublane width
PROB_BITS = 12                # frequency table quantization: sum(freq) = 4096
PROB_SCALE = 1 << PROB_BITS
RANS_L = 1 << 16              # state lower bound; 16-bit renormalization
T_TILE = 8                    # sublane-aligned row granularity (== N_GROUPS)
STREAM_VERSION = 1            # row-major word order; 0 = PR-4 lane-major

_SYM_MASK = 0x1FFF            # 13 bits: freq and cum both reach 4096


def build_freq_table(counts: jax.Array) -> jax.Array:
    """(256,) int32 byte counts -> (256,) int32 freqs summing to PROB_SCALE.

    Integer-exact and overflow-safe in int32: counts are right-shifted until
    their total is < 2^19 (so count*budget < 2^31), every present symbol is
    reserved one slot up front, the remaining budget is floor-allocated
    proportionally, and the rounding remainder goes to the most frequent
    symbol.  Present symbols always get freq >= 1; the sum is exactly
    PROB_SCALE.  Shared verbatim by the Pallas kernel and the jnp reference
    (same role as ``chacha_rounds_planes`` in the seal kernel).
    """
    present = (counts > 0).astype(jnp.int32)
    total = counts.sum()
    # shift = #{k : total >= 2^(19+k)}  -- smallest shift with total>>shift < 2^19
    # (iota, not arange: materialized constants cannot be captured by a
    # pallas kernel body, computed iotas can)
    thresholds = 19 + jax.lax.broadcasted_iota(jnp.int32, (12,), 0)
    shift = (total >= (1 << thresholds)).sum()
    c2 = jnp.maximum(counts >> shift, present)
    n2 = jnp.maximum(c2.sum(), 1)
    budget = PROB_SCALE - present.sum()
    extra = (c2 * budget) // n2        # c2 < 2^19, budget < 2^12: no overflow
    freq = present + extra
    rem = budget - extra.sum()
    # remainder to the most frequent symbol, scatter-free (one-hot select)
    sym = jax.lax.broadcasted_iota(jnp.int32, (256,), 0)
    return freq + jnp.where(sym == jnp.argmax(c2), rem, 0)


def build_enc_tables(freq: jax.Array):
    """(256,) int32 freqs -> per-symbol encode tables (packed, mprime, rcp).

    ``packed[s] = f | (shift-1) << 13 | cum_excl << 19`` (f clamped to
    >= 1: only padding lanes ever look up an absent symbol, their update
    is discarded, and the clamp keeps every division strategy defined).
    ``mprime[s]`` is the Granlund-Montgomery round-up integer reciprocal
    ``ceil(2^(32+shift)/f) - 2^32`` (fits u32), giving the exact quotient

        t = mulhi(x, mprime);  q = (t + ((x - t) >> 1)) >> (shift - 1)

    for every f in [2, PROB_SCALE] and x < 2^32 (f <= 1 short-circuits to
    q = x in :func:`_enc_step`; brute-verified over all f in the tests).
    ``rcp[s] = 1/f`` in f32 drives the fast error-repaired strategy (see
    ``division="rcp32"`` in :func:`_enc_step`).  Built once per shard right
    after :func:`build_freq_table` — the two table divides below run
    256-wide once per shard, not per symbol, and never appear in the hot
    loop.
    """
    f = freq.astype(jnp.uint32)
    cum = (jnp.cumsum(freq) - freq).astype(jnp.uint32)
    # shift = ceil_log2(f) = #{k in [0,13) : 2^k < f}
    pows = jnp.uint32(1) << jax.lax.broadcasted_iota(jnp.uint32, (256, 13), 1)
    shift = (pows < f[:, None]).astype(jnp.uint32).sum(axis=1)
    s1 = jnp.maximum(shift, jnp.uint32(1)) - jnp.uint32(1)
    # ceil(2^(32+shift)/f) - 2^32 via 16+16-bit long division, u32-only:
    # hi2 = 2^(16+shift) <= 2^28; q_hi in [2^16, 2^17) so q_hi - 2^16 < 2^16
    fq = jnp.maximum(f, jnp.uint32(1))
    hi2 = jnp.uint32(1) << (jnp.uint32(16) + shift)
    q_hi = hi2 // fq
    num = (hi2 - q_hi * fq) << jnp.uint32(16)
    q_lo = num // fq
    r2 = num - q_lo * fq
    mprime = (
        ((q_hi - jnp.uint32(1 << 16)) << jnp.uint32(16))
        + q_lo
        + (r2 != 0).astype(jnp.uint32)
    )
    packed = fq | (s1 << jnp.uint32(13)) | (cum << jnp.uint32(19))
    return packed, mprime, jnp.float32(1.0) / fq.astype(jnp.float32)


def build_dec_table(freq: jax.Array) -> jax.Array:
    """(256,) int32 freqs -> packed u32 decode table ``f | cum_excl << 13``."""
    f = freq.astype(jnp.uint32)
    cum = (jnp.cumsum(freq) - freq).astype(jnp.uint32)
    return f | (cum << jnp.uint32(13))


def slot_to_symbol(freq: jax.Array) -> jax.Array:
    """(256,) freqs -> (PROB_SCALE,) inverse cumulative table, slot -> symbol.

    Direct cumulative-bucket fill: scatter-max each symbol id at its
    cumulative start slot, then a running max floods it across the
    symbol's [cum, cum + freq) bucket.  Zero-frequency symbols share a
    start slot with their successor and lose the max (the last symbol at a
    slot always has freq > 0 while any slot < PROB_SCALE remains), so no
    ``searchsorted`` — a 4096-wide binary-search gather per table — is
    needed anywhere in the decoder.
    """
    cum_excl = jnp.cumsum(freq) - freq
    sym = jax.lax.broadcasted_iota(jnp.int32, (256,), 0)
    start = jnp.where(freq > 0, cum_excl, PROB_SCALE)  # absent: dropped
    marks = jnp.zeros((PROB_SCALE,), jnp.int32).at[start].max(sym, mode="drop")
    return jax.lax.cummax(marks)


def _mulhi_u32(a: jax.Array, b: jax.Array) -> jax.Array:
    """High 32 bits of the u32 x u32 product, from 16-bit partials (no u64:
    x64 stays off, and the VPU has no 64-bit lanes either)."""
    al = a & jnp.uint32(0xFFFF)
    ah = a >> jnp.uint32(16)
    bl = b & jnp.uint32(0xFFFF)
    bh = b >> jnp.uint32(16)
    ll = al * bl
    lh = al * bh
    hl = ah * bl
    mid = (ll >> jnp.uint32(16)) + (lh & jnp.uint32(0xFFFF)) + (hl & jnp.uint32(0xFFFF))
    return ah * bh + (lh >> jnp.uint32(16)) + (hl >> jnp.uint32(16)) + (
        mid >> jnp.uint32(16)
    )


def _histogram(vals: jax.Array, n_valid) -> jax.Array:
    """Exact byte histogram of a zero-padded (T, 128) shard -> (256,) int32.

    One-hot matmul form: hist.reshape(16, 16) = onehot(hi)^T @ onehot(lo),
    an (N, 16) x (N, 16) f32 contraction over N.  Exact by IEEE
    arithmetic, not by luck: every product is 0 or 1 and every partial
    sum is an integer bounded by N = T*128 <= MAX_ROWS*128 = 2^24, and
    integers up to 2^24 are exactly representable in f32, so any
    accumulation order yields the true count.  f32 operands matter on
    the CPU interpret backend, where the previous int8-accumulate-int32
    contraction missed the optimized GEMM and its naive fallback loop
    cost more than the entire coding loop (the MXU is indifferent — it
    eats f32 natively).  The one-hots are identity-row gathers (a serial
    gather materializes the operands cheaper than broadcast
    compare+convert, and the iota-equality identity is computed because
    pallas kernels cannot capture materialized constants).  Padding
    positions past ``n_valid`` are *zero bytes* by the ``ops.py``
    contract, so their whole contribution lands in bin 0 and is
    subtracted back out — exact, and cheaper than masking the one-hot.
    """
    n = vals.shape[0] * vals.shape[1]
    v = vals.reshape(n)
    eye16 = (
        jax.lax.broadcasted_iota(jnp.int32, (16, 16), 0)
        == jax.lax.broadcasted_iota(jnp.int32, (16, 16), 1)
    ).astype(jnp.float32)
    h2 = jax.lax.dot_general(
        eye16[v >> 4], eye16[v & 15], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    counts = h2.reshape(256).astype(jnp.int32)
    sym = jax.lax.broadcasted_iota(jnp.int32, (256,), 0)
    return counts - jnp.where(sym == 0, n - n_valid, 0)


_SWAR_CHUNK = 32              # symbols per SWAR sweep: bounds the (S, CHUNK,
                              # T*32) popcount intermediate to a few MiB


def _histogram_swar(vals: jax.Array, nv: jax.Array) -> jax.Array:
    """Exact byte histograms of all S zero-padded (T, 128) shards at once ->
    (S, 256) int32, GEMM-free: SWAR zero-byte test + popcount.

    Bytes pack little-endian 4-per-u32; for each candidate symbol the word
    is XORed against the symbol splatted to all four byte positions, the
    classic ``~(((x & 7f..) + 7f..) | x | 7f..)`` zero-byte detector
    leaves 0x80 exactly at matching bytes, and a ``population_count`` per
    word counts them.  The per-symbol totals reduce over the word axis as
    an explicit halving-tree of adds — spelled as slices on purpose:
    XLA:CPU's reduce lowering over that axis was measured 14x slower than
    the identical adds in log-depth slice form, while the tree vectorizes
    flat-out.  Symbols sweep in ``_SWAR_CHUNK`` batches to bound the
    popcount intermediate (the fused kernel batches K stripes of shards
    through here).  Bit-identical to :func:`_histogram` by construction —
    both count exactly; padding bytes are zero (``ops.py`` contract) and
    are subtracted from bin 0, exactly as there.
    """
    S, T, L = vals.shape
    n = T * L
    # byte-pack via u8 truncate + bitcast: the 4 strided u32 slices +
    # shift-or spelling of the same pack measured ~5 ms on the bench
    # shapes — minor-axis strided loads do not vectorize on XLA:CPU —
    # while the truncate is one dense pass and the bitcast is free
    w = jax.lax.bitcast_convert_type(
        vals.reshape(S, n // 4, 4).astype(jnp.uint8), jnp.uint32
    )                                                        # (S, n/4)
    k7f = jnp.uint32(0x7F7F7F7F)
    k01 = jnp.uint32(0x01010101)
    outs = []
    for y0 in range(0, 256, _SWAR_CHUNK):
        pat = (
            jax.lax.broadcasted_iota(jnp.uint32, (_SWAR_CHUNK,), 0)
            + jnp.uint32(y0)
        ) * k01
        x = w[:, None, :] ^ pat[None, :, None]
        z = ~(((x & k7f) + k7f) | x | k7f)                   # 0x80 at matches
        c = jax.lax.population_count(z)
        while c.shape[2] > 1:
            m = c.shape[2]
            if m % 2:
                c = jnp.pad(c, ((0, 0), (0, 0), (0, 1)))
                m += 1
            c = c[:, :, : m // 2] + c[:, :, m // 2 :]
        outs.append(c[:, :, 0])
    counts = jnp.concatenate(outs, axis=1).astype(jnp.int32)
    sym = jax.lax.broadcasted_iota(jnp.int32, (1, 256), 1)
    return counts - jnp.where(sym == 0, n - nv, 0)


def _enc_step(x, packed, aux, *, division: str = "divide"):
    """One interleaved encode step: (states, sym tables) -> states'.

    Renorm-before-update with the 16-bit word convention: shift out the low
    half when x >= f << 20 (written shift-compare so f = PROB_SCALE cannot
    overflow the uint32 threshold); the caller recovers the emitted words
    and the emission mask from the returned pre-renorm states, so the hot
    loop carries nothing else.  The state update divides by freq with one
    of three exact, bit-identical strategies (asserted in the tests):

      * ``"divide"`` — the hardware udiv.  Fewest ops on paper, but LLVM
        scalarizes it on CPU (no vector u32 divide on x86) so the SIMD
        mulhi path beats it there; Mosaic has no integer division at all
        (which is what kept the PR-3 kernel off real TPUs).
      * ``"rcp32"`` — f32 reciprocal multiply with a +-1 integer repair.
        The renorm invariant bounds the true quotient by 2^20, so the
        faithful-rounding error of f32(x) * (1/f) is < 0.2 quotient units
        and the two-sided repair makes the result exact under ANY IEEE
        rounding — in particular it is immune to the x/c -> x*(1/c) jit
        canonicalization that breaks naive float kernels.  ``aux`` is the
        f32 reciprocal table value.
      * ``"reciprocal"`` — the all-integer Granlund-Montgomery mulhi
        path; ``aux`` is ``mprime``.  More vector ops than ``rcp32`` but
        float-free, for backends where that matters.

    Padding lanes look up a clamped f = 1 table entry; their state update
    is discarded by the caller, so the math only has to stay defined.
    Returns (updated states, pre-renorm states, emission flags).
    """
    f = packed & jnp.uint32(_SYM_MASK)
    c = packed >> jnp.uint32(19)
    x_pre = x
    emit = (x >> jnp.uint32(20)) >= f
    x = jnp.where(emit, x >> jnp.uint32(16), x)
    if division == "divide":
        q = x // f
    elif division == "rcp32":
        qh = (x.astype(jnp.float32) * aux).astype(jnp.uint32)
        r = (x - qh * f).astype(jnp.int32)
        q = (
            qh
            + (r >= f.astype(jnp.int32)).astype(jnp.uint32)
            - (r < 0).astype(jnp.uint32)
        )
    else:  # "reciprocal"
        t = _mulhi_u32(x, aux)
        q = (t + ((x - t) >> jnp.uint32(1))) >> (
            (packed >> jnp.uint32(13)) & jnp.uint32(0x3F)
        )
        q = jnp.where(f <= jnp.uint32(1), x, q)
    # x' = (q << PROB_BITS) + (x mod f) + c, in ryg's mod-free arrangement
    x = x + q * (jnp.uint32(PROB_SCALE) - f) + c
    return x, x_pre, emit


def _dec_step(x, dec_packed, slot2sym):
    """One interleaved decode step -> (pre-renorm states, symbols, need-word).

    ``dec_packed``/``slot2sym`` are (..., 256) / (..., PROB_SCALE) tables
    indexed along their last axis (gathered by the caller so kernel and
    reference share one step body).
    """
    slot = (x & jnp.uint32(PROB_SCALE - 1)).astype(jnp.int32)
    s = jnp.take_along_axis(slot2sym, slot, axis=-1)
    p = jnp.take_along_axis(dec_packed, s, axis=-1)
    f = p & jnp.uint32(_SYM_MASK)
    c = (p >> jnp.uint32(13)) & jnp.uint32(_SYM_MASK)
    x = f * (x >> jnp.uint32(PROB_BITS)) + slot.astype(jnp.uint32) - c
    return x, s, x < jnp.uint32(RANS_L)


def _signed(s, valid):
    """Decoded symbol byte -> int8 two's complement, zeros on pad lanes."""
    return jnp.where(valid, s - ((s & 0x80) << 1), 0).astype(jnp.int8)


def _valid_positions(T: int, nv):
    """(T, S, 128) global-byte-index validity mask vs n_valid (S, 1).

    One batched iota compare — the whole n_valid row/lane schedule the
    old two-loop encoder derived per trip, computed up front so the
    sequential scan carries no masking at all."""
    S = nv.shape[0]
    pos = (
        jax.lax.broadcasted_iota(jnp.int32, (T, 1, N_LANES), 0) * N_LANES
        + jax.lax.broadcasted_iota(jnp.int32, (T, 1, N_LANES), 2)
    )
    return pos < nv.reshape(1, S, 1)


# Identity sentinel symbol entry: f = PROB_SCALE (shift = 12 -> s1 = 11),
# cum = 0.  _enc_step on it is an exact no-op for every division strategy:
# emit = (x >> 20) >= PROB_SCALE never fires for a 32-bit state, and
# x' = x + q*(PROB_SCALE - f) + cum = x regardless of what q computes.
_ENC_SENTINEL = PROB_SCALE | (11 << 13)


def rans_encode_body(vals, nv, *, division: str, rows_per_step: int,
                     histogram: str = "dot"):
    """Encode-stage dataflow shared by the standalone entropy kernel and the
    one-launch entropy+seal kernel (``repro.kernels.fused``): histogram ->
    freq tables -> pregather -> two-phase encode (batched schedule + pure
    ``lax.scan``).  Pure jnp over values already loaded from refs, so both
    kernel bodies trace the exact same op sequence — fusing cannot change a
    single output bit.

    ``vals``: (S, T, 128) int32 symbol bytes in [0, 255]; ``nv``: (S, 1)
    int32 valid byte counts.  Returns ``(words (S, T, 128) u16, mask
    (S, T, 128) u8, freq (S, 256) int32, states (S, 128) u32)``.
    """
    S, T, _ = vals.shape

    # fused stage 1+2: per-shard histogram -> tables (the stripe is the
    # block: shards ride the batch axis of every op, so one scan step
    # feeds S x 128 lanes to the vector unit instead of idling per shard)
    if histogram == "swar":
        counts = _histogram_swar(vals, nv)
    else:
        counts = jnp.stack(
            [_histogram(vals[s], nv[s, 0]) for s in range(S)]
        )
    freq = jax.vmap(build_freq_table)(counts)                # (S, 256)
    packed, mprime, rcp = jax.vmap(build_enc_tables)(freq)

    # pregather the per-position symbol tables once: the scan then reads
    # only aligned (rows_per_step, S, 128) slices, no gathers on the hot
    # path
    flat = vals.reshape(S, T * N_LANES)
    pk = jnp.moveaxis(
        jnp.take_along_axis(packed, flat, axis=1).reshape(S, T, N_LANES),
        0, 1,
    )                                                        # (T, S, 128)
    if division == "rcp32":
        aux = jnp.take_along_axis(rcp, flat, axis=1)
    elif division == "reciprocal":
        aux = jnp.take_along_axis(mprime, flat, axis=1)
    else:
        aux = None                                           # divide: unused
    aux = (
        jnp.moveaxis(aux.reshape(S, T, N_LANES), 0, 1)
        if aux is not None else pk
    )

    # phase 1: the batched schedule.  Swap every invalid position's table
    # entry for the identity sentinel — the encode step freezes the lane
    # exactly like the old per-trip ``where`` masking (same frozen states,
    # words and emissions, bit for bit), but the masking now costs one
    # vectorized select OUTSIDE the sequential region.  ``aux`` needs no
    # swap: with f = PROB_SCALE the quotient is multiplied by zero, so any
    # defined aux value (padding bytes gather symbol 0's, and the table
    # build clamps f >= 1) yields the same frozen state.  Boundary rows,
    # fully-padded rows and n_valid = 0 dummy shards all take this path —
    # there are no dynamic trip counts left to recompute per batch.
    pk = jnp.where(_valid_positions(T, nv), pk, jnp.uint32(_ENC_SENTINEL))

    # phase 2: minimal-carry scan, rows_per_step rows per trip in reverse
    # row order.  Carry = lane states only; words/mask leave through the
    # scan's stacked ys, not a dense dynamic-update chain.
    R = rows_per_step
    pkc = pk.reshape(T // R, R, S, N_LANES)
    auxc = aux.reshape(T // R, R, S, N_LANES)

    def step(x, xs):
        pc, ac = xs
        ws, ms = [None] * R, [None] * R
        for k in range(R - 1, -1, -1):
            x, x_pre, emit = _enc_step(x, pc[k], ac[k], division=division)
            ws[k] = (x_pre & jnp.uint32(0xFFFF)).astype(jnp.uint16)
            ms[k] = emit.astype(jnp.uint8)
        return x, (jnp.stack(ws), jnp.stack(ms))

    x0 = jnp.full((S, N_LANES), RANS_L, jnp.uint32)
    x, (w_rev, m_rev) = jax.lax.scan(step, x0, (pkc[::-1], auxc[::-1]))
    words = jnp.moveaxis(w_rev[::-1].reshape(T, S, N_LANES), 1, 0)
    mask = jnp.moveaxis(m_rev[::-1].reshape(T, S, N_LANES), 1, 0)
    return words, mask, freq, x


def _encode_kernel(codes_ref, nvalid_ref, words_ref, mask_ref, freq_ref,
                   state_ref, *, division: str, rows_per_step: int,
                   histogram: str):
    vals = (codes_ref[...].astype(jnp.int32)) & 0xFF         # (S, T, 128)
    nv = nvalid_ref[...]                                     # (S, 1)
    words, mask, freq, states = rans_encode_body(
        vals, nv, division=division, rows_per_step=rows_per_step,
        histogram=histogram,
    )
    words_ref[...] = words
    mask_ref[...] = mask
    freq_ref[...] = freq
    state_ref[...] = states


def _decode_kernel(stream_ref, freq_ref, state_ref, nvalid_ref, codes_ref,
                   *, rows_per_step: int):
    """Version-1 decode: row-major word stream, prefix-sum read pointer.

    Mirrors the encoder's two-phase shape: the whole row/lane validity
    schedule is one batched iota compare (phase 1), and the sequential
    region is a minimal-carry ``lax.scan`` over the rows — carry = (lane
    states, stream read pointer), decoded rows leave through the scan's
    stacked outputs.  The decode consumes rows forward (the encoder ran
    them in reverse).  Invalid lanes renorm-mask to zero consumption, so
    boundary rows, fully-padded rows and n_valid = 0 shards ride the same
    body with no dynamic trip counts.
    """
    stream = stream_ref[...]                                 # (S, W) u16
    S, W = stream.shape
    freq = freq_ref[...]                                     # (S, 256) int32
    T = codes_ref.shape[1]
    nv = nvalid_ref[...]
    dec_packed = jax.vmap(build_dec_table)(freq)
    slot2sym = jax.vmap(slot_to_symbol)(freq)

    R = rows_per_step
    vc = _valid_positions(T, nv).reshape(T // R, R, S, N_LANES)

    def step(carry, vck):
        x, base = carry
        rows = [None] * R
        for k in range(R):
            valid = vck[k]
            x2, sym, need = _dec_step(x, dec_packed, slot2sym)
            need = need & valid
            sgn = jnp.where(
                valid, (sym - ((sym & 0x80) << 1)).astype(jnp.int8), 0
            )
            csum = jnp.cumsum(need.astype(jnp.int32), axis=-1)
            pos = base[:, None] + csum - need.astype(jnp.int32)
            w = jnp.take_along_axis(
                stream, jnp.minimum(pos, W - 1), axis=1
            ).astype(jnp.uint32)
            x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w, x2)
            x = jnp.where(valid, x2, x)
            base = base + csum[:, N_LANES - 1]
            rows[k] = sgn
        return (x, base), jnp.stack(rows)

    carry = (state_ref[...], jnp.zeros((S,), jnp.int32))
    _, out = jax.lax.scan(step, carry, vc)
    codes_ref[...] = jnp.moveaxis(out.reshape(T, S, N_LANES), 1, 0)


def _decode_kernel_v0(stream_ref, freq_ref, state_ref, nvalid_ref, codes_ref,
                      *, rows_per_step: int):
    """Version-0 decode twin: lane-major words, per-lane read pointers.
    Same minimal-carry scan shape as the v1 decoder — carry = (lane
    states, per-lane word pointers)."""
    lane_words = stream_ref[...]                             # (S, T, 128) u16
    S, T, _ = lane_words.shape
    freq = freq_ref[...]
    nv = nvalid_ref[...]
    dec_packed = jax.vmap(build_dec_table)(freq)
    slot2sym = jax.vmap(slot_to_symbol)(freq)

    R = rows_per_step
    vc = _valid_positions(T, nv).reshape(T // R, R, S, N_LANES)

    def step(carry, vck):
        x, ptr = carry
        rows = [None] * R
        for k in range(R):
            valid = vck[k]
            x2, sym, need = _dec_step(x, dec_packed, slot2sym)
            need = need & valid
            sgn = jnp.where(
                valid, (sym - ((sym & 0x80) << 1)).astype(jnp.int8), 0
            )
            w = jnp.take_along_axis(
                lane_words, jnp.minimum(ptr, T - 1)[:, None, :], axis=1
            )[:, 0].astype(jnp.uint32)
            x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w, x2)
            x = jnp.where(valid, x2, x)
            ptr = ptr + need.astype(jnp.int32)
            rows[k] = sgn
        return (x, ptr), jnp.stack(rows)

    carry = (state_ref[...], jnp.zeros((S, N_LANES), jnp.int32))
    _, out = jax.lax.scan(step, carry, vc)
    codes_ref[...] = jnp.moveaxis(out.reshape(T, S, N_LANES), 1, 0)


def _rows_per_step(rows_per_step, interpret: bool, rows: int) -> int:
    """Static scan-step width: 1 row/trip under interpret (many tiny
    ops beat few fat fused bodies on CPU), an (N_GROUPS, 128) sublane tile
    per trip otherwise (one vreg per step on TPU).  Pure schedule — the
    output bits are identical for every choice."""
    if rows_per_step is None:
        rows_per_step = 1 if interpret else N_GROUPS
    if rows % rows_per_step:
        raise ValueError(f"{rows} rows not a multiple of {rows_per_step}")
    return rows_per_step


def _histogram_impl(histogram, interpret: bool) -> str:
    """Default the histogram strategy per backend: SWAR popcount under
    interpret (the CPU GEMM runs 16-wide tiles at a quarter of peak),
    one-hot matmul otherwise (the MXU eats it).  Bit-identical either
    way — both are exact counts."""
    if histogram is None:
        histogram = "swar" if interpret else "dot"
    if histogram not in ("dot", "swar"):
        raise ValueError(f"unknown histogram strategy {histogram!r}")
    return histogram


def rans_encode_pallas(codes, n_valid, *, division: str = "divide",
                       rows_per_step: int = None, histogram: str = None,
                       interpret: bool = True):
    """Encode all S shards of a stripe in one launch (the stripe is the
    kernel block; shards stack on the batch axis of every vector op).

    codes: (S, T, 128) int8 payload rows, zero-padded (the histogram's
    pad correction requires the padding bytes to BE zero — ``ops.py``
    guarantees it); T % T_TILE == 0.
    n_valid: (S, 1) int32 valid byte count per shard — positions past it
    are padding and are excluded from both the histogram and the coding
    loop (their lanes idle, costing zero stream bytes).
    division: "reciprocal" (all-integer Granlund-Montgomery mulhi — the
    interpret/CPU default; u32 udiv scalarizes on x86), "rcp32"
    (error-repaired f32 reciprocal — the TPU default; Mosaic has no
    integer divide) or "divide" (hardware udiv); the streams are
    bit-identical in all three.
    histogram: "swar" (popcount sweep — interpret/CPU default) or "dot"
    (one-hot matmul — TPU default); exact counts, bit-identical streams
    either way.
    Returns (words (S, T, 128) uint16, mask (S, T, 128) uint8,
    freq (S, 256) int32, states (S, 128) uint32): the dense emission buffer
    + per-row emission mask (rank-select compacted by the caller), the
    per-shard frequency tables, and the final lane states the decoder
    starts from.
    """
    S, T, L = codes.shape
    if L != N_LANES:
        raise ValueError(f"expected {N_LANES} lanes, got {L}")
    if T % T_TILE:
        raise ValueError(f"rows {T} not a multiple of {T_TILE}")
    if division not in ("divide", "rcp32", "reciprocal"):
        raise ValueError(f"unknown division strategy {division!r}")
    rps = _rows_per_step(rows_per_step, interpret, T)
    hist = _histogram_impl(histogram, interpret)
    return pl.pallas_call(
        functools.partial(_encode_kernel, division=division,
                          rows_per_step=rps, histogram=hist),
        out_shape=[
            jax.ShapeDtypeStruct((S, T, N_LANES), jnp.uint16),
            jax.ShapeDtypeStruct((S, T, N_LANES), jnp.uint8),
            jax.ShapeDtypeStruct((S, 256), jnp.int32),
            jax.ShapeDtypeStruct((S, N_LANES), jnp.uint32),
        ],
        interpret=interpret,
    )(codes, n_valid)


def rans_decode_pallas(stream, freq, states, n_valid, *, rows: int,
                       rows_per_step: int = None, interpret: bool = True):
    """Version-1 decode twin: flat row-major word streams -> original bytes.

    stream: (S, W) uint16 — each shard's words in global decoder-read order
    (tails past the shard's word count are never consumed).  The decoder
    advances a single per-shard stream pointer; per sub-step, the lanes
    that renormalize take the next popcount(need) words in lane order via
    an in-register prefix sum — no per-lane offset table is parsed.
    freq: (S, 256) int32 tables; states: (S, 128) uint32 initial lane
    states; n_valid: (S, 1) int32 — must equal the encoder's.
    Returns (S, rows, 128) int8 decoded payload rows, zeros past n_valid.
    """
    S, W = stream.shape
    if rows % T_TILE:
        raise ValueError(f"rows {rows} not a multiple of {T_TILE}")
    rps = _rows_per_step(rows_per_step, interpret, rows)
    return pl.pallas_call(
        functools.partial(_decode_kernel, rows_per_step=rps),
        out_shape=jax.ShapeDtypeStruct((S, rows, N_LANES), jnp.int8),
        interpret=interpret,
    )(stream, freq, states, n_valid)


def rans_decode_pallas_v0(lane_words, freq, states, n_valid, *,
                          rows_per_step: int = None, interpret: bool = True):
    """Version-0 decode twin: per-lane word streams + header tables.

    lane_words: (S, T, 128) uint16 — word j of lane l at [s, j, l] (the
    caller re-gathers the flat lane-major stream into this layout; tails
    past each lane's length are never consumed).  Kept so PR-4-era archives
    and checkpoints stay decodable across the row-major format change.
    """
    S, T, L = lane_words.shape
    if L != N_LANES:
        raise ValueError(f"expected {N_LANES} lanes, got {L}")
    if T % T_TILE:
        raise ValueError(f"rows {T} not a multiple of {T_TILE}")
    rps = _rows_per_step(rows_per_step, interpret, T)
    return pl.pallas_call(
        functools.partial(_decode_kernel_v0, rows_per_step=rps),
        out_shape=jax.ShapeDtypeStruct((S, T, N_LANES), jnp.int8),
        interpret=interpret,
    )(lane_words, freq, states, n_valid)
