"""Staged pure-jnp oracle for the interleaved-rANS coder (bit-exact target).

The reference runs the coder as separate full-stripe passes — histogram,
table build, then one ``lax.scan`` over rows vectorized over (shard, lane) —
i.e. the pre-fusion pipeline with one HBM round-trip per stage, exactly like
``kernels/seal/ref.py`` mirrors the fused seal kernel.  Outputs must match
``rans.rans_encode_pallas`` / ``rans_decode_pallas`` bit-for-bit: the coder
is all-integer, so there is no tolerance anywhere.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.entropy.rans import (
    N_LANES,
    PROB_SCALE,
    RANS_L,
    _dec_step,
    _enc_step,
    build_freq_table,
    slot_to_symbol,
)

__all__ = ["STAGED_PASSES", "N_STAGED_PASSES", "rans_encode_ref", "rans_decode_ref"]

# One entry per full-payload pass in the staged pipeline (the fused kernel
# does all of them in one VMEM residency per shard).
STAGED_PASSES = (
    "byte histogram (read payload)",
    "frequency-table normalize (256-entry, table-only)",
    "interleaved encode scan (read payload, write words+mask)",
    "emission compaction (read words+mask, write stream)",
)
N_STAGED_PASSES = len(STAGED_PASSES)


def _valid_mask(S: int, T: int, n_valid: jax.Array) -> jax.Array:
    """(S, T, 128) bool: position r*128+l is a real (non-padding) byte."""
    gidx = jnp.arange(T * N_LANES, dtype=jnp.int32).reshape(1, T, N_LANES)
    return gidx < n_valid.reshape(S, 1, 1)


def rans_encode_ref(codes: jax.Array, n_valid: jax.Array) -> Tuple[jax.Array, ...]:
    """Staged encode: same signature/outputs as ``rans_encode_pallas``."""
    S, T, L = codes.shape
    assert L == N_LANES, codes.shape
    vals = (codes.astype(jnp.int32)) & 0xFF                  # (S, T, 128)
    vmask = _valid_mask(S, T, n_valid)

    # pass 1-2: histogram + table per shard (padding -> dropped overflow bin)
    hidx = jnp.where(vmask, vals, 256)
    counts = jax.vmap(
        lambda v: jnp.zeros((257,), jnp.int32).at[v.reshape(-1)].add(1)[:256]
    )(hidx)
    freq = jax.vmap(build_freq_table)(counts)                # (S, 256)
    cum = jnp.cumsum(freq, axis=-1) - freq
    f_u = freq.astype(jnp.uint32)
    c_u = cum.astype(jnp.uint32)

    # pass 3: encode scan over rows, reversed (rANS codes backwards),
    # vectorized over the (shard, lane) axes
    def step(x, xs):
        row, valid = xs                                      # (S, 128) each
        f = jnp.take_along_axis(f_u, row, axis=-1)
        c = jnp.take_along_axis(c_u, row, axis=-1)
        x2, w, m = _enc_step(x, f, c)
        x = jnp.where(valid, x2, x)                          # pad lanes: no-op
        return x, (w, (m & valid).astype(jnp.uint8))

    x0 = jnp.full((S, N_LANES), RANS_L, jnp.uint32)
    states, (w_rev, m_rev) = jax.lax.scan(
        step,
        x0,
        (jnp.swapaxes(vals, 0, 1)[::-1], jnp.swapaxes(vmask, 0, 1)[::-1]),
    )
    words = jnp.swapaxes(w_rev[::-1], 0, 1)                  # back to (S, T, 128)
    mask = jnp.swapaxes(m_rev[::-1], 0, 1)
    return words, mask, freq, states


def rans_decode_ref(
    lane_words: jax.Array,
    freq: jax.Array,
    states: jax.Array,
    n_valid: jax.Array,
) -> jax.Array:
    """Staged decode: same signature/outputs as ``rans_decode_pallas``."""
    S, T, L = lane_words.shape
    assert L == N_LANES, lane_words.shape
    vmask = _valid_mask(S, T, n_valid)
    cum_excl = jnp.cumsum(freq, axis=-1) - freq
    slot2sym = jax.vmap(
        lambda f: slot_to_symbol(f, jnp.arange(PROB_SCALE, dtype=jnp.int32))
    )(freq)

    def step(carry, valid):
        x, ptr = carry
        x2, s, need = jax.vmap(_dec_step)(x, freq, cum_excl, slot2sym)
        need = need & valid
        w = jnp.take_along_axis(
            lane_words, jnp.minimum(ptr, T - 1)[:, None, :], axis=1
        )[:, 0].astype(jnp.uint32)
        x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w, x2)
        x = jnp.where(valid, x2, x)                          # pad lanes: no-op
        ptr = ptr + need.astype(jnp.int32)
        signed = jnp.where(valid, s - ((s & 0x80) << 1), 0).astype(jnp.int8)
        return (x, ptr), signed

    ptr0 = jnp.zeros((S, N_LANES), jnp.int32)
    _, rows = jax.lax.scan(step, (states, ptr0), jnp.swapaxes(vmask, 0, 1))
    return jnp.swapaxes(rows, 0, 1)                          # (S, T, 128)
