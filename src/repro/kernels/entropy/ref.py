"""Staged pure-jnp oracle for the interleaved-rANS coder (bit-exact target).

The reference runs the coder as separate full-stripe passes — one-hot
matmul histogram, table build (frequencies + Granlund-Montgomery
reciprocals), then one ``lax.scan`` over rows vectorized over
(shard, lane) — i.e. the pre-fusion pipeline with one HBM round-trip per
stage, exactly like ``kernels/seal/ref.py`` mirrors the fused seal kernel.
Outputs must match ``rans.rans_encode_pallas`` / ``rans_decode_pallas``
bit-for-bit: the coder is all-integer (and the histogram's f32 partial
sums are all exact integer counts < 2^24, so any summation order agrees),
so there is no tolerance anywhere.  The scan steps per *row* while the
kernel steps per (G, 128) lane-group tile; the carried math is identical,
so the schedules agree bit-for-bit.

Both stream versions are mirrored: ``rans_decode_ref`` consumes the
version-1 row-major word stream with a scalar prefix-sum pointer per
shard, ``rans_decode_ref_v0`` the PR-4 lane-major layout with per-lane
pointers.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.entropy.rans import (
    N_LANES,
    RANS_L,
    _dec_step,
    _enc_step,
    _histogram,
    build_dec_table,
    build_enc_tables,
    build_freq_table,
    slot_to_symbol,
)

__all__ = [
    "STAGED_PASSES",
    "N_STAGED_PASSES",
    "rans_encode_ref",
    "rans_decode_ref",
    "rans_decode_ref_v0",
]

# One entry per full-payload pass in the staged pipeline (the fused kernel
# does all of them in one VMEM residency per shard).
STAGED_PASSES = (
    "one-hot matmul histogram (read payload)",
    "table build: freqs + integer reciprocals (256-entry, table-only)",
    "interleaved encode scan (read payload, write words+mask)",
    "emission rank-select compaction (read words+mask, write stream)",
)
N_STAGED_PASSES = len(STAGED_PASSES)


def _valid_mask(S: int, T: int, n_valid: jax.Array) -> jax.Array:
    """(S, T, 128) bool: position r*128+l is a real (non-padding) byte."""
    gidx = jnp.arange(T * N_LANES, dtype=jnp.int32).reshape(1, T, N_LANES)
    return gidx < n_valid.reshape(S, 1, 1)


def rans_encode_ref(codes: jax.Array, n_valid: jax.Array,
                    division: str = "divide") -> Tuple[jax.Array, ...]:
    """Staged encode: same signature/outputs as ``rans_encode_pallas``."""
    S, T, L = codes.shape
    assert L == N_LANES, codes.shape
    vals = (codes.astype(jnp.int32)) & 0xFF                  # (S, T, 128)
    vmask = _valid_mask(S, T, n_valid)

    # pass 1-2: one-hot matmul histogram + tables per shard
    counts = jax.vmap(_histogram)(vals, n_valid.reshape(S))
    freq = jax.vmap(build_freq_table)(counts)                # (S, 256)
    packed, mprime, rcp = jax.vmap(build_enc_tables)(freq)
    aux = {"divide": packed, "reciprocal": mprime, "rcp32": rcp}[division]

    # pass 3: encode scan over rows, reversed (rANS codes backwards),
    # vectorized over the (shard, lane) axes
    def step(x, xs):
        row, valid = xs                                      # (S, 128) each
        p = jnp.take_along_axis(packed, row, axis=-1)
        a = jnp.take_along_axis(aux, row, axis=-1)
        x2, x_pre, e = _enc_step(x, p, a, division=division)
        x = jnp.where(valid, x2, x)                          # pad lanes: no-op
        w = (x_pre & jnp.uint32(0xFFFF)).astype(jnp.uint16)
        return x, (w, (e & valid).astype(jnp.uint8))

    x0 = jnp.full((S, N_LANES), RANS_L, jnp.uint32)
    states, (w_rev, m_rev) = jax.lax.scan(
        step,
        x0,
        (jnp.swapaxes(vals, 0, 1)[::-1], jnp.swapaxes(vmask, 0, 1)[::-1]),
    )
    words = jnp.swapaxes(w_rev[::-1], 0, 1)                  # back to (S, T, 128)
    mask = jnp.swapaxes(m_rev[::-1], 0, 1)
    return words, mask, freq, states


def rans_decode_ref(
    stream: jax.Array,
    freq: jax.Array,
    states: jax.Array,
    n_valid: jax.Array,
    *,
    rows: int,
) -> jax.Array:
    """Version-1 staged decode: same outputs as ``rans_decode_pallas``.

    stream: (S, W) uint16 row-major words; one scalar read pointer per
    shard advances by popcount(need) each row (exclusive in-row prefix sum
    assigns the words to lanes in lane order).
    """
    S, W = stream.shape
    vmask = _valid_mask(S, rows, n_valid)
    dec_packed = jax.vmap(build_dec_table)(freq)
    slot2sym = jax.vmap(slot_to_symbol)(freq)

    def step(carry, valid):
        x, base = carry
        x2, s, need = jax.vmap(_dec_step)(x, dec_packed, slot2sym)
        need = need & valid
        csum = jnp.cumsum(need.astype(jnp.int32), axis=-1)   # (S, 128)
        pos = base[:, None] + csum - need.astype(jnp.int32)  # exclusive
        w = jnp.take_along_axis(
            stream, jnp.minimum(pos, W - 1), axis=1
        ).astype(jnp.uint32)
        x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w, x2)
        x = jnp.where(valid, x2, x)                          # pad lanes: no-op
        base = base + csum[:, -1]
        signed = jnp.where(valid, s - ((s & 0x80) << 1), 0).astype(jnp.int8)
        return (x, base), signed

    base0 = jnp.zeros((S,), jnp.int32)
    _, out = jax.lax.scan(step, (states, base0), jnp.swapaxes(vmask, 0, 1))
    return jnp.swapaxes(out, 0, 1)                           # (S, rows, 128)


def rans_decode_ref_v0(
    lane_words: jax.Array,
    freq: jax.Array,
    states: jax.Array,
    n_valid: jax.Array,
) -> jax.Array:
    """Version-0 staged decode: lane-major words, per-lane read pointers."""
    S, T, L = lane_words.shape
    assert L == N_LANES, lane_words.shape
    vmask = _valid_mask(S, T, n_valid)
    dec_packed = jax.vmap(build_dec_table)(freq)
    slot2sym = jax.vmap(slot_to_symbol)(freq)

    def step(carry, valid):
        x, ptr = carry
        x2, s, need = jax.vmap(_dec_step)(x, dec_packed, slot2sym)
        need = need & valid
        w = jnp.take_along_axis(
            lane_words, jnp.minimum(ptr, T - 1)[:, None, :], axis=1
        )[:, 0].astype(jnp.uint32)
        x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w, x2)
        x = jnp.where(valid, x2, x)                          # pad lanes: no-op
        ptr = ptr + need.astype(jnp.int32)
        signed = jnp.where(valid, s - ((s & 0x80) << 1), 0).astype(jnp.int8)
        return (x, ptr), signed

    ptr0 = jnp.zeros((S, N_LANES), jnp.int32)
    _, rows = jax.lax.scan(step, (states, ptr0), jnp.swapaxes(vmask, 0, 1))
    return jnp.swapaxes(rows, 0, 1)                          # (S, T, 128)
