"""Public wrappers for the interleaved-rANS entropy stage: padding, dispatch,
stream packing, accounting.

``encode_payloads`` / ``decode_payloads`` accept ragged per-shard payloads,
pad them to the kernel's (T, 128) lane grid (T pow2-bucketed like
``seal_ops.bucket_rows_for`` so jit traces stay bounded for mixed GOP
sizes), dispatch either the fused Pallas coder (one launch per stripe) or
the staged jnp oracle (``use_pallas=False``), and pack the result into a
self-contained compressed byte stream per shard:

    [freq table: 256 x u16][lane lengths: 128 x u32][lane states: 128 x u32]
    [per-lane word streams, lane-major, in decoder read order]

Everything a decoder needs except the raw/compressed lengths (tiny host
metadata, recorded in the archive manifest like ``n_i8``) travels inside the
stream, so the compression-ratio accounting is honest: ``n_comp`` includes
the 1280-byte header.  The stream bytes are what the seal kernel encrypts
and parity-codes — the entropy stage output never has to visit the host.

``core_fn`` overrides the coder launch itself; the sharded path
(``repro.distributed.archival``) passes a shard_map'd wrapper with the same
signature, exactly like ``seal_fn``/``unseal_fn`` in the seal pipeline.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import as_payload_list, use_interpret
from repro.kernels.entropy import ref as _ref
from repro.kernels.entropy.rans import (
    N_LANES,
    T_TILE,
    rans_decode_pallas,
    rans_encode_pallas,
)

__all__ = [
    "HEADER_BYTES",
    "MAX_ROWS",
    "rows_for",
    "encode_payloads",
    "decode_payloads",
    "entropy_traffic",
]

# freq u16[256] + lane_lens u32[128] + states u32[128]
HEADER_BYTES = 2 * 256 + 4 * N_LANES + 4 * N_LANES
# int32 global byte indices inside the kernels bound the shard size (the
# practical bound: one stripe shard is a GOP or a checkpoint chunk, not GBs)
MAX_ROWS = 1 << 23  # 1 GiB per shard


def rows_for(n_bytes: int) -> int:
    """Smallest pow2 multiple of ``T_TILE`` lane rows covering n_bytes.

    Pow2 bucketing bounds jit traces at log2(max_rows) for arbitrarily
    ragged payload mixes (same scheme as ``seal_ops.bucket_rows_for``); the
    padding bytes are zeros, which the coder squeezes to ~0 bits each.
    """
    rows = max(1, -(-n_bytes // N_LANES))
    tiles = -(-rows // T_TILE)
    return T_TILE * (1 << (tiles - 1).bit_length())


def _u16_to_u8(w: jax.Array) -> jax.Array:
    """(..., n) uint16 -> (..., 2n) uint8, little-endian."""
    lo = (w & jnp.uint16(0xFF)).astype(jnp.uint8)
    hi = (w >> jnp.uint16(8)).astype(jnp.uint8)
    return jnp.stack([lo, hi], axis=-1).reshape(*w.shape[:-1], -1)


def _u32_to_u8(w: jax.Array) -> jax.Array:
    """(..., n) uint32 -> (..., 4n) uint8, little-endian."""
    parts = [
        ((w >> jnp.uint32(8 * k)) & jnp.uint32(0xFF)).astype(jnp.uint8)
        for k in range(4)
    ]
    return jnp.stack(parts, axis=-1).reshape(*w.shape[:-1], -1)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def _encode_core(codes, n_valid, *, use_pallas: bool, interpret: bool):
    if use_pallas:
        return rans_encode_pallas(codes, n_valid, interpret=interpret)
    return _ref.rans_encode_ref(codes, n_valid)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def _decode_core(lane_words, freq, states, n_valid, *,
                 use_pallas: bool, interpret: bool):
    if use_pallas:
        return rans_decode_pallas(
            lane_words, freq, states, n_valid, interpret=interpret
        )
    return _ref.rans_decode_ref(lane_words, freq, states, n_valid)


@jax.jit
def _pack_streams(words, mask, freq, states):
    """Dense emissions -> (padded compressed bytes (S, C), n_comp (S,)).

    Compaction is a prefix-sum scatter in lane-major order: lane l's words
    land at [off(l), off(l)+len(l)) in increasing row order — exactly the
    order the decoder consumes them (rANS emits backwards, reads forwards;
    the encode kernel already tagged each emission with its row).  Unemitted
    slots are routed to one overflow slot past the end and dropped.
    """
    S, T, L = words.shape
    lm = jnp.swapaxes(mask, 1, 2).reshape(S, L * T) != 0
    wm = jnp.swapaxes(words, 1, 2).reshape(S, L * T)
    pos = jnp.cumsum(lm, axis=1) - 1
    dest = jnp.where(lm, pos, L * T)
    comp_words = (
        jnp.zeros((S, L * T + 1), jnp.uint16)
        .at[jnp.arange(S)[:, None], dest]
        .set(wm)[:, : L * T]
    )
    lane_lens = mask.astype(jnp.int32).sum(axis=1)           # (S, L)
    n_words = lm.sum(axis=1)                                 # (S,)
    header = jnp.concatenate(
        [
            _u16_to_u8(freq.astype(jnp.uint16)),
            _u32_to_u8(lane_lens.astype(jnp.uint32)),
            _u32_to_u8(states),
        ],
        axis=1,
    )
    comp = jnp.concatenate([header, _u16_to_u8(comp_words)], axis=1)
    return comp, HEADER_BYTES + 2 * n_words


@functools.partial(jax.jit, static_argnames=("rows",))
def _parse_streams(comp, *, rows: int):
    """Padded compressed bytes (S, C) uint8 -> decoder inputs.

    Re-gathers the flat word stream into the (S, T, 128) per-lane layout the
    decode kernel scans: word j of lane l sits at stream[off(l) + j].
    Positions past a lane's length gather a clamped index — never consumed,
    because the decoder's renorm flags mirror the encoder's emissions.
    """
    S, C = comp.shape
    u = comp.astype(jnp.int32)
    freq = u[:, 0:512:2] | (u[:, 1:512:2] << 8)              # (S, 256)
    lane_lens = (
        u[:, 512:1024:4]
        | (u[:, 513:1024:4] << 8)
        | (u[:, 514:1024:4] << 16)
        | (u[:, 515:1024:4] << 24)
    )                                                        # (S, 128)
    su = comp.astype(jnp.uint32)
    states = (
        su[:, 1024:1536:4]
        | (su[:, 1025:1536:4] << jnp.uint32(8))
        | (su[:, 1026:1536:4] << jnp.uint32(16))
        | (su[:, 1027:1536:4] << jnp.uint32(24))
    )                                                        # (S, 128)
    body = u[:, HEADER_BYTES:]
    W = body.shape[1] // 2
    stream = (body[:, 0 : 2 * W : 2] | (body[:, 1 : 2 * W : 2] << 8)).astype(
        jnp.uint16
    )
    off = jnp.cumsum(lane_lens, axis=-1) - lane_lens         # exclusive
    idx = off[:, None, :] + jnp.arange(rows, dtype=jnp.int32)[None, :, None]
    idx = jnp.clip(idx, 0, W - 1).reshape(S, rows * N_LANES)
    lane_words = jnp.take_along_axis(stream, idx, axis=1).reshape(
        S, rows, N_LANES
    )
    return lane_words, freq, states


def encode_payloads(
    payloads,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    core_fn=None,
) -> Tuple[List[jax.Array], List[Dict]]:
    """rANS-encode S ragged shard payloads in one fused launch.

    payloads: list of flat int8 arrays (ragged ok) or an (S, N) int8 array.
    Returns (compressed int8 streams — exact length, header included — and
    per-shard metas ``{"codec", "n_raw", "n_comp", "rows"}``).  ``rows`` is
    the padded lane-row count the whole stripe was coded at; decode needs it
    back.  ``core_fn`` overrides the coder launch (sharded path).
    """
    flats = as_payload_list(payloads)
    if not flats:
        raise ValueError("stripe must contain at least one shard payload")
    n_raw = tuple(int(f.shape[0]) for f in flats)
    T = rows_for(max(n_raw))
    if T > MAX_ROWS:
        raise ValueError(
            f"payload of {max(n_raw)} bytes needs {T} lane rows (max "
            f"{MAX_ROWS}); split it across more stripe shards"
        )
    codes = jnp.stack(
        [
            jnp.pad(f, (0, T * N_LANES - n)).reshape(T, N_LANES)
            for f, n in zip(flats, n_raw)
        ]
    )
    n_valid = jnp.asarray(n_raw, jnp.int32).reshape(-1, 1)
    if core_fn is None:
        core_fn = functools.partial(
            _encode_core, use_pallas=use_pallas, interpret=use_interpret(interpret)
        )
    words, mask, freq, states = core_fn(codes, n_valid)
    comp_pad, n_comp_dev = _pack_streams(words, mask, freq, states)
    n_comp = [int(n) for n in np.asarray(n_comp_dev)]        # tiny host metadata
    comps, metas = [], []
    for s, (nr, nc) in enumerate(zip(n_raw, n_comp)):
        if nc >= nr:
            # adaptive raw-skip: an incompressible shard (or one smaller
            # than the 1280-byte stream header) is stored as-is; the
            # manifest flag is what the decode path dispatches on
            comps.append(flats[s].reshape(-1).astype(jnp.int8))
            metas.append(
                {"codec": "rans", "raw": True,
                 "n_raw": nr, "n_comp": nr, "rows": T}
            )
        else:
            comps.append(comp_pad[s, :nc].astype(jnp.int8))
            metas.append(
                {"codec": "rans", "n_raw": nr, "n_comp": nc, "rows": T}
            )
    return comps, metas


def decode_payloads(
    comps: Sequence[jax.Array],
    metas: Sequence[Dict],
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    core_fn=None,
) -> List[jax.Array]:
    """Decode twin: compressed streams + metas -> exact original payloads.

    Shards the encoder flagged ``raw`` (adaptive raw-skip: compressed would
    have been >= raw) pass through untouched; only the genuinely coded
    shards enter the kernel launch, so a stripe that mixes both still runs
    one launch.  Works identically under the sharded ``core_fn``.
    """
    if len(comps) != len(metas):
        raise ValueError(f"{len(comps)} streams vs {len(metas)} metas")
    if not comps:
        raise ValueError("stripe must contain at least one shard payload")
    T = int(metas[0]["rows"])
    if any(int(m["rows"]) != T for m in metas):
        raise ValueError("all shards of a stripe share one padded row count")
    flats = [jnp.asarray(c).reshape(-1).astype(jnp.uint8) for c in comps]
    out: List[Optional[jax.Array]] = [None] * len(flats)
    coded: List[int] = []
    for i, (f, m) in enumerate(zip(flats, metas)):
        if int(f.shape[0]) != int(m["n_comp"]):
            raise ValueError(
                f"stream is {int(f.shape[0])} bytes, manifest says {m['n_comp']}"
            )
        if m.get("raw"):
            if int(m["n_comp"]) != int(m["n_raw"]):
                raise ValueError(
                    f"raw-skip shard must store n_raw bytes, manifest says "
                    f"{m['n_comp']} vs {m['n_raw']}"
                )
            out[i] = f.astype(jnp.int8)
            continue
        if int(f.shape[0]) < HEADER_BYTES:
            raise ValueError("compressed stream shorter than its header")
        coded.append(i)
    if coded:
        sub = [flats[i] for i in coded]
        # common padded width, stream area even and >= one word (tails unread)
        C = max(max(int(f.shape[0]) for f in sub), HEADER_BYTES + 2)
        C += (C - HEADER_BYTES) % 2
        comp = jnp.stack([jnp.pad(f, (0, C - f.shape[0])) for f in sub])
        lane_words, freq, states = _parse_streams(comp, rows=T)
        n_valid = jnp.asarray(
            [int(metas[i]["n_raw"]) for i in coded], jnp.int32
        ).reshape(-1, 1)
        if core_fn is None:
            core_fn = functools.partial(
                _decode_core, use_pallas=use_pallas,
                interpret=use_interpret(interpret),
            )
        codes = core_fn(lane_words, freq, states, n_valid)
        for j, i in enumerate(coded):
            out[i] = codes[j].reshape(-1)[: int(metas[i]["n_raw"])]
    return out


def entropy_traffic(n_raw: int, n_comp: int) -> dict:
    """Structural byte accounting: on-device coder vs host entropy stage.

    The host path must round-trip every payload byte over the host link
    (the exact traffic the paper's CSD offload exists to remove); the fused
    path ships zero payload bytes host-side — only O(1) manifest ints.
    """
    return {
        "ratio": n_raw / n_comp if n_comp else float("nan"),
        "host_entropy_bytes": 0,
        "host_bytes_eliminated": n_raw,
        "staged_passes": _ref.N_STAGED_PASSES,
        "fused_launches": 1,
    }
