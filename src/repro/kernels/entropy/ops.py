"""Public wrappers for the interleaved-rANS entropy stage: padding, dispatch,
stream packing, accounting.

``encode_payloads`` / ``decode_payloads`` accept ragged per-shard payloads,
pad them to the kernel's (T, 128) lane grid (T pow2-bucketed like
``seal_ops.bucket_rows_for`` so jit traces stay bounded for mixed GOP
sizes), dispatch either the fused Pallas coder (one launch per stripe) or
the staged jnp oracle (``use_pallas=False``), and pack the result into a
self-contained compressed byte stream per shard:

    [freq table: 256 x u16][lane lengths: 128 x u32][lane states: 128 x u32]
    [16-bit words in global decoder-read order (row-major across lanes)]

Everything a decoder needs except the raw/compressed lengths and the stream
``version`` (tiny host metadata, recorded in the archive manifest like
``n_i8``) travels inside the stream, so the compression-ratio accounting is
honest: ``n_comp`` includes the 1536-byte header.  The stream bytes are
what the seal kernel encrypts and parity-codes — the entropy stage output
never has to visit the host.

Stream versions: version 1 (current) packs words row-major — the order a
forward decode consumes them — so the decoder runs a single prefix-summed
stream pointer and parsing is a straight byte split.  Version 0 (PR-4)
packed per-lane-contiguous word runs; those streams still decode through
``_parse_streams_v0`` + the lane-major kernel twin.  Both share one header
layout (the lane-length table is self-description/integrity metadata for
v1 — its offsets are only *required* for v0's re-gather), so a version
bump never changes ``n_comp``: the compression ratio is identical by
construction.

Compaction of the dense emission buffer is a two-level rank-select *gather*
(scatter-free: XLA scatters serialize on TPU and CPU alike): the k-th
output word's row comes from a scatter-max + running-max over the 512-odd
row offsets, and its lane from a branchless binary search over the in-row
prefix sums (5 u8 gather rounds to an aligned 4-lane block, one u32 gather
for the block's boundary prefixes).  The search width is *tiered*: the
pack's static capacity is the raw-skip worst case, and a ``lax.cond`` drops
to half width whenever the batch's measured emission counts fit — which is
what lets the encode pipeline run with no mid-stream host sync.  ``core_fn`` overrides the coder launch itself; the
sharded path (``repro.distributed.archival``) passes a shard_map'd wrapper
with the same signature, exactly like ``seal_fn``/``unseal_fn`` in the
seal pipeline.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import as_payload_list, use_interpret
from repro.kernels.entropy import ref as _ref
from repro.kernels.entropy.rans import (
    N_LANES,
    STREAM_VERSION,
    T_TILE,
    rans_decode_pallas,
    rans_decode_pallas_v0,
    rans_encode_pallas,
)

__all__ = [
    "HEADER_BYTES",
    "MAX_ROWS",
    "rows_for",
    "cap_for",
    "stream_word_cap",
    "encode_payloads",
    "decode_payloads",
    "entropy_traffic",
]

# freq u16[256] + lane_lens u32[128] + states u32[128]
HEADER_BYTES = 2 * 256 + 4 * N_LANES + 4 * N_LANES
# 2^17 lane rows = 16 MiB per shard: the practical bound (one stripe
# shard is a GOP or a checkpoint chunk, not GBs), and it keeps the
# histogram's one-hot operands and the coder's working set a size one
# kernel residency can reasonably hold
MAX_ROWS = 1 << 17


def rows_for(n_bytes: int) -> int:
    """Smallest pow2 multiple of ``T_TILE`` lane rows covering n_bytes.

    Pow2 bucketing bounds jit traces at log2(max_rows) for arbitrarily
    ragged payload mixes (same scheme as ``seal_ops.bucket_rows_for``); the
    padding bytes are zeros, which the coder squeezes to ~0 bits each.
    """
    rows = max(1, -(-n_bytes // N_LANES))
    tiles = -(-rows // T_TILE)
    return T_TILE * (1 << (tiles - 1).bit_length())


def cap_for(n_words: int) -> int:
    """Pow2 word capacity bucket (>= 1) for a known emission count.

    Legacy sizing helper: the encode path used to sync the emission counts
    to the host mid-pipeline to jit-specialize the pack on this bucket; it
    now packs at the static worst case (:func:`stream_word_cap`) with the
    tiered rank-select, so no device->host round-trip splits the encode.
    Kept for callers sizing scratch buffers off a known word count.
    """
    return 1 << max(0, int(n_words - 1).bit_length())


def stream_word_cap(T: int) -> int:
    """Worst-case u16 stream words worth packing for a T-row shard (any
    shard emitting more compresses to >= its raw size and is stored raw,
    so capping the pack here discards only streams the raw-skip select
    would discard anyway — the packed words are position-exact for ANY
    cap, see :func:`_pack_rank_impl`)."""
    return max(1, (T * N_LANES - HEADER_BYTES) // 2)


def _u16_to_u8(w: jax.Array) -> jax.Array:
    """(..., n) uint16 -> (..., 2n) uint8, little-endian."""
    lo = (w & jnp.uint16(0xFF)).astype(jnp.uint8)
    hi = (w >> jnp.uint16(8)).astype(jnp.uint8)
    return jnp.stack([lo, hi], axis=-1).reshape(*w.shape[:-1], -1)


def _u32_to_u8(w: jax.Array) -> jax.Array:
    """(..., n) uint32 -> (..., 4n) uint8, little-endian."""
    parts = [
        ((w >> jnp.uint32(8 * k)) & jnp.uint32(0xFF)).astype(jnp.uint8)
        for k in range(4)
    ]
    return jnp.stack(parts, axis=-1).reshape(*w.shape[:-1], -1)


@functools.partial(
    jax.jit, static_argnames=("use_pallas", "interpret", "division")
)
def _encode_core(codes, n_valid, *, use_pallas: bool, interpret: bool,
                 division: Optional[str] = None):
    if division is None:
        # interpret/CPU: the shifted-reciprocal mulhi path beats LLVM's
        # udiv ~18% — x86 has no vector u32 divide, so udiv scalarizes
        # while mulhi stays SIMD; real TPU: Mosaic has no integer divide,
        # the repaired-f32 reciprocal is the fast exact replacement (all
        # three strategies are bit-identical)
        division = "reciprocal" if interpret else "rcp32"
    if use_pallas:
        return rans_encode_pallas(
            codes, n_valid, division=division, interpret=interpret
        )
    return _ref.rans_encode_ref(codes, n_valid, division=division)


@functools.partial(
    jax.jit, static_argnames=("version", "rows", "use_pallas", "interpret")
)
def _decode_core(words, freq, states, n_valid, *, version: int, rows: int,
                 use_pallas: bool, interpret: bool):
    if version == 0:
        if use_pallas:
            return rans_decode_pallas_v0(
                words, freq, states, n_valid, interpret=interpret
            )
        return _ref.rans_decode_ref_v0(words, freq, states, n_valid)
    if use_pallas:
        return rans_decode_pallas(
            words, freq, states, n_valid, rows=rows, interpret=interpret
        )
    return _ref.rans_decode_ref(words, freq, states, n_valid, rows=rows)


def _pack_rank_impl(mask, *, cap: int, tiered: bool = False):
    """Stage 1 of the rank-select pack: per-output-slot source positions.

    For each output slot k the source row is recovered from a scatter-max
    of row ids at their stream offsets followed by a running max (the same
    cumulative-bucket fill the decoder uses for its slot table), and the
    source lane by a branchless bit-step lower bound over the u8 in-row
    prefix sums — every wide op is a gather, which vectorizes where a
    word-per-word scatter would serialize.  (A one-scatter inverse — write
    each word at ``row_off + rank`` — measured ~1.6x SLOWER than these
    gathers at the fused kernel's batch size: XLA:CPU serializes the 2M
    element stores.)

    ``tiered=True`` (both the fused kernel and the host pack, whose
    ``cap`` is the static worst-case ``stream_word_cap``, ~2.5x a typical
    emission count) bounds the per-slot work by the *measured* batch: when
    no shard emits more than cap/2 words a ``lax.cond`` runs the
    rank-select at half width and zero-pads — slots past every shard's
    ``n_words`` are zeroed by the word pass anyway, so the outputs are
    bit-identical.  Packing at the static worst case is what lets the
    encode pipeline run sync-free: no device->host emission-count round
    trip is needed to size the pack buffer.
    """
    S, T, L = mask.shape
    lm = mask != 0                                           # (S, T, L)
    # u8 in-row inclusive prefix (row counts <= 128 fit): 4x less traffic
    # for the rank-select gathers below, and the per-row totals fall out
    # of its last lane for free.  (A log-depth shift-add spelling of this
    # prefix measured 3x faster in isolation but SLOWER in situ — its 7
    # materialized intermediates break the fusion with the rank gathers
    # below — so the associative-scan form stands.)
    icsum3 = jnp.cumsum(lm.astype(jnp.uint8), axis=2, dtype=jnp.uint8)
    cnt = icsum3[:, :, L - 1].astype(jnp.int32)              # (S, T)
    row_off = jnp.cumsum(cnt, axis=1) - cnt                  # exclusive
    n_words = cnt.sum(axis=1)                                # (S,)
    # per-lane emission counts, log-depth halving tree: XLA:CPU lowers the
    # strided axis-1 reduce of the (S, T, L) mask to column loads that
    # don't vectorize (2.6x slower than this tree at the fused batch size)
    x = lm.astype(jnp.int32)
    while x.shape[1] > 1:
        h = x.shape[1] // 2
        x = x[:, :h] + x[:, h:]
    lane_lens = x[:, 0]                                      # (S, L)
    icsum = icsum3.reshape(S, T * L)

    # u32 view of the prefix grid: the final binary-search level reads 4
    # adjacent u8 prefixes as one aligned word (bitcast semantics are
    # HLO-level deterministic: element 0 -> least significant byte)
    icsum4 = jax.lax.bitcast_convert_type(
        icsum3.reshape(S, T * L // 4, 4), jnp.uint32
    )

    def src_for(c: int):
        # source row of output k (k < c): last row whose offset is <= k.
        # Row ids fit u16 at any T below the MAX_ROWS edge, so the
        # scatter-max + running max scan move half the bytes of the i32
        # spelling (dtype picked on the static T)
        idt = jnp.uint16 if T <= 0xFFFF else jnp.int32
        rows_iota = jnp.broadcast_to(jnp.arange(T, dtype=idt), (S, T))
        marks = (
            jnp.zeros((S, c), idt)
            .at[jnp.arange(S)[:, None], row_off]
            .max(rows_iota, mode="drop")
        )
        row_id = jax.lax.cummax(marks, axis=1).astype(jnp.int32)  # (S, c)
        k = jnp.arange(c, dtype=jnp.int32)[None, :]
        j1 = (
            k - jnp.take_along_axis(row_off, row_id, axis=1) + 1
        ).astype(jnp.uint8)                                  # in-row rank + 1

        # source lane: smallest l with icsum[row, l] >= j + 1.  Branchless
        # bit-step lower bound, wide ops only: 5 u8 gather rounds narrow to
        # an aligned 4-lane block, then ONE u32 gather reads that block's
        # remaining 3 boundary prefixes and 2 compare-adds finish the rank
        # — 6 gathers total where the naive 7-round search pays 7
        base = row_id * L
        lane = jnp.zeros((S, c), jnp.int32)
        for b in (64, 32, 16, 8, 4):
            t = lane | b
            v = jnp.take_along_axis(icsum, base + t - 1, axis=1)
            lane = jnp.where(v < j1, t, lane)
        quad = jnp.take_along_axis(
            icsum4, (base >> 2) + (lane >> 2), axis=1
        )
        j32 = j1.astype(jnp.uint32)
        lane += (
            ((quad & jnp.uint32(0xFF)) < j32).astype(jnp.int32)
            + (((quad >> jnp.uint32(8)) & jnp.uint32(0xFF)) < j32).astype(
                jnp.int32
            )
            + (((quad >> jnp.uint32(16)) & jnp.uint32(0xFF)) < j32).astype(
                jnp.int32
            )
        )
        return base + lane

    half = cap // 2
    if tiered and half >= 1:
        src = jax.lax.cond(
            jnp.max(n_words) <= half,
            lambda: jnp.pad(src_for(half), ((0, 0), (0, cap - half))),
            lambda: src_for(cap),
        )
    else:
        src = src_for(cap)
    return src, n_words, lane_lens




def _pack_bytes_impl(words, src, n_words, lane_lens, freq, states):
    """Stage 2: gather the words into stream order and serialize header +
    word area to bytes."""
    S, T, L = words.shape
    cap = src.shape[1]
    w = jnp.take_along_axis(words.reshape(S, T * L), src, axis=1)
    k = jnp.arange(cap, dtype=jnp.int32)[None, :]
    comp_words = jnp.where(k < n_words[:, None], w, 0)
    header = jnp.concatenate(
        [
            _u16_to_u8(freq.astype(jnp.uint16)),
            _u32_to_u8(lane_lens.astype(jnp.uint32)),
            _u32_to_u8(states),
        ],
        axis=1,
    )
    return jnp.concatenate([header, _u16_to_u8(comp_words)], axis=1)


@functools.partial(jax.jit, static_argnames=("cap",))
def _pack_streams(words, mask, freq, states, *, cap: int):
    """One-dispatch host pack: tiered rank-select + byte serialize.

    Returns (packed int8 streams (S, HEADER + 2*cap) — int8 so the exact-
    length shard slices need no per-shard cast — and the (S,) emission
    counts).  The plain ``_pack_rank_impl``/``_pack_bytes_impl`` bodies are
    also traced *inside* the one-launch entropy+seal kernel
    (``repro.kernels.fused``), where an extra jit boundary would be a bug;
    with the tiered rank-select the single-jit spelling measures identical
    to split dispatches, so the host path takes the fewer-roundtrips form.
    """
    src, n_words, lane_lens = _pack_rank_impl(mask, cap=cap, tiered=True)
    comp = _pack_bytes_impl(words, src, n_words, lane_lens, freq, states)
    return comp.astype(jnp.int8), n_words


@functools.partial(jax.jit, static_argnames=("rows",))
def _stage_codes(flats, rows: int):
    """Pad ragged shard payloads to the (rows, 128) lane grid in ONE traced
    dispatch (shape-keyed cache: one trace per distinct payload-length mix,
    the same bound eager per-shard pads paid in per-op dispatches)."""
    return jnp.stack(
        [
            jnp.pad(f, (0, rows * N_LANES - f.shape[0])).reshape(
                rows, N_LANES
            )
            for f in flats
        ]
    )


def _parse_header(comp):
    """Padded compressed bytes (S, C) uint8 -> (freq, lane_lens, states)."""
    u = comp.astype(jnp.int32)
    freq = u[:, 0:512:2] | (u[:, 1:512:2] << 8)              # (S, 256)
    lane_lens = (
        u[:, 512:1024:4]
        | (u[:, 513:1024:4] << 8)
        | (u[:, 514:1024:4] << 16)
        | (u[:, 515:1024:4] << 24)
    )                                                        # (S, 128)
    su = comp.astype(jnp.uint32)
    states = (
        su[:, 1024:1536:4]
        | (su[:, 1025:1536:4] << jnp.uint32(8))
        | (su[:, 1026:1536:4] << jnp.uint32(16))
        | (su[:, 1027:1536:4] << jnp.uint32(24))
    )                                                        # (S, 128)
    return freq, lane_lens, states


@jax.jit
def _parse_streams(comp):
    """Version-1 parse: header split + flat u16 word view, no re-gather.

    The row-major word area is already in decoder-read order, so the
    decode kernel consumes it directly with its prefix-summed pointer.
    """
    freq, _, states = _parse_header(comp)
    body = comp[:, HEADER_BYTES:].astype(jnp.int32)
    W = body.shape[1] // 2
    stream = (body[:, 0 : 2 * W : 2] | (body[:, 1 : 2 * W : 2] << 8)).astype(
        jnp.uint16
    )
    return stream, freq, states


@functools.partial(jax.jit, static_argnames=("rows",))
def _parse_streams_v0(comp, *, rows: int):
    """Version-0 parse: re-gather the lane-major word runs into the
    (S, T, 128) per-lane layout the legacy decode twin scans: word j of
    lane l sits at stream[off(l) + j].  Positions past a lane's length
    gather a clamped index — never consumed, because the decoder's renorm
    flags mirror the encoder's emissions."""
    S, C = comp.shape
    freq, lane_lens, states = _parse_header(comp)
    body = comp[:, HEADER_BYTES:].astype(jnp.int32)
    W = body.shape[1] // 2
    stream = (body[:, 0 : 2 * W : 2] | (body[:, 1 : 2 * W : 2] << 8)).astype(
        jnp.uint16
    )
    off = jnp.cumsum(lane_lens, axis=-1) - lane_lens         # exclusive
    idx = off[:, None, :] + jnp.arange(rows, dtype=jnp.int32)[None, :, None]
    idx = jnp.clip(idx, 0, W - 1).reshape(S, rows * N_LANES)
    lane_words = jnp.take_along_axis(stream, idx, axis=1).reshape(
        S, rows, N_LANES
    )
    return lane_words, freq, states


def encode_payloads(
    payloads,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    division: Optional[str] = None,
    core_fn=None,
) -> Tuple[List[jax.Array], List[Dict]]:
    """rANS-encode S ragged shard payloads in one fused launch.

    payloads: list of flat int8 arrays (ragged ok) or an (S, N) int8 array.
    Returns (compressed int8 streams — exact length, header included; coded
    shards come back as host numpy slices of the one blocking fetch, raw
    shards pass their device payload through — and per-shard metas
    ``{"codec", "version", "n_raw", "n_comp", "rows"}``).
    ``rows`` is the padded lane-row count the whole stripe was coded at;
    decode needs it back.  ``version`` is the stream format version the
    decoder dispatches on.  ``core_fn`` overrides the coder launch (the
    sharded path).
    """
    flats = as_payload_list(payloads)
    if not flats:
        raise ValueError("stripe must contain at least one shard payload")
    n_raw = tuple(int(f.shape[0]) for f in flats)
    T = rows_for(max(n_raw))
    if T > MAX_ROWS:
        raise ValueError(
            f"payload of {max(n_raw)} bytes needs {T} lane rows (max "
            f"{MAX_ROWS}); split it across more stripe shards"
        )
    codes = _stage_codes(flats, rows=T)
    n_valid = jnp.asarray(n_raw, jnp.int32).reshape(-1, 1)
    if core_fn is None:
        core_fn = functools.partial(
            _encode_core, use_pallas=use_pallas,
            interpret=use_interpret(interpret), division=division,
        )
    words, mask, freq, states = core_fn(codes, n_valid)
    # pack at the static raw-skip worst case (no mid-pipeline host sync to
    # size the buffer — the tiered rank-select recovers the tight-bucket
    # cost whenever the batch's true counts allow)
    comp_pad, n_words_dev = _pack_streams(
        words, mask, freq, states, cap=stream_word_cap(T)
    )
    # ONE blocking device->host fetch covers the stream bytes and the
    # emission counts the manifest needs; slicing the host buffer is then
    # free, where per-shard eager device slices each paid a dispatch
    buf = np.asarray(comp_pad)
    n_words = [int(n) for n in np.asarray(n_words_dev)]
    n_comp = [HEADER_BYTES + 2 * nw for nw in n_words]
    comps, metas = [], []
    for s, (nr, nc) in enumerate(zip(n_raw, n_comp)):
        if nc >= nr:
            # adaptive raw-skip: an incompressible shard (or one smaller
            # than the 1536-byte stream header) is stored as-is; the
            # manifest flag is what the decode path dispatches on
            comps.append(flats[s].reshape(-1).astype(jnp.int8))
            metas.append(
                {"codec": "rans", "version": STREAM_VERSION, "raw": True,
                 "n_raw": nr, "n_comp": nr, "rows": T}
            )
        else:
            comps.append(buf[s, :nc])
            metas.append(
                {"codec": "rans", "version": STREAM_VERSION,
                 "n_raw": nr, "n_comp": nc, "rows": T}
            )
    return comps, metas


def decode_payloads(
    comps: Sequence[jax.Array],
    metas: Sequence[Dict],
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    core_fn=None,
) -> List[jax.Array]:
    """Decode twin: compressed streams + metas -> exact original payloads.

    Dispatches on the *recorded* stream ``version`` (absent = 0, the PR-4
    lane-major format, so old archives and checkpoints stay readable).
    Shards the encoder flagged ``raw`` (adaptive raw-skip: compressed would
    have been >= raw) pass through untouched; only the genuinely coded
    shards enter the kernel launch, so a stripe that mixes both still runs
    one launch.  Works identically under the sharded ``core_fn``.
    """
    if len(comps) != len(metas):
        raise ValueError(f"{len(comps)} streams vs {len(metas)} metas")
    if not comps:
        raise ValueError("stripe must contain at least one shard payload")
    T = int(metas[0]["rows"])
    if any(int(m["rows"]) != T for m in metas):
        raise ValueError("all shards of a stripe share one padded row count")
    flats = [jnp.asarray(c).reshape(-1).astype(jnp.uint8) for c in comps]
    out: List[Optional[jax.Array]] = [None] * len(flats)
    coded: List[int] = []
    for i, (f, m) in enumerate(zip(flats, metas)):
        if int(f.shape[0]) != int(m["n_comp"]):
            raise ValueError(
                f"stream is {int(f.shape[0])} bytes, manifest says {m['n_comp']}"
            )
        if m.get("raw"):
            if int(m["n_comp"]) != int(m["n_raw"]):
                raise ValueError(
                    f"raw-skip shard must store n_raw bytes, manifest says "
                    f"{m['n_comp']} vs {m['n_raw']}"
                )
            out[i] = f.astype(jnp.int8)
            continue
        if int(f.shape[0]) < HEADER_BYTES:
            raise ValueError("compressed stream shorter than its header")
        coded.append(i)
    if coded:
        versions = {int(metas[i].get("version", 0)) for i in coded}
        if len(versions) != 1:
            raise ValueError(
                f"stripe mixes stream versions {sorted(versions)}"
            )
        version = versions.pop()
        sub = [flats[i] for i in coded]
        # common padded width, stream area even and >= one word (tails unread)
        C = max(max(int(f.shape[0]) for f in sub), HEADER_BYTES + 2)
        C += (C - HEADER_BYTES) % 2
        comp = jnp.stack([jnp.pad(f, (0, C - f.shape[0])) for f in sub])
        if version == 0:
            words, freq, states = _parse_streams_v0(comp, rows=T)
        else:
            words, freq, states = _parse_streams(comp)
        n_valid = jnp.asarray(
            [int(metas[i]["n_raw"]) for i in coded], jnp.int32
        ).reshape(-1, 1)
        if core_fn is None:
            core_fn = functools.partial(
                _decode_core, use_pallas=use_pallas,
                interpret=use_interpret(interpret),
            )
        codes = core_fn(words, freq, states, n_valid, version=version, rows=T)
        for j, i in enumerate(coded):
            out[i] = codes[j].reshape(-1)[: int(metas[i]["n_raw"])]
    return out


def entropy_traffic(n_raw: int, n_comp: int) -> dict:
    """Structural byte accounting: on-device coder vs host entropy stage.

    The host path must round-trip every payload byte over the host link
    (the exact traffic the paper's CSD offload exists to remove); the fused
    path ships zero payload bytes host-side — only O(1) manifest ints.
    """
    return {
        "ratio": n_raw / n_comp if n_comp else float("nan"),
        "host_entropy_bytes": 0,
        "host_bytes_eliminated": n_raw,
        "staged_passes": _ref.N_STAGED_PASSES,
        "fused_launches": 1,
    }
