"""Interleaved-rANS entropy coder kernel package.

Layout follows the kernel convention: ``rans.py`` (the Pallas coder),
``ref.py`` (staged jnp oracle, bit-identical), ``ops.py`` (public padding/
dispatch/stream-packing wrappers).
"""

from repro.kernels.entropy.ops import (  # noqa: F401
    HEADER_BYTES,
    decode_payloads,
    encode_payloads,
    entropy_traffic,
    rows_for,
)
