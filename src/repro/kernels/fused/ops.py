"""Public wrappers for the one-launch entropy+seal kernel: batching,
padding, dispatch, manifest reconstruction.

``entropy_seal_stripes`` takes a list of stripes (each a list of ragged
int8 shard payloads) plus per-stripe session material and returns, per
stripe, the exact ``(SealedStripe, entropy_metas)`` pair the chained
``entropy.encode_payloads`` -> ``seal.seal_stripe`` path would have
produced — every stored byte, parity word, manifest dict and row count
bit-identical — from ONE kernel launch per homogeneous batch.

Batching: stripes are grouped by (shard count, padded lane rows); each
group launches once with K stripes on the batch axis, so the per-launch
dispatch overhead amortizes K-fold (``StripeCoalescer`` already pow2-
buckets GOPs, so production batches collapse to very few groups).  The
kernel returns fixed-capacity sealed rows; the host derives each shard's
compressed length from the returned rANS word count and slices every
stripe back to the chained path's row count (``bucket_rows_for`` of the
compressed sizes when the caller passed a pad_rows bucket — mirroring
``seal_payload_stripe``'s re-bucketing — else exact ``pad_rows_for``).
Words past a shard's stored length are zero by kernel masking, so the
slice is exact.

``core_fn`` overrides the fused launch itself — it is called with the
same arrays plus the launch's static config as keyword arguments
(``n_shards``/``parity``/``use_pallas``/``interpret``/``division``, since
``n_shards`` varies per batch group); the sharded path
(``repro.distributed.archival``) passes a shard_map'd wrapper, exactly
like the ``core_fn`` seams of the entropy and seal ops.

Pipelined submission: the wrapper is split at the single device→host
sync point (the rANS word-count fetch — the ``encode_payloads``
single-fetch pattern).  ``entropy_seal_stripes_dispatch`` does all host
prep and fires the jitted launches WITHOUT blocking — the returned
:class:`PendingSeal` holds lazy device arrays — and
``entropy_seal_stripes_finalize`` performs the blocking fetch plus the
host-side manifest/slicing tail.  ``entropy_seal_stripes`` is exactly
``finalize(dispatch(...))``, so a caller that overlaps host prep for
batch k+1 with batch k's in-flight launch (``repro.serving.ingest``'s
two-slot submit ring) produces bit-identical archives by construction.
"""

from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archival.raid import gf_pow_gen
from repro.kernels import as_payload_list, use_interpret
from repro.obs import OBS, names as obs_names
from repro.kernels.entropy.ops import HEADER_BYTES, MAX_ROWS, rows_for
from repro.kernels.entropy.rans import N_LANES, STREAM_VERSION
from repro.kernels.fused import ref as _ref
from repro.kernels.fused.entropy_seal import entropy_seal_pallas
from repro.kernels.seal.ops import SealedStripe, bucket_rows_for, pad_rows_for

__all__ = [
    "entropy_seal_stripe",
    "entropy_seal_stripes",
    "entropy_seal_stripes_dispatch",
    "entropy_seal_stripes_finalize",
    "PendingSeal",
]


class _PendingGroup(NamedTuple):
    """One in-flight fused launch group: lazy device outputs + the host
    metadata the finalize tail needs to slice them back per stripe."""

    idxs: List[int]       # stripe indices (input order) in this group
    S: int                # shards per stripe
    T: int                # padded lane rows of the launch
    n_raw: List[int]      # raw payload bytes, group-flat (len(idxs) * S)
    sealed: jax.Array     # lazy (len(idxs)*S, T', 128) sealed rows
    n_words_rans: jax.Array  # lazy per-shard rANS word counts
    p: Optional[jax.Array]
    q: Optional[jax.Array]


class PendingSeal(NamedTuple):
    """A dispatched-but-not-fetched ``entropy_seal_stripes`` batch.

    Every launch in ``groups`` is already in flight (jax dispatch is
    async); the only remaining work is the device→host word-count fetch
    and the host-side slicing, which ``entropy_seal_stripes_finalize``
    performs.  Holding one of these while preparing the next batch is the
    whole double-buffering contract.
    """

    n_stripes: int
    pr_list: List
    groups: List[_PendingGroup]


@functools.partial(
    jax.jit,
    static_argnames=("n_shards", "parity", "use_pallas", "interpret",
                     "division"),
)
def _fused_core(codes, n_valid, keys, nonces, q_coef, *, n_shards: int,
                parity: str, use_pallas: bool, interpret: bool,
                division: str):
    if use_pallas:
        return entropy_seal_pallas(
            codes, n_valid, keys, nonces, q_coef, n_shards=n_shards,
            parity=parity, division=division, interpret=interpret,
        )
    return _ref.entropy_seal_ref(
        codes, n_valid, keys, nonces, q_coef, n_shards=n_shards,
        parity=parity, division=division,
    )


def entropy_seal_stripes_dispatch(
    stripes: Sequence,
    keys: Sequence,
    nonces: Sequence,
    *,
    parity: str = "raid6",
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    pad_rows=None,
    division: Optional[str] = None,
    core_fn=None,
) -> PendingSeal:
    """Host prep + async launch for a batch of stripes — NO device sync.

    Same inputs as ``entropy_seal_stripes``; returns a :class:`PendingSeal`
    whose launches are in flight.  The caller may do arbitrary host work
    (staging the NEXT batch) before calling
    ``entropy_seal_stripes_finalize``, which performs the single blocking
    word-count fetch and the slicing tail.
    """
    if not len(stripes):
        return PendingSeal(0, [], [])
    if not (len(stripes) == len(keys) == len(nonces)):
        raise ValueError(
            f"{len(stripes)} stripes vs {len(keys)} keys / "
            f"{len(nonces)} nonces"
        )
    interp = use_interpret(interpret)
    if division is None:
        # same pick as entropy ops._encode_core: SIMD mulhi reciprocal on
        # interpret/CPU, repaired-f32 reciprocal on real TPU — identical bits
        division = "reciprocal" if interp else "rcp32"
    n_stripes = len(stripes)
    if isinstance(pad_rows, (list, tuple)):
        if len(pad_rows) != n_stripes:
            raise ValueError(
                f"{len(pad_rows)} pad_rows entries vs {n_stripes} stripes"
            )
        pr_list = list(pad_rows)
    else:
        pr_list = [pad_rows] * n_stripes
    plists = [as_payload_list(p) for p in stripes]
    for pl_ in plists:
        if not pl_:
            raise ValueError("stripe must contain at least one shard payload")

    # group into launches by (shard count, padded lane rows): one kernel
    # launch per group, stripes contiguous on the batch axis
    groups: Dict[Tuple[int, int], List[int]] = {}
    stripe_T = []
    for i, pl_ in enumerate(plists):
        T = rows_for(max(int(p.shape[0]) for p in pl_))
        if T > MAX_ROWS:
            raise ValueError(
                f"payload needs {T} lane rows (max {MAX_ROWS}); split it "
                f"across more stripe shards"
            )
        stripe_T.append(T)
        groups.setdefault((len(pl_), T), []).append(i)

    out_groups: List[_PendingGroup] = []
    for (S, T), idxs in groups.items():
        # one Pallas launch per homogeneous group; the telemetry counters
        # let the seal span report its exact launch amortization
        OBS.count(obs_names.FUSED_LAUNCHES)
        OBS.count(obs_names.FUSED_STRIPES, len(idxs))
        flats = [p for i in idxs for p in plists[i]]
        n_raw = [int(f.shape[0]) for f in flats]
        codes = jnp.stack(
            [
                jnp.pad(f, (0, T * N_LANES - n)).reshape(T, N_LANES)
                for f, n in zip(flats, n_raw)
            ]
        )
        n_valid = jnp.asarray(n_raw, jnp.int32).reshape(-1, 1)
        keys_a = jnp.concatenate(
            [jnp.asarray(keys[i], jnp.uint32).reshape(S, 8) for i in idxs]
        )
        nonces_a = jnp.concatenate(
            [jnp.asarray(nonces[i], jnp.uint32).reshape(S, 3) for i in idxs]
        )
        coefs = [gf_pow_gen(s) for s in range(S)]
        q_coef = jnp.asarray(coefs * len(idxs), jnp.uint32).reshape(-1, 1)
        fn = core_fn or _fused_core
        sealed, n_words_rans, p, q = fn(
            codes, n_valid, keys_a, nonces_a, q_coef, n_shards=S,
            parity=parity, use_pallas=use_pallas, interpret=interp,
            division=division,
        )
        out_groups.append(
            _PendingGroup(idxs, S, T, n_raw, sealed, n_words_rans, p, q)
        )
    return PendingSeal(n_stripes, pr_list, out_groups)


def entropy_seal_stripes_finalize(
    pending: PendingSeal,
) -> List[Tuple[SealedStripe, List[Dict]]]:
    """Blocking tail of a dispatched batch: fetch the rANS word counts
    (the ONLY device→host sync) and slice every stripe back to the
    chained path's row count.  Idempotence is not needed — call once."""
    results: List = [None] * pending.n_stripes
    pr_list = pending.pr_list
    for g in pending.groups:
        S, T = g.S, g.T
        nw_host = [int(w) for w in np.asarray(g.n_words_rans).reshape(-1)]
        for j, i in enumerate(g.idxs):
            off = j * S
            metas, stored_words, stored_len = [], [], []
            for s in range(S):
                nr = g.n_raw[off + s]
                nc = HEADER_BYTES + 2 * nw_host[off + s]
                if nc >= nr:
                    metas.append(
                        {"codec": "rans", "version": STREAM_VERSION,
                         "raw": True, "n_raw": nr, "n_comp": nr, "rows": T}
                    )
                    nc = nr
                else:
                    metas.append(
                        {"codec": "rans", "version": STREAM_VERSION,
                         "n_raw": nr, "n_comp": nc, "rows": T}
                    )
                stored_len.append(nc)
                stored_words.append(-(-nc // 4))
            rows_of = bucket_rows_for if pr_list[i] is not None else pad_rows_for
            R = rows_of(max(stored_words))
            stripe = SealedStripe(
                g.sealed[off:off + S, :R],
                g.p[j, :R] if g.p is not None else None,
                g.q[j, :R] if g.q is not None else None,
                tuple(stored_words),
                tuple(stored_len),
            )
            results[i] = (stripe, metas)
    return results


def entropy_seal_stripes(
    stripes: Sequence,
    keys: Sequence,
    nonces: Sequence,
    *,
    parity: str = "raid6",
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    pad_rows=None,
    division: Optional[str] = None,
    core_fn=None,
) -> List[Tuple[SealedStripe, List[Dict]]]:
    """Fused one-launch archival for a batch of stripes.

    stripes: per-stripe payload lists (ragged int8, or (S, N) arrays);
    keys / nonces: per-stripe (S, 8) / (S, 3) uint32 session material;
    pad_rows: None, an int, or a per-stripe sequence — a not-None entry
    requests the chained pipeline's pow2 re-bucketing of the sealed rows
    on the COMPRESSED sizes (the raw bucket value itself is superseded,
    exactly as ``seal_payload_stripe`` re-buckets before the chained
    seal); None requests the chained exact ``pad_rows_for`` padding.

    Returns ``[(SealedStripe, entropy_metas), ...]`` in input order,
    bit-identical to encode_payloads -> seal_stripe per stripe.  This is
    exactly ``finalize(dispatch(...))`` — the pipelined submit ring uses
    the two halves directly and stays bit-identical by construction.
    """
    return entropy_seal_stripes_finalize(
        entropy_seal_stripes_dispatch(
            stripes, keys, nonces, parity=parity, use_pallas=use_pallas,
            interpret=interpret, pad_rows=pad_rows, division=division,
            core_fn=core_fn,
        )
    )


def entropy_seal_stripe(
    payloads, keys, nonces, *, parity: str = "raid6",
    use_pallas: bool = True, interpret: Optional[bool] = None,
    pad_rows: Optional[int] = None, division: Optional[str] = None,
    core_fn=None,
) -> Tuple[SealedStripe, List[Dict]]:
    """Single-stripe convenience twin of ``entropy_seal_stripes``."""
    return entropy_seal_stripes(
        [payloads], [keys], [nonces], parity=parity, use_pallas=use_pallas,
        interpret=interpret, pad_rows=[pad_rows], division=division,
        core_fn=core_fn,
    )[0]
