"""Pallas TPU kernel: one-launch archival — rANS entropy encode fused into
the seal datapath, batched over K coalesced stripes per launch.

One launch takes a batch of B = K * S zero-padded shard payloads straight
through codes -> histogram -> freq tables -> interleaved rANS ->
rank-select stream pack -> adaptive raw-skip select -> ChaCha20 keystream ->
XOR-seal -> RAID-5 P / RAID-6 Q, with the packed word streams living only in
VMEM: the HBM roundtrip the chained ``kernels/entropy`` -> ``kernels/seal``
datapath paid between its two launches (write streams, read streams) is
gone, and so is the second launch's dispatch.

The encode stage is the *same traced op sequence* as the standalone entropy
kernel (``rans.rans_encode_body`` is shared), the pack is the same
rank-select gather (``ops._pack_rank_impl`` / ``_pack_bytes_impl``), and the
keystream/parity stages share their producers with the seal kernel
(``seal.keystream_batch`` / ``seal._gf_mul_const_u32``) — so fusing cannot
change a single stored bit vs the chained path.

Multi-stripe batching has two schedules, bit-identical by construction:

* interpret / CPU (the CI path): the whole K-stripe batch is ONE kernel
  block — every loop op runs over (K*S, 128) operands, so the per-op
  dispatch overhead that dominates interpret-mode runtime amortizes K-fold
  (this is what pushes ``vs_host_speed`` past 1.0 in the committed bench).
* TPU (``grid_stripes=True``, the non-interpret default): stripes ride the
  launch grid axis, one stripe's (S, T, 128) block per step, and Pallas
  double-buffers the revisited in/out blocks — stripe i's encode overlaps
  stripe i-1's sealed/parity writeback, still one launch total.

Capacity invariants (why fixed-size outputs lose nothing):

* stream word cap: a shard whose emission count reaches
  ``(T*128 - HEADER_BYTES) // 2`` words compresses to >= its raw size and
  is stored raw, so capping the pack there discards only streams the
  raw-skip select would discard anyway (the packed words are
  position-exact for ANY cap — see ``_pack_rank_impl``).
* sealed rows cap: the stored body (raw or v1 stream) of a T-row shard
  never exceeds T*128 bytes — the v1 stream is exactly T*128 bytes at the
  raw-skip boundary — so ``pad_rows_for(T*32)`` rows always cover it, and
  every word past a shard's stored length is masked to zero, making the
  host-side slice back to the chained path's row count exact.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.entropy.ops import (
    HEADER_BYTES,
    _pack_bytes_impl,
    _pack_rank_impl,
    stream_word_cap,
)
from repro.kernels.entropy.rans import (
    N_LANES,
    T_TILE,
    _histogram_impl,
    _rows_per_step,
    rans_encode_body,
)
from repro.kernels.seal.ops import pad_rows_for
from repro.kernels.seal.seal import (
    LANES,
    ROW_BYTES,
    _gf_mul_const_u32,
    keystream_batch,
)

# ``stream_word_cap`` moved next to the pack it sizes (entropy ops); the
# fused module re-exports it because the seal-side capacity story lives here
__all__ = ["entropy_seal_pallas", "stream_word_cap", "seal_rows_cap"]


def seal_rows_cap(T: int) -> int:
    """Sealed-row capacity covering any stored body of a T-row shard
    (raw and v1 stream are both <= T*128 bytes = T*32 uint32 words)."""
    return pad_rows_for(T * N_LANES // 4)


def _entropy_seal_kernel(
    codes_ref, nvalid_ref, keys_ref, nonces_ref, qcoef_ref,
    sealed_ref, nwords_ref, *parity_refs,
    n_shards: int, division: str, rows_per_step: int, histogram: str,
):
    B, T, L = codes_ref.shape
    R_cap = sealed_ref.shape[1]
    vals = (codes_ref[...].astype(jnp.int32)) & 0xFF         # (B, T, 128)
    nv = nvalid_ref[...]                                     # (B, 1)

    # stage 1: interleaved rANS encode — the standalone entropy kernel's
    # exact op sequence (shared body), K*S shards on the batch axis
    words, mask, freq, states = rans_encode_body(
        vals, nv, division=division, rows_per_step=rows_per_step,
        histogram=histogram,
    )

    # stage 2: rank-select pack straight into v1 stream bytes, in VMEM —
    # the packed word streams never touch HBM.  ``tiered``: the static cap
    # is the raw-skip worst case (~2.5x typical emissions), so the pack
    # runs at half width whenever the batch's true counts allow
    src, n_words, lane_lens = _pack_rank_impl(
        mask, cap=stream_word_cap(T), tiered=True
    )
    stream_u8 = _pack_bytes_impl(words, src, n_words, lane_lens, freq, states)

    # stage 3: adaptive raw-skip select (n_words is the TRUE emission
    # count — ``_pack_rank_impl`` counts before capping — so the condition
    # is exactly the chained host-side one).  Both branches are zero past
    # their stored length: raw by the ops-layer padding contract, the
    # stream because words at k >= n_words are zeroed in the pack.
    n_raw = nv[:, 0]
    n_comp = HEADER_BYTES + 2 * n_words
    is_raw = n_comp >= n_raw
    buf = T * L
    raw_u8 = vals.reshape(B, buf).astype(jnp.uint8)
    body_u8 = jnp.where(is_raw[:, None], raw_u8, stream_u8[:, :buf])
    pad = R_cap * ROW_BYTES - buf
    if pad:
        body_u8 = jnp.pad(body_u8, ((0, 0), (0, pad)))

    # stage 4: pack u8 -> uint32 little-endian lanes (the seal layout)
    b4 = body_u8.reshape(B, R_cap, L, 4).astype(jnp.uint32)
    packed = (
        b4[..., 0]
        | (b4[..., 1] << jnp.uint32(8))
        | (b4[..., 2] << jnp.uint32(16))
        | (b4[..., 3] << jnp.uint32(24))
    )

    # stage 5: ChaCha20 keystream + XOR-seal + stored-length mask
    ks = keystream_batch(keys_ref[...], nonces_ref[...], R_cap)
    stored = jnp.where(is_raw, n_raw, n_comp)
    n_sealed = -(-stored // 4)                               # stored u32 words
    widx = (
        jax.lax.broadcasted_iota(jnp.int32, (1, R_cap, L), 1) * L
        + jax.lax.broadcasted_iota(jnp.int32, (1, R_cap, L), 2)
    )
    sealed = jnp.where(
        widx < n_sealed[:, None, None], packed ^ ks, jnp.uint32(0)
    )
    sealed_ref[...] = sealed
    nwords_ref[...] = n_words[:, None]

    # stage 6: per-stripe RAID parity — XOR folds over each stripe's S
    # shards (order-free, so any slicing/sharding of the fold is exact)
    if parity_refs:
        K = B // n_shards
        g = sealed.reshape(K, n_shards, R_cap, L)
        p = g[:, 0]
        for s in range(1, n_shards):
            p = p ^ g[:, s]
        parity_refs[0][...] = p
        if len(parity_refs) > 1:
            qc = qcoef_ref[...].reshape(K, n_shards)
            q = _gf_mul_const_u32(g[:, 0], qc[:, 0][:, None, None])
            for s in range(1, n_shards):
                q = q ^ _gf_mul_const_u32(g[:, s], qc[:, s][:, None, None])
            parity_refs[1][...] = q


def entropy_seal_pallas(
    codes, n_valid, keys, nonces, q_coef, *, n_shards: int,
    parity: str = "raid6", division: str = "divide",
    rows_per_step: Optional[int] = None, histogram: Optional[str] = None,
    grid_stripes: Optional[bool] = None, interpret: bool = True,
):
    """One launch: rANS-encode, pack, ChaCha20-XOR-seal and parity-fold a
    batch of K = B // n_shards coalesced stripes.

    codes: (B, T, 128) int8 payload rows, zero-padded (stripes contiguous:
    shard s of stripe k is row k*n_shards + s); n_valid: (B, 1) int32 RAW
    byte counts (pre-compression — the kernel decides raw-skip itself);
    keys (B, 8) / nonces (B, 3) / q_coef (B, 1) uint32 per-shard session
    material and RAID-6 GF coefficients.

    ``grid_stripes`` picks the multi-stripe schedule (None = not
    interpret): False runs the batch as one fat block (interpret/CPU —
    amortizes per-op dispatch), True puts stripes on the launch grid with
    double-buffered blocks (TPU).  Pure schedule; outputs are identical.

    Returns (sealed (B, R_cap, 128) u32, n_words (B, 1) int32 emitted rANS
    word counts, p (K, R_cap, 128) u32 | None, q (K, R_cap, 128) u32 |
    None).  Everything a host needs to reconstruct streams, metas and
    chained-path row counts derives from n_words + the raw lengths.
    """
    B, T, L = codes.shape
    if L != N_LANES:
        raise ValueError(f"expected {N_LANES} lanes, got {L}")
    if T % T_TILE:
        raise ValueError(f"rows {T} not a multiple of {T_TILE}")
    if n_shards <= 0 or B % n_shards:
        raise ValueError(f"batch of {B} shards not a multiple of {n_shards}")
    if division not in ("divide", "rcp32", "reciprocal"):
        raise ValueError(f"unknown division strategy {division!r}")
    if parity not in ("none", "raid5", "raid6"):
        raise ValueError(f"unknown parity {parity!r}")
    K = B // n_shards
    R_cap = seal_rows_cap(T)
    rps = _rows_per_step(rows_per_step, interpret, T)
    if grid_stripes is None:
        grid_stripes = not interpret
    kern = functools.partial(
        _entropy_seal_kernel,
        n_shards=n_shards, division=division, rows_per_step=rps,
        histogram=_histogram_impl(histogram, interpret),
    )
    n_parity = {"none": 0, "raid5": 1, "raid6": 2}[parity]
    out_shape = [
        jax.ShapeDtypeStruct((B, R_cap, LANES), jnp.uint32),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
    ] + [
        jax.ShapeDtypeStruct((K, R_cap, LANES), jnp.uint32)
    ] * n_parity
    if not grid_stripes or K == 1:
        outs = pl.pallas_call(kern, out_shape=out_shape, interpret=interpret)(
            codes, n_valid, keys, nonces, q_coef
        )
    else:
        S = n_shards
        outs = pl.pallas_call(
            kern,
            grid=(K,),
            in_specs=[
                pl.BlockSpec((S, T, L), lambda k: (k, 0, 0)),
                pl.BlockSpec((S, 1), lambda k: (k, 0)),
                pl.BlockSpec((S, 8), lambda k: (k, 0)),
                pl.BlockSpec((S, 3), lambda k: (k, 0)),
                pl.BlockSpec((S, 1), lambda k: (k, 0)),
            ],
            out_specs=[
                pl.BlockSpec((S, R_cap, LANES), lambda k: (k, 0, 0)),
                pl.BlockSpec((S, 1), lambda k: (k, 0)),
            ] + [
                pl.BlockSpec((1, R_cap, LANES), lambda k: (k, 0, 0))
            ] * n_parity,
            out_shape=out_shape,
            interpret=interpret,
        )(codes, n_valid, keys, nonces, q_coef)
    sealed, n_words = outs[0], outs[1]
    p = outs[2] if n_parity else None
    q = outs[3] if n_parity > 1 else None
    return sealed, n_words, p, q
