"""Staged pure-jnp oracle for the one-launch entropy+seal kernel.

The pre-fusion pipeline kept as the bit-exact reference and the
``use_pallas=False`` fallback: the entropy stage runs the scan-based rANS
oracle (``kernels/entropy/ref.py`` — an independent schedule from the
kernel's fori-loop body), the pack runs the shared rank-select gather (the
pack was host-side shared code in the chained path too, never
oracle-duplicated), and the seal stages run the staged seal reference
(``kernels/seal/ref.py`` — per-shard ``chacha20_block`` keystream and the
log/antilog-table GF(256) parity, both independent implementations of the
kernel's plane-batched ChaCha and SWAR GF multiply).

Each tuple entry below is one full-payload HBM round-trip of the staged
pipeline; the fused kernel does all of them in one launch per stripe batch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.entropy import ref as eref
from repro.kernels.entropy.ops import (
    HEADER_BYTES,
    _pack_bytes_impl,
    _pack_rank_impl,
)
from repro.kernels.fused.entropy_seal import seal_rows_cap, stream_word_cap
from repro.kernels.seal import ref as sref
from repro.kernels.seal.seal import ROW_BYTES

__all__ = ["STAGED_PASSES", "N_STAGED_PASSES", "entropy_seal_ref"]

STAGED_PASSES = (
    eref.STAGED_PASSES
    + (
        "v1 stream serialization to bytes (read words, write u8)",
        "adaptive raw-skip select (read stream + raw bytes, write u8)",
    )
    + sref.STAGED_PASSES
)
N_STAGED_PASSES = len(STAGED_PASSES)


def entropy_seal_ref(
    codes, n_valid, keys, nonces, q_coef, *,
    n_shards: int, parity: str = "raid6", division: str = "divide",
):
    """Staged fused archival: same signature/outputs as
    ``entropy_seal_pallas`` (sealed, n_words, p, q), bit-for-bit."""
    B, T, L = codes.shape
    R_cap = seal_rows_cap(T)

    # entropy stage: independent scan-schedule oracle
    words, mask, freq, states = eref.rans_encode_ref(
        codes, n_valid, division=division
    )
    src, n_words, lane_lens = _pack_rank_impl(mask, cap=stream_word_cap(T))
    stream_u8 = _pack_bytes_impl(words, src, n_words, lane_lens, freq, states)

    # raw-skip select + pad to the sealed-rows capacity
    n_raw = n_valid.reshape(B)
    n_comp = HEADER_BYTES + 2 * n_words
    is_raw = n_comp >= n_raw
    buf = T * L
    raw_u8 = (codes.astype(jnp.int32) & 0xFF).reshape(B, buf).astype(jnp.uint8)
    body_u8 = jnp.where(is_raw[:, None], raw_u8, stream_u8[:, :buf])
    body_u8 = jnp.pad(body_u8, ((0, 0), (0, R_cap * ROW_BYTES - buf)))

    # seal stage: the staged seal reference, end to end
    body_i8 = jax.lax.bitcast_convert_type(body_u8, jnp.int8)
    packed = sref._pack_rows(body_i8.reshape(B, R_cap, ROW_BYTES))
    ks = sref._keystream_rows(keys, nonces, R_cap)
    stored = jnp.where(is_raw, n_raw, n_comp)
    sealed = sref._mask_valid(packed ^ ks, -(-stored // 4))
    n_words_out = n_words[:, None]
    if parity == "none":
        return sealed, n_words_out, None, None
    K = B // n_shards
    ps, qs = [], []
    for k in range(K):
        sl = slice(k * n_shards, (k + 1) * n_shards)
        p, q = sref._parity(sealed[sl], q_coef[sl], parity)
        ps.append(p)
        qs.append(q)
    p = jnp.stack(ps)
    q = jnp.stack(qs) if parity == "raid6" else None
    return sealed, n_words_out, p, q
