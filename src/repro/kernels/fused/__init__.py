"""One-launch archival kernel package: rANS entropy encode + v1 stream
pack + adaptive raw-skip + ChaCha20 XOR-seal + RAID-5/6 parity in a single
Pallas launch, batched over K coalesced stripes.

House layout: ``entropy_seal.py`` (the Pallas kernel), ``ref.py`` (the
staged pure-jnp oracle it must match bit-for-bit), ``ops.py`` (jit'd
public wrappers).  The chained stages it fuses live in the sibling
``entropy`` and ``seal`` packages and stay the decode/restore path.
"""

from repro.kernels.fused.ops import (  # noqa: F401
    entropy_seal_stripe,
    entropy_seal_stripes,
)
