"""Pallas kernel packages for the paper's compute hot-spots.

Each kernel lives in its own package as ``<name>.py`` (the Pallas kernel),
``ref.py`` (a pure-jnp oracle the kernel must match bit-for-bit), and
``ops.py`` (jit'd public wrappers handling padding and dispatch).

Kernels: ``polymul`` (R-LWE negacyclic matmul, MXU), ``motion`` (block
matching, VPU), ``quantize`` (blockwise int8, VPU), ``entropy``
(interleaved-rANS byte coder, 128 lanes on the VPU lane axis), ``seal``
(fused archival pack + ChaCha20 + XOR-seal + RAID parity, VPU).
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

__all__ = ["use_interpret", "as_payload_list"]


def use_interpret(interpret: Optional[bool] = None) -> bool:
    """Pallas ``interpret=`` autodetect shared by every kernel ``ops`` module.

    Off-TPU backends (CPU/GPU hosts, CI) run kernels through the Pallas
    interpreter; on TPU the same call sites lower to real Mosaic kernels.
    Pass an explicit bool to override (tests / debugging).
    """
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def as_payload_list(payloads) -> List[jax.Array]:
    """Normalize ragged stripe payloads (list/tuple or stacked (S, N) array)
    to a list of flat int8 arrays — shared by the seal and entropy ops."""
    if isinstance(payloads, (list, tuple)):
        # already-normalized payloads (the hot path) pass through without
        # paying a per-shard reshape/astype dispatch
        return [
            p
            if isinstance(p, jax.Array) and p.dtype == jnp.int8 and p.ndim == 1
            else jnp.asarray(p).reshape(-1).astype(jnp.int8)
            for p in payloads
        ]
    arr = jnp.asarray(payloads)
    return [arr[s].reshape(-1).astype(jnp.int8) for s in range(arr.shape[0])]
