"""Pallas kernel packages for the paper's compute hot-spots.

Each kernel lives in its own package as ``<name>.py`` (the Pallas kernel),
``ref.py`` (a pure-jnp oracle the kernel must match bit-for-bit), and
``ops.py`` (jit'd public wrappers handling padding and dispatch).

Kernels: ``polymul`` (R-LWE negacyclic matmul, MXU), ``motion`` (block
matching, VPU), ``quantize`` (blockwise int8, VPU), ``seal`` (fused archival
pack + ChaCha20 + XOR-seal + RAID parity, VPU).
"""

from __future__ import annotations

from typing import Optional

import jax

__all__ = ["use_interpret"]


def use_interpret(interpret: Optional[bool] = None) -> bool:
    """Pallas ``interpret=`` autodetect shared by every kernel ``ops`` module.

    Off-TPU backends (CPU/GPU hosts, CI) run kernels through the Pallas
    interpreter; on TPU the same call sites lower to real Mosaic kernels.
    Pass an explicit bool to override (tests / debugging).
    """
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"
