"""Architecture registry: name -> ModelConfig (full + reduced smoke variant)."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import EncoderCfg, ModelConfig, MoECfg, SSMCfg

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "reduce_config"]

ARCH_IDS: List[str] = [
    "llama4_maverick_400b_a17b",
    "deepseek_moe_16b",
    "mistral_large_123b",
    "qwen2_0_5b",
    "internlm2_1_8b",
    "nemotron_4_15b",
    "whisper_large_v3",
    "mamba2_370m",
    "jamba_1_5_large_398b",
    "llama_3_2_vision_11b",
]

_ALIASES = {name.replace("_", "-"): name for name in ARCH_IDS}


def _module(arch: str):
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return reduce_config(get_config(arch))


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction: same period structure / block kinds /
    routing topology, tiny widths — used by the per-arch CPU smoke tests."""
    moe = None
    if cfg.moe is not None:
        moe = cfg.moe._replace(
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            n_shared=min(cfg.moe.n_shared, 1),
            capacity_factor=8.0,  # no drops: keeps decode/forward parity exact
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = cfg.ssm._replace(d_state=16, head_dim=8, chunk=8)
    encoder = None
    if cfg.encoder is not None:
        encoder = cfg.encoder._replace(n_layers=2, n_heads=4, n_kv_heads=2, seq_len=12)
    n_layers = cfg.period if cfg.period > 1 else 2
    return cfg._replace(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        moe=moe,
        ssm=ssm,
        encoder=encoder,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 12) if cfg.n_frontend_tokens else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        dtype="float32",
        max_seq_len=128,
    )
