"""Unified model covering all ten assigned architectures.

One ``ModelConfig`` drives: dense GQA transformers, MoE layers (shared +
routed top-k), Mamba2/SSD mixers, hybrid layer patterns (Jamba), an optional
encoder stack with decoder cross-attention (Whisper), and interleaved
cross-attention to stub image embeddings (Llama-3.2-Vision).

Layers are stacked into ``n_super`` repetitions of a ``period``-long block
pattern and executed with ``lax.scan`` — HLO size stays O(period), which is
what lets 88-layer x 512-device dry-runs lower in seconds.  Activation
sharding hints are injected via a caller-supplied ``shard_fn`` so the model
stays distribution-agnostic (distributed/sharding.py supplies the real one).

Decode paths (``init_cache`` + ``decode_step``) maintain per-layer KV caches,
SSM states and precomputed cross-attention K/V; one token per call.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.nn import rms_norm
from repro.models.config import BlockKind, ModelConfig
from repro.models.layers.attention import (
    AttnTemps,
    attention_decode,
    attention_forward,
    cross_attention_decode,
    init_attention,
    init_kv_cache,
    project_cross_kv,
)
from repro.models.layers.mlp import init_mlp, mlp_forward
from repro.models.layers.moe import init_moe, moe_forward
from repro.models.layers.ssm import SSMCache, init_ssm, init_ssm_cache, ssm_decode, ssm_forward

__all__ = ["init_model", "forward", "init_cache", "decode_step", "model_dtype"]

ShardFn = Callable[[jax.Array, str], jax.Array]
_no_shard: ShardFn = lambda x, kind: x


def model_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ===================================================================== init
def _init_block(key, cfg: ModelConfig, kind: BlockKind, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": jnp.ones((d,), dtype), "norm2": jnp.ones((d,), dtype)}
    if kind.mixer == "A":
        p["attn"] = init_attention(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qkv_bias, dtype
        )
    else:
        p["ssm"] = init_ssm(ks[0], d, cfg.ssm, dtype)
    if kind.cross:
        p["cross_norm"] = jnp.ones((d,), dtype)
        p["cross"] = init_attention(
            ks[1], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qkv_bias, dtype
        )
    if kind.moe:
        p["moe"] = init_moe(ks[2], d, cfg.moe, cfg.act, dtype)
    elif cfg.d_ff:
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, cfg.act, dtype)
    else:
        del p["norm2"]  # pure-mixer block (Mamba architecture: no FFN)
    return p


def _init_encoder_block(key, cfg: ModelConfig, dtype):
    enc = cfg.encoder
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "norm1": jnp.ones((d,), dtype),
        "norm2": jnp.ones((d,), dtype),
        "attn": init_attention(ks[0], d, enc.n_heads, enc.n_kv_heads, cfg.hd, False, dtype),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.act, dtype),
    }


def init_model(key, cfg: ModelConfig):
    dtype = model_dtype(cfg)
    kinds = cfg.block_kinds()
    k_embed, k_blocks, k_enc, k_head, k_fp = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02).astype(
            dtype
        ),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab)) * 0.02
        ).astype(dtype)

    block_keys = jax.random.split(k_blocks, cfg.period)
    blocks = []
    for j, kind in enumerate(kinds):
        per_super = jax.random.split(block_keys[j], cfg.n_super)
        blocks.append(
            jax.vmap(lambda k: _init_block(k, cfg, kind, dtype))(per_super)
        )
    params["blocks"] = tuple(blocks)

    if cfg.encoder is not None:
        enc_keys = jax.random.split(k_enc, cfg.encoder.n_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _init_encoder_block(k, cfg, dtype))(enc_keys),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
    if cfg.frontend_dim and cfg.frontend_dim != cfg.d_model:
        params["frontend_proj"] = (
            jax.random.normal(k_fp, (cfg.frontend_dim, cfg.d_model)) * 0.02
        ).astype(dtype)
    return params


# ================================================================== forward
def _make_weight_gather(shard_fn: ShardFn, enabled: bool):
    """int8-compressed FSDP weight gathers (§Perf change #2).

    FSDP-sharded weights are re-gathered over the ``data`` axis every layer
    (and again in remat recompute) — the dominant collective for the 100B+
    archs.  Quantizing the local shard to int8 with per-output-channel scales
    *before* the gather halves the bytes on the wire; a straight-through
    estimator keeps gradients flowing to the bf16 master weights.  This is
    the paper's compress-before-the-link thesis applied to weights.
    """
    mesh = getattr(shard_fn, "mesh", None)
    if not enabled or mesh is None or "data" not in mesh.shape:
        return lambda w, kind: w
    from jax.sharding import NamedSharding, PartitionSpec as P

    def _spec(ndim: int, kind: str) -> P:
        # gathered spec: keep TP/EP ("model") placement, drop the data axis
        if kind == "col":  # (..., d, f): f model-sharded
            return P(*([None] * (ndim - 1) + ["model"]))
        if kind == "row":  # (..., f, d): f model-sharded
            return P(*([None] * (ndim - 2) + ["model", None]))
        # "moe": (E, ..., ...): experts model-sharded, rest gathered
        return P(*(["model"] + [None] * (ndim - 1)))

    def _impl(w, kind):
        spec = _spec(w.ndim, kind)
        scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
        scale = jnp.maximum(scale, 1e-8) / 127.0
        w8 = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(
            jnp.int8
        )
        # the gather over `data` happens HERE, on the int8 tensor (2x fewer
        # bytes on the wire than the bf16 FSDP gather it replaces); the
        # optimization barrier stops the partitioner from sinking the dequant
        # convert below the gather (which would re-widen the wire bytes)
        w8 = jax.lax.with_sharding_constraint(w8, NamedSharding(mesh, spec))
        w8 = jax.lax.optimization_barrier(w8)
        scale = jax.lax.with_sharding_constraint(
            scale, NamedSharding(mesh, _spec(scale.ndim, kind))
        )
        return (w8.astype(jnp.float32) * scale).astype(w.dtype)

    @functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
    def roundtrip(w, kind):
        return _impl(w, kind)

    def _fwd(w, kind):
        return _impl(w, kind), None

    def _bwd(kind, _, g):
        return (g,)  # identity grad: the partitioner reduce-scatters g into
        # w's FSDP sharding; quantization noise is forward-only (QAT-style)

    roundtrip.defvjp(_fwd, _bwd)

    def wg(w, kind: str):
        if w.ndim < 2:
            return w
        return roundtrip(w, kind)

    return wg


_NO_WG = lambda w, kind: w


def _apply_block(
    bp, cfg: ModelConfig, kind: BlockKind, x, cross_src, q_chunk, shard_fn: ShardFn,
    inner_remat: bool = False,
    wg=_NO_WG,
    flash: bool = True,
):
    """inner_remat: checkpoint each sub-block (mixer / cross / FFN) separately
    so the backward peak is the LARGEST sub-block's transients, not their sum
    — this is what bounds per-device HBM for the 100B+ archs at mb=1."""
    ck = jax.checkpoint if inner_remat else (lambda f: f)
    aux = jnp.zeros((), jnp.float32)

    def mixer(x, p):
        h = rms_norm(x, p["norm1"])
        if kind.mixer == "A":
            ap = dict(
                p["attn"],
                wq=wg(p["attn"]["wq"], "col"),
                wk=wg(p["attn"]["wk"], "col"),
                wv=wg(p["attn"]["wv"], "col"),
                wo=wg(p["attn"]["wo"], "row"),
            )
            h = attention_forward(
                ap,
                h,
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.hd,
                causal=True,
                rope_theta=cfg.rope_theta,
                q_chunk=q_chunk,
                flash=flash,
            )
        else:
            h = ssm_forward(p["ssm"], h, cfg.ssm, cfg.d_model)
        return shard_fn(x + h, "resid")

    x = ck(mixer)(x, bp)
    if kind.cross:

        def cross(x, p):
            h = rms_norm(x, p["cross_norm"])
            h = attention_forward(
                p["cross"],
                h,
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.hd,
                causal=False,
                rope_theta=None,
                kv_source=cross_src,
            )
            return shard_fn(x + h, "resid")

        x = ck(cross)(x, bp)
    if kind.moe:

        def ffn(x, p):
            h = rms_norm(x, p["norm2"])
            h, aux = moe_forward(
                p["moe"], h, cfg.moe, cfg.act, shard_fn,
                wg=wg if wg is not _NO_WG else None,
            )
            return shard_fn(x + h, "resid"), aux

        x, aux = ck(ffn)(x, bp)
    elif "mlp" in bp:

        def ffn(x, p):
            h = rms_norm(x, p["norm2"])
            mp = {k: wg(v, "row" if k == "w_out" else "col")
                  for k, v in p["mlp"].items()}
            h = mlp_forward(mp, h, cfg.act)
            return shard_fn(x + h, "resid")

        x = ck(ffn)(x, bp)
    return x, aux


def _encode(params, cfg: ModelConfig, frontend, q_chunk, shard_fn, unroll=False):
    """Whisper-style encoder over stub frame embeddings (B, S, d)."""
    enc = cfg.encoder
    x = frontend
    if "frontend_proj" in params:
        x = x @ params["frontend_proj"]
    # sinusoidal positions
    S, d = x.shape[1], x.shape[2]
    pos = jnp.arange(S)[:, None] / (
        1e4 ** (jnp.arange(0, d, 2)[None, :] / d)
    )
    pe = jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)[:, :d]
    x = x + pe[None].astype(x.dtype)

    def body(carry, bp):
        h = rms_norm(carry, bp["norm1"])
        h = attention_forward(
            bp["attn"],
            h,
            n_heads=enc.n_heads,
            n_kv_heads=enc.n_kv_heads,
            head_dim=cfg.hd,
            causal=False,
            rope_theta=None,
            q_chunk=q_chunk,
        )
        carry = carry + h
        h = rms_norm(carry, bp["norm2"])
        carry = carry + mlp_forward(bp["mlp"], h, cfg.act)
        return shard_fn(carry, "resid"), None

    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"], unroll=unroll)
    return rms_norm(x, params["encoder"]["final_norm"])


def forward(
    params,
    cfg: ModelConfig,
    tokens,
    frontend: Optional[jax.Array] = None,
    *,
    q_chunk: int = 512,
    shard_fn: ShardFn = _no_shard,
    remat: bool = False,
    return_hidden: bool = False,
    unroll: bool = False,
    int8_gather: bool = False,
    flash: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, L) int32 -> (logits (B, L, V), aux_loss scalar).

    frontend: encoder frames (enc-dec) or image patch embeddings (VLM).
    return_hidden: skip the output head, return final hidden states — the
    trainer computes cross-entropy in sequence chunks against the (tied)
    head so the (B, L, V) logits tensor is never materialized.
    """
    dtype = model_dtype(cfg)
    x = params["embed"][tokens].astype(dtype)
    x = shard_fn(x, "resid")

    cross_src = None
    if cfg.encoder is not None:
        assert frontend is not None, "enc-dec model needs frontend frames"
        cross_src = _encode(
            params, cfg, frontend.astype(dtype), q_chunk, shard_fn, unroll
        )
    elif cfg.n_frontend_tokens:
        assert frontend is not None, "VLM needs image patch embeddings"
        cross_src = frontend.astype(dtype)
        if "frontend_proj" in params:
            cross_src = cross_src @ params["frontend_proj"]

    kinds = cfg.block_kinds()
    wg = _make_weight_gather(shard_fn, int8_gather) if int8_gather else _NO_WG

    def superblock(carry, stacked):
        x, aux = carry
        for j, kind in enumerate(kinds):
            x, a = _apply_block(
                stacked[j], cfg, kind, x, cross_src, q_chunk, shard_fn,
                inner_remat=False,  # adds weight re-gathers without reducing
                # the measured peak; outer per-super remat is the sweet spot
                wg=wg,
                flash=flash,
            )
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(superblock) if remat else superblock
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["blocks"], unroll=unroll
    )

    x = rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, aux
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = shard_fn(x @ head, "logits")
    return logits, aux


def output_head(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# =================================================================== decode
def init_cache(
    params, cfg: ModelConfig, batch: int, max_len: int, frontend=None
) -> Dict[str, Any]:
    """Preallocate KV/SSM caches; precompute cross K/V from the frontend."""
    dtype = model_dtype(cfg)
    kinds = cfg.block_kinds()
    cross_src = None
    if cfg.encoder is not None:
        cross_src = _encode(params, cfg, frontend.astype(dtype), 0, _no_shard)
    elif cfg.n_frontend_tokens and frontend is not None:
        cross_src = frontend.astype(dtype)
        if "frontend_proj" in params:
            cross_src = cross_src @ params["frontend_proj"]

    blocks = []
    for j, kind in enumerate(kinds):
        entry: Dict[str, Any] = {}
        if kind.mixer == "A":
            entry["kv"] = jax.vmap(
                lambda _: init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, dtype)
            )(jnp.arange(cfg.n_super))
        else:
            entry["ssm"] = jax.vmap(
                lambda _: init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype)
            )(jnp.arange(cfg.n_super))
        if kind.cross:
            entry["cross_kv"] = jax.vmap(
                lambda bp: project_cross_kv(
                    bp, cross_src, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd
                )
            )(params["blocks"][j]["cross"])
        blocks.append(entry)
    return {"blocks": tuple(blocks)}


def decode_step(
    params,
    cfg: ModelConfig,
    token,
    cache,
    pos,
    *,
    shard_fn: ShardFn = _no_shard,
    unroll: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """token (B, 1) int32, pos scalar int32 -> (logits (B, V), new cache)."""
    dtype = model_dtype(cfg)
    x = params["embed"][token].astype(dtype)
    kinds = cfg.block_kinds()

    def superblock(x, scanned):
        stacked, cached = scanned
        new_cache = {}
        for j, kind in enumerate(kinds):
            bp, cj = stacked[j], cached[j]
            nc: Dict[str, Any] = {}
            h = rms_norm(x, bp["norm1"])
            if kind.mixer == "A":
                h, kv = attention_decode(
                    bp["attn"],
                    h,
                    AttnTemps(*cj["kv"]),
                    pos,
                    n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta,
                )
                nc["kv"] = kv
            else:
                h, st = ssm_decode(bp["ssm"], h, SSMCache(*cj["ssm"]), cfg.ssm, cfg.d_model)
                nc["ssm"] = st
            x = x + h
            if kind.cross:
                h = rms_norm(x, bp["cross_norm"])
                ck, cv = cj["cross_kv"]
                h = cross_attention_decode(
                    bp["cross"], h, ck, cv,
                    n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                )
                x = x + h
                nc["cross_kv"] = cj["cross_kv"]
            if kind.moe:
                h = rms_norm(x, bp["norm2"])
                h, _ = moe_forward(bp["moe"], h, cfg.moe, cfg.act, shard_fn)
                x = x + h
            elif "mlp" in bp:
                h = rms_norm(x, bp["norm2"])
                h = mlp_forward(bp["mlp"], h, cfg.act)
                x = x + h
            x = shard_fn(x, "resid")
            new_cache[j] = nc
        return x, tuple(new_cache[j] for j in range(len(kinds)))

    x, new_blocks = jax.lax.scan(
        superblock, x, (params["blocks"], cache["blocks"]), unroll=unroll
    )
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = shard_fn((x[:, 0] @ head), "logits")
    return logits, {"blocks": new_blocks}
