"""Model configuration schema for the architecture zoo.

One unified ``ModelConfig`` covers all ten assigned families: dense/GQA
transformers, MoE (shared + routed, top-k), SSM (Mamba2/SSD), hybrids
(layer_pattern strings), encoder-decoder (whisper), and cross-attention VLMs.
Layers are grouped into a repeating *period* (``layer_pattern`` x MoE
interleave) so the forward pass scans over stacked parameter pytrees — this
keeps HLO size O(period) instead of O(n_layers), which is what makes 88-layer
x 512-device dry-runs compile quickly.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

__all__ = ["MoECfg", "SSMCfg", "EncoderCfg", "ModelConfig", "BlockKind"]


class MoECfg(NamedTuple):
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # always-on shared experts (DeepSeek-MoE style)
    capacity_factor: float = 1.25
    every: int = 1  # MoE FFN on layers with (idx % every == every - 1)
    router_jitter: float = 0.0


class SSMCfg(NamedTuple):
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256  # SSD chunk length


class EncoderCfg(NamedTuple):
    n_layers: int
    n_heads: int
    n_kv_heads: int
    seq_len: int  # frontend tokens (whisper: 1500 audio frames)


class ModelConfig(NamedTuple):
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    act: str = "silu_gated"  # silu_gated | squared_relu | gelu_gated | gelu
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    layer_pattern: str = "A"  # string over {A: attention, M: mamba}, tiled
    cross_attn_every: int = 0  # VLM: every k-th layer gains cross-attention
    encoder: Optional[EncoderCfg] = None  # enc-dec (whisper)
    n_frontend_tokens: int = 0  # image tokens (VLM) — encoder covers audio
    frontend_dim: int = 0  # stub embedding dim (0 -> d_model)
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    max_seq_len: int = 32768  # RoPE table default cap

    # ---- derived ------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        p = len(self.layer_pattern)
        if self.moe is not None:
            p = _lcm(p, self.moe.every)
        if self.cross_attn_every:
            p = _lcm(p, self.cross_attn_every)
        return p

    @property
    def n_super(self) -> int:
        assert self.n_layers % self.period == 0, (self.n_layers, self.period)
        return self.n_layers // self.period

    def block_kinds(self) -> Tuple["BlockKind", ...]:
        """The per-position block spec within one period."""
        kinds = []
        for j in range(self.period):
            mixer = self.layer_pattern[j % len(self.layer_pattern)]
            is_moe = self.moe is not None and (j % self.moe.every == self.moe.every - 1)
            has_cross = bool(
                self.cross_attn_every
                and (j % self.cross_attn_every == self.cross_attn_every - 1)
            )
            kinds.append(BlockKind(mixer=mixer, moe=is_moe, cross=has_cross))
        return tuple(kinds)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.qkv_bias:
            attn += hd * (self.n_heads + 2 * self.n_kv_heads)
        ff_mults = {"silu_gated": 3, "gelu_gated": 3, "squared_relu": 2, "gelu": 2}
        dense_ffn = ff_mults[self.act] * d * self.d_ff
        total = 0
        for k in self.block_kinds():
            if k.mixer == "A":
                total += attn + 2 * d  # + norms
            else:
                s = self.ssm or SSMCfg()
                di = s.expand * d
                nheads = di // s.head_dim
                total += (
                    d * (2 * di + 2 * s.d_state + nheads)  # in_proj (z,x,B,C,dt)
                    + s.d_conv * (di + 2 * s.d_state)
                    + di * d
                    + nheads * 2
                    + 2 * d
                )
            if k.cross:
                total += attn + d
            if k.moe:
                m = self.moe
                e = ff_mults[self.act] * d * m.d_ff_expert
                total += m.n_experts * e + m.n_shared * e + d * m.n_experts
            else:
                total += dense_ffn
        total *= self.n_super
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        total += d  # final norm
        if self.encoder is not None:
            enc_attn = d * hd * (self.encoder.n_heads + 2 * self.encoder.n_kv_heads)
            enc_attn += self.encoder.n_heads * hd * d
            total += self.encoder.n_layers * (enc_attn + dense_ffn + 2 * d)
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        ff_mults = {"silu_gated": 3, "gelu_gated": 3, "squared_relu": 2, "gelu": 2}
        e = ff_mults[self.act] * self.d_model * m.d_ff_expert
        n_moe_layers = sum(k.moe for k in self.block_kinds()) * self.n_super
        inactive = n_moe_layers * (m.n_experts - m.top_k) * e
        return int(full - inactive)


class BlockKind(NamedTuple):
    mixer: str  # "A" attention | "M" mamba
    moe: bool
    cross: bool


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)
