"""GQA attention: init, full/chunked causal forward, cross-attention, decode.

Conventions: activations (B, L, d); q heads H, kv heads KV, group G = H // KV;
softmax always in float32.  The chunked path scans over query chunks with the
keys resident (memory O(chunk * S) instead of O(L * S)) — required for the
32k-prefill shapes, and remat-friendly.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers.rope import apply_rope, rope_angles

__all__ = ["init_attention", "attention_forward", "attention_decode", "AttnTemps"]

NEG_INF = -1e30


def init_attention(key, d_model, n_heads, n_kv_heads, head_dim, qkv_bias=False,
                   dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    so = 1.0 / math.sqrt(n_heads * head_dim)
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_kv_heads * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_kv_heads * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads * head_dim, d_model)) * so).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(p, x, xk, n_heads, n_kv_heads, head_dim):
    B, L, _ = x.shape
    S = xk.shape[1]
    q = x @ p["wq"]
    k = xk @ p["wk"]
    v = xk @ p["wv"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, L, n_kv_heads, n_heads // n_kv_heads, head_dim)
    k = k.reshape(B, S, n_kv_heads, head_dim)
    v = v.reshape(B, S, n_kv_heads, head_dim)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q (B, Lq, KV, G, D), k/v (B, S, KV, D), mask broadcastable to
    (B, KV, G, Lq, S) or None -> (B, Lq, KV, G, D)."""
    scores = jnp.einsum("blkgd,bskd->bkgls", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgls,bskd->blkgd", w, v)


def _flash_fwd_inner(q, k, v, causal, scale, q_chunk, k_chunk):
    """Returns (out (B, L, KV, G, D) f32, lse (B, KV, G, L) f32)."""
    B, L, KV, G, D = q.shape
    S = k.shape[1]
    nq, nk = L // q_chunk, S // k_chunk
    qc = jnp.moveaxis(q.reshape(B, nq, q_chunk, KV, G, D), 1, 0)

    def per_q(args):
        qi_idx, qi = args
        q_pos = qi_idx * q_chunk + jnp.arange(q_chunk)

        def per_k(carry, kj_idx):
            m, l, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(k, kj_idx * k_chunk, k_chunk, 1)
            vj = jax.lax.dynamic_slice_in_dim(v, kj_idx * k_chunk, k_chunk, 1)
            s = jnp.einsum("blkgd,bskd->bkgls", qi, kj).astype(jnp.float32) * scale
            if causal:
                k_pos = kj_idx * k_chunk + jnp.arange(k_chunk)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bkgls,bskd->bkgld", p.astype(v.dtype), vj).astype(
                jnp.float32
            )
            return (m_new, l_new, acc * alpha[..., None] + pv), None

        m0 = jnp.full((B, KV, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(per_k, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse  # (B, KV, G, Cq, D), (B, KV, G, Cq)

    _, (outs, lses) = jax.lax.scan(
        lambda c, x: (c, per_q(x)), None, (jnp.arange(nq), qc)
    )
    out = jnp.moveaxis(jnp.moveaxis(outs, 0, 3).reshape(B, KV, G, L, D), 3, 1)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, KV, G, L)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention_core(q, k, v, causal, scale, q_chunk, k_chunk):
    out, _ = _flash_fwd_inner(q, k, v, causal, scale, q_chunk, k_chunk)
    return out


def _flash_core_fwd(q, k, v, causal, scale, q_chunk, k_chunk):
    out, lse = _flash_fwd_inner(q, k, v, causal, scale, q_chunk, k_chunk)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(causal, scale, q_chunk, k_chunk, res, do):
    """FlashAttention-2-style backward: recompute p per (q, kv) chunk pair
    from the saved logsumexp — only (out, lse) were kept from the forward."""
    q, k, v, out, lse = res
    B, L, KV, G, D = q.shape
    S = k.shape[1]
    nq, nk = L // q_chunk, S // k_chunk
    delta = (do.astype(jnp.float32) * out).sum(-1)  # (B, L, KV, G)
    delta = jnp.moveaxis(delta, 1, 3)  # (B, KV, G, L)

    qc = jnp.moveaxis(q.reshape(B, nq, q_chunk, KV, G, D), 1, 0)
    doc = jnp.moveaxis(do.reshape(B, nq, q_chunk, KV, G, D), 1, 0)

    def per_q(carry, args):
        dk, dv = carry
        qi_idx, qi, doi = args
        q_pos = qi_idx * q_chunk + jnp.arange(q_chunk)
        lsei = jax.lax.dynamic_slice_in_dim(lse, qi_idx * q_chunk, q_chunk, 3)
        deltai = jax.lax.dynamic_slice_in_dim(delta, qi_idx * q_chunk, q_chunk, 3)

        def per_k(inner, kj_idx):
            dqi, dk, dv = inner
            kj = jax.lax.dynamic_slice_in_dim(k, kj_idx * k_chunk, k_chunk, 1)
            vj = jax.lax.dynamic_slice_in_dim(v, kj_idx * k_chunk, k_chunk, 1)
            s = jnp.einsum("blkgd,bskd->bkgls", qi, kj).astype(jnp.float32) * scale
            if causal:
                k_pos = kj_idx * k_chunk + jnp.arange(k_chunk)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lsei[..., None])  # (B, KV, G, Cq, Ck)
            dvj = jnp.einsum("bkgls,blkgd->bskd", p.astype(do.dtype), doi)
            dp = jnp.einsum("blkgd,bskd->bkgls", doi, vj).astype(jnp.float32)
            ds = p * (dp - deltai[..., None]) * scale
            dqi = dqi + jnp.einsum("bkgls,bskd->blkgd", ds.astype(q.dtype), kj)
            dkj = jnp.einsum("bkgls,blkgd->bskd", ds.astype(q.dtype), qi)
            dk = jax.lax.dynamic_update_slice_in_dim(
                dk,
                jax.lax.dynamic_slice_in_dim(dk, kj_idx * k_chunk, k_chunk, 1)
                + dkj.astype(dk.dtype),
                kj_idx * k_chunk,
                1,
            )
            dv = jax.lax.dynamic_update_slice_in_dim(
                dv,
                jax.lax.dynamic_slice_in_dim(dv, kj_idx * k_chunk, k_chunk, 1)
                + dvj.astype(dv.dtype),
                kj_idx * k_chunk,
                1,
            )
            return (dqi, dk, dv), None

        dqi0 = jnp.zeros_like(qi, jnp.float32)
        (dqi, dk, dv), _ = jax.lax.scan(per_k, (dqi0, dk, dv), jnp.arange(nk))
        return (dk, dv), dqi

    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)
    (dk, dv), dqs = jax.lax.scan(per_q, (dk0, dv0), (jnp.arange(nq), qc, doc))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, L, KV, G, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_attention_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def _flash_attention(q, k, v, *, causal: bool, scale: float, q_chunk: int,
                     k_chunk: int = 1024):
    """Online-softmax (flash-style) attention with a custom VJP.

    q (B, L, KV, G, D); k/v (B, S, KV, D).  The (L, S) score matrix is never
    materialized in either direction: forward saves only (out, lse); backward
    recomputes the probabilities chunk-by-chunk (FlashAttention-2 dataflow) —
    §Perf change #1 for the memory-bound train/prefill cells.
    """
    B, L, KV, G, D = q.shape
    S = k.shape[1]
    q_chunk = min(q_chunk, L)
    k_chunk = min(k_chunk, S)
    if L % q_chunk or S % k_chunk:
        q_chunk, k_chunk = L, S  # ragged fallback: single chunk
    out = _flash_attention_core(q, k, v, causal, scale, q_chunk, k_chunk)
    return out.astype(v.dtype)


def attention_forward(
    p,
    x,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    causal: bool = True,
    rope_theta: Optional[float] = 1e4,
    kv_source: Optional[jax.Array] = None,
    q_chunk: int = 0,
    positions: Optional[jax.Array] = None,
    flash: bool = True,
):
    """Self- or cross-attention over full sequences.

    kv_source: if given, cross-attention (no causal mask, no rope on kv source
    positions beyond its own indexing).  q_chunk > 0 enables the chunked scan.
    """
    B, L, _ = x.shape
    xk = x if kv_source is None else kv_source
    q, k, v = _project_qkv(p, x, xk, n_heads, n_kv_heads, head_dim)
    S = k.shape[1]
    if rope_theta is not None and kv_source is None:
        pos = positions if positions is not None else jnp.arange(L)
        cos, sin = rope_angles(pos, head_dim, rope_theta)
        qf = q.reshape(B, L, n_heads, head_dim)
        qf = apply_rope(qf, cos, sin)
        q = qf.reshape(B, L, n_kv_heads, n_heads // n_kv_heads, head_dim)
        k = apply_rope(k, cos, sin)
    scale = 1.0 / math.sqrt(head_dim)

    if q_chunk and L > q_chunk and L % q_chunk == 0 and flash:
        out = _flash_attention(
            q, k, v, causal=causal and kv_source is None, scale=scale,
            q_chunk=q_chunk,
        ).reshape(B, L, n_heads * head_dim)
    elif q_chunk and L > q_chunk and L % q_chunk == 0:
        # chunked full-softmax: scores materialize per q-chunk only
        n_chunks = L // q_chunk
        qc = q.reshape(B, n_chunks, q_chunk, n_kv_heads, -1, head_dim)
        qc = jnp.moveaxis(qc, 1, 0)

        def body(carry, args):
            ci, qi = args
            if causal and kv_source is None:
                rows = ci * q_chunk + jnp.arange(q_chunk)
                mask = (rows[:, None] >= jnp.arange(S)[None, :])[None, None, None]
            else:
                mask = None
            return carry, _sdpa(qi, k, v, mask, scale)

        _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qc))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, L, n_heads * head_dim)
    else:
        mask = None
        if causal and kv_source is None:
            mask = (jnp.arange(L)[:, None] >= jnp.arange(S)[None, :])[
                None, None, None
            ]
        out = _sdpa(q, k, v, mask, scale).reshape(B, L, n_heads * head_dim)
    return out @ p["wo"]


class AttnTemps(NamedTuple):
    k: jax.Array  # (B, S_max, KV, D)
    v: jax.Array


def init_kv_cache(batch, max_len, n_kv_heads, head_dim, dtype=jnp.bfloat16):
    z = jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype)
    return AttnTemps(z, jnp.copy(z))


def attention_decode(
    p,
    x,
    cache: AttnTemps,
    pos,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float] = 1e4,
):
    """One-token decode: x (B, 1, d), cache (B, S_max, KV, D), pos scalar int.

    Returns (out (B, 1, d), new_cache).  Masking: keys at index > pos are
    excluded (cache beyond the current position may be uninitialized).
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, x, n_heads, n_kv_heads, head_dim)
    if rope_theta is not None:
        posv = jnp.full((1,), pos)
        cos, sin = rope_angles(posv, head_dim, rope_theta)
        qf = q.reshape(B, 1, n_heads, head_dim)
        qf = apply_rope(qf, cos, sin)
        q = qf.reshape(B, 1, n_kv_heads, n_heads // n_kv_heads, head_dim)
        k_new = apply_rope(k_new, cos, sin)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, pos, 0, 0))
    S = k.shape[1]
    mask = (jnp.arange(S) <= pos)[None, None, None, None, :]
    out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(head_dim))
    out = out.reshape(B, 1, n_heads * head_dim) @ p["wo"]
    return out, AttnTemps(k, v)


def cross_attention_decode(p, x, k, v, *, n_heads, n_kv_heads, head_dim):
    """Decode-time cross-attention against precomputed (static) K/V."""
    B = x.shape[0]
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(B, 1, n_kv_heads, n_heads // n_kv_heads, head_dim)
    out = _sdpa(q, k, v, None, 1.0 / math.sqrt(head_dim))
    return out.reshape(B, 1, n_heads * head_dim) @ p["wo"]


def project_cross_kv(p, kv_source, *, n_kv_heads, head_dim):
    """Precompute cross-attention K/V once per request."""
    B, S, _ = kv_source.shape
    k = kv_source @ p["wk"]
    v = kv_source @ p["wv"]
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return (
        k.reshape(B, S, n_kv_heads, head_dim),
        v.reshape(B, S, n_kv_heads, head_dim),
    )
