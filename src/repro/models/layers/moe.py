"""Mixture-of-Experts FFN: top-k routing with capacity buffers (+ shared experts).

Dispatch is the scatter/gather formulation (GShard capacity semantics without
the (T, E, C) one-hot): tokens are scattered into per-expert capacity buffers
(E, C, d) via computed slots, experts run as one batched einsum (EP: the E dim
shards over the ``model``/``expert`` mesh axis), results gather back weighted
by router probabilities.  Tokens beyond capacity are dropped (standard
capacity-factor semantics); shared experts (DeepSeek-style) are a fused dense
FFN that always runs.

Returns a Switch-style load-balance auxiliary loss.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import MoECfg
from repro.models.layers.mlp import init_mlp, mlp_forward

try:  # JAX >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

__all__ = ["init_moe", "moe_forward"]


def init_moe(key, d_model: int, cfg: MoECfg, act: str, dtype=jnp.bfloat16):
    kr, ke, kg, ko, ks = jax.random.split(key, 5)
    E, f = cfg.n_experts, cfg.d_ff_expert
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(kr, (d_model, E)) * s_in).astype(jnp.float32),
        "w_in": (jax.random.normal(ke, (E, d_model, f)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ko, (E, f, d_model)) * s_out).astype(dtype),
    }
    if act in ("silu_gated", "gelu_gated"):
        p["w_gate"] = (jax.random.normal(kg, (E, d_model, f)) * s_in).astype(dtype)
    if cfg.n_shared:
        p["shared"] = init_mlp(ks, d_model, cfg.n_shared * f, act, dtype)
    return p


def _expert_ffn(p, h, act: str):
    """h: (E, C, d) -> (E, C, d), batched over experts."""
    u = jnp.einsum("ecd,edf->ecf", h, p["w_in"])
    if act in ("silu_gated", "gelu_gated"):
        g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
        u = (jax.nn.silu(g) if act == "silu_gated" else jax.nn.gelu(g)) * u
    elif act == "squared_relu":
        u = jnp.square(jax.nn.relu(u))
    else:
        u = jax.nn.gelu(u)
    return jnp.einsum("ecf,efd->ecd", u, p["w_out"])


def _dispatch_compute_combine(p, xl, cfg: MoECfg, act: str, e_base, E_loc: int, C: int):
    """Route local tokens to local experts with capacity C (no comms).

    xl (T_loc, d); expert weights in ``p`` already local (E_loc, d, f).
    Returns (partial y (T_loc, d) — contributions of local experts only,
    me (E,), ce (E,) for the aux loss).
    """
    T_loc, d = xl.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = xl.astype(jnp.float32) @ p["router"]  # (T_loc, E) fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(0)
    ce = jax.nn.one_hot(top_idx[:, 0], E, dtype=jnp.float32).mean(0)

    rel = top_idx - e_base  # (T_loc, K) index into local experts
    mine = (rel >= 0) & (rel < E_loc)
    flat_rel = jnp.where(mine, rel, E_loc).reshape(-1)  # E_loc = dump bucket
    oh = jax.nn.one_hot(flat_rel, E_loc + 1, dtype=jnp.int32)
    pos = ((jnp.cumsum(oh, axis=0) - 1) * oh).sum(-1)
    valid = mine.reshape(-1) & (pos < C)
    slot = jnp.where(valid, flat_rel * C + pos, E_loc * C)

    xrep = jnp.broadcast_to(xl[:, None, :], (T_loc, K, d)).reshape(T_loc * K, d)
    buf = jnp.zeros((E_loc * C + 1, d), xl.dtype).at[slot].set(xrep)
    h = buf[: E_loc * C].reshape(E_loc, C, d)
    o = _expert_ffn(p, h, act)
    o_flat = jnp.concatenate([o.reshape(E_loc * C, d), jnp.zeros((1, d), o.dtype)])
    y_tk = o_flat[slot] * valid[:, None].astype(o.dtype)
    y = (y_tk.reshape(T_loc, K, d) * top_w[..., None].astype(xl.dtype)).sum(1)
    return y, me, ce


def _moe_forward_shard_map(
    p, xf, cfg: MoECfg, act: str, mesh, wg=None
) -> Tuple[jax.Array, jax.Array]:
    """EP dispatch under shard_map: tokens sharded over the data axes, experts
    over ``model``.  Dispatch buffers are per-shard ((E/M) x C_loc x d — MBs,
    not GiBs), the only communication is one psum over ``model`` to combine
    expert contributions (replacing the dense-FFN TP reduction).
    """
    from jax.sharding import PartitionSpec as P

    da = tuple(a for a in ("pod", "data") if a in mesh.shape)
    M = mesh.shape["model"]
    D = 1
    for a in da:
        D *= mesh.shape[a]
    T, d = xf.shape
    E, K = cfg.n_experts, cfg.top_k
    E_loc = E // M
    T_loc = T // D
    C_loc = max(1, int(math.ceil(T_loc * K / E * cfg.capacity_factor)))

    wspecs = {
        "router": P(None, None),
        "w_in": P("model", None, None),
        "w_out": P("model", None, None),
    }
    if "w_gate" in p:
        wspecs["w_gate"] = P("model", None, None)
    if wg is not None:
        # int8-compressed FSDP gather of the expert weights (Perf change #2)
        pw = {"router": jax.lax.with_sharding_constraint(
            p["router"], jax.sharding.NamedSharding(mesh, wspecs["router"]))}
        for k in ("w_in", "w_gate", "w_out"):
            if k in p:
                pw[k] = wg(p[k], "moe")
    else:
        pw = {k: jax.lax.with_sharding_constraint(
            p[k], jax.sharding.NamedSharding(mesh, s)) for k, s in wspecs.items()}

    def local_fn(weights, xl):
        j = jax.lax.axis_index("model")
        y, me, ce = _dispatch_compute_combine(
            weights, xl, cfg, act, j * E_loc, E_loc, C_loc
        )
        y = jax.lax.psum(y, "model")
        me = jax.lax.pmean(me, da) if da else me
        ce = jax.lax.pmean(ce, da) if da else ce
        aux = E * jnp.sum(me * ce)
        return y, aux

    y, aux = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(wspecs, P(da, None)),
        out_specs=(P(da, None), P()),
        check_vma=False,
    )(pw, xf)
    return y, aux


def moe_forward(
    p, x, cfg: MoECfg, act: str, shard_fn=lambda a, k: a, wg=None
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, L, d) -> (y, aux_loss).

    When the caller's ``shard_fn`` carries a mesh (distributed runs) and the
    token count divides the data axes, routing runs under shard_map (EP with
    per-shard capacity buffers — see ``_moe_forward_shard_map``); otherwise
    the single-device pjit scatter path below is used (smoke tests, tiny
    decode batches)."""
    B, L, d = x.shape
    T = B * L
    E, K = cfg.n_experts, cfg.top_k

    mesh = getattr(shard_fn, "mesh", None)
    if mesh is not None and "model" in mesh.shape and E % mesh.shape["model"] == 0:
        da = tuple(a for a in ("pod", "data") if a in mesh.shape)
        D = 1
        for a in da:
            D *= mesh.shape[a]
        if T % D == 0 and T >= D:
            xf = x.reshape(T, d)
            y, aux = _moe_forward_shard_map(p, xf, cfg, act, mesh, wg)
            if "shared" in p:
                y = y + mlp_forward(p["shared"], xf, act)
            return y.reshape(B, L, d), aux

    xf = shard_fn(x.reshape(T, d), "moe_tokens")

    logits = (xf.astype(jnp.float32)) @ p["router"]  # (T, E) fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, K)  # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux (Switch): E * sum_e f_e * P_e ----------------
    me = probs.mean(0)  # (E,)
    one_hot_top1 = jax.nn.one_hot(top_idx[:, 0], E, dtype=jnp.float32)
    ce = one_hot_top1.mean(0)
    aux = E * jnp.sum(me * ce)

    # ---- capacity slots -------------------------------------------------
    C = max(1, int(math.ceil(T * K / E * cfg.capacity_factor)))
    flat_e = top_idx.reshape(-1)  # (T*K,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    pos_in_e = (jnp.cumsum(oh, axis=0) - 1) * oh  # running index per expert
    pos = pos_in_e.sum(-1)  # (T*K,)
    valid = pos < C
    slot = jnp.where(valid, flat_e * C + pos, E * C)  # E*C = drop row

    # ---- dispatch -> expert compute -> combine -------------------------
    xrep = jnp.broadcast_to(xf[:, None, :], (T, K, d)).reshape(T * K, d)
    xrep = shard_fn(xrep, "moe_tokens")
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xrep)
    h = shard_fn(buf[: E * C].reshape(E, C, d), "moe_buf")
    o = shard_fn(_expert_ffn(p, h, act), "moe_buf")
    o_flat = jnp.concatenate([o.reshape(E * C, d), jnp.zeros((1, d), o.dtype)])
    y_tk = shard_fn(o_flat[slot], "moe_tokens")  # dropped tokens read zeros
    y = (y_tk.reshape(T, K, d) * top_w[..., None].astype(x.dtype)).sum(1)

    if "shared" in p:
        y = y + mlp_forward(p["shared"], xf, act)
    return y.reshape(B, L, d), aux
