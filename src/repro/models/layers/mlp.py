"""FFN variants: gated SiLU/GELU (llama-style), squared-ReLU (nemotron), GELU."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["init_mlp", "mlp_forward", "ffn_weight_shapes"]


def ffn_weight_shapes(act: str):
    """Number of projection matrices for the activation type."""
    return 3 if act in ("silu_gated", "gelu_gated") else 2


def init_mlp(key, d_model, d_ff, act="silu_gated", dtype=jnp.bfloat16):
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    n = ffn_weight_shapes(act)
    ks = jax.random.split(key, n)
    p = {
        "w_in": (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[1], (d_ff, d_model)) * s_out).astype(dtype),
    }
    if n == 3:
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * s_in).astype(dtype)
    return p


def mlp_forward(p, x, act="silu_gated"):
    h = x @ p["w_in"]
    if act == "silu_gated":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    elif act == "gelu_gated":
        h = jax.nn.gelu(x @ p["w_gate"]) * h
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return h @ p["w_out"]
