"""Mamba2 (SSD — state-space duality) mixer: chunked train/prefill + decode.

Implements the SSD algorithm of Dao & Gu 2024 (arXiv:2405.21060): the
sequence is split into chunks; within a chunk the recurrence is computed as a
masked attention-like quadratic form (MXU-friendly), across chunks a short
scan propagates the (H, P, N) state — O(L) total with matmul-dominated
compute, exactly the property that makes the ``long_500k`` cell feasible.

Decode maintains (conv window, SSM state) per layer: O(1) per token.
Single B/C group (G=1); conv over the concatenated [x, B, C] channels as in
the reference implementation.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.nn import rms_norm
from repro.models.config import SSMCfg

__all__ = ["init_ssm", "ssm_forward", "ssm_decode", "init_ssm_cache", "SSMCache"]


class SSMCache(NamedTuple):
    conv: jax.Array  # (B, d_conv - 1, d_in + 2N) rolling window
    state: jax.Array  # (B, H, P, N) fp32


def _dims(d_model: int, cfg: SSMCfg):
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    return d_in, H, cfg.head_dim, cfg.d_state


def init_ssm(key, d_model: int, cfg: SSMCfg, dtype=jnp.bfloat16):
    d_in, H, P, N = _dims(d_model, cfg)
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d_model)
    proj_out = 2 * d_in + 2 * N + H  # z, x, B, C, dt
    dt = jnp.exp(
        jax.random.uniform(ks[2], (H,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    return {
        "in_proj": (jax.random.normal(ks[0], (d_model, proj_out)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, d_in + 2 * N)) * 0.2).astype(
            dtype
        ),
        "conv_b": jnp.zeros((d_in + 2 * N,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[3], (H,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "D": jnp.ones((H,), jnp.float32),
        "norm_g": jnp.ones((d_in,), dtype),
        "out_proj": (jax.random.normal(ks[4], (d_in, d_model)) * s / math.sqrt(2)).astype(
            dtype
        ),
    }


def _split_proj(proj, d_in, N, H):
    z = proj[..., :d_in]
    xbc = proj[..., d_in : 2 * d_in + 2 * N]
    dt = proj[..., 2 * d_in + 2 * N :]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv: xbc (B, L, ch), w (K, ch)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    y = jax.lax.conv_general_dilated(
        pad,
        w[:, None, :].astype(xbc.dtype),  # (K, 1, ch) HWIO-like for 1D
        (1,),
        "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1],
    )
    return jax.nn.silu(y + b.astype(y.dtype))


def _segsum_decay(dA_c):
    """dA_c: (B, nc, H, Q) -> within-chunk decay matrix exp(cs_i - cs_j), i>=j."""
    cs = jnp.cumsum(dA_c, axis=-1)  # (B, nc, H, Q)
    diff = cs[..., :, None] - cs[..., None, :]  # (B, nc, H, Q, Q)
    Q = dA_c.shape[-1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0), cs


def ssm_forward(p, x, cfg: SSMCfg, d_model: int):
    """x: (B, L, d_model) -> (B, L, d_model).  L must divide by cfg.chunk
    (or be smaller than it)."""
    B, L, _ = x.shape
    d_in, H, P, N = _dims(d_model, cfg)
    Q = min(cfg.chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    proj = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(proj, d_in, N, H)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_in].reshape(B, L, H, P)
    Bm = xbc[..., d_in : d_in + N]  # (B, L, N)
    Cm = xbc[..., d_in + N :]  # (B, L, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, L, H)
    A = -jnp.exp(p["A_log"])  # (H,) negative
    dA = dt * A  # (B, L, H)

    # chunk
    xc = xs.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, H).transpose(0, 1, 3, 2)  # (B, nc, H, Q)
    dAc = dA.reshape(B, nc, Q, H).transpose(0, 1, 3, 2)

    Lmat, cs = _segsum_decay(dAc)  # (B, nc, H, Q, Q), (B, nc, H, Q)
    att = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B, nc, Q, Q)
    full = att[:, :, None] * Lmat * dtc[:, :, :, None, :]  # (B, nc, H, Q, Q)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", full, xc)

    decay_end = jnp.exp(cs[..., -1:] - cs)  # (B, nc, H, Q)
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", Bc, decay_end * dtc, xc)
    chunk_decay = jnp.exp(cs[..., -1])  # (B, nc, H)

    def scan_body(s, inp):
        st_c, dec_c = inp
        out = s
        s = s * dec_c[..., None, None] + st_c
        return s, out

    _, s_prev = jax.lax.scan(
        scan_body,
        jnp.zeros((B, H, P, N), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N): state at chunk start

    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", Cc, s_prev, jnp.exp(cs))
    y = (y_diag + y_off).reshape(B, L, H, P)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, L, d_in).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_g"])
    return y @ p["out_proj"]


def init_ssm_cache(batch: int, d_model: int, cfg: SSMCfg, dtype=jnp.bfloat16):
    d_in, H, P, N = _dims(d_model, cfg)
    return SSMCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, d_in + 2 * N), dtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32),
    )


def ssm_decode(p, x, cache: SSMCache, cfg: SSMCfg, d_model: int):
    """One-token decode: x (B, 1, d_model) -> (y (B, 1, d_model), new cache)."""
    B = x.shape[0]
    d_in, H, P, N = _dims(d_model, cfg)
    proj = x[:, 0] @ p["in_proj"]  # (B, proj_out)
    z, xbc, dt_raw = _split_proj(proj, d_in, N, H)

    window = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B, K, ch)
    conv = (window * p["conv_w"].astype(window.dtype)[None]).sum(1) + p[
        "conv_b"
    ].astype(window.dtype)
    conv = jax.nn.silu(conv)
    new_conv = window[:, 1:]

    xs = conv[..., :d_in].reshape(B, H, P).astype(jnp.float32)
    Bv = conv[..., d_in : d_in + N].astype(jnp.float32)  # (B, N)
    Cv = conv[..., d_in + N :].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # (B, H)
    state = cache.state * dA[..., None, None] + (dt[..., None] * xs)[
        ..., None
    ] * Bv[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", state, Cv) + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z[:, None, :])
    y = rms_norm(y, p["norm_g"])
    return y @ p["out_proj"], SSMCache(new_conv, state)
