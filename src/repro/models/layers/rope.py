"""Rotary position embeddings (half-rotation convention)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rope_angles", "apply_rope"]


def rope_angles(positions, head_dim: int, theta: float = 1e4):
    """positions: (...,) int -> (cos, sin) each (..., head_dim/2) float32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, L, H, D); cos/sin: (L, D/2) or (B, L, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (L, half) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, L, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
