"""Streaming multi-stream ingest frontend: admission control + the
two-slot pipelined submit ring over the archive ingest tier.

``ArchiveIngest`` (``serving/engine.py``) is the storage tier's sealing
core, but by itself it is a single-caller toy: one synchronous
``submit -> coalesce -> seal`` chain with no notion of N concurrent
camera streams, no behavior when the coalescer falls behind, and no
overlap between host-side GOP staging and the device launch.  This
module is the edge server's camera-facing front door over it:

* **N bounded stream queues** — every stream gets its own session
  identity (a per-stream key derived by ``fold_in`` from the frontend
  seed) and per-stream sequence numbers, and a bounded GOP queue.  The
  identity material tags GOPs and shed records; stripe *seal* keys are
  untouched (still ``ArchiveIngest``'s sequence-numbered draw), so
  archives stay bit-identical to the synchronous path.
* **Admission control** — when a stream's queue is full, or aggregate
  queued bytes exceed ``queue_budget_bytes``, the LOWEST-novelty queued
  GOP is shed first (the retrieval tier would have ranked it last
  anyway).  A shed is never silent: each one appends a journal record
  (stream id, sequence number, novelty, bytes, reason), lands on the
  ``ingest.shed`` ledger edge (billed at exactly one call site,
  ``_shed``), and bumps the ``ingest.shed_bytes``/``ingest.shed_gops``
  counters.
* **Two-slot submit ring** — ``pump()`` moves admitted GOPs into the
  coalescer and walks ready stripes through
  ``_seal_dispatch``/``_seal_commit`` (the split around the fused seal's
  single blocking device→host fetch): the batch-k launch runs on device
  while batch k+1's host prep (bucketing, payload staging, KEM) runs on
  the host, and slot k is fetched/committed only after k+1 has been
  dispatched.  Commits are strictly FIFO, so stripe ids/keys keep their
  sequence order and the ring is bit-identical to the synchronous path
  by construction (pinned by ``tests/test_ingest_scale.py``).
* **Straggler-aware drain** — each ``pump()`` also force-drains
  coalescer buckets whose oldest GOP has waited past ``deadline_us``
  (``StripeCoalescer.drain_expired``), so p99 GOP-to-commit is bounded
  even on cold buckets that never fill a stripe.

The 16/256/1024-stream ``ingest_scale`` bench
(``benchmarks/kernels_bench.py`` + ``benchmarks/ingest_workload.py``)
drives this frontend and gates stripes/s, p50/p99 GOP-to-commit, shed
fraction, and launches-per-stripe in ``run.py --check``.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, NamedTuple, Optional

import jax
import numpy as np

from repro.core.archival.pipeline import StripeArchive
from repro.obs import EDGE_INGEST_SHED, OBS
from repro.obs import names as obs_names
from repro.serving.engine import ArchiveIngest

__all__ = [
    "FrontendConfig",
    "QueuedGOP",
    "ShedRecord",
    "StreamIngestFrontend",
]

SHED_PREFIX = "shed_"


class FrontendConfig(NamedTuple):
    # per-stream bounded queue: GOPs a single camera may hold un-admitted
    max_stream_gops: int = 8
    # aggregate admission budget over every stream's queued payload bytes
    queue_budget_bytes: int = 8 << 20
    # ready stripes per submit-ring slot (one fused dispatch per slot)
    batch_stripes: int = 4
    # straggler deadline: a coalescer bucket whose oldest GOP has waited
    # longer than this is force-drained as a partial stripe
    deadline_us: float = 500_000.0


class QueuedGOP(NamedTuple):
    """One admitted-but-uncoalesced GOP in a stream's bounded queue."""

    stream_id: int
    seq: int            # per-stream sequence number
    payload: jax.Array  # flat int8 codec payload
    manifest: Dict
    meta: Dict          # novelty/feature/_t_submit (+ stream identity)
    nbytes: int


class ShedRecord(NamedTuple):
    """What admission control refused — journaled, never silently dropped."""

    stream_id: int
    seq: int
    nbytes: int
    novelty: float
    reason: str  # "stream_queue" | "byte_budget"


class _StreamState:
    __slots__ = ("key", "seq", "queue")

    def __init__(self, key):
        self.key = key
        self.seq = 0
        self.queue: deque = deque()


class StreamIngestFrontend:
    """Admission control + pipelined seal submission for N camera streams.

    ``offer`` admits one PRE-ENCODED GOP payload from one stream (the
    neural codec runs upstream where the frames are hot — the frontend
    moves flat int8 payloads, exactly what ``StripeCoalescer`` eats).
    ``pump`` advances the machine: queued GOPs -> coalescer -> ready
    stripes -> the two-slot submit ring.  ``drain`` force-flushes
    everything (partial stripes included) and empties the ring.
    """

    def __init__(
        self,
        ingest: ArchiveIngest,
        cfg: FrontendConfig = FrontendConfig(),
        *,
        seed: int = 0,
        journal=None,
    ):
        self.ingest = ingest
        self.cfg = cfg
        self.journal = journal
        self.metrics = ingest.metrics  # one registry for the whole tier
        self._root_key = jax.random.PRNGKey(seed * 6151 + 13)
        self._streams: Dict[int, _StreamState] = {}
        self._queued_bytes = 0
        self._inflight = None  # the ring's occupied slot (0 or 1 in flight)
        self._shed_seq = 0
        self.shed_log: List[ShedRecord] = []
        self.committed: int = 0  # stripes committed through the ring

    # ---------------------------------------------------------- admission
    def _stream(self, stream_id: int) -> _StreamState:
        st = self._streams.get(stream_id)
        if st is None:
            # per-stream session identity: derived once, rides in GOP meta
            # and shed records; stripe seal keys are NOT derived from it
            st = _StreamState(jax.random.fold_in(self._root_key, stream_id))
            self._streams[stream_id] = st
        return st

    def offer(
        self,
        stream_id: int,
        payload,
        manifest: Dict,
        *,
        novelty: float = 0.0,
        feature=None,
        now_ns: Optional[int] = None,
    ) -> bool:
        """Admit one GOP into its stream's bounded queue.

        Returns True if the offered GOP was admitted (it may still be shed
        LATER by the byte-budget pass if lower-novelty work is absent),
        False if admission shed it immediately.  Shedding always prefers
        the lowest-novelty GOP — offered or already queued.
        """
        st = self._stream(stream_id)
        seq = st.seq
        st.seq += 1
        payload = np.asarray(payload).reshape(-1).astype(np.int8)
        nbytes = int(payload.shape[0])
        meta = {
            "novelty": float(novelty),
            "stream_seq": seq,
            "_t_submit": time.perf_counter_ns() if now_ns is None
            else int(now_ns),
        }
        if feature is not None:
            meta["feature"] = np.asarray(feature, np.float32).reshape(-1)
        gop = QueuedGOP(stream_id, seq, payload, manifest, meta, nbytes)
        admitted = True
        if len(st.queue) >= self.cfg.max_stream_gops:
            # stream queue full: keep the higher-novelty of (offered,
            # lowest-novelty queued) — shed the other
            victim_i = min(
                range(len(st.queue)),
                key=lambda i: st.queue[i].meta["novelty"],
            )
            victim = st.queue[victim_i]
            if victim.meta["novelty"] < gop.meta["novelty"]:
                del st.queue[victim_i]
                self._queued_bytes -= victim.nbytes
                self._shed(victim, "stream_queue")
                st.queue.append(gop)
                self._queued_bytes += nbytes
            else:
                self._shed(gop, "stream_queue")
                admitted = False
        else:
            st.queue.append(gop)
            self._queued_bytes += nbytes
        self._enforce_budget()
        OBS.gauge(obs_names.ING_QUEUE_DEPTH, self.queue_bytes)
        self.metrics.set_gauge(obs_names.ING_QUEUE_DEPTH, self.queue_bytes)
        return admitted

    def _enforce_budget(self) -> None:
        """Shed lowest-novelty queued GOPs until under the byte budget."""
        while self._queued_bytes > self.cfg.queue_budget_bytes:
            victim_st, victim_i = None, -1
            worst = None
            for st in self._streams.values():
                for i, g in enumerate(st.queue):
                    nov = g.meta["novelty"]
                    if worst is None or nov < worst:
                        worst, victim_st, victim_i = nov, st, i
            if victim_st is None:
                break  # nothing queued; budget must be < 0 — give up
            victim = victim_st.queue[victim_i]
            del victim_st.queue[victim_i]
            self._queued_bytes -= victim.nbytes
            self._shed(victim, "byte_budget")

    def _shed(self, gop: QueuedGOP, reason: str) -> None:
        """The ONE shed site: journal + ledger edge + counters.  Never a
        silent drop — the record survives a power loss if a journal is
        attached, and always lands in ``shed_log``."""
        rec = ShedRecord(
            gop.stream_id, gop.seq, gop.nbytes,
            float(gop.meta["novelty"]), reason,
        )
        self.shed_log.append(rec)
        if self.journal is not None:
            self.journal.commit(
                f"{SHED_PREFIX}{self._shed_seq:08d}.json",
                b"",
                meta={
                    "stream_id": rec.stream_id,
                    "seq": rec.seq,
                    "nbytes": rec.nbytes,
                    "novelty": rec.novelty,
                    "reason": rec.reason,
                },
            )
        self._shed_seq += 1
        OBS.flow(EDGE_INGEST_SHED, gop.nbytes)
        OBS.count(obs_names.ING_SHED_BYTES, gop.nbytes)
        OBS.count(obs_names.ING_SHED_GOPS)
        self.metrics.add(obs_names.ING_SHED_BYTES, gop.nbytes)
        self.metrics.add(obs_names.ING_SHED_GOPS)

    # ------------------------------------------------------------- pumping
    def _admit_to_coalescer(self) -> List:
        """Drain every stream queue into the coalescer, round-robin across
        streams in stream-id order so no camera can starve its peers."""
        ready = []
        queues = [
            (sid, st) for sid, st in sorted(self._streams.items())
            if st.queue
        ]
        while queues:
            next_round = []
            for sid, st in queues:
                g = st.queue.popleft()
                self._queued_bytes -= g.nbytes
                ready += self.ingest.coalescer.add(
                    g.stream_id, g.payload, g.manifest, meta=g.meta
                )
                if st.queue:
                    next_round.append((sid, st))
            queues = next_round
        return ready

    def pump(self, *, now_ns: Optional[int] = None) -> List[StripeArchive]:
        """Advance the machine one turn: admit queued GOPs, deadline-drain
        straggler buckets, and walk ready stripes through the two-slot
        submit ring.  Returns the stripes COMMITTED this turn (the ring
        may still hold one dispatched-but-unfetched slot — ``drain`` it).
        """
        ready = self._admit_to_coalescer()
        ready += self.ingest.coalescer.drain_expired(
            self.cfg.deadline_us, now_ns=now_ns
        )
        committed: List[StripeArchive] = []
        B = max(1, int(self.cfg.batch_stripes))
        for i in range(0, len(ready), B):
            batch = ready[i : i + B]
            # dispatch k+1 (host prep + async launch), THEN fetch/commit
            # slot k — the fetch waits on a launch that has been running
            # the whole time the host was staging this batch
            slot = self.ingest._seal_dispatch(batch)
            if self._inflight is not None:
                committed += self.ingest._seal_commit(self._inflight)
            self._inflight = slot
        self.committed += len(committed)
        self.metrics.set_gauge(obs_names.ING_QUEUE_DEPTH, self.queue_bytes)
        return committed

    def drain(self) -> List[StripeArchive]:
        """Flush everything: queued GOPs, partial coalescer buckets, and
        the ring's in-flight slot.  The frontend is empty afterwards."""
        ready = self._admit_to_coalescer()
        ready += self.ingest.coalescer.flush()
        committed: List[StripeArchive] = []
        if self._inflight is not None:
            committed += self.ingest._seal_commit(self._inflight)
            self._inflight = None
        if ready:
            committed += self.ingest._seal(ready)
        self.committed += len(committed)
        self.metrics.set_gauge(obs_names.ING_QUEUE_DEPTH, self.queue_bytes)
        return committed

    # ------------------------------------------------------------ querying
    @property
    def queue_bytes(self) -> int:
        """Aggregate queued payload bytes (streams + coalescer)."""
        return self._queued_bytes + self.ingest.coalescer.queue_bytes

    @property
    def n_streams(self) -> int:
        return len(self._streams)

    def stream_key(self, stream_id: int) -> jax.Array:
        """The stream's derived session identity key."""
        return self._stream(stream_id).key

    def stats(self) -> Dict[str, float]:
        m = self.metrics
        gops = int(m.get(obs_names.ING_GOPS))
        shed = int(m.get(obs_names.ING_SHED_GOPS))
        offered = gops + shed
        return {
            "n_streams": self.n_streams,
            "queue_bytes": self.queue_bytes,
            "stripes_committed": self.committed,
            "shed_gops": shed,
            "shed_bytes": int(m.get(obs_names.ING_SHED_BYTES)),
            "shed_frac": shed / offered if offered else 0.0,
        }
