"""Batched serving engine: slot-based continuous batching over decode_step,
plus the edge server's camera-facing archive ingest tier.

LM serving: requests occupy fixed batch slots; each engine step decodes one
token for every active slot (padded slots run but are masked).  Prefill uses
the full forward to populate KV/SSM caches token-by-token (teacher-forcing
path — the same code the parity tests validate), so serve results match
training-side semantics exactly.

Archive ingest (``ArchiveIngest``): the continuous-learning edge server also
*serves* N camera streams pushing ragged GOPs.  Ingest mirrors the LM
engine's batching idea at the storage layer: GOPs are codec-encoded on
arrival, coalesced across streams into full parity stripes
(``StripeCoalescer``), and each completed stripe is entropy-coded by the
on-device interleaved-rANS kernel and sealed, one fused launch per stage —
shard_map'd over the storage mesh's ``data`` axis when a mesh is attached,
so every mesh shard codes + seals its local slice (the CSD-array mapping;
see ``repro.distributed.archival``).  ``IngestConfig.archive.codec_name``
falls back to the host zstd/zlib codec for compatibility.

The ingest tier also fronts the archive's READ side: every sealed stripe is
indexed into a :class:`StripeCatalog` with the per-GOP salience descriptors
callers pass to ``submit`` (feature vector + novelty — computed where the
frames were already hot), and ``query`` turns a trainer's centroids into a
budgeted :class:`ReadPlan` over the catalog without decoding anything.
``stats()`` reports the measured entropy ratio, host-side entropy bytes
(zero for the on-device coder), and the retrieval counters: cataloged GOPs/
bytes and how many bytes the plans served actually touched vs the no-index
full-restore baseline.

The ingest tier also hosts the durability loop over everything it sealed
(scrub -> rebuild -> retire; ``core/archival/scrub.py``): ``scrub_round``
parity-verifies retained stripes on a byte budget and repairs located
corruption, ``mark_csd_lost``/``rebuild_csd`` degrade and then reconstruct
a dead CSD's shards onto a replacement (budget-bounded, salience-priority),
and ``retire`` journals low-salience stripes out of existence before their
key material is recycled.  All of it shows up in ``stats()``.
"""

from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archival.catalog import StripeCatalog, gop_descriptors
from repro.core.archival.pipeline import (
    ArchiveConfig,
    StripeArchive,
    encode_gop_payload,
    stripe_manifests,
)
from repro.core.archival.scrub import StripeScrubber, retire_stripes
from repro.core.csd.retrieval import ReadPlan, plan_retrieval
from repro.distributed.archival import (
    StripeCoalescer,
    plan_rebuild,
    rebuild_csd_sharded,
    seal_coalesced_stripes_dispatch,
    seal_coalesced_stripes_finalize,
)
from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache
from repro.obs import Metrics, OBS
from repro.obs import names as obs_names

__all__ = [
    "ServeConfig",
    "Request",
    "ServingEngine",
    "IngestConfig",
    "ArchiveIngest",
]


class ServeConfig(NamedTuple):
    max_batch: int = 4
    max_len: int = 64
    greedy: bool = True


class Request(NamedTuple):
    rid: int
    prompt: List[int]
    max_new: int


class _Slot(NamedTuple):
    rid: int
    pos: int
    remaining: int
    tokens: List[int]


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig, frontend=None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.frontend = frontend
        self.cache = init_cache(
            params, cfg, scfg.max_batch, scfg.max_len, frontend=frontend
        )
        self.slots: List[Optional[_Slot]] = [None] * scfg.max_batch
        self.queue: List[Request] = []
        self.finished: Dict[int, List[int]] = {}
        self._step = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos)
        )

    # ------------------------------------------------------------ admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.scfg.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                # prefill: feed prompt tokens one at a time into slot i's cache
                for t, tok in enumerate(req.prompt[:-1]):
                    self._feed(i, tok, t)
                self.slots[i] = _Slot(
                    req.rid,
                    len(req.prompt) - 1,
                    req.max_new,
                    list(req.prompt),
                )

    def _feed(self, slot: int, token: int, pos: int):
        toks = jnp.zeros((self.scfg.max_batch, 1), jnp.int32).at[slot, 0].set(token)
        _, self.cache = self._step(self.params, toks, self.cache, jnp.int32(pos))

    # ------------------------------------------------------------- step
    def step(self) -> int:
        """Decode one token for every active slot; returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        # NOTE: slots share a positional counter per step in this reference
        # engine only when their positions coincide; for mixed positions we
        # step the max-position slot batch-wise and others individually.
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(self.slots[i].pos, []).append(i)
        for pos, idxs in sorted(by_pos.items()):
            toks = jnp.zeros((self.scfg.max_batch, 1), jnp.int32)
            for i in idxs:
                toks = toks.at[i, 0].set(self.slots[i].tokens[-1])
            logits, self.cache = self._step(
                self.params, toks, self.cache, jnp.int32(pos)
            )
            nxt = (
                jnp.argmax(logits, axis=-1)
                if self.scfg.greedy
                else jax.random.categorical(jax.random.PRNGKey(pos), logits)
            )
            for i in idxs:
                s = self.slots[i]
                tok = int(np.asarray(nxt)[i])
                tokens = s.tokens + [tok]
                if s.remaining <= 1 or s.pos + 2 >= self.scfg.max_len:
                    self.finished[s.rid] = tokens
                    self.slots[i] = None
                else:
                    self.slots[i] = _Slot(s.rid, s.pos + 1, s.remaining - 1, tokens)
        return len(active)

    def run_to_completion(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.finished


# ------------------------------------------------------------ archive ingest
class IngestConfig(NamedTuple):
    n_shards: int = 4  # GOPs per stripe == storage shards per parity group
    archive: ArchiveConfig = ArchiveConfig()
    feature_dim: int = 8  # salience descriptor width (zeros when not given)


class ArchiveIngest:
    """Multi-stream GOP ingest front-end for the edge server's storage tier.

    ``submit`` accepts one GOP from one camera stream: the clip is
    codec-encoded immediately (features are hot — same frames the serving/
    training tier just saw) and the flat payload joins the coalescer; the
    optional ``feature``/``novelty`` salience descriptor rides along and is
    catalog-indexed when the stripe seals.  The returned list holds every
    :class:`StripeArchive` whose stripe this GOP completed — sealed,
    parity-coded, ready for the journal/placement tier.  ``flush`` drains
    stragglers (end of epoch, shutdown) the same way.  ``query`` serves the
    retrieval side: centroids in, budgeted per-shard read plan out.
    """

    def __init__(
        self,
        codec_params,
        pub,
        cfg: IngestConfig = IngestConfig(),
        *,
        mesh=None,
        axis: str = "data",
        seed: int = 0,
        journal=None,
    ):
        self.codec_params = codec_params
        self.pub = pub
        self.cfg = cfg
        self.mesh = mesh
        self.axis = axis
        # one instrument registry for the whole ingest tier — the coalescer
        # shares it, so ``stats()`` / ``snapshot()`` are views of a single
        # set of counters instead of two hand-assembled dicts
        self.metrics = Metrics()
        self.coalescer = StripeCoalescer(cfg.n_shards, metrics=self.metrics)
        self.catalog = StripeCatalog(journal)
        if journal is not None:
            # a restart must see the old index AND resume the stripe id
            # sequence past it — otherwise new seals would overwrite old
            # catalog records (and reuse key material) under colliding ids
            self.catalog.load()
        self._key = jax.random.PRNGKey(seed * 9176 + 29)
        self._stripe_seq = max(
            (
                int(e.stripe_id[len("ingest_"):]) + 1
                for e in self.catalog.entries
                if e.stripe_id.startswith("ingest_")
            ),
            default=0,
        )
        # durability tier: retained sealed stripes + replicated manifests
        # (the in-memory stand-in for the CSD fleet's disks), the background
        # scrubber, and the lost-CSD set the rebuild path drains
        self._stripes: Dict[str, StripeArchive] = {}
        self._manifests: Dict[str, List[Dict]] = {}
        self._lost_csds: set = set()
        self._scrubber = StripeScrubber(
            self._stripes.__getitem__, self._stripes.__setitem__
        )

    def _seal_dispatch(self, ready):
        """Async half of ``_seal``: draw keys/ids in sequence order, stage
        the batch and dispatch the fused launch WITHOUT the device sync.
        Returns the slot the submit ring carries: ``(ready, stripe_ids,
        pending)``, redeemed by ``_seal_commit`` — which MUST run in
        dispatch order (stripe ids/keys are sequence-numbered)."""
        if not ready:
            return None
        # draw every stripe's key/id up front (sequence order fixed before
        # any sealing), then hand the whole batch to the fused path — same-
        # bucket stripes share ONE kernel launch instead of one per stripe
        keys, stripe_ids = [], []
        for _ in ready:
            keys.append(jax.random.fold_in(self._key, self._stripe_seq))
            stripe_ids.append(f"ingest_{self._stripe_seq:08d}")
            self._stripe_seq += 1
        with OBS.span(
            "ingest.seal", stripes=len(ready),
            codec=self.cfg.archive.codec_name,
        ):
            pending = seal_coalesced_stripes_dispatch(
                self.pub, list(ready), keys, self.cfg.archive,
                mesh=self.mesh, axis=self.axis,
            )
        return (list(ready), stripe_ids, pending)

    def _seal_commit(self, slot) -> List[StripeArchive]:
        """Blocking half of ``_seal``: fetch the dispatched batch, then
        catalog/retain/meter every stripe exactly as the synchronous path
        always has (the commit stamp feeds the GOP latency histogram)."""
        if slot is None:
            return []
        ready, stripe_ids, pending = slot
        stripes = seal_coalesced_stripes_finalize(pending)
        t_commit = time.perf_counter_ns()
        for cs, stripe_id, stripe in zip(ready, stripe_ids, stripes):
            for b in stripe.blocks:
                em = b.manifest.get("entropy")
                if em and em.get("codec") != "none":
                    self.metrics.add(
                        obs_names.ING_ENTROPY_RAW, int(em["n_raw"])
                    )
                    self.metrics.add(
                        obs_names.ING_ENTROPY_COMP, int(em["n_comp"])
                    )
            for g in cs.gops:
                t_sub = (g.meta or {}).get("_t_submit")
                if t_sub is not None:
                    self.metrics.observe(
                        obs_names.ING_GOP_LATENCY_US,
                        (t_commit - t_sub) / 1e3,
                    )
            self.catalog.add_stripe(
                stripe_id,
                stripe,
                gop_descriptors(
                    cs.gops,
                    self.catalog.feature_dim or self.cfg.feature_dim,
                ),
            )
            self._stripes[stripe_id] = stripe
            self._manifests[stripe_id] = stripe_manifests(stripe)
        self.metrics.set_gauge(obs_names.CAT_GOPS, len(self.catalog))
        self.metrics.set_gauge(
            obs_names.CAT_BYTES, self.catalog.bytes_indexed
        )
        self.metrics.set_gauge(
            obs_names.STRIPES_RETAINED, len(self._stripes)
        )
        return list(stripes)

    def _seal(self, ready) -> List[StripeArchive]:
        # the synchronous entry IS dispatch+commit back-to-back, so the
        # pipelined submit ring (``serving/ingest.py``) stays bit-identical
        # to this path by construction
        return self._seal_commit(self._seal_dispatch(ready))

    def submit(
        self,
        stream_id: int,
        frames: jax.Array,
        *,
        feature=None,
        novelty: float = 0.0,
    ) -> List[StripeArchive]:
        """frames: (T, B, H, W, 3) one GOP. Returns stripes it completed.

        ``feature``: (feature_dim,) pooled salience descriptor from the
        serving/training tier (the frames are hot there); ``novelty``: its
        score vs the current exemplar centroids.  Both are optional — GOPs
        without them are cataloged with zero descriptors and simply rank
        last in retrieval queries.
        """
        flat, manifest, _ = encode_gop_payload(
            self.codec_params, frames, self.cfg.archive
        )
        # the submit stamp feeds the GOP-submit -> journal-commit latency
        # histogram when this GOP's stripe seals (monotonic clock; the key
        # rides the coalescer meta, ignored by gop_descriptors)
        meta = {"novelty": float(novelty), "_t_submit": time.perf_counter_ns()}
        if feature is not None:
            meta["feature"] = np.asarray(feature, np.float32).reshape(-1)
        ready = self.coalescer.add(stream_id, flat, manifest, meta=meta)
        return self._seal(ready)

    def flush(self) -> List[StripeArchive]:
        """Seal all pending GOPs into (possibly short) stripes."""
        return self._seal(self.coalescer.flush())

    def query(
        self,
        centroids=None,
        *,
        budget_bytes: Optional[int] = None,
        k: Optional[int] = None,
        dead_shards=(),
    ) -> ReadPlan:
        """Plan a retrieval over everything this ingest tier has sealed:
        rank cataloged GOPs by novelty vs ``centroids``, price host-vs-CSD
        decode, and emit the per-stripe shard subsets to restore."""
        plan = plan_retrieval(
            self.catalog, centroids, budget_bytes, k=k,
            dead_shards=dead_shards,
            parity_shards={"raid6": 2, "raid5": 1, "none": 0}[
                self.cfg.archive.parity
            ],
        )
        self.metrics.add(obs_names.RETR_PLANS)
        self.metrics.add(obs_names.RETR_PLANNED_BYTES, plan.bytes_planned)
        self.metrics.add(obs_names.RETR_FULL_BYTES, plan.bytes_full_restore)
        self.metrics.add(obs_names.RETR_SKIPPED, plan.skipped)
        return plan

    # ------------------------------------------------------ durability tier
    def scrub_round(self, budget_bytes: int):
        """One byte-budgeted background scrub pass over the retained
        stripes (parity syndromes through the fused unseal — zero keys
        move; see ``core/archival/scrub``).  Corrupt shards located by the
        P/Q syndrome are repaired in place.  Returns the ``ScrubRound``."""
        rnd = self._scrubber.scrub_round(
            sorted(self._stripes), budget_bytes
        )
        self.metrics.add(obs_names.SCRUB_ROUNDS)
        self.metrics.add(obs_names.SCRUB_STRIPES, rnd.stripes_checked)
        self.metrics.add(obs_names.SCRUB_BYTES, rnd.bytes_scrubbed)
        self.metrics.add(obs_names.SCRUB_FINDINGS, len(rnd.findings))
        self.metrics.add(
            obs_names.SCRUB_REPAIRED, sum(f.repaired for f in rnd.findings)
        )
        return rnd

    def mark_csd_lost(self, csd: int) -> int:
        """A CSD died (StragglerMonitor verdict): its shard of every
        retained stripe is gone until ``rebuild_csd`` restores it onto a
        replacement.  Returns how many stripe shards went degraded."""
        self._lost_csds.add(int(csd))
        self.metrics.set_gauge(obs_names.LOST_CSDS, len(self._lost_csds))
        n = 0
        for sid, stripe in self._stripes.items():
            if csd < len(stripe.blocks) and stripe.blocks[csd] is not None:
                blocks = list(stripe.blocks)
                blocks[csd] = None
                self._stripes[sid] = stripe._replace(blocks=blocks)
                n += 1
        return n

    def rebuild_csd(self, csd: int, budget_bytes: int, centroids=None):
        """One budget-bounded rebuild round for a lost CSD: reconstruct its
        shards onto the replacement via the sharded parity pass, most-
        salient stripes first.  Call repeatedly until ``remaining`` is
        empty — the CSD leaves the lost set only then."""
        items = [
            it for it in plan_rebuild(self.catalog, csd, centroids)
            if it.stripe_id in self._stripes
            and self._stripes[it.stripe_id].blocks[it.shard] is None
        ]

        def put_shard(sid, shard, blk):
            stripe = self._stripes[sid]
            blocks = list(stripe.blocks)
            blocks[shard] = blk
            self._stripes[sid] = stripe._replace(blocks=blocks)

        rnd = rebuild_csd_sharded(
            self._stripes.__getitem__, self._manifests.__getitem__, items,
            budget_bytes=budget_bytes, put_shard=put_shard,
            mesh=self.mesh, axis=self.axis,
        )
        self.metrics.add(obs_names.REBUILD_SHARDS, len(rnd.rebuilt))
        self.metrics.add(obs_names.REBUILD_BYTES, rnd.bytes_rebuilt)
        if not rnd.remaining:
            self._lost_csds.discard(int(csd))
        self.metrics.set_gauge(obs_names.LOST_CSDS, len(self._lost_csds))
        return rnd

    def retire(self, stripe_ids) -> int:
        """Retire stripes (lifecycle tier): journal the retirement, compact
        the catalog's journal, then drop bodies + key material — strictly
        in that order (see ``scrub.retire_stripes``).  Returns #retired."""
        report = retire_stripes(self.catalog, list(stripe_ids))
        for sid in report.keys_recyclable:
            # bodies (and the KEM material inside them) only after the
            # retirement is journaled
            self._stripes.pop(sid, None)
            self._manifests.pop(sid, None)
        self.metrics.add(obs_names.RETIRED_STRIPES, len(report.retired))
        self.metrics.set_gauge(
            obs_names.STRIPES_RETAINED, len(self._stripes)
        )
        self.metrics.set_gauge(obs_names.CAT_GOPS, len(self.catalog))
        self.metrics.set_gauge(
            obs_names.CAT_BYTES, self.catalog.bytes_indexed
        )
        return len(report.retired)

    def stats(self) -> Dict[str, float]:
        """Legacy stats view — every value read back from the shared
        ``Metrics`` registry (one set of instruments, see ``snapshot``
        for the windowed raw form)."""
        m = self.metrics
        s = self.coalescer.stats()
        raw = m.get(obs_names.ING_ENTROPY_RAW)
        comp = m.get(obs_names.ING_ENTROPY_COMP)
        s["entropy_ratio"] = raw / comp if comp else float("nan")
        # payload bytes the entropy stage moved over the host link: the
        # on-device coder ships none, the zstd/zlib fallback ships them all
        on_device = self.cfg.archive.codec_name in ("rans", "none")
        s["host_entropy_bytes"] = 0 if on_device else int(raw)
        # retrieval side: what the salience index is saving on reads
        s["catalog_gops"] = len(self.catalog)
        s["catalog_bytes"] = self.catalog.bytes_indexed
        s["plans_served"] = int(m.get(obs_names.RETR_PLANS))
        planned = int(m.get(obs_names.RETR_PLANNED_BYTES))
        full = int(m.get(obs_names.RETR_FULL_BYTES))
        s["planned_read_bytes"] = planned
        s["planned_full_bytes"] = full
        s["retrieval_bytes_ratio"] = planned / full if full else float("nan")
        # durability tier: is the archive being continuously verified?
        s["stripes_retained"] = len(self._stripes)
        s["lost_csds"] = len(self._lost_csds)
        s["scrub_rounds"] = int(m.get(obs_names.SCRUB_ROUNDS))
        s["scrub_bytes"] = int(m.get(obs_names.SCRUB_BYTES))
        s["scrub_findings"] = int(m.get(obs_names.SCRUB_FINDINGS))
        s["scrub_repaired"] = int(m.get(obs_names.SCRUB_REPAIRED))
        s["rebuilt_shards"] = int(m.get(obs_names.REBUILD_SHARDS))
        s["rebuilt_bytes"] = int(m.get(obs_names.REBUILD_BYTES))
        s["stripes_retired"] = int(m.get(obs_names.RETIRED_STRIPES))
        return s

    def snapshot(self, reset: bool = False) -> Dict[str, object]:
        """Raw registry snapshot (canonical ``repro.obs.names`` keys,
        histograms as summary dicts).  ``reset=True`` gives windowed
        semantics: counters and histograms zero after the read so the next
        snapshot reports per-interval activity; gauges (occupancy, catalog
        size) are levels and keep their value.  NOTE: ``stats()`` reads
        the same counters, so a windowed reset clears its cumulative
        totals too — pick one consumption style per instance.
        """
        return self.metrics.snapshot(reset=reset)
