"""Batched serving engine: slot-based continuous batching over decode_step.

Requests occupy fixed batch slots; each engine step decodes one token for
every active slot (padded slots run but are masked).  Prefill uses the full
forward to populate KV/SSM caches token-by-token (teacher-forcing path — the
same code the parity tests validate), so serve results match training-side
semantics exactly.
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache

__all__ = ["ServeConfig", "Request", "ServingEngine"]


class ServeConfig(NamedTuple):
    max_batch: int = 4
    max_len: int = 64
    greedy: bool = True


class Request(NamedTuple):
    rid: int
    prompt: List[int]
    max_new: int


class _Slot(NamedTuple):
    rid: int
    pos: int
    remaining: int
    tokens: List[int]


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig, frontend=None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.frontend = frontend
        self.cache = init_cache(
            params, cfg, scfg.max_batch, scfg.max_len, frontend=frontend
        )
        self.slots: List[Optional[_Slot]] = [None] * scfg.max_batch
        self.queue: List[Request] = []
        self.finished: Dict[int, List[int]] = {}
        self._step = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos)
        )

    # ------------------------------------------------------------ admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.scfg.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                # prefill: feed prompt tokens one at a time into slot i's cache
                for t, tok in enumerate(req.prompt[:-1]):
                    self._feed(i, tok, t)
                self.slots[i] = _Slot(
                    req.rid,
                    len(req.prompt) - 1,
                    req.max_new,
                    list(req.prompt),
                )

    def _feed(self, slot: int, token: int, pos: int):
        toks = jnp.zeros((self.scfg.max_batch, 1), jnp.int32).at[slot, 0].set(token)
        _, self.cache = self._step(self.params, toks, self.cache, jnp.int32(pos))

    # ------------------------------------------------------------- step
    def step(self) -> int:
        """Decode one token for every active slot; returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        # NOTE: slots share a positional counter per step in this reference
        # engine only when their positions coincide; for mixed positions we
        # step the max-position slot batch-wise and others individually.
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(self.slots[i].pos, []).append(i)
        for pos, idxs in sorted(by_pos.items()):
            toks = jnp.zeros((self.scfg.max_batch, 1), jnp.int32)
            for i in idxs:
                toks = toks.at[i, 0].set(self.slots[i].tokens[-1])
            logits, self.cache = self._step(
                self.params, toks, self.cache, jnp.int32(pos)
            )
            nxt = (
                jnp.argmax(logits, axis=-1)
                if self.scfg.greedy
                else jax.random.categorical(jax.random.PRNGKey(pos), logits)
            )
            for i in idxs:
                s = self.slots[i]
                tok = int(np.asarray(nxt)[i])
                tokens = s.tokens + [tok]
                if s.remaining <= 1 or s.pos + 2 >= self.scfg.max_len:
                    self.finished[s.rid] = tokens
                    self.slots[i] = None
                else:
                    self.slots[i] = _Slot(s.rid, s.pos + 1, s.remaining - 1, tokens)
        return len(active)

    def run_to_completion(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.finished
