"""Batched serving engine: slot-based continuous batching over decode_step,
plus the edge server's camera-facing archive ingest tier.

LM serving: requests occupy fixed batch slots; each engine step decodes one
token for every active slot (padded slots run but are masked).  Prefill uses
the full forward to populate KV/SSM caches token-by-token (teacher-forcing
path — the same code the parity tests validate), so serve results match
training-side semantics exactly.

Archive ingest (``ArchiveIngest``): the continuous-learning edge server also
*serves* N camera streams pushing ragged GOPs.  Ingest mirrors the LM
engine's batching idea at the storage layer: GOPs are codec-encoded on
arrival, coalesced across streams into full parity stripes
(``StripeCoalescer``), and each completed stripe is entropy-coded by the
on-device interleaved-rANS kernel and sealed, one fused launch per stage —
shard_map'd over the storage mesh's ``data`` axis when a mesh is attached,
so every mesh shard codes + seals its local slice (the CSD-array mapping;
see ``repro.distributed.archival``).  ``IngestConfig.archive.codec_name``
falls back to the host zstd/zlib codec for compatibility; ``stats()``
reports the measured entropy ratio and how many payload bytes the entropy
stage shipped host-side (zero for the on-device coder).
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archival.pipeline import (
    ArchiveConfig,
    StripeArchive,
    encode_gop_payload,
)
from repro.distributed.archival import StripeCoalescer, seal_coalesced_stripe
from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache

__all__ = [
    "ServeConfig",
    "Request",
    "ServingEngine",
    "IngestConfig",
    "ArchiveIngest",
]


class ServeConfig(NamedTuple):
    max_batch: int = 4
    max_len: int = 64
    greedy: bool = True


class Request(NamedTuple):
    rid: int
    prompt: List[int]
    max_new: int


class _Slot(NamedTuple):
    rid: int
    pos: int
    remaining: int
    tokens: List[int]


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig, frontend=None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.frontend = frontend
        self.cache = init_cache(
            params, cfg, scfg.max_batch, scfg.max_len, frontend=frontend
        )
        self.slots: List[Optional[_Slot]] = [None] * scfg.max_batch
        self.queue: List[Request] = []
        self.finished: Dict[int, List[int]] = {}
        self._step = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos)
        )

    # ------------------------------------------------------------ admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.scfg.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                # prefill: feed prompt tokens one at a time into slot i's cache
                for t, tok in enumerate(req.prompt[:-1]):
                    self._feed(i, tok, t)
                self.slots[i] = _Slot(
                    req.rid,
                    len(req.prompt) - 1,
                    req.max_new,
                    list(req.prompt),
                )

    def _feed(self, slot: int, token: int, pos: int):
        toks = jnp.zeros((self.scfg.max_batch, 1), jnp.int32).at[slot, 0].set(token)
        _, self.cache = self._step(self.params, toks, self.cache, jnp.int32(pos))

    # ------------------------------------------------------------- step
    def step(self) -> int:
        """Decode one token for every active slot; returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        # NOTE: slots share a positional counter per step in this reference
        # engine only when their positions coincide; for mixed positions we
        # step the max-position slot batch-wise and others individually.
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(self.slots[i].pos, []).append(i)
        for pos, idxs in sorted(by_pos.items()):
            toks = jnp.zeros((self.scfg.max_batch, 1), jnp.int32)
            for i in idxs:
                toks = toks.at[i, 0].set(self.slots[i].tokens[-1])
            logits, self.cache = self._step(
                self.params, toks, self.cache, jnp.int32(pos)
            )
            nxt = (
                jnp.argmax(logits, axis=-1)
                if self.scfg.greedy
                else jax.random.categorical(jax.random.PRNGKey(pos), logits)
            )
            for i in idxs:
                s = self.slots[i]
                tok = int(np.asarray(nxt)[i])
                tokens = s.tokens + [tok]
                if s.remaining <= 1 or s.pos + 2 >= self.scfg.max_len:
                    self.finished[s.rid] = tokens
                    self.slots[i] = None
                else:
                    self.slots[i] = _Slot(s.rid, s.pos + 1, s.remaining - 1, tokens)
        return len(active)

    def run_to_completion(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.finished


# ------------------------------------------------------------ archive ingest
class IngestConfig(NamedTuple):
    n_shards: int = 4  # GOPs per stripe == storage shards per parity group
    archive: ArchiveConfig = ArchiveConfig()


class ArchiveIngest:
    """Multi-stream GOP ingest front-end for the edge server's storage tier.

    ``submit`` accepts one GOP from one camera stream: the clip is
    codec-encoded immediately (features are hot — same frames the serving/
    training tier just saw) and the flat payload joins the coalescer.  The
    returned list holds every :class:`StripeArchive` whose stripe this GOP
    completed — sealed, parity-coded, ready for the journal/placement tier.
    ``flush`` drains stragglers (end of epoch, shutdown) the same way.
    """

    def __init__(
        self,
        codec_params,
        pub,
        cfg: IngestConfig = IngestConfig(),
        *,
        mesh=None,
        axis: str = "data",
        seed: int = 0,
    ):
        self.codec_params = codec_params
        self.pub = pub
        self.cfg = cfg
        self.mesh = mesh
        self.axis = axis
        self.coalescer = StripeCoalescer(cfg.n_shards)
        self._key = jax.random.PRNGKey(seed * 9176 + 29)
        self._stripe_seq = 0
        self._entropy_raw = 0
        self._entropy_comp = 0

    def _seal(self, ready) -> List[StripeArchive]:
        out = []
        for cs in ready:
            key = jax.random.fold_in(self._key, self._stripe_seq)
            self._stripe_seq += 1
            stripe = seal_coalesced_stripe(
                self.pub, cs, key, self.cfg.archive,
                mesh=self.mesh, axis=self.axis,
            )
            for b in stripe.blocks:
                em = b.manifest.get("entropy")
                if em and em.get("codec") != "none":
                    self._entropy_raw += int(em["n_raw"])
                    self._entropy_comp += int(em["n_comp"])
            out.append(stripe)
        return out

    def submit(self, stream_id: int, frames: jax.Array) -> List[StripeArchive]:
        """frames: (T, B, H, W, 3) one GOP. Returns stripes it completed."""
        flat, manifest, _ = encode_gop_payload(
            self.codec_params, frames, self.cfg.archive
        )
        ready = self.coalescer.add(stream_id, flat, manifest)
        return self._seal(ready)

    def flush(self) -> List[StripeArchive]:
        """Seal all pending GOPs into (possibly short) stripes."""
        return self._seal(self.coalescer.flush())

    def stats(self) -> Dict[str, float]:
        s = self.coalescer.stats()
        s["entropy_ratio"] = (
            self._entropy_raw / self._entropy_comp
            if self._entropy_comp
            else float("nan")
        )
        # payload bytes the entropy stage moved over the host link: the
        # on-device coder ships none, the zstd/zlib fallback ships them all
        on_device = self.cfg.archive.codec_name in ("rans", "none")
        s["host_entropy_bytes"] = 0 if on_device else self._entropy_raw
        return s
