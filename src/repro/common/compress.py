"""Byte-level entropy stage: zstd when available, stdlib zlib fallback.

``zstandard`` is an optional dependency (the paper's own entropy coder); on
hosts without it the archival/checkpoint paths degrade to zlib rather than
failing at import.  Within one host the choice is deterministic, so blobs
written by ``compress`` always round-trip through ``decompress``.
"""

from __future__ import annotations

__all__ = ["HAVE_ZSTD", "CODEC_NAME", "compress", "decompress"]

try:
    import zstandard as _zstd

    HAVE_ZSTD = True
    CODEC_NAME = "zstd"

    def compress(data: bytes, level: int = 3) -> bytes:
        return _zstd.ZstdCompressor(level=level).compress(data)

    def decompress(blob: bytes, max_output_size: int = 0) -> bytes:
        return _zstd.ZstdDecompressor().decompress(
            blob, max_output_size=max_output_size
        )

except ModuleNotFoundError:
    import zlib as _zlib

    HAVE_ZSTD = False
    CODEC_NAME = "zlib"

    def compress(data: bytes, level: int = 3) -> bytes:
        # zstd levels go to 22; clamp into zlib's 0..9 range
        return _zlib.compress(data, min(level, 9))

    def decompress(blob: bytes, max_output_size: int = 0) -> bytes:
        if max_output_size:
            out = _zlib.decompressobj().decompress(blob, max_output_size)
        else:
            out = _zlib.decompress(blob)
        return out
