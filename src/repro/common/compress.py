"""Byte-level entropy stage: zstd when available, stdlib zlib fallback.

``zstandard`` is an optional dependency (the paper's own entropy coder); on
hosts without it the archival/checkpoint paths degrade to zlib rather than
failing at import.  Within one host the choice is deterministic, so blobs
written by ``compress`` always round-trip through ``decompress``.

``compress_as`` / ``decompress_as`` take the codec *by name* for callers
that persist it (archive manifests, checkpoint metadata): zlib is stdlib
and therefore always readable/writable, zstd only when the module exists —
so a blob recorded as "zlib" stays decodable on every host, including ones
that prefer zstd.
"""

from __future__ import annotations

import zlib as _zlib

__all__ = [
    "HAVE_ZSTD",
    "CODEC_NAME",
    "compress",
    "decompress",
    "compress_as",
    "decompress_as",
]


def _zlib_compress(data: bytes, level: int = 3) -> bytes:
    # zstd levels go to 22; clamp into zlib's 0..9 range
    return _zlib.compress(data, min(level, 9))


def _zlib_decompress(blob: bytes, max_output_size: int = 0) -> bytes:
    if max_output_size:
        return _zlib.decompressobj().decompress(blob, max_output_size)
    return _zlib.decompress(blob)


try:
    import zstandard as _zstd

    HAVE_ZSTD = True
    CODEC_NAME = "zstd"

    def compress(data: bytes, level: int = 3) -> bytes:
        return _zstd.ZstdCompressor(level=level).compress(data)

    def decompress(blob: bytes, max_output_size: int = 0) -> bytes:
        return _zstd.ZstdDecompressor().decompress(
            blob, max_output_size=max_output_size
        )

except ImportError:  # also ModuleNotFoundError; lets tests block the import
    HAVE_ZSTD = False
    CODEC_NAME = "zlib"
    compress = _zlib_compress
    decompress = _zlib_decompress


def _dispatch(name: str):
    if name == "zlib":
        return _zlib_compress, _zlib_decompress
    if name == "zstd":
        if not HAVE_ZSTD:
            raise ValueError(
                "codec 'zstd' requires the zstandard module "
                "(install zstandard, or use 'zlib')"
            )
        return compress, decompress
    raise ValueError(f"unknown host entropy codec {name!r}")


def compress_as(name: str, data: bytes, level: int = 3) -> bytes:
    """Compress with the codec *named* ``name`` (not the host preference)."""
    return _dispatch(name)[0](data, level)


def decompress_as(name: str, blob: bytes, max_output_size: int = 0) -> bytes:
    """Decompress a blob recorded as written by codec ``name``."""
    return _dispatch(name)[1](blob, max_output_size)
