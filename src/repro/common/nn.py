"""Minimal parameterized NN primitives shared by the codec and model zoo.

Plain pytrees of arrays + pure functions (no flax/haiku dependency): params are
nested dicts, apply functions are jit/pjit-friendly and shard_map-safe.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "init_conv",
    "conv2d",
    "init_conv_transpose",
    "conv2d_transpose",
    "init_dense",
    "dense",
    "layer_norm",
    "rms_norm",
]

_DN = ("NHWC", "HWIO", "NHWC")


def init_conv(key, kh, kw, cin, cout, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(kh * kw * cin)
    wk, bk = jax.random.split(key)
    return {
        "w": jax.random.uniform(wk, (kh, kw, cin, cout), dtype, -scale, scale),
        "b": jnp.zeros((cout,), dtype),
    }


def conv2d(params, x, stride=1, padding="SAME", feature_group_count=1):
    s = (stride, stride) if isinstance(stride, int) else stride
    y = jax.lax.conv_general_dilated(
        x,
        params["w"].astype(x.dtype),
        window_strides=s,
        padding=padding,
        dimension_numbers=_DN,
        feature_group_count=feature_group_count,
    )
    return y + params["b"].astype(x.dtype)


def init_conv_transpose(key, kh, kw, cin, cout, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(kh * kw * cin)
    wk, bk = jax.random.split(key)
    return {
        "w": jax.random.uniform(wk, (kh, kw, cin, cout), dtype, -scale, scale),
        "b": jnp.zeros((cout,), dtype),
    }


def conv2d_transpose(params, x, stride=2, padding="SAME"):
    s = (stride, stride) if isinstance(stride, int) else stride
    y = jax.lax.conv_transpose(
        x,
        params["w"].astype(x.dtype),
        strides=s,
        padding=padding,
        dimension_numbers=_DN,
    )
    return y + params["b"].astype(x.dtype)


def init_dense(key, din, dout, dtype=jnp.float32, bias=True):
    scale = 1.0 / math.sqrt(din)
    p = {"w": jax.random.uniform(key, (din, dout), dtype, -scale, scale)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype)
    return p


def dense(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def layer_norm(x, gamma=None, beta=None, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if gamma is not None:
        y = y * gamma.astype(x.dtype)
    if beta is not None:
        y = y + beta.astype(x.dtype)
    return y


def rms_norm(x, gamma, eps=1e-6):
    # reduce in f32 for stability regardless of activation dtype
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * gamma.astype(jnp.float32)).astype(x.dtype)
