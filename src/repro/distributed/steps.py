"""Train / prefill / serve steps — the functions the launcher jits and the
dry-run lowers for every (arch x shape x mesh) cell.

``train_step``: microbatched fwd+bwd (remat'd scan-over-layers, chunked
cross-entropy so (B, L, V) logits never materialize) + AdamW.  ``serve_step``:
one-token decode against preallocated KV/SSM caches.  Sharding enters only
via ``shard_fn`` and the in/out shardings the caller attaches at jit time.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, forward, output_head
from repro.train.optimizer import AdamWConfig, AdamWState, adamw_update

__all__ = ["StepConfig", "loss_fn", "train_step", "prefill_step", "serve_step"]


class StepConfig(NamedTuple):
    remat: bool = True
    q_chunk: int = 512
    n_microbatch: int = 1
    ce_chunk: int = 512
    aux_weight: float = 0.01
    opt: AdamWConfig = AdamWConfig(lr=3e-4, grad_clip=1.0)
    grad_accum_dtype: str = "float32"  # bf16 for the 100B+ archs (policy):
    # the f32 microbatch accumulator alone is 2 bytes/param of extra HBM
    int8_gather: bool = False  # int8-compressed FSDP weight gathers (§Perf #2)
    flash_attn: bool = True  # online-softmax attention (§Perf #1; see policy)
    unroll: bool = False  # unroll all scans: used by the costing lowering so
    # HLO cost analysis counts every loop iteration (XLA counts bodies once)


def _chunked_ce(hidden, head, labels, chunk: int, unroll: bool = False):
    """Mean token cross-entropy, scanning over sequence chunks.

    hidden (B, L, d), head (d, V), labels (B, L) -> scalar f32.  Each chunk's
    logits live only inside the (checkpointed) scan body.
    """
    B, L, d = hidden.shape
    chunk = min(chunk, L)
    if L % chunk:
        chunk = L  # fallback: single chunk
    n = L // chunk
    hs = jnp.moveaxis(hidden.reshape(B, n, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    def body(acc, xs):
        h, lab = xs
        logits = (h @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return acc + (lse - gold).sum(), None

    tot, _ = jax.lax.scan(
        jax.checkpoint(body), jnp.zeros((), jnp.float32), (hs, ls), unroll=unroll
    )
    return tot / (B * L)


def loss_fn(params, cfg: ModelConfig, scfg: StepConfig, tokens, labels,
            frontend=None, shard_fn=lambda x, k: x):
    hidden, aux = forward(
        params,
        cfg,
        tokens,
        frontend,
        q_chunk=scfg.q_chunk,
        shard_fn=shard_fn,
        remat=scfg.remat,
        return_hidden=True,
        unroll=scfg.unroll,
        int8_gather=scfg.int8_gather,
        flash=scfg.flash_attn,
    )
    head = output_head(params, cfg)
    ce = _chunked_ce(hidden, head, labels, scfg.ce_chunk, scfg.unroll)
    return ce + scfg.aux_weight * aux, {"ce": ce, "aux": aux}


def train_step(
    params,
    opt_state: AdamWState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ModelConfig,
    scfg: StepConfig,
    shard_fn=lambda x, k: x,
):
    """batch: {'tokens' (B, L), 'labels' (B, L)[, 'frontend']}.

    Microbatching: the global batch is split along B and scanned, averaging
    gradients — bounds activation memory for the 100B+ archs.
    """
    tokens, labels = batch["tokens"], batch["labels"]
    frontend = batch.get("frontend")
    mb = scfg.n_microbatch
    B = tokens.shape[0]
    if mb > 1 and B % mb == 0:
        def one(mtok, mlab, mfe):
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, scfg, mtok, mlab, mfe, shard_fn
            )
            return l, m, g

        toks = tokens.reshape(mb, B // mb, -1)
        labs = labels.reshape(mb, B // mb, -1)
        fes = (
            frontend.reshape(mb, B // mb, *frontend.shape[1:])
            if frontend is not None
            else None
        )

        def body(acc, xs):
            l_acc, g_acc = acc
            if fes is None:
                mtok, mlab = xs
                mfe = None
            else:
                mtok, mlab, mfe = xs
            l, m, g = one(mtok, mlab, mfe)
            g_acc = jax.tree.map(lambda a, b: (a + b.astype(a.dtype)), g_acc, g)
            return (l_acc + l, g_acc), m

        acc_dt = (
            jnp.bfloat16 if scfg.grad_accum_dtype == "bfloat16" else jnp.float32
        )
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        xs = (toks, labs) if fes is None else (toks, labs, fes)
        (loss_sum, grads), metrics = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_g), xs, unroll=scfg.unroll
        )
        loss = loss_sum / mb
        grads = jax.tree.map(lambda g: g / mb, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
    else:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, scfg, tokens, labels, frontend, shard_fn
        )
    # pin the data-parallel gradient reduction HERE, in the grads' own dtype
    # (bf16): without the barrier GSPMD defers it past the optimizer's f32
    # casts and reduces 2x the bytes (measured: all collectives were f32)
    grads = jax.lax.optimization_barrier(grads)
    new_params, new_opt = adamw_update(params, grads, opt_state, scfg.opt)
    metrics = dict(metrics, loss=loss)
    return new_params, new_opt, metrics


def prefill_step(
    params,
    tokens,
    frontend=None,
    *,
    cfg: ModelConfig,
    scfg: StepConfig,
    shard_fn=lambda x, k: x,
):
    """Full-sequence forward (inference prefill); returns last-token logits."""
    hidden, _ = forward(
        params,
        cfg,
        tokens,
        frontend,
        q_chunk=scfg.q_chunk,
        shard_fn=shard_fn,
        remat=False,
        return_hidden=True,
        unroll=scfg.unroll,
        flash=scfg.flash_attn,
    )
    head = output_head(params, cfg)
    return hidden[:, -1, :] @ head


def serve_step(
    params,
    token,
    cache,
    pos,
    *,
    cfg: ModelConfig,
    shard_fn=lambda x, k: x,
    unroll: bool = False,
):
    """One decode step: (B, 1) token + caches at seq_len -> next logits."""
    return decode_step(params, cfg, token, cache, pos, shard_fn=shard_fn, unroll=unroll)
