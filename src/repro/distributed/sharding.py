"""NamedSharding rules: DP / TP / EP / SP / FSDP over the production mesh.

Axis roles (see DESIGN.md §5):
  * ``data`` (x ``pod``)  — batch DP; FSDP/ZeRO weight+optimizer sharding for
    the big archs; the *storage-shard* axis for the archival layer; KV-cache
    batch or sequence sharding for decode shapes.
  * ``model``             — TP (attention heads / FFN hidden / vocab),
    EP (MoE experts), SP (residual-stream sequence sharding — this is what
    bounds scan-carry activation memory for the 88-layer models).

Specs are derived from parameter *path names*, with divisibility guards: an
axis is only assigned to a dim it divides, so the same rules serve every arch
and both meshes.  GSPMD/pjit guarantees correctness regardless of the specs;
these choose the layout.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = [
    "data_axes",
    "param_pspecs",
    "param_shardings",
    "make_shard_fn",
    "batch_pspecs",
    "cache_pspecs",
    "tree_shardings",
]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _axsize(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh: Mesh, spec_entries, shape) -> P:
    """Drop axes that don't divide their dim (keeps layouts clean/even)."""
    out = []
    for dim, ax in zip(shape, spec_entries):
        if ax is None:
            out.append(None)
        elif dim % _axsize(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


# ----------------------------------------------------------------- params
def _param_rule(path: str, shape, mesh: Mesh, fsdp: bool):
    """path: '/'-joined key names; shapes may carry a leading n_super dim."""
    name = path.split("/")[-1]
    nd = len(shape)
    fs = "data" if fsdp else None
    in_moe = "/moe/" in path or path.endswith("moe")

    def pad(*tail):  # fill leading dims (stack dims) with None
        return [None] * (nd - len(tail)) + list(tail)

    if name == "embed":
        return _fit(mesh, ["model", fs], shape)
    if name == "lm_head":
        return _fit(mesh, [fs, "model"], shape)
    if name == "frontend_proj":
        return _fit(mesh, [None, "model"], shape)
    if name in ("wq", "wk", "wv"):
        return _fit(mesh, pad(fs, "model"), shape)
    if name == "wo":
        return _fit(mesh, pad("model", fs), shape)
    if name in ("w_in", "w_gate"):
        if in_moe:  # (S, E, d, f): EP on experts, FSDP on d
            return _fit(mesh, pad("model", fs, None), shape)
        return _fit(mesh, pad(fs, "model"), shape)
    if name == "w_out":
        if in_moe:  # (S, E, f, d)
            return _fit(mesh, pad("model", fs, None), shape)
        return _fit(mesh, pad("model", fs), shape)
    if name == "router":
        return P(*([None] * nd))
    if name == "in_proj":  # (S, d, proj_out)
        return _fit(mesh, pad(fs, "model"), shape)
    if name == "out_proj":  # (S, d_in, d)
        return _fit(mesh, pad("model", fs), shape)
    if name in ("conv_w",):  # (S, K, ch)
        return _fit(mesh, pad(None, "model"), shape)
    if name in ("conv_b", "norm_g", "bq", "bk", "bv"):
        return _fit(mesh, pad("model"), shape)
    if name in ("A_log", "dt_bias", "D"):
        return _fit(mesh, pad("model"), shape)
    # norms and everything else: replicated
    return P(*([None] * nd))


def param_pspecs(params, mesh: Mesh, fsdp: bool = False, tp: bool = True):
    def rule(path, leaf):
        if not tp:  # pure-DP: replicate weights (sub-2B models)
            return P(*([None] * len(leaf.shape)))
        keys = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        return _param_rule(keys, leaf.shape, mesh, fsdp)

    return jax.tree_util.tree_map_with_path(rule, params)


def tree_shardings(pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_shardings(params, mesh: Mesh, fsdp: bool = False):
    return tree_shardings(param_pspecs(params, mesh, fsdp), mesh)


# ------------------------------------------------------------- activations
def make_shard_fn(mesh: Mesh, seq_shard: bool = True, tp: bool = True):
    """Activation constrainer passed into the model as ``shard_fn``."""
    da = data_axes(mesh) if tp else data_axes(mesh) + ("model",)

    def shard_fn(x, kind: str):
        if kind == "moe_tokens" and x.ndim == 2:
            T = x.shape[0]
            ba = da if T % _axsize(mesh, da) == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(ba, None))
            )
        if kind == "moe_buf" and x.ndim == 3:
            E, C, _ = x.shape
            ea = "model" if E % _axsize(mesh, "model") == 0 else None
            ca = da if C % _axsize(mesh, da) == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(ea, ca, None))
            )
        if x.ndim != 3:
            return x
        B, L, _ = x.shape
        if kind == "resid":
            ba = da if B % _axsize(mesh, da) == 0 else None
            sa = (
                "model"
                if seq_shard and L > 1 and L % _axsize(mesh, "model") == 0
                else None
            )
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(ba, sa, None)))
        if kind == "logits":
            ba = da if B % _axsize(mesh, da) == 0 else None
            va = "model" if tp else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(ba, None, va))
            )
        return x

    shard_fn.mesh = mesh  # lets layers (MoE) opt into shard_map dispatch
    return shard_fn


# ------------------------------------------------------------------ inputs
def batch_pspecs(mesh: Mesh, tp: bool = True):
    """tokens/labels (B, L); frontend (B, S, F)."""
    da = data_axes(mesh) if tp else data_axes(mesh) + ("model",)
    return {
        "tokens": P(da, None),
        "labels": P(da, None),
        "frontend": P(da, None, None),
    }


# ------------------------------------------------------------------- cache
def cache_pspecs(cache, mesh: Mesh, batch: int, seq_len: int):
    """Decode caches: shard batch over data when possible; otherwise (long-
    context, batch=1) shard the KV sequence dim over every available axis."""
    da = data_axes(mesh)
    batch_ok = batch % _axsize(mesh, da) == 0 and batch >= _axsize(mesh, da)

    def rule(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        nd = len(shape)
        if "kv" in keys or name in ("k", "v"):  # (S_sup, B, S, KV, hd) or (B, S, KV, hd)
            lead = [None] * (nd - 4)
            if batch_ok:
                return _fit(mesh, lead + [da, "model", None, None], shape)
            return _fit(mesh, lead + [None, da + ("model",), None, None], shape)
        if "ssm" in keys and name in ("conv",):  # (..., B, K-1, ch)
            lead = [None] * (nd - 3)
            return _fit(mesh, lead + [da if batch_ok else None, None, "model"], shape)
        if "ssm" in keys and name in ("state",):  # (..., B, H, P, N)
            lead = [None] * (nd - 4)
            return _fit(
                mesh, lead + [da if batch_ok else None, "model", None, None], shape
            )
        if "cross_kv" in keys:  # (S_sup, B, S_src, KV, hd)
            lead = [None] * (nd - 4)
            return _fit(mesh, lead + [da if batch_ok else None, None, None, None], shape)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, cache)
