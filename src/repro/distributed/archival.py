"""Sharded archival: the fused seal kernel distributed over the storage mesh.

Sharded archival (mesh axis <-> CSD array):

Salient Store's headline wins come from running compression/encryption/
parity *where the shards live*, in parallel across the CSD array, so only
parity-sized traffic crosses the interconnect.  On the TPU adaptation the
``data`` mesh axis is the designated storage-shard axis (see
``distributed/sharding.py``): mesh shard d owns stripe shards
``s % D == d``-style contiguous slices, exactly as CSD d owns its disks'
stripes in the paper.  ``seal_stripe_sharded`` shard_maps the fused Pallas
seal kernel (``repro.kernels.seal``) over that axis:

  * each mesh shard runs ONE local kernel launch over its (S/D, R, 512)
    slice of the stripe — pack + ChaCha20 + XOR-seal + local partial
    RAID-5 P / RAID-6 Q;
  * the only cross-shard communication is an XOR reduce of the partial
    parities (``_xor_allreduce``).  XOR is exact, associative and
    commutative, so the reduce order cannot change bits: sharded outputs
    are bit-identical to the single-device ``seal_stripe`` for every mesh
    shape.  (GF(256) Q coefficients g^s ride in as per-shard operands
    carrying the *global* shard index, so Q partials are globally correct
    before the reduce.)

``entropy_seal_sharded`` is the one-launch twin: the FUSED entropy+seal
kernel (``repro.kernels.fused`` — rANS + pack + raw-skip + ChaCha20 +
parity in a single launch, K stripes batched per launch) shard_maps the
same way, so the rans write path needs exactly one local launch per mesh
shard per stripe batch, with the identical parity-reduce story.  The
chained ``seal_stripe_sharded`` / ``entropy_encode_sharded`` pair stays
the decode-side and host-codec path.

Multi-stream ingest coalescing:

Continuous-learning edge servers batch retraining data from many cameras;
GOPs arrive ragged and one-at-a-time, and sealing each alone wastes the
stripe-wide kernel (one launch per GOP, parity over a single shard).
``StripeCoalescer`` buckets incoming GOPs by pow2-padded stripe height and
emits full S-shard stripes, so N streams' small GOPs amortize into one
fused launch per mesh shard, and the jit trace count stays bounded at
log2(max_rows) regardless of the GOP-size mix.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, PartitionSpec as P

try:  # JAX >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map_raw
except ImportError:  # pragma: no cover - older JAX
    from jax.experimental.shard_map import shard_map as _shard_map_raw


def _shard_map(f, *, mesh, in_specs, out_specs):
    """shard_map with the replication check off, across jax versions
    (``check_vma`` on >= 0.6, ``check_rep`` before)."""
    try:
        return _shard_map_raw(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    except TypeError:
        return _shard_map_raw(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )

from repro.core.archival.pipeline import (
    ArchiveConfig,
    PendingStripeSeal,
    StripeArchive,
    archive_stripe,
    restore_stripe,
    seal_payload_stripe,
    seal_payload_stripes,
    seal_payload_stripes_dispatch,
    seal_payload_stripes_finalize,
)
from repro.core.crypto import rlwe
from repro.kernels import use_interpret
from repro.kernels.entropy import ops as entropy_ops
from repro.kernels.entropy.rans import PROB_SCALE
from repro.kernels.fused import ops as fused_ops
from repro.kernels.fused import ref as fused_ref
from repro.kernels.fused.entropy_seal import entropy_seal_pallas
from repro.kernels.seal import ops as seal_ops
from repro.kernels.seal import ref as _ref
from repro.obs import (
    EDGE_REBUILD_READ,
    EDGE_REBUILD_WRITE,
    Metrics,
    OBS,
)
from repro.obs import names as obs_names
from repro.kernels.seal.ops import SealedStripe
from repro.kernels.seal.seal import (
    seal_stripe_pallas,
    unseal_stripe_pallas,
)

__all__ = [
    "seal_stripe_sharded",
    "unseal_stripe_sharded",
    "entropy_encode_sharded",
    "entropy_decode_sharded",
    "entropy_seal_sharded",
    "archive_stripe_sharded",
    "restore_stripe_sharded",
    "PendingGOP",
    "CoalescedStripe",
    "StripeCoalescer",
    "seal_coalesced_stripe",
    "seal_coalesced_stripes",
    "seal_coalesced_stripes_dispatch",
    "seal_coalesced_stripes_finalize",
    "RebuildItem",
    "RebuildRound",
    "plan_rebuild",
    "rebuild_csd_sharded",
]


# ------------------------------------------------------------ sharded seal
def _xor_allreduce(x: jax.Array, axis: str, D: int) -> jax.Array:
    """Cross-shard XOR reduce (the RAID-parity analogue of ``psum``).

    ``psum`` adds, which is wrong for GF(2) parity; gather + fold keeps the
    reduction exact.  D is static (mesh size) so the fold unrolls.
    """
    if D == 1:
        return x
    g = jax.lax.all_gather(x, axis)  # (D, R, LANES) on every shard
    acc = g[0]
    for i in range(1, D):
        acc = acc ^ g[i]
    return acc


@functools.lru_cache(maxsize=None)
def _sharded_core(mesh: Mesh, axis: str, parity: str, unseal: bool,
                  use_pallas: bool, interpret: bool):
    """jit'd shard_map'd seal/unseal core, cached per (mesh, mode).

    Inputs arrive stacked over the full stripe (S_pad divisible by the mesh
    axis); each mesh shard sees its local (S_loc, ...) slice and runs the
    fused kernel exactly once — launches/stripe/device = 1.
    """
    D = int(mesh.shape[axis])
    with_p = parity != "none"
    with_q = parity == "raid6"

    def local_fn(payload, keys, nonces, n_valid, q_coef):
        if use_pallas:
            fn = unseal_stripe_pallas if unseal else seal_stripe_pallas
            out, p, q = fn(payload, keys, nonces, n_valid, q_coef,
                           parity=parity, interpret=interpret)
        else:
            fn = _ref.unseal_stripe_ref if unseal else _ref.seal_stripe_ref
            out, p, q = fn(payload, keys, nonces, n_valid, q_coef,
                           parity=parity)
        outs = [out]
        if with_p:
            outs.append(_xor_allreduce(p, axis, D))
        if with_q:
            outs.append(_xor_allreduce(q, axis, D))
        return tuple(outs)

    n_extra = int(with_p) + int(with_q)
    fn = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis),) + (P(),) * n_extra,
    )
    return jax.jit(fn)


def _pad_shard_axis(arr: jax.Array, s_pad: int) -> jax.Array:
    if arr.shape[0] == s_pad:
        return arr
    pad = [(0, s_pad - arr.shape[0])] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, pad)


def seal_stripe_sharded(payloads, keys, nonces, *, mesh: Mesh,
                        axis: str = "data", parity: str = "raid6",
                        use_pallas: bool = True,
                        interpret: Optional[bool] = None,
                        pad_rows: Optional[int] = None) -> SealedStripe:
    """``seal_ops.seal_stripe`` with the shard axis partitioned over ``mesh``.

    Same inputs/outputs as the single-device wrapper; each mesh shard on
    ``axis`` seals its local slice in one fused launch and partial parities
    are XOR-combined across shards.  Stripes whose shard count does not
    divide the mesh axis are padded with zero-length dummy shards
    (``n_valid = 0`` masks them to zero, so they cannot perturb parity).
    """
    flats = seal_ops._as_payload_list(payloads)
    codes, n_words, n_i8 = seal_ops._stack_padded(flats, pad_rows)
    meta = seal_ops._meta_arrays(keys, nonces, n_words)
    S = len(n_words)
    D = int(mesh.shape[axis])
    s_pad = -(-S // D) * D
    args = [_pad_shard_axis(a, s_pad) for a in (codes, *meta)]
    core = _sharded_core(
        mesh, axis, parity, False, use_pallas, use_interpret(interpret)
    )
    outs = core(*args)
    sealed = outs[0][:S]
    p = outs[1] if parity != "none" else None
    q = outs[2] if parity == "raid6" else None
    return SealedStripe(sealed, p, q, n_words, n_i8)


def unseal_stripe_sharded(stripe: SealedStripe, keys, nonces, *, mesh: Mesh,
                          axis: str = "data", parity: str = "raid6",
                          use_pallas: bool = True,
                          interpret: Optional[bool] = None,
                          shard_ids: Optional[Tuple[int, ...]] = None):
    """Sharded twin of ``seal_ops.unseal_stripe`` (same outputs).

    Parity is recomputed from the stored bodies per mesh shard and
    XOR-reduced, so the integrity check covers the whole stripe while each
    device only reads its own slice.  ``shard_ids`` carries global stripe
    shard indices for subset reads (a retrieval plan's shards land on the
    mesh devices that own them; the rest of the stripe never moves).
    """
    if not stripe.n_words:
        raise ValueError("stripe must contain at least one shard payload")
    meta = seal_ops._meta_arrays(keys, nonces, stripe.n_words, shard_ids)
    S = stripe.sealed.shape[0]
    D = int(mesh.shape[axis])
    s_pad = -(-S // D) * D
    args = [_pad_shard_axis(a, s_pad) for a in (stripe.sealed, *meta)]
    core = _sharded_core(
        mesh, axis, parity, True, use_pallas, use_interpret(interpret)
    )
    outs = core(*args)
    codes = outs[0][:S]
    p = outs[1] if parity != "none" else None
    q = outs[2] if parity == "raid6" else None
    flats = [
        codes[s].reshape(-1)[: stripe.n_i8[s]] for s in range(S)
    ]
    return flats, p, q


# --------------------------------------------- sharded one-launch archival
@functools.lru_cache(maxsize=None)
def _sharded_fused_core(mesh: Mesh, axis: str, s_loc: int, parity: str,
                        use_pallas: bool, interpret: bool, division: str):
    """jit'd shard_map'd one-launch entropy+seal core, cached per (mesh,
    local shard count, mode).

    Inputs arrive regrouped as (K, S_pad, ...) — stripes on axis 0, stripe
    shards on axis 1, the SHARD axis partitioned over the mesh (the CSD-
    array mapping: mesh shard d compresses and seals the stripe shards it
    owns).  Each mesh shard flattens its local (K, s_loc, ...) slice back
    onto the kernel batch axis and runs the fused entropy+seal kernel
    exactly ONCE — launches/stripe-batch/device = 1 covering rANS + pack +
    raw-skip + ChaCha20 + local partial P/Q.  The only cross-shard traffic
    is the XOR reduce of the per-stripe parity partials (exact, order-free
    — bit-identical to the single-device launch); GF(256) Q coefficients
    ride in as operands carrying the *global* shard index, so Q partials
    are globally correct before the reduce.
    """
    D = int(mesh.shape[axis])
    with_p = parity != "none"
    with_q = parity == "raid6"

    def local_fn(codes, n_valid, keys, nonces, q_coef):
        K = codes.shape[0]

        def flat(a):
            return a.reshape((K * s_loc,) + a.shape[2:])

        fn = entropy_seal_pallas if use_pallas else fused_ref.entropy_seal_ref
        kw = {"interpret": interpret} if use_pallas else {}
        sealed, nw, p, q = fn(
            flat(codes), flat(n_valid), flat(keys), flat(nonces),
            flat(q_coef), n_shards=s_loc, parity=parity, division=division,
            **kw,
        )
        outs = [
            sealed.reshape((K, s_loc) + sealed.shape[1:]),
            nw.reshape(K, s_loc, 1),
        ]
        if with_p:
            outs.append(_xor_allreduce(p, axis, D))
        if with_q:
            outs.append(_xor_allreduce(q, axis, D))
        return tuple(outs)

    n_extra = int(with_p) + int(with_q)
    fn = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(None, axis),) * 5,
        out_specs=(P(None, axis), P(None, axis)) + (P(),) * n_extra,
    )
    return jax.jit(fn)


def entropy_seal_sharded(codes, n_valid, keys, nonces, q_coef, *,
                         mesh: Mesh, axis: str = "data", n_shards: int,
                         parity: str = "raid6", use_pallas: bool = True,
                         interpret: Optional[bool] = None,
                         division: str = "divide"):
    """Sharded twin of the fused one-launch core (same array outputs).

    Drop-in ``core_fn`` for ``fused_ops.entropy_seal_stripes`` (bake
    ``mesh``/``axis`` with ``functools.partial``; the batching layer
    supplies the remaining static config as keyword arguments).  Stripe
    shard counts that do not divide the mesh axis are padded with dummy
    zero shards — ``n_valid = 0`` raw-skips them to zero stored bytes, so
    sealed rows and parity partials are unperturbed.
    """
    B = codes.shape[0]
    K = B // n_shards
    D = int(mesh.shape[axis])
    s_pad = -(-n_shards // D) * D

    def regroup(a):
        a = a.reshape((K, n_shards) + a.shape[1:])
        if s_pad == n_shards:
            return a
        pad = [(0, 0), (0, s_pad - n_shards)] + [(0, 0)] * (a.ndim - 2)
        return jnp.pad(a, pad)

    core = _sharded_fused_core(
        mesh, axis, s_pad // D, parity, use_pallas,
        use_interpret(interpret), division,
    )
    outs = core(*(regroup(a) for a in (codes, n_valid, keys, nonces, q_coef)))
    sealed = outs[0][:, :n_shards].reshape((B,) + outs[0].shape[2:])
    n_words = outs[1][:, :n_shards].reshape(B, 1)
    i = 2
    p = q = None
    if parity != "none":
        p = outs[i]
        i += 1
    if parity == "raid6":
        q = outs[i]
    return sealed, n_words, p, q


def _sharded_fused_fn(mesh: Mesh, axis: str):
    """The ``fused_fn`` seam value: the fused batching layer with its
    kernel launch shard_map'd over ``mesh`` (see ``entropy_seal_sharded``)."""
    return functools.partial(
        fused_ops.entropy_seal_stripes,
        core_fn=functools.partial(entropy_seal_sharded, mesh=mesh, axis=axis),
    )


def _sharded_fused_dispatch_fn(mesh: Mesh, axis: str):
    """``_sharded_fused_fn``'s async twin — the ``fused_dispatch_fn`` seam
    value for the pipelined submit ring (dispatch only, no device sync)."""
    return functools.partial(
        fused_ops.entropy_seal_stripes_dispatch,
        core_fn=functools.partial(entropy_seal_sharded, mesh=mesh, axis=axis),
    )


# --------------------------------------------------- sharded entropy stage
@functools.lru_cache(maxsize=None)
def _sharded_entropy_core(mesh: Mesh, axis: str, decode: bool,
                          use_pallas: bool, interpret: bool,
                          version: int = 0, rows: int = 0):
    """jit'd shard_map'd rANS core, cached per (mesh, mode, stream version).

    The coder has no cross-shard term at all — each mesh shard runs the
    fused histogram+table+scan kernel on its local slice of the stripe
    (launches/stripe/device = 1), which is exactly the paper's per-CSD
    compression: only the seal stage's parity reduce ever crosses shards.
    ``version``/``rows`` (pow2-bucketed, so the cache stays bounded) pick
    the decode twin: row-major streams for version 1, the PR-4 lane-major
    layout for version 0.
    """

    def local_encode(codes, n_valid):
        return entropy_ops._encode_core(
            codes, n_valid, use_pallas=use_pallas, interpret=interpret
        )

    def local_decode(words, freq, states, n_valid):
        return entropy_ops._decode_core(
            words, freq, states, n_valid, version=version, rows=rows,
            use_pallas=use_pallas, interpret=interpret,
        )

    if decode:
        fn = _shard_map(
            local_decode, mesh=mesh,
            in_specs=(P(axis),) * 4, out_specs=P(axis),
        )
    else:
        fn = _shard_map(
            local_encode, mesh=mesh,
            in_specs=(P(axis), P(axis)), out_specs=(P(axis),) * 4,
        )
    return jax.jit(fn)


def entropy_encode_sharded(payloads, *, mesh: Mesh, axis: str = "data",
                           use_pallas: bool = True,
                           interpret: Optional[bool] = None):
    """``entropy_ops.encode_payloads`` with the coder shard_map'd over
    ``mesh`` — same streams/metas bit-for-bit for every mesh shape (dummy
    zero-length shards pad non-divisible stripes; ``n_valid = 0`` idles
    their lanes so they emit nothing)."""
    D = int(mesh.shape[axis])
    core = _sharded_entropy_core(
        mesh, axis, False, use_pallas, use_interpret(interpret)
    )

    def core_fn(codes, n_valid):
        S = codes.shape[0]
        s_pad = -(-S // D) * D
        outs = core(
            _pad_shard_axis(codes, s_pad), _pad_shard_axis(n_valid, s_pad)
        )
        return tuple(o[:S] for o in outs)

    return entropy_ops.encode_payloads(
        payloads, use_pallas=use_pallas, core_fn=core_fn
    )


def entropy_decode_sharded(comps, metas, *, mesh: Mesh, axis: str = "data",
                           use_pallas: bool = True,
                           interpret: Optional[bool] = None):
    """Sharded twin of ``entropy_ops.decode_payloads`` (same outputs),
    for both stream versions (the per-mesh-shard twin is picked from the
    recorded ``version`` exactly like the single-device dispatch)."""
    D = int(mesh.shape[axis])
    # dummy shards decode against a degenerate-but-valid table (symbol 0
    # owns the whole range) so padded lanes cannot divide by zero or gather
    # out of range; n_valid = 0 masks their output anyway
    dummy_freq = jnp.zeros((256,), jnp.int32).at[0].set(PROB_SCALE)

    def core_fn(words, freq, states, n_valid, *, version: int, rows: int):
        core = _sharded_entropy_core(
            mesh, axis, True, use_pallas, use_interpret(interpret),
            version, rows,
        )
        S = words.shape[0]
        s_pad = -(-S // D) * D
        freq_p = jnp.concatenate(
            [freq] + [dummy_freq[None]] * (s_pad - S), axis=0
        ) if s_pad != S else freq
        out = core(
            _pad_shard_axis(words, s_pad),
            freq_p,
            _pad_shard_axis(states, s_pad),
            _pad_shard_axis(n_valid, s_pad),
        )
        return out[:S]

    return entropy_ops.decode_payloads(
        comps, metas, use_pallas=use_pallas, core_fn=core_fn
    )


def archive_stripe_sharded(
    codec_params,
    pub: rlwe.PublicKey,
    frames_list: List[jax.Array],
    key: jax.Array,
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    mesh: Mesh,
    axis: str = "data",
    use_pallas: bool = True,
) -> Tuple[StripeArchive, List[jax.Array]]:
    """``archive_stripe`` with the one-launch entropy+seal kernel
    shard_map'd over ``mesh``: each mesh shard entropy-codes, packs, seals
    and parity-folds its own slice of the stripe (the CSD-array mapping)
    in ONE local launch — codes -> rANS -> pack -> ChaCha20 -> parity with
    only the parity XOR reduce crossing devices.  (Host codecs ride the
    chained sharded seal instead.)

    Outputs (streams, sealed bodies, P, Q, manifests) are bit-identical to
    the single-device ``archive_stripe`` for every mesh shape — the KEM runs
    host-side in the same order, and the sharded launches differ only in
    where each shard's kernel executes.
    """
    return archive_stripe(
        codec_params, pub, frames_list, key, cfg, use_pallas=use_pallas,
        seal_fn=functools.partial(seal_stripe_sharded, mesh=mesh, axis=axis),
        entropy_fn=functools.partial(
            entropy_encode_sharded, mesh=mesh, axis=axis
        ),
        fused_fn=_sharded_fused_fn(mesh, axis),
    )


def restore_stripe_sharded(
    codec_params,
    s: jax.Array,
    stripe: StripeArchive,
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    mesh: Mesh,
    axis: str = "data",
    use_pallas: bool = True,
    verify_parity: bool = True,
    shards: Optional[List[int]] = None,
    manifests: Optional[List[Dict]] = None,
) -> List[jax.Array]:
    """``restore_stripe`` with the unseal + entropy-decode launches
    shard_map'd over ``mesh`` — including shard-subset retrieval reads
    (``shards``) and parity-based degraded reads (``manifests``; see
    ``restore_stripe_payloads``)."""
    return restore_stripe(
        codec_params, s, stripe, cfg, use_pallas=use_pallas,
        verify_parity=verify_parity, shards=shards, manifests=manifests,
        unseal_fn=functools.partial(
            unseal_stripe_sharded, mesh=mesh, axis=axis
        ),
        entropy_decode_fn=functools.partial(
            entropy_decode_sharded, mesh=mesh, axis=axis
        ),
    )


# ------------------------------------------------------ ingest coalescing
class PendingGOP(NamedTuple):
    """One encoded-but-unsealed GOP waiting for stripe-mates."""

    stream_id: int
    payload: jax.Array  # flat int8 codec payload
    manifest: Dict
    meta: Optional[Dict] = None  # caller tag (shard assignment, psnr, ...)


class CoalescedStripe(NamedTuple):
    """S GOPs bucketed into one stripe + the pow2 row bucket to pad to."""

    gops: List[PendingGOP]
    pad_rows: int


class StripeCoalescer:
    """Buckets ragged GOPs from N camera streams into full seal stripes.

    GOPs from interleaved streams are queued by their pow2 row bucket
    (``bucket_rows_for``); whenever a bucket holds ``n_shards`` GOPs they
    are emitted as one :class:`CoalescedStripe` — one fused seal launch per
    mesh shard instead of one launch per GOP.  Bucketing serves two jobs:

      * *trace bound*: the jit'd seal core specializes on the padded stripe
        shape, so pow2 buckets cap traces at log2(max_rows) for arbitrarily
        mixed GOP sizes;
      * *padding bound*: same-bucket GOPs differ by < 2x in padded height,
        so ragged-stripe padding waste stays < 2x worst-case.

    ``flush()`` force-drains leftovers (end of epoch / checkpoint) into
    possibly short stripes so no GOP is ever stranded unsealed;
    ``drain_expired(deadline_us)`` is the straggler-aware variant — it
    drains ONLY the buckets whose oldest GOP has waited past the deadline
    (oldest bucket first), so a cold bucket cannot hold its GOPs hostage
    and p99 GOP-to-commit stays bounded while hot buckets keep batching.

    Accounting lives on a ``repro.obs.Metrics`` registry (pass ``metrics``
    to share one with the owning ingest tier — ``ArchiveIngest`` does, so
    its ``stats()`` and the coalescer's are views of the SAME instruments
    instead of two hand-assembled dicts): ``ingest.gops`` /
    ``ingest.stripes_sealed`` counters plus the ``ingest.pending_gops``
    occupancy gauge.  ``add()`` stamps ``meta["_t_submit"]`` (monotonic ns)
    when the caller didn't, so latency and deadline accounting never need
    the caller's cooperation.
    """

    def __init__(self, n_shards: int, *, metrics: Optional[Metrics] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = n_shards
        self._buckets: Dict[int, List[PendingGOP]] = {}
        self._pending_bytes = 0
        self.metrics = metrics if metrics is not None else Metrics()

    @property
    def n_gops(self) -> int:
        return int(self.metrics.get(obs_names.ING_GOPS))

    @property
    def n_stripes(self) -> int:
        return int(self.metrics.get(obs_names.ING_STRIPES))

    @staticmethod
    def _bucket_of(payload: jax.Array) -> int:
        n_words = -(-int(payload.shape[0]) // 4)
        return seal_ops.bucket_rows_for(n_words)

    def add(self, stream_id: int, payload, manifest: Dict,
            meta: Optional[Dict] = None) -> List[CoalescedStripe]:
        """Queue one GOP; returns the stripes it completed (usually 0 or 1)."""
        payload = jnp.asarray(payload).reshape(-1).astype(jnp.int8)
        meta = dict(meta) if meta else {}
        meta.setdefault("_t_submit", time.perf_counter_ns())
        r = self._bucket_of(payload)
        pending = self._buckets.setdefault(r, [])
        pending.append(PendingGOP(stream_id, payload, manifest, meta))
        self._pending_bytes += int(payload.shape[0])
        self.metrics.add(obs_names.ING_GOPS)
        out: List[CoalescedStripe] = []
        while len(pending) >= self.n_shards:
            out.append(CoalescedStripe(pending[: self.n_shards], r))
            del pending[: self.n_shards]
        return self._emitted(out)

    def _emitted(self, out: List[CoalescedStripe]) -> List[CoalescedStripe]:
        if out:
            self.metrics.add(obs_names.ING_STRIPES, len(out))
            self._pending_bytes -= sum(
                int(g.payload.shape[0]) for cs in out for g in cs.gops
            )
        self.metrics.set_gauge(obs_names.ING_PENDING, self.n_pending)
        return out

    def flush(self) -> List[CoalescedStripe]:
        """Drain leftovers into (possibly short) stripes, largest bucket last.

        Leftovers are grouped smallest-bucket-first so mixed-size stragglers
        pad to the smallest row count covering their group.
        """
        pending = [
            g for r in sorted(self._buckets) for g in self._buckets[r]
        ]
        self._buckets.clear()
        out: List[CoalescedStripe] = []
        for i in range(0, len(pending), self.n_shards):
            group = pending[i : i + self.n_shards]
            rows = max(self._bucket_of(g.payload) for g in group)
            out.append(CoalescedStripe(group, rows))
        return self._emitted(out)

    def drain_expired(self, deadline_us: float,
                      now_ns: Optional[int] = None) -> List[CoalescedStripe]:
        """Force-drain buckets whose OLDEST GOP has waited past the deadline.

        The straggler policy: a bucket that has not filled a stripe within
        ``deadline_us`` of its oldest GOP's submit stamp is drained into a
        (possibly short) stripe rather than holding its GOPs hostage —
        this is what bounds p99 GOP-to-commit on cold buckets.  Expired
        buckets drain oldest-first (and insertion order within a bucket is
        already oldest-first), so the longest-waiting GOPs always land in
        the first emitted stripe.  Fresh buckets are untouched and keep
        batching toward full stripes.
        """
        now = time.perf_counter_ns() if now_ns is None else int(now_ns)
        cutoff = now - int(float(deadline_us) * 1e3)
        aged = []
        for r, pending in self._buckets.items():
            if not pending:  # fully-drained bucket keys linger in the dict
                continue
            t_old = min(
                (g.meta or {}).get("_t_submit", now) for g in pending
            )
            if t_old <= cutoff:
                aged.append((t_old, r))
        if not aged:
            return []
        aged.sort()
        gops = [g for _, r in aged for g in self._buckets.pop(r)]
        out: List[CoalescedStripe] = []
        for i in range(0, len(gops), self.n_shards):
            group = gops[i : i + self.n_shards]
            rows = max(self._bucket_of(g.payload) for g in group)
            out.append(CoalescedStripe(group, rows))
        return self._emitted(out)

    @property
    def n_pending(self) -> int:
        return sum(len(v) for v in self._buckets.values())

    @property
    def queue_bytes(self) -> int:
        """Payload bytes currently queued (running counter, O(1))."""
        return self._pending_bytes

    def oldest_submit_ns(self) -> Optional[int]:
        """Submit stamp of the oldest pending GOP, or None when empty."""
        stamps = [
            (g.meta or {}).get("_t_submit")
            for v in self._buckets.values() for g in v
        ]
        stamps = [s for s in stamps if s is not None]
        return min(stamps) if stamps else None

    def stats(self) -> Dict[str, float]:
        """Launch accounting: naive ingest = one seal launch per GOP.

        A registry view — every value is read back from the shared
        ``Metrics`` instruments, never tracked twice.
        """
        n_gops, n_stripes = self.n_gops, self.n_stripes
        sealed_gops = n_gops - self.n_pending
        return {
            "n_gops": n_gops,
            "n_stripes": n_stripes,
            "n_pending": self.n_pending,
            "launch_reduction": (
                sealed_gops / n_stripes if n_stripes else float("nan")
            ),
        }


def seal_coalesced_stripe(
    pub: rlwe.PublicKey,
    cs: CoalescedStripe,
    key: jax.Array,
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "data",
    use_pallas: bool = True,
) -> StripeArchive:
    """Entropy-code + seal one coalesced stripe (sharded over ``mesh`` when
    given: the fused entropy+seal kernel runs once per mesh shard).

    The bucket's ``pad_rows`` flows into the launch so every stripe from the
    same bucket shares one jit trace (re-bucketed on the compressed sizes
    when an entropy stage runs — see ``seal_payload_stripe``).
    """
    seal_fn = None
    entropy_fn = None
    fused_fn = None
    if mesh is not None:
        seal_fn = functools.partial(seal_stripe_sharded, mesh=mesh, axis=axis)
        entropy_fn = functools.partial(
            entropy_encode_sharded, mesh=mesh, axis=axis
        )
        fused_fn = _sharded_fused_fn(mesh, axis)
    return seal_payload_stripe(
        pub,
        [g.payload for g in cs.gops],
        [g.manifest for g in cs.gops],
        key,
        cfg,
        use_pallas=use_pallas,
        pad_rows=cs.pad_rows,
        seal_fn=seal_fn,
        entropy_fn=entropy_fn,
        fused_fn=fused_fn,
    )


def seal_coalesced_stripes(
    pub: rlwe.PublicKey,
    batch: List[CoalescedStripe],
    keys: List[jax.Array],
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "data",
    use_pallas: bool = True,
) -> List[StripeArchive]:
    """Batched ``seal_coalesced_stripe``: K ready stripes, ONE fused launch
    per homogeneous (shard count, row bucket) group — multi-stream ingest's
    steady state, where a drained coalescer hands over several same-bucket
    stripes at once and per-launch dispatch amortizes K-fold.

    ``keys`` carries one stripe key per batch entry (the caller's sequence
    numbering — e.g. ``ArchiveIngest`` fold_in's its stripe counter), so
    session material is bit-identical to sealing the stripes one at a time.
    Host codecs fall back to per-stripe chained sealing.
    """
    if len(batch) != len(keys):
        raise ValueError(f"{len(batch)} stripes vs {len(keys)} keys")
    if not batch:
        return []
    return seal_coalesced_stripes_finalize(
        seal_coalesced_stripes_dispatch(
            pub, batch, keys, cfg, mesh=mesh, axis=axis,
            use_pallas=use_pallas,
        )
    )


def seal_coalesced_stripes_dispatch(
    pub: rlwe.PublicKey,
    batch: List[CoalescedStripe],
    keys: List[jax.Array],
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "data",
    use_pallas: bool = True,
) -> PendingStripeSeal:
    """Async half of ``seal_coalesced_stripes``: stage + launch the batch
    WITHOUT the device sync (see ``seal_payload_stripes_dispatch``).  The
    two-slot submit ring dispatches batch k+1's host prep between this and
    ``seal_coalesced_stripes_finalize``.  Non-rans codecs have no async
    seam and seal eagerly inside the returned handle.
    """
    if len(batch) != len(keys):
        raise ValueError(f"{len(batch)} stripes vs {len(keys)} keys")
    if not batch:
        return PendingStripeSeal(None, None, [], [], [])
    if cfg.codec_name != "rans":
        archives = [
            seal_coalesced_stripe(
                pub, cs, k, cfg, mesh=mesh, axis=axis, use_pallas=use_pallas
            )
            for cs, k in zip(batch, keys)
        ]
        return PendingStripeSeal(None, None, archives, [], [])
    return seal_payload_stripes_dispatch(
        pub,
        [[g.payload for g in cs.gops] for cs in batch],
        [[g.manifest for g in cs.gops] for cs in batch],
        list(keys),
        cfg,
        use_pallas=use_pallas,
        pad_rows=[cs.pad_rows for cs in batch],
        fused_dispatch_fn=(
            _sharded_fused_dispatch_fn(mesh, axis) if mesh is not None
            else None
        ),
    )


def seal_coalesced_stripes_finalize(
    pending: PendingStripeSeal,
) -> List[StripeArchive]:
    """Blocking half: redeem a dispatched coalesced batch (the single
    device→host fetch + archive assembly + ledger billing)."""
    return seal_payload_stripes_finalize(pending)


# ------------------------------------------------------------- CSD rebuild
class RebuildItem(NamedTuple):
    """One lost shard to reconstruct onto the replacement CSD."""

    stripe_id: str
    shard: int        # stripe shard index the dead CSD owned
    body_bytes: int   # sealed bytes the rebuild writes (the budget unit)
    salience: float   # priority: most-salient stripes come back first


class RebuildRound(NamedTuple):
    rebuilt: List[RebuildItem]    # completed this round, in priority order
    bytes_rebuilt: int            # strictly <= the round's budget
    remaining: List[RebuildItem]  # carry over to the next round


def plan_rebuild(
    catalog,
    dead_csd: int,
    centroids=None,
    *,
    owner_of=None,
) -> List[RebuildItem]:
    """Rebuild work-list for one dead CSD, most-salient stripes first.

    ``owner_of(entry) -> csd`` maps a catalog entry to the device that owns
    its shard; the default is the identity mapping the ingest tiers use
    (stripe shard s lives on CSD s).  Salience is scored against the
    caller's CURRENT ``centroids`` (same scoring as retrieval), so the
    shards replay is most likely to ask for are the first ones back — a
    degraded read window shrinks where it matters most.
    """
    owner_of = owner_of or (lambda e: e.shard)
    entries = catalog.entries
    nov = catalog.score(centroids)
    items = [
        RebuildItem(e.stripe_id, e.shard, e.body_bytes, float(nov[i]))
        for i, e in enumerate(entries)
        if owner_of(e) == dead_csd
    ]
    items.sort(key=lambda it: (-it.salience, it.stripe_id, it.shard))
    return items


def _rebuild_shard_body(
    stripe: StripeArchive,
    shard: int,
    manifests: List[Dict],
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "data",
    use_pallas: bool = True,
):
    """Reconstruct one lost shard's sealed body from parity.

    Single loss rides the shard_mapped parity pass: the surviving bodies go
    through the unseal kernel with zero keys and ``parity="raid5"`` — the
    kernel's P accumulation IS the XOR fold of the survivors (cross-shard
    partials combined by ``_xor_allreduce`` on a mesh), and
    ``lost = P_stored ^ XOR(survivors)``.  Only parity-sized traffic
    crosses devices; bodies stay where they live.  A double loss (another
    shard of the same stripe already missing) falls back to the host
    GF(256) ``recover_stripe`` path.
    """
    from repro.core.archival.pipeline import (
        _u32_rows_to_u8,
        recover_stripe,
    )

    parity = stripe.parity
    if parity is None:
        raise ValueError(f"shard {shard} lost and the stripe has no parity")
    missing = [i for i, b in enumerate(stripe.blocks)
               if b is None or i == shard]
    meta = manifests[shard]
    n_words = int(meta["n_words"])
    if len(missing) > 1:
        blocks = [None if i in missing else b
                  for i, b in enumerate(stripe.blocks)]
        body_lens = [
            int(manifests[i]["n_words"]) if i in missing
            else int(stripe.blocks[i].sealed.n_valid_u32)
            for i in range(len(stripe.blocks))
        ]
        return recover_stripe(
            blocks, parity, missing, manifests, body_lens,
        )[shard]
    pad_to = int(parity["pad_to"])
    R = pad_to // 128
    survivors = [
        (i, b) for i, b in enumerate(stripe.blocks) if i != shard
    ]
    nw = tuple(int(b.sealed.n_valid_u32) for _, b in survivors)
    sealed = jnp.stack(
        [
            jnp.pad(b.sealed.body, (0, pad_to - int(b.sealed.body.shape[0])))
            .reshape(R, 128)
            for _, b in survivors
        ]
    )
    packed = SealedStripe(sealed, None, None, nw, nw)
    S = len(survivors)
    zero_k = jnp.zeros((S, 8), jnp.uint32)
    zero_n = jnp.zeros((S, 3), jnp.uint32)
    if mesh is not None:
        _, p, _ = unseal_stripe_sharded(
            packed, zero_k, zero_n, mesh=mesh, axis=axis, parity="raid5",
            use_pallas=use_pallas,
        )
    else:
        _, p, _ = seal_ops.unseal_stripe(
            packed, zero_k, zero_n, parity="raid5", use_pallas=use_pallas,
        )
    import numpy as np

    from repro.core.crypto.hybrid import SealedBlock
    from repro.core.archival.pipeline import ArchivedBlock

    lost = np.asarray(_u32_rows_to_u8(p)) ^ np.asarray(parity["p"], np.uint8)
    words = jnp.asarray(
        np.ascontiguousarray(lost[: pad_to * 4]).view(np.uint32)[:n_words]
    )
    sealed_blk = SealedBlock(
        meta["kem_c1"], meta["kem_c2"], meta["nonce"], words, n_words
    )
    return ArchivedBlock(sealed_blk, meta["manifest"])


def rebuild_csd_sharded(
    get_stripe,
    manifests_for,
    items: List[RebuildItem],
    *,
    budget_bytes: int,
    put_shard,
    mesh: Optional[Mesh] = None,
    axis: str = "data",
    use_pallas: bool = True,
) -> RebuildRound:
    """One budget-bounded rebuild round onto the replacement CSD.

    Processes ``items`` strictly in order (``plan_rebuild`` already sorted
    by salience) and STOPS at the first item that would overflow
    ``budget_bytes`` — the budget is a hard ceiling, never exceeded, so
    replay traffic keeps its share of the interconnect; skipping ahead to
    smaller items would subvert the salience priority, so the round ends
    instead and ``remaining`` carries over.  ``get_stripe(stripe_id)``
    reads the degraded stripe, ``manifests_for(stripe_id)`` its replicated
    metadata records (``stripe_manifests`` format — the lost shard's KEM
    polys/nonce/length), ``put_shard(stripe_id, shard, block)`` installs
    the reconstructed :class:`ArchivedBlock` on the replacement.
    """
    rebuilt: List[RebuildItem] = []
    remaining: List[RebuildItem] = []
    spent = 0
    items = list(items)
    t0 = time.perf_counter_ns() if OBS.enabled else 0
    with OBS.span(
        "rebuild.round", items=len(items), budget_bytes=budget_bytes
    ) as sp:
        for k, it in enumerate(items):
            if spent + it.body_bytes > budget_bytes:
                remaining = items[k:]
                break
            stripe = get_stripe(it.stripe_id)
            if OBS.enabled:
                # rebuild.read: every surviving body + both parity strips
                # feed the reconstruction; rebuild.write: the rebuilt body
                # landing on the replacement CSD
                nb = sum(
                    4 * int(b.sealed.n_valid_u32)
                    for b in stripe.blocks
                    if b is not None
                )
                if stripe.parity is not None:
                    nb += int(stripe.parity["p"].size)
                    q_strip = stripe.parity.get("q")
                    if q_strip is not None:
                        nb += int(q_strip.size)
                OBS.flow(EDGE_REBUILD_READ, nb)
                OBS.flow(EDGE_REBUILD_WRITE, it.body_bytes)
            blk = _rebuild_shard_body(
                stripe, it.shard, manifests_for(it.stripe_id),
                mesh=mesh, axis=axis, use_pallas=use_pallas,
            )
            put_shard(it.stripe_id, it.shard, blk)
            rebuilt.append(it)
            spent += it.body_bytes
        sp.set(rebuilt=len(rebuilt), bytes_rebuilt=spent)
    if OBS.enabled:
        OBS.count(obs_names.REBUILD_ROUNDS)
        OBS.count(obs_names.REBUILD_SHARDS, len(rebuilt))
        OBS.count(obs_names.REBUILD_BYTES, spent)
        OBS.gauge(obs_names.REBUILD_BUDGET, budget_bytes)
        OBS.observe(
            obs_names.REBUILD_ROUND_US, (time.perf_counter_ns() - t0) / 1e3
        )
    return RebuildRound(rebuilt, spent, remaining)
