"""Per-arch distribution policy: FSDP threshold, optimizer dtype, microbatching.

Derived from HBM budgets (16 GiB / v5e chip, 256 chips/pod):

  * params > 8B  -> FSDP (params/grads sharded over ``data`` too; pure
    TP-sharded replicas would exceed per-chip HBM).
  * params > 100B -> bf16 optimizer states (f32 AdamW for 398-400B is 3.2 TB
    > the pod's 4 TB once params+grads are added).
  * microbatches sized so saved scan-carry activations stay ~O(1 GiB)/chip
    (with SP the carry is already L/model_parallel per layer).
"""

from __future__ import annotations

from typing import NamedTuple

from repro.models.config import ModelConfig

__all__ = ["DistPolicy", "policy_for"]


class DistPolicy(NamedTuple):
    fsdp: bool
    opt_state_dtype: str
    opt_kind: str  # "adamw" | "adafactor"
    n_microbatch: int
    q_chunk: int
    remat: bool
    seq_shard: bool  # SP on the residual stream
    tp: bool = True  # tensor-parallel over `model`; small models (<2B) run
    # pure-DP over all axes instead — TP-ing a 0.5B model across 16 shards
    # makes every layer collective-bound (§Perf iteration 3)
    flash_attn: bool = False  # online-softmax attention (helps the
    # collective-bound MoE giants; measured neutral/negative on dense/fine-
    # grained archs at L=4k — see EXPERIMENTS.md §Perf iteration 1)
    int8_gather: bool = False  # int8 FSDP weight gathers (§Perf iteration 2)


def policy_for(cfg: ModelConfig, shape_kind: str = "train") -> DistPolicy:
    n = cfg.param_count()
    fsdp = n > 8e9
    opt_kind = "adafactor" if n > 100e9 else "adamw"
    opt_dtype = "bfloat16" if n > 100e9 else "float32"
    # Microbatching multiplies FSDP weight re-gathers (HBM spikes + collective
    # bytes) while SP already bounds activation carries — so mb stays at 1
    # except for the dense 100B+ archs whose saved residual carries alone
    # (88 layers x 100 MB) exceed budget.
    if n > 100e9 and cfg.moe is None:
        mb = 2
    else:
        mb = 1
    if shape_kind != "train":
        mb = 1
    moe_giant = cfg.moe is not None and n > 100e9
    # Pure-DP for sub-2B models was measured (§Perf iter 3): collective term
    # -87%, but the replicated-weight memory traffic raised the net bound
    # (0.95->1.55s) — so TP stays default; the mechanism remains available.
    tp = True
    return DistPolicy(
        tp=tp,
        fsdp=fsdp,
        opt_state_dtype=opt_dtype,
        opt_kind=opt_kind,
        n_microbatch=mb,
        q_chunk=512,
        remat=True,
        seq_shard=tp,
        flash_attn=moe_giant or shape_kind == "prefill",
        int8_gather=fsdp and n > 100e9,
    )
