"""Telemetry exporters: fsync-disciplined JSONL and Chrome trace_event.

Two consumers, two formats, one event source:

* ``write_jsonl`` — the machine log.  One JSON object per line: every
  finished span, then one ``metrics`` record (the registry snapshot) and
  one ``ledger`` record (the byte-flow report).  Written with the SAME
  durability discipline as ``core/csd/failure.Journal.commit`` — tmp file,
  ``fsync``, atomic ``os.replace``, directory ``fsync`` — so a power cut
  mid-export leaves the previous log intact, never a torn one.
  ``commit_jsonl`` routes the identical payload through an actual
  :class:`Journal` instead (crc32 record + replayable), for trainers that
  already own one.
* ``write_chrome_trace`` — the human view.  Chrome ``trace_event`` JSON
  (the ``{"traceEvents": [...]}`` envelope): spans become complete ``"X"``
  events whose begin/end nesting Perfetto reconstructs from timestamps,
  ledger edges become counter ``"C"`` samples at the trace tail.  Load at
  https://ui.perfetto.dev — the whole stripe lifecycle on one timeline.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

__all__ = ["write_jsonl", "commit_jsonl", "write_chrome_trace",
           "jsonl_lines", "chrome_trace_events"]


def _fsync_replace(path: str, data: bytes) -> None:
    """Durable atomic write (the Journal.commit discipline): payload fsync,
    atomic rename, then directory fsync so the rename itself survives."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fd = os.open(d, os.O_RDONLY)
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - fs without dir fsync support
        pass
    finally:
        os.close(fd)


def jsonl_lines(telemetry) -> List[str]:
    """The JSONL event log as a list of lines (shared by both sinks)."""
    lines = [
        json.dumps(dict(ev, kind="span"), default=str)
        for ev in telemetry.tracer.events
    ]
    if telemetry.tracer.dropped:
        lines.append(json.dumps(
            {"kind": "dropped_spans", "count": telemetry.tracer.dropped}
        ))
    lines.append(json.dumps(
        {"kind": "metrics", "snapshot": telemetry.metrics.snapshot()},
        default=str,
    ))
    lines.append(json.dumps(
        {"kind": "ledger", "report": telemetry.ledger.report()},
        default=str,
    ))
    return lines


def write_jsonl(path: str, telemetry) -> int:
    """Write the JSONL event log durably; returns the number of records."""
    lines = jsonl_lines(telemetry)
    _fsync_replace(path, ("\n".join(lines) + "\n").encode())
    return len(lines)


def commit_jsonl(journal, telemetry, name: str = "telemetry.jsonl") -> str:
    """Commit the JSONL event log through an existing ``Journal`` (crc32'd
    record, replayable, fsync discipline included).  Returns the payload
    path the journal wrote."""
    lines = jsonl_lines(telemetry)
    return journal.commit(
        name,
        ("\n".join(lines) + "\n").encode(),
        {"kind": "telemetry", "records": len(lines)},
    )


def chrome_trace_events(telemetry) -> List[Dict]:
    """Span + counter events in Chrome ``trace_event`` form (ts/dur in us)."""
    events: List[Dict] = [
        {
            "ph": "M", "pid": 0, "tid": 0, "name": "process_name",
            "args": {"name": "salient-store"},
        }
    ]
    last_ts = 0.0
    for ev in telemetry.tracer.events:
        ts = ev["ts_ns"] / 1e3
        events.append(
            {
                "name": ev["name"],
                "ph": "X",
                "ts": ts,
                "dur": ev["dur_ns"] / 1e3,
                "pid": 0,
                "tid": 0,
                "args": {k: str(v) for k, v in ev["attrs"].items()},
            }
        )
        last_ts = max(last_ts, ts + ev["dur_ns"] / 1e3)
    for edge, nbytes in sorted(telemetry.ledger.totals().items()):
        events.append(
            {
                "name": f"bytes:{edge}",
                "ph": "C",
                "ts": last_ts,
                "pid": 0,
                "tid": 0,
                "args": {"bytes": nbytes},
            }
        )
    return events


def write_chrome_trace(path: str, telemetry) -> int:
    """Write a Perfetto-loadable trace file; returns the event count."""
    events = chrome_trace_events(telemetry)
    payload = {"traceEvents": events, "displayTimeUnit": "ms"}
    _fsync_replace(path, json.dumps(payload).encode())
    return len(events)
