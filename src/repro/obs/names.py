"""Canonical instrument names, shared by every tier.

The serving (``serving/engine.py``) and distributed
(``distributed/archival.py``) stats surfaces used to hand-assemble their
own dicts, so a counter could be renamed on one side and silently stop
matching the other.  Both now register instruments under THESE constants
(one definition, two registries), so the names cannot drift — and the
exported snapshots stay joinable across tiers.
"""

from __future__ import annotations

# ---------------------------------------------------------------- ingest
ING_GOPS = "ingest.gops"                       # counter: GOPs submitted
ING_STRIPES = "ingest.stripes_sealed"          # counter: stripes sealed
ING_PENDING = "ingest.pending_gops"            # gauge: coalescer occupancy
ING_ENTROPY_RAW = "ingest.entropy_raw_bytes"   # counter
ING_ENTROPY_COMP = "ingest.entropy_comp_bytes"  # counter
ING_GOP_LATENCY_US = "ingest.gop_to_commit_us"  # histogram: submit->sealed
ING_QUEUE_DEPTH = "ingest.queue_depth"         # gauge: frontend queued bytes
ING_SHED_BYTES = "ingest.shed_bytes"           # counter: admission sheds
ING_SHED_GOPS = "ingest.shed_gops"             # counter: GOPs shed

# ------------------------------------------------------------- retrieval
RETR_PLANS = "retrieval.plans_served"          # counter
RETR_PLANNED_BYTES = "retrieval.planned_bytes"  # counter
RETR_FULL_BYTES = "retrieval.full_restore_bytes"  # counter
RETR_SKIPPED = "retrieval.candidates_skipped"  # counter: budget rejections

# ------------------------------------------------------------- catalog
CAT_GOPS = "catalog.gops"                      # gauge
CAT_BYTES = "catalog.bytes_indexed"            # gauge

# ------------------------------------------------------------ durability
SCRUB_ROUNDS = "scrub.rounds"                  # counter
SCRUB_STRIPES = "scrub.stripes_checked"        # counter
SCRUB_BYTES = "scrub.bytes_scrubbed"           # counter
SCRUB_SYNDROME_HITS = "scrub.syndrome_hits"    # counter: nonzero syndromes
SCRUB_FINDINGS = "scrub.findings"              # counter
SCRUB_REPAIRED = "scrub.repaired"              # counter
SCRUB_ROUND_US = "scrub.round_us"              # histogram

REBUILD_ROUNDS = "rebuild.rounds"              # counter
REBUILD_SHARDS = "rebuild.shards"              # counter
REBUILD_BYTES = "rebuild.bytes_rebuilt"        # counter
REBUILD_BUDGET = "rebuild.budget_bytes"        # gauge: last round's budget
REBUILD_ROUND_US = "rebuild.round_us"          # histogram

RETIRED_STRIPES = "lifecycle.stripes_retired"  # counter
STRIPES_RETAINED = "lifecycle.stripes_retained"  # gauge
LOST_CSDS = "lifecycle.lost_csds"              # gauge

# --------------------------------------------------------------- kernels
FUSED_LAUNCHES = "kernels.fused_launches"      # counter: one-launch groups
FUSED_STRIPES = "kernels.fused_stripes"        # counter: stripes batched
