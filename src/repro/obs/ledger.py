"""ByteLedger: every byte crossing a boundary, attributed to a labeled edge.

The paper's Fig. 1 is a data-movement diagram; this module makes it a
queryable table.  Each edge label names one arrow of the stripe lifecycle,
billed at exactly ONE call site so totals conserve (bytes in == bytes
attributed — ``tests/test_obs.py`` pins it on a seal→scrub→restore
roundtrip):

==========================  ===================================================
edge                        billed by / meaning
==========================  ===================================================
``ingest.host_to_device``   raw codec payload bytes entering the fused seal
                            launch (``pipeline._assemble_stripe``) — the
                            pre-compression volume a host-codec design would
                            have shipped
``ingest.entropy_raw``      raw bytes through the entropy stage (shards whose
``ingest.entropy_comp``     manifest records a real codec) and the compressed
                            stream bytes they became — their ratio IS the
                            archive's rANS ``ratio``
``ingest.device_to_journal``sealed body bytes leaving the kernel for the
                            journal (compressed + sealed: the only payload
                            traffic the CSD design ships)
``ingest.shard_to_parity``  P/Q parity strip bytes per sealed stripe
``ingest.shed``             payload bytes the streaming admission controller
                            refused under queue pressure
                            (``serving/ingest.StreamIngestFrontend._shed``,
                            journaled — never a silent drop)
``replay.planned``          bytes a retrieval plan promised to move
                            (``plan_retrieval``; virtual — billed at plan
                            time, compared against ``replay.read``)
``replay.full_baseline``    the no-index full-restore volume of the same
                            query (virtual) — ``planned / full_baseline`` IS
                            the catalog's ``bytes_moved_ratio``
``replay.read``             sealed body bytes a restore actually moved
                            (``restore_stripe_payloads``, present wanted
                            shards only)
``replay.parity``           degraded-read amplification: surviving unwanted
                            peer bodies + parity strips a rebuild had to read
``scrub.read``              sealed bytes a scrub round recomputed parity over
``scrub.syndrome``          P/Q strip bytes the scrub ships host-side
``rebuild.read``            surviving bodies + parity read per rebuilt shard
``rebuild.write``           reconstructed body bytes written to the
                            replacement CSD
==========================  ===================================================

``report()`` folds the table into the paper's headline ratios in one call:
``entropy_ratio`` (the rANS compression ratio recomputed from ledger edges
alone) and ``bytes_moved_ratio`` (planned subset reads vs the no-index
baseline) — the ~6.1x data-volume claim as a query, not a hand-assembled
stat.
"""

from __future__ import annotations

from typing import Dict

__all__ = [
    "ByteLedger",
    "EDGE_HOST_TO_DEVICE",
    "EDGE_ENTROPY_RAW",
    "EDGE_ENTROPY_COMP",
    "EDGE_DEVICE_TO_JOURNAL",
    "EDGE_SHARD_TO_PARITY",
    "EDGE_INGEST_SHED",
    "EDGE_REPLAY_PLANNED",
    "EDGE_REPLAY_FULL_BASELINE",
    "EDGE_REPLAY_READ",
    "EDGE_REPLAY_PARITY",
    "EDGE_SCRUB_READ",
    "EDGE_SCRUB_SYNDROME",
    "EDGE_REBUILD_READ",
    "EDGE_REBUILD_WRITE",
]

EDGE_HOST_TO_DEVICE = "ingest.host_to_device"
EDGE_ENTROPY_RAW = "ingest.entropy_raw"
EDGE_ENTROPY_COMP = "ingest.entropy_comp"
EDGE_DEVICE_TO_JOURNAL = "ingest.device_to_journal"
EDGE_SHARD_TO_PARITY = "ingest.shard_to_parity"
EDGE_INGEST_SHED = "ingest.shed"
EDGE_REPLAY_PLANNED = "replay.planned"
EDGE_REPLAY_FULL_BASELINE = "replay.full_baseline"
EDGE_REPLAY_READ = "replay.read"
EDGE_REPLAY_PARITY = "replay.parity"
EDGE_SCRUB_READ = "scrub.read"
EDGE_SCRUB_SYNDROME = "scrub.syndrome"
EDGE_REBUILD_READ = "rebuild.read"
EDGE_REBUILD_WRITE = "rebuild.write"


class ByteLedger:
    """Per-edge byte totals + event counts.  Edges are created on first
    bill, so the totals only ever name flows that actually happened."""

    def __init__(self) -> None:
        self._bytes: Dict[str, int] = {}
        self._events: Dict[str, int] = {}

    def add(self, edge: str, nbytes: int, events: int = 1) -> None:
        self._bytes[edge] = self._bytes.get(edge, 0) + int(nbytes)
        self._events[edge] = self._events.get(edge, 0) + events

    def bytes(self, edge: str) -> int:
        return self._bytes.get(edge, 0)

    def events(self, edge: str) -> int:
        return self._events.get(edge, 0)

    def totals(self) -> Dict[str, int]:
        return dict(self._bytes)

    def _ratio(self, num: str, den: str) -> float:
        d = self._bytes.get(den, 0)
        return self._bytes.get(num, 0) / d if d else float("nan")

    def report(self) -> Dict[str, object]:
        """The one-call data-movement report: every edge's bytes/events
        plus the paper's derived ratios, computed from ledger edges alone."""
        return {
            "edges": {
                e: {"bytes": b, "events": self._events.get(e, 0)}
                for e, b in sorted(self._bytes.items())
            },
            # rANS compression ratio (raw / compressed through the coder)
            "entropy_ratio": self._ratio(EDGE_ENTROPY_RAW, EDGE_ENTROPY_COMP),
            # planned subset reads vs the no-index full-restore baseline —
            # the catalog's bytes_moved_ratio
            "bytes_moved_ratio": self._ratio(
                EDGE_REPLAY_PLANNED, EDGE_REPLAY_FULL_BASELINE
            ),
            # what restore actually moved vs what the plan promised (reads
            # of planned-but-retired stripes show up here, not as drift)
            "moved_vs_planned": self._ratio(
                EDGE_REPLAY_READ, EDGE_REPLAY_PLANNED
            ),
            # total ingest traffic the CSD design ships vs the raw volume a
            # host-codec design would have — the data-volume-reduction claim
            "ingest_volume_ratio": self._ratio(
                EDGE_DEVICE_TO_JOURNAL, EDGE_HOST_TO_DEVICE
            ),
        }

    def reset(self) -> None:
        self._bytes.clear()
        self._events.clear()
