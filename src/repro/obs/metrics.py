"""Metrics registry: counters, gauges, fixed-bucket histograms.

Dependency-free (stdlib only — the registry is imported by the kernels-
adjacent layers, which must never grow a host-side dependency).  The three
instrument kinds cover everything the stripe lifecycle reports:

* :class:`Counter` — monotone event/byte totals (GOPs ingested, stripes
  sealed, scrub findings).  ``snapshot(reset=True)`` windows them, so a
  caller polling at an interval gets per-interval rates instead of
  cumulative-only totals (the ``ingest_scale`` bench's requirement).
* :class:`Gauge` — instantaneous levels (coalescer occupancy, lost CSDs).
  Levels survive a windowed snapshot: resetting a level would fabricate
  an empty coalescer.
* :class:`Histogram` — fixed geometric buckets, p50/p95/p99 WITHOUT
  storing samples: each observation lands in bucket
  ``floor(log(v / lo) / log(growth))`` and percentiles interpolate
  geometrically inside the covering bucket, clamped to the exact observed
  min/max.  With the default ``growth = 2 ** (1/8)`` the worst-case
  relative error of a percentile estimate is one bucket ratio (~9%),
  at a constant 321 * 8 bytes of state per histogram — the property that
  lets ingest tail latency run at production stream counts.

``Metrics`` is instantiable (the serving tier keeps a per-``ArchiveIngest``
registry so two ingest frontends never share counters); the process-global
telemetry singleton owns its own instance (``repro.obs.OBS.metrics``).
Canonical instrument names live in ``repro.obs.names`` so the serving and
distributed tiers cannot drift apart.
"""

from __future__ import annotations

import math
from typing import Dict, Optional

__all__ = ["Counter", "Gauge", "Histogram", "Metrics"]


class Counter:
    """Monotone counter (events or bytes)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def add(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Instantaneous level; never reset by windowed snapshots."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed geometric buckets; percentiles without stored samples.

    ``lo`` is the first bucket's lower bound; values below it clamp into
    bucket 0, values past ``lo * growth**n_buckets`` into the last bucket.
    The defaults cover 1 unit .. 2**40 units (microseconds up to ~2 weeks,
    bytes up to a terabyte) at ~9% bucket ratio.
    """

    __slots__ = ("lo", "growth", "n_buckets", "_inv_lg", "buckets",
                 "count", "total", "vmin", "vmax")

    def __init__(self, lo: float = 1.0, growth: float = 2.0 ** 0.125,
                 n_buckets: int = 321):
        if lo <= 0 or growth <= 1.0 or n_buckets < 1:
            raise ValueError("need lo > 0, growth > 1, n_buckets >= 1")
        self.lo = float(lo)
        self.growth = float(growth)
        self.n_buckets = int(n_buckets)
        self._inv_lg = 1.0 / math.log(growth)
        self.buckets = [0] * self.n_buckets
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        if v > self.lo:
            i = int(math.log(v / self.lo) * self._inv_lg)
            if i >= self.n_buckets:
                i = self.n_buckets - 1
        else:
            i = 0
        self.buckets[i] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile by geometric interpolation inside
        the covering bucket, clamped to the observed min/max."""
        if not self.count:
            return float("nan")
        rank = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.buckets):
            if c == 0:
                continue
            if cum + c >= rank:
                frac = (rank - cum) / c
                b_lo = max(self.lo * self.growth ** i, self.vmin)
                b_hi = min(self.lo * self.growth ** (i + 1), self.vmax)
                if b_hi <= b_lo:
                    return b_lo
                return b_lo * (b_hi / b_lo) ** frac
            cum += c
        return self.vmax

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }

    def reset(self) -> None:
        self.buckets = [0] * self.n_buckets
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf


class Metrics:
    """Named instrument registry with windowed snapshots."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # ------------------------------------------------------- get-or-create
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, **kw) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(**kw)
        return h

    # -------------------------------------------------------- conveniences
    def add(self, name: str, n: int = 1) -> None:
        self.counter(name).add(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def get(self, name: str, default: float = 0) -> float:
        """Current value of a counter or gauge (0 when never touched)."""
        c = self._counters.get(name)
        if c is not None:
            return c.value
        g = self._gauges.get(name)
        if g is not None:
            return g.value
        return default

    def percentile(self, name: str, q: float) -> float:
        h = self._hists.get(name)
        return h.percentile(q) if h is not None else float("nan")

    # ----------------------------------------------------------- snapshots
    def snapshot(self, reset: bool = False) -> Dict[str, object]:
        """Flat ``{name: value}`` view: counters/gauges as numbers,
        histograms as their summary dicts.  ``reset=True`` zeroes counters
        and histograms AFTER reading (windowed semantics: successive
        snapshots report per-interval deltas); gauges are levels and keep
        their value either way.
        """
        out: Dict[str, object] = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.value
        for name, h in self._hists.items():
            out[name] = h.summary()
        if reset:
            for c in self._counters.values():
                c.value = 0
            for h in self._hists.values():
                h.reset()
        return out

    def clear(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()
