"""repro.obs — archive telemetry tier: spans, metrics, byte-flow ledger.

One process-global :class:`Telemetry` bundle (``OBS``) that the whole
stripe lifecycle reports into:

* ``OBS.span("archive.seal", stripes=4)`` — nested spans with monotonic
  durations and structured attrs (stripe ids, shard counts, codec names,
  Pallas launch counts).  Exports as JSONL or a Chrome/Perfetto trace.
* ``OBS.metrics`` — counters / gauges / fixed-bucket histograms (p50/p95/
  p99 without stored samples).  Canonical names in :mod:`repro.obs.names`.
* ``OBS.ledger`` — every byte crossing a lifecycle boundary attributed to
  a labeled edge (:mod:`repro.obs.ledger`); ``OBS.ledger.report()`` is the
  paper's data-movement table in one call.

Zero overhead when disabled — the contract every hot path relies on:
``OBS`` starts disabled; ``span()`` then returns the shared ``NULL_SPAN``
and ``count``/``flow``/``observe``/``gauge`` return after a single
attribute test.  No event, no allocation beyond the argument tuple, no
timestamps.  The ``obs_overhead`` bench gates the enabled cost at <= 3%
of ``seal_payload_stripe``; disabled cost is one branch.

Instrumented call sites follow one pattern::

    from repro import obs

    with obs.OBS.span("archive.seal", stripes=len(stripes)) as sp:
        ...
        sp.set(launches=n_launches)
    obs.OBS.flow(obs.EDGE_DEVICE_TO_JOURNAL, body_nbytes)

Tests use the ``enabled()`` context manager for a fresh, isolated capture::

    with obs.enabled() as t:
        seal_payload_stripe(...)
    assert t.ledger.bytes(obs.EDGE_SHARD_TO_PARITY) == expected
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Dict

from .ledger import (  # noqa: F401  (re-exported surface)
    ByteLedger,
    EDGE_DEVICE_TO_JOURNAL,
    EDGE_ENTROPY_COMP,
    EDGE_ENTROPY_RAW,
    EDGE_HOST_TO_DEVICE,
    EDGE_INGEST_SHED,
    EDGE_REBUILD_READ,
    EDGE_REBUILD_WRITE,
    EDGE_REPLAY_FULL_BASELINE,
    EDGE_REPLAY_PARITY,
    EDGE_REPLAY_PLANNED,
    EDGE_REPLAY_READ,
    EDGE_SCRUB_READ,
    EDGE_SCRUB_SYNDROME,
    EDGE_SHARD_TO_PARITY,
)
from .metrics import Counter, Gauge, Histogram, Metrics  # noqa: F401
from .trace import NULL_SPAN, NullSpan, Span, Tracer  # noqa: F401
from . import names  # noqa: F401

__all__ = [
    "Telemetry", "OBS", "enable", "disable", "reset", "enabled",
    "Metrics", "Counter", "Gauge", "Histogram",
    "Tracer", "Span", "NullSpan", "NULL_SPAN",
    "ByteLedger", "names",
    "EDGE_HOST_TO_DEVICE", "EDGE_ENTROPY_RAW", "EDGE_ENTROPY_COMP",
    "EDGE_DEVICE_TO_JOURNAL", "EDGE_SHARD_TO_PARITY", "EDGE_INGEST_SHED",
    "EDGE_REPLAY_PLANNED", "EDGE_REPLAY_FULL_BASELINE",
    "EDGE_REPLAY_READ", "EDGE_REPLAY_PARITY",
    "EDGE_SCRUB_READ", "EDGE_SCRUB_SYNDROME",
    "EDGE_REBUILD_READ", "EDGE_REBUILD_WRITE",
]


class Telemetry:
    """Tracer + metrics + ledger behind one enable flag.

    Every recording entry point tests ``self.enabled`` exactly once and
    returns immediately when off — that single branch is the entire
    disabled-mode cost at a call site.
    """

    __slots__ = ("enabled", "tracer", "metrics", "ledger")

    def __init__(self) -> None:
        self.enabled = False
        self.tracer = Tracer()
        self.metrics = Metrics()
        self.ledger = ByteLedger()

    # ------------------------------------------------------------ recording
    def span(self, name: str, **attrs):
        if not self.enabled:
            return NULL_SPAN
        return self.tracer.span(name, **attrs)

    def count(self, name: str, n: int = 1) -> None:
        if self.enabled:
            self.metrics.add(name, n)

    def gauge(self, name: str, v: float) -> None:
        if self.enabled:
            self.metrics.set_gauge(name, v)

    def observe(self, name: str, v: float) -> None:
        if self.enabled:
            self.metrics.observe(name, v)

    def flow(self, edge: str, nbytes: int, events: int = 1) -> None:
        """Bill bytes to a ledger edge (no-op when disabled)."""
        if self.enabled:
            self.ledger.add(edge, nbytes, events)

    # ------------------------------------------------------------- querying
    def snapshot(self, reset: bool = False) -> Dict[str, object]:
        """Metrics snapshot plus the ledger report (ledger never resets
        here — it is a conservation ledger, not a rate window)."""
        out = self.metrics.snapshot(reset=reset)
        out["ledger"] = self.ledger.report()
        return out

    def reset(self) -> None:
        self.tracer.clear()
        self.metrics.clear()
        self.ledger.reset()


#: The process-global telemetry bundle every instrumented seam reports to.
OBS = Telemetry()


def enable(reset: bool = False) -> Telemetry:
    if reset:
        OBS.reset()
    OBS.enabled = True
    return OBS


def disable() -> Telemetry:
    OBS.enabled = False
    return OBS


def reset() -> Telemetry:
    OBS.reset()
    return OBS


@contextmanager
def enabled(fresh: bool = True):
    """Enable OBS for a block, restoring the prior state after.  With
    ``fresh=True`` (the default) the capture starts empty AND is cleared
    on exit, so tests never leak events into each other."""
    prior = OBS.enabled
    if fresh:
        OBS.reset()
    OBS.enabled = True
    try:
        yield OBS
    finally:
        # The capture stays readable after the block; only the flag reverts.
        OBS.enabled = prior
