"""Tracer: nested spans with monotonic-clock durations.

A :class:`Span` is a context manager; entering pushes it on the tracer's
stack (so spans opened inside it become its children), exiting records a
finished-span event ``{id, parent, name, ts_ns, dur_ns, attrs}`` with
timestamps from ``time.perf_counter_ns`` (monotonic — wall-clock steps
never produce negative durations) relative to the tracer's epoch.

Structured attributes ride on the span: pass them at creation
(``tracer.span("archive.seal", stripes=4, codec="rans")``) or attach
mid-span with ``span.set(launches=2)`` for values only known after the
work ran (e.g. the Pallas launch count a batched seal actually used).

The disabled fast path lives one level up (``repro.obs.Telemetry.span``
returns a shared no-op span without touching this module), so a call site
pays one branch when telemetry is off.  Events accumulate in
``tracer.events`` bounded by ``max_events`` (drops are counted, never
silent) and export via ``repro.obs.export`` (JSONL / Chrome trace_event).
"""

from __future__ import annotations

import time
from typing import Dict, List

__all__ = ["Span", "NullSpan", "NULL_SPAN", "Tracer"]


class NullSpan:
    """Shared no-op span: the single-branch disabled path returns this."""

    __slots__ = ()

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "NullSpan":
        return self


NULL_SPAN = NullSpan()


class Span:
    __slots__ = ("_tracer", "name", "attrs", "span_id", "parent_id", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = 0
        self.parent_id = 0
        self._t0 = 0

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (launch counts, sizes)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        tr = self._tracer
        self.span_id = tr._next_id
        tr._next_id += 1
        self.parent_id = tr._stack[-1] if tr._stack else 0
        tr._stack.append(self.span_id)
        self._t0 = tr._clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        t1 = tr._clock()
        if tr._stack and tr._stack[-1] == self.span_id:
            tr._stack.pop()
        tr._finish(self, self._t0, t1)
        return False


class Tracer:
    """Collects finished spans as plain dict events (export-ready)."""

    def __init__(self, clock=time.perf_counter_ns, max_events: int = 100_000):
        self._clock = clock
        self.max_events = max_events
        self.events: List[Dict] = []
        self.dropped = 0
        self._stack: List[int] = []
        self._next_id = 1
        self._epoch = clock()

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def _finish(self, span: Span, t0: int, t1: int) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(
            {
                "id": span.span_id,
                "parent": span.parent_id,
                "name": span.name,
                "ts_ns": t0 - self._epoch,
                "dur_ns": t1 - t0,
                "attrs": span.attrs,
            }
        )

    def clear(self) -> None:
        self.events = []
        self.dropped = 0
        self._stack = []
        self._next_id = 1
        self._epoch = self._clock()
