"""Gradient compression via the paper's layered quantization codec.

Beyond-paper application of Salient Store's core idea ("compress before the
expensive link") to distributed training: before the cross-pod gradient
reduction, each gradient tensor is quantized into K progressive int8 layers
(layer k encodes the residual of layers < k at a finer scale) with
error-feedback accumulation, so the DCN hop moves K bytes/param instead of 4.

The compression is bit-exactly simulated at the math level (quantize ->
dequantize) and the wire bytes are reported; on real multi-pod hardware the
int8 payloads feed ``jax.lax.psum`` over the ``pod`` axis directly.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["GradCompressConfig", "GradCompressState", "init_state", "compress_tree"]


class GradCompressConfig(NamedTuple):
    n_layers: int = 2  # progressive int8 layers (1 = plain int8)
    error_feedback: bool = True


class GradCompressState(NamedTuple):
    residual: Any  # pytree like grads: error-feedback carry


def init_state(grads_template) -> GradCompressState:
    return GradCompressState(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)
    )


def _quantize_layered(g, n_layers: int):
    """g (f32) -> (reconstruction, wire_bytes).  Each layer: int8 at a scale
    1/127 of the current residual's max — progressive refinement exactly like
    the video codec's quality layers."""
    recon = jnp.zeros_like(g)
    resid = g
    for _ in range(n_layers):
        scale = jnp.maximum(jnp.max(jnp.abs(resid)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(resid / scale), -127, 127)
        layer = q * scale
        recon = recon + layer
        resid = resid - layer
    wire_bytes = g.size * n_layers  # int8 per layer (+ negligible scales)
    return recon, wire_bytes


def compress_tree(
    grads, state: GradCompressState, cfg: GradCompressConfig
) -> Tuple[Any, GradCompressState, jax.Array, jax.Array]:
    """Returns (decompressed grads, new state, wire_bytes, raw_bytes)."""
    wire = 0
    raw = 0

    def one(g, r):
        nonlocal wire, raw
        gf = g.astype(jnp.float32)
        if cfg.error_feedback:
            gf = gf + r
        recon, wb = _quantize_layered(gf, cfg.n_layers)
        wire += wb
        raw += g.size * 4
        new_r = (gf - recon) if cfg.error_feedback else jnp.zeros_like(gf)
        return recon.astype(g.dtype), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_grads = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_resid = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_grads, GradCompressState(new_resid), jnp.asarray(wire), jnp.asarray(raw)
