"""AdamW in pure JAX.

Used by both the codec trainer (Alg. 2: backprop "only the layers of the
autoencoder" — freezing is done by optimizing only the trainable subtree) and
the LM trainer.  State is a pytree mirroring params, so it shards with the
same NamedSharding rules (ZeRO-1 over the data axis is applied by
distributed/sharding.py).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
]


class AdamWConfig(NamedTuple):
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = None
    state_dtype: str = "float32"  # "bfloat16" halves optimizer HBM
    kind: str = "adamw"  # "adamw" | "adafactor" — the 100B+ archs use
    # Adafactor (factored second moment, ~0 state bytes/param): AdamW state
    # for 398-400B params exceeds a 256-chip pod's 4 TB HBM (PaLM/T5 policy)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    if cfg.kind == "adafactor":
        return _adafactor_init(params)
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros(), zeros())


def _adafactor_init(params) -> AdamWState:
    """State: row/col EMAs of squared grads (factored over the last 2 dims);
    1-D leaves keep a full v in ``mu`` with a scalar placeholder in ``nu``."""
    def vr(p):
        if p.ndim >= 2:
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        if p.ndim >= 2:
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((), jnp.float32)

    return AdamWState(
        jnp.zeros((), jnp.int32),
        jax.tree.map(vr, params),
        jax.tree.map(vc, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(
    params,
    grads,
    state: AdamWState,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
):
    """Returns (new_params, new_state)."""
    if cfg.grad_clip is not None:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    if cfg.kind == "adafactor":
        return _adafactor_update(params, grads, state, cfg, lr_scale)
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    new_mu = jax.tree.map(
        lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
        state.mu,
        grads,
    )
    new_nu = jax.tree.map(
        lambda v, g: (
            b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))
        ).astype(v.dtype),
        state.nu,
        grads,
    )

    def upd(p, m, v):
        m = m.astype(jnp.float32)
        v = v.astype(jnp.float32)
        delta = lr * (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_mu, new_nu)
    return new_params, AdamWState(step, new_mu, new_nu)


def _adafactor_update(params, grads, state: AdamWState, cfg: AdamWConfig, lr_scale):
    """Adafactor (Shazeer & Stern 2018), beta1=0, factored v, RMS clipping."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    b2 = 1.0 - t ** -0.8  # time-dependent decay
    lr = cfg.lr * lr_scale
    eps = 1e-30

    def upd_flat(p, g, r, c):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if p.ndim >= 2:
            r2 = b2 * r + (1 - b2) * g2.mean(-1)
            c2 = b2 * c + (1 - b2) * g2.mean(-2)
            denom = jnp.maximum(r2.mean(-1, keepdims=True), eps)
            vhat = (r2 / denom)[..., None] * c2[..., None, :]
        else:
            r2 = b2 * r + (1 - b2) * g2
            c2 = c
            vhat = r2
        u = gf * jax.lax.rsqrt(vhat + eps)
        rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms_u)  # clip update RMS to 1
        delta = lr * u
        if cfg.weight_decay:
            delta = delta + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype), r2, c2

    def upd(p, g, r, c):
        # stacked (n_super, ...) leaves update slice-by-slice: bounds the f32
        # transients to one layer's worth (2.5 GB -> ~100 MB for the 400B MoE
        # expert stacks) — HBM peak, not FLOPs, is the binding constraint
        if p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda a: upd_flat(*a), (p, g, r, c))
        return upd_flat(p, g, r, c)

    # three passes (XLA CSEs the duplicates under jit); avoids tuple-leaf
    # ambiguity in nested pytrees
    args = (params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda p, g, r, c: upd(p, g, r, c)[0], *args)
    new_r = jax.tree.map(lambda p, g, r, c: upd(p, g, r, c)[1], *args)
    new_c = jax.tree.map(lambda p, g, r, c: upd(p, g, r, c)[2], *args)
    return new_params, AdamWState(step, new_r, new_c)
