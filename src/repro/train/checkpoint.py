"""Fault-tolerant checkpointing through the Salient Store archival pipeline.

Checkpoints are archival data: each save is chunked into S logical storage
shards, zstd-compressed, optionally sealed (R-LWE KEM + ChaCha20) and
RAID-6-parity-coded, then committed through the power-loss-safe ``Journal``
(write payload -> fsync -> manifest record).  Restore tolerates:

  * torn writes (journal replay drops them),
  * up to two missing/corrupt shards per checkpoint (parity rebuild),
  * a different mesh on restart (elastic: arrays are saved unsharded-logical
    and resharded by the caller's NamedShardings at load).
"""

from __future__ import annotations

import io
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import compress as entropy
from repro.core.archival import raid
from repro.core.crypto import rlwe
from repro.core.crypto.chacha import xor_stream
from repro.core.csd.failure import Journal

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step", "CheckpointError"]


class CheckpointError(RuntimeError):
    pass


def _serialize_tree(tree) -> bytes:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    buf = io.BytesIO()
    np.savez(
        buf,
        __treedef__=np.frombuffer(str(treedef).encode(), dtype=np.uint8),
        **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)},
    )
    return buf.getvalue()


def _deserialize_leaves(blob: bytes) -> List[np.ndarray]:
    buf = io.BytesIO(blob)
    with np.load(buf) as z:
        n = sum(1 for k in z.files if k.startswith("leaf_"))
        return [z[f"leaf_{i}"] for i in range(n)]


def save_checkpoint(
    root: str,
    step: int,
    state: Any,
    *,
    n_shards: int = 4,
    parity: str = "raid6",
    seal_key: Optional[rlwe.PublicKey] = None,
    rng: Optional[jax.Array] = None,
    zstd_level: int = 3,
) -> Dict:
    """state: arbitrary pytree (params/opt/extra). Returns the manifest."""
    j = Journal(root)
    raw = _serialize_tree(state)
    comp = entropy.compress(raw, level=zstd_level)

    meta: Dict[str, Any] = {
        "step": int(step),
        "n_shards": n_shards,
        "parity": parity,
        "raw_len": len(raw),
        "comp_len": len(comp),
        "sealed": bool(seal_key is not None),
        "codec": entropy.CODEC_NAME,  # zstd or the zlib fallback
    }
    payload = comp
    if seal_key is not None:
        if rng is None:
            rng = jax.random.PRNGKey(step)
        pad = (-len(payload)) % 4
        words = jnp.asarray(
            np.frombuffer(payload + b"\0" * pad, dtype="<u4").copy()
        )
        from repro.core.crypto.hybrid import seal

        blk = seal(seal_key, words, rng)
        meta["kem_c1"] = np.asarray(blk.kem_c1).tolist()
        meta["kem_c2"] = np.asarray(blk.kem_c2).tolist()
        meta["nonce"] = np.asarray(blk.nonce).tolist()
        payload = np.asarray(blk.body).astype("<u4").tobytes()[: len(payload) + pad]

    # shard + parity
    shard_len = (len(payload) + n_shards - 1) // n_shards
    padded = payload + b"\0" * (shard_len * n_shards - len(payload))
    shards = [
        padded[i * shard_len : (i + 1) * shard_len] for i in range(n_shards)
    ]
    meta["payload_len"] = len(payload)
    meta["shard_len"] = shard_len

    names = []
    for i, s in enumerate(shards):
        name = f"ckpt_{step:08d}_shard{i}.bin"
        j.commit(name, s, {"step": step, "shard": i})
        names.append(name)
    if parity != "none":
        arr = jnp.asarray(
            np.stack([np.frombuffer(s, np.uint8) for s in shards])
        )
        if parity == "raid5":
            p = raid.raid5_encode(arr)
            j.commit(f"ckpt_{step:08d}_parity_p.bin", bytes(np.asarray(p)), {"step": step})
        else:
            p, q = raid.raid6_encode(arr)
            j.commit(f"ckpt_{step:08d}_parity_p.bin", bytes(np.asarray(p)), {"step": step})
            j.commit(f"ckpt_{step:08d}_parity_q.bin", bytes(np.asarray(q)), {"step": step})
    meta["shards"] = names
    j.commit(f"ckpt_{step:08d}_manifest.json", json.dumps(meta).encode(), {"step": step})
    return meta


def latest_step(root: str) -> Optional[int]:
    j = Journal(root)
    steps = [
        r["meta"]["step"]
        for r in j.replay()
        if r["name"].endswith("_manifest.json") and "step" in r.get("meta", {})
    ]
    return max(steps) if steps else None


def load_checkpoint(
    root: str,
    template: Any,
    step: Optional[int] = None,
    *,
    secret: Optional[jax.Array] = None,
    shardings: Any = None,
) -> Tuple[int, Any]:
    """Restore into the structure of ``template``; reshard with ``shardings``
    (a matching pytree of NamedSharding) if given — elastic restarts pass the
    NEW mesh's shardings here."""
    j = Journal(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise CheckpointError(f"no checkpoint in {root}")
    meta = json.loads(j.read(f"ckpt_{step:08d}_manifest.json"))

    shards: List[Optional[bytes]] = []
    missing: List[int] = []
    for i, name in enumerate(meta["shards"]):
        path = os.path.join(root, name)
        if os.path.exists(path) and os.path.getsize(path) == meta["shard_len"]:
            shards.append(j.read(name))
        else:
            shards.append(None)
            missing.append(i)
    if missing:
        if meta["parity"] == "none":
            raise CheckpointError(f"shards {missing} lost and no parity")
        rows = [
            None if s is None else jnp.asarray(np.frombuffer(s, np.uint8))
            for s in shards
        ]
        p = jnp.asarray(
            np.frombuffer(j.read(f"ckpt_{step:08d}_parity_p.bin"), np.uint8)
        )
        q = None
        if meta["parity"] == "raid6":
            q = jnp.asarray(
                np.frombuffer(j.read(f"ckpt_{step:08d}_parity_q.bin"), np.uint8)
            )
        if meta["parity"] == "raid5":
            assert len(missing) == 1, "RAID-5 covers one erasure"
            rows[missing[0]] = raid.raid5_reconstruct(rows, p, missing[0])
        else:
            rows = raid.raid6_reconstruct(rows, p, q, missing)
        shards = [bytes(np.asarray(r)) for r in rows]

    payload = b"".join(shards)[: meta["payload_len"]]
    if meta["sealed"]:
        if secret is None:
            raise CheckpointError("checkpoint is sealed; need the R-LWE secret")
        from repro.core.crypto.hybrid import SealedBlock, unseal

        words = jnp.asarray(np.frombuffer(payload, dtype="<u4").copy())
        blk = SealedBlock(
            jnp.asarray(meta["kem_c1"], jnp.int32),
            jnp.asarray(meta["kem_c2"], jnp.int32),
            jnp.asarray(meta["nonce"], jnp.uint32),
            words,
            int(words.size),
        )
        plain = unseal(secret, blk)
        payload = np.asarray(plain).astype("<u4").tobytes()[: meta["comp_len"]]
    else:
        payload = payload[: meta["comp_len"]]

    ckpt_codec = meta.get("codec", "zstd")
    if ckpt_codec != entropy.CODEC_NAME:
        raise CheckpointError(
            f"checkpoint was written with {ckpt_codec!r} but this host's "
            f"entropy codec is {entropy.CODEC_NAME!r} "
            f"(install zstandard to read zstd checkpoints)"
        )
    raw = entropy.decompress(payload, max_output_size=meta["raw_len"])
    leaves = _deserialize_leaves(raw)
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(leaves) != len(t_leaves):
        raise CheckpointError(
            f"leaf count mismatch: ckpt {len(leaves)} vs template {len(t_leaves)}"
        )
    arrays = [jnp.asarray(l).astype(t.dtype) for l, t in zip(leaves, t_leaves)]
    if shardings is not None:
        s_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "mesh")
        )
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, s_leaves)]
    return step, jax.tree_util.tree_unflatten(treedef, arrays)
