"""Fault-tolerant checkpointing through the Salient Store archival pipeline.

Checkpoints are archival data: each save is chunked into S logical storage
shards (stripe tiles) and pushed through the SAME one-launch archival
kernel as the video archive (``repro.kernels.fused``): interleaved-rANS
entropy coding + stream pack + ChaCha20 + XOR + RAID-5 P / RAID-6 Q in a
single launch over the stripe (``codec_name="zstd"``/``"zlib"`` keeps the
host codec + chained ``repro.kernels.seal`` as a fallback).  With a ``seal_key``
the per-shard ChaCha session keys are R-LWE-KEM-encapsulated (true
encryption, secret needed to restore); without one they are stored in the
manifest — whitening only, but the datapath and on-disk layout stay
identical, so the parity tier is always exercised.  Shards are committed
through the power-loss-safe ``Journal`` (write payload -> fsync -> manifest
record).  Restore tolerates:

  * torn writes (journal replay drops them),
  * up to two missing/corrupt shards per checkpoint (parity rebuild over the
    sealed bodies, then one fused unseal of the repaired stripe — the same
    recompute-and-compare integrity check the archive restore uses),
  * a different mesh on restart (elastic: arrays are saved unsharded-logical
    and resharded by the caller's NamedShardings at load).
"""

from __future__ import annotations

import io
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import compress as entropy
from repro.core.archival import raid
from repro.core.crypto import rlwe
from repro.core.crypto.hybrid import encapsulate_session
from repro.core.csd.failure import Journal
from repro.kernels.entropy import ops as entropy_ops
from repro.kernels.fused import ops as fused_ops
from repro.kernels.seal import ops as seal_ops

__all__ = ["save_checkpoint", "load_checkpoint", "load_checkpoint_meta",
           "latest_step", "CheckpointError"]


class CheckpointError(RuntimeError):
    pass


def _serialize_tree(tree) -> bytes:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    buf = io.BytesIO()
    np.savez(
        buf,
        __treedef__=np.frombuffer(str(treedef).encode(), dtype=np.uint8),
        **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)},
    )
    return buf.getvalue()


def _deserialize_leaves(blob: bytes) -> List[np.ndarray]:
    buf = io.BytesIO(blob)
    with np.load(buf) as z:
        n = sum(1 for k in z.files if k.startswith("leaf_"))
        return [z[f"leaf_{i}"] for i in range(n)]


def _session_material(
    meta: Dict[str, Any],
    n_shards: int,
    step: int,
    seal_key: Optional[rlwe.PublicKey],
    rng: Optional[jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    """(S, 8) uint32 ChaCha keys + (S, 3) nonces for the stripe launch.

    Sealed: fresh per-shard session keys under the lattice KEM (ciphertexts
    into the manifest, keys never stored).  Unsealed: manifest-stored
    whitening keys — restore needs no secret and the kernel path is shared.
    """
    if seal_key is not None:
        if rng is None:
            rng = jax.random.PRNGKey(step)
        mats = [
            encapsulate_session(seal_key, jax.random.fold_in(rng, i))
            for i in range(n_shards)
        ]
        meta["kem_c1"] = [np.asarray(m.kem_c1).tolist() for m in mats]
        meta["kem_c2"] = [np.asarray(m.kem_c2).tolist() for m in mats]
        meta["nonce"] = [np.asarray(m.nonce).tolist() for m in mats]
        return (
            jnp.stack([m.session for m in mats]),
            jnp.stack([m.nonce for m in mats]),
        )
    rk = np.random.default_rng(step)
    keys = rk.integers(0, 2**32, (n_shards, 8), dtype=np.uint32)
    nonces = rk.integers(0, 2**32, (n_shards, 3), dtype=np.uint32)
    meta["keys"] = keys.tolist()
    meta["nonce"] = nonces.tolist()
    return jnp.asarray(keys), jnp.asarray(nonces)


def save_checkpoint(
    root: str,
    step: int,
    state: Any,
    *,
    n_shards: int = 4,
    parity: str = "raid6",
    seal_key: Optional[rlwe.PublicKey] = None,
    rng: Optional[jax.Array] = None,
    zstd_level: int = 3,
    codec_name: str = "rans",
    extra_meta: Optional[Dict[str, Any]] = None,
) -> Dict:
    """state: arbitrary pytree (params/opt/extra). Returns the manifest.

    ``codec_name="rans"`` (default) chunks the RAW serialized tree into S
    shards and runs the entropy stage on-device, chained straight into the
    fused seal launch — the checkpoint bytes never visit a host codec.
    ``"zstd"``/``"zlib"`` keeps the legacy host path (must match what this
    host's ``repro.common.compress`` actually provides).

    ``extra_meta``: JSON-able caller payload stored under ``meta["extra"]``
    — the trainer persists its exemplar centroids here so novelty scoring
    (and catalog queries) survive a restart instead of re-learning the
    known distribution from scratch.  Read it back with
    ``load_checkpoint_meta``.
    """
    j = Journal(root)
    raw = _serialize_tree(state)

    meta: Dict[str, Any] = {
        "step": int(step),
        "n_shards": n_shards,
        "parity": parity,
        "raw_len": len(raw),
        "sealed": bool(seal_key is not None),
        "codec": codec_name,
        "extra": extra_meta or {},
    }

    if codec_name == "rans":
        # chunk the RAW payload into S stripe tiles; entropy + seal run as
        # ONE on-device launch (repro.kernels.fused) — the checkpoint bytes
        # never visit a host codec and the packed streams never visit HBM.
        # Big states grow the shard count so each tile stays inside the
        # coder's per-shard bound (entropy_ops.MAX_ROWS rows of 128 lanes)
        # instead of failing the encode launch.
        max_shard = entropy_ops.MAX_ROWS * 128
        n_shards = max(n_shards, -(-len(raw) // max_shard))
        meta["n_shards"] = n_shards
        shard_len = (len(raw) + n_shards - 1) // n_shards
        padded = raw + b"\0" * (shard_len * n_shards - len(raw))
        flats = [
            jnp.asarray(
                np.frombuffer(
                    padded[i * shard_len : (i + 1) * shard_len], np.int8
                )
            )
            for i in range(n_shards)
        ]
        meta["shard_len"] = shard_len
        keys, nonces = _session_material(meta, n_shards, step, seal_key, rng)
        stripe, emetas = fused_ops.entropy_seal_stripe(
            flats, keys, nonces, parity=parity
        )
        meta["entropy"] = emetas
        meta["comp_len"] = sum(m["n_comp"] for m in emetas)
    else:
        try:
            comp = entropy.compress_as(codec_name, raw, level=zstd_level)
        except ValueError as e:
            raise CheckpointError(f"host entropy codec: {e}") from e
        meta["comp_len"] = len(comp)
        shard_len = (len(comp) + n_shards - 1) // n_shards
        padded = comp + b"\0" * (shard_len * n_shards - len(comp))
        flats = [
            jnp.asarray(
                np.frombuffer(padded[i * shard_len : (i + 1) * shard_len], np.int8)
            )
            for i in range(n_shards)
        ]
        meta["shard_len"] = shard_len
        keys, nonces = _session_material(meta, n_shards, step, seal_key, rng)
        stripe = seal_ops.seal_stripe(flats, keys, nonces, parity=parity)
    meta["n_words"] = [int(n) for n in stripe.n_words]
    meta["pad_words"] = int(stripe.pad_words)

    names = []
    for i in range(n_shards):
        name = f"ckpt_{step:08d}_shard{i}.bin"
        body = np.asarray(stripe.body(i)).astype("<u4").tobytes()
        j.commit(name, body, {"step": step, "shard": i})
        names.append(name)
    if parity != "none":
        p_u8 = np.asarray(
            jax.lax.bitcast_convert_type(stripe.p, jnp.uint8)
        ).reshape(-1)
        j.commit(f"ckpt_{step:08d}_parity_p.bin", p_u8.tobytes(), {"step": step})
        if stripe.q is not None:
            q_u8 = np.asarray(
                jax.lax.bitcast_convert_type(stripe.q, jnp.uint8)
            ).reshape(-1)
            j.commit(
                f"ckpt_{step:08d}_parity_q.bin", q_u8.tobytes(), {"step": step}
            )
    meta["shards"] = names
    j.commit(f"ckpt_{step:08d}_manifest.json", json.dumps(meta).encode(), {"step": step})
    return meta


def load_checkpoint_meta(root: str, step: Optional[int] = None) -> Dict:
    """The manifest of a checkpoint (``step=None`` -> latest) WITHOUT
    decoding the stripe — the host-metadata tier (incl. ``extra``)."""
    j = Journal(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise CheckpointError(f"no checkpoint in {root}")
    return json.loads(j.read(f"ckpt_{step:08d}_manifest.json"))


def latest_step(root: str) -> Optional[int]:
    j = Journal(root)
    steps = [
        r["meta"]["step"]
        for r in j.replay()
        if r["name"].endswith("_manifest.json") and "step" in r.get("meta", {})
    ]
    return max(steps) if steps else None


def _read_bodies(
    j: Journal, root: str, meta: Dict
) -> Tuple[List[Optional[bytes]], List[int]]:
    bodies: List[Optional[bytes]] = []
    missing: List[int] = []
    for i, name in enumerate(meta["shards"]):
        path = os.path.join(root, name)
        want = 4 * meta["n_words"][i]
        if os.path.exists(path) and os.path.getsize(path) == want:
            bodies.append(j.read(name))
        else:
            bodies.append(None)
            missing.append(i)
    return bodies, missing


def _rebuild_missing(
    j: Journal, meta: Dict, bodies: List[Optional[bytes]], missing: List[int]
) -> List[bytes]:
    """Parity-rebuild lost sealed bodies (host RAID math over u8 rows)."""
    step, pad_u8 = meta["step"], 4 * meta["pad_words"]
    rows: List[Optional[jnp.ndarray]] = [
        None
        if b is None
        else jnp.asarray(np.frombuffer(b.ljust(pad_u8, b"\0"), np.uint8))
        for b in bodies
    ]
    p = jnp.asarray(
        np.frombuffer(j.read(f"ckpt_{step:08d}_parity_p.bin"), np.uint8)
    )
    if meta["parity"] == "raid5":
        if len(missing) != 1:
            raise CheckpointError(
                f"shards {missing} lost; RAID-5 covers one erasure"
            )
        rows[missing[0]] = raid.raid5_reconstruct(rows, p, missing[0])
    else:
        q = jnp.asarray(
            np.frombuffer(j.read(f"ckpt_{step:08d}_parity_q.bin"), np.uint8)
        )
        rows = raid.raid6_reconstruct(rows, p, q, missing)
    return [
        bytes(np.asarray(r))[: 4 * meta["n_words"][i]]
        for i, r in enumerate(rows)
    ]


def _stripe_keys(meta: Dict, secret: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    nonces = jnp.asarray(meta["nonce"], jnp.uint32)
    if meta["sealed"]:
        if secret is None:
            raise CheckpointError("checkpoint is sealed; need the R-LWE secret")
        keys = jnp.stack(
            [
                rlwe.kem_decapsulate(
                    secret,
                    rlwe.Ciphertext(
                        jnp.asarray(meta["kem_c1"][i], jnp.int32),
                        jnp.asarray(meta["kem_c2"][i], jnp.int32),
                    ),
                )
                for i in range(len(meta["shards"]))
            ]
        )
    else:
        keys = jnp.asarray(meta["keys"], jnp.uint32)
    return keys, nonces


def _verify_stripe_parity(j: Journal, meta: Dict, p2, q2) -> None:
    step = meta["step"]
    for name, got in (("p", p2), ("q", q2)):
        if got is None:
            continue
        want = np.frombuffer(
            j.read(f"ckpt_{step:08d}_parity_{name}.bin"), np.uint8
        )
        got_u8 = np.asarray(
            jax.lax.bitcast_convert_type(got, jnp.uint8)
        ).reshape(-1)
        if not np.array_equal(got_u8, want):
            raise CheckpointError(
                f"checkpoint parity mismatch on {name.upper()} "
                f"(corrupt shard beyond what erasure coding can see)"
            )


def load_checkpoint(
    root: str,
    template: Any,
    step: Optional[int] = None,
    *,
    secret: Optional[jax.Array] = None,
    shardings: Any = None,
) -> Tuple[int, Any]:
    """Restore into the structure of ``template``; reshard with ``shardings``
    (a matching pytree of NamedSharding) if given — elastic restarts pass the
    NEW mesh's shardings here."""
    j = Journal(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise CheckpointError(f"no checkpoint in {root}")
    meta = json.loads(j.read(f"ckpt_{step:08d}_manifest.json"))
    if "n_words" not in meta:
        raise CheckpointError(
            f"checkpoint at step {step} predates the fused-kernel stripe "
            "format (manifest has no 'n_words'); re-save it with this version"
        )

    bodies, missing = _read_bodies(j, root, meta)
    if missing:
        if meta["parity"] == "none":
            raise CheckpointError(f"shards {missing} lost and no parity")
        bodies = _rebuild_missing(j, meta, bodies, missing)

    # one fused unseal of the whole stripe (keystream + XOR + unpack), with
    # parity recomputed from the bodies as stored for the integrity check
    keys, nonces = _stripe_keys(meta, secret)
    n_words = tuple(meta["n_words"])
    R = meta["pad_words"] // seal_ops.LANES
    sealed = jnp.stack(
        [
            jnp.pad(
                jnp.asarray(np.frombuffer(b, "<u4").copy()), (0, R * seal_ops.LANES - n)
            ).reshape(R, seal_ops.LANES)
            for b, n in zip(bodies, n_words)
        ]
    )
    ckpt_codec = meta.get("codec", "zstd")
    if ckpt_codec == "rans":
        n_i8 = tuple(m["n_comp"] for m in meta["entropy"])
    else:
        n_i8 = (meta["shard_len"],) * len(bodies)
    packed = seal_ops.SealedStripe(sealed, None, None, n_words, n_i8)
    flats, p2, q2 = seal_ops.unseal_stripe(
        packed, keys, nonces, parity=meta["parity"]
    )
    if meta["parity"] != "none":
        _verify_stripe_parity(j, meta, p2, q2)

    if ckpt_codec == "rans":
        # on-device entropy decode of the unsealed streams, then reassemble
        raws = entropy_ops.decode_payloads(flats, meta["entropy"])
        raw = b"".join(np.asarray(f, np.int8).tobytes() for f in raws)
        raw = raw[: meta["raw_len"]]
    else:
        payload = b"".join(np.asarray(f, np.int8).tobytes() for f in flats)
        payload = payload[: meta["comp_len"]]
        try:
            raw = entropy.decompress_as(
                ckpt_codec, payload, max_output_size=meta["raw_len"]
            )
        except ValueError as e:
            raise CheckpointError(
                f"checkpoint was written with {ckpt_codec!r}: {e}"
            ) from e
    leaves = _deserialize_leaves(raw)
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(leaves) != len(t_leaves):
        raise CheckpointError(
            f"leaf count mismatch: ckpt {len(leaves)} vs template {len(t_leaves)}"
        )
    arrays = [jnp.asarray(l).astype(t.dtype) for l, t in zip(leaves, t_leaves)]
    if shardings is not None:
        s_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "mesh")
        )
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, s_leaves)]
    return step, jax.tree_util.tree_unflatten(treedef, arrays)
