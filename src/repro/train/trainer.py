"""Continuous-learning trainer with the Salient Store archival loop.

Per step (Fig. 1's dual-stream dataflow):
  1. ingest a clip batch per stream (placement engine decides which storage
     shard owns each stream — Table 2 load balancing);
  2. run the frozen backbone ONCE: its features feed both exemplar selection
     (k-means++ novelty -> train-or-archive) and the codec (compute reuse);
  3. novel samples -> codec training step (Alg. 2);
  4. known samples -> archive ingest: layered-codec encode, then the GOP
     joins the multi-stream ``StripeCoalescer`` — ragged GOPs from many
     cameras are bucketed into full stripes so one fused seal launch (per
     mesh shard, when a storage mesh is attached) covers S GOPs instead of
     one launch each; completed stripes are sealed + parity-coded and
     journal-committed;
  5. heartbeat the straggler monitor; rebalance placement when flagged;
  6. periodic checkpoint (pending stripes drain first; the checkpoint itself
     runs compressed+sealed+parity through the same fused kernel,
     train/checkpoint).

Everything is pure JAX + the core modules; the same loop drives the LM path
through ``lm_train_step`` (distributed/steps.py) with codec-based gradient
compression as an option.
"""

from __future__ import annotations

import re
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archival.exemplar import select_exemplars
from repro.core.archival.pipeline import ArchiveConfig, encode_gop_payload
from repro.core.codec.feature_extractor import extract_features
from repro.core.codec.layered_codec import CodecConfig, init_codec, psnr
from repro.core.codec.training import (
    CodecTrainConfig,
    codec_train_step,
    init_codec_trainer,
)
from repro.core.crypto import rlwe
from repro.core.csd.failure import Journal, StragglerMonitor
from repro.core.csd.placement import Placement, balance_streams, rebalance
from repro.data.video import VideoStream, render_clip
from repro.distributed.archival import StripeCoalescer, seal_coalesced_stripe
from repro.train.checkpoint import latest_step, load_checkpoint, save_checkpoint

__all__ = ["SalientTrainer", "TrainerConfig", "StepReport"]


class TrainerConfig(NamedTuple):
    codec: CodecConfig = CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)
    archive: Optional[ArchiveConfig] = None  # derived from codec if None
    n_shards: int = 4
    clip_len: int = 3
    exemplar_k: int = 4
    n_train_exemplars: int = 2
    checkpoint_every: int = 5
    parity: str = "raid6"


class StepReport(NamedTuple):
    step: int
    codec_loss: float
    psnr: float
    archived_streams: int  # GOPs sealed to the journal this step
    archive_bytes: int
    novel_selected: int
    rebalanced: bool
    stripes_sealed: int = 0  # fused launches this step (coalesced stripes)
    pending_gops: int = 0  # encoded GOPs still waiting for stripe-mates


class SalientTrainer:
    def __init__(
        self,
        streams: List[VideoStream],
        workdir: str,
        cfg: TrainerConfig = TrainerConfig(),
        seed: int = 0,
        mesh=None,
    ):
        """``mesh``: optional storage mesh — when given, stripe seals are
        shard_map'd over its ``data`` axis (one fused launch per mesh shard,
        cross-shard parity reduce) instead of running on one device."""
        self.cfg = cfg
        self.streams = streams
        self.workdir = workdir
        self.mesh = mesh
        key = jax.random.PRNGKey(seed)
        kc, kk = jax.random.split(key)
        self.codec_params = init_codec(kc, cfg.codec)
        self.train_cfg = CodecTrainConfig(codec=cfg.codec)
        self.trainable, self.frozen, self.opt_state = init_codec_trainer(
            self.codec_params, self.train_cfg
        )
        self.pub, self.secret = rlwe.keygen(kk)
        self.archive_cfg = cfg.archive or ArchiveConfig(
            codec=cfg.codec, parity=cfg.parity
        )
        self.placement: Placement = balance_streams(
            [s.fps for s in streams], cfg.n_shards
        )
        self.monitor = StragglerMonitor(cfg.n_shards)
        self.journal = Journal(workdir)
        self.coalescer = StripeCoalescer(cfg.n_shards)
        self._archive_key = jax.random.PRNGKey(seed * 31 + 7)
        # resume the stripe sequence from the journal: a restart must not
        # overwrite committed stripes or re-derive their key/nonce material
        self._stripe_seq = max(
            (
                int(m.group(1)) + 1
                for m in (
                    re.match(r"archive_(\d+)\.bin$", r["name"])
                    for r in self.journal.replay()
                )
                if m
            ),
            default=0,
        )
        self.step = 0
        self.known_centroids = None
        self._maybe_restore()

    # ------------------------------------------------------------- state
    def _params(self):
        return dict(self.frozen, **self.trainable)

    def _maybe_restore(self):
        st = latest_step(self.workdir)
        if st is None:
            return
        template = {
            "trainable": self.trainable,
            "opt": self.opt_state,
            "step": jnp.zeros((), jnp.int32),
        }
        _, state = load_checkpoint(self.workdir, template, st)
        self.trainable = state["trainable"]
        self.opt_state = state["opt"]
        self.step = int(state["step"])

    def checkpoint(self):
        # drain pending ragged stripes first so a restart loses no GOP
        self._seal_and_commit(self.coalescer.flush())
        save_checkpoint(
            self.workdir,
            self.step,
            {
                "trainable": self.trainable,
                "opt": self.opt_state,
                "step": jnp.asarray(self.step, jnp.int32),
            },
            n_shards=self.cfg.n_shards,
            parity=self.cfg.parity,
        )

    # ----------------------------------------------------------- archival
    def _seal_and_commit(self, stripes) -> Tuple[int, int]:
        """Seal coalesced stripes (one fused launch each, sharded over the
        storage mesh when attached) and journal-commit bodies + parity.

        Returns (GOPs sealed, sealed bytes).
        """
        n_gops, total_bytes = 0, 0
        for cs in stripes:
            key = jax.random.fold_in(self._archive_key, self._stripe_seq)
            stripe = seal_coalesced_stripe(
                self.pub, cs, key, self.archive_cfg, mesh=self.mesh
            )
            rec_name = f"archive_{self._stripe_seq:08d}"
            self._stripe_seq += 1
            body = b"".join(
                np.asarray(b.sealed.body).astype("<u4").tobytes()
                for b in stripe.blocks
            )
            self.journal.commit(
                rec_name + ".bin",
                body,
                {
                    "step": self.step,
                    "streams": [g.stream_id for g in cs.gops],
                    "shards": [
                        (g.meta or {}).get("shard") for g in cs.gops
                    ],
                    "parity": self.archive_cfg.parity,
                    "body_words": [
                        int(b.sealed.body.size) for b in stripe.blocks
                    ],
                },
            )
            if stripe.parity is not None:
                # persist P/Q so shard loss in the .bin is actually recoverable
                p_u8 = np.asarray(stripe.parity["p"])
                q_u8 = stripe.parity.get("q")
                self.journal.commit(
                    rec_name + ".parity.bin",
                    p_u8.tobytes()
                    + (np.asarray(q_u8).tobytes() if q_u8 is not None else b""),
                    {
                        "step": self.step,
                        "pad_to": int(stripe.parity["pad_to"]),
                        "p_len": int(p_u8.size),
                        "has_q": q_u8 is not None,
                    },
                )
            n_gops += len(stripe.blocks)
            total_bytes += sum(
                int(b.sealed.body.size) * 4 for b in stripe.blocks
            )
        return n_gops, total_bytes

    # -------------------------------------------------------------- step
    def run_step(self, shard_times: Optional[List[float]] = None) -> StepReport:
        cfg = self.cfg
        step_key = jax.random.PRNGKey(self.step * 977 + 13)
        params = self._params()

        # 1. ingest one clip per stream
        clips = {
            s.stream_id: render_clip(s, self.step * cfg.clip_len, cfg.clip_len)
            for s in self.streams
        }

        # 2. shared backbone features -> exemplar selection (per stream,
        #    pooled over space/time)
        feats = []
        for sid, clip in clips.items():
            f = extract_features(params["extractor"], clip)  # (T, h, w, C)
            feats.append(f.mean(axis=(0, 1, 2)))
        fmat = jnp.stack(feats)  # (n_streams, C)
        split = select_exemplars(
            step_key,
            fmat,
            k=min(cfg.exemplar_k, fmat.shape[0]),
            n_train=min(cfg.n_train_exemplars, fmat.shape[0]),
            known_centroids=self.known_centroids,
        )
        self.known_centroids = split.centroids
        train_ids = [int(i) for i in np.asarray(split.train_idx)]
        archive_ids = [int(i) for i in np.asarray(split.archive_idx)]

        # 3. codec training on the novel clips (Alg. 2)
        train_clips = jnp.stack(
            [clips[self.streams[i].stream_id] for i in train_ids], axis=1
        )  # (T, B, H, W, 3)
        self.trainable, self.opt_state, metrics = codec_train_step(
            self.trainable, self.frozen, self.opt_state, self.train_cfg, train_clips
        )

        # 4. archive ingest: codec-encode the known clips, coalesce ragged
        # GOPs across streams into full stripes; every completed stripe is
        # packed + sealed + parity-coded in ONE fused kernel launch (per
        # mesh shard when a storage mesh is attached)
        params = self._params()
        recon_psnrs = []
        ready = []
        for i in archive_ids:
            sid = self.streams[i].stream_id
            frames = clips[sid][:, None]  # (T, 1, H, W, 3)
            flat, manifest, recons = encode_gop_payload(
                params, frames, self.archive_cfg
            )
            recon_psnrs.append(float(psnr(recons, frames)))
            ready += self.coalescer.add(
                sid, flat, manifest,
                meta={"shard": self.placement.assignment[i]},
            )
        n_sealed, total_bytes = self._seal_and_commit(ready)

        # 5. straggler handling
        rebalanced = False
        if shard_times is not None:
            status = self.monitor.update(shard_times)
            if status.stragglers or status.dead:
                self.placement = rebalance(
                    self.placement,
                    [s.fps for s in self.streams],
                    status.speed,
                )
                rebalanced = True

        # 6. checkpoint
        self.step += 1
        if self.step % cfg.checkpoint_every == 0:
            self.checkpoint()

        return StepReport(
            step=self.step,
            codec_loss=float(metrics["loss"]),
            psnr=float(np.mean(recon_psnrs)) if recon_psnrs else float("nan"),
            archived_streams=n_sealed,
            archive_bytes=total_bytes,
            novel_selected=len(train_ids),
            rebalanced=rebalanced,
            stripes_sealed=len(ready),
            pending_gops=self.coalescer.n_pending,
        )
