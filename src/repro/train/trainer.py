"""Continuous-learning trainer with the full Salient Store archival loop.

Per step (Fig. 1's dual-stream dataflow, now closed into a cycle):
  1. ingest a clip batch per stream (placement engine decides which storage
     shard owns each stream — Table 2 load balancing);
  2. run the frozen backbone ONCE: its features feed both exemplar selection
     (k-means++ novelty -> train-or-archive) and the codec (compute reuse);
  3. REPLAY: every ``replay_every`` steps the trainer queries the salience
     catalog for the most-novel archived GOPs (``plan_retrieval`` against
     the current centroids, byte-budgeted), restores ONLY the planned shard
     subsets (degraded parity reads when a shard's CSD is flagged dead),
     and folds the decoded GOPs into the training batch — the archive
     participates in learning instead of being write-only;
  4. novel samples + replayed exemplars -> codec training step (Alg. 2);
  5. known samples -> archive ingest: layered-codec encode, then the GOP
     joins the multi-stream ``StripeCoalescer``; completed stripes are
     sealed + parity-coded, journal-committed (bodies, parity AND the
     replicated manifest record), and indexed into the ``StripeCatalog``
     with the GOP's pooled feature + novelty (descriptors are computed
     pre-seal, so later queries never decode a payload);
  6. heartbeat the straggler monitor; rebalance placement when flagged and
     remember dead shards so the next replay plans degraded reads;
  7. periodic checkpoint (pending stripes drain first; exemplar centroids
     ride in the checkpoint meta so novelty scoring survives a restart),
     then the stripe lifecycle tier retires archives past their TTL.

Durability interleave: every ``scrub_every`` steps a byte-budgeted
background scrub round (``core/archival/scrub.py``) re-reads journaled
stripes, parity-verifies them through the fused unseal (zero key material
moves), locates corrupt shards by P/Q syndrome and re-commits repaired
bodies — so silent corruption is found and fixed while training continues,
not discovered by a failed replay read months later.

Everything is pure JAX + the core modules; the same loop drives the LM path
through ``lm_train_step`` (distributed/steps.py) with codec-based gradient
compression as an option.
"""

from __future__ import annotations

import json
import os
import re
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archival.catalog import StripeCatalog, gop_descriptors
from repro.core.archival.exemplar import select_exemplars
from repro.core.archival.scrub import (
    ScrubRound,
    StripeScrubber,
    plan_retirement,
    retire_stripes,
)
from repro.core.archival.pipeline import (
    ArchiveConfig,
    ArchivedBlock,
    StripeArchive,
    encode_gop_payload,
    restore_stripe,
    stripe_manifests,
    stripe_manifests_from_json,
    stripe_manifests_to_json,
)
from repro.core.codec.feature_extractor import extract_features
from repro.core.codec.layered_codec import CodecConfig, init_codec, psnr
from repro.core.codec.training import (
    CodecTrainConfig,
    codec_train_step,
    init_codec_trainer,
)
from repro.core.crypto import rlwe
from repro.core.crypto.hybrid import SealedBlock
from repro.core.csd.failure import Journal, StragglerMonitor
from repro.core.csd.placement import Placement, balance_streams, rebalance
from repro.core.csd.retrieval import ReadPlan, plan_retrieval
from repro.data.video import VideoStream, render_clip
from repro.distributed.archival import (
    StripeCoalescer,
    seal_coalesced_stripes,
)
from repro.obs import OBS, enable as obs_enable
from repro.obs.export import commit_jsonl, write_chrome_trace, write_jsonl
from repro.train.checkpoint import (
    latest_step,
    load_checkpoint,
    load_checkpoint_meta,
    save_checkpoint,
)

__all__ = ["SalientTrainer", "TrainerConfig", "StepReport"]


class TrainerConfig(NamedTuple):
    codec: CodecConfig = CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)
    archive: Optional[ArchiveConfig] = None  # derived from codec if None
    n_shards: int = 4
    clip_len: int = 3
    exemplar_k: int = 4
    n_train_exemplars: int = 2
    checkpoint_every: int = 5
    parity: str = "raid6"
    # replay: every N steps pull the top-k most-novel archived GOPs (within
    # the byte budget) back through the retrieval planner into the batch;
    # replay_every=0 disables the stage
    replay_every: int = 2
    replay_k: int = 2
    replay_budget_bytes: int = 1 << 20
    # background scrub: every N steps parity-verify journaled stripes on a
    # byte budget, repairing located corruption in place (0 = off).  Scrub
    # rounds interleave with replay — both are budgeted so neither starves
    # the other
    scrub_every: int = 0
    scrub_budget_bytes: int = 1 << 20
    # stripe lifecycle: at checkpoint time retire stripes whose every GOP
    # was sealed >= ttl steps ago (0 = off) and whose novelty vs the
    # current centroids is at most retire_max_novelty (None = age alone)
    retire_ttl_steps: int = 0
    retire_max_novelty: Optional[float] = None
    # straggler drain: force-flush coalescer buckets whose oldest GOP has
    # waited longer than this many microseconds (0 = off, buckets only
    # drain at checkpoint) — bounds GOP-to-commit tail latency when a
    # cold bucket never fills a stripe
    archive_deadline_us: float = 0.0
    # telemetry: enable the process-global repro.obs tier (spans + metrics
    # + byte-flow ledger) for this trainer; each StepReport then carries a
    # per-step snapshot and ``export_telemetry`` writes a Perfetto trace +
    # fsync'd JSONL log.  Off by default: every instrumented site then
    # costs a single branch.
    telemetry: bool = False


class StepReport(NamedTuple):
    step: int
    codec_loss: float
    psnr: float
    archived_streams: int  # GOPs sealed to the journal this step
    archive_bytes: int
    novel_selected: int
    rebalanced: bool
    stripes_sealed: int = 0  # fused launches this step (coalesced stripes)
    pending_gops: int = 0  # encoded GOPs still waiting for stripe-mates
    replayed_gops: int = 0  # archived GOPs pulled back into the batch
    replay_read_bytes: int = 0  # sealed bytes the retrieval plan touched
    replay_full_bytes: int = 0  # no-index baseline (whole catalog restore)
    replay_degraded: int = 0  # replayed GOPs that needed a parity rebuild
    scrub_stripes: int = 0  # stripes parity-verified this step
    scrub_bytes: int = 0  # sealed bytes the scrub pass recomputed over
    scrub_findings: int = 0  # corruptions detected this step
    scrub_repaired: int = 0  # ... of which repaired in place + re-verified
    retired_stripes: int = 0  # stripes journaled as retired this step
    # per-step telemetry snapshot when TrainerConfig.telemetry is on:
    # {"stages": {span -> dur_us}, "metrics": registry snapshot,
    #  "ledger": byte-flow report} — None when telemetry is off
    telemetry: Optional[Dict] = None


class SalientTrainer:
    def __init__(
        self,
        streams: List[VideoStream],
        workdir: str,
        cfg: TrainerConfig = TrainerConfig(),
        seed: int = 0,
        mesh=None,
    ):
        """``mesh``: optional storage mesh — when given, stripe seals are
        shard_map'd over its ``data`` axis (one fused launch per mesh shard,
        cross-shard parity reduce) instead of running on one device."""
        self.cfg = cfg
        self.streams = streams
        self.workdir = workdir
        self.mesh = mesh
        if cfg.telemetry:
            obs_enable()
        key = jax.random.PRNGKey(seed)
        kc, kk = jax.random.split(key)
        self.codec_params = init_codec(kc, cfg.codec)
        self.train_cfg = CodecTrainConfig(codec=cfg.codec)
        self.trainable, self.frozen, self.opt_state = init_codec_trainer(
            self.codec_params, self.train_cfg
        )
        self.pub, self.secret = rlwe.keygen(kk)
        self.archive_cfg = cfg.archive or ArchiveConfig(
            codec=cfg.codec, parity=cfg.parity
        )
        self.placement: Placement = balance_streams(
            [s.fps for s in streams], cfg.n_shards
        )
        self.monitor = StragglerMonitor(cfg.n_shards)
        self.journal = Journal(workdir)
        self.coalescer = StripeCoalescer(cfg.n_shards)
        # salience index over every sealed stripe; rebuilt from the journal
        # on restart so old archives stay queryable
        self.catalog = StripeCatalog(self.journal)
        self.catalog.load()
        self._stripes: Dict[str, StripeArchive] = {}  # hot in-memory bodies
        self._dead_shards: List[int] = []  # monitor-flagged, for replay plans
        self._archive_key = jax.random.PRNGKey(seed * 31 + 7)
        # resume the stripe sequence from the journal: a restart must not
        # overwrite committed stripes or re-derive their key/nonce material
        self._stripe_seq = max(
            (
                int(m.group(1)) + 1
                for m in (
                    re.match(r"archive_(\d+)\.bin$", r["name"])
                    for r in self.journal.replay()
                )
                if m
            ),
            default=0,
        )
        # background scrubber over the journaled archive; the cursor lives
        # on the scrubber so successive rounds walk the whole archive even
        # when each round's budget covers a fraction of it
        self._scrub_recs: Dict[str, Dict] = {}
        self._scrubber = StripeScrubber(self._scrub_get, self._scrub_put)
        self._last_retired = 0
        self.step = 0
        self.known_centroids = None
        self._maybe_restore()

    # ------------------------------------------------------------- state
    def _params(self):
        return dict(self.frozen, **self.trainable)

    def _maybe_restore(self):
        st = latest_step(self.workdir)
        if st is None:
            return
        template = {
            "trainable": self.trainable,
            "opt": self.opt_state,
            "step": jnp.zeros((), jnp.int32),
        }
        _, state = load_checkpoint(self.workdir, template, st)
        self.trainable = state["trainable"]
        self.opt_state = state["opt"]
        self.step = int(state["step"])
        # exemplar centroids ride in the checkpoint meta: novelty scoring
        # (and catalog queries) resume against the learned distribution
        # instead of re-fitting it from scratch
        cents = load_checkpoint_meta(self.workdir, st).get("extra", {}).get(
            "centroids"
        )
        if cents is not None:
            self.known_centroids = jnp.asarray(cents, jnp.float32)

    def checkpoint(self):
        # drain pending ragged stripes first so a restart loses no GOP
        self._seal_and_commit(self.coalescer.flush())
        self._last_retired = self._retire_expired()
        extra = {}
        if self.known_centroids is not None:
            extra["centroids"] = np.asarray(
                self.known_centroids, np.float32
            ).tolist()
        save_checkpoint(
            self.workdir,
            self.step,
            {
                "trainable": self.trainable,
                "opt": self.opt_state,
                "step": jnp.asarray(self.step, jnp.int32),
            },
            n_shards=self.cfg.n_shards,
            parity=self.cfg.parity,
            extra_meta=extra,
        )

    # ----------------------------------------------------------- archival
    def _seal_and_commit(self, stripes) -> Tuple[int, int]:
        """Seal coalesced stripes (batched: same-bucket stripes share ONE
        fused launch, sharded over the storage mesh when attached),
        journal-commit bodies + parity + the replicated manifest record,
        and index the stripe into the salience catalog so retrieval plans
        can find its GOPs.

        Returns (GOPs sealed, sealed bytes).
        """
        stripes = list(stripes)
        n_gops, total_bytes = 0, 0
        if not stripes:
            return n_gops, total_bytes
        # draw every stripe's key/name up front (sequence order fixed
        # before any sealing — bit-identical to sealing one at a time),
        # then hand the whole batch to the fused path
        keys, rec_names = [], []
        for _ in stripes:
            keys.append(
                jax.random.fold_in(self._archive_key, self._stripe_seq)
            )
            rec_names.append(f"archive_{self._stripe_seq:08d}")
            self._stripe_seq += 1
        sealed = seal_coalesced_stripes(
            self.pub, stripes, keys, self.archive_cfg, mesh=self.mesh
        )
        for cs, rec_name, stripe in zip(stripes, rec_names, sealed):
            body = b"".join(
                np.asarray(b.sealed.body).astype("<u4").tobytes()
                for b in stripe.blocks
            )
            self.journal.commit(
                rec_name + ".bin",
                body,
                {
                    "step": self.step,
                    "streams": [g.stream_id for g in cs.gops],
                    "shards": [
                        (g.meta or {}).get("shard") for g in cs.gops
                    ],
                    "parity": self.archive_cfg.parity,
                    "body_words": [
                        int(b.sealed.body.size) for b in stripe.blocks
                    ],
                },
            )
            # replicated metadata tier: KEM polys, nonces and the packing
            # manifest, so a restarted trainer (or a degraded read) can
            # rebuild and decode this stripe from the journal alone
            self.journal.commit(
                rec_name + ".manifest.json",
                json.dumps(
                    stripe_manifests_to_json(stripe_manifests(stripe))
                ).encode(),
                {"step": self.step, "kind": "stripe_manifest"},
            )
            if stripe.parity is not None:
                # persist P/Q so shard loss in the .bin is actually recoverable
                p_u8 = np.asarray(stripe.parity["p"])
                q_u8 = stripe.parity.get("q")
                self.journal.commit(
                    rec_name + ".parity.bin",
                    p_u8.tobytes()
                    + (np.asarray(q_u8).tobytes() if q_u8 is not None else b""),
                    {
                        "step": self.step,
                        "pad_to": int(stripe.parity["pad_to"]),
                        "p_len": int(p_u8.size),
                        "has_q": q_u8 is not None,
                    },
                )
            # salience index: pooled feature + novelty recorded PRE-seal by
            # the exemplar stage rode along in the coalescer meta
            self.catalog.add_stripe(
                rec_name,
                stripe,
                gop_descriptors(cs.gops, self.catalog.feature_dim),
                sealed_step=self.step,
            )
            self._cache_stripe(rec_name, stripe)
            n_gops += len(stripe.blocks)
            total_bytes += sum(
                int(b.sealed.body.size) * 4 for b in stripe.blocks
            )
        return n_gops, total_bytes

    # ---------------------------------------------------------- retrieval
    # sealed bodies are already durable in the journal; the in-memory copy
    # is only a hot cache for replay, so it stays bounded
    STRIPE_CACHE_MAX = 16

    def _cache_stripe(self, rec_name: str, stripe: StripeArchive) -> None:
        self._stripes[rec_name] = stripe
        while len(self._stripes) > self.STRIPE_CACHE_MAX:
            self._stripes.pop(next(iter(self._stripes)))  # oldest first

    def _load_stripe(
        self, rec_name: str, recs: Optional[Dict[str, Dict]] = None
    ) -> StripeArchive:
        """Rebuild a sealed stripe from the journal (restart path): body
        words from the .bin record, KEM/nonce/manifest from the replicated
        manifest record, parity strips from the .parity.bin record.
        ``recs``: pre-scanned ``{name: record}`` journal map, so one replay
        round doing many loads scans the journal once."""
        if recs is None:
            recs = {r["name"]: r for r in self.journal.replay()}
        body_rec = recs.get(rec_name + ".bin")
        if body_rec is None:
            raise KeyError(f"stripe {rec_name} not in journal")
        mfs = stripe_manifests_from_json(
            json.loads(self.journal.read(rec_name + ".manifest.json"))
        )
        words = np.frombuffer(self.journal.read(rec_name + ".bin"), "<u4")
        blocks, off = [], 0
        for m, n in zip(mfs, body_rec["meta"]["body_words"]):
            blocks.append(
                ArchivedBlock(
                    SealedBlock(
                        m["kem_c1"], m["kem_c2"], m["nonce"],
                        jnp.asarray(words[off : off + n].copy()), int(n),
                    ),
                    m["manifest"],
                )
            )
            off += int(n)
        parity = None
        prec = recs.get(rec_name + ".parity.bin")
        if prec is not None:
            raw = np.frombuffer(
                self.journal.read(rec_name + ".parity.bin"), np.uint8
            )
            p_len = int(prec["meta"]["p_len"])
            parity = {
                "p": jnp.asarray(raw[:p_len]),
                "pad_to": int(prec["meta"]["pad_to"]),
            }
            if prec["meta"].get("has_q"):
                parity["q"] = jnp.asarray(raw[p_len:])
        return StripeArchive(blocks, parity)

    def _get_stripe(
        self, rec_name: str, recs: Optional[Dict[str, Dict]] = None
    ) -> StripeArchive:
        stripe = self._stripes.get(rec_name)
        if stripe is None:
            stripe = self._load_stripe(rec_name, recs)
            self._cache_stripe(rec_name, stripe)
        return stripe

    # --------------------------------------------------- scrub + lifecycle
    def _scrub_get(self, rec_name: str) -> StripeArchive:
        # journal truth, NOT the hot cache: disk corruption only shows up
        # when the bytes are re-read, and the scrub recs map is built with
        # verify_crc=False so known-corrupt bodies still load for repair
        return self._load_stripe(rec_name, self._scrub_recs)

    def _scrub_put(self, rec_name: str, stripe: StripeArchive) -> None:
        """Write a scrub-repaired stripe back: re-commit the body and
        parity records (the journal's newest record for a name wins on
        load, and the fresh crc32 re-arms silent-corruption detection)."""
        body = b"".join(
            np.asarray(b.sealed.body).astype("<u4").tobytes()
            for b in stripe.blocks
        )
        old = self._scrub_recs[rec_name + ".bin"]
        self.journal.commit(
            rec_name + ".bin", body,
            dict(old["meta"], scrub_repaired_step=self.step),
        )
        if stripe.parity is not None:
            p_u8 = np.asarray(stripe.parity["p"])
            q_u8 = stripe.parity.get("q")
            self.journal.commit(
                rec_name + ".parity.bin",
                p_u8.tobytes()
                + (np.asarray(q_u8).tobytes() if q_u8 is not None else b""),
                {
                    "step": self.step,
                    "pad_to": int(stripe.parity["pad_to"]),
                    "p_len": int(p_u8.size),
                    "has_q": q_u8 is not None,
                },
            )
        self._scrub_recs = {
            r["name"]: r for r in self.journal.replay(verify_crc=False)
        }
        self._stripes.pop(rec_name, None)  # drop any stale cached copy

    def _scrub_round(self) -> ScrubRound:
        """One byte-budgeted background scrub pass over the journaled
        archive (see ``core/archival/scrub``): parity syndromes through the
        fused unseal locate corrupt shards, repairs re-commit through the
        journal.  Interleaves with replay — both byte-budgeted."""
        self._scrub_recs = {
            r["name"]: r for r in self.journal.replay(verify_crc=False)
        }
        ids = sorted(
            m.group(0)[: -len(".bin")]
            for m in (
                re.match(r"archive_\d+\.bin$", n) for n in self._scrub_recs
            )
            if m
        )
        return self._scrubber.scrub_round(ids, self.cfg.scrub_budget_bytes)

    def _retire_expired(self) -> int:
        """Stripe lifecycle at checkpoint: retire stripes past the TTL (and
        below the novelty bar) in the crash-safe order — retirement record
        journaled first, then bodies/manifests/parity compact out of the
        journal, and only then is the key material gone.  Returns #retired."""
        if not self.cfg.retire_ttl_steps:
            return 0
        ids = plan_retirement(
            self.catalog,
            self.known_centroids,
            now_step=self.step,
            ttl_steps=self.cfg.retire_ttl_steps,
            max_novelty=self.cfg.retire_max_novelty,
        )
        if not ids:
            return 0
        report = retire_stripes(
            self.catalog, ids,
            records_for=lambda sid: [
                sid + ".bin", sid + ".manifest.json", sid + ".parity.bin",
            ],
        )
        for sid in report.retired:
            self._stripes.pop(sid, None)
        return len(report.retired)

    def _replay_from_archive(self) -> Tuple[List[jax.Array], Optional[ReadPlan]]:
        """Query the catalog for the most-novel archived GOPs and restore
        ONLY the shard subsets the plan names (degraded parity reads for
        shards whose CSD the monitor flagged dead)."""
        if not len(self.catalog):
            return [], None
        plan = plan_retrieval(
            self.catalog,
            self.known_centroids,
            budget_bytes=self.cfg.replay_budget_bytes,
            k=self.cfg.replay_k,
            dead_shards=self._dead_shards,
            parity_shards={"raid6": 2, "raid5": 1, "none": 0}[
                self.archive_cfg.parity
            ],
        )
        params = self._params()
        clips: List[jax.Array] = []
        recs = None
        if any(n not in self._stripes for n in plan.shards_by_stripe):
            # one journal scan shared by every cold stripe load this round
            recs = {r["name"]: r for r in self.journal.replay()}
        for rec_name in sorted(plan.shards_by_stripe):
            shard_ids = plan.shards_by_stripe[rec_name]
            try:
                stripe = self._get_stripe(rec_name, recs)
            except KeyError:
                # the stripe's journal record didn't survive replay (torn
                # mid-seal commit, or crc-failed awaiting scrub repair):
                # replay makes progress with what IS readable
                continue
            manifests = stripe_manifests(stripe)
            dead = [
                i for i in self._dead_shards if 0 <= i < len(stripe.blocks)
            ]
            if dead and stripe.parity is not None:
                # the flagged CSDs' bodies are unreachable: null them out so
                # the read is truly degraded.  The planner already refuses
                # degraded reads beyond the parity tolerance, so any WANTED
                # dead shard here is rebuildable; unwanted holes never
                # trigger a rebuild at all.
                holes = list(stripe.blocks)
                for i in dead:
                    holes[i] = None
                stripe = StripeArchive(holes, stripe.parity)
            clips.extend(
                restore_stripe(
                    params, self.secret, stripe, self.archive_cfg,
                    shards=shard_ids, manifests=manifests,
                )
            )
        return clips, plan

    # ----------------------------------------------------------- telemetry
    def _step_telemetry(self, ev0: int) -> Dict:
        """Per-step snapshot for ``StepReport.telemetry``: this step's
        span durations by stage, the metrics registry and the byte-flow
        ledger (both cumulative — the ledger is a conservation ledger)."""
        stages: Dict[str, float] = {}
        for ev in OBS.tracer.events[ev0:]:
            us = ev["dur_ns"] / 1e3
            stages[ev["name"]] = stages.get(ev["name"], 0.0) + us
        return {
            "stages": stages,
            "metrics": OBS.metrics.snapshot(),
            "ledger": OBS.ledger.report(),
        }

    def export_telemetry(self, basename: str = "telemetry") -> Dict[str, str]:
        """Write the telemetry captured so far: a Perfetto-loadable Chrome
        trace (``<workdir>/<basename>_trace.json``) plus the JSONL event
        log, committed through this trainer's journal (crc32 + fsync
        discipline — the log survives exactly like the archive does).
        Returns the paths written."""
        trace_path = os.path.join(self.workdir, f"{basename}_trace.json")
        write_chrome_trace(trace_path, OBS)
        jsonl_path = commit_jsonl(self.journal, OBS, f"{basename}.jsonl")
        return {"trace": trace_path, "jsonl": jsonl_path}

    # -------------------------------------------------------------- step
    def run_step(self, shard_times: Optional[List[float]] = None) -> StepReport:
        cfg = self.cfg
        step_key = jax.random.PRNGKey(self.step * 977 + 13)
        params = self._params()
        ev0 = len(OBS.tracer.events)

        with OBS.span("trainer.step", step=self.step):
            # 1. ingest one clip per stream
            with OBS.span("trainer.ingest_clips", streams=len(self.streams)):
                clips = {
                    s.stream_id: render_clip(
                        s, self.step * cfg.clip_len, cfg.clip_len
                    )
                    for s in self.streams
                }

            # 2. shared backbone features -> exemplar selection (per stream,
            #    pooled over space/time)
            with OBS.span("trainer.features"):
                feats = []
                for sid, clip in clips.items():
                    f = extract_features(params["extractor"], clip)
                    feats.append(f.mean(axis=(0, 1, 2)))
                fmat = jnp.stack(feats)  # (n_streams, C)
                split = select_exemplars(
                    step_key,
                    fmat,
                    k=min(cfg.exemplar_k, fmat.shape[0]),
                    n_train=min(cfg.n_train_exemplars, fmat.shape[0]),
                    known_centroids=self.known_centroids,
                )
                self.known_centroids = split.centroids
                train_ids = [int(i) for i in np.asarray(split.train_idx)]
                archive_ids = [int(i) for i in np.asarray(split.archive_idx)]

            # 3. replay: pull the most-novel archived GOPs (vs the CURRENT
            # centroids) back through the retrieval planner — only the
            # planned shard subsets are restored, so replay moves
            # catalog-priced bytes, not whole stripes
            replay_clips: List[jax.Array] = []
            plan = None
            if (
                cfg.replay_every
                and self.step % cfg.replay_every == cfg.replay_every - 1
            ):
                with OBS.span("trainer.replay"):
                    replay_clips, plan = self._replay_from_archive()

            # 3b. background scrub round (interleaves with replay; both are
            # byte-budgeted so recovery traffic never starves training reads)
            scrub = None
            if (
                cfg.scrub_every
                and self.step % cfg.scrub_every == cfg.scrub_every - 1
            ):
                with OBS.span("trainer.scrub"):
                    scrub = self._scrub_round()

            # 4. codec training on the novel clips + replayed exemplars
            with OBS.span("trainer.codec_train"):
                batch = [clips[self.streams[i].stream_id] for i in train_ids]
                want_shape = batch[0].shape if batch else None
                n_replayed = 0  # only GOPs that actually join the batch
                for g in replay_clips:
                    g = jnp.squeeze(g, axis=1)  # (T,1,H,W,3) -> (T,H,W,3)
                    # GOPs archived under a different clip geometry can't
                    # join this batch; they were still read, so the byte
                    # counters keep them
                    if want_shape is None or g.shape == want_shape:
                        batch.append(g)
                        n_replayed += 1
                train_clips = jnp.stack(batch, axis=1)  # (T, B, H, W, 3)
                self.trainable, self.opt_state, metrics = codec_train_step(
                    self.trainable, self.frozen, self.opt_state,
                    self.train_cfg, train_clips
                )

            # 5. archive ingest: codec-encode the known clips, coalesce
            # ragged GOPs across streams into full stripes; every completed
            # stripe is packed + sealed + parity-coded in ONE fused kernel
            # launch (per mesh shard when a storage mesh is attached) and
            # catalog-indexed with the exemplar stage's descriptors
            with OBS.span("trainer.archive", gops=len(archive_ids)):
                params = self._params()
                recon_psnrs = []
                ready = []
                for i in archive_ids:
                    sid = self.streams[i].stream_id
                    frames = clips[sid][:, None]  # (T, 1, H, W, 3)
                    flat, manifest, recons = encode_gop_payload(
                        params, frames, self.archive_cfg
                    )
                    recon_psnrs.append(float(psnr(recons, frames)))
                    ready += self.coalescer.add(
                        sid, flat, manifest,
                        meta={
                            "shard": self.placement.assignment[i],
                            "feature": np.asarray(fmat[i], np.float32),
                            "novelty": float(np.asarray(split.novelty)[i]),
                        },
                    )
                if cfg.archive_deadline_us > 0:
                    # straggler-aware drain: GOPs stuck past the deadline
                    # seal as (possibly short) stripes instead of waiting
                    # for stripe-mates that may never come
                    ready += self.coalescer.drain_expired(
                        cfg.archive_deadline_us
                    )
                n_sealed, total_bytes = self._seal_and_commit(ready)

            # 6. straggler handling (dead shards feed the next replay plan)
            rebalanced = False
            if shard_times is not None:
                status = self.monitor.update(shard_times)
                self._dead_shards = list(status.dead)
                if status.stragglers or status.dead:
                    self.placement = rebalance(
                        self.placement,
                        [s.fps for s in self.streams],
                        status.speed,
                    )
                    rebalanced = True

            # 7. checkpoint (drains stripes, then retires expired ones)
            self._last_retired = 0
            self.step += 1
            if self.step % cfg.checkpoint_every == 0:
                with OBS.span("trainer.checkpoint"):
                    self.checkpoint()

        return StepReport(
            step=self.step,
            codec_loss=float(metrics["loss"]),
            psnr=float(np.mean(recon_psnrs)) if recon_psnrs else float("nan"),
            archived_streams=n_sealed,
            archive_bytes=total_bytes,
            novel_selected=len(train_ids),
            rebalanced=rebalanced,
            stripes_sealed=len(ready),
            pending_gops=self.coalescer.n_pending,
            replayed_gops=n_replayed,
            replay_read_bytes=plan.bytes_planned if plan else 0,
            replay_full_bytes=plan.bytes_full_restore if plan else 0,
            replay_degraded=(
                sum(1 for r in plan.reads if r.degraded) if plan else 0
            ),
            scrub_stripes=scrub.stripes_checked if scrub else 0,
            scrub_bytes=scrub.bytes_scrubbed if scrub else 0,
            scrub_findings=len(scrub.findings) if scrub else 0,
            scrub_repaired=(
                sum(f.repaired for f in scrub.findings) if scrub else 0
            ),
            retired_stripes=self._last_retired,
            telemetry=self._step_telemetry(ev0) if OBS.enabled else None,
        )
