"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds:

    compute    = HLO_FLOPs_total        / (chips * PEAK_FLOPS_BF16)
    memory     = HLO_bytes_total        / (chips * HBM_BW)
    collective = collective_bytes_total / (chips * ICI_BW_PER_LINK)

``cost_analysis`` supplies per-device FLOPs/bytes (the compiled program is
the per-partition module); collective bytes are parsed from the compiled HLO
text by summing the *operand* sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction.
"""

from __future__ import annotations

import re
from typing import Dict, NamedTuple, Optional

from repro.roofline import hw

__all__ = [
    "collective_bytes",
    "collective_bytes_weighted",
    "roofline_terms",
    "RooflineTerms",
    "dominant_term",
]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _parse_collective_line(stripped: str):
    """Returns (kind, operand_bytes) or None.

    Compiled-HLO operands are printed without shapes, so sizes come from the
    RESULT shape(s) (between ``=`` and the op name) converted to operand
    semantics with the replica-group size ``gs``:
      all-gather operand = result/gs; reduce-scatter operand = result*gs;
      all-reduce / all-to-all / collective-permute operand = result.
    """
    for kind in _COLLECTIVES:
        for marker in (f" {kind}(", f" {kind}-start("):
            idx = stripped.find(marker)
            if idx < 0:
                continue
            eq = stripped.find(" = ")
            if eq < 0 or eq > idx:
                continue
            result_str = stripped[eq + 3 : idx]
            rbytes = 0
            for m in _SHAPE_RE.finditer(result_str):
                rbytes += _shape_bytes(m.group(1), m.group(2))
            gm = _GROUPS_RE.search(stripped)
            gs = int(gm.group(2)) if gm else 1
            if kind == "all-gather":
                ob = rbytes // max(gs, 1)
            elif kind == "reduce-scatter":
                ob = rbytes * max(gs, 1)
            else:
                ob = rbytes
            return kind, ob
    return None


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective op kind from (compiled) HLO text.

    Flat count: loop bodies tallied once — see ``collective_bytes_weighted``
    for the trip-count-corrected total."""
    totals = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        hit = _parse_collective_line(line.strip())
        if hit:
            totals[hit[0]] += hit[1]
    return totals


# -------------------------------------------------------- loop-aware count
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{")
_WHILE_COND_BODY = re.compile(r"condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_WHILE_INIT = re.compile(r"\bwhile\(%([\w\.\-]+)\)")
_CALL_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"%([\w\.\-]+)\s*=\s*s32\[\]\s+constant\((\d+)\)")
_INST_NAME_RE = re.compile(r"^%([\w\.\-]+)\s*=")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _line_collective_bytes(stripped: str) -> int:
    hit = _parse_collective_line(stripped)
    return hit[1] if hit else 0


def collective_bytes_weighted(hlo_text: str) -> float:
    """Loop-aware collective operand bytes: while-loop bodies are weighted by
    their trip counts (XLA's textual HLO nests collectives inside scan/while
    bodies, which a flat count would tally once).

    Trip-count recovery: the loop bound is an s32[] constant either compared
    directly in the condition computation or threaded through the while init
    tuple; we take the max plausible constant (bounds are the largest counter
    constants in play).  Unresolvable loops fall back to multiplier 1.
    """
    # --- split into computations -------------------------------------
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        if not raw.startswith(" "):
            m = _COMP_HDR.match(raw.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if raw.lstrip().startswith("ENTRY"):
                    entry = cur
                continue
            cur = None
        elif cur is not None:
            comps[cur].append(raw.strip())

    consts: Dict[str, int] = {}
    tuples: Dict[str, list] = {}
    for name, lines in comps.items():
        for ln in lines:
            cm = _CONST_RE.match(ln)
            if cm:
                consts[cm.group(1)] = int(cm.group(2))
            if " tuple(" in ln:
                nm = _INST_NAME_RE.match(ln)
                if nm:
                    args = ln[ln.find(" tuple(") + 7 :]
                    args = args[: args.rfind(")")] if ")" in args else args
                    tuples[nm.group(1)] = _OPERAND_NAME_RE.findall(args)

    def trip_count(init_name: str, cond_name: str) -> int:
        # 1) constant compared inside the condition
        cand = []
        for ln in comps.get(cond_name, []):
            cm = _CONST_RE.match(ln)
            if cm:
                cand.append(int(cm.group(2)))
        if cand:
            return max(cand)
        # 2) s32 constants threaded through the init tuple
        ops = tuples.get(init_name, [])
        vals = [consts[o] for o in ops if o in consts]
        vals = [v for v in vals if v > 0]
        if vals:
            return max(vals)
        return 1

    memo: Dict[str, float] = {}

    def total(comp: str) -> float:
        if comp in memo:
            return memo[comp]
        memo[comp] = 0.0  # cycle guard
        acc = 0.0
        for ln in comps.get(comp, []):
            acc += _line_collective_bytes(ln)
            if " while(" in ln:
                cb = _WHILE_COND_BODY.search(ln)
                im = _WHILE_INIT.search(ln)
                if cb:
                    cond, body = cb.groups()
                    t = trip_count(im.group(1) if im else "", cond)
                    acc += t * (total(body) + total(cond))
                    continue
            cm = _CALL_RE.search(ln)
            if cm and cm.group(1) in comps:
                acc += total(cm.group(1))
            bm = _BRANCH_RE.search(ln)
            if bm:
                for b in _OPERAND_NAME_RE.findall(bm.group(1)):
                    if b in comps:
                        acc += total(b)
        memo[comp] = acc
        return acc

    if entry is None:
        return float(sum(collective_bytes(hlo_text).values()))
    return total(entry)


class RooflineTerms(NamedTuple):
    compute_s: float
    memory_s: float
    collective_s: float
    device_flops: float
    device_bytes: float
    collective_bytes_dev: float
    n_devices: int

    @property
    def dominant(self) -> str:
        vals = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """compute_term / bound — 1.0 means perfectly compute-bound (at the
        FLOPs roofline); lower means memory or collectives dominate."""
        return self.compute_s / max(self.bound_s, 1e-30)


def roofline_terms(
    cost: Dict[str, float],
    hlo_text: str,
    n_devices: int,
    coll_bytes: Optional[Dict[str, int]] = None,
) -> RooflineTerms:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    if coll_bytes is None:
        coll_bytes = collective_bytes(hlo_text)
    cb_dev = float(sum(coll_bytes.values()))
    return RooflineTerms(
        compute_s=flops_dev * n_devices / (n_devices * hw.PEAK_FLOPS_BF16),
        memory_s=bytes_dev * n_devices / (n_devices * hw.HBM_BW),
        collective_s=cb_dev * n_devices / (n_devices * hw.ICI_BW_PER_LINK),
        device_flops=flops_dev,
        device_bytes=bytes_dev,
        collective_bytes_dev=cb_dev,
        n_devices=n_devices,
    )


def dominant_term(t: RooflineTerms) -> str:
    return t.dominant
