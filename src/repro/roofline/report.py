"""Roofline report generator: results/dryrun/*.json -> markdown tables.

Per cell: the three roofline terms (seconds), dominant bottleneck,
MODEL_FLOPS (6·N_active·D for train, 2·N_active·D for prefill/decode) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste shows up
here), plus a one-line "what would move the dominant term" note.

Usage: PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs.shapes import SHAPES

__all__ = ["load_records", "model_flops", "build_table", "main"]


def load_records(d: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def model_flops(rec: Dict) -> float:
    sp = SHAPES[rec["shape"]]
    n_active = rec.get("active_param_count") or rec.get("param_count", 0)
    if sp.kind == "train":
        tokens = sp.global_batch * sp.seq_len
        return 6.0 * n_active * tokens
    if sp.kind == "prefill":
        tokens = sp.global_batch * sp.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * sp.global_batch


_ADVICE = {
    ("collective", "train"): "cut FSDP weight re-gathers (2D expert TP / fewer microbatches) and overlap grad reduce-scatter with bwd",
    ("collective", "prefill"): "reduce SP<->TP transitions per layer (fuse norm+attention resharding)",
    ("collective", "decode"): "keep KV local: batch-shard decode and avoid per-token weight gathers",
    ("memory", "train"): "raise arithmetic intensity: fewer weight passes (larger fused blocks), bf16 end-to-end",
    ("memory", "prefill"): "fuse attention pipeline (flash) so KV streams once per q-chunk",
    ("memory", "decode"): "quantize KV cache to int8 and batch more requests per weight read",
    ("compute", "train"): "already compute-bound: raise MFU via larger matmul tiles / less remat",
    ("compute", "prefill"): "already compute-bound: larger q-chunks to amortize softmax overhead",
    ("compute", "decode"): "already compute-bound (rare for decode): increase batch",
}


def build_table(records: List[Dict], mesh: str = "single") -> str:
    rows = []
    hdr = (
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPS | useful ratio | fits 16G | note |\n"
        "|---|---|---|---|---|---|---|---|---|---|"
    )
    for rec in records:
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | — | — | — | — | "
                f"SKIPPED: {rec['reason'][:60]} |"
            )
            continue
        if rec.get("status") != "ok":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | — | — | — | — | "
                f"FAILED: {rec.get('error', '?')[:60]} |"
            )
            continue
        r = rec["roofline"]
        mf = model_flops(rec)
        hlo = rec.get("flops_total_exact", 0.0)
        ratio = mf / hlo if hlo else float("nan")
        kind = rec.get("kind", "train")
        note = _ADVICE.get((r["dominant"], kind), "")
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | {r['dominant']} | "
            f"{mf:.3g} | {ratio:.2f} | "
            f"{'yes' if rec.get('fits_hbm_16g') else 'NO'} | {note} |"
        )
    return hdr + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(build_table(recs, args.mesh))


if __name__ == "__main__":
    main()
