"""Erasure coding for the archival pipeline: RAID-5 (XOR) and RAID-6 (GF(256) RS).

Salient Store's archival flow ends in "a distributed set of disks to ensure
redundancy (e.g., RAID 5)".  On the TPU adaptation a "disk" is a storage shard
on the data mesh axis; parity shards let the system survive shard loss
(node failure / the paper's intermittent-power events) and are also applied to
checkpoint shards (train/checkpoint.py).

All arithmetic is vectorized JAX on uint8 payloads: XOR on the VPU for P,
log/antilog-table Reed-Solomon over GF(2^8) (poly 0x11D, generator 2) for Q.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "gf_mul",
    "gf_div",
    "gf_pow_gen",
    "raid5_encode",
    "raid5_reconstruct",
    "raid6_encode",
    "raid6_reconstruct",
    "raid6_syndrome_locate",
]


def _gf_tables():
    exp = np.zeros(512, np.int32)
    log = np.zeros(256, np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= 0x11D
    exp[255:510] = exp[:255]
    exp[510:] = exp[:2]
    return jnp.asarray(exp), jnp.asarray(log)


_EXP, _LOG = _gf_tables()


def gf_mul(a, b):
    """Elementwise GF(256) multiply; a, b uint8 arrays (broadcastable)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    prod = jnp.take(_EXP, jnp.take(_LOG, a) + jnp.take(_LOG, b))
    return jnp.where((a == 0) | (b == 0), 0, prod).astype(jnp.uint8)


def gf_div(a, b):
    """Elementwise GF(256) divide (b must be nonzero where a is nonzero)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    quot = jnp.take(_EXP, jnp.take(_LOG, a) - jnp.take(_LOG, b) + 255)
    return jnp.where(a == 0, 0, quot).astype(jnp.uint8)


def gf_pow_gen(i: int) -> int:
    """g^i for generator g=2 (host-side scalar)."""
    return int(_EXP[i % 255])


# ------------------------------------------------------------------ RAID-5
def raid5_encode(shards: jnp.ndarray) -> jnp.ndarray:
    """shards: (k, ...) uint8 -> parity (...,) uint8."""
    p = shards[0]
    for i in range(1, shards.shape[0]):
        p = p ^ shards[i]
    return p


def raid5_reconstruct(
    shards: Sequence[Optional[jnp.ndarray]], parity: jnp.ndarray, missing: int
) -> jnp.ndarray:
    """Recover the single missing data shard."""
    acc = parity
    for i, s in enumerate(shards):
        if i != missing:
            assert s is not None, f"shard {i} also missing; RAID-5 covers 1 erasure"
            acc = acc ^ s
    return acc


# ------------------------------------------------------------------ RAID-6
def raid6_encode(shards: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shards: (k, ...) uint8 -> (P, Q) parities."""
    k = shards.shape[0]
    p = raid5_encode(shards)
    q = jnp.zeros_like(shards[0])
    for i in range(k):
        q = q ^ gf_mul(np.uint8(gf_pow_gen(i)), shards[i])
    return p, q


def raid6_reconstruct(
    shards: List[Optional[jnp.ndarray]],
    p: Optional[jnp.ndarray],
    q: Optional[jnp.ndarray],
    missing: Sequence[int],
) -> List[jnp.ndarray]:
    """Recover up to two missing *data* shards (P/Q may be among the losses).

    ``missing`` lists data-shard indices that are None in ``shards``.  Lost
    parities are simply re-encoded afterwards by the caller.
    Returns the complete data shard list.
    """
    shards = list(shards)
    k = len(shards)
    missing = sorted(missing)
    if len(missing) == 0:
        return shards
    if len(missing) == 1:
        (i,) = missing
        if p is not None:
            shards[i] = raid5_reconstruct(shards, p, i)
        else:
            assert q is not None, "need P or Q for a single erasure"
            acc = q
            for m, s in enumerate(shards):
                if m != i:
                    acc = acc ^ gf_mul(np.uint8(gf_pow_gen(m)), s)
            shards[i] = gf_div(acc, np.uint8(gf_pow_gen(i)))
        return shards
    if len(missing) == 2:
        i, j = missing
        assert p is not None and q is not None, "two erasures need both P and Q"
        pxor = p
        qxor = q
        for m, s in enumerate(shards):
            if m not in (i, j):
                pxor = pxor ^ s
                qxor = qxor ^ gf_mul(np.uint8(gf_pow_gen(m)), s)
        # pxor = d_i ^ d_j ;  qxor = g^i d_i ^ g^j d_j
        gi, gj = np.uint8(gf_pow_gen(i)), np.uint8(gf_pow_gen(j))
        denom = np.uint8(int(gi) ^ int(gj))
        dj = gf_div(qxor ^ gf_mul(gi, pxor), denom)
        di = pxor ^ dj
        shards[i], shards[j] = di, dj
        return shards
    raise ValueError(f"RAID-6 covers at most 2 erasures, got {missing}")


# --------------------------------------------------------- scrub syndromes
def raid6_syndrome_locate(sp, sq, n_shards: int) -> Optional[int]:
    """Locate a single corrupt data shard from RAID-6 parity syndromes.

    ``sp = P_recomputed ^ P_stored`` and ``sq = Q_recomputed ^ Q_stored``
    (uint8 arrays of equal length).  If exactly one data shard ``z`` carries
    an XOR error ``e`` then ``sp = e`` and ``sq = g^z * e``, so every byte
    with ``sp != 0`` must agree on ``z = log(sq) - log(sp) (mod 255)``.
    Returns ``z`` when all nonzero bytes agree on one ``z < n_shards``,
    else ``None`` (multi-shard / unlocatable corruption — rebuild from a
    clean replica instead of patching).  Host-side numpy: syndromes are a
    few KB, the scrubber ships syndromes, not bodies (costmodel note).
    """
    sp = np.asarray(sp, np.uint8)
    sq = np.asarray(sq, np.uint8)
    if sp.shape != sq.shape:
        return None
    nz = sp != 0
    if not nz.any() or (sq[nz] == 0).any() or (sq[~nz] != 0).any():
        return None
    log = np.asarray(_LOG)
    z = (log[sq[nz].astype(np.int32)] - log[sp[nz].astype(np.int32)]) % 255
    z0 = int(z[0])
    if (z == z0).all() and z0 < n_shards:
        return z0
    return None
