"""Background parity scrub + stripe lifecycle (the archive that survives).

The write path ends with every stripe sealed and parity-coded; nothing in
the seed repo ever *checked* that parity again, so a silent bit flip in a
journaled body would sit undetected until a degraded read decoded garbage.
This module closes that gap with the scrub -> rebuild -> retire loop
(pipeline.py docstring, steps 7–9):

* ``StripeScrubber`` walks sealed stripes on a byte-budgeted round-robin
  schedule and recomputes P/Q through the fused unseal kernel
  (``pipeline.recompute_stripe_parity`` — parity is defined over the
  SEALED bodies, so the scrub holds zero key material and can run on the
  CSD tier, shipping only syndrome bytes; see ``csd/costmodel.py``).
  A nonzero syndrome detects corruption; for RAID-6 the (P, Q) syndrome
  pair LOCATES the corrupt shard (``raid.raid6_syndrome_locate``) and the
  scrubber repairs it in place (body ^= P-syndrome) and re-verifies.
  RAID-5 detects but cannot locate — the finding escalates to a rebuild
  from a replica.
* ``plan_retirement`` / ``retire_stripes`` implement the lifecycle tier:
  stripes whose salience has decayed past a TTL are retired in the safe
  order — (1) the retirement record is journaled
  (``catalog.retire_stripe``), (2) the journal compacts (live records
  rewritten, retired bodies dropped), (3) only then is the stripe's
  key/nonce material reported recyclable.  A crash between any two steps
  replays to a consistent state: the retirement record wins over a
  surviving catalog record or body.

Budget semantics: a scrub round scans stripes until the byte budget is
exhausted but always scans AT LEAST one stripe (otherwise a budget smaller
than the smallest stripe would starve scrubbing forever); the round-robin
cursor persists across rounds so every stripe is eventually visited.
Rebuild rounds (``distributed/archival.rebuild_csd_sharded``) are the
strict side: they never exceed their budget, so replay traffic is never
starved by recovery.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence

import numpy as np

from repro.core.archival import raid
from repro.core.archival.catalog import CATALOG_PREFIX, StripeCatalog
from repro.core.archival.pipeline import (
    StripeArchive,
    recompute_stripe_parity,
)
from repro.obs import EDGE_SCRUB_READ, EDGE_SCRUB_SYNDROME, OBS
from repro.obs import names as obs_names

__all__ = [
    "ScrubFinding",
    "ScrubRound",
    "StripeScrubber",
    "RetireReport",
    "plan_retirement",
    "retire_stripes",
]


class ScrubFinding(NamedTuple):
    """One corruption (or verification failure) found by a scrub pass."""

    stripe_id: str
    # "shard" | "p" | "q" | "unlocatable" | "noparity" | "degraded"
    kind: str
    shard: Optional[int]  # corrupt shard index (kind == "shard")
    repaired: bool       # fixed in place and re-verified clean


class ScrubRound(NamedTuple):
    stripes_checked: int
    bytes_scrubbed: int    # sealed body bytes recomputed through the kernel
    syndrome_bytes: int    # what the scrub SHIPS host-side (P+Q strips)
    findings: List[ScrubFinding]  # clean stripes produce no finding


def _stripe_bytes(stripe: StripeArchive) -> int:
    return sum(4 * int(b.sealed.n_valid_u32) for b in stripe.blocks
               if b is not None)


def _xor_into_body(stripe: StripeArchive, shard: int,
                   syndrome: np.ndarray) -> StripeArchive:
    """Repair shard ``shard``: XOR the P-syndrome (== the error) into its
    sealed body, preserving the stripe's padded parity geometry."""
    import jax.numpy as jnp

    blk = stripe.blocks[shard]
    body = np.asarray(blk.sealed.body, np.uint32).copy()
    nbytes = min(body.size * 4, (syndrome.size // 4) * 4)
    err = np.ascontiguousarray(syndrome[:nbytes]).view(np.uint32)
    body[: err.size] ^= err
    blocks = list(stripe.blocks)
    blocks[shard] = blk._replace(
        sealed=blk.sealed._replace(body=jnp.asarray(body))
    )
    return stripe._replace(blocks=blocks)


class StripeScrubber:
    """Byte-budgeted background parity scrubber with a persistent cursor.

    ``get_stripe(stripe_id) -> StripeArchive`` reads a sealed stripe;
    ``put_stripe(stripe_id, stripe)`` (optional) writes a repaired one
    back — without it the scrubber detects and locates but leaves repair
    to the caller (findings carry ``repaired=False``).
    """

    def __init__(
        self,
        get_stripe: Callable[[str], StripeArchive],
        put_stripe: Optional[Callable[[str, StripeArchive], None]] = None,
        *,
        use_pallas: bool = True,
    ):
        self.get_stripe = get_stripe
        self.put_stripe = put_stripe
        self.use_pallas = use_pallas
        self._next = 0  # round-robin cursor over the caller's stripe list

    # ----------------------------------------------------------- one stripe
    def scrub_stripe(self, stripe_id: str) -> List[ScrubFinding]:
        """Parity-verify one stripe; locate + repair what the mode allows."""
        stripe = self.get_stripe(stripe_id)
        if stripe.parity is None:
            return [ScrubFinding(stripe_id, "noparity", None, False)]
        if any(b is None for b in stripe.blocks):
            # a shard is out for rebuild: parity cannot be verified until
            # the stripe is whole again — defer, don't crash the round
            return [ScrubFinding(stripe_id, "degraded", None, False)]
        findings = self._classify(stripe_id, stripe)
        if not findings or self.put_stripe is None:
            return findings
        out = []
        for f in findings:
            repaired = self._repair(stripe_id, f)
            out.append(f._replace(repaired=repaired))
        return out

    def _classify(self, stripe_id: str,
                  stripe: StripeArchive) -> List[ScrubFinding]:
        got = recompute_stripe_parity(stripe, use_pallas=self.use_pallas)
        stored_p = np.asarray(stripe.parity["p"], np.uint8)
        sp = got["p"] ^ stored_p
        if "q" not in stripe.parity:
            if sp.any():
                # RAID-5: one syndrome cannot locate the corrupt shard
                return [ScrubFinding(stripe_id, "unlocatable", None, False)]
            return []
        stored_q = np.asarray(stripe.parity["q"], np.uint8)
        sq = got["q"] ^ stored_q
        p_bad, q_bad = bool(sp.any()), bool(sq.any())
        if not p_bad and not q_bad:
            return []
        if p_bad and q_bad:
            z = raid.raid6_syndrome_locate(sp, sq, len(stripe.blocks))
            if z is None:
                return [ScrubFinding(stripe_id, "unlocatable", None, False)]
            return [ScrubFinding(stripe_id, "shard", z, False)]
        # data shards consistent with exactly one parity strip => the
        # OTHER strip rotted on disk
        kind = "p" if p_bad else "q"
        return [ScrubFinding(stripe_id, kind, None, False)]

    def _repair(self, stripe_id: str, f: ScrubFinding) -> bool:
        stripe = self.get_stripe(stripe_id)
        got = recompute_stripe_parity(stripe, use_pallas=self.use_pallas)
        if f.kind == "shard":
            sp = got["p"] ^ np.asarray(stripe.parity["p"], np.uint8)
            stripe = _xor_into_body(stripe, f.shard, sp)
        elif f.kind in ("p", "q"):
            parity = dict(stripe.parity)
            parity[f.kind] = got[f.kind]
            stripe = stripe._replace(parity=parity)
        else:  # unlocatable / noparity: nothing this tier can fix
            return False
        # re-verify before declaring victory: a repaired stripe must be
        # syndrome-clean or the finding stays open
        clean = recompute_stripe_parity(stripe, use_pallas=self.use_pallas)
        ok = np.array_equal(clean["p"], np.asarray(stripe.parity["p"]))
        if ok and "q" in stripe.parity:
            ok = np.array_equal(clean["q"], np.asarray(stripe.parity["q"]))
        if ok:
            self.put_stripe(stripe_id, stripe)
        return bool(ok)

    # ---------------------------------------------------------------- round
    def scrub_round(self, stripe_ids: Sequence[str],
                    budget_bytes: int) -> ScrubRound:
        """Scrub stripes round-robin until ``budget_bytes`` is spent.

        Always scans at least one stripe (minimum progress); the cursor
        persists so successive rounds cover the whole archive even when
        each round affords only a fraction of it.
        """
        ids = list(stripe_ids)
        if not ids:
            return ScrubRound(0, 0, 0, [])
        t0 = time.perf_counter_ns() if OBS.enabled else 0
        checked = scanned = shipped = 0
        findings: List[ScrubFinding] = []
        with OBS.span(
            "scrub.round", stripes=len(ids), budget_bytes=budget_bytes
        ) as sp:
            while checked < len(ids):
                sid = ids[self._next % len(ids)]
                cost = _stripe_bytes(self.get_stripe(sid))
                if checked > 0 and scanned + cost > budget_bytes:
                    break
                findings.extend(self.scrub_stripe(sid))
                stripe = self.get_stripe(sid)
                if stripe.parity is not None:
                    shipped += sum(
                        np.asarray(stripe.parity[k]).size
                        for k in ("p", "q") if k in stripe.parity
                    )
                scanned += cost
                checked += 1
                self._next = (self._next + 1) % len(ids)
                if scanned >= budget_bytes:
                    break
            sp.set(checked=checked, bytes_scrubbed=scanned,
                   findings=len(findings))
        if OBS.enabled:
            # a syndrome hit = stored parity disagreed with the recompute
            # (noparity/degraded findings never got as far as a syndrome)
            hits = sum(
                1 for f in findings
                if f.kind in ("shard", "p", "q", "unlocatable")
            )
            OBS.count(obs_names.SCRUB_ROUNDS)
            OBS.count(obs_names.SCRUB_STRIPES, checked)
            OBS.count(obs_names.SCRUB_BYTES, scanned)
            OBS.count(obs_names.SCRUB_FINDINGS, len(findings))
            OBS.count(obs_names.SCRUB_SYNDROME_HITS, hits)
            OBS.count(obs_names.SCRUB_REPAIRED,
                      sum(1 for f in findings if f.repaired))
            OBS.flow(EDGE_SCRUB_READ, scanned, events=checked)
            OBS.flow(EDGE_SCRUB_SYNDROME, shipped, events=checked)
            OBS.observe(
                obs_names.SCRUB_ROUND_US, (time.perf_counter_ns() - t0) / 1e3
            )
        return ScrubRound(checked, scanned, shipped, findings)


# ------------------------------------------------------------------ lifecycle
class RetireReport(NamedTuple):
    retired: List[str]          # stripe ids retired (journaled, in order)
    dropped_records: int        # journal records removed by compaction
    dropped_entries: int        # catalog entries removed
    keys_recyclable: List[str]  # ids whose key/nonce material may now be
    #                             recycled — strictly the journaled set


def plan_retirement(
    catalog: StripeCatalog,
    centroids=None,
    *,
    now_step: int,
    ttl_steps: int,
    max_novelty: Optional[float] = None,
    limit: Optional[int] = None,
) -> List[str]:
    """Pick stripes to retire: past TTL and (optionally) low-salience.

    A stripe is eligible when EVERY GOP in it was sealed ≥ ``ttl_steps``
    trainer steps ago (entries without a seal stamp never expire) and,
    when ``max_novelty`` is given, its most-novel GOP — scored against the
    caller's CURRENT ``centroids`` — is at or below it: age alone never
    deletes data the trainer still finds surprising.  Least-salient first,
    capped at ``limit``.
    """
    by_stripe: Dict[str, List[int]] = {}
    entries = catalog.entries
    for i, e in enumerate(entries):
        by_stripe.setdefault(e.stripe_id, []).append(i)
    nov = catalog.score(centroids)
    eligible = []
    for sid, idxs in by_stripe.items():
        ok_age = all(
            entries[i].sealed_step >= 0
            and now_step - entries[i].sealed_step >= ttl_steps
            for i in idxs
        )
        if not ok_age:
            continue
        top = float(max(nov[i] for i in idxs)) if idxs else 0.0
        if max_novelty is not None and top > max_novelty:
            continue
        eligible.append((top, sid))
    eligible.sort()
    ids = [sid for _, sid in eligible]
    return ids[: limit] if limit is not None else ids


def retire_stripes(
    catalog: StripeCatalog,
    stripe_ids: Sequence[str],
    *,
    journal=None,
    records_for: Optional[Callable[[str], List[str]]] = None,
) -> RetireReport:
    """Retire stripes in the crash-safe order.

    Per stripe: (1) ``catalog.retire_stripe`` journals the retirement
    record and drops the in-memory entries; then, once ALL retirements are
    durable, (2) one journal ``compact`` drops the retired bodies and
    catalog records (``records_for(stripe_id)`` names a stripe's journal
    records — bodies, manifests, parity; the catalog record is always
    included).  Key/nonce material is recyclable only for ids in the
    returned report — i.e. strictly after their retirement is journaled.
    """
    journal = journal if journal is not None else catalog.journal
    retired: List[str] = []
    dropped_entries = 0
    for sid in stripe_ids:
        dropped_entries += catalog.retire_stripe(sid)
        retired.append(sid)
    drop: List[str] = []
    for sid in retired:
        drop.append(f"{CATALOG_PREFIX}{sid}.json")
        if records_for is not None:
            drop.extend(records_for(sid))
    dropped_records = journal.compact(drop) if journal is not None else 0
    return RetireReport(retired, dropped_records, dropped_entries,
                        list(retired))
