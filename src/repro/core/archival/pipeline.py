"""End-to-end archival pipeline: compress -> encrypt -> parity (Fig. 1).

Device path (runs where the data shard lives — the CSD analogue):
  1. layered neural codec encodes the GOP (int8 codes + int8 motion fields);
  2. codes are packed into uint32 words and sealed (R-LWE KEM + ChaCha20);
  3. sealed bodies from the S shards of a stripe are parity-coded
     (RAID-5/6) so any 1-2 shard losses are recoverable.

Only steps that must see raw bytes (zstd entropy stage, disk I/O) run host
side, on *sealed, compressed* data — the paper's data-movement thesis.
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archival import raid
from repro.core.codec.layered_codec import (
    CodecConfig,
    FrameCode,
    decode_gop,
    encode_gop,
)
from repro.core.crypto import rlwe
from repro.core.crypto.hybrid import SealedBlock, seal, unseal

__all__ = [
    "ArchiveConfig",
    "ArchivedBlock",
    "pack_i8_to_u32",
    "unpack_u32_to_i8",
    "archive_gop",
    "restore_gop",
    "stripe_parity",
    "recover_stripe",
]


class ArchiveConfig(NamedTuple):
    codec: CodecConfig = CodecConfig()
    rlwe: rlwe.RLWEParams = rlwe.RLWEParams()
    n_layers: Optional[int] = None  # quality-layer prefix (None = all)
    parity: str = "raid6"  # "raid5" | "raid6" | "none"


class ArchivedBlock(NamedTuple):
    sealed: SealedBlock
    manifest: Dict  # shapes/lengths to invert packing (host-side metadata)


def pack_i8_to_u32(x: jax.Array) -> jax.Array:
    """Flat int8 (4N,) -> (N,) uint32 (little-endian lanes)."""
    b = (x.astype(jnp.int32) & 0xFF).astype(jnp.uint32).reshape(-1, 4)
    sh = jnp.arange(4, dtype=jnp.uint32) * 8
    return (b << sh).sum(-1, dtype=jnp.uint32)


def unpack_u32_to_i8(w: jax.Array, n: int) -> jax.Array:
    """(N,) uint32 -> flat int8 (n,)."""
    sh = jnp.arange(4, dtype=jnp.uint32) * 8
    b = ((w[:, None] >> sh) & jnp.uint32(0xFF)).astype(jnp.uint8)
    return b.reshape(-1)[:n].astype(jnp.int8)


def _flatten_codes(frame_codes: List[FrameCode]) -> Tuple[jax.Array, Dict]:
    parts, spec = [], []
    for fc in frame_codes:
        centry = []
        for z in fc.codes:
            parts.append(z.astype(jnp.int8).reshape(-1))
            centry.append(tuple(z.shape))
        mv_shape = None
        if fc.mv is not None:
            parts.append(fc.mv.astype(jnp.int8).reshape(-1))
            mv_shape = tuple(fc.mv.shape)
        spec.append({"codes": centry, "mv": mv_shape})
    flat = jnp.concatenate(parts)
    n = int(flat.shape[0])
    pad = (-n) % 4
    flat = jnp.pad(flat, (0, pad))
    return flat, {"spec": spec, "n_i8": n}


def _unflatten_codes(flat_i8: jax.Array, manifest: Dict) -> List[FrameCode]:
    out = []
    off = 0
    for entry in manifest["spec"]:
        codes = []
        for shp in entry["codes"]:
            sz = int(np.prod(shp))
            codes.append(
                flat_i8[off : off + sz].astype(jnp.float32).reshape(shp)
            )
            off += sz
        mv = None
        if entry["mv"] is not None:
            sz = int(np.prod(entry["mv"]))
            mv = flat_i8[off : off + sz].astype(jnp.int32).reshape(entry["mv"])
            off += sz
        out.append(FrameCode(codes, mv))
    return out


def archive_gop(
    codec_params,
    pub: rlwe.PublicKey,
    frames: jax.Array,
    key: jax.Array,
    cfg: ArchiveConfig = ArchiveConfig(),
) -> Tuple[ArchivedBlock, jax.Array]:
    """frames: (T, B, H, W, 3). Returns (ArchivedBlock, recons)."""
    frame_codes, recons = encode_gop(
        codec_params, cfg.codec, frames, n_layers=cfg.n_layers
    )
    flat, manifest = _flatten_codes(frame_codes)
    words = pack_i8_to_u32(flat)
    sealed = seal(pub, words, key, cfg.rlwe)
    manifest = dict(manifest, frames_shape=tuple(frames.shape))
    return ArchivedBlock(sealed, manifest), recons


def restore_gop(
    codec_params,
    s: jax.Array,
    block: ArchivedBlock,
    cfg: ArchiveConfig = ArchiveConfig(),
) -> jax.Array:
    words = unseal(s, block.sealed, cfg.rlwe)
    flat = unpack_u32_to_i8(words, block.manifest["n_i8"])
    frame_codes = _unflatten_codes(flat, block.manifest)
    return decode_gop(codec_params, cfg.codec, frame_codes)


# --------------------------------------------------------------- parity tier
def _bodies_u8(blocks: List[ArchivedBlock], pad_to: int) -> jnp.ndarray:
    rows = []
    for b in blocks:
        w = b.sealed.body
        w = jnp.pad(w, (0, pad_to - w.shape[0]))
        rows.append(jax.lax.bitcast_convert_type(w, jnp.uint8).reshape(-1))
    return jnp.stack(rows)  # (S, pad_to*4) uint8


def stripe_parity(blocks: List[ArchivedBlock], mode: str = "raid6"):
    """Parity over the sealed bodies of one stripe (S storage shards)."""
    if mode == "none":
        return None
    pad_to = max(int(b.sealed.body.shape[0]) for b in blocks)
    data = _bodies_u8(blocks, pad_to)
    if mode == "raid5":
        return {"p": raid.raid5_encode(data), "pad_to": pad_to}
    p, q = raid.raid6_encode(data)
    return {"p": p, "q": q, "pad_to": pad_to}


def recover_stripe(
    blocks: List[Optional[ArchivedBlock]],
    parity: Dict,
    missing: List[int],
    manifests: List[Dict],
    body_lens: List[int],
) -> List[ArchivedBlock]:
    """Rebuild missing shards' sealed bodies from parity.

    Note: parity protects the *body*; KEM polys + nonce are tiny and stored
    replicated in the manifest tier (standard metadata replication).
    """
    pad_to = parity["pad_to"]
    rows: List[Optional[jnp.ndarray]] = []
    for b in blocks:
        rows.append(None if b is None else _bodies_u8([b], pad_to)[0])
    if "q" in parity:
        full = raid.raid6_reconstruct(rows, parity["p"], parity.get("q"), missing)
    else:
        assert len(missing) == 1
        full = list(rows)
        full[missing[0]] = raid.raid5_reconstruct(rows, parity["p"], missing[0])
    out: List[ArchivedBlock] = []
    for i, b in enumerate(blocks):
        if b is not None:
            out.append(b)
            continue
        words = jax.lax.bitcast_convert_type(
            full[i].reshape(-1, 4), jnp.uint32
        ).reshape(-1)[: body_lens[i]]
        meta = manifests[i]
        sealed = SealedBlock(
            meta["kem_c1"], meta["kem_c2"], meta["nonce"], words, body_lens[i]
        )
        out.append(ArchivedBlock(sealed, meta["manifest"]))
    return out
