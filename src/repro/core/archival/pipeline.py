"""End-to-end archival pipeline: the full ingest -> archive -> query ->
replay loop of Salient Store (Fig. 1, both directions).

Write path (runs where the data shard lives — the CSD analogue):
  1. layered neural codec encodes the GOP (int8 codes + int8 motion fields);
  2. the flat codes go through the ONE-LAUNCH archival kernel
     (``repro.kernels.fused``, ``codec_name="rans"`` — the default): one
     Pallas launch per stripe batch runs interleaved-rANS entropy coding
     (with per-shard adaptive raw-skip, flagged in the manifest and
     honored by every decode path), v1 stream packing into uint32 words,
     the ChaCha20 XOR-seal (session keys R-LWE-KEM-encapsulated host-side,
     tiny), and RAID-5/6 parity over the S shards — the packed streams
     are never materialized in HBM between stages, and K coalesced
     stripes batch onto the launch's stripe axis so dispatch overhead
     amortizes K-fold.  (The pre-fusion chained launches —
     ``repro.kernels.entropy`` then ``repro.kernels.seal`` — remain the
     decode path, the host-codec path, and the bit-exact reference.);
  3. AT SEAL TIME the stripe is indexed into the salience catalog
     (``core/archival/catalog.py``): per-GOP pooled feature + novelty,
     recorded while the backbone features are hot — queries never decode.

Read path (the archive is an ACTIVE participant in continuous learning,
not a write-only sink):
  4. the trainer asks the query planner (``core/csd/retrieval.py``) for
     the most-novel archived GOPs vs its CURRENT exemplar centroids; the
     plan prices host-vs-CSD decode (``csd/costmodel.py``) and names, per
     stripe, exactly the shard subset to read;
  5. ``restore_stripe(shards=...)`` decodes ONLY those shards — one fused
     unseal launch over the subset — falling back to a parity-based
     degraded read (``recover_stripe``) when a wanted shard is missing or
     its CSD is flagged dead by the ``StragglerMonitor``;
  6. the decoded GOPs join the training batch (``train/trainer.py``'s
     replay stage), closing the loop: ingest -> archive -> query -> replay.

Durability loop (scrub -> rebuild -> retire, ``core/archival/scrub.py``):

  7. a background scrubber walks sealed stripes on a byte-budgeted round
     schedule and recomputes P/Q *over the sealed bodies* through the same
     unseal kernel (``recompute_stripe_parity`` — parity is defined on
     ciphertext, so the scrub holds ZERO key material); a nonzero syndrome
     against the stored parity detects silent corruption, and for RAID-6
     the P/Q syndrome pair LOCATES the corrupt shard
     (``raid.raid6_syndrome_locate``) so it can be repaired in place;
  8. a shard whose CSD the ``StragglerMonitor`` declares dead is rebuilt
     onto a replacement by the sharded parity pass
     (``distributed/archival.rebuild_csd_sharded``), budget-bounded per
     round so replay traffic is never starved, priority-ordered by catalog
     salience;
  9. stripes whose salience has decayed past a TTL are *retired*: the
     retirement is journaled first, then catalog + journal compact (live
     records rewritten, retired bodies dropped) — only after that is the
     stripe's key/nonce material recycled.

With the whole codes -> entropy -> pack -> ChaCha20 -> parity chain fused
into one launch nothing round-trips the host OR HBM mid-chain; only disk
I/O and O(1) manifest metadata (lengths, KEM polys, nonces, salience
descriptors) are host-side, and they cover *sealed, compressed* data — the paper's
data-movement thesis in BOTH directions: ingest moves compressed bytes,
retrieval moves only the planned shard subset (the ``retrieval`` bench
gates on that byte ratio).  ``ArchiveConfig.codec_name`` selects ``"rans"``
(on-device, default), ``"zstd"``/``"zlib"`` (the legacy host-side codec via
``repro.common.compress``, kept as the fallback for hosts that want a
byte-for-byte zstd archive), or ``"none"``; manifests record the codec (and
the raw-skip flag) so ``restore_stripe`` dispatches on what was written.

Granularities and seams:

* ``archive_stripe`` / ``restore_stripe`` — the batched hot path.  All S
  shards of a stripe are entropy-coded, packed, ChaCha-sealed, and
  parity-coded in ONE fused Pallas launch (``repro.kernels.fused``); only
  the tiny per-shard KEM runs outside the kernel.  ``seal_payload_stripes``
  is the K-stripe batched entry (one launch per homogeneous stripe group).
  ``use_pallas=False`` dispatches the staged jnp reference instead
  (bit-identical outputs).
* ``restore_stripe_payloads`` — the retrieval datapath below the neural
  codec: subset unseal + entropy decode + degraded-read fallback, shared
  by ``restore_stripe`` and the byte-accounting benches.
* ``archive_gop`` / ``restore_gop`` + ``stripe_parity`` — the per-block
  reference path, kept as the dispatch/compat layer and for single-GOP use.
* ``stripe_manifests`` (+ ``..._to_json``/``..._from_json``) — the
  replicated metadata tier: KEM polys, nonces, packing manifests and body
  lengths, journaled next to the bodies so restarts and degraded reads
  never depend on in-memory state.

Sharded archival (mesh axis <-> CSD array):

The stripe's shard axis IS the paper's CSD array: shard s of a stripe lives
on storage device s, and the whole point of the CSD offload is that each
device seals *its own* shard locally while only the tiny parity reduction
crosses devices.  On the TPU adaptation the ``data`` mesh axis plays the
CSD-array role (see ``distributed/sharding.py``): ``repro.distributed.
archival`` shard_maps the fused entropy+seal kernel over ``data`` so every
mesh shard runs one local kernel launch on its slice of the stripe, then
combines RAID-5 P / RAID-6 Q with a cross-shard XOR reduce (exact, order-
free, bit-identical to this module's single-device path).  The hooks below
(``encode_gop_payload`` / ``seal_payload_stripe`` / the ``fused_fn`` /
``seal_fn`` / ``unseal_fn`` / ``entropy_fn`` / ``entropy_decode_fn``
parameters) are the seams that path plugs into — subset reads ride the
same seams via ``shard_ids``.

Telemetry (``repro.obs``, off by default — one branch per site when off):

Every byte this pipeline moves is billed to a named ledger edge, each at
exactly ONE site so the totals conserve:

* ``ingest.host_to_device`` — raw codec payload bytes entering the seal
  (the pre-compression volume a host-codec design would ship); billed in
  ``_assemble_stripe``, the join point of the fused AND chained write
  paths.
* ``ingest.entropy_raw`` / ``ingest.entropy_comp`` — bytes through the
  entropy stage and the streams they became; their ratio is the archive's
  compression ratio, recomputable from the ledger alone.
* ``ingest.device_to_journal`` — sealed body bytes leaving the kernel for
  the journal (the only payload traffic the CSD design ships host-side).
* ``ingest.shard_to_parity`` — P/Q strip bytes per sealed stripe.
* ``replay.read`` — sealed bytes a restore actually moved (present wanted
  shards); ``replay.parity`` — degraded-read amplification (surviving
  unwanted peers + parity strips fed to ``recover_stripe``); both billed
  in ``restore_stripe_payloads``.
* ``ingest.shed`` — payload bytes the streaming admission controller
  (``serving/ingest.py``) refused under queue pressure; billed at exactly
  one site (``StreamIngestFrontend._shed``), each shed journaled — never
  a silent drop.
* ``replay.planned`` / ``replay.full_baseline`` are billed by the query
  planner (``core/csd/retrieval.py``); ``scrub.*`` / ``rebuild.*`` by the
  durability tier (``core/archival/scrub.py``, ``distributed/archival``).

Pipelined submission: ``seal_payload_stripes`` splits into a dispatch
half (KEM + host staging + async fused launch) and a finalize half (the
single blocking device→host fetch + archive assembly).  The streaming
ingest tier (``serving/ingest.py``) runs them through a two-slot submit
ring so batch k's seal overlaps batch k+1's host prep; the synchronous
entry is literally ``finalize(dispatch(...))``, so both paths are
bit-identical by construction.

Spans (``archive.seal`` / ``archive.seal_chained`` / ``archive.unseal`` /
``archive.entropy_*`` / ``archive.parity_recompute``) carry stripe shape,
codec, parity mode and the exact fused-launch count, and export as a
Perfetto-loadable trace via ``repro.obs.export``.
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import compress as host_entropy
from repro.core.archival import raid
from repro.core.codec.layered_codec import (
    CodecConfig,
    FrameCode,
    decode_gop,
    encode_gop,
)
from repro.core.crypto import rlwe
from repro.core.crypto.hybrid import (
    SealedBlock,
    encapsulate_session,
    seal,
    unseal,
)
from repro.kernels.entropy import ops as entropy_ops
from repro.kernels.fused import ops as fused_ops
from repro.kernels.seal import ops as seal_ops
from repro.obs import (
    EDGE_DEVICE_TO_JOURNAL,
    EDGE_ENTROPY_COMP,
    EDGE_ENTROPY_RAW,
    EDGE_HOST_TO_DEVICE,
    EDGE_REPLAY_PARITY,
    EDGE_REPLAY_READ,
    EDGE_SHARD_TO_PARITY,
    OBS,
)
from repro.obs import names as obs_names

__all__ = [
    "ArchiveConfig",
    "ArchivedBlock",
    "StripeArchive",
    "pack_i8_to_u32",
    "unpack_u32_to_i8",
    "archive_gop",
    "restore_gop",
    "encode_gop_payload",
    "entropy_encode_payloads",
    "entropy_decode_payloads",
    "seal_payload_stripe",
    "seal_payload_stripes",
    "seal_payload_stripes_dispatch",
    "seal_payload_stripes_finalize",
    "PendingStripeSeal",
    "archive_stripe",
    "restore_stripe",
    "restore_stripe_payloads",
    "stripe_manifests",
    "stripe_manifests_to_json",
    "stripe_manifests_from_json",
    "stripe_parity",
    "recover_stripe",
    "recompute_stripe_parity",
]


class ArchiveConfig(NamedTuple):
    codec: CodecConfig = CodecConfig()
    rlwe: rlwe.RLWEParams = rlwe.RLWEParams()
    n_layers: Optional[int] = None  # quality-layer prefix (None = all)
    parity: str = "raid6"  # "raid5" | "raid6" | "none"
    # entropy stage: "rans" (on-device kernel) | "zstd"/"zlib" (host
    # fallback via repro.common.compress) | "none"
    codec_name: str = "rans"


class ArchivedBlock(NamedTuple):
    sealed: SealedBlock
    manifest: Dict  # shapes/lengths to invert packing (host-side metadata)


class StripeArchive(NamedTuple):
    """One parity stripe: S archived shards + their P/Q parity."""

    blocks: List[ArchivedBlock]
    parity: Optional[Dict]  # {"p": u8, "q"?: u8, "pad_to": words} or None


def pack_i8_to_u32(x: jax.Array) -> jax.Array:
    """Flat int8 (4N,) -> (N,) uint32 (little-endian lanes)."""
    b = (x.astype(jnp.int32) & 0xFF).astype(jnp.uint32).reshape(-1, 4)
    sh = jnp.arange(4, dtype=jnp.uint32) * 8
    return (b << sh).sum(-1, dtype=jnp.uint32)


def unpack_u32_to_i8(w: jax.Array, n: int) -> jax.Array:
    """(N,) uint32 -> flat int8 (n,)."""
    sh = jnp.arange(4, dtype=jnp.uint32) * 8
    b = ((w[:, None] >> sh) & jnp.uint32(0xFF)).astype(jnp.uint8)
    return b.reshape(-1)[:n].astype(jnp.int8)


def _flatten_codes(frame_codes: List[FrameCode]) -> Tuple[jax.Array, Dict]:
    parts, spec = [], []
    for fc in frame_codes:
        centry = []
        for z in fc.codes:
            parts.append(z.astype(jnp.int8).reshape(-1))
            centry.append(tuple(z.shape))
        mv_shape = None
        if fc.mv is not None:
            parts.append(fc.mv.astype(jnp.int8).reshape(-1))
            mv_shape = tuple(fc.mv.shape)
        spec.append({"codes": centry, "mv": mv_shape})
    flat = jnp.concatenate(parts)
    n = int(flat.shape[0])
    pad = (-n) % 4
    flat = jnp.pad(flat, (0, pad))
    return flat, {"spec": spec, "n_i8": n}


def _unflatten_codes(flat_i8: jax.Array, manifest: Dict) -> List[FrameCode]:
    out = []
    off = 0
    for entry in manifest["spec"]:
        codes = []
        for shp in entry["codes"]:
            sz = int(np.prod(shp))
            codes.append(
                flat_i8[off : off + sz].astype(jnp.float32).reshape(shp)
            )
            off += sz
        mv = None
        if entry["mv"] is not None:
            sz = int(np.prod(entry["mv"]))
            mv = flat_i8[off : off + sz].astype(jnp.int32).reshape(entry["mv"])
            off += sz
        out.append(FrameCode(codes, mv))
    return out


def archive_gop(
    codec_params,
    pub: rlwe.PublicKey,
    frames: jax.Array,
    key: jax.Array,
    cfg: ArchiveConfig = ArchiveConfig(),
) -> Tuple[ArchivedBlock, jax.Array]:
    """frames: (T, B, H, W, 3). Returns (ArchivedBlock, recons)."""
    frame_codes, recons = encode_gop(
        codec_params, cfg.codec, frames, n_layers=cfg.n_layers
    )
    flat, manifest = _flatten_codes(frame_codes)
    words = pack_i8_to_u32(flat)
    sealed = seal(pub, words, key, cfg.rlwe)
    manifest = dict(manifest, frames_shape=tuple(frames.shape))
    return ArchivedBlock(sealed, manifest), recons


def restore_gop(
    codec_params,
    s: jax.Array,
    block: ArchivedBlock,
    cfg: ArchiveConfig = ArchiveConfig(),
) -> jax.Array:
    words = unseal(s, block.sealed, cfg.rlwe)
    flat = unpack_u32_to_i8(words, block.manifest["n_i8"])
    frame_codes = _unflatten_codes(flat, block.manifest)
    return decode_gop(codec_params, cfg.codec, frame_codes)


# ------------------------------------------------------------ batched stripe
def _u32_rows_to_u8(rows: jax.Array) -> jax.Array:
    """(R, 128) uint32 parity tile -> flat uint8 (R*512,)."""
    return jax.lax.bitcast_convert_type(rows, jnp.uint8).reshape(-1)


def encode_gop_payload(
    codec_params,
    frames: jax.Array,
    cfg: ArchiveConfig = ArchiveConfig(),
) -> Tuple[jax.Array, Dict, jax.Array]:
    """Codec-encode one GOP to a flat int8 seal payload.

    frames: (T, B, H, W, 3).  Returns (flat int8 payload, manifest, recons).
    This is the encode half of ``archive_gop``/``archive_stripe``, split out
    so ingest layers (``repro.distributed.archival.StripeCoalescer``) can
    encode GOPs as they arrive and defer sealing until a full stripe exists.
    """
    frame_codes, recons = encode_gop(
        codec_params, cfg.codec, frames, n_layers=cfg.n_layers
    )
    flat, manifest = _flatten_codes(frame_codes)
    return flat, dict(manifest, frames_shape=tuple(frames.shape)), recons


def entropy_encode_payloads(
    flats: List[jax.Array],
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    use_pallas: bool = True,
    entropy_fn=None,
) -> Tuple[List[jax.Array], List[Dict]]:
    """Entropy-code S shard payloads per ``cfg.codec_name``.

    Returns (compressed flats, per-shard entropy metas recorded into the
    manifests).  ``entropy_fn`` overrides the on-device coder launch — the
    sharded path passes a shard_map'd wrapper with the same signature as
    ``entropy_ops.encode_payloads`` (the ``seal_fn`` pattern).  Host codecs
    (zstd/zlib) pull the payload to the host — that is the traffic the
    on-device coder exists to remove; they are kept as the compatibility
    fallback.
    """
    name = cfg.codec_name
    if name == "none":
        return list(flats), [
            {"codec": "none", "n_raw": int(f.shape[0]), "n_comp": int(f.shape[0])}
            for f in flats
        ]
    if name == "rans":
        with OBS.span("archive.entropy_encode", codec=name, shards=len(flats)):
            if entropy_fn is not None:
                return entropy_fn(flats, use_pallas=use_pallas)
            return entropy_ops.encode_payloads(flats, use_pallas=use_pallas)
    if name in ("zstd", "zlib"):
        comps, metas = [], []
        for f in flats:
            raw = np.asarray(f, np.int8).tobytes()
            blob = host_entropy.compress_as(name, raw)
            if len(blob) >= len(raw):
                # adaptive raw-skip, same manifest flag as the rANS path
                comps.append(jnp.asarray(np.frombuffer(raw, np.int8)))
                metas.append(
                    {"codec": name, "raw": True,
                     "n_raw": len(raw), "n_comp": len(raw)}
                )
            else:
                comps.append(jnp.asarray(np.frombuffer(blob, np.int8)))
                metas.append(
                    {"codec": name, "n_raw": len(raw), "n_comp": len(blob)}
                )
        return comps, metas
    raise ValueError(f"unknown entropy codec {name!r}")


def entropy_decode_payloads(
    comps: List[jax.Array],
    metas: List[Dict],
    *,
    use_pallas: bool = True,
    entropy_decode_fn=None,
) -> List[jax.Array]:
    """Invert ``entropy_encode_payloads``, dispatching on the *recorded*
    codec (the manifest is ground truth, not the caller's current config)."""
    if not metas:
        return []
    names = {m["codec"] for m in metas}
    if len(names) != 1:
        raise ValueError(f"stripe mixes entropy codecs {sorted(names)}")
    name = names.pop()
    if name == "none":
        return list(comps)
    if name == "rans":
        with OBS.span("archive.entropy_decode", codec=name, shards=len(comps)):
            if entropy_decode_fn is not None:
                return entropy_decode_fn(comps, metas, use_pallas=use_pallas)
            return entropy_ops.decode_payloads(
                comps, metas, use_pallas=use_pallas
            )
    if name in ("zstd", "zlib"):
        out = []
        for c, m in zip(comps, metas):
            if m.get("raw"):  # adaptive raw-skip: stored bytes ARE the payload
                out.append(jnp.asarray(c).reshape(-1).astype(jnp.int8))
                continue
            raw = host_entropy.decompress_as(
                name, np.asarray(c, np.int8).tobytes(),
                max_output_size=m["n_raw"],
            )
            out.append(jnp.asarray(np.frombuffer(raw, np.int8)))
        return out
    raise ValueError(f"unknown entropy codec {name!r}")


def _bill_ingest(stripe, manifests: List[Dict], parity: Optional[Dict]) -> None:
    """Bill one sealed stripe's ingest edges to the byte-flow ledger.

    This is the SINGLE ingest billing site: the fused batched path and the
    chained reference path both assemble here with entropy-merged
    manifests, so every sealed stripe is billed exactly once.
    """
    raw = comp = host = 0
    for m in manifests:
        em = m.get("entropy") or {"codec": "none"}
        n_raw = int(em.get("n_raw", m.get("n_i8", 0)))
        host += n_raw
        if em.get("codec", "none") != "none":
            raw += n_raw
            comp += int(em.get("n_comp", n_raw))
    S = len(manifests)
    OBS.flow(EDGE_HOST_TO_DEVICE, host, events=S)
    if raw:
        OBS.flow(EDGE_ENTROPY_RAW, raw, events=S)
        OBS.flow(EDGE_ENTROPY_COMP, comp, events=S)
    OBS.flow(
        EDGE_DEVICE_TO_JOURNAL,
        sum(4 * int(n) for n in stripe.n_words),
        events=S,
    )
    if parity is not None:
        nb = int(parity["p"].size)
        q = parity.get("q")
        if q is not None:
            nb += int(q.size)
        OBS.flow(EDGE_SHARD_TO_PARITY, nb)


def _assemble_stripe(stripe, mats, manifests: List[Dict]) -> StripeArchive:
    """Wrap a SealedStripe + its KEM material as a ``StripeArchive``."""
    blocks = [
        ArchivedBlock(
            SealedBlock(
                m.kem_c1, m.kem_c2, m.nonce, stripe.body(s), stripe.n_words[s]
            ),
            manifests[s],
        )
        for s, m in enumerate(mats)
    ]
    parity = None
    if stripe.p is not None:
        parity = {"p": _u32_rows_to_u8(stripe.p), "pad_to": stripe.pad_words}
        if stripe.q is not None:
            parity["q"] = _u32_rows_to_u8(stripe.q)
    if OBS.enabled:
        _bill_ingest(stripe, manifests, parity)
    return StripeArchive(blocks, parity)


class PendingStripeSeal(NamedTuple):
    """A dispatched-but-unfetched stripe-seal batch.

    Exactly one of the three payload fields is populated:

    * ``kernel``   — a ``fused_ops.PendingSeal`` (the default async path:
      the jitted launch is in flight, nothing has synced);
    * ``results``  — eager ``[(SealedStripe, emetas), ...]`` from a legacy
      one-shot ``fused_fn`` override (already blocked at dispatch);
    * ``archives`` — fully assembled ``StripeArchive``s (host-codec /
      non-rans fallback, which has no async seam).

    ``mats`` / ``manifests`` ride along so the finalize half can assemble
    without re-deriving KEM material.
    """

    kernel: Optional[fused_ops.PendingSeal]
    results: Optional[List]
    archives: Optional[List[StripeArchive]]
    mats: List[List]
    manifests: List[List[Dict]]


def seal_payload_stripes_dispatch(
    pub: rlwe.PublicKey,
    stripes: List[List[jax.Array]],
    manifests: List[List[Dict]],
    keys: List[jax.Array],
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    use_pallas: bool = True,
    pad_rows=None,
    fused_fn=None,
    fused_dispatch_fn=None,
) -> PendingStripeSeal:
    """Async half of ``seal_payload_stripes``: KEM-encapsulate the session
    keys, stage the payloads, and dispatch the fused launch WITHOUT the
    device→host sync.  The returned handle is redeemed by
    ``seal_payload_stripes_finalize``; the two-slot submit ring
    (``repro.serving.ingest``) dispatches batch k+1's host prep between
    the two halves so host staging overlaps the in-flight seal.

    ``fused_dispatch_fn`` overrides the async launch (the sharded path
    passes ``entropy_seal_stripes_dispatch`` with a shard_map'd
    ``core_fn``); a legacy one-shot ``fused_fn`` still works but blocks
    at dispatch (its results are carried to finalize eagerly).
    """
    n = len(stripes)
    if not (n == len(manifests) == len(keys)):
        raise ValueError(
            f"{n} stripes vs {len(manifests)} manifests / {len(keys)} keys"
        )
    if isinstance(pad_rows, (list, tuple)):
        pr_list = list(pad_rows)
    else:
        pr_list = [pad_rows] * n
    if cfg.codec_name != "rans":
        archives = [
            seal_payload_stripe(
                pub, f, m, k, cfg, use_pallas=use_pallas, pad_rows=pr
            )
            for f, m, k, pr in zip(stripes, manifests, keys, pr_list)
        ]
        return PendingStripeSeal(None, None, archives, [], [])
    mats = [
        [
            encapsulate_session(pub, jax.random.fold_in(k, s), cfg.rlwe)
            for s in range(len(f))
        ]
        for k, f in zip(keys, stripes)
    ]
    keys_a = [jnp.stack([m.session for m in ms]) for ms in mats]
    nonces_a = [jnp.stack([m.nonce for m in ms]) for ms in mats]
    with OBS.span(
        "archive.seal", stripes=n, shards=len(stripes[0]),
        codec=cfg.codec_name, parity=cfg.parity,
    ) as sp:
        launches0 = OBS.metrics.get(obs_names.FUSED_LAUNCHES) if OBS.enabled else 0
        if fused_fn is not None:
            results = fused_fn(
                stripes, keys_a, nonces_a, parity=cfg.parity,
                use_pallas=use_pallas, pad_rows=pr_list,
            )
            kernel = None
        else:
            dispatch = fused_dispatch_fn or fused_ops.entropy_seal_stripes_dispatch
            kernel = dispatch(
                stripes, keys_a, nonces_a, parity=cfg.parity,
                use_pallas=use_pallas, pad_rows=pr_list,
            )
            results = None
        if OBS.enabled:
            sp.set(launches=int(
                OBS.metrics.get(obs_names.FUSED_LAUNCHES) - launches0
            ))
    return PendingStripeSeal(kernel, results, None, mats, manifests)


def seal_payload_stripes_finalize(
    pending: PendingStripeSeal,
) -> List[StripeArchive]:
    """Blocking half: fetch the dispatched batch's rANS word counts (the
    only device→host sync) and assemble + ledger-bill the archives."""
    if pending.archives is not None:
        return pending.archives
    if pending.kernel is not None:
        results = fused_ops.entropy_seal_stripes_finalize(pending.kernel)
    else:
        results = pending.results
    return [
        _assemble_stripe(
            stripe, ms, [dict(m, entropy=em) for m, em in zip(mfs, emetas)]
        )
        for (stripe, emetas), ms, mfs in zip(
            results, pending.mats, pending.manifests
        )
    ]


def seal_payload_stripes(
    pub: rlwe.PublicKey,
    stripes: List[List[jax.Array]],
    manifests: List[List[Dict]],
    keys: List[jax.Array],
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    use_pallas: bool = True,
    pad_rows=None,
    fused_fn=None,
) -> List[StripeArchive]:
    """Batched ``seal_payload_stripe``: K stripes per fused kernel launch.

    stripes / manifests / keys are per-stripe lists; ``pad_rows`` is None,
    an int, or a per-stripe sequence (same re-bucketing semantics as the
    singular).  For ``codec_name="rans"`` the whole batch goes through the
    one-launch fused kernel (``repro.kernels.fused``): homogeneous stripes
    share ONE launch with K stripes on the batch axis, so per-launch
    dispatch amortizes K-fold and the packed streams never visit HBM
    between entropy and seal.  ``fused_fn`` overrides the batched launch
    (the sharded path passes ``entropy_seal_stripes`` with a shard_map'd
    ``core_fn``).  Host codecs fall back to the per-stripe chained path.
    Outputs are bit-identical to mapping ``seal_payload_stripe`` — and,
    being exactly ``finalize(dispatch(...))``, to the pipelined submit
    ring by construction.
    """
    return seal_payload_stripes_finalize(
        seal_payload_stripes_dispatch(
            pub, stripes, manifests, keys, cfg, use_pallas=use_pallas,
            pad_rows=pad_rows, fused_fn=fused_fn,
        )
    )


def seal_payload_stripe(
    pub: rlwe.PublicKey,
    flats: List[jax.Array],
    manifests: List[Dict],
    key: jax.Array,
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    use_pallas: bool = True,
    pad_rows: Optional[int] = None,
    seal_fn=None,
    entropy_fn=None,
    fused_fn=None,
) -> StripeArchive:
    """Entropy-code + seal pre-encoded payloads as one parity stripe.

    For ``codec_name="rans"`` the default path is the ONE-LAUNCH fused
    kernel (``repro.kernels.fused``): codes -> histogram/freq-table ->
    rANS -> v1 pack -> raw-skip -> ChaCha20 XOR-seal -> RAID-P/Q in a
    single Pallas launch, packed streams never materialized in HBM.
    Per-shard session keys are KEM-encapsulated host-side first (tiny,
    and the ``fold_in`` order matches the chained path, so archives are
    bit-identical).  ``fused_fn`` overrides the fused launch (the sharded
    path passes a shard_map'd wrapper); passing only ``seal_fn`` /
    ``entropy_fn`` (same signatures as ``seal_ops.seal_stripe`` /
    ``entropy_ops.encode_payloads``) keeps the two-launch chained path —
    which also serves host codecs and stays the decode-side reference.
    """
    if cfg.codec_name == "rans" and (
        fused_fn is not None or (seal_fn is None and entropy_fn is None)
    ):
        return seal_payload_stripes(
            pub, [flats], [manifests], [key], cfg, use_pallas=use_pallas,
            pad_rows=[pad_rows], fused_fn=fused_fn,
        )[0]
    with OBS.span(
        "archive.seal_chained", shards=len(flats),
        codec=cfg.codec_name, parity=cfg.parity,
    ):
        flats, emetas = entropy_encode_payloads(
            flats, cfg, use_pallas=use_pallas, entropy_fn=entropy_fn
        )
        manifests = [dict(m, entropy=em) for m, em in zip(manifests, emetas)]
        if cfg.codec_name != "none" and pad_rows is not None:
            # the caller's bucket covered the RAW payload; re-bucket on the
            # compressed sizes (still pow2, so jit traces stay bounded) — an
            # incompressible shard can exceed its raw bucket (stream header +
            # 16-bit renorm slack)
            pad_rows = seal_ops.bucket_rows_for(
                max(-(-int(f.shape[0]) // 4) for f in flats)
            )
        mats = [
            encapsulate_session(pub, jax.random.fold_in(key, s), cfg.rlwe)
            for s in range(len(flats))
        ]
        seal_fn = seal_fn or seal_ops.seal_stripe
        stripe = seal_fn(
            flats,
            jnp.stack([m.session for m in mats]),
            jnp.stack([m.nonce for m in mats]),
            parity=cfg.parity,
            use_pallas=use_pallas,
            pad_rows=pad_rows,
        )
        return _assemble_stripe(stripe, mats, manifests)


def archive_stripe(
    codec_params,
    pub: rlwe.PublicKey,
    frames_list: List[jax.Array],
    key: jax.Array,
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    use_pallas: bool = True,
    seal_fn=None,
    entropy_fn=None,
    fused_fn=None,
) -> Tuple[StripeArchive, List[jax.Array]]:
    """Archive S GOPs as one parity stripe: codes -> one-launch entropy+seal.

    frames_list: S clips, each (T, B, H, W, 3) — one per storage shard.
    ``use_pallas=False`` runs the staged jnp references instead
    (bit-identical streams, bodies and parity); ``seal_fn``/``entropy_fn``/
    ``fused_fn`` dispatch the launches (see ``seal_payload_stripe``).
    """
    flats, manifests, recons = [], [], []
    for frames in frames_list:
        flat, manifest, rec = encode_gop_payload(codec_params, frames, cfg)
        flats.append(flat)
        manifests.append(manifest)
        recons.append(rec)
    stripe = seal_payload_stripe(
        pub, flats, manifests, key, cfg, use_pallas=use_pallas,
        seal_fn=seal_fn, entropy_fn=entropy_fn, fused_fn=fused_fn,
    )
    return stripe, recons


def restore_stripe_payloads(
    s: jax.Array,
    stripe: StripeArchive,
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    shards: Optional[Sequence[int]] = None,
    use_pallas: bool = True,
    verify_parity: bool = True,
    manifests: Optional[List[Dict]] = None,
    unseal_fn=None,
    entropy_decode_fn=None,
) -> Tuple[List[jax.Array], List[ArchivedBlock]]:
    """Unseal + entropy-decode a stripe down to codec payloads.

    This is the retrieval datapath below the neural codec: everything
    ``restore_stripe`` does except the final ``decode_gop``.  Returns
    (flat int8 payloads, the blocks they came from) in ``shards`` order.

    Shard-subset reads: ``shards`` names the stripe shards a query plan
    actually wants (``core/csd/retrieval.plan_retrieval`` emits them) —
    ONLY those bodies are stacked into the unseal launch, so a top-k
    retrieval moves/decodes k shards instead of the whole stripe.  Parity
    cannot be recomputed from a subset, so subset reads skip the
    recompute-and-compare integrity check (full-stripe reads keep it).

    Degraded reads: entries of ``stripe.blocks`` may be ``None`` (shard
    lost, or its CSD flagged dead by the ``StragglerMonitor``).  Wanted
    missing shards are rebuilt from RAID parity via ``recover_stripe``
    first — that read touches the surviving shards + parity (the classic
    degraded-read amplification; the planner bills it), and needs the
    replicated metadata records (``stripe_manifests`` format) in
    ``manifests`` for the lost shards' KEM polys/nonces/lengths.
    """
    if not stripe.blocks:
        raise ValueError("stripe must contain at least one shard payload")
    S = len(stripe.blocks)
    subset = shards is not None
    wanted = list(range(S)) if shards is None else [int(i) for i in shards]
    if not wanted:
        raise ValueError("shard subset must name at least one shard")
    if len(set(wanted)) != len(wanted):
        raise ValueError(f"duplicate shard ids in {wanted}")
    if any(i < 0 or i >= S for i in wanted):
        raise ValueError(f"shard ids {wanted} out of range for S={S}")
    blocks = list(stripe.blocks)
    missing = [i for i, b in enumerate(blocks) if b is None]
    if any(i in missing for i in wanted):
        if stripe.parity is None:
            raise ValueError(
                f"shards {sorted(set(missing) & set(wanted))} are missing "
                "and the stripe has no parity to rebuild from"
            )
        if manifests is None:
            raise ValueError(
                "degraded read needs the replicated metadata records "
                "(stripe_manifests format) for the missing shards"
            )
        body_lens = [
            int(manifests[i]["n_words"])
            if blocks[i] is None
            else int(blocks[i].sealed.n_valid_u32)
            for i in range(S)
        ]
        blocks = recover_stripe(
            blocks, stripe.parity, missing, manifests, body_lens
        )
    sub = [blocks[i] for i in wanted]
    if OBS.enabled:
        # replay.read: sealed bytes the subset read actually moved (wanted
        # shards that were present on their CSD)
        OBS.flow(
            EDGE_REPLAY_READ,
            sum(
                4 * int(stripe.blocks[i].sealed.n_valid_u32)
                for i in wanted
                if stripe.blocks[i] is not None
            ),
            events=len(wanted),
        )
        deg = set(missing) & set(wanted)
        if deg:
            # replay.parity: the degraded-read amplification — surviving
            # peers OUTSIDE the wanted subset plus both parity strips, all
            # of which recover_stripe had to pull in
            amp = sum(
                4 * int(stripe.blocks[i].sealed.n_valid_u32)
                for i in range(S)
                if stripe.blocks[i] is not None and i not in wanted
            )
            amp += int(stripe.parity["p"].size)
            q_strip = stripe.parity.get("q")
            if q_strip is not None:
                amp += int(q_strip.size)
            OBS.flow(EDGE_REPLAY_PARITY, amp, events=len(deg))
    sessions, nonces = [], []
    for b in sub:
        sessions.append(
            rlwe.kem_decapsulate(
                s, rlwe.Ciphertext(b.sealed.kem_c1, b.sealed.kem_c2), cfg.rlwe
            )
        )
        nonces.append(b.sealed.nonce)

    n_words = tuple(int(b.sealed.body.shape[0]) for b in sub)
    emetas = [b.manifest.get("entropy", {"codec": "none"}) for b in sub]
    # bytes inside the sealed body: the compressed stream when an entropy
    # stage ran, the raw payload otherwise
    n_i8 = tuple(
        int(em.get("n_comp", b.manifest["n_i8"]))
        for b, em in zip(sub, emetas)
    )
    R = seal_ops.pad_rows_for(max(n_words))
    sealed = jnp.stack(
        [
            jnp.pad(b.sealed.body, (0, R * 128 - n)).reshape(R, 128)
            for b, n in zip(sub, n_words)
        ]
    )
    packed = seal_ops.SealedStripe(sealed, None, None, n_words, n_i8)
    # recompute parity in the mode the stripe was actually sealed with (the
    # stored parity dict is ground truth), not whatever the caller's cfg
    # says — otherwise verify_parity could silently compare nothing.  A
    # subset read cannot recompute stripe-wide parity, so it runs "none".
    if subset or stripe.parity is None:
        parity_mode = "none"
    else:
        parity_mode = "raid6" if "q" in stripe.parity else "raid5"
    unseal_fn = unseal_fn or seal_ops.unseal_stripe
    with OBS.span(
        "archive.unseal", shards=len(wanted), subset=subset,
        degraded=len(set(missing) & set(wanted)), parity=parity_mode,
    ):
        flats, p2, q2 = unseal_fn(
            packed,
            jnp.stack(sessions),
            jnp.stack(nonces),
            parity=parity_mode,
            use_pallas=use_pallas,
            shard_ids=tuple(wanted),
        )
    if not subset and verify_parity and stripe.parity is not None:
        for name, got in (("p", p2), ("q", q2)):
            want = stripe.parity.get(name)
            if want is None or got is None:
                continue
            got_u8 = np.asarray(_u32_rows_to_u8(got))
            want_u8 = np.asarray(want)
            n = min(got_u8.size, want_u8.size)
            if not (
                np.array_equal(got_u8[:n], want_u8[:n])
                and not got_u8[n:].any()
                and not want_u8[n:].any()
            ):
                raise ValueError(f"stripe parity mismatch on {name.upper()}")

    payloads = entropy_decode_payloads(
        [flats[j][: n_i8[j]] for j in range(len(sub))],
        [dict(em, codec=em.get("codec", "none")) for em in emetas],
        use_pallas=use_pallas,
        entropy_decode_fn=entropy_decode_fn,
    )
    return (
        [p[: b.manifest["n_i8"]] for p, b in zip(payloads, sub)],
        sub,
    )


def restore_stripe(
    codec_params,
    s: jax.Array,
    stripe: StripeArchive,
    cfg: ArchiveConfig = ArchiveConfig(),
    *,
    shards: Optional[Sequence[int]] = None,
    use_pallas: bool = True,
    verify_parity: bool = True,
    manifests: Optional[List[Dict]] = None,
    unseal_fn=None,
    entropy_decode_fn=None,
) -> List[jax.Array]:
    """Decode stripe shards: fused unseal -> entropy decode -> GOPs.

    ``shards=None`` decodes the whole stripe with the recompute-and-compare
    parity integrity check; ``shards=[...]`` is the retrieval fast path —
    only the named shards' bodies enter the unseal launch (see
    ``restore_stripe_payloads`` for subset/degraded-read semantics; missing
    wanted shards are parity-rebuilt when ``manifests`` carries their
    replicated metadata).  The entropy codec is dispatched from the
    manifest (what was written wins over the caller's cfg).
    ``unseal_fn``/``entropy_decode_fn`` dispatch the launches (the sharded
    path passes shard_map'd wrappers).  Returns one decoded GOP per
    requested shard, in ``shards`` order.
    """
    payloads, sub = restore_stripe_payloads(
        s, stripe, cfg, shards=shards, use_pallas=use_pallas,
        verify_parity=verify_parity, manifests=manifests,
        unseal_fn=unseal_fn, entropy_decode_fn=entropy_decode_fn,
    )
    return [
        decode_gop(
            codec_params, cfg.codec, _unflatten_codes(p, b.manifest)
        )
        for p, b in zip(payloads, sub)
    ]


def stripe_manifests(stripe: StripeArchive) -> List[Dict]:
    """Replicated-metadata records in the format ``recover_stripe`` and the
    degraded-read path expect (``n_words`` sizes a lost shard's body)."""
    return [
        {
            "kem_c1": b.sealed.kem_c1,
            "kem_c2": b.sealed.kem_c2,
            "nonce": b.sealed.nonce,
            "manifest": b.manifest,
            "n_words": int(b.sealed.n_valid_u32),
        }
        for b in stripe.blocks
    ]


def stripe_manifests_to_json(manifests: List[Dict]) -> List[Dict]:
    """JSON-able form of ``stripe_manifests`` records, so the replicated
    metadata tier can live in the power-loss-safe journal and a restarted
    trainer can still execute retrieval plans against old stripes."""
    return [
        {
            "kem_c1": np.asarray(m["kem_c1"]).tolist(),
            "kem_c2": np.asarray(m["kem_c2"]).tolist(),
            "nonce": np.asarray(m["nonce"]).tolist(),
            "manifest": m["manifest"],
            "n_words": int(m["n_words"]),
        }
        for m in manifests
    ]


def stripe_manifests_from_json(data: List[Dict]) -> List[Dict]:
    """Invert ``stripe_manifests_to_json`` (arrays back on device)."""
    return [
        {
            "kem_c1": jnp.asarray(m["kem_c1"], jnp.int32),
            "kem_c2": jnp.asarray(m["kem_c2"], jnp.int32),
            "nonce": jnp.asarray(m["nonce"], jnp.uint32),
            "manifest": m["manifest"],
            "n_words": int(m["n_words"]),
        }
        for m in data
    ]


# --------------------------------------------------------------- parity tier
def _bodies_u8(blocks: List[ArchivedBlock], pad_to: int) -> jnp.ndarray:
    rows = []
    for b in blocks:
        w = b.sealed.body
        w = jnp.pad(w, (0, pad_to - w.shape[0]))
        rows.append(jax.lax.bitcast_convert_type(w, jnp.uint8).reshape(-1))
    return jnp.stack(rows)  # (S, pad_to*4) uint8


def stripe_parity(blocks: List[ArchivedBlock], mode: str = "raid6"):
    """Parity over the sealed bodies of one stripe (S storage shards)."""
    if mode == "none":
        return None
    pad_to = max(int(b.sealed.body.shape[0]) for b in blocks)
    data = _bodies_u8(blocks, pad_to)
    if mode == "raid5":
        return {"p": raid.raid5_encode(data), "pad_to": pad_to}
    p, q = raid.raid6_encode(data)
    return {"p": p, "q": q, "pad_to": pad_to}


def recover_stripe(
    blocks: List[Optional[ArchivedBlock]],
    parity: Dict,
    missing: List[int],
    manifests: List[Dict],
    body_lens: List[int],
    *,
    stripe_id: str = "",
) -> List[ArchivedBlock]:
    """Rebuild missing shards' sealed bodies from parity.

    Note: parity protects the *body*; KEM polys + nonce are tiny and stored
    replicated in the manifest tier (standard metadata replication).
    ``stripe_id`` (optional) names the stripe in error messages so a
    degraded read that exceeds the parity mode's erasure budget is
    diagnosable from the exception alone.
    """
    pad_to = parity["pad_to"]
    mode = "raid6" if "q" in parity else "raid5"
    rows: List[Optional[jnp.ndarray]] = []
    for b in blocks:
        rows.append(None if b is None else _bodies_u8([b], pad_to)[0])
    if mode == "raid6":
        full = raid.raid6_reconstruct(rows, parity["p"], parity.get("q"), missing)
    else:
        if len(missing) != 1:
            which = f"stripe {stripe_id!r}" if stripe_id else "stripe"
            raise ValueError(
                f"{which}: RAID-5 parity covers exactly 1 erasure but shards "
                f"{sorted(missing)} are missing — data is unrecoverable "
                "without a RAID-6 Q strip or a replica"
            )
        full = list(rows)
        full[missing[0]] = raid.raid5_reconstruct(rows, parity["p"], missing[0])
    out: List[ArchivedBlock] = []
    for i, b in enumerate(blocks):
        if b is not None:
            out.append(b)
            continue
        words = jax.lax.bitcast_convert_type(
            full[i].reshape(-1, 4), jnp.uint32
        ).reshape(-1)[: body_lens[i]]
        meta = manifests[i]
        sealed = SealedBlock(
            meta["kem_c1"], meta["kem_c2"], meta["nonce"], words, body_lens[i]
        )
        out.append(ArchivedBlock(sealed, meta["manifest"]))
    return out


def recompute_stripe_parity(
    stripe: StripeArchive,
    *,
    use_pallas: bool = True,
    unseal_fn=None,
) -> Dict[str, np.ndarray]:
    """Recompute a sealed stripe's P/Q WITHOUT any key material.

    The seal kernel defines parity over the *sealed* bodies (ciphertext),
    so the scrubber can drive the same fused unseal launch with all-zero
    session keys/nonces: the ChaCha XOR it applies is garbage, but the
    P/Q accumulation runs on the input bodies and is exact.  This is what
    lets scrubbing run on the CSD tier — it never decrypts, never holds
    keys, and ships only syndrome bytes (see ``csd/costmodel.py``).

    Bodies are stacked at the stripe's seal-time geometry
    (``parity["pad_to"]`` words) so recomputed strips align byte-for-byte
    with the stored ones.  Returns ``{"p": u8, "q"?: u8}`` as numpy.
    """
    parity = stripe.parity
    if parity is None:
        raise ValueError("stripe has no parity strips to recompute")
    if any(b is None for b in stripe.blocks):
        raise ValueError(
            "parity recompute needs every shard body present; rebuild "
            "missing shards first (recover_stripe / rebuild_csd_sharded)"
        )
    S = len(stripe.blocks)
    pad_to = int(parity["pad_to"])
    R = pad_to // 128
    n_words = tuple(int(b.sealed.body.shape[0]) for b in stripe.blocks)
    if max(n_words) > pad_to:
        raise ValueError(
            f"shard body of {max(n_words)} words exceeds the stripe's "
            f"seal-time pad_to={pad_to}"
        )
    sealed = jnp.stack(
        [
            jnp.pad(b.sealed.body, (0, pad_to - n)).reshape(R, 128)
            for b, n in zip(stripe.blocks, n_words)
        ]
    )
    packed = seal_ops.SealedStripe(sealed, None, None, n_words, n_words)
    mode = "raid6" if "q" in parity else "raid5"
    fn = unseal_fn or seal_ops.unseal_stripe
    with OBS.span("archive.parity_recompute", shards=S, parity=mode):
        _, p2, q2 = fn(
            packed,
            jnp.zeros((S, 8), jnp.uint32),
            jnp.zeros((S, 3), jnp.uint32),
            parity=mode,
            use_pallas=use_pallas,
        )
    out = {"p": np.asarray(_u32_rows_to_u8(p2))}
    if q2 is not None:
        out["q"] = np.asarray(_u32_rows_to_u8(q2))
    return out
