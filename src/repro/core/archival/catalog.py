"""Salience-indexed archive catalog: query sealed stripes WITHOUT decoding.

Salient Store's retrieval thesis is that the archive is an active
participant in continuous learning: the trainer must be able to ask "which
archived GOPs are most novel w.r.t. what I know now?" and move only those
bytes.  Decoding stripes to answer that question would forfeit the win, so
each stripe is indexed AT ARCHIVE TIME — before seal, while the backbone
features for the GOP are still hot from exemplar selection — with a
per-GOP salience descriptor:

  * the pooled feature vector of the GOP (same features ``select_exemplars``
    clusters, so catalog queries and the trainer speak one embedding space);
  * the novelty score against the trainer's exemplar centroids at archive
    time (a prior that stays useful even when the query passes no centroids);
  * the byte geometry of the sealed shard (raw/compressed/body lengths) so
    the query planner (``core/csd/retrieval.py``) can price a read plan
    without touching the stripe.

Descriptors are tiny (one feature vector + a handful of ints per GOP) and
live in the replicated-metadata tier: ``StripeCatalog`` persists one record
per stripe through the power-loss-safe ``csd.failure.Journal``, so a restart
replays the catalog exactly like it replays committed stripes.  Queries
re-score stored features against the CALLER's current centroids
(``novelty_scores``) — novelty drifts as the trainer learns, the features do
not.
"""

from __future__ import annotations

import json
from typing import Dict, List, NamedTuple, Optional, Sequence

import numpy as np

from repro.core.archival.exemplar import novelty_scores
from repro.core.csd.failure import Journal

__all__ = ["CatalogEntry", "StripeCatalog", "gop_descriptors",
           "CATALOG_PREFIX", "RETIRE_PREFIX"]

CATALOG_PREFIX = "catalog_"
RETIRE_PREFIX = "retired_"


def gop_descriptors(gops, feature_dim: Optional[int] = None) -> List[Dict]:
    """``add_stripe`` descriptors from coalescer ``PendingGOP``s.

    The exemplar stage rides ``feature``/``novelty`` in each GOP's meta;
    GOPs without one get a zero vector sized to ``feature_dim`` (pass the
    catalog's locked dim, or the configured descriptor width) so one
    ingest tier never mixes embedding widths.  Shared by the trainer and
    the serving ingest so the fallback cannot drift between them.
    """
    fallback = np.zeros(feature_dim or 1, np.float32)
    return [
        {
            "stream_id": g.stream_id,
            "feature": (g.meta or {}).get("feature", fallback),
            "novelty": (g.meta or {}).get("novelty", 0.0),
        }
        for g in gops
    ]


class CatalogEntry(NamedTuple):
    """One archived GOP: where it lives and how salient it looked at seal."""

    stripe_id: str
    shard: int          # shard index inside the stripe (== CSD that owns it)
    stream_id: int      # camera stream the GOP came from (-1 if unknown)
    feature: np.ndarray  # (D,) float32 pooled backbone feature of the GOP
    novelty: float      # novelty vs trainer centroids at archive time
    n_i8: int           # raw codec payload bytes (post neural codec)
    n_comp: int         # entropy-coded bytes inside the sealed body
    body_bytes: int     # sealed body bytes on disk (what a read moves)
    sealed_step: int = -1  # trainer step at seal time (-1 = unknown); TTL clock

    def to_record(self) -> Dict:
        return {
            "shard": self.shard,
            "stream_id": self.stream_id,
            "feature": np.asarray(self.feature, np.float32).tolist(),
            "novelty": float(self.novelty),
            "n_i8": self.n_i8,
            "n_comp": self.n_comp,
            "body_bytes": self.body_bytes,
            "sealed_step": self.sealed_step,
        }

    @classmethod
    def from_record(cls, stripe_id: str, rec: Dict) -> "CatalogEntry":
        return cls(
            stripe_id=stripe_id,
            shard=int(rec["shard"]),
            stream_id=int(rec["stream_id"]),
            feature=np.asarray(rec["feature"], np.float32),
            novelty=float(rec["novelty"]),
            n_i8=int(rec["n_i8"]),
            n_comp=int(rec["n_comp"]),
            body_bytes=int(rec["body_bytes"]),
            sealed_step=int(rec.get("sealed_step", -1)),
        )


class StripeCatalog:
    """In-memory index of archived GOP descriptors, journal-persisted.

    ``journal``: optional :class:`Journal`; when given, ``add_stripe``
    commits one ``catalog_<stripe_id>.json`` record per stripe (payload =
    the descriptor list) and ``load()`` rebuilds the index from a replay —
    torn catalog writes are dropped exactly like torn stripe bodies.
    """

    def __init__(self, journal: Optional[Journal] = None):
        self.journal = journal
        self._entries: List[CatalogEntry] = []
        self._stripe_ids: set = set()
        self._retired: set = set()

    # ------------------------------------------------------------ indexing
    def add_stripe(
        self,
        stripe_id: str,
        stripe,  # StripeArchive (duck-typed to avoid the import cycle)
        descriptors: Sequence[Dict],
        sealed_step: int = -1,
    ) -> List[CatalogEntry]:
        """Index one sealed stripe; descriptors[s] describes GOP/shard s.

        Each descriptor needs ``feature`` ((D,) array-like) and optionally
        ``stream_id`` / ``novelty``.  Byte geometry comes from the stripe's
        own manifests, so the catalog can never disagree with what was
        sealed.  ``sealed_step`` stamps the trainer step at seal time — the
        stripe-lifecycle TTL clock.  Returns the new entries (appended).
        """
        if stripe_id in self._stripe_ids:
            raise ValueError(f"stripe {stripe_id!r} already cataloged")
        if len(descriptors) != len(stripe.blocks):
            raise ValueError(
                f"{len(descriptors)} descriptors for "
                f"{len(stripe.blocks)} stripe shards"
            )
        entries = []
        want_dim = self._entries[0].feature.size if self._entries else None
        for s, (blk, d) in enumerate(zip(stripe.blocks, descriptors)):
            em = blk.manifest.get("entropy", {})
            n_i8 = int(blk.manifest["n_i8"])
            feature = np.asarray(d["feature"], np.float32).reshape(-1)
            # one embedding space per catalog: a mismatched descriptor
            # would otherwise blow up much later, inside a query's stack
            if want_dim is None:
                want_dim = feature.size
            elif feature.size != want_dim:
                raise ValueError(
                    f"shard {s} descriptor has dim {feature.size}, catalog "
                    f"uses dim {want_dim}"
                )
            entries.append(
                CatalogEntry(
                    stripe_id=stripe_id,
                    shard=s,
                    stream_id=int(d.get("stream_id", -1)),
                    feature=feature,
                    novelty=float(d.get("novelty", 0.0)),
                    n_i8=n_i8,
                    n_comp=int(em.get("n_comp", n_i8)),
                    body_bytes=4 * int(blk.sealed.n_valid_u32),
                    sealed_step=int(sealed_step),
                )
            )
        self._entries.extend(entries)
        self._stripe_ids.add(stripe_id)
        if self.journal is not None:
            payload = json.dumps([e.to_record() for e in entries]).encode()
            self.journal.commit(
                f"{CATALOG_PREFIX}{stripe_id}.json",
                payload,
                {"kind": "catalog", "stripe_id": stripe_id,
                 "n_gops": len(entries)},
            )
        return entries

    # ----------------------------------------------------------- lifecycle
    def retire_stripe(self, stripe_id: str, meta: Optional[Dict] = None) -> int:
        """Retire one stripe: journal the retirement, then drop its entries.

        The ``retired_<id>.json`` record is committed BEFORE the in-memory
        entries disappear — the retirement is the durable fact; body/journal
        compaction and key/nonce recycling happen strictly after it (see
        ``core/archival/scrub.retire_stripes``).  Idempotent on replay:
        ``load()`` skips stripes with a retirement record even if their
        catalog record still exists.  Returns the number of entries dropped.
        """
        if self.journal is not None:
            payload = json.dumps(
                {"stripe_id": stripe_id, **(meta or {})}
            ).encode()
            self.journal.commit(
                f"{RETIRE_PREFIX}{stripe_id}.json",
                payload,
                {"kind": "retired", "stripe_id": stripe_id},
            )
        before = len(self._entries)
        self._entries = [e for e in self._entries if e.stripe_id != stripe_id]
        self._stripe_ids.discard(stripe_id)
        self._retired.add(stripe_id)
        return before - len(self._entries)

    @property
    def retired(self) -> set:
        return set(self._retired)

    def load(self) -> int:
        """Rebuild the index from the journal replay; returns #stripes.

        Two passes: retirement records win over catalog records regardless
        of journal order, so a stripe retired after cataloging never comes
        back on restart.
        """
        if self.journal is None:
            raise ValueError("catalog has no journal to load from")
        recs = self.journal.replay()
        for rec in recs:
            name = rec["name"]
            if name.startswith(RETIRE_PREFIX) and name.endswith(".json"):
                self._retired.add(name[len(RETIRE_PREFIX) : -len(".json")])
        n = 0
        for rec in recs:
            name = rec["name"]
            if not (name.startswith(CATALOG_PREFIX) and name.endswith(".json")):
                continue
            stripe_id = name[len(CATALOG_PREFIX) : -len(".json")]
            if stripe_id in self._stripe_ids or stripe_id in self._retired:
                continue
            records = json.loads(self.journal.read(name))
            self._entries.extend(
                CatalogEntry.from_record(stripe_id, r) for r in records
            )
            self._stripe_ids.add(stripe_id)
            n += 1
        return n

    # ------------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def entries(self) -> List[CatalogEntry]:
        return list(self._entries)

    @property
    def n_stripes(self) -> int:
        return len(self._stripe_ids)

    @property
    def feature_dim(self) -> Optional[int]:
        """Descriptor width the catalog is locked to (None while empty)."""
        return int(self._entries[0].feature.size) if self._entries else None

    @property
    def bytes_indexed(self) -> int:
        """Total sealed body bytes the catalog covers (full-restore cost)."""
        return sum(e.body_bytes for e in self._entries)

    def features(self) -> np.ndarray:
        """(N, D) stacked descriptor features (empty -> (0, 0))."""
        if not self._entries:
            return np.zeros((0, 0), np.float32)
        return np.stack([e.feature for e in self._entries])

    def score(self, centroids=None) -> np.ndarray:
        """Per-entry novelty against ``centroids`` (the caller's CURRENT
        exemplar centroids); falls back to the archive-time score when no
        centroids are given.  Never touches a payload byte."""
        if not self._entries:
            return np.zeros((0,), np.float32)
        if centroids is None:
            return np.asarray([e.novelty for e in self._entries], np.float32)
        return np.asarray(
            novelty_scores(self.features(), np.asarray(centroids, np.float32))
        )

    def topk(self, k: int, centroids=None) -> List[CatalogEntry]:
        """The k most-novel archived GOPs, most novel first."""
        nov = self.score(centroids)
        order = np.argsort(-nov, kind="stable")[: max(int(k), 0)]
        return [self._entries[i] for i in order]
