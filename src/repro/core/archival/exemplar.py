"""Exemplar selection: representation learning + k-means++ (paper §2.2).

The continuous-learning loop converts data to feature vectors with the frozen
backbone, clusters them (k-means++ seeding, Lloyd refinement), and scores
novelty as distance-to-nearest-centroid: far samples are "new classes" routed
to training; near samples are "known classes" routed to the archival path.
Pure JAX, jit-able, runs per storage shard inside shard_map.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["kmeanspp_init", "kmeans", "novelty_scores", "select_exemplars", "ExemplarSplit"]


class ExemplarSplit(NamedTuple):
    train_idx: jax.Array  # indices routed to continuous learning
    archive_idx: jax.Array  # indices routed to the archival pipeline
    novelty: jax.Array  # per-sample novelty score
    centroids: jax.Array


def _sqdist(x, c):
    """(N, D), (K, D) -> (N, K) squared distances."""
    return (
        jnp.sum(x * x, -1, keepdims=True)
        - 2.0 * x @ c.T
        + jnp.sum(c * c, -1)[None, :]
    )


def kmeanspp_init(key, x, k: int):
    """k-means++ seeding (Arthur & Vassilvitskii) in pure JAX."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    cents = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, carry):
        cents, key = carry
        d = _sqdist(x, cents)
        # distance to nearest chosen centroid (mask out un-chosen slots)
        mask = jnp.arange(k) < i
        dmin = jnp.min(jnp.where(mask[None, :], d, jnp.inf), axis=1)
        key, kc = jax.random.split(key)
        probs = dmin / jnp.maximum(dmin.sum(), 1e-12)
        nxt = jax.random.choice(kc, n, p=probs)
        return cents.at[i].set(x[nxt]), key

    cents, _ = jax.lax.fori_loop(1, k, body, (cents, key))
    return cents


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key, x, k: int, iters: int = 10):
    """Returns (centroids (k, D), assignment (N,))."""
    cents = kmeanspp_init(key, x, k)

    def step(_, cents):
        d = _sqdist(x, cents)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)  # (N, K)
        counts = onehot.sum(0)  # (K,)
        sums = onehot.T @ x  # (K, D)
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # keep old centroid for empty clusters
        return jnp.where(counts[:, None] > 0, new, cents)

    cents = jax.lax.fori_loop(0, iters, step, cents)
    assign = jnp.argmin(_sqdist(x, cents), axis=1)
    return cents, assign


def novelty_scores(x, centroids):
    return jnp.sqrt(jnp.maximum(jnp.min(_sqdist(x, centroids), axis=1), 0.0))


@functools.partial(jax.jit, static_argnames=("k", "n_train", "iters"))
def select_exemplars(
    key, feats, k: int = 8, n_train: int = 16, iters: int = 8, known_centroids=None
):
    """feats: (N, D) pooled feature vectors.

    Novelty is measured against the *known* distribution: the centroids from
    previous rounds (``known_centroids``) when available — the paper's "images
    much different from the training data distribution".  Without history,
    clusters are fit on the batch and only *established* clusters (size >=
    N/2k) count as known, so a handful of out-of-distribution samples forming
    their own tiny cluster still scores as novel.

    Top-``n_train`` most-novel samples go to training; the rest to archival.
    """
    n = feats.shape[0]
    cents, assign = kmeans(key, feats, k, iters)
    if known_centroids is not None:
        nov = novelty_scores(feats, known_centroids)
    else:
        counts = jax.nn.one_hot(assign, k, dtype=feats.dtype).sum(0)  # (K,)
        established = counts >= (n / (2.0 * k))
        d = _sqdist(feats, cents)
        d = jnp.where(established[None, :], d, jnp.inf)
        nov = jnp.sqrt(jnp.maximum(jnp.min(d, axis=1), 0.0))
    order = jnp.argsort(-nov)  # most novel first
    return ExemplarSplit(
        train_idx=order[:n_train],
        archive_idx=order[n_train:],
        novelty=nov,
        centroids=cents,
    )
