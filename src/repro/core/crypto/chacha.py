"""ChaCha20 stream cipher in pure JAX uint32 (RFC 8439 dataflow).

Role in Salient Store: the paper encrypts *bulk* archival data; R-LWE is the
quantum-safe key layer.  Production archival stacks wrap a symmetric stream
cipher under the KEM (encrypting terabytes coefficient-by-coefficient with
R-LWE would inflate data ~80x, defeating the data-movement goal).  ChaCha20
is pure 32-bit add/rotate/xor — fully vectorizable on the TPU VPU, one lane
per 64-byte block, so the whole keystream is a single fused elementwise graph.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["chacha20_block", "keystream", "xor_stream", "encrypt_u32", "decrypt_u32"]

_CONSTANTS = (0x61707865, 0x3320646E, 0x79622D32, 0x6B206574)  # "expand 32-byte k"

_COLUMN_IX = ((0, 4, 8, 12), (1, 5, 9, 13), (2, 6, 10, 14), (3, 7, 11, 15))
_DIAG_IX = ((0, 5, 10, 15), (1, 6, 11, 12), (2, 7, 8, 13), (3, 4, 9, 14))


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _quarter(x, ia, ib, ic, id_):
    a, b, c, d = x[..., ia], x[..., ib], x[..., ic], x[..., id_]
    a = a + b
    d = _rotl(d ^ a, 16)
    c = c + d
    b = _rotl(b ^ c, 12)
    a = a + b
    d = _rotl(d ^ a, 8)
    c = c + d
    b = _rotl(b ^ c, 7)
    return x.at[..., ia].set(a).at[..., ib].set(b).at[..., ic].set(c).at[..., id_].set(d)


def _double_round(x):
    for ix in _COLUMN_IX:
        x = _quarter(x, *ix)
    for ix in _DIAG_IX:
        x = _quarter(x, *ix)
    return x


def chacha20_block(key: jax.Array, counter: jax.Array, nonce: jax.Array) -> jax.Array:
    """key (8,) u32, counter scalar-or-(B,) u32, nonce (3,) u32 -> (..., 16) u32."""
    counter = jnp.atleast_1d(jnp.asarray(counter, jnp.uint32))
    B = counter.shape[0]
    const = jnp.tile(jnp.array(_CONSTANTS, jnp.uint32), (B, 1))
    keyw = jnp.tile(key.astype(jnp.uint32), (B, 1))
    noncew = jnp.tile(nonce.astype(jnp.uint32), (B, 1))
    state = jnp.concatenate([const, keyw, counter[:, None], noncew], axis=-1)
    x = state
    x = jax.lax.fori_loop(0, 10, lambda _, s: _double_round(s), x)
    return x + state


@functools.partial(jax.jit, static_argnames=("n_words",))
def keystream(
    key: jax.Array, nonce: jax.Array, n_words: int, counter0: int = 0
) -> jax.Array:
    """(n_words,) uint32 keystream (n_words rounded up internally to 16)."""
    n_blocks = (n_words + 15) // 16
    counters = jnp.uint32(counter0) + jnp.arange(n_blocks, dtype=jnp.uint32)
    ks = chacha20_block(key, counters, nonce)  # (n_blocks, 16)
    return ks.reshape(-1)[:n_words]


def xor_stream(key, nonce, data_u32: jax.Array, counter0: int = 0) -> jax.Array:
    """XOR a flat uint32 array with the keystream (encrypt == decrypt)."""
    flat = data_u32.reshape(-1).astype(jnp.uint32)
    ks = keystream(key, nonce, flat.shape[0], counter0)
    return (flat ^ ks).reshape(data_u32.shape)


encrypt_u32 = xor_stream
decrypt_u32 = xor_stream
