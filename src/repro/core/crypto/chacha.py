"""ChaCha20 stream cipher in pure JAX uint32 (RFC 8439 dataflow).

Role in Salient Store: the paper encrypts *bulk* archival data; R-LWE is the
quantum-safe key layer.  Production archival stacks wrap a symmetric stream
cipher under the KEM (encrypting terabytes coefficient-by-coefficient with
R-LWE would inflate data ~80x, defeating the data-movement goal).  ChaCha20
is pure 32-bit add/rotate/xor — fully vectorizable on the TPU VPU, one lane
per 64-byte block, so the whole keystream is a single fused elementwise graph.

The round function is exposed in a *kernel-callable* form
(``chacha_rounds_planes``): the 16 state words live as 16 separate arrays of
identical shape ("planes"), so the whole permutation is scatter/gather-free
elementwise arithmetic — exactly what a Pallas VPU kernel can consume (see
``repro.kernels.seal``).  The host-side ``chacha20_block`` is a thin layout
wrapper over the same core, so kernel and reference share one dataflow.
"""

from __future__ import annotations

import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "CONSTANTS",
    "chacha_rounds_planes",
    "chacha20_block",
    "keystream",
    "xor_stream",
    "encrypt_u32",
    "decrypt_u32",
    "bucket_n_words",
]

CONSTANTS = (0x61707865, 0x3320646E, 0x79622D32, 0x6B206574)  # "expand 32-byte k"
_CONSTANTS = CONSTANTS  # backward-compat alias

_COLUMN_IX = ((0, 4, 8, 12), (1, 5, 9, 13), (2, 6, 10, 14), (3, 7, 11, 15))
_DIAG_IX = ((0, 5, 10, 15), (1, 6, 11, 12), (2, 7, 8, 13), (3, 4, 9, 14))


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _quarter_planes(x: List[jax.Array], ia: int, ib: int, ic: int, id_: int) -> None:
    a, b, c, d = x[ia], x[ib], x[ic], x[id_]
    a = a + b
    d = _rotl(d ^ a, 16)
    c = c + d
    b = _rotl(b ^ c, 12)
    a = a + b
    d = _rotl(d ^ a, 8)
    c = c + d
    b = _rotl(b ^ c, 7)
    x[ia], x[ib], x[ic], x[id_] = a, b, c, d


def chacha_rounds_planes(state: Sequence[jax.Array]) -> List[jax.Array]:
    """20 ChaCha rounds + feed-forward on 16 uint32 planes of equal shape.

    Pure add/rotate/xor on whole planes — no scatter, no gather, no lane
    shuffles — so it is directly callable from inside a Pallas kernel body
    where the planes are VMEM-resident tiles of block counters.
    """
    def _double_round(_, planes):
        x = list(planes)
        for ix in _COLUMN_IX:
            _quarter_planes(x, *ix)
        for ix in _DIAG_IX:
            _quarter_planes(x, *ix)
        return tuple(x)

    x = jax.lax.fori_loop(0, 10, _double_round, tuple(state))
    return [xi + si for xi, si in zip(x, state)]


def chacha20_block(key: jax.Array, counter: jax.Array, nonce: jax.Array) -> jax.Array:
    """key (8,) u32, counter scalar-or-(B,) u32, nonce (3,) u32 -> (..., 16) u32."""
    counter = jnp.atleast_1d(jnp.asarray(counter, jnp.uint32))
    B = counter.shape[0]
    key = key.astype(jnp.uint32)
    nonce = nonce.astype(jnp.uint32)
    state = (
        [jnp.full((B,), c, jnp.uint32) for c in CONSTANTS]
        + [jnp.broadcast_to(key[i], (B,)) for i in range(8)]
        + [counter]
        + [jnp.broadcast_to(nonce[i], (B,)) for i in range(3)]
    )
    return jnp.stack(chacha_rounds_planes(state), axis=-1)


@functools.partial(jax.jit, static_argnames=("n_words",))
def keystream(
    key: jax.Array, nonce: jax.Array, n_words: int, counter0: int = 0
) -> jax.Array:
    """(n_words,) uint32 keystream (n_words rounded up internally to 16)."""
    n_blocks = (n_words + 15) // 16
    counters = jnp.uint32(counter0) + jnp.arange(n_blocks, dtype=jnp.uint32)
    ks = chacha20_block(key, counters, nonce)  # (n_blocks, 16)
    return ks.reshape(-1)[:n_words]


def bucket_n_words(n: int) -> int:
    """Smallest power of two >= max(n, 16).

    ``keystream`` specializes on ``n_words`` (a static argname), so every
    distinct body length would trigger a fresh jit trace.  Bucketing lengths
    to powers of two bounds the number of traces at log2(max_len) across
    arbitrarily mixed GOP sizes (pad to the bucket, slice back after XOR).
    """
    return max(16, 1 << (int(n) - 1).bit_length())


def xor_stream(key, nonce, data_u32: jax.Array, counter0: int = 0) -> jax.Array:
    """XOR a flat uint32 array with the keystream (encrypt == decrypt).

    Keystream length is bucketed to the next power of two so mixed-size
    payloads (e.g. variable GOPs in ``hybrid.seal``/``unseal``) share one
    compiled trace per bucket instead of one per distinct length.
    """
    flat = data_u32.reshape(-1).astype(jnp.uint32)
    n = flat.shape[0]
    nb = bucket_n_words(n)
    if nb != n:
        flat = jnp.pad(flat, (0, nb - n))
    ks = keystream(key, nonce, nb, counter0)
    return (flat ^ ks)[:n].reshape(data_u32.shape)


encrypt_u32 = xor_stream
decrypt_u32 = xor_stream
