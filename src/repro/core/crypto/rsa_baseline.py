"""RSA-512 software baseline for the Fig. 7 encryption comparison.

The paper compares its FPGA lattice engine against software/FPGA RSA.  This
module provides the *software RSA* work profile: textbook RSA over a 511-bit
modulus built from two fixed primes (2^256 - 189 and 2^255 - 19, both prime),
e = 65537, square-and-multiply modexp on the host CPU.  It exists purely as a
measured baseline — it is not a hardened RSA implementation (no OAEP, fixed
primes).
"""

from __future__ import annotations

from typing import List, Tuple

__all__ = ["RSA_P", "RSA_Q", "rsa_keypair", "rsa_encrypt_int", "rsa_decrypt_int",
           "rsa_encrypt_blocks", "rsa_decrypt_blocks"]

RSA_P = (1 << 256) - 189  # largest prime below 2^256
RSA_Q = (1 << 255) - 19  # the Curve25519 prime
_E = 65537


def rsa_keypair() -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """Returns ((n, e), (n, d))."""
    n = RSA_P * RSA_Q
    lam = (RSA_P - 1) * (RSA_Q - 1)
    d = pow(_E, -1, lam)
    return (n, _E), (n, d)


def rsa_encrypt_int(m: int, pub: Tuple[int, int]) -> int:
    n, e = pub
    assert 0 <= m < n
    return pow(m, e, n)


def rsa_decrypt_int(c: int, priv: Tuple[int, int]) -> int:
    n, d = priv
    return pow(c, d, n)


def rsa_encrypt_blocks(data: bytes, pub: Tuple[int, int]) -> List[int]:
    """Encrypt in 48-byte blocks (< 511-bit modulus)."""
    out = []
    for i in range(0, len(data), 48):
        out.append(rsa_encrypt_int(int.from_bytes(data[i : i + 48], "little"), pub))
    return out


def rsa_decrypt_blocks(blocks: List[int], n_bytes: int, priv) -> bytes:
    out = bytearray()
    for c in blocks:
        out += rsa_decrypt_int(c, priv).to_bytes(64, "little")[:48]
    return bytes(out[:n_bytes])
