"""Ring-LWE public-key encryption / KEM (Salient Store §4, Alg. 3).

Paper-faithful parameters: ring dimension n = 256 (the HSPM services degree-256
polynomials with 128 MAC lanes), 13-bit modulus q = 12289 (the SDMM packs
13-bit "signed Gaussian" samples), centered-binomial error distribution
(psi_16, sigma ~= 2.83 — the signed-sampling trick of Liu et al. cited by the
paper).  The encryption equation is the paper's ``d = a.b + c`` dataflow:

    keygen:   b_pk = a o s + e
    encrypt:  C1 = a o r + e1,        (Alg. 3 line 4, "utilizing HSPM")
              C2 = b_pk o r + e2 + encode(m)   (line 5, "employing SDMM")
    decrypt:  m  = decode(C2 - C1 o s)

All polynomial products route through the Pallas MXU kernel
(``kernels/polymul``) in the bulk fixed-key layout.

This is a systems reproduction of the paper's accelerator, not an audited
cryptographic implementation (no CCA transform, no constant-time host code).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.polymul.ops import polymul_fixed

__all__ = [
    "RLWEParams",
    "PublicKey",
    "Ciphertext",
    "keygen",
    "encrypt_bits",
    "decrypt_bits",
    "kem_encapsulate",
    "kem_decapsulate",
    "pack_bits_u32",
    "unpack_bits_u32",
]


class RLWEParams(NamedTuple):
    n: int = 256  # ring dimension (x^n + 1)
    q: int = 12289  # 13-bit modulus (NewHope-style, matches paper's samples)
    cbd_k: int = 16  # centered binomial psi_k, sigma = sqrt(k/2)


class PublicKey(NamedTuple):
    a: jax.Array  # (n,) uniform public polynomial
    b: jax.Array  # (n,) a o s + e


class Ciphertext(NamedTuple):
    c1: jax.Array  # (B, n)
    c2: jax.Array  # (B, n)


def _sample_uniform(key, shape, q):
    return jax.random.randint(key, shape, 0, q, dtype=jnp.int32)


def _sample_cbd(key, shape, k, q):
    """Centered binomial psi_k in [0, q) (mod-q representation)."""
    bits = jax.random.bernoulli(key, 0.5, shape + (2 * k,)).astype(jnp.int32)
    e = bits[..., :k].sum(-1) - bits[..., k:].sum(-1)  # in [-k, k]
    return jnp.mod(e, q).astype(jnp.int32)


def keygen(key: jax.Array, params: RLWEParams = RLWEParams()):
    """Returns (PublicKey, secret s)."""
    n, q, k = params
    ka, ks, ke = jax.random.split(key, 3)
    a = _sample_uniform(ka, (n,), q)
    s = _sample_cbd(ks, (n,), k, q)
    e = _sample_cbd(ke, (n,), k, q)
    b = jnp.mod(polymul_fixed(a, s[None, :], q)[0] + e, q)
    return PublicKey(a, b), s


def encrypt_bits(
    pub: PublicKey, m_bits: jax.Array, key: jax.Array, params: RLWEParams = RLWEParams()
) -> Ciphertext:
    """Encrypt a batch of bit-vectors. m_bits: (B, n) in {0, 1}."""
    n, q, k = params
    B = m_bits.shape[0]
    kr, k1, k2 = jax.random.split(key, 3)
    r = _sample_cbd(kr, (B, n), k, q)
    e1 = _sample_cbd(k1, (B, n), k, q)
    e2 = _sample_cbd(k2, (B, n), k, q)
    half_q = q // 2
    c1 = jnp.mod(polymul_fixed(pub.a, r, q) + e1, q)
    c2 = jnp.mod(polymul_fixed(pub.b, r, q) + e2 + m_bits.astype(jnp.int32) * half_q, q)
    return Ciphertext(c1, c2)


def decrypt_bits(
    s: jax.Array, ct: Ciphertext, params: RLWEParams = RLWEParams()
) -> jax.Array:
    """Decrypt to (B, n) bits."""
    n, q, k = params
    d = jnp.mod(ct.c2 - polymul_fixed(s, ct.c1, q), q)
    # bit = 1 iff d is closer to q/2 than to 0 (mod q)
    return ((d > q // 4) & (d < 3 * q // 4)).astype(jnp.int32)


def pack_bits_u32(bits: jax.Array) -> jax.Array:
    """(..., 32*w) {0,1} -> (..., w) uint32, little-endian bit order."""
    *lead, nb = bits.shape
    assert nb % 32 == 0, nb
    b = bits.reshape(*lead, nb // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    return (b * weights).sum(-1).astype(jnp.uint32)


def unpack_bits_u32(words: jax.Array, nbits: int) -> jax.Array:
    """(..., w) uint32 -> (..., nbits) {0,1}."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], words.shape[-1] * 32)[..., :nbits].astype(
        jnp.int32
    )


def kem_encapsulate(pub: PublicKey, key: jax.Array, params: RLWEParams = RLWEParams()):
    """Returns (Ciphertext, shared_key (8,) uint32 = 256 bits)."""
    n, q, k = params
    kb, ke = jax.random.split(key)
    m = jax.random.bernoulli(kb, 0.5, (1, n)).astype(jnp.int32)
    ct = encrypt_bits(pub, m, ke, params)
    shared = pack_bits_u32(m[0])
    return ct, shared


def kem_decapsulate(
    s: jax.Array, ct: Ciphertext, params: RLWEParams = RLWEParams()
) -> jax.Array:
    m = decrypt_bits(s, ct, params)
    return pack_bits_u32(m[0])
