"""Hybrid archival encryption: R-LWE KEM + ChaCha20 bulk layer.

This is the quantum-safe archival path of Salient Store: every archived block
is encrypted under a fresh session key encapsulated with the lattice KEM, so
the store-now-decrypt-later adversary faces the R-LWE problem, while the bulk
bytes only pay a stream-cipher XOR (vectorized on the VPU, near-memory on the
"CSD" shard).  The design is programmable per the paper's requirement —
session keys rotate per block / per epoch by construction.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.crypto import rlwe
from repro.core.crypto.chacha import xor_stream

__all__ = [
    "SealedBlock",
    "SessionMaterial",
    "encapsulate_session",
    "seal",
    "unseal",
    "bytes_to_u32",
    "u32_to_bytes",
]


class SealedBlock(NamedTuple):
    kem_c1: jax.Array  # (1, n) int32
    kem_c2: jax.Array  # (1, n) int32
    nonce: jax.Array  # (3,) uint32
    body: jax.Array  # uint32 payload, same shape as the input
    n_valid_u32: int  # logical length (payload may be padded by callers)


def bytes_to_u32(data: bytes) -> jax.Array:
    """Little-endian pack, zero-padded to a multiple of 4 bytes."""
    import numpy as np

    pad = (-len(data)) % 4
    buf = np.frombuffer(data + b"\0" * pad, dtype="<u4")
    return jnp.asarray(buf)


def u32_to_bytes(words: jax.Array, n_bytes: int) -> bytes:
    import numpy as np

    return np.asarray(words).astype("<u4").tobytes()[:n_bytes]


class SessionMaterial(NamedTuple):
    """One shard's bulk-encryption material: KEM ciphertext + symmetric key."""

    kem_c1: jax.Array  # (1, n) int32
    kem_c2: jax.Array  # (1, n) int32
    session: jax.Array  # (8,) uint32 ChaCha key (never stored)
    nonce: jax.Array  # (3,) uint32


def encapsulate_session(
    pub: rlwe.PublicKey,
    key: jax.Array,
    params: rlwe.RLWEParams = rlwe.RLWEParams(),
) -> SessionMaterial:
    """Fresh session key + nonce under the lattice KEM.

    Split out of ``seal`` so batched paths (the fused stripe kernel in
    ``repro.kernels.seal``) can run the tiny per-shard KEM host-side and hand
    all S session keys to one kernel launch for the bulk bytes.
    """
    k_kem, k_nonce = jax.random.split(key)
    ct, session = rlwe.kem_encapsulate(pub, k_kem, params)
    nonce = jax.random.randint(
        k_nonce, (3,), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32
    ).astype(jnp.uint32)
    return SessionMaterial(ct.c1, ct.c2, session, nonce)


def seal(
    pub: rlwe.PublicKey,
    payload_u32: jax.Array,
    key: jax.Array,
    params: rlwe.RLWEParams = rlwe.RLWEParams(),
) -> SealedBlock:
    """Encrypt a uint32 payload under a fresh encapsulated session key."""
    sm = encapsulate_session(pub, key, params)
    body = xor_stream(sm.session, sm.nonce, payload_u32)
    return SealedBlock(sm.kem_c1, sm.kem_c2, sm.nonce, body, int(payload_u32.size))


def unseal(
    s: jax.Array,
    block: SealedBlock,
    params: rlwe.RLWEParams = rlwe.RLWEParams(),
) -> jax.Array:
    session = rlwe.kem_decapsulate(
        s, rlwe.Ciphertext(block.kem_c1, block.kem_c2), params
    )
    return xor_stream(session, block.nonce, block.body)
