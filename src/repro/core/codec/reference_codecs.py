"""Classical-codec baselines for the Fig. 8/9 comparisons, in pure JAX.

The paper benchmarks its neural codec against H.264 and HEVC.  No codec
binaries exist in this container, so we implement the two standards'
*transform-coding cores* (the part that determines rate-distortion shape):

* ``h264_like``  — 8x8 block DCT, JPEG-style quantization matrix scaled by QP,
  motion-compensated P-frames (reusing our block-matching kernel), zstd
  entropy stage.
* ``hevc_like``  — 16x16 transforms (H.265's larger CTU transforms), flatter
  quantization with a deadzone (better rate at equal PSNR, more compute) —
  qualitatively reproducing "HEVC beats H.264; HEVC costs much more compute".

These are *reference implementations for comparison*, not conformant codecs.
"""

from __future__ import annotations

import math
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.motion.ops import estimate_motion, warp

__all__ = ["dct_matrix", "ClassicalCodec", "h264_like", "hevc_like", "CodedGop"]

# JPEG luminance quantization table (the H.264 default scaling-list shape)
_JPEG_Q = np.array(
    [
        [16, 11, 10, 16, 24, 40, 51, 61],
        [12, 12, 14, 19, 26, 58, 60, 55],
        [14, 13, 16, 24, 40, 57, 69, 56],
        [14, 17, 22, 29, 51, 87, 80, 62],
        [18, 22, 37, 56, 68, 109, 103, 77],
        [24, 35, 55, 64, 81, 104, 113, 92],
        [49, 64, 78, 87, 103, 121, 120, 101],
        [72, 92, 95, 98, 112, 100, 103, 99],
    ],
    dtype=np.float32,
)


def dct_matrix(n: int) -> jnp.ndarray:
    """Orthonormal DCT-II matrix."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    m = np.cos(np.pi * (2 * i + 1) * k / (2 * n)) * math.sqrt(2.0 / n)
    m[0] /= math.sqrt(2.0)
    return jnp.asarray(m, jnp.float32)


class CodedGop(NamedTuple):
    coeffs: List[jax.Array]  # per-frame quantized transform coeffs (int32)
    mvs: List[Optional[jax.Array]]


class ClassicalCodec:
    def __init__(self, block: int, qmat: jnp.ndarray, deadzone: float = 0.5,
                 name: str = "classical", mc_radius: int = 8):
        self.block = block
        self.qmat = qmat  # (block, block)
        self.deadzone = deadzone
        self.name = name
        self.mc_radius = mc_radius
        self.dct = dct_matrix(block)

    # ---- transforms -------------------------------------------------
    def _blocks(self, img):
        H, W, C = img.shape
        b = self.block
        x = img.reshape(H // b, b, W // b, b, C)
        return x.transpose(0, 2, 4, 1, 3)  # (nby, nbx, C, b, b)

    def _unblocks(self, blocks, H, W, C):
        b = self.block
        x = blocks.transpose(0, 3, 1, 4, 2)  # (nby, b, nbx, b, C)
        return x.reshape(H, W, C)

    def _fwd(self, img, qp: float):
        blk = self._blocks(img * 255.0)
        coef = jnp.einsum("ij,...jk,lk->...il", self.dct, blk, self.dct)
        q = self.qmat * qp
        y = coef / q
        yq = jnp.sign(y) * jnp.floor(jnp.abs(y) + (1.0 - self.deadzone))
        return yq.astype(jnp.int32)

    def _inv(self, yq, qp: float, H, W, C):
        q = self.qmat * qp
        coef = yq.astype(jnp.float32) * q
        blk = jnp.einsum("ji,...jk,kl->...il", self.dct, coef, self.dct)
        return jnp.clip(self._unblocks(blk, H, W, C) / 255.0, 0.0, 1.0)

    # ---- GOP coding --------------------------------------------------
    def encode_gop(self, frames, qp: float = 1.0, gop: int = 8):
        """frames: (T, H, W, 3) in [0,1]. Returns (CodedGop, recons)."""
        T, H, W, C = frames.shape
        coeffs, mvs, recons = [], [], []
        prev = None
        for t in range(T):
            if t % gop == 0 or prev is None:
                yq = self._fwd(frames[t], qp)
                rec = self._inv(yq, qp, H, W, C)
                mv = None
            else:
                mv, _ = estimate_motion(
                    frames[t], prev, block=16, radius=self.mc_radius
                )
                pred = warp(prev, mv, 16)
                resid = frames[t] - pred
                yq = self._fwd(resid + 0.5, qp)
                rec = jnp.clip(
                    pred + self._inv(yq, qp, H, W, C) - 0.5, 0.0, 1.0
                )
            coeffs.append(yq)
            mvs.append(mv)
            recons.append(rec)
            prev = rec
        return CodedGop(coeffs, mvs), jnp.stack(recons)

    def bitstream_bytes(self, coded: CodedGop, level: int = 9):
        from repro.common import compress as entropy

        parts = []
        for yq in coded.coeffs:
            parts.append(np.asarray(yq).astype(np.int16).tobytes())
        for mv in coded.mvs:
            if mv is not None:
                parts.append(np.asarray(mv).astype(np.int8).tobytes())
        raw = b"".join(parts)
        return entropy.compress(raw, level=level)


def h264_like() -> ClassicalCodec:
    return ClassicalCodec(8, jnp.asarray(_JPEG_Q), deadzone=0.5, name="h264_like")


def hevc_like() -> ClassicalCodec:
    # 16x16 transform; flatter matrix + deadzone quantization = better RD
    base = np.kron(_JPEG_Q, np.ones((2, 2), np.float32))
    flat = 0.5 * base + 0.5 * base.mean()
    return ClassicalCodec(16, jnp.asarray(flat * 0.75), deadzone=0.75, name="hevc_like")
