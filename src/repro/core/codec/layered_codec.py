"""The full Salient Store layered neural codec (Alg. 1): GOP pipeline.

Per GOP of G frames: the anchor frame is intra-coded (features -> layered AE);
every subsequent frame is inter-coded — block motion vs the previous
*reconstruction*, `R_t = F_t - predict(F_{t-1}, M_t)`, the residual encoded by
the layered AE *conditioned on the motion-vector latent* (the paper's "motion
vectors as a latent space").  The bitstream per frame is (int8 layer codes,
int8 motion field); the byte-level entropy stage is zstd (the paper's own
Table 1 entropy coder), applied host-side at persist time.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.common.nn import conv2d, init_conv
from repro.core.codec.autoencoder import (
    decode_layers,
    encode_layers,
    init_layered_ae,
)
from repro.core.codec.feature_extractor import (
    FEATURE_STRIDE,
    extract_features,
    init_feature_extractor,
)
from repro.kernels.motion.ops import estimate_motion, warp

__all__ = [
    "init_codec",
    "encode_frame",
    "encode_gop",
    "decode_gop",
    "psnr",
    "serialize_bitstream",
    "CodecConfig",
    "FrameCode",
]


class CodecConfig(NamedTuple):
    n_layers: int = 4
    latent_ch: int = 8
    feat_ch: int = 64
    mv_cond_ch: int = 8
    block: int = 16
    radius: int = 8
    gop: int = 8


class FrameCode(NamedTuple):
    codes: Sequence[jax.Array]  # K x (B, h, w, latent) quantized (int values)
    mv: Optional[jax.Array]  # (B, nby, nbx, 2) int32 or None for anchors


def init_codec(key, cfg: CodecConfig = CodecConfig(), dtype=jnp.float32):
    ke, ka, km = jax.random.split(key, 3)
    return {
        "extractor": init_feature_extractor(ke, out_ch=cfg.feat_ch, dtype=dtype),
        "ae": init_layered_ae(
            ka,
            feat_ch=cfg.feat_ch,
            latent_ch=cfg.latent_ch,
            n_layers=cfg.n_layers,
            cond_ch=cfg.mv_cond_ch,
            stride=FEATURE_STRIDE,
            dtype=dtype,
        ),
        "mv_embed": init_conv(km, 1, 1, 2, cfg.mv_cond_ch, dtype),
    }


def _mv_cond(params, mv, feat_hw, cfg: CodecConfig):
    """Motion field (B, nby, nbx, 2) -> conditioning latent at feature res."""
    h, w = feat_hw
    mvf = mv.astype(jnp.float32) / float(cfg.radius)
    rep = cfg.block // FEATURE_STRIDE
    mvf = jnp.repeat(jnp.repeat(mvf, rep, axis=1), rep, axis=2)  # (B, h, w, 2)
    return conv2d(params["mv_embed"], mvf)


def _zero_cond(params, feats, cfg: CodecConfig):
    B, h, w, _ = feats.shape
    zeros = jnp.zeros((B, h, w, 2), feats.dtype)
    return conv2d(params["mv_embed"], zeros)


def encode_frame(params, cfg: CodecConfig, frame, prev_recon, *, train=False, n_layers=None):
    """One frame. frame: (B, H, W, 3) in [0,1]; prev_recon: same or None.

    Returns (FrameCode, recon).
    """
    if prev_recon is None:
        feats = extract_features(params["extractor"], frame)
        cond = _zero_cond(params, feats, cfg)
        codes, recon = encode_layers(
            params["ae"], feats, frame, cond=cond, n_layers=n_layers, train=train
        )
        return FrameCode(codes, None), jnp.clip(recon, 0.0, 1.0)
    mv, _sad = jax.vmap(
        lambda c, p: estimate_motion(c, p, block=cfg.block, radius=cfg.radius)
    )(frame, prev_recon)
    pred = jax.vmap(lambda p, m: warp(p, m, cfg.block))(prev_recon, mv)
    resid = frame - pred
    feats = extract_features(params["extractor"], resid * 0.5 + 0.5)
    cond = _mv_cond(params, mv, feats.shape[1:3], cfg)
    codes, rec_resid = encode_layers(
        params["ae"], feats, resid, cond=cond, n_layers=n_layers, train=train
    )
    recon = jnp.clip(pred + rec_resid, 0.0, 1.0)
    return FrameCode(codes, mv), recon


def encode_gop(params, cfg: CodecConfig, frames, *, train=False, n_layers=None):
    """frames: (T, B, H, W, 3). Returns (list[FrameCode], recons (T, B, H, W, 3))."""
    T = frames.shape[0]
    frame_codes = []
    recons = []
    prev = None
    for t in range(T):
        fc, recon = encode_frame(
            params, cfg, frames[t], prev, train=train, n_layers=n_layers
        )
        frame_codes.append(fc)
        recons.append(recon)
        prev = recon
    return frame_codes, jnp.stack(recons)


def decode_gop(params, cfg: CodecConfig, frame_codes):
    """Inverse of encode_gop (uses only codes + mv)."""
    recons = []
    prev = None
    for fc in frame_codes:
        part = decode_layers(params["ae"], fc.codes)
        if fc.mv is None:
            recon = jnp.clip(part, 0.0, 1.0)
        else:
            pred = jax.vmap(lambda p, m: warp(p, m, cfg.block))(prev, fc.mv)
            recon = jnp.clip(pred + part, 0.0, 1.0)
        recons.append(recon)
        prev = recon
    return jnp.stack(recons)


def psnr(a, b, max_val=1.0):
    mse = jnp.mean((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2)
    return 10.0 * jnp.log10(max_val**2 / jnp.maximum(mse, 1e-12))


def serialize_bitstream(frame_codes, level: int = 9):
    """Host-side entropy stage: int8 codes + int8 motion -> zstd bytes.

    Returns (blob: bytes, n_raw_bytes: int).  Compression ratios in the
    benchmarks are computed from real compressed sizes, not proxies.
    """
    import numpy as np

    from repro.common import compress as entropy

    parts = []
    for fc in frame_codes:
        for z in fc.codes:
            parts.append(np.asarray(z).astype(np.int8).tobytes())
        if fc.mv is not None:
            parts.append(np.asarray(fc.mv).astype(np.int8).tobytes())
    raw = b"".join(parts)
    blob = entropy.compress(raw, level=level)
    return blob, len(raw)
