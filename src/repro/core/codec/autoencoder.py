"""Layered (progressive) autoencoder — the trainable "A" of Alg. 1/2.

Structure (paper §3: "each successive layer ... corrects errors from the
previous layers"):

    x~0 = 0
    for k in 1..K:
        z_k   = Enc_k(residual_k)            residual_k = target - partial recon
        zq_k  = Q(z_k)                       per-layer trained quantization scale
        x~k   = x~{k-1} + Dec_k(zq_k)

Decoding any prefix of the K layers yields a progressively better
reconstruction (SVC/SHVC-style quality layers).  The encoder consumes the
*frozen backbone's features* (+ optional motion-vector latent conditioning,
the paper's "motion vectors as a latent space"), the decoder reconstructs
pixels, upsampling by the backbone stride.

Quantization uses a straight-through estimator during training; at archive
time the int8 codes are the bitstream (entropy-coded with zstd host-side).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.nn import conv2d, conv2d_transpose, init_conv, init_conv_transpose

__all__ = [
    "init_layered_ae",
    "encode_layers",
    "decode_layers",
    "quantize_code",
    "dequantize_code",
]


def init_layered_ae(
    key,
    feat_ch: int = 64,
    latent_ch: int = 8,
    n_layers: int = 4,
    out_ch: int = 3,
    cond_ch: int = 0,
    width: int = 32,
    stride: int = 8,
    dtype=jnp.float32,
):
    """cond_ch: channels of the conditioning latent (motion field), concat'd
    to the encoder input of every layer.  stride: backbone downsampling (the
    decoder upsamples 2 x 2 x (stride/4))."""
    assert stride in (4, 8), stride
    layers = []
    keys = jax.random.split(key, n_layers)
    for k in range(n_layers):
        ek = jax.random.split(keys[k], 8)
        # every layer's encoder sees backbone features + cond + the pooled
        # remaining error (layer 0's "error" is the target itself)
        enc_in = feat_ch + cond_ch + out_ch
        layer = {
            # encoder: features (+cond, +pixel residual downsampled) -> latent
            "enc1": init_conv(ek[0], 3, 3, enc_in, width, dtype),
            "enc2": init_conv(ek[1], 3, 3, width, latent_ch, dtype),
            # decoder: latent -> pixels (x2 up, x2 up, x(stride/4) up)
            "dec1": init_conv_transpose(ek[2], 4, 4, latent_ch, width, dtype),
            "dec2": init_conv_transpose(ek[3], 4, 4, width, width, dtype),
            "dec3": init_conv_transpose(ek[4], 4, 4, width, width, dtype)
            if stride == 8
            else None,
            "dec_out": init_conv(ek[5], 3, 3, width, out_ch, dtype),
            "q_log_scale": jnp.full((latent_ch,), -3.0, dtype),  # trained quant scale (init ~0.05)
        }
        layers.append(layer)
    return {"layers": layers}


def _avgpool(x, factor):
    B, H, W, C = x.shape
    return x.reshape(B, H // factor, factor, W // factor, factor, C).mean((2, 4))


def quantize_code(z, log_scale, train: bool = False):
    """Symmetric int8 quantization with straight-through gradients."""
    scale = jnp.exp(log_scale)
    y = z / scale
    yq = jnp.clip(jnp.round(y), -127, 127)
    if train:
        yq = y + jax.lax.stop_gradient(yq - y)  # STE
    return yq


def dequantize_code(zq, log_scale):
    return zq * jnp.exp(log_scale)


def _decode_one(layer, zq):
    h = dequantize_code(zq, layer["q_log_scale"])
    h = jax.nn.relu(conv2d_transpose(layer["dec1"], h, stride=2))
    h = jax.nn.relu(conv2d_transpose(layer["dec2"], h, stride=2))
    if layer["dec3"] is not None:
        h = jax.nn.relu(conv2d_transpose(layer["dec3"], h, stride=2))
    return conv2d(layer["dec_out"], h)


def encode_layers(params, feats, target, cond=None, n_layers=None, train=False):
    """Progressive encode.

    feats:  (B, h, w, F) frozen-backbone features of the *coding target*
    target: (B, H, W, C) pixels to reconstruct (frame or residual)
    cond:   optional (B, h, w, M) conditioning latent (motion field)
    Returns (codes [K x (B, h, w, L)], recon (B, H, W, C)).
    """
    layers = params["layers"]
    K = len(layers) if n_layers is None else n_layers
    stride = target.shape[1] // feats.shape[1]  # backbone downsampling factor
    recon = jnp.zeros_like(target)
    codes = []
    for k in range(K):
        layer = layers[k]
        err = target - recon  # what previous layers failed to explain
        enc_in = feats
        if cond is not None:
            enc_in = jnp.concatenate([enc_in, cond], axis=-1)
        enc_in = jnp.concatenate([enc_in, _avgpool(err, stride)], axis=-1)
        h = jax.nn.relu(conv2d(layer["enc1"], enc_in))
        z = conv2d(layer["enc2"], h)
        zq = quantize_code(z, layer["q_log_scale"], train=train)
        codes.append(zq)
        recon = recon + _decode_one(layer, zq)
    return codes, recon


def decode_layers(params, codes):
    """Reconstruct from any prefix of quality layers."""
    recon = None
    for k, zq in enumerate(codes):
        d = _decode_one(params["layers"][k], zq)
        recon = d if recon is None else recon + d
    return recon
