"""Frozen MobileNet-style feature extractor (Salient Store Alg. 1/2, "M").

The paper reuses the analytics backbone (MobileNet) as the codec's feature
extractor: its weights are frozen, the codec's autoencoder trains on top.
This is the "maximize compute reuse between inference and archival" insight —
the same forward pass serves exemplar selection AND compression.

Depthwise-separable stack, stride-8 total downsampling:
  stem 3x3 s2 -> [dw 3x3 + pw 1x1] x3 (strides 2, 2, 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.nn import conv2d, init_conv

__all__ = ["init_feature_extractor", "extract_features", "FEATURE_STRIDE"]

FEATURE_STRIDE = 8


def init_feature_extractor(key, in_ch=3, width=16, out_ch=64, dtype=jnp.float32):
    k = jax.random.split(key, 7)
    w2 = width * 2
    return {
        "stem": init_conv(k[0], 3, 3, in_ch, width, dtype),
        "dw1": init_conv(k[1], 3, 3, 1, width, dtype),  # depthwise (groups=width)
        "pw1": init_conv(k[2], 1, 1, width, w2, dtype),
        "dw2": init_conv(k[3], 3, 3, 1, w2, dtype),
        "pw2": init_conv(k[4], 1, 1, w2, out_ch, dtype),
        "dw3": init_conv(k[5], 3, 3, 1, out_ch, dtype),
        "pw3": init_conv(k[6], 1, 1, out_ch, out_ch, dtype),
    }


def extract_features(params, frames):
    """frames: (B, H, W, C) in [0, 1] -> (B, H/8, W/8, out_ch)."""
    x = frames
    x = jax.nn.relu(conv2d(params["stem"], x, stride=2))
    x = jax.nn.relu(conv2d(params["dw1"], x, stride=2, feature_group_count=x.shape[-1]))
    x = jax.nn.relu(conv2d(params["pw1"], x))
    x = jax.nn.relu(conv2d(params["dw2"], x, stride=2, feature_group_count=x.shape[-1]))
    x = jax.nn.relu(conv2d(params["pw2"], x))
    x = jax.nn.relu(conv2d(params["dw3"], x, stride=1, feature_group_count=x.shape[-1]))
    x = jax.nn.relu(conv2d(params["pw3"], x))
    return x
