"""Codec training (Alg. 2): freeze the inference backbone, train the AE.

Loss = sum_t ||F_t - F^_t||_2^2 (the paper's reconstruction objective)
     + lambda_rate * L1(codes)   (rate proxy; true rate is measured with zstd
                                  at eval — the proxy only shapes sparsity).

Only the ``ae`` and ``mv_embed`` subtrees receive gradients; ``extractor``
(MobileNet stand-in) stays frozen, exactly Alg. 2's "Backpropagate loss and
update weights of A only".
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.codec.layered_codec import CodecConfig, encode_gop
from repro.train.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

__all__ = ["CodecTrainConfig", "codec_loss", "make_codec_train_step", "init_codec_trainer"]


class CodecTrainConfig(NamedTuple):
    codec: CodecConfig = CodecConfig()
    opt: AdamWConfig = AdamWConfig(lr=3e-4, grad_clip=1.0)
    lambda_rate: float = 1e-5


def codec_loss(trainable, frozen, cfg: CodecTrainConfig, clips):
    """clips: (T, B, H, W, 3). Returns (loss, metrics)."""
    params = dict(frozen, **trainable)
    frame_codes, recons = encode_gop(params, cfg.codec, clips, train=True)
    recon_mse = jnp.mean((recons - clips) ** 2)
    rate = sum(jnp.mean(jnp.abs(z)) for fc in frame_codes for z in fc.codes) / len(
        frame_codes
    )
    loss = recon_mse + cfg.lambda_rate * rate
    return loss, {"recon_mse": recon_mse, "rate_l1": rate, "loss": loss}


def init_codec_trainer(params, cfg: CodecTrainConfig):
    trainable = {k: params[k] for k in ("ae", "mv_embed")}
    frozen = {k: params[k] for k in ("extractor",)}
    return trainable, frozen, adamw_init(trainable)


@functools.partial(jax.jit, static_argnames=("cfg",))
def codec_train_step(trainable, frozen, opt_state: AdamWState, cfg: CodecTrainConfig, clips):
    (loss, metrics), grads = jax.value_and_grad(codec_loss, has_aux=True)(
        trainable, frozen, cfg, clips
    )
    trainable, opt_state = adamw_update(trainable, grads, opt_state, cfg.opt)
    return trainable, opt_state, metrics


def make_codec_train_step(cfg: CodecTrainConfig):
    return functools.partial(codec_train_step, cfg=cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def codec_pretrain_step(params, opt_state: AdamWState, cfg: CodecTrainConfig, clips):
    """Backbone pretraining phase: ALL params trainable (stands in for the
    paper's pretrained MobileNet); Alg. 2 then freezes the extractor."""
    def loss(p):
        return codec_loss({k: p[k] for k in ("ae", "mv_embed")},
                          {"extractor": p["extractor"]}, cfg, clips)

    (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
    params, opt_state = adamw_update(params, grads, opt_state, cfg.opt)
    return params, opt_state, metrics
