"""Failure management for intermittent edge servers (paper §1, contribution 2).

Three mechanisms, mapped to pod scale:

* **Straggler monitor** — EWMA of per-shard step times; shards slower than
  ``straggler_factor`` x median are flagged and the placement engine moves
  streams off them (paper: load imbalance dominates, Table 2).  Dead-shard
  detection covers both pathologies a churning fleet produces: a shard that
  heartbeats absurdly slowly (``dead_factor`` x median) and a shard that
  stops heartbeating at all (``miss_threshold`` consecutive misses — a
  single dropout or a short rolling restart is tolerated).  A warm-up grace
  (``warmup_rounds``) keeps shards that simply have not heartbeated YET out
  of the dead list, so step-0 retrieval plans do not bill every stripe as a
  degraded read.
* **Shard-loss detection + parity rebuild** — a dead shard's archival data is
  reconstructed from RAID-5/6 parity (core/archival/raid.py), the TPU
  analogue of a failed CSD being rebuilt from the redundancy stripe.
* **Power-loss journaling** — archival blocks commit atomically via a
  manifest (write body -> fsync -> append manifest record); a restart replays
  the manifest and discards torn writes.  Records carry a crc32 of their
  payload so a silently flipped bit in a committed body is DETECTED, not
  replayed as valid — the scrubber (core/archival/scrub.py) then locates and
  repairs it from parity.  Used by train/checkpoint.py too.
"""

from __future__ import annotations

import json
import os
import time
import zlib
from typing import Dict, List, NamedTuple, Optional, Sequence

__all__ = ["StragglerMonitor", "ShardStatus", "Journal"]


class ShardStatus(NamedTuple):
    speed: List[float]  # EWMA relative speed per shard (1 = median pace)
    stragglers: List[int]
    dead: List[int]


class StragglerMonitor:
    """Tracks per-shard step latencies; flags stragglers and dead shards.

    ``warmup_rounds``: minimum ``update`` calls before a shard with NO
    heartbeat history may be flagged dead (cold-start grace — without it a
    step-0 monitor declares every not-yet-heard shard dead and the planner
    bills every stripe as a degraded read).  ``miss_threshold``: consecutive
    missed heartbeats (``None`` step times) before a previously-healthy
    shard is declared dead — one dropout or a short rolling restart stays a
    non-event, a silent permanent loss is caught within a few rounds.
    """

    def __init__(
        self,
        n_shards: int,
        alpha: float = 0.3,
        straggler_factor: float = 1.5,
        dead_factor: float = 10.0,
        warmup_rounds: int = 2,
        miss_threshold: int = 3,
    ):
        self.n = n_shards
        self.alpha = alpha
        self.straggler_factor = straggler_factor
        self.dead_factor = dead_factor
        self.warmup_rounds = warmup_rounds
        self.miss_threshold = miss_threshold
        self.ewma: List[Optional[float]] = [None] * n_shards
        self.misses: List[int] = [0] * n_shards
        self.rounds = 0

    def update(self, step_times: Sequence[Optional[float]]) -> ShardStatus:
        """step_times[i] = seconds for shard i this step (None = no heartbeat)."""
        self.rounds += 1
        for i, t in enumerate(step_times):
            if t is None:
                self.misses[i] += 1
                continue
            self.misses[i] = 0
            self.ewma[i] = (
                t if self.ewma[i] is None else self.alpha * t + (1 - self.alpha) * self.ewma[i]
            )
        known = sorted(t for t in self.ewma if t is not None)
        if not known:
            return ShardStatus([1.0] * self.n, [], [])
        mid = len(known) // 2
        med = known[mid] if len(known) % 2 else 0.5 * (known[mid - 1] + known[mid])
        speed, stragglers, dead = [], [], []
        for i, t in enumerate(self.ewma):
            if t is None:
                # never heartbeated: dead only past the warm-up grace
                if self.rounds >= self.warmup_rounds:
                    speed.append(0.0)
                    dead.append(i)
                else:
                    speed.append(1.0)
            else:
                rel = med / t
                speed.append(rel)
                if (
                    self.misses[i] >= self.miss_threshold
                    or t > self.dead_factor * med
                ):
                    dead.append(i)
                elif t > self.straggler_factor * med:
                    stragglers.append(i)
        return ShardStatus(speed, stragglers, dead)


class Journal:
    """Append-only commit journal with atomic records (power-loss safe).

    Record layout: one JSON object per line, written AFTER its payload file is
    durably on disk; replay keeps only records whose payload exists and whose
    length matches — torn payloads are discarded, exactly the paper's
    "data integrity ... during power disruptions" requirement.

    Silent corruption: each record carries a crc32 of its payload, verified
    on ``replay()`` (and on ``read(..., crc32=...)``), so a flipped bit in a
    committed body no longer replays as valid just because the byte length
    matches.  Records written before the crc existed are still accepted.
    ``replay(verify_crc=False)`` is the scrubber's entry: it returns
    crc-failed records too (marked ``crc_ok=False``) so the parity syndrome
    can LOCATE and REPAIR the corruption instead of merely dropping it.

    Durability of the rename itself: ``os.replace`` only becomes power-loss
    safe once the *directory* entry is on disk, so ``commit`` fsyncs the
    journal directory after the rename and after appending the record.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.path = os.path.join(root, "journal.jsonl")

    def _fsync_dir(self) -> None:
        """fsync the journal directory so renames/creates survive power loss."""
        fd = os.open(self.root, os.O_RDONLY)
        try:
            os.fsync(fd)
        except OSError:  # pragma: no cover - fs without dir fsync support
            pass
        finally:
            os.close(fd)

    def commit(self, name: str, payload: bytes, meta: Optional[Dict] = None) -> str:
        body_path = os.path.join(self.root, name)
        tmp = body_path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, body_path)
        self._fsync_dir()
        rec = {
            "name": name,
            "bytes": len(payload),
            "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
            "ts": time.time(),
            "meta": meta or {},
        }
        with open(self.path, "a") as f:
            f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._fsync_dir()
        return body_path

    def replay(self, verify_crc: bool = True) -> List[Dict]:
        """Valid committed records, in order; torn writes dropped.

        ``verify_crc=True`` (default) also drops records whose payload no
        longer matches its committed crc32 — silent bit flips read as
        missing data, never as valid data.  ``verify_crc=False`` keeps them,
        with ``crc_ok=False`` set, for the scrub/repair path.
        """
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn journal tail
                p = os.path.join(self.root, rec["name"])
                if not (os.path.exists(p) and os.path.getsize(p) == rec["bytes"]):
                    continue
                want = rec.get("crc32")
                if want is not None:
                    with open(p, "rb") as bf:
                        ok = (zlib.crc32(bf.read()) & 0xFFFFFFFF) == want
                    if not ok:
                        if not verify_crc:
                            out.append(dict(rec, crc_ok=False))
                        continue
                out.append(rec)
        return out

    def read(self, name: str, crc32: Optional[int] = None) -> bytes:
        """Read a committed payload; verifies ``crc32`` when the caller has
        the record in hand (silent corruption raises instead of decoding)."""
        with open(os.path.join(self.root, name), "rb") as f:
            data = f.read()
        if crc32 is not None and (zlib.crc32(data) & 0xFFFFFFFF) != crc32:
            raise ValueError(
                f"journal payload {name!r} fails its committed crc32 "
                "(silent corruption)"
            )
        return data

    def compact(self, drop: Sequence[str]) -> int:
        """Stripe-lifecycle compaction: rewrite the journal without the
        ``drop`` records and delete their payload files.

        The rewrite is atomic (tmp + ``os.replace`` + directory fsync) and
        runs over ``replay(verify_crc=False)``, so compaction also sheds torn
        tails while PRESERVING crc-failed records that still await scrub
        repair.  Payload files are unlinked only after the new journal is
        durable — key/nonce material inside a retired stripe's manifest
        record is recycled strictly after the retirement is journaled.
        Returns the number of records dropped.
        """
        dropset = set(drop)
        keep, dropped = [], 0
        for rec in self.replay(verify_crc=False):
            rec = dict(rec)
            rec.pop("crc_ok", None)
            if rec["name"] in dropset:
                dropped += 1
            else:
                keep.append(rec)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            for rec in keep:
                f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        self._fsync_dir()
        live = {r["name"] for r in keep}
        for name in dropset - live:
            p = os.path.join(self.root, name)
            if os.path.exists(p):
                os.remove(p)
        self._fsync_dir()
        return dropped
