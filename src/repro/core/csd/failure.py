"""Failure management for intermittent edge servers (paper §1, contribution 2).

Three mechanisms, mapped to pod scale:

* **Straggler monitor** — EWMA of per-shard step times; shards slower than
  ``straggler_factor`` x median are flagged and the placement engine moves
  streams off them (paper: load imbalance dominates, Table 2).
* **Shard-loss detection + parity rebuild** — a dead shard's archival data is
  reconstructed from RAID-5/6 parity (core/archival/raid.py), the TPU
  analogue of a failed CSD being rebuilt from the redundancy stripe.
* **Power-loss journaling** — archival blocks commit atomically via a
  manifest (write body -> fsync -> append manifest record); a restart replays
  the manifest and discards torn writes.  Used by train/checkpoint.py too.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, NamedTuple, Optional, Sequence

__all__ = ["StragglerMonitor", "ShardStatus", "Journal"]


class ShardStatus(NamedTuple):
    speed: List[float]  # EWMA relative speed per shard (1 = median pace)
    stragglers: List[int]
    dead: List[int]


class StragglerMonitor:
    """Tracks per-shard step latencies; flags stragglers and dead shards."""

    def __init__(
        self,
        n_shards: int,
        alpha: float = 0.3,
        straggler_factor: float = 1.5,
        dead_factor: float = 10.0,
    ):
        self.n = n_shards
        self.alpha = alpha
        self.straggler_factor = straggler_factor
        self.dead_factor = dead_factor
        self.ewma: List[Optional[float]] = [None] * n_shards

    def update(self, step_times: Sequence[Optional[float]]) -> ShardStatus:
        """step_times[i] = seconds for shard i this step (None = no heartbeat)."""
        for i, t in enumerate(step_times):
            if t is None:
                continue
            self.ewma[i] = (
                t if self.ewma[i] is None else self.alpha * t + (1 - self.alpha) * self.ewma[i]
            )
        known = sorted(t for t in self.ewma if t is not None)
        if not known:
            return ShardStatus([1.0] * self.n, [], [])
        mid = len(known) // 2
        med = known[mid] if len(known) % 2 else 0.5 * (known[mid - 1] + known[mid])
        speed, stragglers, dead = [], [], []
        for i, t in enumerate(self.ewma):
            if t is None:
                speed.append(0.0)
                dead.append(i)
            else:
                rel = med / t
                speed.append(rel)
                if t > self.dead_factor * med:
                    dead.append(i)
                elif t > self.straggler_factor * med:
                    stragglers.append(i)
        return ShardStatus(speed, stragglers, dead)


class Journal:
    """Append-only commit journal with atomic records (power-loss safe).

    Record layout: one JSON object per line, written AFTER its payload file is
    durably on disk; replay keeps only records whose payload exists and whose
    length matches — torn payloads are discarded, exactly the paper's
    "data integrity ... during power disruptions" requirement.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.path = os.path.join(root, "journal.jsonl")

    def commit(self, name: str, payload: bytes, meta: Optional[Dict] = None) -> str:
        body_path = os.path.join(self.root, name)
        tmp = body_path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, body_path)
        rec = {
            "name": name,
            "bytes": len(payload),
            "ts": time.time(),
            "meta": meta or {},
        }
        with open(self.path, "a") as f:
            f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())
        return body_path

    def replay(self) -> List[Dict]:
        """Valid committed records, in order; torn writes dropped."""
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn journal tail
                p = os.path.join(self.root, rec["name"])
                if os.path.exists(p) and os.path.getsize(p) == rec["bytes"]:
                    out.append(rec)
        return out

    def read(self, name: str) -> bytes:
        with open(os.path.join(self.root, name), "rb") as f:
            return f.read()
