"""Analytical latency/data-movement model of the storage system (§5 figures).

The container has no SmartSSDs, so the paper's *hardware* numbers (Figs 4, 5,
6, 10, 11 and Table 2) are reproduced with a structural cost model:
every scenario is decomposed into link transfers + compute stages, with
bandwidths/rates as explicit parameters.  The defaults below are calibrated
so the model reproduces the paper's published ratios (see
benchmarks/table2_placement.py etc.; EXPERIMENTS.md reports model-vs-paper
error per figure).  The same model drives placement decisions at runtime
(csd/placement.py) — it is the framework's storage scheduler, not just a
benchmark artifact.

Key structural facts encoded:
  * classical path ships RAW bytes over the host link and archives on the
    storage-server CPU;
  * the CSD path computes AT the data (SSD-internal bandwidth), ships only
    COMPRESSED+ENCRYPTED bytes peer-to-peer — the paper's entire thesis;
  * CSD compute rate ~= 3.9x storage-CPU rate (Table 2 row 2);
  * multi-node remote access suffers contention growing with node count
    (Fig. 10's super-linear latency);
  * the entropy stage is placeable (``entropy_placement_cost`` /
    ``best_entropy_placement``): host-side zstd pays a raw-byte host-link
    crossing, the on-device rANS kernel pays none — the term the placement
    scheduler prices now that ``repro.kernels.entropy`` exists;
  * the background scrub is placeable the same way
    (``scrub_placement_cost``): parity verification runs over the SEALED
    bodies, so a CSD-side scrub reads flash-locally and ships only P/Q
    syndrome bytes for the cross-shard compare, while a host-side scrub
    must move every sealed body over the host link;
  * per-launch dispatch overhead is NOT a per-stripe term on the on-device
    path: the one-launch archival kernel (``repro.kernels.fused``) runs
    entropy + pack + seal + parity as a single launch and batches K
    coalesced stripes per launch, so fixed dispatch cost amortizes across
    K stripes (launches/stripe = 1/K; the chained path paid 2 per stripe).
    The model therefore keeps dispatch folded into the per-byte compute
    rates instead of charging a per-stripe constant.

On ``compress_ratio``: 6.1 is the paper's END-TO-END data-volume reduction
(Fig. 5c), i.e. neural codec x entropy stage.  Our measured *entropy-stage*
ratio on int8 latent codes is ~2.5x (``BENCH_kernels.json`` ->
``entropy_fused.ratio``); the remaining factor comes from the lossy codec
upstream, so 6.1 stays the right end-to-end default here.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

__all__ = ["SystemModel", "classical_archive", "vss_archive", "csd_archive",
           "multinode_latency", "multinode_movement_latency",
           "csd_ratio_tradeoff", "entropy_placement_cost",
           "best_entropy_placement", "retrieval_placement_cost",
           "best_retrieval_placement", "scrub_placement_cost",
           "best_scrub_placement"]


class SystemModel(NamedTuple):
    host_link_GBps: float = 3.2  # host <-> storage bus (effective)
    p2p_GBps: float = 6.4  # CSD peer-to-peer PCIe
    ssd_internal_GBps: float = 9.6  # SSD internal bandwidth feeding the FPGA
    cpu_rate_GBps: float = 0.55  # storage-CPU archival pipeline throughput
    csd_speedup: float = 3.9  # Table 2: CSD kernel vs CPU kernel
    ssd_write_GBps: float = 2.0
    network_GBps: float = 1.25  # inter storage-node (10 GbE)
    contention: float = 0.55  # per-extra-node network contention factor
    compress_ratio: float = 6.1  # paper's data-volume reduction (Fig. 5c)
    vss_factor: float = 1.38  # VSS speedup over classical (Fig. 5b ratio)
    stripe_serial_frac: float = 0.25  # non-parallel stripe work (parity,
    # coordination, metadata) — system-level only; Table 2's independent
    # streams scale near-linearly, Fig. 11's shared stripe does not.
    entropy_cpu_GBps: float = 1.1  # host entropy-coder (zstd-class) rate
    entropy_ratio: float = 2.5  # entropy-stage-only ratio on int8 latents
    # (measured: BENCH_kernels.json entropy_fused.ratio; compress_ratio
    # above is the END-TO-END 6.1x incl. the neural codec)

    @property
    def csd_rate_GBps(self) -> float:
        return self.cpu_rate_GBps * self.csd_speedup

    @property
    def entropy_csd_GBps(self) -> float:
        """On-CSD entropy rate: same kernel-vs-CPU factor as Table 2."""
        return self.entropy_cpu_GBps * self.csd_speedup


class ArchiveCost(NamedTuple):
    latency_s: float
    moved_bytes: float  # bytes crossing host/network links (the Fig. 5c metric)


def classical_archive(sys: SystemModel, raw_bytes: float) -> ArchiveCost:
    """Raw video -> host link -> storage CPU (compress+encrypt+RAID) -> disks.

    All scenarios model *streamed/pipelined* stages: latency = the bottleneck
    stage (max), not the sum — this is what reproduces the paper's Table 2
    curve (3.9x single CSD -> 7.7x at 50/50; a summed model caps at ~6.5x).
    """
    out = raw_bytes / sys.compress_ratio
    lat = max(
        raw_bytes / (sys.host_link_GBps * 1e9),
        raw_bytes / (sys.cpu_rate_GBps * 1e9),
        out / (sys.ssd_write_GBps * 1e9),
    )
    return ArchiveCost(lat, raw_bytes)


def vss_archive(sys: SystemModel, raw_bytes: float) -> ArchiveCost:
    """VSS (Haynes et al.): better data organization/caching, same data path."""
    base = classical_archive(sys, raw_bytes)
    return ArchiveCost(base.latency_s / sys.vss_factor, raw_bytes)


def csd_archive(
    sys: SystemModel, raw_bytes: float, split: Sequence[float] = (1.0,)
) -> ArchiveCost:
    """Salient Store: data already resides on CSD shards (fractions ``split``);
    each FPGA archives its fraction in parallel; only compressed bytes move
    peer-to-peer to their parity/placement targets."""
    assert abs(sum(split) - 1.0) < 1e-6, split
    out = raw_bytes / sys.compress_ratio
    frac = max(split)  # slowest shard bounds the stripe (pipelined stages)
    lat = max(
        frac * raw_bytes / (sys.csd_rate_GBps * 1e9),  # FPGA archival kernels
        frac * raw_bytes / (sys.ssd_internal_GBps * 1e9),  # flash -> FPGA feed
        out / (sys.p2p_GBps * 1e9),  # sealed bytes, peer-to-peer
        out / (sys.ssd_write_GBps * 1e9),
    )
    return ArchiveCost(lat, out)


def entropy_placement_cost(
    sys: SystemModel, raw_bytes: float, where: str = "csd"
) -> ArchiveCost:
    """Price the entropy stage alone at a given placement.

    ``where="host"``: the legacy zstd/zlib stage — every raw payload byte
    crosses the host link, gets coded at CPU rate, and the compressed
    stream crosses back to be sealed where the data lives (pipelined: the
    bottleneck stage bounds latency, the *moved* figure counts both hops).
    ``where="csd"``: the on-device rANS kernel — coded at the CSD kernel
    rate, zero payload bytes on the host link (manifest ints only).
    """
    out = raw_bytes / sys.entropy_ratio
    if where == "host":
        lat = max(
            raw_bytes / (sys.host_link_GBps * 1e9),   # raw up
            raw_bytes / (sys.entropy_cpu_GBps * 1e9),  # CPU coder
            out / (sys.host_link_GBps * 1e9),          # stream back down
        )
        return ArchiveCost(lat, raw_bytes + out)
    if where == "csd":
        lat = max(
            raw_bytes / (sys.entropy_csd_GBps * 1e9),      # on-device coder
            raw_bytes / (sys.ssd_internal_GBps * 1e9),     # flash feed
        )
        return ArchiveCost(lat, 0.0)
    raise ValueError(f"unknown entropy placement {where!r}")


def best_entropy_placement(
    sys: SystemModel, raw_bytes: float
) -> Tuple[str, dict]:
    """The scheduler's entropy-stage decision: cheapest latency placement,
    with the per-option costs so callers can weigh movement too."""
    costs = {
        w: entropy_placement_cost(sys, raw_bytes, w) for w in ("host", "csd")
    }
    return min(costs, key=lambda w: costs[w].latency_s), costs


def retrieval_placement_cost(
    sys: SystemModel, comp_bytes: float, raw_bytes: float, where: str = "host"
) -> ArchiveCost:
    """Price a retrieval's decode stage (unseal + entropy decode) at a
    given placement — the read-side mirror of ``entropy_placement_cost``.

    ``comp_bytes``: sealed/entropy-coded bytes the plan reads off flash;
    ``raw_bytes``: the decoded codec payload those expand to.  Unlike the
    ingest direction the byte tradeoff INVERTS here: decoding on the host
    ships the small compressed stream over the host link and spends host
    CPU, decoding on the CSD spends the 3.9x-faster kernel but ships the
    EXPANDED payload up.  Which wins depends on the link/compute balance —
    exactly the decision ``plan_retrieval`` asks this model to make.
    """
    if where == "host":
        lat = max(
            comp_bytes / (sys.host_link_GBps * 1e9),   # sealed stream up
            raw_bytes / (sys.cpu_rate_GBps * 1e9),     # host unseal+decode
        )
        return ArchiveCost(lat, comp_bytes)
    if where == "csd":
        lat = max(
            comp_bytes / (sys.ssd_internal_GBps * 1e9),  # flash -> FPGA feed
            raw_bytes / (sys.csd_rate_GBps * 1e9),       # on-device decode
            raw_bytes / (sys.host_link_GBps * 1e9),      # decoded payload up
        )
        return ArchiveCost(lat, raw_bytes)
    raise ValueError(f"unknown retrieval placement {where!r}")


def best_retrieval_placement(
    sys: SystemModel, comp_bytes: float, raw_bytes: float
) -> Tuple[str, dict]:
    """Cheapest-latency decode placement for a retrieval plan, with the
    per-option costs so the planner can report movement too."""
    costs = {
        w: retrieval_placement_cost(sys, comp_bytes, raw_bytes, w)
        for w in ("host", "csd")
    }
    return min(costs, key=lambda w: costs[w].latency_s), costs


def scrub_placement_cost(
    sys: SystemModel, body_bytes: float, syndrome_bytes: float,
    where: str = "csd",
) -> ArchiveCost:
    """Price one background scrub pass (parity re-verification of sealed
    stripes — ``core/archival/scrub.py``) at a given placement.

    The scrub's structural advantage on the CSD tier is extreme: parity is
    defined over the SEALED bodies, so verification needs no keys and no
    decode — each CSD streams its own bodies through the parity fold at
    internal bandwidth and ships only the P/Q *syndromes* (a few hundred
    bytes per stripe) for the cross-shard compare.  ``where="host"`` prices
    the naive alternative — every sealed body crosses the host link to be
    XOR/GF-folded on the storage CPU — which moves ``body_bytes`` per pass
    and is why host-side scrubbing of a large archive starves ingest.
    ``body_bytes``: sealed bytes verified per pass; ``syndrome_bytes``: the
    P+Q strips shipped for comparison (what the CSD path moves instead).
    """
    if where == "host":
        lat = max(
            body_bytes / (sys.host_link_GBps * 1e9),  # every sealed byte up
            body_bytes / (sys.cpu_rate_GBps * 1e9),   # host parity fold
        )
        return ArchiveCost(lat, body_bytes)
    if where == "csd":
        lat = max(
            body_bytes / (sys.ssd_internal_GBps * 1e9),  # flash-local read
            body_bytes / (sys.csd_rate_GBps * 1e9),      # on-device fold
            syndrome_bytes / (sys.p2p_GBps * 1e9),       # syndromes only
        )
        return ArchiveCost(lat, syndrome_bytes)
    raise ValueError(f"unknown scrub placement {where!r}")


def best_scrub_placement(
    sys: SystemModel, body_bytes: float, syndrome_bytes: float
) -> Tuple[str, dict]:
    """Cheapest-latency scrub placement (movement reported per option —
    the CSD tier wins on both axes for any realistically sized archive)."""
    costs = {
        w: scrub_placement_cost(sys, body_bytes, syndrome_bytes, w)
        for w in ("host", "csd")
    }
    return min(costs, key=lambda w: costs[w].latency_s), costs


def cpu_on_csd_data(sys: SystemModel, raw_bytes: float) -> ArchiveCost:
    """Table 2 row 1: data on CSD but kernels on the host CPU — raw bytes must
    cross the host link first (pipelined with CPU compute)."""
    out = raw_bytes / sys.compress_ratio
    lat = max(
        raw_bytes / (sys.host_link_GBps * 1e9),
        raw_bytes / (sys.cpu_rate_GBps * 1e9),
        out / (sys.ssd_write_GBps * 1e9),
    )
    return ArchiveCost(lat, raw_bytes)


def multinode_movement_latency(
    sys: SystemModel, raw_bytes: float, n_nodes: int
) -> float:
    """Fig. 10: *data-movement* latency when one application's data is spread
    over N storage servers.  A (1 - 1/N) fraction needs a remote hop, and the
    network contends with the other N-1 servers' traffic — super-linear
    growth, the paper's "keep an application's data on one server" advice."""
    if n_nodes <= 1:
        return 0.0
    remote_bytes = raw_bytes * (1.0 - 1.0 / n_nodes)
    eff_net = sys.network_GBps * 1e9 / (1.0 + sys.contention * (n_nodes - 1))
    return remote_bytes / eff_net


def multinode_latency(
    sys: SystemModel, raw_bytes: float, n_nodes: int, locality: float = 0.8
) -> ArchiveCost:
    """Fig. 6 (Salient Store row): total archival on N storage nodes.  Compute
    parallelizes over nodes; the (1 - locality) remote fraction crosses the
    contended network *compressed at the ingest CSD* — the near-data thesis
    applied to the network hop.  Speedup over the classical row is sub-linear
    in N (movement grows super-linearly)."""
    per_node = raw_bytes / n_nodes
    local = csd_archive(sys, per_node)
    remote_raw = raw_bytes * (1.0 - locality)
    net_lat = multinode_movement_latency(
        sys, remote_raw / sys.compress_ratio, n_nodes
    )
    moved = local.moved_bytes * n_nodes + (remote_raw / sys.compress_ratio) * (
        1.0 - 1.0 / n_nodes
    )
    return ArchiveCost(local.latency_s + net_lat, moved)


def classical_multinode_latency(
    sys: SystemModel, raw_bytes: float, n_nodes: int, locality: float = 0.8
) -> ArchiveCost:
    """Fig. 6 (classical row): same fragmentation, but remote traffic is RAW
    (compression happens only at the destination storage CPU)."""
    per_node = raw_bytes / n_nodes
    local = classical_archive(sys, per_node)
    remote_raw = raw_bytes * (1.0 - locality)
    net_lat = multinode_movement_latency(sys, remote_raw, n_nodes)
    moved = local.moved_bytes * n_nodes + remote_raw * (1.0 - 1.0 / n_nodes)
    return ArchiveCost(local.latency_s + net_lat, moved)


def csd_ratio_tradeoff(
    sys: SystemModel,
    raw_bytes: float,
    n_ssd: int,
    n_csd: int,
    csd_cost: float = 15.0,
    ssd_cost: float = 1.0,
):
    """Fig. 11: speedup and cost-normalized benefit of n_csd CSDs serving
    n_ssd SSDs.  Compute parallelism scales with CSDs (minus the serial
    stripe fraction) until the SSD write tier saturates; CSDs cost ~15x an
    SSD, so the cost-normalized optimum lands at the paper's 8:1 knee."""
    single = csd_archive(sys, raw_bytes, (1.0,)).latency_s
    sf = sys.stripe_serial_frac
    parallel_lat = sf * single + (1.0 - sf) * single / n_csd
    out = raw_bytes / sys.compress_ratio
    write_floor = out / (sys.ssd_write_GBps * 1e9 * max(n_ssd, 1))
    lat = max(parallel_lat, write_floor)
    base = classical_archive(sys, raw_bytes).latency_s
    speedup = base / lat
    cost = n_csd * csd_cost + n_ssd * ssd_cost
    return speedup, speedup / cost
