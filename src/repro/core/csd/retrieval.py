"""Salience-driven retrieval planner — the query half of Salient Store.

The write path (PRs 1-3) seals data where it lives; this module plans the
READ path: given the trainer's current exemplar centroids and a byte
budget, decide WHICH archived GOPs to pull back for replay and WHAT that
costs, without touching a single payload byte.  Three inputs meet here:

  * the :class:`~repro.core.archival.catalog.StripeCatalog` — per-GOP
    salience descriptors recorded at archive time, so ranking is a pure
    metadata operation;
  * the failure tier — shards whose CSD the ``StragglerMonitor`` flagged
    dead are still retrievable, but only through a parity-based degraded
    read that touches the surviving shards + parity (``dead_shards``
    makes the planner bill that amplification honestly);
  * the cost model — ``best_retrieval_placement`` prices the decode on
    the host (ship compressed, spend host CPU) vs on the CSD (spend the
    faster kernel, ship the expanded payload) and the plan records the
    winner.

The emitted :class:`ReadPlan` maps each touched stripe to the shard subset
to decode — exactly the ``shards=`` argument of ``restore_stripe`` /
``restore_stripe_sharded`` — so executing a plan moves only the bytes the
plan accounted for.  ``bytes_full_restore`` keeps the no-index baseline
(restore every stripe, score after decode) alongside for the paper's
data-volume-reduction claim; the ``retrieval`` bench gates on the ratio.
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

from repro.core.csd import costmodel
from repro.obs import EDGE_REPLAY_FULL_BASELINE, EDGE_REPLAY_PLANNED, OBS
from repro.obs import names as obs_names

__all__ = ["ShardRead", "ReadPlan", "plan_retrieval"]


class ShardRead(NamedTuple):
    """One planned GOP read: where it lives and what pulling it costs."""

    stripe_id: str
    shard: int
    stream_id: int
    novelty: float      # score vs the QUERY centroids (not archive-time)
    body_bytes: int     # sealed body bytes of this shard
    n_comp: int         # entropy-coded bytes inside the body
    n_i8: int           # decoded codec payload bytes
    degraded: bool      # CSD dead -> parity rebuild (reads peers + parity)
    read_bytes: int     # marginal flash bytes this read adds to the plan


class ReadPlan(NamedTuple):
    reads: List[ShardRead]                  # ranked, most novel first
    shards_by_stripe: Dict[str, List[int]]  # restore_stripe(shards=...) input
    bytes_planned: int       # flash bytes read (incl. degraded amplification)
    bytes_full_restore: int  # no-index baseline: read every cataloged body
    placement: str           # "host" | "csd" decode placement (cheapest)
    costs: Dict[str, costmodel.ArchiveCost]  # both placements, priced
    skipped: int             # ranked candidates the byte budget rejected


def _degraded_read_bytes(
    stripe_entries: List, touched: Set[int], dead: Set[int],
    parity_shards: int,
) -> int:
    """Marginal bytes a parity rebuild adds: every not-yet-read SURVIVING
    body (dead shards cannot be read, they are what gets reconstructed)
    plus the parity strips (sized like the widest body)."""
    peers = sum(
        e.body_bytes
        for e in stripe_entries
        if e.shard not in touched and e.shard not in dead
    )
    pad = max(e.body_bytes for e in stripe_entries)
    return peers + parity_shards * pad


def plan_retrieval(
    catalog,
    centroids=None,
    budget_bytes: Optional[int] = None,
    *,
    k: Optional[int] = None,
    sys: costmodel.SystemModel = costmodel.SystemModel(),
    dead_shards: Sequence[int] = (),
    parity_shards: int = 2,
) -> ReadPlan:
    """Rank the catalog by novelty and emit a budgeted per-shard read plan.

    ``centroids``: the trainer's CURRENT exemplar centroids ((K, D); None
    falls back to archive-time novelty).  ``budget_bytes`` caps the flash
    bytes the plan may touch; ``k`` caps the GOP count (both optional —
    give neither and the plan covers the whole catalog, ranked).
    ``dead_shards``: stripe-shard indices whose CSD the StragglerMonitor
    declared dead — wanted GOPs there are planned as degraded reads and
    their parity-rebuild amplification is billed against the budget.
    ``parity_shards``: parity strips per stripe (2 for RAID-6, 1 for
    RAID-5) used to size that bill.
    """
    with OBS.span("retrieval.plan") as sp:
        entries = catalog.entries
        scores = catalog.score(centroids)
        order = sorted(range(len(entries)), key=lambda i: -float(scores[i]))
        if k is not None:
            order = order[: max(int(k), 0)]
        dead = set(int(d) for d in dead_shards)

        by_stripe: Dict[str, List] = {}
        for e in entries:
            by_stripe.setdefault(e.stripe_id, []).append(e)

        reads: List[ShardRead] = []
        touched: Dict[str, Set[int]] = {}
        rebuilt: Set[str] = set()  # stripes whose parity rebuild already ran
        planned = 0
        skipped = 0
        for i in order:
            e = entries[i]
            got = touched.setdefault(e.stripe_id, set())
            degraded = e.shard in dead
            if degraded:
                # a stripe with more dead shards than parity strips cannot be
                # rebuilt — planning that read would bill bytes for a rebuild
                # that must fail, so it is dropped instead of promised
                stripe_dead = dead & {x.shard for x in by_stripe[e.stripe_id]}
                if len(stripe_dead) > parity_shards:
                    skipped += 1
                    continue
                # one rebuild reconstructs every lost shard of the stripe at
                # once; a second dead-shard read there adds no new bytes
                cost = (
                    0
                    if e.stripe_id in rebuilt
                    else _degraded_read_bytes(
                        by_stripe[e.stripe_id], got, dead, parity_shards
                    )
                )
            else:
                cost = 0 if e.shard in got else e.body_bytes
            if budget_bytes is not None and planned + cost > budget_bytes:
                skipped += 1
                continue
            planned += cost
            if degraded:
                # the rebuild read every surviving body in the stripe
                rebuilt.add(e.stripe_id)
                got.update(x.shard for x in by_stripe[e.stripe_id])
            else:
                got.add(e.shard)
            reads.append(
                ShardRead(
                    stripe_id=e.stripe_id,
                    shard=e.shard,
                    stream_id=e.stream_id,
                    novelty=float(scores[i]),
                    body_bytes=e.body_bytes,
                    n_comp=e.n_comp,
                    n_i8=e.n_i8,
                    degraded=degraded,
                    read_bytes=cost,
                )
            )

        shards_by_stripe = {
            sid: sorted({r.shard for r in reads if r.stripe_id == sid})
            for sid in {r.stripe_id for r in reads}
        }
        comp = float(sum(r.n_comp for r in reads))
        raw = float(sum(r.n_i8 for r in reads))
        if reads:
            placement, costs = costmodel.best_retrieval_placement(
                sys, comp, raw
            )
        else:
            placement, costs = "host", {
                w: costmodel.ArchiveCost(0.0, 0.0) for w in ("host", "csd")
            }
        if OBS.enabled:
            # the planned-vs-baseline pair is VIRTUAL traffic billed at
            # plan time; restore bills replay.read/replay.parity when bytes
            # actually move, so the ledger's moved_vs_planned closes the loop
            sp.set(reads=len(reads), skipped=skipped,
                   planned_bytes=planned, placement=placement)
            OBS.count(obs_names.RETR_PLANS)
            OBS.count(obs_names.RETR_PLANNED_BYTES, planned)
            OBS.count(obs_names.RETR_FULL_BYTES, catalog.bytes_indexed)
            OBS.count(obs_names.RETR_SKIPPED, skipped)
            OBS.flow(EDGE_REPLAY_PLANNED, planned, events=len(reads))
            OBS.flow(EDGE_REPLAY_FULL_BASELINE, catalog.bytes_indexed)
        return ReadPlan(
            reads=reads,
            shards_by_stripe=shards_by_stripe,
            bytes_planned=planned,
            bytes_full_restore=catalog.bytes_indexed,
            placement=placement,
            costs=costs,
            skipped=skipped,
        )
