"""Deterministic fault injection for a simulated CSD fleet (chaos harness).

The paper's durability claim — storage that *survives* intermittent edge
deployments — is only credible if it is continuously exercised, so this
module simulates a fleet of 100s of CSDs under the fault classes an
unattended edge pod actually produces and drives them through the real
seams: the ``StragglerMonitor`` (heartbeats), the ``Journal`` (commits),
and the scrub/rebuild tier (``core/archival/scrub.py``,
``distributed/archival.rebuild_csd_sharded``).

Fault classes (``FaultEvent.kind``):

* ``"bitflip"``  — silent corruption: one bit flips in a committed body
  (``flip_bit``); the Journal's crc32 detects it, the scrubber's parity
  syndrome locates and repairs it.
* ``"loss"``     — permanent CSD loss: the device stops heartbeating
  forever; the monitor declares it dead after ``miss_threshold`` rounds
  and its shards are rebuilt onto a replacement.
* ``"restart"``  — rolling restart: the CSD misses ``restart_rounds``
  heartbeats then returns; must NOT be declared dead (the monitor's
  ``miss_threshold`` grace exists exactly for this).
* ``"dropout"``  — a single missed heartbeat; a non-event.
* ``"torn"``     — power loss mid-seal: a stripe body hits the disk
  truncated with its journal record already appended (``torn_commit``);
  replay must discard it cleanly.

Determinism is the contract: the ENTIRE schedule — every event and every
per-round step time — is precomputed in ``__init__`` from
``np.random.default_rng(cfg.seed)``, so the same seed replays the same
chaos bit-for-bit no matter how the consumer interleaves ``tick()`` with
repairs.  CI pins a seed and asserts the acceptance invariant: every
sealed stripe ends scrub-verified, rebuilt bit-exact, or journaled as
retired — zero undetected corruptions.
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS",
    "ChaosConfig",
    "FaultEvent",
    "FleetRound",
    "ChaosFleet",
    "flip_bit",
    "torn_commit",
]

FAULT_KINDS = ("bitflip", "loss", "restart", "dropout", "torn")


class ChaosConfig(NamedTuple):
    """Fleet shape + per-round, per-CSD fault probabilities."""

    n_csds: int = 256
    n_rounds: int = 32
    seed: int = 0
    p_bitflip: float = 0.002
    p_loss: float = 0.0005
    p_restart: float = 0.002
    p_dropout: float = 0.01
    p_torn: float = 0.001
    restart_rounds: int = 2       # heartbeats missed by a rolling restart
    base_step_time: float = 1.0   # healthy heartbeat latency (seconds)
    jitter: float = 0.05          # relative step-time noise
    # kinds guaranteed ≥1 event in the schedule (one deterministic event is
    # appended per absent kind) — tests use this to exercise every class
    # without cranking probabilities
    ensure_kinds: Tuple[str, ...] = ()


class FaultEvent(NamedTuple):
    round: int   # fleet round the fault fires in
    kind: str    # one of FAULT_KINDS
    csd: int     # device the fault hits
    param: int   # kind-specific: bitflip/torn = draw for the bit/cut point


class FleetRound(NamedTuple):
    """One fleet heartbeat round as the monitor and the tests see it."""

    round: int
    events: List[FaultEvent]            # faults that fired THIS round
    step_times: List[Optional[float]]   # per-CSD heartbeat (None = missed)
    down: List[int]                     # CSDs not heartbeating this round
    lost: List[int]                     # CSDs permanently lost so far


def flip_bit(payload: bytes, event: FaultEvent) -> bytes:
    """Deterministically flip one bit of ``payload`` per a bitflip event."""
    if not payload:
        return payload
    bit = event.param % (len(payload) * 8)
    buf = bytearray(payload)
    buf[bit // 8] ^= 1 << (bit % 8)
    return bytes(buf)


def torn_commit(journal, name: str, payload: bytes, event: FaultEvent,
                meta: Optional[Dict] = None) -> None:
    """Simulate power loss mid-seal: the journal record lands but the body
    is truncated on disk (the record claims the full size).  ``replay()``
    must treat this exactly like a torn write and discard it."""
    import json
    import os
    import time
    import zlib

    cut = event.param % max(len(payload), 1)
    with open(os.path.join(journal.root, name), "wb") as f:
        f.write(payload[:cut])
    rec = {
        "name": name,
        "bytes": len(payload),
        "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
        "ts": time.time(),
        "meta": meta or {},
    }
    with open(journal.path, "a") as f:
        f.write(json.dumps(rec) + "\n")


class ChaosFleet:
    """A seed-deterministic fleet of simulated CSDs.

    ``tick()`` advances one heartbeat round and returns the faults that
    fired plus the per-CSD step times to feed the ``StragglerMonitor``.
    The consumer applies data faults itself (``flip_bit`` on a journaled
    body, ``torn_commit`` for a mid-seal loss) — the fleet only decides
    WHAT fails WHEN, so the same schedule can drive any storage stack.

    ``replace(csd)`` models a rebuilt replacement device taking over a lost
    CSD's slot: it resumes heartbeating on the next round.
    """

    def __init__(self, cfg: ChaosConfig = ChaosConfig()):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        probs = {
            "bitflip": cfg.p_bitflip,
            "loss": cfg.p_loss,
            "restart": cfg.p_restart,
            "dropout": cfg.p_dropout,
            "torn": cfg.p_torn,
        }
        # precompute EVERYTHING up front: draws never depend on consumer
        # behavior, so seed => schedule is bijective
        schedule: List[List[FaultEvent]] = [[] for _ in range(cfg.n_rounds)]
        for r in range(cfg.n_rounds):
            for kind in FAULT_KINDS:  # fixed order => fixed draw order
                hits = rng.random(cfg.n_csds) < probs[kind]
                params = rng.integers(0, 2**31 - 1, cfg.n_csds)
                for c in np.flatnonzero(hits):
                    schedule[r].append(
                        FaultEvent(r, kind, int(c), int(params[c]))
                    )
        self.step_time_table = cfg.base_step_time * (
            1.0 + cfg.jitter * rng.standard_normal((cfg.n_rounds, cfg.n_csds))
        )
        # deterministic backfill for kinds the random draws never produced
        present = {e.kind for evs in schedule for e in evs}
        for i, kind in enumerate(k for k in cfg.ensure_kinds
                                 if k not in present):
            params = rng.integers(0, 2**31 - 1, 2)
            r = int(params[0]) % max(cfg.n_rounds - 1, 1)
            c = (int(params[1]) + i) % cfg.n_csds
            schedule[r].append(FaultEvent(r, kind, c, int(params[1])))
        for evs in schedule:
            evs.sort(key=lambda e: (e.csd, FAULT_KINDS.index(e.kind)))
        self.schedule = schedule
        self.round = 0
        self._lost: set = set()
        self._down_until: Dict[int, int] = {}  # csd -> first round back up

    # --------------------------------------------------------------- state
    @property
    def lost(self) -> List[int]:
        return sorted(self._lost)

    def replace(self, csd: int) -> None:
        """A replacement device takes over a lost CSD's slot."""
        self._lost.discard(csd)
        self._down_until.pop(csd, None)

    def events_of(self, kind: str) -> List[FaultEvent]:
        """All scheduled events of one kind (inspection/tests)."""
        return [e for evs in self.schedule for e in evs if e.kind == kind]

    # ---------------------------------------------------------------- tick
    def tick(self) -> FleetRound:
        if self.round >= self.cfg.n_rounds:
            raise StopIteration(
                f"chaos schedule exhausted at round {self.cfg.n_rounds}"
            )
        r = self.round
        events = list(self.schedule[r])
        for e in events:
            if e.kind == "loss":
                self._lost.add(e.csd)
            elif e.kind == "restart":
                self._down_until[e.csd] = r + self.cfg.restart_rounds
            elif e.kind == "dropout":
                self._down_until.setdefault(e.csd, r + 1)
        down = sorted(
            set(self._lost)
            | {c for c, until in self._down_until.items() if r < until}
        )
        downset = set(down)
        step_times: List[Optional[float]] = [
            None if c in downset else float(self.step_time_table[r, c])
            for c in range(self.cfg.n_csds)
        ]
        self._down_until = {
            c: until for c, until in self._down_until.items() if r + 1 < until
        }
        self.round += 1
        return FleetRound(r, events, step_times, down, self.lost)

    def run(self) -> List[FleetRound]:
        """Tick through the remaining schedule (no data faults applied)."""
        return [self.tick() for _ in range(self.round, self.cfg.n_rounds)]
