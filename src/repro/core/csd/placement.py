"""Stream -> storage-shard placement and load balancing (Table 2, §3.1).

The paper shows (Table 2) that balancing archival load across CSDs is the
dominant lever: a 50/50 split of two CSDs reaches 7.7x vs 3.9x for a single
CSD.  This module is the framework's placement engine: greedy LPT assignment
of weighted streams to shards, plus incremental rebalancing driven by the
straggler monitor (csd/failure.py) — the same mechanism serves both load
balance and straggler mitigation at pod scale.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, NamedTuple, Sequence

__all__ = ["Placement", "balance_streams", "rebalance", "placement_ratios"]


class Placement(NamedTuple):
    assignment: Dict[int, int]  # stream id -> shard id
    loads: List[float]  # per-shard total rate

    def shard_streams(self, shard: int) -> List[int]:
        return [s for s, sh in self.assignment.items() if sh == shard]


def balance_streams(
    rates: Sequence[float], n_shards: int, capacities: Sequence[float] | None = None
) -> Placement:
    """Greedy LPT: heaviest stream first onto the least-loaded shard
    (normalized by capacity)."""
    caps = list(capacities) if capacities is not None else [1.0] * n_shards
    assert len(caps) == n_shards
    heap = [(0.0, i) for i in range(n_shards)]
    heapq.heapify(heap)
    assignment: Dict[int, int] = {}
    loads = [0.0] * n_shards
    for sid in sorted(range(len(rates)), key=lambda s: -rates[s]):
        norm_load, shard = heapq.heappop(heap)
        assignment[sid] = shard
        loads[shard] += rates[sid]
        heapq.heappush(heap, (loads[shard] / caps[shard], shard))
    return Placement(assignment, loads)


def placement_ratios(p: Placement) -> List[float]:
    total = sum(p.loads)
    return [l / total if total else 0.0 for l in p.loads]


def rebalance(
    p: Placement,
    rates: Sequence[float],
    shard_speed: Sequence[float],
    max_moves: int = 2,
) -> Placement:
    """Straggler-aware incremental rebalance: move up to ``max_moves`` streams
    off the slowest (highest normalized-time) shards.  ``shard_speed`` is the
    EWMA relative throughput from the straggler monitor (1.0 = healthy,
    0 = dead)."""
    n_shards = len(p.loads)
    eff = [max(s, 1e-6) for s in shard_speed]
    new_assign = dict(p.assignment)
    loads = list(p.loads)
    for _ in range(max_moves):
        norm = [loads[i] / eff[i] for i in range(n_shards)]
        src = max(range(n_shards), key=lambda i: norm[i])
        dst = min(range(n_shards), key=lambda i: norm[i])
        if src == dst:
            break
        movable = [s for s, sh in new_assign.items() if sh == src]
        if not movable:
            break
        # move the smallest stream that improves the imbalance
        movable.sort(key=lambda s: rates[s])
        moved = False
        for s in movable:
            if loads[src] / eff[src] - rates[s] / eff[src] >= 0 and (
                (loads[dst] + rates[s]) / eff[dst] < loads[src] / eff[src]
            ):
                new_assign[s] = dst
                loads[src] -= rates[s]
                loads[dst] += rates[s]
                moved = True
                break
        if not moved:
            break
    return Placement(new_assign, loads)
