"""ShapeDtypeStruct stand-ins for every (arch x shape) cell — no allocation.

``input_specs(arch, shape)`` returns the exact argument pytrees the dry-run
lowers against: model inputs (tokens/labels/frontend or token+caches), and
``cell_specs`` adds params/optimizer trees via ``jax.eval_shape`` so even the
398-400B configs cost zero host memory.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeSpec, applicable_shapes
from repro.distributed.policy import DistPolicy, policy_for
from repro.models.config import ModelConfig
from repro.models.registry import get_config
from repro.models.transformer import init_cache, init_model
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.distributed.steps import StepConfig

__all__ = ["input_specs", "cell_specs", "CellSpec"]

SDS = jax.ShapeDtypeStruct


def _frontend_sds(cfg: ModelConfig, batch: int) -> Optional[SDS]:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.encoder is not None:
        return SDS((batch, cfg.encoder.seq_len, cfg.frontend_dim or cfg.d_model), dt)
    if cfg.n_frontend_tokens:
        return SDS((batch, cfg.n_frontend_tokens, cfg.frontend_dim or cfg.d_model), dt)
    return None


def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for one cell (weak-type-correct,
    shardable, no device allocation)."""
    cfg = get_config(arch) if isinstance(arch, str) else arch
    sp: ShapeSpec = SHAPES[shape]
    B, L = sp.global_batch, sp.seq_len
    if sp.kind == "train":
        out = {
            "tokens": SDS((B, L), jnp.int32),
            "labels": SDS((B, L), jnp.int32),
        }
        fe = _frontend_sds(cfg, B)
        if fe is not None:
            out["frontend"] = fe
        return out
    if sp.kind == "prefill":
        out = {"tokens": SDS((B, L), jnp.int32)}
        fe = _frontend_sds(cfg, B)
        if fe is not None:
            out["frontend"] = fe
        return out
    # decode: one new token against a cache of seq_len
    out = {
        "token": SDS((B, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }
    fe = _frontend_sds(cfg, B)
    if fe is not None:
        out["frontend"] = fe
    return out


class CellSpec(NamedTuple):
    cfg: ModelConfig
    shape: ShapeSpec
    policy: DistPolicy
    step_cfg: StepConfig
    params: Any  # SDS pytree
    opt_state: Any  # SDS pytree (train only)
    cache: Any  # SDS pytree (decode only)
    inputs: Dict[str, Any]


def cell_specs(arch: str, shape: str) -> CellSpec:
    cfg = get_config(arch)
    sp = SHAPES[shape]
    if shape not in applicable_shapes(cfg):
        raise ValueError(f"cell ({arch}, {shape}) is skipped (sub-quadratic only)")
    pol = policy_for(cfg, sp.kind)
    scfg = StepConfig(
        remat=pol.remat,
        q_chunk=pol.q_chunk,
        n_microbatch=pol.n_microbatch,
        opt=AdamWConfig(
            lr=3e-4,
            grad_clip=1.0,
            state_dtype=pol.opt_state_dtype,
            kind=pol.opt_kind,
        ),
        grad_accum_dtype=pol.opt_state_dtype,  # bf16 accum iff bf16 states
        int8_gather=pol.int8_gather,
        flash_attn=pol.flash_attn,
    )
    params = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    opt_state = None
    cache = None
    inputs = input_specs(arch, shape)
    if sp.kind == "train":
        opt_state = jax.eval_shape(functools.partial(adamw_init, cfg=scfg.opt), params)
    if sp.kind == "decode":
        fe = inputs.get("frontend")
        cache = jax.eval_shape(
            lambda p, f: init_cache(p, cfg, sp.global_batch, sp.seq_len, f),
            params,
            fe,
        ) if fe is not None else jax.eval_shape(
            lambda p: init_cache(p, cfg, sp.global_batch, sp.seq_len), params
        )
    return CellSpec(cfg, sp, pol, scfg, params, opt_state, cache, inputs)
