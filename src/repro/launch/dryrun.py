import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build ShapeDtypeStruct stand-ins (launch/specs.py) — zero allocation;
  * jit the train/prefill/serve step with the production NamedShardings;
  * ``.lower().compile()`` on the 16x16 single-pod mesh AND the 2x16x16
    multi-pod mesh;
  * print ``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes);
  * parse collective bytes from the compiled HLO;
  * append one JSON record per cell to --out (incremental, resumable).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out results/dryrun] [--skip-done]
"""

import argparse
import functools
import json
import time
import traceback

import jax


def _json_default(o):
    return str(o)


def costing_flops(arch: str, shape: str) -> dict:
    """Global (unpartitioned) FLOPs/bytes from an *unrolled* lowering.

    XLA's cost analysis counts while/scan bodies once; unrolling every scan
    (layers, CE chunks, microbatches) makes HLO_FLOPs exact.  q_chunk=0
    removes the attention chunking scan (same FLOPs, no loop).  No compile,
    no mesh, no allocation — pure abstract tracing.
    """
    import functools

    from repro.distributed.steps import prefill_step, serve_step, train_step
    from repro.launch.specs import cell_specs

    spec = cell_specs(arch, shape)
    cfg, sp = spec.cfg, spec.shape
    scfg = spec.step_cfg._replace(unroll=True, q_chunk=0)
    if sp.kind == "train":
        step = functools.partial(train_step, cfg=cfg, scfg=scfg)
        low = jax.jit(step).lower(spec.params, spec.opt_state, spec.inputs)
    elif sp.kind == "prefill":
        step = functools.partial(prefill_step, cfg=cfg, scfg=scfg)
        args = [spec.inputs["tokens"]]
        if "frontend" in spec.inputs:
            args.append(spec.inputs["frontend"])
        low = jax.jit(step).lower(spec.params, *args)
    else:
        step = functools.partial(serve_step, cfg=cfg, unroll=True)
        low = jax.jit(step).lower(
            spec.params, spec.inputs["token"], spec.cache, spec.inputs["pos"]
        )
    ca = low.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    return {
        "flops_total": float(ca.get("flops", 0.0)),
        "bytes_total": float(ca.get("bytes accessed", 0.0)),
    }


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str) -> dict:
    from repro.distributed.sharding import (
        batch_pspecs,
        cache_pspecs,
        data_axes,
        make_shard_fn,
        param_pspecs,
        tree_shardings,
    )
    from repro.distributed.steps import prefill_step, serve_step, train_step
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import cell_specs
    from repro.roofline.analysis import (
        collective_bytes,
        collective_bytes_weighted,
        roofline_terms,
    )
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    spec = cell_specs(arch, shape)
    cfg, sp, pol, scfg = spec.cfg, spec.shape, spec.policy, spec.step_cfg
    shard_fn = make_shard_fn(mesh, pol.seq_shard, pol.tp)
    p_sh = tree_shardings(param_pspecs(spec.params, mesh, pol.fsdp, pol.tp), mesh)
    bsp = batch_pspecs(mesh, pol.tp)
    da = data_axes(mesh)

    t0 = time.time()
    with mesh:
        if sp.kind == "train":
            o_sh = tree_shardings(
                param_pspecs(spec.opt_state, mesh, pol.fsdp, pol.tp), mesh
            )
            batch = dict(spec.inputs)
            b_sh = {
                k: NamedSharding(mesh, bsp["frontend" if k == "frontend" else k])
                for k in batch
            }
            step = functools.partial(
                train_step, cfg=cfg, scfg=scfg, shard_fn=shard_fn
            )
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),  # params/opt update in place
            )
            lowered = jitted.lower(spec.params, spec.opt_state, batch)
        elif sp.kind == "prefill":
            step = functools.partial(
                prefill_step, cfg=cfg, scfg=scfg, shard_fn=shard_fn
            )
            args = [spec.inputs["tokens"]]
            in_sh = [NamedSharding(mesh, bsp["tokens"])]
            if "frontend" in spec.inputs:
                args.append(spec.inputs["frontend"])
                in_sh.append(NamedSharding(mesh, bsp["frontend"]))
            jitted = jax.jit(
                step, in_shardings=(p_sh, *in_sh), out_shardings=None
            )
            lowered = jitted.lower(spec.params, *args)
        else:  # decode
            c_sh = tree_shardings(
                cache_pspecs(spec.cache, mesh, sp.global_batch, sp.seq_len), mesh
            )
            tok_axes = da if sp.global_batch % mesh.shape[da[0]] == 0 and sp.global_batch >= n_dev // mesh.shape["model"] else None
            tok_sh = NamedSharding(mesh, P(tok_axes, None))
            step = functools.partial(serve_step, cfg=cfg, shard_fn=shard_fn)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, tok_sh, c_sh, None),
                out_shardings=(None, c_sh),
            )
            lowered = jitted.lower(
                spec.params, spec.inputs["token"], spec.cache, spec.inputs["pos"]
            )
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"--- memory_analysis [{arch} x {shape} x {'multi' if multi_pod else 'single'}]")
    print(mem)
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    print(f"--- cost_analysis flops={cost.get('flops', 0):.3e} bytes={cost.get('bytes accessed', 0):.3e}")
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)  # flat (loop bodies once) — for reference
    coll_weighted = collective_bytes_weighted(hlo)  # trip-count-corrected

    # exact global FLOPs/bytes from the unrolled costing lowering
    try:
        exact = costing_flops(arch, shape)
    except Exception as e:  # noqa: BLE001 — fall back to compiled estimate
        print(f"costing lowering failed ({e!r}); falling back to compiled cost")
        exact = {
            "flops_total": float(cost.get("flops", 0.0)) * n_dev,
            "bytes_total": float(cost.get("bytes accessed", 0.0)) * n_dev,
        }
    # memory bytes: compiled (fused, SPMD-partitioned) per-device bytes,
    # corrected for loops-counted-once by the exact/compiled FLOPs ratio —
    # costing-lowering bytes are unfused and overestimate ~50x.
    compiled_flops = float(cost.get("flops", 0.0))
    loop_ratio = (
        exact["flops_total"] / n_dev / compiled_flops if compiled_flops > 0 else 1.0
    )
    loop_ratio = max(loop_ratio, 1.0)
    cost_corrected = {
        "flops": exact["flops_total"] / n_dev,
        "bytes accessed": float(cost.get("bytes accessed", 0.0)) * loop_ratio,
    }
    terms = roofline_terms(
        cost_corrected, hlo, n_dev, {"weighted": int(coll_weighted)}
    )

    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "kind": sp.kind,
        "policy": pol._asdict(),
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_total_exact": exact["flops_total"],
        "bytes_total_exact": exact["bytes_total"],
        "flops_per_device": cost_corrected["flops"],
        "bytes_per_device": cost_corrected["bytes accessed"],
        "compiled_flops_per_device_loopsonce": cost.get("flops", 0.0),
        "collective_bytes_per_device": coll,
        "collective_bytes_per_device_weighted": coll_weighted,
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "fraction": terms.roofline_fraction(),
        },
        "fits_hbm_16g": bool(
            (getattr(mem, "argument_size_in_bytes", 0)
             + getattr(mem, "temp_size_in_bytes", 0)) < 16 * 1024**3
        ),
        "memory_analysis": {
            k: getattr(mem, k)
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1, default=_json_default)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    from repro.configs.shapes import all_cells

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_ok = n_fail = n_skip = 0
    for arch, shape, runnable in all_cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        for multi in meshes:
            mesh_name = "multi" if multi else "single"
            fname = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
            if args.skip_done and os.path.exists(fname):
                print(f"[done] {arch} x {shape} x {mesh_name}")
                continue
            if not runnable:
                os.makedirs(args.out, exist_ok=True)
                with open(fname, "w") as f:
                    json.dump(
                        {
                            "arch": arch, "shape": shape, "mesh": mesh_name,
                            "status": "skipped",
                            "reason": "long_500k requires sub-quadratic mixer "
                                      "(full-attention arch) — see DESIGN.md",
                        },
                        f, indent=1,
                    )
                n_skip += 1
                print(f"[skip] {arch} x {shape} ({mesh_name}): full-attention arch")
                continue
            print(f"[cell] {arch} x {shape} x {mesh_name} ...", flush=True)
            try:
                rec = run_cell(arch, shape, multi, args.out)
                n_ok += 1
                r = rec["roofline"]
                print(
                    f"[ ok ] {arch} x {shape} x {mesh_name}: "
                    f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
                    f"dominant={r['dominant']} fraction={r['fraction']:.3f}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — record and continue
                n_fail += 1
                os.makedirs(args.out, exist_ok=True)
                with open(fname, "w") as f:
                    json.dump(
                        {
                            "arch": arch, "shape": shape, "mesh": mesh_name,
                            "status": "fail", "error": repr(e),
                            "traceback": traceback.format_exc()[-4000:],
                        },
                        f, indent=1,
                    )
                print(f"[FAIL] {arch} x {shape} x {mesh_name}: {e!r}", flush=True)
    print(f"dry-run complete: ok={n_ok} fail={n_fail} skipped={n_skip}")


if __name__ == "__main__":
    main()
