"""Serving launcher: batched greedy decoding with the slot engine (smoke scale).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2_370m --requests 3
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    import jax

    from repro.models.registry import get_smoke_config
    from repro.models.transformer import init_model
    from repro.serving.engine import Request, ServeConfig, ServingEngine

    cfg = get_smoke_config(args.arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    frontend = None
    if cfg.encoder is not None or cfg.n_frontend_tokens:
        n = cfg.encoder.seq_len if cfg.encoder else cfg.n_frontend_tokens
        frontend = jax.random.normal(
            jax.random.PRNGKey(1), (4, n, cfg.frontend_dim or cfg.d_model)
        )
    eng = ServingEngine(params, cfg, ServeConfig(max_batch=4, max_len=64), frontend)
    for r in range(args.requests):
        prompt = [1 + r, 2 + r, 3 + r]
        eng.submit(Request(rid=r, prompt=prompt, max_new=args.max_new))
    out = eng.run_to_completion()
    for rid, toks in sorted(out.items()):
        print(f"request {rid}: {toks}")


if __name__ == "__main__":
    main()
