"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count locks on first jax init).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_debug_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small host-device mesh for integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the *caller*
    before jax initializes)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
