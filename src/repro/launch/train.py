"""Training launcher.

Two modes:
  * ``--mode smoke``  — run real training steps on CPU with the reduced
    family-preserving config (validates the full runtime path end-to-end);
  * ``--mode dryrun`` — delegate to launch/dryrun.py semantics for the full
    config on the production mesh (lower+compile only).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --steps 5
"""

from __future__ import annotations

import argparse
import functools
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="smoke", choices=["smoke", "dryrun"])
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--grad-compress", type=int, default=0,
                    help="layered int8 gradient-compression layers (0=off)")
    ap.add_argument("--workdir", default="results/train")
    args = ap.parse_args()

    if args.mode == "dryrun":
        from repro.launch import dryrun

        rec = dryrun.run_cell(args.arch, "train_4k", False, args.workdir)
        print(rec["roofline"])
        return

    import jax
    import jax.numpy as jnp

    from repro.data.tokens import TokenStreamConfig, sample_batch
    from repro.distributed.steps import StepConfig, train_step
    from repro.models.registry import get_smoke_config
    from repro.models.transformer import init_model
    from repro.train.checkpoint import save_checkpoint
    from repro.train.grad_compress import GradCompressConfig
    from repro.train.optimizer import adamw_init

    cfg = get_smoke_config(args.arch)
    scfg = StepConfig(remat=False, q_chunk=0, n_microbatch=1)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params, scfg.opt)
    ts_cfg = TokenStreamConfig(cfg.vocab, args.seq, args.batch)
    step_fn = jax.jit(functools.partial(train_step, cfg=cfg, scfg=scfg))

    for step in range(args.steps):
        batch = sample_batch(ts_cfg, step)
        if cfg.encoder is not None or cfg.n_frontend_tokens:
            n = cfg.encoder.seq_len if cfg.encoder else cfg.n_frontend_tokens
            batch["frontend"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, n, cfg.frontend_dim or cfg.d_model)
            )
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        print(
            f"step {step}: loss={float(metrics['loss']):.4f} "
            f"ce={float(metrics['ce']):.4f} ({time.time() - t0:.2f}s)"
        )
    save_checkpoint(args.workdir, args.steps, {"params": params})
    print(f"checkpoint saved to {args.workdir}")


if __name__ == "__main__":
    main()
