"""Assigned input-shape set (the 4 shapes x 10 archs = 40 cells).

``long_500k`` requires a sub-quadratic mixer: it runs only for archs whose
layer pattern contains Mamba blocks (mamba2-370m, jamba-1.5) and is recorded
as SKIPPED for the 8 pure-full-attention archs (see DESIGN.md
§Arch-applicability and EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from typing import List, NamedTuple

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "applicable_shapes", "all_cells"]


class ShapeSpec(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if "M" in cfg.layer_pattern:  # sub-quadratic mixers only
        names.append("long_500k")
    return names


def skipped_shapes(cfg: ModelConfig) -> List[str]:
    return [n for n in SHAPES if n not in applicable_shapes(cfg)]


def all_cells():
    """Yield (arch_id, shape_name, runnable: bool) for all 40 cells."""
    from repro.models.registry import ARCH_IDS, get_config

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        app = set(applicable_shapes(cfg))
        for shape in SHAPES:
            yield arch, shape, shape in app
