"""Per-architecture configs (assigned pool) + the paper's own edge config."""
