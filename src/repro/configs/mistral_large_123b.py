"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral_large_123b",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=32768,
        act="silu_gated",
        rope_theta=1e6,
        tie_embeddings=False,
    )
