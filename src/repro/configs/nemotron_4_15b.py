"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000, squared-ReLU FFN [arXiv:2402.16819; unverified]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron_4_15b",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab=256000,
        act="squared_relu",
        rope_theta=1e4,
        tie_embeddings=False,
    )
