"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936,
QKV bias [arXiv:2407.10671; hf]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_0_5b",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab=151936,
        act="silu_gated",
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=True,
    )
