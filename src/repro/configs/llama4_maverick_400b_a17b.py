"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128e top-1 [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

MoE on alternate layers (every=2): 24 MoE layers x 128 experts x
3*5120*8192 ~ 386B expert params + dense/attention ~ 12B -> ~398B total,
~14B active (top-1 + dense FFN + attention) -- the published 400B-A17B class.
"""

from repro.models.config import ModelConfig, MoECfg


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4_maverick_400b_a17b",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=202048,
        act="silu_gated",
        rope_theta=5e5,
        moe=MoECfg(n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1, every=2),
        tie_embeddings=False,
    )
