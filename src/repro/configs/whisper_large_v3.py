"""whisper-large-v3 [audio]: enc-dec, 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866, conv frontend STUB [arXiv:2212.04356; unverified].

The modality frontend is a stub per the assignment: ``input_specs()`` supplies
precomputed (B, 1500, 1280) mel-frame embeddings (post-conv).  Deviation noted
in DESIGN.md: positions use sinusoids (encoder) + RoPE (decoder) instead of
whisper's learned decoder embeddings.
"""

from repro.models.config import EncoderCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper_large_v3",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab=51866,
        act="gelu",
        qkv_bias=True,
        rope_theta=1e4,
        encoder=EncoderCfg(n_layers=32, n_heads=20, n_kv_heads=20, seq_len=1500),
        frontend_dim=1280,
        tie_embeddings=True,
    )
