"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave
[arXiv:2403.19887; hf].

Period-8 pattern MMMAMMMM (attention at position 3 of each 8, as in Jamba),
MoE FFN on every other layer: 36 MoE layers x 16 experts x 3*8192*24576
~ 348B + mamba/attention/dense ~ 50B -> ~398B total.  Sub-quadratic mixer
majority: runs the long_500k cell.
"""

from repro.models.config import ModelConfig, MoECfg, SSMCfg


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba_1_5_large_398b",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab=65536,
        act="silu_gated",
        rope_theta=1e4,
        layer_pattern="MMMAMMMM",
        moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=24576, n_shared=0, every=2),
        ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        tie_embeddings=False,
    )
