"""mamba2-370m [ssm]: 48L d_model=1024, attn-free, ssm_state=128, SSD
(state-space duality) [arXiv:2405.21060; unverified].

Every layer is a Mamba2 mixer (d_ff=0: no separate FFN, matching the Mamba
architecture).  Sub-quadratic: runs the long_500k cell.
"""

from repro.models.config import ModelConfig, SSMCfg


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_370m",
        n_layers=48,
        d_model=1024,
        n_heads=16,       # unused by M blocks; kept for schema completeness
        n_kv_heads=16,
        head_dim=64,
        d_ff=0,
        vocab=50280,
        act="silu_gated",
        layer_pattern="M",
        ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        tie_embeddings=True,
    )
