"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

The vision tower is a STUB per the assignment: ``input_specs()`` supplies
precomputed (B, 1601, 7680) patch embeddings; a learned projection maps them
to d_model.  Cross-attention every 5th layer (8 cross layers in 40).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama_3_2_vision_11b",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=128256,
        act="silu_gated",
        rope_theta=5e5,
        cross_attn_every=5,
        n_frontend_tokens=1601,
        frontend_dim=7680,
        tie_embeddings=False,
    )
