"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 -- 2 shared + 64 routed, fine-grained experts
[arXiv:2401.06066; hf]."""

from repro.models.config import ModelConfig, MoECfg


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_moe_16b",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab=102400,
        act="silu_gated",
        rope_theta=1e4,
        moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2, every=1),
        tie_embeddings=False,
    )
