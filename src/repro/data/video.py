"""Synthetic video stream generator (continuous-learning workload).

Deterministic, seeded streams of smooth moving-object scenes with occasional
*distribution drift* (new object classes appear) — the paper's continuous
learning trigger.  Frames are (H, W, 3) float32 in [0, 1]; each ``VideoStream``
models one camera with its own rate (frames/s) for the placement engine.
"""

from __future__ import annotations

from typing import Iterator, List, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["VideoStream", "make_streams", "render_clip"]


class VideoStream(NamedTuple):
    stream_id: int
    seed: int
    height: int
    width: int
    fps: float  # relative rate -> placement weight
    drift_period: int  # frames between new-class appearances


def make_streams(n: int, height=64, width=64, base_seed=0) -> List[VideoStream]:
    return [
        VideoStream(
            stream_id=i,
            seed=base_seed + 1000 * i,
            height=height,
            width=width,
            fps=float(15 * (1 + (i % 4))),  # heterogeneous rates (Table 2)
            drift_period=64 + 32 * (i % 3),
        )
        for i in range(n)
    ]


def render_clip(stream: VideoStream, t0: int, n_frames: int) -> jnp.ndarray:
    """Render frames [t0, t0 + n_frames) -> (T, H, W, 3).

    Scene: K gaussian blobs orbiting with per-stream phases; after each
    drift_period a new blob with a distinct color signature appears —
    the "new class" the exemplar selector should flag.
    """
    key = jax.random.PRNGKey(stream.seed)
    kx, kc = jax.random.split(key)
    H, W = stream.height, stream.width
    max_blobs = 8
    centers0 = jax.random.uniform(kx, (max_blobs, 2), minval=0.2, maxval=0.8)
    colors = jax.random.uniform(kc, (max_blobs, 3), minval=0.2, maxval=1.0)
    yy, xx = jnp.mgrid[0:H, 0:W]
    yy = yy / H
    xx = xx / W

    ts = t0 + jnp.arange(n_frames)
    n_active = jnp.minimum(2 + ts // stream.drift_period, max_blobs)  # (T,)

    def frame(t, na):
        ang = 2 * jnp.pi * (t / 96.0) + jnp.arange(max_blobs)
        cy = centers0[:, 0] + 0.15 * jnp.sin(ang)
        cx = centers0[:, 1] + 0.15 * jnp.cos(ang)
        active = (jnp.arange(max_blobs) < na).astype(jnp.float32)
        blob = jnp.exp(
            -(((yy[None] - cy[:, None, None]) ** 2 + (xx[None] - cx[:, None, None]) ** 2))
            / 0.01
        ) * active[:, None, None]
        img = jnp.einsum("khw,kc->hwc", blob, colors)
        return jnp.clip(img, 0.0, 1.0)

    return jax.vmap(frame)(ts, n_active)
