"""Synthetic token stream for LM training (deterministic, drift-aware).

Markov-ish token sequences whose transition structure shifts every
``drift_period`` batches — exercises the continuous-learning path for the LM
architectures the same way the video streams do for the codec.
"""

from __future__ import annotations

from typing import Dict, Iterator, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TokenStreamConfig", "sample_batch"]


class TokenStreamConfig(NamedTuple):
    vocab: int
    seq_len: int
    batch: int
    drift_period: int = 100
    n_modes: int = 4  # distinct "domains" cycled by drift


def sample_batch(cfg: TokenStreamConfig, step: int) -> Dict[str, jnp.ndarray]:
    """Deterministic batch for ``step``; labels = next-token shift."""
    mode = (step // cfg.drift_period) % cfg.n_modes
    key = jax.random.PRNGKey(step * 7919 + mode)
    k1, k2 = jax.random.split(key)
    # mode-dependent vocab band + shared band: drift = band migration
    band = cfg.vocab // (cfg.n_modes + 1)
    base = jax.random.randint(
        k1, (cfg.batch, cfg.seq_len), mode * band, (mode + 1) * band
    )
    shared = jax.random.randint(k2, (cfg.batch, cfg.seq_len), cfg.n_modes * band, cfg.vocab)
    pick = jax.random.bernoulli(k2, 0.3, base.shape)
    tokens = jnp.where(pick, shared, base).astype(jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}
