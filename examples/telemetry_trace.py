"""Telemetry in one screen: trace an ``ArchiveIngest`` session to Perfetto.

Enables the process-global ``repro.obs`` tier, pushes four camera GOPs
through the serving ingest engine (codec-encode -> stripe-coalesce ->
fused seal -> catalog), serves one budgeted retrieval plan, then dumps:

  * ``telemetry_trace.json`` — Chrome trace_event JSON; drag it onto
    https://ui.perfetto.dev and the whole stripe lifecycle (ingest.seal,
    archive.seal, retrieval.plan spans + per-edge byte counters) is one
    timeline;
  * ``telemetry_events.jsonl`` — the machine log: one JSON object per
    span, then the metrics snapshot and the byte-flow ledger report.

The ledger report at the end is the paper's data-movement claim computed
from edges alone — no counters hand-wired into the pipeline.

Run:  PYTHONPATH=src python examples/telemetry_trace.py
"""

import jax
import numpy as np

from repro import obs
from repro.core.archival.pipeline import ArchiveConfig
from repro.core.codec.layered_codec import CodecConfig, init_codec
from repro.core.crypto import rlwe
from repro.data.video import VideoStream, render_clip
from repro.obs.export import write_chrome_trace, write_jsonl
from repro.serving.engine import ArchiveIngest, IngestConfig


def main():
    obs.enable(reset=True)  # one switch; off by default everywhere

    ccfg = CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)
    icfg = IngestConfig(
        n_shards=4, archive=ArchiveConfig(codec=ccfg), feature_dim=8
    )
    pub, _ = rlwe.keygen(jax.random.PRNGKey(1))
    ing = ArchiveIngest(init_codec(jax.random.PRNGKey(0), ccfg), pub, icfg)

    rng = np.random.default_rng(0)
    print("== ingest 4 GOPs (one stripe) + 1 retrieval plan ==")
    for sid in range(4):
        frames = render_clip(
            VideoStream(sid, 1000 + sid, 32, 32, 30.0, 64), 0, 2
        )[:, None]
        ing.submit(
            sid, frames,
            feature=rng.normal(0, 1, 8),
            novelty=float(sid == 3),
        )
    ing.flush()
    plan = ing.query(np.zeros((1, 8), np.float32), k=2)
    print(f"plan: {len(plan.reads)} reads, {plan.bytes_planned} B "
          f"(full restore {plan.bytes_full_restore} B)")

    n_ev = write_chrome_trace("telemetry_trace.json", obs.OBS)
    n_ln = write_jsonl("telemetry_events.jsonl", obs.OBS)
    print(f"wrote telemetry_trace.json ({n_ev} events) -> ui.perfetto.dev")
    print(f"wrote telemetry_events.jsonl ({n_ln} records)")

    rep = obs.OBS.ledger.report()
    print("\n== byte-flow ledger (every byte attributed to an edge) ==")
    for edge, rec in rep["edges"].items():
        print(f"  {edge:28s} {rec['bytes']:>10d} B  ({rec['events']} events)")
    for k in ("entropy_ratio", "bytes_moved_ratio", "ingest_volume_ratio"):
        print(f"  {k:28s} {rep[k]:.4f}")
    obs.disable()


if __name__ == "__main__":
    main()
