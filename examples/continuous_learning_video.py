"""End-to-end continuous-learning edge-server driver (the paper's Fig. 1 loop).

Eight camera streams with distribution drift feed the SalientTrainer:
exemplar selection routes novel clips to codec training (Alg. 2) and known
clips to the archival pipeline; a straggling storage shard triggers placement
rebalancing; checkpoints are erasure-coded; the run then simulates a power
loss and restarts from the journal.

Run:  PYTHONPATH=src python examples/continuous_learning_video.py
"""

import shutil
import tempfile

from repro.data.video import make_streams
from repro.train.trainer import SalientTrainer, TrainerConfig


def main():
    workdir = tempfile.mkdtemp(prefix="salient_")
    streams = make_streams(8, height=32, width=32)
    cfg = TrainerConfig(checkpoint_every=3, n_shards=4)
    tr = SalientTrainer(streams, workdir, cfg)
    print(f"== continuous learning: 8 streams, 4 storage shards -> {workdir}")
    print(f"initial placement: {tr.placement.assignment}")

    shard_times = [1.0, 1.0, 1.0, 1.0]
    for step in range(6):
        if step == 3:
            shard_times = [1.0, 6.0, 1.0, 1.0]  # shard 1 starts straggling
            print("-- shard 1 degrades (straggler) --")
        rep = tr.run_step(shard_times=shard_times)
        print(
            f"step {rep.step}: loss={rep.codec_loss:.4f} "
            f"novel->{rep.novel_selected} archived->{rep.archived_streams} "
            f"({rep.archive_bytes}B sealed) psnr={rep.psnr:.1f}dB "
            f"rebalanced={rep.rebalanced}"
        )

    print(f"placement after straggler: {tr.placement.assignment}")
    print("-- simulating power loss: new trainer restores from journal --")
    tr2 = SalientTrainer(streams, workdir, cfg)
    print(f"restored at step {tr2.step} (journal replay, torn writes dropped)")
    rep = tr2.run_step()
    print(f"step {rep.step}: loss={rep.codec_loss:.4f} — resumed cleanly")
    shutil.rmtree(workdir)


if __name__ == "__main__":
    main()
