"""Quickstart: the Salient Store archival pipeline in ~60 lines.

    compress (layered neural codec, motion-vector latent)
      -> encrypt (R-LWE KEM + ChaCha20)
        -> erasure-code (RAID-6 across storage shards)
          -> lose two shards -> rebuild -> decrypt -> decode.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.archival.pipeline import (
    ArchiveConfig,
    archive_gop,
    recover_stripe,
    restore_gop,
    stripe_parity,
)
from repro.core.codec.layered_codec import CodecConfig, init_codec, psnr
from repro.core.crypto import rlwe
from repro.data.video import VideoStream, render_clip


def main():
    cfg = ArchiveConfig(
        codec=CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)
    )
    codec_params = init_codec(jax.random.PRNGKey(0), cfg.codec)
    pub, secret = rlwe.keygen(jax.random.PRNGKey(1))
    print("== Salient Store quickstart ==")

    # four camera streams -> four storage shards (one GOP each)
    blocks = []
    for sid in range(4):
        stream = VideoStream(sid, 1000 * sid, 32, 32, 30.0, 64)
        frames = render_clip(stream, 0, 3)[:, None]  # (T, 1, H, W, 3)
        blk, recons = archive_gop(
            codec_params, pub, frames, jax.random.PRNGKey(10 + sid), cfg
        )
        blocks.append(blk)
        print(
            f"stream {sid}: {frames.size * 4:6d} raw bytes -> "
            f"{blk.sealed.body.size * 4:5d} sealed bytes, "
            f"codec psnr {float(psnr(recons, frames)):.1f} dB (untrained AE)"
        )

    parity = stripe_parity(blocks, "raid6")
    print("RAID-6 parity computed over the stripe")

    # simulate losing two storage shards (paper: intermittent power / pulled disk)
    manifests = [
        {"kem_c1": b.sealed.kem_c1, "kem_c2": b.sealed.kem_c2,
         "nonce": b.sealed.nonce, "manifest": b.manifest}
        for b in blocks
    ]
    lens = [int(b.sealed.body.shape[0]) for b in blocks]
    holes = [None if i in (1, 3) else blocks[i] for i in range(4)]
    print("shards 1 and 3 LOST -> rebuilding from parity ...")
    rebuilt = recover_stripe(holes, parity, [1, 3], manifests, lens)

    for i in (1, 3):
        a = restore_gop(codec_params, secret, rebuilt[i], cfg)
        b = restore_gop(codec_params, secret, blocks[i], cfg)
        assert np.allclose(np.asarray(a), np.asarray(b)), "rebuild mismatch!"
    print("rebuilt shards decrypt + decode identically. done.")


if __name__ == "__main__":
    main()
