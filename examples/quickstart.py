"""Quickstart: the Salient Store archival pipeline in ~60 lines.

    compress (layered neural codec, motion-vector latent)
      -> entropy-code on-device (interleaved rANS, repro.kernels.entropy)
        -> encrypt + erasure-code in ONE fused kernel pass
           (pack + ChaCha20 + XOR-seal + RAID-6 P/Q, repro.kernels.seal)
          -> lose two shards -> rebuild -> decrypt -> decode.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core.archival.pipeline import (
    ArchiveConfig,
    StripeArchive,
    archive_stripe,
    recover_stripe,
    restore_stripe,
    stripe_manifests,
)
from repro.core.codec.layered_codec import CodecConfig, init_codec, psnr
from repro.core.crypto import rlwe
from repro.data.video import VideoStream, render_clip


def main():
    cfg = ArchiveConfig(
        codec=CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)
    )
    codec_params = init_codec(jax.random.PRNGKey(0), cfg.codec)
    pub, secret = rlwe.keygen(jax.random.PRNGKey(1))
    print("== Salient Store quickstart ==")

    # four camera streams -> four storage shards, archived as ONE stripe:
    # a single fused kernel launch packs, seals, and parity-codes all four
    frames_list = []
    for sid in range(4):
        stream = VideoStream(sid, 1000 * sid, 32, 32, 30.0, 64)
        frames_list.append(render_clip(stream, 0, 3)[:, None])  # (T, 1, H, W, 3)

    stripe, recons = archive_stripe(
        codec_params, pub, frames_list, jax.random.PRNGKey(10), cfg
    )
    for sid, (frames, blk, rec) in enumerate(
        zip(frames_list, stripe.blocks, recons)
    ):
        em = blk.manifest["entropy"]
        print(
            f"stream {sid}: {frames.size * 4:6d} raw bytes -> "
            f"{em['n_raw']:5d} codes -{em['codec']}-> {em['n_comp']:5d} -> "
            f"{blk.sealed.body.size * 4:5d} sealed bytes, "
            f"codec psnr {float(psnr(rec, frames)):.1f} dB (untrained AE)"
        )
    print("entropy stage ran on-device; RAID-6 parity in the same seal pass")

    # simulate losing two storage shards (paper: intermittent power / pulled disk)
    manifests = stripe_manifests(stripe)
    lens = [int(b.sealed.body.shape[0]) for b in stripe.blocks]
    holes = [None if i in (1, 3) else stripe.blocks[i] for i in range(4)]
    print("shards 1 and 3 LOST -> rebuilding from parity ...")
    rebuilt = recover_stripe(holes, stripe.parity, [1, 3], manifests, lens)

    # fused unseal also re-verifies parity against the stored P/Q
    a = restore_stripe(
        codec_params, secret, StripeArchive(rebuilt, stripe.parity), cfg
    )
    b = restore_stripe(codec_params, secret, stripe, cfg)
    for i in (1, 3):
        assert np.allclose(np.asarray(a[i]), np.asarray(b[i])), "rebuild mismatch!"
    print("rebuilt shards decrypt + decode identically. done.")


if __name__ == "__main__":
    main()
