"""Serve a small model with batched requests through the slot engine.

Demonstrates: prefill -> continuous batched decode with KV/SSM caches for any
assigned architecture family (attention, SSM, hybrid, enc-dec, VLM).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch jamba_1_5_large_398b
      (the reduced family-preserving config, not the 398B weights!)
"""

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=6)
    args = ap.parse_args()

    import jax

    from repro.models.registry import get_smoke_config
    from repro.models.transformer import init_model
    from repro.serving.engine import Request, ServeConfig, ServingEngine

    cfg = get_smoke_config(args.arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    frontend = None
    if cfg.encoder is not None or cfg.n_frontend_tokens:
        n = cfg.encoder.seq_len if cfg.encoder else cfg.n_frontend_tokens
        frontend = jax.random.normal(
            jax.random.PRNGKey(1), (4, n, cfg.frontend_dim or cfg.d_model)
        )
    eng = ServingEngine(params, cfg, ServeConfig(max_batch=4, max_len=64), frontend)
    print(f"== serving {args.arch} (reduced config): "
          f"{args.requests} requests, batch slots=4 ==")
    t0 = time.time()
    for r in range(args.requests):
        eng.submit(Request(rid=r, prompt=[10 + r, 20 + r, 30 + r], max_new=args.max_new))
    out = eng.run_to_completion()
    dt = time.time() - t0
    total_toks = sum(len(v) for v in out.values())
    for rid, toks in sorted(out.items()):
        print(f"request {rid}: {toks}")
    print(f"{total_toks} tokens in {dt:.1f}s ({total_toks/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
