"""Train an LM with the full runtime stack: any assigned arch (reduced scale),
drift-aware token stream, optional codec-based gradient compression, and
erasure-coded checkpoints.

Default: a ~20M-param qwen2-family model for 30 steps (CPU-friendly).
The ~100M/300-step configuration from the deliverable spec:

  PYTHONPATH=src python examples/train_lm.py --arch qwen2_0_5b \\
      --d-model 512 --n-layers 8 --steps 300 --batch 8 --seq 256

Run (quick):  PYTHONPATH=src python examples/train_lm.py
"""

import argparse
import functools
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--grad-compress", type=int, default=2)
    ap.add_argument("--workdir", default="results/train_lm")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.data.tokens import TokenStreamConfig, sample_batch
    from repro.distributed.steps import StepConfig, loss_fn
    from repro.models.registry import get_config
    from repro.models.transformer import init_model
    from repro.train.checkpoint import save_checkpoint
    from repro.train.grad_compress import GradCompressConfig, compress_tree, init_state
    from repro.train.optimizer import adamw_init, adamw_update

    base = get_config(args.arch)
    period = base.period
    n_layers = max(args.n_layers // period, 1) * period
    cfg = base._replace(
        n_layers=n_layers,
        d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 128, 1),
        head_dim=64,
        d_ff=args.d_model * 4 if base.d_ff else 0,
        vocab=args.vocab,
        moe=base.moe._replace(n_experts=8, d_ff_expert=args.d_model) if base.moe else None,
        encoder=base.encoder._replace(n_layers=2, n_heads=4, n_kv_heads=4, seq_len=16)
        if base.encoder
        else None,
        n_frontend_tokens=min(base.n_frontend_tokens, 16),
        frontend_dim=64 if base.frontend_dim else 0,
        dtype="float32",
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"== train_lm: {cfg.name} family, {n_params/1e6:.1f}M params, "
          f"{args.steps} steps ==")

    scfg = StepConfig(remat=False, q_chunk=0)
    opt_state = adamw_init(params, scfg.opt)
    gc_cfg = GradCompressConfig(n_layers=args.grad_compress)
    gc_state = init_state(params) if args.grad_compress else None
    ts = TokenStreamConfig(cfg.vocab, args.seq, args.batch, drift_period=10)

    grad_fn = jax.jit(
        jax.value_and_grad(
            functools.partial(loss_fn, cfg=cfg, scfg=scfg), has_aux=True
        ),
        static_argnames=(),
    )

    def frontend(step):
        if cfg.encoder is None and not cfg.n_frontend_tokens:
            return None
        n = cfg.encoder.seq_len if cfg.encoder else cfg.n_frontend_tokens
        return jax.random.normal(
            jax.random.PRNGKey(step), (args.batch, n, cfg.frontend_dim or cfg.d_model)
        )

    t0 = time.time()
    wire = raw = 0
    for step in range(args.steps):
        batch = sample_batch(ts, step)
        (loss, metrics), grads = grad_fn(
            params, tokens=batch["tokens"], labels=batch["labels"],
            frontend=frontend(step),
        )
        if gc_state is not None:
            grads, gc_state, w, r = compress_tree(grads, gc_state, gc_cfg)
            wire += int(w)
            raw += int(r)
        params, opt_state = adamw_update(params, grads, opt_state, scfg.opt)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}: loss={float(loss):.4f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
    if raw:
        print(f"gradient compression: {raw/1e6:.1f}MB -> {wire/1e6:.1f}MB "
              f"({raw/max(wire,1):.1f}x) on the cross-pod hop")
    save_checkpoint(args.workdir, args.steps, {"params": params}, parity="raid6")
    print(f"erasure-coded checkpoint -> {args.workdir}")


if __name__ == "__main__":
    main()
