"""Telemetry tier tests: histogram accuracy, snapshot windowing, the
disabled fast path, byte-ledger conservation across the stripe lifecycle,
registry-backed engine stats, and the trainer-level acceptance loop
(Perfetto trace + ledger report whose ratios recompute from edges alone).
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.core.archival.pipeline import (
    ArchiveConfig,
    restore_stripe_payloads,
    seal_payload_stripe,
    stripe_manifests,
)
from repro.core.archival.scrub import StripeScrubber
from repro.core.crypto import rlwe
from repro.obs import (
    EDGE_DEVICE_TO_JOURNAL,
    EDGE_ENTROPY_COMP,
    EDGE_ENTROPY_RAW,
    EDGE_HOST_TO_DEVICE,
    EDGE_REPLAY_FULL_BASELINE,
    EDGE_REPLAY_PARITY,
    EDGE_REPLAY_PLANNED,
    EDGE_REPLAY_READ,
    EDGE_SCRUB_READ,
    EDGE_SCRUB_SYNDROME,
    EDGE_SHARD_TO_PARITY,
    OBS,
    Metrics,
)
from repro.obs import names as obs_names


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts disabled with empty instruments and leaves the
    process-global singleton the same way (other test files rely on the
    off-by-default contract)."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


def _stripe(seed=3, n=8 * 1024, S=4, cfg=None):
    rng = np.random.default_rng(seed)
    cfg = cfg or ArchiveConfig()
    pub, sec = rlwe.keygen(jax.random.PRNGKey(seed + 1))
    flats = [
        jnp.asarray(
            np.clip(np.round(rng.normal(0, 2.0, n)), -128, 127), jnp.int8
        )
        for _ in range(S)
    ]
    mans = [{"n_i8": int(f.shape[0]), "spec": []} for f in flats]
    stripe = seal_payload_stripe(
        pub, flats, mans, jax.random.PRNGKey(seed + 2), cfg
    )
    return stripe, flats, sec, cfg


def _body_bytes(stripe, shards):
    return sum(
        4 * int(stripe.blocks[i].sealed.n_valid_u32)
        for i in shards
        if stripe.blocks[i] is not None
    )


# ----------------------------------------------------------- histograms
def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=5.0, sigma=2.0, size=20_000)
    m = Metrics()
    for x in samples:
        m.observe("lat", float(x))
    for q, key in ((50, "p50"), (95, "p95"), (99, "p99")):
        ref = float(np.percentile(samples, q))
        got = m.histogram("lat").summary()[key]
        # fixed geometric buckets (growth 2**0.125 => <=~9% bucket error)
        assert got == pytest.approx(ref, rel=0.12), (q, got, ref)
    s = m.histogram("lat").summary()
    assert s["count"] == samples.size
    assert s["min"] == pytest.approx(samples.min())
    assert s["max"] == pytest.approx(samples.max())
    assert s["sum"] == pytest.approx(samples.sum(), rel=1e-6)


def test_histogram_percentiles_clamped_to_observed_range():
    m = Metrics()
    m.observe("one", 42.0)
    s = m.histogram("one").summary()
    assert s["p50"] == s["p99"] == pytest.approx(42.0)


# ---------------------------------------------------- snapshot windowing
def test_snapshot_reset_windowing():
    m = Metrics()
    m.add("c", 5)
    m.set_gauge("g", 7.0)
    m.observe("h", 10.0)
    m.observe("h", 20.0)

    snap = m.snapshot(reset=True)  # window 1: read-and-zero
    assert snap["c"] == 5
    assert snap["g"] == 7.0
    assert snap["h"]["count"] == 2

    m.add("c", 2)
    snap2 = m.snapshot(reset=True)  # window 2 holds ONLY window-2 traffic
    assert snap2["c"] == 2
    assert snap2["h"]["count"] == 0
    assert snap2["g"] == 7.0  # gauges are levels, not flows: they persist

    assert m.snapshot()["c"] == 0  # plain snapshot does not consume


def test_engine_style_snapshot_delegates(tmp_path):
    # ArchiveIngest.snapshot(reset=...) is a thin view of its registry
    from repro.serving.engine import ArchiveIngest  # noqa: F401  (API exists)

    assert hasattr(ArchiveIngest, "snapshot")


# -------------------------------------------------- disabled fast path
def test_disabled_mode_records_nothing():
    assert not OBS.enabled
    stripe, flats, sec, cfg = _stripe(seed=11)
    scrubber = StripeScrubber({"s": stripe}.__getitem__, lambda k, v: None)
    scrubber.scrub_round(["s"], 1 << 30)
    restore_stripe_payloads(sec, stripe, cfg)
    assert OBS.tracer.events == []
    assert OBS.tracer.dropped == 0
    assert OBS.ledger.totals() == {}
    assert OBS.metrics.snapshot() == {}


def test_disabled_span_is_shared_null():
    sp = OBS.span("x", a=1)
    assert sp is OBS.span("y")  # one shared NullSpan, zero allocation


# ------------------------------------------------- ledger conservation
def test_ledger_conservation_seal_scrub_restore():
    with obs.enabled():
        stripe, flats, sec, cfg = _stripe(seed=5)
        S = len(stripe.blocks)
        led = OBS.ledger

        # ingest: journal edge == the sealed bodies, byte for byte
        d2j = _body_bytes(stripe, range(S))
        assert led.bytes(EDGE_DEVICE_TO_JOURNAL) == d2j
        assert led.bytes(EDGE_HOST_TO_DEVICE) == sum(
            int(f.shape[0]) for f in flats
        )
        # rans actually ran: raw == host payload bytes, comp is smaller
        assert led.bytes(EDGE_ENTROPY_RAW) == led.bytes(EDGE_HOST_TO_DEVICE)
        assert 0 < led.bytes(EDGE_ENTROPY_COMP) < led.bytes(EDGE_ENTROPY_RAW)
        par = int(stripe.parity["p"].size) + int(stripe.parity["q"].size)
        assert led.bytes(EDGE_SHARD_TO_PARITY) == par

        # scrub: the round's own accounting and the ledger agree exactly
        store = {"s": stripe}
        scrubber = StripeScrubber(store.__getitem__, store.__setitem__)
        sr = scrubber.scrub_round(["s"], 1 << 30)
        assert led.bytes(EDGE_SCRUB_READ) == sr.bytes_scrubbed == d2j
        assert led.bytes(EDGE_SCRUB_SYNDROME) == sr.syndrome_bytes == par

        # full restore: replay.read == every sealed body == journal edge
        restore_stripe_payloads(sec, stripe, cfg)
        assert led.bytes(EDGE_REPLAY_READ) == d2j
        assert led.bytes(EDGE_REPLAY_PARITY) == 0

        # degraded subset read: wanted [1, 2] with shard 1 lost.  The
        # present wanted body bills replay.read; the rebuild's extra
        # traffic (surviving peers OUTSIDE the subset + both parity
        # strips) bills replay.parity — nothing is double-billed.
        led.reset()
        mans = stripe_manifests(stripe)
        holes = list(stripe.blocks)
        holes[1] = None
        broken = stripe._replace(blocks=holes)
        out, _ = restore_stripe_payloads(
            sec, broken, cfg, shards=[1, 2], manifests=mans
        )
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(flats[1]))
        assert led.bytes(EDGE_REPLAY_READ) == _body_bytes(broken, [1, 2])
        assert led.bytes(EDGE_REPLAY_PARITY) == (
            _body_bytes(broken, [0, 3]) + par
        )
        assert led.events(EDGE_REPLAY_PARITY) == 1  # one degraded shard
    assert not OBS.enabled  # context restored the prior flag


def test_ledger_report_ratios_recompute_from_edges():
    with obs.enabled():
        stripe, flats, sec, cfg = _stripe(seed=7)
        restore_stripe_payloads(sec, stripe, cfg)
        led = OBS.ledger
        rep = led.report()
        assert rep["entropy_ratio"] == pytest.approx(
            led.bytes(EDGE_ENTROPY_RAW) / led.bytes(EDGE_ENTROPY_COMP)
        )
        assert rep["ingest_volume_ratio"] == pytest.approx(
            led.bytes(EDGE_DEVICE_TO_JOURNAL) / led.bytes(EDGE_HOST_TO_DEVICE)
        )
        # no plan ran -> the planned-vs-baseline ratios are honest NaNs
        assert np.isnan(rep["bytes_moved_ratio"])
        for e, rec in rep["edges"].items():
            assert rec["bytes"] == led.bytes(e)
            assert rec["events"] == led.events(e)


# ------------------------------------------------------- engine registry
def test_engine_stats_are_registry_views(tmp_path):
    from repro.core.codec.layered_codec import CodecConfig, init_codec
    from repro.core.csd.failure import Journal
    from repro.data.video import VideoStream, render_clip
    from repro.serving.engine import ArchiveIngest, IngestConfig

    ccfg = CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)
    codec_params = init_codec(jax.random.PRNGKey(0), ccfg)
    pub, _ = rlwe.keygen(jax.random.PRNGKey(1))
    icfg = IngestConfig(
        n_shards=2, archive=ArchiveConfig(codec=ccfg), feature_dim=4
    )
    ing = ArchiveIngest(
        codec_params, pub, icfg, journal=Journal(str(tmp_path))
    )

    def _frames(i):
        return render_clip(
            VideoStream(i, 300 + i, 32, 32, 30.0, 64), 0, 2
        )[:, None]

    for i in range(4):
        ing.submit(i, _frames(i), feature=np.zeros(4), novelty=0.5)
    ing.flush()
    ing.query(np.zeros((1, 4), np.float32), k=1)

    s = ing.stats()
    snap = ing.snapshot()
    # stats() and the coalescer's stats() are views over ONE registry
    assert s["catalog_gops"] == snap[obs_names.CAT_GOPS] == 4
    assert s["plans_served"] == snap[obs_names.RETR_PLANS] == 1
    assert (
        ing.coalescer.stats()["n_gops"] == snap[obs_names.ING_GOPS] == 4
    )
    assert s["entropy_ratio"] == pytest.approx(
        snap[obs_names.ING_ENTROPY_RAW] / snap[obs_names.ING_ENTROPY_COMP]
    )
    # submit->commit latency histogram saw every sealed GOP
    assert snap[obs_names.ING_GOP_LATENCY_US]["count"] == 4
    assert snap[obs_names.ING_GOP_LATENCY_US]["p50"] > 0

    # windowed read: second window only carries new traffic
    ing.snapshot(reset=True)
    assert ing.snapshot()[obs_names.RETR_PLANS] == 0
    assert ing.snapshot()[obs_names.CAT_GOPS] == 4  # gauge: still the level
    ing.query(np.zeros((1, 4), np.float32), k=1)
    assert ing.snapshot()[obs_names.RETR_PLANS] == 1
    assert ing.stats()["catalog_gops"] == 4  # stats() unharmed by windows


# -------------------------------------------------- trainer acceptance
def test_trainer_telemetry_trace_and_ledger(tmp_path):
    from repro.data.video import make_streams
    from repro.train.trainer import SalientTrainer, TrainerConfig

    cfg = TrainerConfig(
        n_shards=2,
        checkpoint_every=2,
        replay_every=2,
        scrub_every=2,
        telemetry=True,
    )
    streams = make_streams(4, height=32, width=32)
    tr = SalientTrainer(streams, str(tmp_path), cfg)
    reports = [tr.run_step(shard_times=[1.0, 1.0]) for _ in range(4)]

    # every step carries a telemetry snapshot with stage timings
    for rep in reports:
        assert rep.telemetry is not None
        assert rep.telemetry["stages"].get("trainer.step", 0) > 0
        assert "archive.seal" in rep.telemetry["stages"]

    led = OBS.ledger
    rep = led.report()
    # the paper ratios recompute from ledger edges alone (within 1%)
    assert rep["entropy_ratio"] == pytest.approx(
        led.bytes(EDGE_ENTROPY_RAW) / led.bytes(EDGE_ENTROPY_COMP), rel=0.01
    )
    assert rep["bytes_moved_ratio"] == pytest.approx(
        led.bytes(EDGE_REPLAY_PLANNED) / led.bytes(EDGE_REPLAY_FULL_BASELINE),
        rel=0.01,
    )
    # ...and agree with the trainer's own per-step accounting (within 1%)
    planned = sum(r.replay_read_bytes for r in reports)
    baseline = sum(r.replay_full_bytes for r in reports)
    assert led.bytes(EDGE_REPLAY_PLANNED) == pytest.approx(planned, rel=0.01)
    assert led.bytes(EDGE_REPLAY_FULL_BASELINE) == pytest.approx(
        baseline, rel=0.01
    )
    moved = led.bytes(EDGE_REPLAY_READ) + led.bytes(EDGE_REPLAY_PARITY)
    assert moved == pytest.approx(planned, rel=0.01)

    # exporters: Perfetto-loadable Chrome trace + journaled JSONL log
    paths = tr.export_telemetry()
    with open(paths["trace"]) as f:
        trace = json.load(f)
    evs = trace["traceEvents"]
    assert any(
        e.get("ph") == "X" and e.get("name") == "trainer.step" for e in evs
    )
    assert any(
        e.get("ph") == "C" and e["name"].endswith(EDGE_DEVICE_TO_JOURNAL)
        for e in evs
    )
    assert all("ts" in e for e in evs if e.get("ph") == "X")
    assert os.path.exists(paths["jsonl"])
    with open(paths["jsonl"]) as f:
        kinds = [json.loads(ln)["kind"] for ln in f if ln.strip()]
    assert "span" in kinds and "metrics" in kinds and "ledger" in kinds
