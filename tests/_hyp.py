"""Optional-dependency shim for hypothesis.

``hypothesis`` is a dev extra (see pyproject.toml), not a runtime dependency.
When it is absent, property-based tests degrade to individual skips instead
of failing the whole module at collection time — the rest of the suite still
runs green.  Usage in test modules::

    from _hyp import given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass  # zero-arg stub: strategy params must not look like fixtures

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Accepts any strategy expression; values are never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
