"""Sharded seal pipeline tests: shard_map bit-identity over mesh shapes,
multi-stream ingest coalescing, checkpoint parity through the fused kernel.

Mesh-shape cases beyond the host's device count skip; run the suite under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI multi-device
job does) to exercise all of {1, 2, 4, 8}.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core.archival.pipeline import (
    ArchiveConfig,
    StripeArchive,
    archive_stripe,
    restore_stripe,
)
from repro.core.codec.layered_codec import CodecConfig, init_codec
from repro.core.crypto import rlwe
from repro.distributed.archival import (
    StripeCoalescer,
    archive_stripe_sharded,
    restore_stripe_sharded,
    seal_coalesced_stripe,
    seal_stripe_sharded,
    unseal_stripe_sharded,
)
from repro.kernels.seal import ops as sops
from repro.kernels.seal.seal import R_TILE

CFG = CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)
MESH_SIZES = [1, 2, 4, 8]


def _mesh(d: int) -> Mesh:
    if jax.device_count() < d:
        pytest.skip(
            f"need {d} devices, have {jax.device_count()} "
            "(run with XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
    return Mesh(np.array(jax.devices()[:d]), ("data",))


def _stripe_inputs(seed, lens):
    rng = np.random.default_rng(seed)
    S = len(lens)
    payloads = [jnp.asarray(rng.integers(-128, 128, n), jnp.int8) for n in lens]
    keys = jnp.asarray(rng.integers(0, 2**32, (S, 8), dtype=np.uint32))
    nonces = jnp.asarray(rng.integers(0, 2**32, (S, 3), dtype=np.uint32))
    return payloads, keys, nonces


def _eq(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------- sharded vs single-device
@pytest.mark.parametrize("d", MESH_SIZES)
@pytest.mark.parametrize("parity", ["raid6", "raid5", "none"])
def test_sharded_bit_identical_to_single_device(d, parity):
    """Acceptance: sealed bodies, P and Q match the single-device kernel
    bit-for-bit on every mesh shape."""
    mesh = _mesh(d)
    payloads, keys, nonces = _stripe_inputs(d, [5000, 4093, 4096, 2500,
                                                100, 7000, 512, 4095])
    single = sops.seal_stripe(payloads, keys, nonces, parity=parity)
    sharded = seal_stripe_sharded(
        payloads, keys, nonces, mesh=mesh, parity=parity
    )
    assert _eq(sharded.sealed, single.sealed)
    assert sharded.n_words == single.n_words
    if parity != "none":
        assert _eq(sharded.p, single.p)
    if parity == "raid6":
        assert _eq(sharded.q, single.q)


@pytest.mark.parametrize("d", MESH_SIZES)
def test_sharded_unseal_roundtrip_and_parity_recompute(d):
    mesh = _mesh(d)
    payloads, keys, nonces = _stripe_inputs(20 + d, [3000, 47, 4096, 900,
                                                     1, 2048, 5000, 64])
    stripe = seal_stripe_sharded(payloads, keys, nonces, mesh=mesh)
    back, p2, q2 = unseal_stripe_sharded(stripe, keys, nonces, mesh=mesh)
    for got, want in zip(back, payloads):
        assert _eq(got, want)
    # parity recomputed from stored bodies must match seal-time parity
    assert _eq(p2, stripe.p)
    assert _eq(q2, stripe.q)


@pytest.mark.parametrize("d,s", [(2, 3), (4, 5), (8, 3)])
def test_sharded_pads_non_divisible_shard_counts(d, s):
    """S % D != 0: dummy zero shards may not perturb bodies or parity."""
    mesh = _mesh(d)
    payloads, keys, nonces = _stripe_inputs(s, [1000 + 37 * i for i in range(s)])
    single = sops.seal_stripe(payloads, keys, nonces)
    sharded = seal_stripe_sharded(payloads, keys, nonces, mesh=mesh)
    assert _eq(sharded.sealed, single.sealed)
    assert _eq(sharded.p, single.p)
    assert _eq(sharded.q, single.q)


@pytest.mark.parametrize("d", [1, 2, 4])
def test_archive_stripe_sharded_end_to_end(d):
    """Acceptance: archive_stripe_sharded outputs (bodies, P, Q, manifests)
    bit-identical to single-device archive_stripe; sharded restore decodes."""
    mesh = _mesh(d)
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, secret = rlwe.keygen(jax.random.PRNGKey(1))
    frames = [
        jnp.clip(jax.random.uniform(jax.random.PRNGKey(60 + i),
                                    (3, 1, 32, 32, 3)), 0.0, 1.0)
        for i in range(4)
    ]
    key = jax.random.PRNGKey(7)
    sharded, rec_s = archive_stripe_sharded(
        codec_params, pub, frames, key, cfg, mesh=mesh
    )
    plain, _ = archive_stripe(codec_params, pub, frames, key, cfg)
    for bs, bp in zip(sharded.blocks, plain.blocks):
        assert _eq(bs.sealed.body, bp.sealed.body)
        assert bs.manifest == bp.manifest
    assert _eq(sharded.parity["p"], plain.parity["p"])
    assert _eq(sharded.parity["q"], plain.parity["q"])
    # sharded restore (with the cross-shard parity check) decodes
    out = restore_stripe_sharded(codec_params, secret, sharded, cfg, mesh=mesh)
    for got, want in zip(out, rec_s):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ------------------------------------------------------- ingest coalescing
def test_bucket_rows_pow2():
    assert sops.bucket_rows_for(1) == R_TILE
    assert sops.bucket_rows_for(R_TILE * 128) == R_TILE
    assert sops.bucket_rows_for(R_TILE * 128 + 1) == 2 * R_TILE
    assert sops.bucket_rows_for(3 * R_TILE * 128) == 4 * R_TILE
    for n in (1, 100, 5000, 12345, 99999):
        r = sops.bucket_rows_for(n)
        assert r >= sops.pad_rows_for(n) and r % R_TILE == 0
        assert (r // R_TILE) & (r // R_TILE - 1) == 0  # pow2 tile count


def test_coalescer_emits_full_stripes_and_bounds_traces():
    rng = np.random.default_rng(0)
    coal = StripeCoalescer(n_shards=4)
    # 16 ragged GOPs from 3 interleaved streams, sizes within one pow2 bucket
    lens = [int(rng.integers(8 * 512 * 2 + 4, 8 * 512 * 4)) for _ in range(16)]
    stripes = []
    for i, n in enumerate(lens):
        payload = jnp.asarray(rng.integers(-128, 128, n), jnp.int8)
        stripes += coal.add(i % 3, payload, {"i": i})
    assert len(stripes) == 4  # 16 GOPs / 4 shards, single bucket
    assert coal.n_pending == 0
    assert len({cs.pad_rows for cs in stripes}) == 1  # one trace bucket
    st = coal.stats()
    assert st["launch_reduction"] == 4.0  # >= 4x for the ragged workload


def test_coalescer_mixed_sizes_roundtrip():
    """Mixed GOP sizes + stream interleaving: every payload survives the
    coalesce -> seal -> unseal roundtrip bit-exactly."""
    rng = np.random.default_rng(1)
    coal = StripeCoalescer(n_shards=3)
    gops = {}
    stripes = []
    for i in range(11):  # mixed buckets: tiny, medium, large
        n = int(rng.integers(1, 4 * 8 * 512))
        payload = jnp.asarray(rng.integers(-128, 128, n), jnp.int8)
        gops[i] = payload
        stripes += coal.add(i % 5, payload, {"gop": i})
    stripes += coal.flush()  # leftovers, possibly short stripes
    assert coal.n_pending == 0
    seen = set()
    for cs in stripes:
        S = len(cs.gops)
        keys = jnp.asarray(rng.integers(0, 2**32, (S, 8), dtype=np.uint32))
        nonces = jnp.asarray(rng.integers(0, 2**32, (S, 3), dtype=np.uint32))
        stripe = sops.seal_stripe(
            [g.payload for g in cs.gops], keys, nonces, pad_rows=cs.pad_rows
        )
        assert stripe.sealed.shape[1] == cs.pad_rows
        back, _, _ = unseal_stripe_sharded(
            stripe, keys, nonces, mesh=_mesh(1)
        )
        for g, got in zip(cs.gops, back):
            assert _eq(got, gops[g.manifest["gop"]])
            seen.add(g.manifest["gop"])
    assert seen == set(gops)  # nothing stranded, nothing duplicated


def test_seal_coalesced_stripe_matches_plain_archive():
    """Coalesced seal (with pow2 pad_rows) decodes through the standard
    restore path, parity verification included."""
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, secret = rlwe.keygen(jax.random.PRNGKey(1))
    from repro.core.archival.pipeline import encode_gop_payload

    coal = StripeCoalescer(n_shards=2)
    frames, stripes = [], []
    for i in range(2):
        f = jnp.clip(
            jax.random.uniform(jax.random.PRNGKey(80 + i), (3, 1, 32, 32, 3)),
            0.0, 1.0,
        )
        frames.append(f)
        flat, manifest, _ = encode_gop_payload(codec_params, f, cfg)
        stripes += coal.add(i, flat, manifest)
    assert len(stripes) == 1
    archive = seal_coalesced_stripe(
        pub, stripes[0], jax.random.PRNGKey(9), cfg
    )
    out = restore_stripe(codec_params, secret, archive, cfg)
    assert len(out) == 2
    for o, f in enumerate(out):
        assert np.asarray(f).shape == frames[o].shape


# ------------------------------------------------------ error-path parity
def test_restore_stripe_empty_raises_clear_valueerror():
    """Both dispatch paths reject an empty stripe with the same message the
    seal path uses (was: bare max()/IndexError from the staged path)."""
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    secret = jnp.zeros((1, 256), jnp.int32)
    for use_pallas in (True, False):
        with pytest.raises(ValueError, match="at least one shard"):
            restore_stripe(
                codec_params, secret, StripeArchive([], None), cfg,
                use_pallas=use_pallas,
            )
    with pytest.raises(ValueError, match="at least one shard"):
        sops.unseal_stripe(
            sops.SealedStripe(jnp.zeros((0, 8, 128), jnp.uint32), None, None,
                              (), ()),
            jnp.zeros((0, 8), jnp.uint32),
            jnp.zeros((0, 3), jnp.uint32),
        )


# --------------------------------------------- checkpoint via fused kernel
def test_checkpoint_two_shard_loss_through_fused_parity(tmp_path):
    """Sealed checkpoint -> lose 2 of 5 shards -> RAID-6 rebuild over the
    sealed bodies -> one fused unseal (KEM-decapsulated keys) -> bit-exact."""
    from repro.train.checkpoint import load_checkpoint, save_checkpoint

    pub, secret = rlwe.keygen(jax.random.PRNGKey(0))
    state = {
        "w": jax.random.normal(jax.random.PRNGKey(1), (64, 32)),
        "n": jnp.arange(1000, dtype=jnp.int32),
    }
    meta = save_checkpoint(
        str(tmp_path), 11, state, n_shards=5, parity="raid6", seal_key=pub
    )
    import os

    os.remove(os.path.join(tmp_path, meta["shards"][0]))
    with open(os.path.join(tmp_path, meta["shards"][4]), "wb") as f:
        f.write(b"torn")  # wrong size -> treated as lost
    step, loaded = load_checkpoint(str(tmp_path), state, secret=secret)
    assert step == 11
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_silent_body_corruption(tmp_path):
    """A flipped byte that keeps the file size intact must fail the
    recompute-and-compare parity check, not silently decode garbage."""
    from repro.train.checkpoint import CheckpointError, load_checkpoint, save_checkpoint

    state = {"w": jnp.arange(4096, dtype=jnp.float32)}
    meta = save_checkpoint(str(tmp_path), 3, state, n_shards=4)
    import os

    path = os.path.join(tmp_path, meta["shards"][2])
    blob = bytearray(open(path, "rb").read())
    blob[7] ^= 0x40
    with open(path, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(CheckpointError, match="parity mismatch"):
        load_checkpoint(str(tmp_path), state)


# ------------------------------------------------------------ ingest tiers
def test_trainer_coalesces_and_drains_on_checkpoint(tmp_path):
    """Trainer ingest: encoded GOPs wait for stripe-mates; checkpoint()
    drains them so a restart never strands pending archives."""
    from repro.data.video import make_streams
    from repro.train.trainer import SalientTrainer, TrainerConfig

    streams = make_streams(4, height=32, width=32)
    tr = SalientTrainer(
        streams, str(tmp_path), TrainerConfig(checkpoint_every=3, n_shards=4)
    )
    sealed, pending_seen = 0, 0
    for _ in range(3):
        rep = tr.run_step()
        sealed += rep.archived_streams
        pending_seen = max(pending_seen, rep.pending_gops)
    # checkpoint at step 3 flushed the coalescer
    assert tr.coalescer.n_pending == 0
    journal_names = [r["name"] for r in tr.journal.replay()]
    n_stripe_recs = sum(
        1 for n in journal_names
        if n.startswith("archive_") and n.endswith(".bin")
        and ".parity" not in n
    )
    assert n_stripe_recs == tr.coalescer.stats()["n_stripes"]
    # restart restores cleanly with the coalescer empty, and resumes the
    # stripe sequence past the committed records (no journal overwrite, no
    # key/nonce reuse for post-restart stripes)
    tr2 = SalientTrainer(
        streams, str(tmp_path), TrainerConfig(checkpoint_every=3, n_shards=4)
    )
    assert tr2.step == 3
    assert tr2.coalescer.n_pending == 0
    assert tr2._stripe_seq == n_stripe_recs


def test_archive_ingest_engine_multi_stream():
    """Serving-tier ingest: 8 streams x ragged GOPs -> stripes of 4, one
    fused launch each; flush() drains the tail."""
    from repro.serving.engine import ArchiveIngest, IngestConfig

    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, secret = rlwe.keygen(jax.random.PRNGKey(1))
    ing = ArchiveIngest(
        codec_params, pub, IngestConfig(n_shards=4, archive=cfg)
    )
    done = []
    for i in range(6):
        f = jnp.clip(
            jax.random.uniform(jax.random.PRNGKey(90 + i), (2, 1, 32, 32, 3)),
            0.0, 1.0,
        )
        done += ing.submit(stream_id=i % 8, frames=f)
    assert len(done) == 1 and len(done[0].blocks) == 4
    tail = ing.flush()
    assert len(tail) == 1 and len(tail[0].blocks) == 2
    assert ing.stats()["n_pending"] == 0
    # stripes decode through the standard restore path
    out = restore_stripe(codec_params, secret, done[0], cfg)
    assert len(out) == 4
