"""Source-level hygiene guards for kernel/ref pairs.

Float division by a *constant* is banned in kernel-adjacent code: under jit
XLA canonicalizes ``x / c`` to ``x * (1/c)``, which differs by up to 1 ULP
from a true divide, so a kernel and its reference can disagree on
round-half cases and break the bit-exact tests (the quantize kernel hit
exactly this; it now multiplies by an explicit reciprocal).  Audit result
as of the entropy-subsystem PR: motion, polymul, seal, entropy and the
kernel-callable ChaCha core are integer-only; quantize carries the
reciprocal-multiply fix.  This test keeps it that way.
"""

import ast
import io
import os
import token
import tokenize

import pytest

import repro.kernels as _k
from repro.core.crypto import chacha as _chacha

KERNEL_ROOT = os.path.dirname(_k.__file__)


def _kernel_sources():
    files = [_chacha.__file__]  # kernel-callable ChaCha core
    for dirpath, _, names in os.walk(KERNEL_ROOT):
        files += [
            os.path.join(dirpath, n) for n in names if n.endswith(".py")
        ]
    return sorted(files)


def _float_const_divisions(source: str):
    """Yield (line, text) for each ``<array-ish> / <float literal>``.

    Token-based so docstrings/comments can't false-positive.  A literal
    numerator (``1.0 / 127.0``) is allowed: Python folds it to one exact
    constant before tracing, no XLA rewrite involved.  ``x / traced`` is
    allowed: both sides of a kernel/ref pair trace the same divide op.
    """
    toks = [
        t
        for t in tokenize.generate_tokens(io.StringIO(source).readline)
        if t.type not in (token.NL, token.NEWLINE, token.INDENT, token.DEDENT,
                          token.COMMENT)
    ]
    for i, t in enumerate(toks):
        if t.type != token.OP or t.string != "/" or not (0 < i < len(toks) - 1):
            continue
        prev, nxt = toks[i - 1], toks[i + 1]
        # any numeric literal divisor: jnp's `/` is true division even for
        # int literals, so `x / 127` hits the same reciprocal rewrite as
        # `x / 127.0` (`//` tokenizes as its own operator and is exempt)
        numerator_arrayish = (
            prev.type == token.NAME
            or (prev.type == token.OP and prev.string in (")", "]"))
        )
        if nxt.type == token.NUMBER and numerator_arrayish:
            yield t.start[0], t.line.strip()


@pytest.mark.parametrize("path", _kernel_sources(), ids=os.path.basename)
def test_no_float_division_by_constant(path):
    with open(path) as f:
        offenders = [
            f"{path}:{line}: {text}"
            for line, text in _float_const_divisions(f.read())
        ]
    assert not offenders, (
        "float division by a constant in kernel code (jit rewrites x/c to "
        "x*(1/c); use an explicit exact reciprocal multiply or integer "
        "ops):\n" + "\n".join(offenders)
    )


def _banned_tpu_constructs(source: str):
    """Yield (line, text) for ``searchsorted`` uses and ``.at[...].add``
    scatter-adds.

    Both serialize on TPU (and are slow scalar loops on the CPU backend
    too): the entropy coder replaced its 4096-entry ``searchsorted``
    decode-table build with a cumulative-bucket fill (scatter-max +
    running max) and its scatter-add histogram with a one-hot matmul, and
    this test keeps those TPU-hostile constructs from silently returning
    to any kernel source.  Token-based so docstrings/comments cannot
    false-positive; ``.at[...].set`` / ``.at[...].max`` stay allowed (the
    emission pack and the bucket fill use them on small index sets).
    """
    toks = [
        t
        for t in tokenize.generate_tokens(io.StringIO(source).readline)
        if t.type not in (token.NL, token.NEWLINE, token.INDENT, token.DEDENT,
                          token.COMMENT)
    ]
    for i, t in enumerate(toks):
        if t.type == token.NAME and t.string == "searchsorted":
            yield t.start[0], t.line.strip()
        # the scatter-add pattern: OP'.' NAME'at' OP'[' ... OP']' OP'.'
        # NAME'add' OP'('
        if (
            t.type == token.OP and t.string == "."
            and i + 2 < len(toks)
            and toks[i + 1].type == token.NAME and toks[i + 1].string == "at"
            and toks[i + 2].type == token.OP and toks[i + 2].string == "["
        ):
            depth = 0
            for k in range(i + 2, len(toks)):
                if toks[k].type == token.OP and toks[k].string == "[":
                    depth += 1
                elif toks[k].type == token.OP and toks[k].string == "]":
                    depth -= 1
                    if depth == 0:
                        if (
                            k + 3 < len(toks)
                            and toks[k + 1].string == "."
                            and toks[k + 2].string == "add"
                            and toks[k + 3].string == "("
                        ):
                            yield t.start[0], t.line.strip()
                        break


def _entropy_sources():
    """The entropy column: the coder package plus the fused write chain."""
    files = []
    for sub in ("entropy", "fused"):
        root = os.path.join(KERNEL_ROOT, sub)
        for dirpath, _, names in os.walk(root):
            files += [
                os.path.join(dirpath, n) for n in names if n.endswith(".py")
            ]
    return sorted(files)


def _uses_name(node: ast.AST, name: str) -> bool:
    return any(
        isinstance(n, ast.Name) and n.id == name for n in ast.walk(node)
    )


def _induction_indexed_fori_loops(source: str):
    """Yield (line, text) for each ``fori_loop`` whose body indexes by the
    induction variable — a per-row subscript gather/update inside the
    carry chain, the serializing construct the two-phase encode removed
    (XLA:CPU cannot vectorize across trips whose memory access depends on
    the trip index; each row waits on the last).  A ``fori_loop`` whose
    body never subscripts by its induction variable (reduction-style
    carries) stays allowed.
    """
    tree = ast.parse(source)
    defs = {
        n.name: n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)
    }
    src_lines = source.splitlines()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        fn_name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None
        )
        if fn_name != "fori_loop" or len(node.args) < 3:
            continue
        body = node.args[2]
        if isinstance(body, ast.Name):
            body = defs.get(body.id)
        if body is None or not getattr(body, "args", None):
            continue
        params = body.args.args
        if not params:
            continue
        ivar = params[0].arg
        inner = body.body if isinstance(body, ast.FunctionDef) else [body.body]
        for stmt in inner:
            for n in ast.walk(stmt):
                hit = (
                    isinstance(n, ast.Subscript) and _uses_name(n.slice, ivar)
                ) or (
                    isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr.startswith("dynamic_")
                    and any(_uses_name(a, ivar) for a in n.args)
                )
                if hit:
                    yield node.lineno, src_lines[node.lineno - 1].strip()
                    return


@pytest.mark.parametrize("path", _entropy_sources(), ids=os.path.basename)
def test_no_induction_indexed_fori_loop_in_entropy(path):
    """PR 9 removed the per-row ``fori_loop`` carry chain from the entropy
    encode (the two-phase schedule computes the full emission schedule as
    batched tensor ops and compacts in one pass); this keeps the
    serializing construct from returning to the coder column."""
    with open(path) as f:
        offenders = [
            f"{path}:{line}: {text}"
            for line, text in _induction_indexed_fori_loops(f.read())
        ]
    assert not offenders, (
        "induction-indexed fori_loop in entropy coder code (serializes "
        "rows on every backend — use the two-phase batched schedule: "
        "precompute the emission schedule with tensor ops, then one "
        "gather/select pass):\n" + "\n".join(offenders)
    )


@pytest.mark.parametrize("path", _kernel_sources(), ids=os.path.basename)
def test_no_searchsorted_or_scatter_add(path):
    with open(path) as f:
        offenders = [
            f"{path}:{line}: {text}"
            for line, text in _banned_tpu_constructs(f.read())
        ]
    assert not offenders, (
        "TPU-hostile construct in kernel code (searchsorted lowers to a "
        "serial binary-search gather loop, .at[...].add to a serializing "
        "scatter; use a cumulative-bucket fill / one-hot matmul instead):\n"
        + "\n".join(offenders)
    )
