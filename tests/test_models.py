"""Per-architecture smoke tests (reduced configs) + decode/forward parity.

Every assigned arch: instantiate the reduced family-preserving config, run a
forward pass and one train step on CPU, assert output shapes and no NaNs;
then check that token-by-token decode with caches matches the full forward
(the strongest consistency check between the train and serve paths).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.config import ModelConfig
from repro.models.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.transformer import decode_step, forward, init_cache, init_model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

B, L = 2, 16


def _frontend(cfg: ModelConfig, key, batch=B):
    if cfg.encoder is not None:
        return jax.random.normal(
            key, (batch, cfg.encoder.seq_len, cfg.frontend_dim or cfg.d_model)
        )
    if cfg.n_frontend_tokens:
        return jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.frontend_dim or cfg.d_model)
        )
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab)
    fe = _frontend(cfg, jax.random.PRNGKey(2))

    logits, aux = forward(params, cfg, tokens, fe, q_chunk=8)
    assert logits.shape == (B, L, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))

    # one train step: loss + grads finite, params move
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        lg, aux = forward(p, cfg, tokens, fe, q_chunk=8)
        lp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        ce = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        return ce + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    opt = adamw_init(params)
    new_params, _ = adamw_update(params, grads, opt, AdamWConfig(lr=1e-3))
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce full-forward logits."""
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    T = 6
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    fe = _frontend(cfg, jax.random.PRNGKey(2))

    full_logits, _ = forward(params, cfg, tokens, fe, q_chunk=0)
    cache = init_cache(params, cfg, B, max_len=T + 2, frontend=fe)
    outs = []
    for t in range(T):
        lg, cache = decode_step(params, cfg, tokens[:, t : t + 1], cache, t)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        atol=2e-3,
        rtol=2e-3,
    )


def test_param_counts_match_published():
    targets = {
        "llama4_maverick_400b_a17b": 400e9,
        "deepseek_moe_16b": 16.4e9,
        "mistral_large_123b": 123e9,
        "qwen2_0_5b": 0.49e9,
        "internlm2_1_8b": 1.9e9,
        "nemotron_4_15b": 15e9,
        "whisper_large_v3": 1.55e9,
        "mamba2_370m": 0.37e9,
        "jamba_1_5_large_398b": 398e9,
        "llama_3_2_vision_11b": 9.8e9,
    }
    for arch, tgt in targets.items():
        got = get_config(arch).param_count()
        assert abs(got - tgt) / tgt < 0.25, (arch, got, tgt)
    # MoE active counts land in the published class
    assert 10e9 < get_config("llama4_maverick_400b_a17b").active_param_count() < 20e9
    a = get_config("jamba_1_5_large_398b").active_param_count()
    assert 80e9 < a < 100e9  # official: 94B active


def test_smoke_param_tree_is_arrays_only():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        params = init_model(jax.random.PRNGKey(0), cfg)
        for leaf in jax.tree.leaves(params):
            assert hasattr(leaf, "shape"), type(leaf)


def test_flash_attention_matches_exact():
    """Online-softmax path must match full-softmax attention (fp tolerance),
    causal and non-causal, GQA and MHA, ragged + aligned chunk sizes."""
    from repro.models.layers.attention import attention_forward, init_attention

    for (h, kv, causal, L) in [(4, 2, True, 64), (4, 4, False, 64), (8, 2, True, 96)]:
        p = init_attention(
            jax.random.PRNGKey(h), 32, h, kv, 16, False, dtype=jnp.float32
        )
        x = jax.random.normal(jax.random.PRNGKey(1), (2, L, 32))
        exact = attention_forward(
            p, x, n_heads=h, n_kv_heads=kv, head_dim=16, causal=causal, q_chunk=0
        )
        flash = attention_forward(
            p, x, n_heads=h, n_kv_heads=kv, head_dim=16, causal=causal, q_chunk=16
        )
        np.testing.assert_allclose(
            np.asarray(flash), np.asarray(exact), atol=2e-5, rtol=2e-5
        )
