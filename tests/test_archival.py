"""Archival layer tests: RAID, exemplar selection, full pipeline, CSD model."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core.archival import raid
from repro.core.archival.exemplar import kmeans, novelty_scores, select_exemplars
from repro.core.archival.pipeline import (
    ArchiveConfig,
    StripeArchive,
    archive_gop,
    archive_stripe,
    pack_i8_to_u32,
    recover_stripe,
    restore_gop,
    restore_stripe,
    stripe_manifests,
    stripe_parity,
    unpack_u32_to_i8,
)
from repro.core.codec.layered_codec import CodecConfig, init_codec, psnr
from repro.core.crypto import rlwe
from repro.core.csd import costmodel as cm
from repro.core.csd.failure import Journal, StragglerMonitor
from repro.core.csd.placement import balance_streams, placement_ratios, rebalance

CFG = CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)


# ------------------------------------------------------------------- GF/RAID
def test_gf_field_axioms():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 256, 1000), jnp.uint8)
    b = jnp.asarray(rng.integers(1, 256, 1000), jnp.uint8)
    c = jnp.asarray(rng.integers(0, 256, 1000), jnp.uint8)
    np.testing.assert_array_equal(
        np.asarray(raid.gf_mul(a, b)), np.asarray(raid.gf_mul(b, a))
    )
    # division inverts multiplication
    np.testing.assert_array_equal(
        np.asarray(raid.gf_div(raid.gf_mul(a, b), b)), np.asarray(a)
    )
    # distributivity over xor
    lhs = raid.gf_mul(a, b ^ c)
    rhs = raid.gf_mul(a, b) ^ raid.gf_mul(a, c)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(3, 8),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_raid6_recovers_any_two_erasures(k, seed, data):
    rng = np.random.default_rng(seed)
    shards = jnp.asarray(rng.integers(0, 256, (k, 64)), jnp.uint8)
    p, q = raid.raid6_encode(shards)
    missing = data.draw(
        st.lists(st.integers(0, k - 1), min_size=1, max_size=2, unique=True)
    )
    holes = [None if i in missing else shards[i] for i in range(k)]
    rec = raid.raid6_reconstruct(holes, p, q, missing)
    for i in range(k):
        np.testing.assert_array_equal(np.asarray(rec[i]), np.asarray(shards[i]))


def test_raid6_single_erasure_via_q_only():
    rng = np.random.default_rng(3)
    shards = jnp.asarray(rng.integers(0, 256, (5, 32)), jnp.uint8)
    _, q = raid.raid6_encode(shards)
    holes = [None if i == 2 else shards[i] for i in range(5)]
    rec = raid.raid6_reconstruct(holes, None, q, [2])
    np.testing.assert_array_equal(np.asarray(rec[2]), np.asarray(shards[2]))


def test_raid5_roundtrip():
    rng = np.random.default_rng(1)
    shards = jnp.asarray(rng.integers(0, 256, (4, 128)), jnp.uint8)
    parity = raid.raid5_encode(shards)
    holes = [None if i == 1 else shards[i] for i in range(4)]
    rec = raid.raid5_reconstruct(holes, parity, 1)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(shards[1]))


# ------------------------------------------------------------------ exemplar
def test_kmeans_separates_clusters():
    key = jax.random.PRNGKey(0)
    c1 = jax.random.normal(key, (50, 8)) * 0.1 + 5.0
    c2 = jax.random.normal(jax.random.PRNGKey(1), (50, 8)) * 0.1 - 5.0
    x = jnp.concatenate([c1, c2])
    cents, assign = kmeans(jax.random.PRNGKey(2), x, k=2, iters=10)
    a = np.asarray(assign)
    assert len(set(a[:50])) == 1 and len(set(a[50:])) == 1
    assert a[0] != a[50]


def test_exemplar_selection_routes_novel_to_training():
    key = jax.random.PRNGKey(0)
    known = jax.random.normal(key, (60, 8)) * 0.2  # tight cluster at 0
    novel = jax.random.normal(jax.random.PRNGKey(1), (4, 8)) * 0.2 + 20.0
    x = jnp.concatenate([known, novel])
    split = select_exemplars(jax.random.PRNGKey(2), x, k=4, n_train=4)
    train = set(np.asarray(split.train_idx).tolist())
    # all 4 novel points (indices 60..63) must be selected for training
    assert {60, 61, 62, 63} <= train or len({60, 61, 62, 63} & train) >= 3
    assert np.asarray(split.novelty).shape == (64,)


# ------------------------------------------------------------------ pipeline
def test_pack_unpack_i8_u32_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, 1000), jnp.int8)
    xp = jnp.pad(x, (0, (-x.shape[0]) % 4))
    w = pack_i8_to_u32(xp)
    back = unpack_u32_to_i8(w, 1000)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def _clip(key, t=3, b=1, h=32, w=32):
    f = jax.random.uniform(key, (t, b, h, w, 3))
    # smooth it so compression has structure
    k = jnp.ones((3, 3)) / 9.0
    from jax import lax

    f = lax.conv_general_dilated(
        f.reshape(t * b, h, w, 3),
        jnp.tile(k[:, :, None, None], (1, 1, 1, 3)).astype(f.dtype),
        (1, 1),
        "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=3,
    ).reshape(t, b, h, w, 3)
    return jnp.clip(f, 0.0, 1.0)


def test_archive_restore_gop_roundtrip():
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, s = rlwe.keygen(jax.random.PRNGKey(1))
    frames = _clip(jax.random.PRNGKey(2))
    block, recons = archive_gop(codec_params, pub, frames, jax.random.PRNGKey(3), cfg)
    restored = restore_gop(codec_params, s, block, cfg)
    # decryption + unpacking must reproduce the encoder-side reconstruction
    np.testing.assert_allclose(np.asarray(restored), np.asarray(recons), atol=1e-5)
    # sealed body must not leak plaintext structure
    assert np.asarray(block.sealed.body).std() > 1e6  # uniform uint32-ish


def test_stripe_parity_recovers_two_lost_shards():
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, s = rlwe.keygen(jax.random.PRNGKey(1))
    blocks, restored_ref = [], []
    for i in range(4):
        frames = _clip(jax.random.PRNGKey(10 + i))
        blk, _ = archive_gop(codec_params, pub, frames, jax.random.PRNGKey(20 + i), cfg)
        blocks.append(blk)
        restored_ref.append(restore_gop(codec_params, s, blk, cfg))
    parity = stripe_parity(blocks, "raid6")
    manifests = [
        {
            "kem_c1": b.sealed.kem_c1,
            "kem_c2": b.sealed.kem_c2,
            "nonce": b.sealed.nonce,
            "manifest": b.manifest,
        }
        for b in blocks
    ]
    body_lens = [int(b.sealed.body.shape[0]) for b in blocks]
    holes = [None if i in (0, 2) else blocks[i] for i in range(4)]
    rec = recover_stripe(holes, parity, [0, 2], manifests, body_lens)
    for i in (0, 2):
        got = restore_gop(codec_params, s, rec[i], cfg)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(restored_ref[i]), atol=1e-5
        )


def test_archive_stripe_fused_bit_identical_to_staged():
    """Acceptance: fused kernel stripe == staged reference (bodies, P, Q)."""
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, s = rlwe.keygen(jax.random.PRNGKey(1))
    frames = [_clip(jax.random.PRNGKey(30 + i)) for i in range(3)]
    key = jax.random.PRNGKey(7)
    fused, rec_f = archive_stripe(
        codec_params, pub, frames, key, cfg, use_pallas=True
    )
    staged, _ = archive_stripe(
        codec_params, pub, frames, key, cfg, use_pallas=False
    )
    for bf, bs in zip(fused.blocks, staged.blocks):
        np.testing.assert_array_equal(
            np.asarray(bf.sealed.body), np.asarray(bs.sealed.body)
        )
    np.testing.assert_array_equal(
        np.asarray(fused.parity["p"]), np.asarray(staged.parity["p"])
    )
    np.testing.assert_array_equal(
        np.asarray(fused.parity["q"]), np.asarray(staged.parity["q"])
    )
    # fused restore (with parity verification) reproduces the encoder recons
    out = restore_stripe(codec_params, s, fused, cfg)
    for got, want in zip(out, rec_f):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_archive_stripe_loss_recovery_roundtrip():
    """Fused stripe -> lose 2 shards -> parity rebuild -> fused restore."""
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, s = rlwe.keygen(jax.random.PRNGKey(1))
    frames = [_clip(jax.random.PRNGKey(40 + i)) for i in range(4)]
    stripe, recons = archive_stripe(
        codec_params, pub, frames, jax.random.PRNGKey(8), cfg
    )
    manifests = stripe_manifests(stripe)
    lens = [int(b.sealed.body.shape[0]) for b in stripe.blocks]
    holes = [None if i in (1, 3) else stripe.blocks[i] for i in range(4)]
    rebuilt = recover_stripe(holes, stripe.parity, [1, 3], manifests, lens)
    out = restore_stripe(
        codec_params, s, StripeArchive(rebuilt, stripe.parity), cfg
    )
    for got, want in zip(out, recons):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_restore_stripe_detects_corrupt_body():
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, s = rlwe.keygen(jax.random.PRNGKey(1))
    frames = [_clip(jax.random.PRNGKey(50 + i)) for i in range(3)]
    stripe, _ = archive_stripe(
        codec_params, pub, frames, jax.random.PRNGKey(9), cfg
    )
    bad = stripe.blocks[1]
    bad = bad._replace(
        sealed=bad.sealed._replace(
            body=bad.sealed.body.at[0].set(bad.sealed.body[0] ^ 1)
        )
    )
    corrupted = StripeArchive(
        [stripe.blocks[0], bad, stripe.blocks[2]], stripe.parity
    )
    with pytest.raises(ValueError, match="parity mismatch"):
        restore_stripe(codec_params, s, corrupted, cfg)


# ------------------------------------------------------------------ CSD model
def test_table2_placement_speedups_match_paper():
    sys = cm.SystemModel()
    base = cm.cpu_on_csd_data(sys, 1e9).latency_s
    paper = {
        (1.0,): 3.9,
        (0.9, 0.1): 4.46,
        (0.7, 0.3): 5.608,
        (0.6, 0.4): 6.67,
        (0.5, 0.5): 7.7,
    }
    for split, expect in paper.items():
        got = base / cm.csd_archive(sys, 1e9, split).latency_s
        assert abs(got - expect) / expect < 0.08, (split, got, expect)


def test_data_movement_reduction_matches_paper():
    sys = cm.SystemModel()
    classical = cm.classical_archive(sys, 1e9)
    salient = cm.csd_archive(sys, 1e9, (0.5, 0.5))
    reduction = classical.moved_bytes / salient.moved_bytes
    assert 5.0 < reduction < 7.0  # paper: ~5.63-6.13x


def test_multinode_movement_superlinear():
    """Fig. 10: data-movement latency grows super-linearly with server count."""
    sys = cm.SystemModel()
    lats = [cm.multinode_movement_latency(sys, 8e9, n) for n in (1, 2, 4, 8)]
    assert lats[0] == 0.0 and lats[1] > 0
    assert (lats[3] - lats[2]) > (lats[2] - lats[1]) > 0


def test_multinode_fig6_speedups_match_paper():
    """Fig. 6: 5 storage nodes -> ~4.77x vs classical, ~3x vs VSS."""
    sys = cm.SystemModel()
    sal = cm.multinode_latency(sys, 8e9, 5).latency_s
    cla = cm.classical_multinode_latency(sys, 8e9, 5).latency_s
    vs_classical = cla / sal
    vs_vss = (cla / sys.vss_factor) / sal
    assert abs(vs_classical - 4.77) / 4.77 < 0.15, vs_classical
    assert abs(vs_vss - 3.0) / 3.0 < 0.25, vs_vss


def test_csd_ratio_knee_near_8_to_1():
    sys = cm.SystemModel()
    best = max(
        ((n_csd, cm.csd_ratio_tradeoff(sys, 64e9, n_ssd=8, n_csd=n_csd)[1])
         for n_csd in (1, 2, 4, 8, 16)),
        key=lambda t: t[1],
    )
    assert best[0] in (1, 2)  # 8 SSD : 1 CSD is the cost-optimal knee


# ------------------------------------------------------------------ placement
def test_balance_streams_lpt():
    p = balance_streams([5, 3, 3, 2, 2, 1], 2)
    assert abs(p.loads[0] - p.loads[1]) <= 1
    ratios = placement_ratios(p)
    assert abs(sum(ratios) - 1.0) < 1e-9


def test_rebalance_moves_off_straggler():
    p = balance_streams([2, 2, 2, 2], 2)
    # shard 0 is 4x slower
    p2 = rebalance(p, [2, 2, 2, 2], shard_speed=[0.25, 1.0])
    assert p2.loads[0] < p.loads[0]


def test_straggler_monitor_flags():
    mon = StragglerMonitor(4)
    st_ = mon.update([1.0, 1.0, 2.5, 1.0])
    assert 2 in st_.stragglers
    st_ = mon.update([1.0, 1.0, None, 1.0])
    assert st_.speed[2] > 0  # still has EWMA
    mon2 = StragglerMonitor(3)
    s = mon2.update([1.0, 1.0, 60.0])
    assert 2 in s.dead


def test_journal_commit_replay_and_torn_write(tmp_path):
    j = Journal(str(tmp_path))
    j.commit("a.bin", b"hello", {"k": 1})
    j.commit("b.bin", b"world!")
    # torn write: payload missing
    with open(j.path, "a") as f:
        f.write('{"name": "c.bin", "bytes": 5, "ts": 0, "meta": {}}\n')
        f.write('{"name": "d.bin", "bytes"')  # torn journal line
    recs = j.replay()
    assert [r["name"] for r in recs] == ["a.bin", "b.bin"]
    assert j.read("a.bin") == b"hello"


def test_recover_stripe_raid5_double_loss_raises():
    """RAID-5 covers exactly one erasure: asking for two must fail loudly
    (named stripe in the message) instead of returning garbage bytes."""
    cfg = ArchiveConfig(codec=CFG, parity="raid5")
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, _ = rlwe.keygen(jax.random.PRNGKey(1))
    frames = [_clip(jax.random.PRNGKey(50 + i)) for i in range(3)]
    stripe, _ = archive_stripe(
        codec_params, pub, frames, jax.random.PRNGKey(9), cfg
    )
    manifests = stripe_manifests(stripe)
    lens = [int(b.sealed.body.shape[0]) for b in stripe.blocks]
    holes = [None if i in (0, 2) else stripe.blocks[i] for i in range(3)]
    with pytest.raises(ValueError, match=r"RAID-5.*\[0, 2\].*unrecoverable"):
        recover_stripe(holes, stripe.parity, [0, 2], manifests, lens,
                       stripe_id="s_test")
    # the stripe id names the failing stripe in the message
    with pytest.raises(ValueError, match="s_test"):
        recover_stripe(holes, stripe.parity, [0, 2], manifests, lens,
                       stripe_id="s_test")
    # a single erasure still recovers fine on the same stripe
    one_hole = [None if i == 0 else stripe.blocks[i] for i in range(3)]
    rec = recover_stripe(one_hole, stripe.parity, [0], manifests, lens)
    np.testing.assert_array_equal(
        np.asarray(rec[0].sealed.body), np.asarray(stripe.blocks[0].sealed.body)
    )


def test_straggler_monitor_warmup_grace_and_miss_threshold():
    """Cold start: shards that have not heartbeated YET are not dead (no
    degraded-read planning at step 0); past the grace they are.  A healthy
    shard is only declared dead after miss_threshold consecutive misses,
    so a single dropout or a short rolling restart is a non-event."""
    mon = StragglerMonitor(3, warmup_rounds=2, miss_threshold=3)
    s = mon.update([1.0, 1.0, None])  # round 1: inside warm-up grace
    assert s.dead == [] and s.speed[2] == 1.0
    s = mon.update([1.0, 1.0, None])  # round 2: grace expired, never heard
    assert s.dead == [2] and s.speed[2] == 0.0
    # once it has history, misses are counted against the threshold
    mon2 = StragglerMonitor(2, miss_threshold=3)
    mon2.update([1.0, 1.0])
    assert mon2.update([1.0, None]).dead == []       # dropout: 1 miss
    assert mon2.update([1.0, None]).dead == []       # rolling restart: 2
    assert mon2.update([1.0, None]).dead == [1]      # permanent: 3 misses
    # heartbeat resumes -> miss counter resets, shard rejoins
    assert mon2.update([1.0, 1.0]).dead == []
    assert mon2.update([1.0, None]).dead == []


def test_journal_crc_roundtrip_and_silent_flip(tmp_path):
    import os
    import zlib

    j = Journal(str(tmp_path))
    j.commit("x.bin", b"payload-bytes" * 11)
    rec = j.replay()[0]
    assert rec["crc32"] == (zlib.crc32(b"payload-bytes" * 11) & 0xFFFFFFFF)
    # same-length silent flip: replay refuses, read(crc32=...) raises
    with open(os.path.join(str(tmp_path), "x.bin"), "r+b") as f:
        f.seek(3)
        b0 = f.read(1)[0]
        f.seek(3)
        f.write(bytes([b0 ^ 1]))
    assert j.replay() == []
    flagged = j.replay(verify_crc=False)
    assert flagged[0]["crc_ok"] is False
    with pytest.raises(ValueError, match="crc32"):
        j.read("x.bin", crc32=rec["crc32"])
