"""One-launch fused archival tests: the ``kernels/fused`` entropy+seal
kernel must be bit-identical to the chained ``kernels/entropy`` ->
``kernels/seal`` path it replaces, at every layer it is wired into —
direct kernel launch (both multi-stripe schedules), batching wrappers,
the pipeline's default rANS dispatch, the shard_map'd mesh twin, and the
read side (full / subset / degraded restores of fused-written archives).

Mesh-shape cases beyond the host's device count skip; run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
multi-device job does) to exercise all of {1, 2, 4, 8}.
"""

import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core.archival.pipeline import (
    ArchiveConfig,
    StripeArchive,
    restore_stripe,
    restore_stripe_payloads,
    seal_payload_stripe,
    seal_payload_stripes,
    stripe_manifests,
)
from repro.core.archival.raid import gf_pow_gen
from repro.core.codec.layered_codec import CodecConfig, init_codec
from repro.core.crypto import rlwe
from repro.distributed.archival import (
    StripeCoalescer,
    entropy_seal_sharded,
    seal_coalesced_stripes,
)
from repro.kernels.entropy import ops as eops
from repro.kernels.entropy.ops import rows_for
from repro.kernels.entropy.rans import N_LANES
from repro.kernels.fused import ops as fops
from repro.kernels.fused.entropy_seal import entropy_seal_pallas
from repro.kernels.seal import ops as sops

CFG = CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)
MESH_SIZES = [1, 2, 4, 8]


def _mesh(d: int) -> Mesh:
    if jax.device_count() < d:
        pytest.skip(
            f"need {d} devices, have {jax.device_count()} "
            "(run with XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
    return Mesh(np.array(jax.devices()[:d]), ("data",))


def _eq(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))


def _payloads(seed, lens, raw_shards=()):
    """Ragged int8 shard payloads: low-entropy (compressible) by default,
    full-range uniform (incompressible -> raw-skip) for ``raw_shards``."""
    rng = np.random.default_rng(seed)
    out = []
    for s, n in enumerate(lens):
        if s in raw_shards:
            x = rng.integers(-128, 128, n)
        else:
            x = np.clip(np.rint(rng.normal(0.0, 2.0, n)), -128, 127)
        out.append(jnp.asarray(x, jnp.int8))
    return out


def _session(seed, S):
    rng = np.random.default_rng(1000 + seed)
    keys = jnp.asarray(rng.integers(0, 2**32, (S, 8), dtype=np.uint32))
    nonces = jnp.asarray(rng.integers(0, 2**32, (S, 3), dtype=np.uint32))
    return keys, nonces


def _chained(payloads, keys, nonces, parity):
    """The two-launch reference: entropy coder then seal kernel."""
    comps, metas = eops.encode_payloads(payloads)
    return sops.seal_stripe(comps, keys, nonces, parity=parity), metas


def _assert_stripes_equal(got, want):
    gs, gm = got
    ws, wm = want
    assert gm == wm
    assert _eq(gs.sealed, ws.sealed)
    assert gs.n_words == ws.n_words
    assert gs.n_i8 == ws.n_i8
    for a, b in ((gs.p, ws.p), (gs.q, ws.q)):
        assert (a is None) == (b is None)
        if a is not None:
            assert _eq(a, b)


# ------------------------------------------------ fused vs chained identity
@pytest.mark.parametrize("parity", ["raid6", "raid5", "none"])
def test_fused_bit_identical_to_chained(parity):
    """Acceptance: the one-launch kernel's sealed bodies, parity, metas and
    row counts match the chained entropy->seal path bit-for-bit, including
    a raw-skip (incompressible) shard mid-stripe."""
    lens = [5000, 4093, 4096, 777]
    payloads = _payloads(3, lens, raw_shards=(1,))
    keys, nonces = _session(3, len(lens))
    fused = fops.entropy_seal_stripe(payloads, keys, nonces, parity=parity)
    assert fused[1][1]["raw"] is True  # the high-entropy shard raw-skipped
    assert "raw" not in fused[1][0]
    _assert_stripes_equal(fused, _chained(payloads, keys, nonces, parity))


def test_fused_all_raw_stripe():
    """Every shard incompressible: the kernel raw-skips the whole stripe and
    still matches the chained path (stored bytes ARE the payloads)."""
    lens = [2048, 4096, 1023]
    payloads = _payloads(4, lens, raw_shards=range(len(lens)))
    keys, nonces = _session(4, len(lens))
    fused = fops.entropy_seal_stripe(payloads, keys, nonces)
    assert all(m["raw"] is True for m in fused[1])
    assert fused[0].n_i8 == tuple(lens)
    _assert_stripes_equal(fused, _chained(payloads, keys, nonces, "raid6"))


def test_fused_ref_matches_pallas():
    """The staged jnp oracle (use_pallas=False) is bit-identical to the
    kernel on a mixed compressible/raw stripe."""
    payloads = _payloads(5, [3000, 512, 4095], raw_shards=(2,))
    keys, nonces = _session(5, 3)
    _assert_stripes_equal(
        fops.entropy_seal_stripe(payloads, keys, nonces, use_pallas=False),
        fops.entropy_seal_stripe(payloads, keys, nonces, use_pallas=True),
    )


def test_batched_stripes_match_per_stripe():
    """K stripes through one batched call == K singular calls, across
    heterogeneous groups (different shard counts and row buckets)."""
    stripes = [
        _payloads(10, [4000, 4001]),
        _payloads(11, [3999, 100], raw_shards=(1,)),
        _payloads(12, [9000, 8888, 7000]),  # different (S, T) group
    ]
    mats = [_session(20 + i, len(p)) for i, p in enumerate(stripes)]
    keys = [m[0] for m in mats]
    nonces = [m[1] for m in mats]
    batched = fops.entropy_seal_stripes(stripes, keys, nonces)
    for got, p, k, n in zip(batched, stripes, keys, nonces):
        _assert_stripes_equal(got, fops.entropy_seal_stripe(p, k, n))


def test_grid_schedule_bit_identical_to_fat_block():
    """The two multi-stripe schedules (one fat block vs stripes on the
    launch grid axis) are pure scheduling: identical outputs."""
    S, K = 2, 3
    flats = [p for i in range(K) for p in _payloads(30 + i, [2500, 2501])]
    n_raw = [int(f.shape[0]) for f in flats]
    T = rows_for(max(n_raw))
    codes = jnp.stack(
        [jnp.pad(f, (0, T * N_LANES - n)).reshape(T, N_LANES)
         for f, n in zip(flats, n_raw)]
    )
    n_valid = jnp.asarray(n_raw, jnp.int32).reshape(-1, 1)
    keys, nonces = _session(30, K * S)
    q_coef = jnp.asarray(
        [gf_pow_gen(s) for s in range(S)] * K, jnp.uint32
    ).reshape(-1, 1)
    run = functools.partial(
        entropy_seal_pallas, codes, n_valid, keys, nonces, q_coef,
        n_shards=S, parity="raid6", interpret=True,
    )
    fat = run(grid_stripes=False)
    grid = run(grid_stripes=True)
    for a, b in zip(fat, grid):
        assert _eq(a, b)


# -------------------------------------------------- pipeline-level dispatch
def test_seal_payload_stripe_default_is_fused_and_identical_to_chained():
    """The default rANS path dispatches the fused launch (observed via the
    fused_fn seam) and its archive equals the explicit chained path."""
    cfg = ArchiveConfig(codec=CFG)
    pub, _ = rlwe.keygen(jax.random.PRNGKey(0))
    flats = _payloads(6, [4000, 123, 4096], raw_shards=(2,))
    manifests = [{"n_i8": int(f.shape[0])} for f in flats]
    key = jax.random.PRNGKey(42)

    calls = []

    def counting_fused(*a, **kw):
        calls.append(1)
        return fops.entropy_seal_stripes(*a, **kw)

    fused = seal_payload_stripe(
        pub, flats, manifests, key, cfg, fused_fn=counting_fused
    )
    assert len(calls) == 1
    default = seal_payload_stripe(pub, flats, manifests, key, cfg)
    chained = seal_payload_stripe(
        pub, flats, manifests, key, cfg,
        seal_fn=sops.seal_stripe, entropy_fn=eops.encode_payloads,
    )
    for got in (fused, default):
        for bg, bc in zip(got.blocks, chained.blocks):
            assert _eq(bg.sealed.body, bc.sealed.body)
            assert _eq(bg.sealed.kem_c1, bc.sealed.kem_c1)
            assert _eq(bg.sealed.nonce, bc.sealed.nonce)
            assert bg.manifest == bc.manifest
        assert _eq(got.parity["p"], chained.parity["p"])
        assert _eq(got.parity["q"], chained.parity["q"])


def test_seal_payload_stripes_matches_singular():
    cfg = ArchiveConfig(codec=CFG)
    pub, _ = rlwe.keygen(jax.random.PRNGKey(1))
    stripes = [_payloads(40 + i, [3000 + 7 * i, 2999]) for i in range(3)]
    manifests = [
        [{"n_i8": int(f.shape[0])} for f in fl] for fl in stripes
    ]
    keys = [jax.random.PRNGKey(100 + i) for i in range(3)]
    plural = seal_payload_stripes(pub, stripes, manifests, keys, cfg)
    for got, fl, mf, k in zip(plural, stripes, manifests, keys):
        want = seal_payload_stripe(pub, fl, mf, k, cfg)
        for bg, bw in zip(got.blocks, want.blocks):
            assert _eq(bg.sealed.body, bw.sealed.body)
            assert bg.manifest == bw.manifest
        assert _eq(got.parity["p"], want.parity["p"])
        assert _eq(got.parity["q"], want.parity["q"])


# --------------------------------------------------- read side: fused-written
def test_restore_full_subset_degraded_through_fused_archive():
    """Fused-written archives decode through every read path: full stripe
    (with parity verification), shard-subset retrieval, and a parity-
    rebuilt degraded read of a lost shard."""
    cfg = ArchiveConfig(codec=CFG)
    pub, secret = rlwe.keygen(jax.random.PRNGKey(2))
    flats = _payloads(7, [5000, 4093, 64, 4096, 2500], raw_shards=(3,))
    manifests = [{"n_i8": int(f.shape[0])} for f in flats]
    archive = seal_payload_stripe(
        pub, flats, manifests, jax.random.PRNGKey(9), cfg
    )
    # full restore, parity recompute-and-compare on
    back, _ = restore_stripe_payloads(secret, archive, cfg)
    for got, want in zip(back, flats):
        assert _eq(got, want)
    # subset retrieval (raw-skip shard included)
    sub, blocks = restore_stripe_payloads(secret, archive, cfg, shards=[3, 1])
    assert _eq(sub[0], flats[3]) and _eq(sub[1], flats[1])
    assert blocks[0].manifest["entropy"]["raw"] is True
    # degraded read: lose a shard, rebuild from RAID parity + replicated meta
    recs = stripe_manifests(archive)
    holed = StripeArchive(
        [None if i == 2 else b for i, b in enumerate(archive.blocks)],
        archive.parity,
    )
    deg, _ = restore_stripe_payloads(
        secret, holed, cfg, shards=[2, 0], manifests=recs
    )
    assert _eq(deg[0], flats[2]) and _eq(deg[1], flats[0])


def test_golden_v0_fixture_unaffected_by_fused_write_path():
    """The fused kernel is write-side only: PR-4-era version-0 archives keep
    decoding, and fused re-encodes of the same payloads emit version-1
    streams bit-identical to the chained coder's."""
    import base64
    import json
    import os

    with open(os.path.join(os.path.dirname(__file__),
                           "data_rans_v0.json")) as f:
        g = json.load(f)
    comps = [
        jnp.asarray(np.frombuffer(base64.b64decode(b), np.int8))
        for b in g["streams_b64"]
    ]
    wants = [
        np.frombuffer(base64.b64decode(b), np.int8)
        for b in g["payloads_b64"]
    ]
    back = eops.decode_payloads(comps, g["metas"])
    for got, want in zip(back, wants):
        assert _eq(got, want)
    keys, nonces = _session(8, len(wants))
    fused = fops.entropy_seal_stripe(
        [jnp.asarray(w) for w in wants], keys, nonces
    )
    for m, m0 in zip(fused[1], g["metas"]):
        assert m["version"] == 1
        assert m["n_comp"] == m0["n_comp"]  # format moves words, adds none
    _assert_stripes_equal(
        fused, _chained([jnp.asarray(w) for w in wants], keys, nonces,
                        "raid6")
    )


# ------------------------------------------------------------- sharded twin
@pytest.mark.parametrize("d", MESH_SIZES)
def test_sharded_fused_core_bit_identical(d):
    """entropy_seal_sharded (shard_map'd local kernels + cross-shard XOR
    parity reduce) == the single-device fused launch on every mesh shape,
    including S % D != 0 (dummy zero-shard padding)."""
    mesh = _mesh(d)
    core = functools.partial(entropy_seal_sharded, mesh=mesh, axis="data")
    for seed, lens, raw in ((50, [4000, 3999, 4001, 128], (3,)),
                            (51, [2000, 1999, 2001], ())):  # S=3: padding
        payloads = _payloads(seed, lens, raw_shards=raw)
        keys, nonces = _session(seed, len(lens))
        _assert_stripes_equal(
            fops.entropy_seal_stripe(payloads, keys, nonces, core_fn=core),
            fops.entropy_seal_stripe(payloads, keys, nonces),
        )


@pytest.mark.parametrize("d", MESH_SIZES)
def test_seal_coalesced_stripes_sharded_end_to_end(d):
    """Coalescer -> batched sharded seal == batched local seal, and the
    fused-written stripes decode through the standard restore path."""
    mesh = _mesh(d)
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, secret = rlwe.keygen(jax.random.PRNGKey(1))
    from repro.core.archival.pipeline import encode_gop_payload

    coal = StripeCoalescer(n_shards=2)
    batch = []
    for i in range(4):
        f = jnp.clip(
            jax.random.uniform(jax.random.PRNGKey(70 + i), (2, 1, 32, 32, 3)),
            0.0, 1.0,
        )
        flat, manifest, _ = encode_gop_payload(codec_params, f, cfg)
        batch += coal.add(i % 3, flat, manifest)
    batch += coal.flush()
    assert batch and coal.n_pending == 0
    keys = [jax.random.PRNGKey(200 + i) for i in range(len(batch))]
    sharded = seal_coalesced_stripes(pub, batch, keys, cfg, mesh=mesh)
    local = seal_coalesced_stripes(pub, batch, keys, cfg)
    for gs, gl in zip(sharded, local):
        for bs, bl in zip(gs.blocks, gl.blocks):
            assert _eq(bs.sealed.body, bl.sealed.body)
            assert bs.manifest == bl.manifest
        assert _eq(gs.parity["p"], gl.parity["p"])
        assert _eq(gs.parity["q"], gl.parity["q"])
    out = restore_stripe(codec_params, secret, sharded[0], cfg)
    assert len(out) == len(sharded[0].blocks)


# ------------------------------------------------------------------ hygiene
def test_hygiene_sweep_covers_fused_sources():
    """The TPU-hostile-construct bans apply to the fused kernel package:
    its sources must be inside the hygiene sweep's file set."""
    from test_kernel_hygiene import _kernel_sources

    srcs = _kernel_sources()
    for want in ("entropy_seal.py", "ref.py", "ops.py"):
        assert any(p.endswith("fused/" + want) for p in srcs), want
