"""Direct unit tests for ``repro.common.compress`` (the host entropy
fallback): level clamping, truncated decompression, cross-codec behavior.

The zlib branch is loaded explicitly (with ``zstandard`` import-blocked)
into a private module instance, so both branches are exercised no matter
which codec this host actually has — CI runs one job per branch on top.
"""

import importlib.util
import sys
import zlib

import pytest

from repro.common import compress as active

DATA = (b"salient store entropy stage " * 200) + bytes(range(256))


def _load_compress_module(block_zstd: bool):
    """Fresh instance of repro/common/compress.py, optionally with the
    zstandard import forced to fail (sys.modules[name] = None makes the
    import statement raise ImportError)."""
    spec = importlib.util.spec_from_file_location(
        f"_compress_{'zlib' if block_zstd else 'auto'}", active.__file__
    )
    mod = importlib.util.module_from_spec(spec)
    had = "zstandard" in sys.modules
    prev = sys.modules.get("zstandard")
    if block_zstd:
        sys.modules["zstandard"] = None
    try:
        spec.loader.exec_module(mod)
    finally:
        if block_zstd:
            if had:
                sys.modules["zstandard"] = prev
            else:
                del sys.modules["zstandard"]
    return mod


@pytest.fixture(scope="module")
def zlib_branch():
    mod = _load_compress_module(block_zstd=True)
    assert not mod.HAVE_ZSTD and mod.CODEC_NAME == "zlib"
    return mod


# ------------------------------------------------------------- active codec
def test_active_codec_roundtrip():
    blob = active.compress(DATA)
    assert len(blob) < len(DATA)
    assert active.decompress(blob) == DATA
    assert active.decompress(blob, max_output_size=len(DATA)) == DATA


def test_active_codec_high_level_roundtrip():
    # zstd levels go to 22; the zlib fallback must clamp instead of raising
    blob = active.compress(DATA, level=22)
    assert active.decompress(blob) == DATA


# -------------------------------------------------------------- zlib branch
def test_zlib_fallback_level_clamp(zlib_branch):
    # zlib.compress raises on level > 9; the fallback clamps 22 -> 9
    with pytest.raises(Exception):
        zlib.compress(DATA, 22)
    blob = zlib_branch.compress(DATA, level=22)
    assert blob == zlib.compress(DATA, 9)
    assert zlib_branch.decompress(blob) == DATA


def test_zlib_max_output_size_truncates(zlib_branch):
    blob = zlib_branch.compress(DATA)
    out = zlib_branch.decompress(blob, max_output_size=100)
    assert out == DATA[:100]
    # 0 means "no limit", not "empty output"
    assert zlib_branch.decompress(blob, max_output_size=0) == DATA


def test_zlib_blob_is_stdlib_zlib(zlib_branch):
    # the fallback writes plain zlib streams: any zlib reader can decode
    assert zlib.decompress(zlib_branch.compress(DATA, level=3)) == DATA


# ------------------------------------------------------------- cross-codec
def test_cross_codec_roundtrip_within_host():
    """Within one host the codec choice is deterministic, so compress ->
    decompress must always invert — for the active branch AND the forced
    zlib branch (they need not produce the same bytes as each other)."""
    zl = _load_compress_module(block_zstd=True)
    for mod in (active, zl):
        blob = mod.compress(DATA, level=5)
        assert mod.decompress(blob, max_output_size=len(DATA)) == DATA


def test_named_codec_api():
    """compress_as/decompress_as dispatch by recorded name: zlib always
    works (stdlib), zstd only when the module exists."""
    blob = active.compress_as("zlib", DATA, level=22)  # clamps like the branch
    assert zlib.decompress(blob) == DATA
    assert active.decompress_as("zlib", blob, max_output_size=50) == DATA[:50]
    if active.HAVE_ZSTD:
        z = active.compress_as("zstd", DATA)
        assert active.decompress_as("zstd", z, max_output_size=len(DATA)) == DATA
    else:
        with pytest.raises(ValueError, match="requires the zstandard"):
            active.compress_as("zstd", DATA)
    with pytest.raises(ValueError, match="unknown host entropy codec"):
        active.decompress_as("lz4", b"")


def test_zstd_blob_rejected_by_zlib_branch(zlib_branch):
    """A blob from the other codec must fail loudly, not roundtrip quietly
    (this is why checkpoint manifests record the codec name)."""
    if not active.HAVE_ZSTD:
        pytest.skip("host has no zstandard; branches coincide")
    blob = active.compress(DATA)
    with pytest.raises(Exception):
        zlib_branch.decompress(blob)
