"""Fused seal-datapath kernel tests: exactness vs oracle, recovery, padding."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.archival import raid
from repro.kernels import use_interpret
from repro.kernels.seal import ops as sops
from repro.kernels.seal import ref as sref
from repro.kernels.seal.seal import LANES, R_TILE, ROW_BYTES


def _stripe_inputs(seed, lens):
    rng = np.random.default_rng(seed)
    S = len(lens)
    payloads = [jnp.asarray(rng.integers(-128, 128, n), jnp.int8) for n in lens]
    keys = jnp.asarray(rng.integers(0, 2**32, (S, 8), dtype=np.uint32))
    nonces = jnp.asarray(rng.integers(0, 2**32, (S, 3), dtype=np.uint32))
    return payloads, keys, nonces


def _eq(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------- kernel vs jnp oracle
@pytest.mark.parametrize("parity", ["raid6", "raid5", "none"])
def test_fused_matches_staged_oracle(parity):
    payloads, keys, nonces = _stripe_inputs(0, [5000, 4093, 4096, 2500])
    fused = sops.seal_stripe(payloads, keys, nonces, parity=parity)
    staged = sops.seal_stripe(
        payloads, keys, nonces, parity=parity, use_pallas=False
    )
    assert _eq(fused.sealed, staged.sealed)
    if parity != "none":
        assert _eq(fused.p, staged.p)
    if parity == "raid6":
        assert _eq(fused.q, staged.q)
    # sealed bodies must not leak plaintext structure
    assert np.asarray(fused.body(0)).std() > 1e6


def test_fused_multi_tile_rows():
    """Payloads spanning several 8-row grid steps (exercise tile counters)."""
    lens = [3 * R_TILE * ROW_BYTES, 2 * R_TILE * ROW_BYTES + 17]
    payloads, keys, nonces = _stripe_inputs(1, lens)
    fused = sops.seal_stripe(payloads, keys, nonces)
    staged = sops.seal_stripe(payloads, keys, nonces, use_pallas=False)
    assert _eq(fused.sealed, staged.sealed)
    assert _eq(fused.q, staged.q)
    back, _, _ = sops.unseal_stripe(fused, keys, nonces)
    for got, want in zip(back, payloads):
        assert _eq(got, want)


# -------------------------------------------------- stripe loss + recovery
def _u8_rows(stripe):
    """Per-shard sealed bodies as (S, R*512) uint8 (padded layout)."""
    return np.array(
        jax.lax.bitcast_convert_type(stripe.sealed, jnp.uint8)
    ).reshape(stripe.sealed.shape[0], -1)


def _rebuild_stripe(stripe, rows_u8):
    sealed = jax.lax.bitcast_convert_type(
        jnp.asarray(rows_u8, jnp.uint8).reshape(
            stripe.sealed.shape[0], stripe.sealed.shape[1], LANES, 4
        ),
        jnp.uint32,
    )
    return stripe._replace(sealed=sealed)


@pytest.mark.parametrize(
    "parity,missing", [("raid5", [1]), ("raid6", [0, 2]), ("none", [])]
)
def test_stripe_roundtrip_with_shard_loss(parity, missing):
    """seal -> drop shards -> parity-reconstruct -> unseal -> bit-exact."""
    payloads, keys, nonces = _stripe_inputs(2, [1500, 900, 2049, 700])
    stripe = sops.seal_stripe(payloads, keys, nonces, parity=parity)
    rows = _u8_rows(stripe)
    holes = [None if i in missing else jnp.asarray(rows[i]) for i in range(4)]
    if parity == "raid5":
        p_u8 = np.asarray(
            jax.lax.bitcast_convert_type(stripe.p, jnp.uint8)
        ).reshape(-1)
        rows[missing[0]] = np.asarray(
            raid.raid5_reconstruct(holes, jnp.asarray(p_u8), missing[0])
        )
    elif parity == "raid6":
        p_u8 = jax.lax.bitcast_convert_type(stripe.p, jnp.uint8).reshape(-1)
        q_u8 = jax.lax.bitcast_convert_type(stripe.q, jnp.uint8).reshape(-1)
        rec = raid.raid6_reconstruct(holes, p_u8, q_u8, missing)
        for i in missing:
            rows[i] = np.asarray(rec[i])
    restored = _rebuild_stripe(stripe, rows)
    back, p2, q2 = sops.unseal_stripe(restored, keys, nonces, parity=parity)
    for got, want in zip(back, payloads):
        assert _eq(got, want)
    if parity != "none":
        assert _eq(p2, stripe.p)  # recomputed parity matches seal-time parity
    if parity == "raid6":
        assert _eq(q2, stripe.q)


# -------------------------------------------------------- padding edge cases
@pytest.mark.parametrize(
    "lens",
    [
        [1, 2, 3],                      # sub-word shards
        [4097, 13],                     # one word past a tile, vs tiny
        [ROW_BYTES * R_TILE, 511],      # exactly one tile, vs one byte short
        [37, 37],                       # equal odd lengths
    ],
)
def test_odd_length_padding_edges(lens):
    payloads, keys, nonces = _stripe_inputs(sum(lens), lens)
    fused = sops.seal_stripe(payloads, keys, nonces)
    staged = sops.seal_stripe(payloads, keys, nonces, use_pallas=False)
    assert _eq(fused.sealed, staged.sealed)
    assert _eq(fused.p, staged.p)
    assert _eq(fused.q, staged.q)
    back, _, _ = sops.unseal_stripe(fused, keys, nonces)
    for got, want in zip(back, payloads):
        assert _eq(got, want)
    # padded tails are zero so parity over ragged shards is well-defined
    for s, n in enumerate(fused.n_words):
        tail = np.asarray(fused.sealed[s]).reshape(-1)[n:]
        assert not tail.any()


def test_pad_rows_alignment():
    assert sops.pad_rows_for(1) == R_TILE
    assert sops.pad_rows_for(R_TILE * LANES) == R_TILE
    assert sops.pad_rows_for(R_TILE * LANES + 1) == 2 * R_TILE


# -------------------------------------------------------- dispatch plumbing
def test_interpret_autodetect():
    # this suite runs on CPU: kernels must auto-select interpret mode,
    # and an explicit override must win
    assert use_interpret() == (jax.default_backend() != "tpu")
    assert use_interpret(True) is True
    assert use_interpret(False) is False


def test_traffic_accounting_structure():
    t = sops.datapath_traffic(4, 4096, "raid6")
    assert t["fused_launches"] == 1
    assert t["staged_passes"] == sref.N_STAGED_PASSES >= 5
    assert t["reduction"] > 3.0
