"""Interleaved-rANS entropy kernel tests: exactness vs oracle, roundtrip,
stream format, pipeline chaining (single-device and sharded)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core.archival.pipeline import (
    ArchiveConfig,
    archive_stripe,
    restore_stripe,
)
from repro.core.codec.layered_codec import CodecConfig, init_codec
from repro.core.crypto import rlwe
from repro.kernels.entropy import ops as eops
from repro.kernels.entropy.rans import (
    N_LANES,
    PROB_SCALE,
    STREAM_VERSION,
    build_freq_table,
)

CFG = CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)


def _eq(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))


def _latents(seed, n, sigma=2.0):
    """Peaked int8 distribution shaped like quantized codec latents."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.clip(np.round(rng.normal(0.0, sigma, n)), -128, 127), jnp.int8
    )


# ------------------------------------------------------- kernel vs jnp oracle
def test_encode_matches_staged_oracle():
    payloads = [_latents(i, n) for i, n in enumerate([5000, 4093, 4096, 2500])]
    ck, mk = eops.encode_payloads(payloads, use_pallas=True)
    cr, mr = eops.encode_payloads(payloads, use_pallas=False)
    assert mk == mr
    for a, b in zip(ck, cr):
        assert _eq(a, b)  # streams bit-identical, header included


def test_roundtrip_bit_exact_both_paths():
    payloads = [_latents(7, 9000), _latents(8, 100)]
    comp, metas = eops.encode_payloads(payloads)
    for use_pallas in (True, False):
        back = eops.decode_payloads(comp, metas, use_pallas=use_pallas)
        for got, want in zip(back, payloads):
            assert _eq(got, want)


@pytest.mark.parametrize(
    "lens",
    [
        [1],                          # single byte
        [7, 1],                       # sub-lane shards
        [N_LANES * 8, 511],           # exactly one tile vs one byte short
        [4097, 13],                   # one word past a tile vs tiny
        [37, 37],                     # equal odd lengths
    ],
)
def test_odd_length_edges(lens):
    payloads = [_latents(sum(lens) + i, n) for i, n in enumerate(lens)]
    ck, mk = eops.encode_payloads(payloads, use_pallas=True)
    cr, mr = eops.encode_payloads(payloads, use_pallas=False)
    assert mk == mr
    for a, b in zip(ck, cr):
        assert _eq(a, b)
    back = eops.decode_payloads(ck, mk)
    for got, want in zip(back, payloads):
        assert _eq(got, want)


def test_degenerate_distributions_roundtrip():
    """Single-symbol (freq == PROB_SCALE), all-zero, and uniform-random
    (incompressible) payloads must all survive the coder exactly."""
    payloads = [
        jnp.full((4096,), -5, jnp.int8),
        jnp.zeros((300,), jnp.int8),
        jnp.asarray(
            np.random.default_rng(0).integers(-128, 128, 3000), jnp.int8
        ),
    ]
    comp, metas = eops.encode_payloads(payloads)
    comp_r, metas_r = eops.encode_payloads(payloads, use_pallas=False)
    assert metas == metas_r
    for a, b in zip(comp, comp_r):
        assert _eq(a, b)
    back = eops.decode_payloads(comp, metas)
    for got, want in zip(back, payloads):
        assert _eq(got, want)
    # single-symbol shard never renormalizes: stream is exactly the header
    assert metas[0]["n_comp"] == eops.HEADER_BYTES


def test_freq_table_exact_invariants():
    rng = np.random.default_rng(2)
    for counts in [
        rng.integers(0, 1000, 256),
        np.eye(256, dtype=np.int64)[3] * 10**9,      # huge single-symbol count
        np.full(256, 1 << 22),                       # huge uniform (downscale)
        np.zeros(256),                               # empty payload
    ]:
        f = np.asarray(build_freq_table(jnp.asarray(counts, jnp.int32)))
        assert f.sum() == PROB_SCALE, counts
        assert (f[counts > 0] >= 1).all()
        assert (f >= 0).all()


def test_compression_ratio_on_latents():
    """Acceptance shape: >= 2x on realistically peaked int8 latent codes."""
    payloads = [_latents(i, 65536) for i in range(4)]
    comp, metas = eops.encode_payloads(payloads)
    ratio = sum(m["n_raw"] for m in metas) / sum(m["n_comp"] for m in metas)
    assert ratio >= 2.0, ratio
    back = eops.decode_payloads(comp, metas)
    for got, want in zip(back, payloads):
        assert _eq(got, want)


def test_stream_is_self_contained():
    """Tables/lengths/states travel in the stream header; metas carry only
    lengths + row count + stream version (what the archive manifest
    stores)."""
    payloads = [_latents(0, 5000)]
    comp, metas = eops.encode_payloads(payloads)
    assert set(metas[0]) == {"codec", "version", "n_raw", "n_comp", "rows"}
    assert metas[0]["version"] == STREAM_VERSION == 1
    assert int(comp[0].shape[0]) == metas[0]["n_comp"] >= eops.HEADER_BYTES


def test_division_strategies_bit_identical():
    """All three per-symbol division strategies — hardware udiv, the
    error-repaired f32 reciprocal (TPU default; Mosaic has no integer
    divide), and the Granlund-Montgomery mulhi — must produce identical
    streams bit-for-bit."""
    payloads = [_latents(3, 9000), _latents(4, 100)]
    outs = {
        d: eops.encode_payloads(payloads, division=d)
        for d in ("divide", "rcp32", "reciprocal")
    }
    ref_c, ref_m = outs["divide"]
    for d, (c, m) in outs.items():
        assert m == ref_m, d
        for a, b in zip(c, ref_c):
            assert _eq(a, b), d


def test_row_and_tile_schedules_bit_identical():
    """The loop schedule (rows per trip: 1 on CPU interpret, the (8, 128)
    sublane tile on TPU) is pure scheduling — outputs must be identical."""
    from repro.kernels.entropy.rans import (
        N_GROUPS,
        rans_decode_pallas,
        rans_encode_pallas,
    )

    n = 5000
    T = eops.rows_for(n)
    flat = _latents(9, n)
    codes = jnp.stack([jnp.pad(flat, (0, T * N_LANES - n)).reshape(T, N_LANES)])
    nv = jnp.asarray([[n]], jnp.int32)
    outs = [
        rans_encode_pallas(codes, nv, rows_per_step=r, interpret=True)
        for r in (1, N_GROUPS)
    ]
    for a, b in zip(*outs):
        assert _eq(a, b)
    # decode twin: both schedules reproduce the payload from the packed
    # version-1 stream
    comp, metas = eops.encode_payloads([flat])
    stream, freq, states = eops._parse_streams(
        jnp.stack([jnp.pad(jnp.asarray(comp[0]).astype(jnp.uint8),
                           (0, (metas[0]["n_comp"] % 2)))])
    )
    for r in (1, N_GROUPS):
        got = rans_decode_pallas(
            stream, freq, states, nv, rows=T, rows_per_step=r, interpret=True
        )
        assert _eq(got[0].reshape(-1)[:n], flat)


def _bucket_stripe(T, seed):
    """A stripe pinning bucket T's n_valid boundaries: an exactly-full
    shard, one byte short (last lane of the last row pads), the first
    byte of the last row, a sub-header tiny shard, and an incompressible
    raw-skip rider."""
    rng = np.random.default_rng(seed)
    n_full = T * N_LANES
    return [
        _latents(seed, n_full),
        _latents(seed + 1, n_full - 1),
        _latents(seed + 2, (T - 1) * N_LANES + 1),
        _latents(seed + 3, 5),
        jnp.asarray(rng.integers(-128, 128, n_full, dtype=np.int8)),
    ]


@pytest.mark.parametrize("T", [8, 16, 32, 64, 128, 256, 512])
def test_two_phase_bit_identity_every_bucket(T):
    """The batched two-phase encode (phase 1: full emission schedule as
    tensor ops; phase 2: one compaction pass) matches the staged scan
    oracle bit for bit in EVERY pow2 row bucket, with raw-skip shards and
    n_valid boundary rows riding in the same stripe."""
    payloads = _bucket_stripe(T, seed=40 + T)
    ck, mk = eops.encode_payloads(payloads, use_pallas=True)
    cr, mr = eops.encode_payloads(payloads, use_pallas=False)
    assert mk == mr
    assert all(m["rows"] == T for m in mk)
    assert mk[3]["raw"] and mk[4]["raw"]  # tiny + incompressible skip
    if T >= 32:  # smaller buckets can't amortize the 1536-byte header
        assert not mk[0].get("raw")
    for a, b in zip(ck, cr):
        assert _eq(a, b)
    back = eops.decode_payloads(ck, mk)
    for got, want in zip(back, payloads):
        assert _eq(got, want)


def test_two_phase_histogram_impls_bit_identical():
    """Both exact histogram strategies (SWAR popcount sweep / one-hot
    matmul) feed the two-phase schedule identical tables — streams must
    not differ by a bit."""
    from repro.kernels.entropy.rans import rans_encode_pallas

    payloads = _bucket_stripe(32, seed=77)
    outs = {
        h: eops.encode_payloads(
            payloads,
            core_fn=lambda c, nv, h=h: rans_encode_pallas(
                c, nv, histogram=h, interpret=True
            ),
        )
        for h in ("swar", "dot")
    }
    (c_s, m_s), (c_d, m_d) = outs["swar"], outs["dot"]
    assert m_s == m_d
    for a, b in zip(c_s, c_d):
        assert _eq(a, b)


@pytest.mark.parametrize("D", [1, 2, 4, 8])
@pytest.mark.parametrize("T", [8, 64])
def test_two_phase_sharded_buckets_bit_identical(T, D):
    """The shard_map'd twins inherit the two-phase schedule unchanged:
    mesh {1,2,4,8} encodes of boundary-row stripes match the single-device
    streams byte-for-byte and roundtrip."""
    if D > jax.device_count():
        pytest.skip(f"need {D} devices, have {jax.device_count()}")
    from repro.distributed.archival import (
        entropy_decode_sharded,
        entropy_encode_sharded,
    )

    payloads = _bucket_stripe(T, seed=60 + T)
    single_c, single_m = eops.encode_payloads(payloads)
    mesh = Mesh(np.array(jax.devices()[:D]), ("data",))
    c, m = entropy_encode_sharded(payloads, mesh=mesh)
    assert m == single_m
    for a, b in zip(c, single_c):
        assert _eq(a, b)
    back = entropy_decode_sharded(c, m, mesh=mesh)
    for got, want in zip(back, payloads):
        assert _eq(got, want)


def test_golden_v0_stream_decodes():
    """A PR-4-era version-0 (128-lane, lane-major words) stream captured at
    the old HEAD must keep decoding after the lane-group format change —
    on both the kernel and the staged-reference paths, and sharded."""
    import base64
    import json
    import os

    with open(os.path.join(os.path.dirname(__file__),
                           "data_rans_v0.json")) as f:
        g = json.load(f)
    comps = [
        jnp.asarray(np.frombuffer(base64.b64decode(b), np.int8))
        for b in g["streams_b64"]
    ]
    wants = [
        np.frombuffer(base64.b64decode(b), np.int8)
        for b in g["payloads_b64"]
    ]
    assert "version" not in g["metas"][0]  # recorded before the field existed
    assert g["metas"][1].get("raw") is True  # raw-skip shard rides along
    for use_pallas in (True, False):
        back = eops.decode_payloads(comps, g["metas"], use_pallas=use_pallas)
        for got, want in zip(back, wants):
            assert np.array_equal(np.asarray(got), want)
    # re-encoding the same payload now yields a version-1 stream of the
    # same compressed size (the format change moves words, never adds any)
    comp1, metas1 = eops.encode_payloads(
        [jnp.asarray(w) for w in wants]
    )
    assert metas1[0]["version"] == STREAM_VERSION
    assert metas1[0]["n_comp"] == g["metas"][0]["n_comp"]
    from repro.distributed.archival import entropy_decode_sharded

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    back = entropy_decode_sharded(comps, g["metas"], mesh=mesh)
    for got, want in zip(back, wants):
        assert np.array_equal(np.asarray(got), want)


def test_corrupt_meta_rejected():
    comp, metas = eops.encode_payloads([_latents(1, 1000)])
    bad = [dict(metas[0], n_comp=metas[0]["n_comp"] + 4)]
    with pytest.raises(ValueError, match="manifest says"):
        eops.decode_payloads(comp, bad)
    with pytest.raises(ValueError, match="share one padded row count"):
        eops.decode_payloads(
            comp + comp, [metas[0], dict(metas[0], rows=metas[0]["rows"] * 2)]
        )


# ------------------------------------------------------------ pipeline chain
def _clip(key, t=3, b=1, h=32, w=32):
    f = jax.random.uniform(key, (t, b, h, w, 3))
    k = jnp.ones((3, 3)) / 9.0
    from jax import lax

    f = lax.conv_general_dilated(
        f.reshape(t * b, h, w, 3),
        jnp.tile(k[:, :, None, None], (1, 1, 1, 3)).astype(f.dtype),
        (1, 1),
        "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=3,
    ).reshape(t, b, h, w, 3)
    return jnp.clip(f, 0.0, 1.0)


def test_archive_stripe_rans_roundtrip_and_bit_identity():
    """Acceptance: codec_name="rans" stripes roundtrip bit-exactly and the
    Pallas/staged-reference paths agree on every stored byte."""
    cfg = ArchiveConfig(codec=CFG, codec_name="rans")
    params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, sec = rlwe.keygen(jax.random.PRNGKey(1))
    frames = [_clip(jax.random.PRNGKey(30 + i)) for i in range(3)]
    key = jax.random.PRNGKey(7)
    fused, rec = archive_stripe(params, pub, frames, key, cfg, use_pallas=True)
    staged, _ = archive_stripe(params, pub, frames, key, cfg, use_pallas=False)
    for bf, bs in zip(fused.blocks, staged.blocks):
        assert _eq(bf.sealed.body, bs.sealed.body)
        assert bf.manifest["entropy"] == bs.manifest["entropy"]
        assert bf.manifest["entropy"]["codec"] == "rans"
    assert _eq(fused.parity["p"], staged.parity["p"])
    assert _eq(fused.parity["q"], staged.parity["q"])
    for use_pallas in (True, False):
        out = restore_stripe(params, sec, fused, cfg, use_pallas=use_pallas)
        for got, want in zip(out, rec):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), atol=1e-5
            )


def test_archive_stripe_host_codec_fallback():
    from repro.common import compress as host_entropy

    cfg = ArchiveConfig(codec=CFG, codec_name=host_entropy.CODEC_NAME)
    params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, sec = rlwe.keygen(jax.random.PRNGKey(1))
    frames = [_clip(jax.random.PRNGKey(50 + i)) for i in range(2)]
    stripe, rec = archive_stripe(params, pub, frames, jax.random.PRNGKey(9), cfg)
    assert stripe.blocks[0].manifest["entropy"]["codec"] == host_entropy.CODEC_NAME
    out = restore_stripe(params, sec, stripe, cfg)
    for got, want in zip(out, rec):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_zlib_codec_always_available():
    """zlib is stdlib: a codec_name="zlib" stripe must write and restore on
    every host, whatever compressor the host prefers."""
    cfg = ArchiveConfig(codec=CFG, codec_name="zlib")
    params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, sec = rlwe.keygen(jax.random.PRNGKey(1))
    stripe, rec = archive_stripe(
        params, pub, [_clip(jax.random.PRNGKey(61))], jax.random.PRNGKey(5), cfg
    )
    assert stripe.blocks[0].manifest["entropy"]["codec"] == "zlib"
    out = restore_stripe(params, sec, stripe, cfg)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(rec[0]), atol=1e-5)


def test_missing_zstd_raises():
    from repro.common import compress as host_entropy

    if host_entropy.HAVE_ZSTD:
        pytest.skip("zstandard installed; nothing to be missing")
    cfg = ArchiveConfig(codec=CFG, codec_name="zstd")
    params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, _ = rlwe.keygen(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="requires the zstandard"):
        archive_stripe(
            params, pub, [_clip(jax.random.PRNGKey(60))],
            jax.random.PRNGKey(3), cfg,
        )


def test_restore_dispatches_on_manifest_not_cfg():
    """What was written wins: a rans stripe restores even if the caller's
    cfg says a host codec (and vice versa the manifest drives decode)."""
    cfg = ArchiveConfig(codec=CFG, codec_name="rans")
    params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, sec = rlwe.keygen(jax.random.PRNGKey(1))
    stripe, rec = archive_stripe(
        params, pub, [_clip(jax.random.PRNGKey(70))], jax.random.PRNGKey(4), cfg
    )
    out = restore_stripe(
        params, sec, stripe, cfg._replace(codec_name="none")
    )
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(rec[0]), atol=1e-5)


# ------------------------------------------------------- checkpoint chaining
def test_checkpoint_codec_dispatch(tmp_path):
    """Checkpoints default to the on-device coder; the host codec stays a
    working fallback; an unavailable host codec fails loudly at save."""
    from repro.common import compress as host_entropy
    from repro.train.checkpoint import (
        CheckpointError,
        load_checkpoint,
        save_checkpoint,
    )

    state = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)),
        "step": jnp.asarray(3, jnp.int32),
    }
    meta = save_checkpoint(str(tmp_path), 3, state)  # codec_name="rans"
    assert meta["codec"] == "rans"
    assert [m["codec"] for m in meta["entropy"]] == ["rans"] * meta["n_shards"]
    _, back = load_checkpoint(str(tmp_path), state, 3)
    assert _eq(back["w"], state["w"])

    # zlib is stdlib: always a valid fallback, whatever the host prefers
    meta_h = save_checkpoint(str(tmp_path / "host"), 3, state, codec_name="zlib")
    assert meta_h["codec"] == "zlib"
    _, back_h = load_checkpoint(str(tmp_path / "host"), state, 3)
    assert _eq(back_h["w"], state["w"])

    if not host_entropy.HAVE_ZSTD:
        with pytest.raises(CheckpointError, match="host entropy codec"):
            save_checkpoint(str(tmp_path / "bad"), 3, state, codec_name="zstd")


# ------------------------------------------------------------- sharded coder
@pytest.mark.parametrize("D", [1, 2, 4, 8])
def test_sharded_coder_bit_identical(D):
    if D > jax.device_count():
        pytest.skip(f"need {D} devices, have {jax.device_count()}")
    from repro.distributed.archival import (
        entropy_decode_sharded,
        entropy_encode_sharded,
    )

    payloads = [
        _latents(i, n) for i, n in enumerate([5000, 4093, 4096, 2500, 9000])
    ]  # S=5: exercises dummy-shard padding for D in {2, 4, 8}
    single_c, single_m = eops.encode_payloads(payloads)
    mesh = Mesh(np.array(jax.devices()[:D]), ("data",))
    c, m = entropy_encode_sharded(payloads, mesh=mesh)
    assert m == single_m
    for a, b in zip(c, single_c):
        assert _eq(a, b)
    back = entropy_decode_sharded(c, m, mesh=mesh)
    for got, want in zip(back, payloads):
        assert _eq(got, want)


@pytest.mark.parametrize("D", [2, 8])
def test_archive_stripe_sharded_rans(D):
    """Acceptance: the 8-host-device sharded path roundtrips codec_name="rans"
    stripes bit-exactly and matches the single-device archive byte-for-byte."""
    if D > jax.device_count():
        pytest.skip(f"need {D} devices, have {jax.device_count()}")
    from repro.distributed.archival import (
        archive_stripe_sharded,
        restore_stripe_sharded,
    )

    cfg = ArchiveConfig(codec=CFG, codec_name="rans")
    params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, sec = rlwe.keygen(jax.random.PRNGKey(1))
    frames = [_clip(jax.random.PRNGKey(80 + i)) for i in range(3)]
    key = jax.random.PRNGKey(11)
    mesh = Mesh(np.array(jax.devices()[:D]), ("data",))
    sharded, rec = archive_stripe_sharded(
        params, pub, frames, key, cfg, mesh=mesh
    )
    single, _ = archive_stripe(params, pub, frames, key, cfg)
    for bs, b1 in zip(sharded.blocks, single.blocks):
        assert _eq(bs.sealed.body, b1.sealed.body)
        assert bs.manifest["entropy"] == b1.manifest["entropy"]
    assert _eq(sharded.parity["p"], single.parity["p"])
    assert _eq(sharded.parity["q"], single.parity["q"])
    out = restore_stripe_sharded(params, sec, sharded, cfg, mesh=mesh)
    for got, want in zip(out, rec):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
