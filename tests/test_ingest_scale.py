"""Streaming ingest frontend: the pipelined submit ring must be
bit-identical to the synchronous seal path, admission control must shed
loudly (journal + ledger + counters, never silently), and the coalescer's
straggler drain must emit oldest-first partial stripes that round-trip
bit-exact through the fused seal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.ingest_workload import IngestWorkload, WorkloadConfig
from repro import obs
from repro.core.archival.pipeline import (
    ArchiveConfig,
    restore_stripe_payloads,
)
from repro.core.crypto import rlwe
from repro.core.csd.failure import Journal
from repro.distributed.archival import (
    StripeCoalescer,
    seal_coalesced_stripe,
    seal_coalesced_stripes,
)
from repro.obs import EDGE_INGEST_SHED
from repro.obs import names as obs_names
from repro.serving.engine import ArchiveIngest, IngestConfig
from repro.serving.ingest import (
    SHED_PREFIX,
    FrontendConfig,
    StreamIngestFrontend,
)

CFG = ArchiveConfig()
# small heavy-tailed GOPs: fast under interpret mode, still multi-bucket
SIZE_KW = dict(min_bytes=512, median_bytes=1024, sigma=0.4, max_bytes=4096)
NO_DEADLINE = 1e15  # straggler drain disabled (cutoff far in the past)


@pytest.fixture(scope="module")
def keypair():
    return rlwe.keygen(jax.random.PRNGKey(3))


def _eq(a, b) -> bool:
    return np.array_equal(np.asarray(a), np.asarray(b))


def _deep_eq(a, b) -> bool:
    """Structural equality that compares array leaves by value (manifest
    dicts carry jnp arrays, so plain ``==`` is ambiguous)."""
    if isinstance(a, dict):
        return (
            isinstance(b, dict) and a.keys() == b.keys()
            and all(_deep_eq(a[k], b[k]) for k in a)
        )
    if isinstance(a, (list, tuple)):
        return (
            isinstance(b, (list, tuple)) and len(a) == len(b)
            and all(_deep_eq(x, y) for x, y in zip(a, b))
        )
    if isinstance(a, (np.ndarray, jax.Array)):
        return _eq(a, b)
    return a == b


def _assert_stripes_equal(got, want):
    assert len(got.blocks) == len(want.blocks)
    for bg, bw in zip(got.blocks, want.blocks):
        assert _deep_eq(bg.manifest, bw.manifest)
        for field in ("kem_c1", "kem_c2", "nonce", "body"):
            assert _eq(getattr(bg.sealed, field), getattr(bw.sealed, field))
        assert int(bg.sealed.n_valid_u32) == int(bw.sealed.n_valid_u32)
    assert (got.parity is None) == (want.parity is None)
    if got.parity is not None:
        assert _eq(got.parity["p"], want.parity["p"])
        assert _eq(got.parity["q"], want.parity["q"])


# ------------------------------------------------------------ bit-identity
def _drive(pipelined: bool, pub, pump_every: int = 5):
    """Feed the same deterministic workload through the frontend, sealing
    either via the two-slot submit ring (``pump``) or via the synchronous
    ``_seal`` at the same cadence.  Queues/budget are sized so nothing is
    shed and the straggler deadline never fires: stripe composition then
    depends only on admission order, which is identical in both arms."""
    wl = IngestWorkload(
        WorkloadConfig(n_streams=6, n_gops=36, seed=13, **SIZE_KW)
    )
    ing = ArchiveIngest(None, pub, IngestConfig(), seed=21)
    fe = StreamIngestFrontend(
        ing,
        FrontendConfig(
            max_stream_gops=64, queue_budget_bytes=1 << 30,
            batch_stripes=2, deadline_us=NO_DEADLINE,
        ),
        seed=7,
    )
    for a in wl.arrivals:
        fe.offer(a.stream_id, wl.payload(a), wl.manifest(a),
                 novelty=a.novelty)
        if (a.index + 1) % pump_every == 0:
            if pipelined:
                fe.pump()
            else:
                ready = fe._admit_to_coalescer()
                ready += ing.coalescer.drain_expired(fe.cfg.deadline_us)
                ing._seal(ready)
    if pipelined:
        fe.pump()
        fe.drain()
    else:
        ready = fe._admit_to_coalescer() + ing.coalescer.flush()
        ing._seal(ready)
    assert not fe.shed_log  # identity run must not shed
    return ing


def test_submit_ring_bit_identical_to_synchronous(keypair):
    """The two-slot ring (dispatch k+1, THEN commit k) produces byte-for-
    byte the archives the synchronous dispatch+commit path produces: same
    stripe ids, same sealed bodies/KEM/nonces/parity, same manifests."""
    pub, _ = keypair
    ring = _drive(True, pub)
    sync = _drive(False, pub)
    assert sorted(ring._stripes) == sorted(sync._stripes)
    assert len(ring._stripes) >= 3
    for sid in sync._stripes:
        _assert_stripes_equal(ring._stripes[sid], sync._stripes[sid])
        assert _deep_eq(ring._manifests[sid], sync._manifests[sid])


# -------------------------------------------------------- admission control
def test_admission_shed_is_journaled_and_billed(tmp_path, keypair):
    """Under queue pressure the frontend sheds the LOWEST-novelty GOP —
    and every shed leaves a journal record, lands on the ``ingest.shed``
    ledger edge, and bumps the shed counters.  Nothing disappears
    silently: offered == sealed + shed."""
    pub, _ = keypair
    j = Journal(str(tmp_path))
    ing = ArchiveIngest(None, pub, IngestConfig(), seed=4)
    fe = StreamIngestFrontend(
        ing,
        FrontendConfig(
            max_stream_gops=2, queue_budget_bytes=5 * 2048,
            batch_stripes=2, deadline_us=NO_DEADLINE,
        ),
        seed=1,
        journal=j,
    )
    payload = np.ones(2048, np.int8)
    man = {"spec": [], "n_i8": 2048}
    with obs.enabled():
        assert fe.offer(0, payload, man, novelty=0.5)
        assert fe.offer(0, payload, man, novelty=0.6)
        # stream queue full, offered novelty is the lowest -> shed offered
        assert not fe.offer(0, payload, man, novelty=0.4)
        # offered novelty beats the lowest queued -> evict the 0.5
        assert fe.offer(0, payload, man, novelty=0.9)
        # byte budget (5 GOPs): later streams push past it -> global
        # lowest novelty (the 0.6) is shed by the budget pass
        for nov in (0.8, 0.75, 0.85, 0.7):
            fe.offer(1 + int(nov * 100) % 3, payload, man, novelty=nov)
        assert fe.queue_bytes <= fe.cfg.queue_budget_bytes
        totals = obs.OBS.ledger.totals()
        assert totals[EDGE_INGEST_SHED] == sum(
            r.nbytes for r in fe.shed_log
        )
    assert [r.novelty for r in fe.shed_log] == [0.4, 0.5, 0.6]
    assert [r.reason for r in fe.shed_log] == [
        "stream_queue", "stream_queue", "byte_budget",
    ]
    assert fe.metrics.get(obs_names.ING_SHED_GOPS) == 3
    assert fe.metrics.get(obs_names.ING_SHED_BYTES) == 3 * 2048
    # every shed survived into the journal, in shed order, meta intact
    recs = [
        r for r in j.replay() if r["name"].startswith(SHED_PREFIX)
    ]
    assert [r["meta"]["novelty"] for r in recs] == [0.4, 0.5, 0.6]
    assert [r["meta"]["reason"] for r in recs] == [
        "stream_queue", "stream_queue", "byte_budget",
    ]
    assert all("stream_id" in r["meta"] and "seq" in r["meta"]
               for r in recs)
    # the survivors still seal; offered == sealed + shed
    fe.pump()
    fe.drain()
    st = fe.stats()
    offered = 8
    assert st["shed_gops"] == 3
    assert int(fe.metrics.get(obs_names.ING_GOPS)) == offered - 3
    assert st["shed_frac"] == pytest.approx(3 / offered)


# ------------------------------------------------------- straggler drain
def test_drain_expired_emits_oldest_bucket_first():
    """Expired buckets drain oldest-bucket-first (by their oldest GOP's
    submit stamp), insertion order within a bucket; fresh buckets are
    untouched and keep batching toward full stripes."""
    coal = StripeCoalescer(n_shards=4)
    t0 = 1_000_000_000
    now = t0 + 10_000_000_000  # 10s later

    def add(nbytes, t, tag):
        return coal.add(
            tag, np.full(nbytes, tag % 5, np.int8),
            {"spec": [], "n_i8": nbytes, "tag": tag},
            meta={"_t_submit": t},
        )

    # bucket B (8KB rows) is NEWER than bucket A (512B rows) but added
    # first — the drain must still emit A's GOPs first
    assert add(8192, t0 + 1000, 10) == []
    assert add(8192, t0 + 1100, 11) == []
    assert add(512, t0, 0) == []
    assert add(512, t0 + 50, 1) == []
    assert add(512, t0 + 100, 2) == []
    # fresh bucket C (its own 32KB row bucket): stamped "now", must
    # survive the drain
    assert add(32768, now, 99) == []

    out = coal.drain_expired(1.0, now_ns=now)
    tags = [g.manifest["tag"] for cs in out for g in cs.gops]
    assert tags == [0, 1, 2, 10, 11]  # oldest bucket first, FIFO within
    assert [len(cs.gops) for cs in out] == [4, 1]
    # a mixed drained group pads to the LARGEST member bucket
    assert out[0].pad_rows == coal._bucket_of(jnp.zeros(8192, jnp.int8))
    assert coal.n_pending == 1  # the fresh GOP kept batching
    assert coal.queue_bytes == 32768
    # nothing left to re-expire once drained
    assert coal.drain_expired(1.0, now_ns=now) == []


def test_drained_partial_stripe_roundtrips_through_fused_seal(keypair):
    """A deadline-drained SHORT stripe (S=3 of 4) seals bit-identically
    through the batched fused path vs the per-stripe reference, and its
    payloads restore bit-exact (parity verified)."""
    pub, sec = keypair
    coal = StripeCoalescer(n_shards=4)
    rng = np.random.default_rng(9)
    payloads = [
        np.clip(rng.normal(0, 8.0, 1024 + 32 * i), -127, 127).astype(
            np.int8
        )
        for i in range(3)
    ]
    for i, p in enumerate(payloads):
        assert coal.add(
            i, p, {"spec": [], "n_i8": int(p.size)},
            meta={"_t_submit": 1000},
        ) == []
    out = coal.drain_expired(1.0, now_ns=10_000_000_000)
    assert len(out) == 1 and len(out[0].gops) == 3  # short stripe
    key = jax.random.PRNGKey(77)
    batched = seal_coalesced_stripes(pub, out, [key], CFG)
    assert len(batched) == 1
    _assert_stripes_equal(
        batched[0], seal_coalesced_stripe(pub, out[0], key, CFG)
    )
    back, _ = restore_stripe_payloads(sec, batched[0], CFG)
    assert len(back) == 3
    for got, want in zip(back, payloads):
        assert _eq(got, want)
