"""Codec tests: motion kernel vs oracle, layered AE, GOP roundtrip, training."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core.codec.autoencoder import (
    decode_layers,
    dequantize_code,
    encode_layers,
    init_layered_ae,
    quantize_code,
)
from repro.core.codec.feature_extractor import extract_features, init_feature_extractor
from repro.core.codec.layered_codec import (
    CodecConfig,
    decode_gop,
    encode_gop,
    init_codec,
    psnr,
    serialize_bitstream,
)
from repro.core.codec.reference_codecs import dct_matrix, h264_like, hevc_like
from repro.core.codec.training import (
    CodecTrainConfig,
    codec_train_step,
    init_codec_trainer,
)
from repro.kernels.motion.ref import block_motion_ref, warp_blocks
from repro.kernels.motion.ops import estimate_motion, warp

H, W = 64, 64
CFG = CodecConfig(n_layers=3, latent_ch=4, feat_ch=16, mv_cond_ch=4)


def _frames(key, t=3, b=1, h=H, w=W):
    """Smooth-ish synthetic video: drifting blobs."""
    ks = jax.random.split(key, 4)
    yy, xx = jnp.mgrid[0:h, 0:w]
    cx = jax.random.uniform(ks[0], (t, b, 1, 1), minval=10, maxval=w - 10)
    cy = jax.random.uniform(ks[1], (t, b, 1, 1), minval=10, maxval=h - 10)
    drift = jnp.arange(t)[:, None, None, None] * 2.0
    base = jnp.exp(
        -(((xx - cx - drift) ** 2 + (yy - cy) ** 2)) / 200.0
    )  # (t, b, h, w)
    rgb = jnp.stack([base, base * 0.5 + 0.2, 1.0 - base], axis=-1)
    noise = 0.02 * jax.random.normal(ks[2], rgb.shape)
    return jnp.clip(rgb + noise, 0.0, 1.0)


# ------------------------------------------------------------- motion kernel
@pytest.mark.parametrize("block,radius", [(8, 4), (16, 8), (16, 4), (32, 8)])
def test_motion_kernel_matches_ref(block, radius):
    rng = np.random.default_rng(block * 100 + radius)
    h, w = 4 * block, 6 * block
    cur = jnp.asarray(rng.integers(0, 256, (h, w)), jnp.int32)
    prev = jnp.asarray(rng.integers(0, 256, (h, w)), jnp.int32)
    mv_r, sad_r = block_motion_ref(cur, prev, block, radius)
    mv_k, sad_k = estimate_motion(cur, prev, block=block, radius=radius)
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_r))
    np.testing.assert_array_equal(np.asarray(sad_k), np.asarray(sad_r))


@settings(max_examples=15, deadline=None)
@given(
    dy=st.integers(-8, 8),
    dx=st.integers(-8, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_motion_recovers_global_shift(dy, dx, seed):
    rng = np.random.default_rng(seed)
    h, w = 64, 64
    prev = rng.integers(0, 256, (h, w)).astype(np.int32)
    ys = np.clip(np.arange(h) + dy, 0, h - 1)
    xs = np.clip(np.arange(w) + dx, 0, w - 1)
    cur = prev[ys][:, xs]
    mv, sad = estimate_motion(jnp.asarray(cur), jnp.asarray(prev), block=16, radius=8)
    inner = np.asarray(mv)[1:-1, 1:-1].reshape(-1, 2)
    assert (inner == [dy, dx]).all(), (dy, dx, np.unique(inner, axis=0))
    assert np.asarray(sad)[1:-1, 1:-1].max() == 0


def test_warp_inverts_known_shift():
    rng = np.random.default_rng(0)
    prev = jnp.asarray(rng.random((64, 64, 3)), jnp.float32)
    mv = jnp.full((4, 4, 2), 3, jnp.int32)
    out = warp(prev, mv, 16)
    # interior pixels shifted by (3, 3)
    np.testing.assert_allclose(
        np.asarray(out)[:-3, :-3], np.asarray(prev)[3:, 3:], rtol=0, atol=0
    )


# ------------------------------------------------------------- extractor/AE
def test_feature_extractor_shape_and_finite():
    params = init_feature_extractor(jax.random.PRNGKey(0), out_ch=16)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, H, W, 3))
    f = extract_features(params, x)
    assert f.shape == (2, H // 8, W // 8, 16)
    assert np.isfinite(np.asarray(f)).all()


def test_quantize_roundtrip_and_range():
    z = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 8)) * 3
    ls = jnp.zeros((8,))
    zq = quantize_code(z, ls)
    assert np.abs(np.asarray(zq)).max() <= 127
    deq = dequantize_code(zq, ls)
    assert np.abs(np.asarray(deq - z)).max() <= 0.5 + 1e-6  # scale=1 rounding


def test_layered_ae_progressive_quality():
    """More layers must not decrease reconstruction quality (trained or not,
    each extra layer explains the remaining error)."""
    key = jax.random.PRNGKey(0)
    ae = init_layered_ae(key, feat_ch=8, latent_ch=4, n_layers=4, stride=8)
    feats = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 8))
    target = jax.random.uniform(jax.random.PRNGKey(2), (1, 64, 64, 3))
    errs = []
    for k in range(1, 5):
        codes, recon = encode_layers(ae, feats, target, n_layers=k)
        assert len(codes) == k
        errs.append(float(jnp.mean((recon - target) ** 2)))
    # progressive refinement: error non-increasing in K (allow tiny fp slack)
    for a, b in zip(errs, errs[1:]):
        assert b <= a * 1.05, errs


def test_decode_matches_encode_side_recon():
    ae = init_layered_ae(jax.random.PRNGKey(3), feat_ch=8, latent_ch=4, n_layers=2)
    feats = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 8, 8))
    target = jax.random.uniform(jax.random.PRNGKey(5), (1, 64, 64, 3))
    codes, recon = encode_layers(ae, feats, target)
    recon2 = decode_layers(ae, codes)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(recon2), atol=1e-5)


# ------------------------------------------------------------- full codec
def test_gop_encode_decode_consistency():
    params = init_codec(jax.random.PRNGKey(0), CFG)
    frames = _frames(jax.random.PRNGKey(1), t=3)
    codes, recons = encode_gop(params, CFG, frames)
    assert recons.shape == frames.shape
    assert np.isfinite(np.asarray(recons)).all()
    dec = decode_gop(params, CFG, codes)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(recons), atol=1e-5)
    assert codes[0].mv is None and codes[1].mv is not None


def test_bitstream_serialization_compresses():
    params = init_codec(jax.random.PRNGKey(0), CFG)
    frames = _frames(jax.random.PRNGKey(1), t=3)
    codes, _ = encode_gop(params, CFG, frames)
    blob, raw = serialize_bitstream(codes)
    assert 0 < len(blob) < raw
    # codes must be far smaller than raw pixels
    assert raw < frames.size * 4


def test_codec_training_reduces_loss():
    cfg = CodecTrainConfig(codec=CFG)
    params = init_codec(jax.random.PRNGKey(0), CFG)
    trainable, frozen, opt_state = init_codec_trainer(params, cfg)
    clips = _frames(jax.random.PRNGKey(1), t=2)
    first = None
    ext0 = jax.tree.leaves(frozen)[0].copy()
    for i in range(8):
        trainable, opt_state, metrics = codec_train_step(
            trainable, frozen, opt_state, cfg, clips
        )
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first
    # extractor frozen (Alg. 2)
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(frozen)[0]), np.asarray(ext0))


# ------------------------------------------------------------- ref codecs
def test_dct_matrix_orthonormal():
    for n in (8, 16):
        d = np.asarray(dct_matrix(n))
        np.testing.assert_allclose(d @ d.T, np.eye(n), atol=1e-5)


@pytest.mark.parametrize("codec_fn", [h264_like, hevc_like])
def test_classical_codec_roundtrip(codec_fn):
    codec = codec_fn()
    frames = _frames(jax.random.PRNGKey(2), t=3)[:, 0]  # (T, H, W, 3)
    coded, recons = codec.encode_gop(frames, qp=1.0)
    assert recons.shape == frames.shape
    p = float(psnr(recons, frames))
    assert p > 25.0, p  # near-lossless at qp=1 on smooth content
    blob = codec.bitstream_bytes(coded)
    assert len(blob) < frames.size * 4


def test_hevc_like_beats_h264_like_rd():
    """Qualitative RD ordering the paper reports (Fig. 8)."""
    frames = _frames(jax.random.PRNGKey(3), t=2)[:, 0]
    h264 = h264_like()
    hevc = hevc_like()
    c1, r1 = h264.encode_gop(frames, qp=2.0)
    c2, r2 = hevc.encode_gop(frames, qp=2.0)
    p1, p2 = float(psnr(r1, frames)), float(psnr(r2, frames))
    b1, b2 = len(h264.bitstream_bytes(c1)), len(hevc.bitstream_bytes(c2))
    # hevc_like should be no worse on at least one axis at equal qp
    assert (p2 >= p1 - 0.5) or (b2 <= b1)
