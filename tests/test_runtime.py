"""Runtime tests: checkpoint fault tolerance, grad compression, trainer loop,
serving engine."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.crypto import rlwe
from repro.data.tokens import TokenStreamConfig, sample_batch
from repro.data.video import make_streams, render_clip
from repro.models.registry import get_smoke_config
from repro.models.transformer import forward, init_model
from repro.serving.engine import Request, ServeConfig, ServingEngine
from repro.train.checkpoint import (
    CheckpointError,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.train.grad_compress import GradCompressConfig, compress_tree, init_state
from repro.train.trainer import SalientTrainer, TrainerConfig


# ----------------------------------------------------------------- ckpt
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (32, 16)),
        "b": {"c": jnp.arange(100, dtype=jnp.int32), "d": jnp.float32(3.5)},
    }


def _assert_tree_equal(x, y):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip(tmp_path):
    state = _tree()
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    step, loaded = load_checkpoint(str(tmp_path), state)
    assert step == 7
    _assert_tree_equal(state, loaded)


def test_checkpoint_survives_two_lost_shards(tmp_path):
    state = _tree(1)
    meta = save_checkpoint(str(tmp_path), 3, state, n_shards=5, parity="raid6")
    # destroy two shards
    os.remove(os.path.join(tmp_path, meta["shards"][1]))
    with open(os.path.join(tmp_path, meta["shards"][3]), "wb") as f:
        f.write(b"short")  # corrupt (wrong size)
    step, loaded = load_checkpoint(str(tmp_path), state)
    _assert_tree_equal(state, loaded)


def test_checkpoint_sealed_requires_secret(tmp_path):
    pub, s = rlwe.keygen(jax.random.PRNGKey(0))
    state = _tree(2)
    save_checkpoint(str(tmp_path), 1, state, seal_key=pub)
    with pytest.raises(CheckpointError):
        load_checkpoint(str(tmp_path), state)
    _, loaded = load_checkpoint(str(tmp_path), state, secret=s)
    _assert_tree_equal(state, loaded)


def test_checkpoint_picks_latest(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree(1))
    save_checkpoint(str(tmp_path), 5, _tree(5))
    step, loaded = load_checkpoint(str(tmp_path), _tree(0))
    assert step == 5
    _assert_tree_equal(_tree(5), loaded)


# -------------------------------------------------------------- grad comp
def test_grad_compress_accuracy_and_bytes():
    grads = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (64,)) * 0.01,
    }
    st = init_state(grads)
    out1, st, wire, raw = compress_tree(grads, st, GradCompressConfig(n_layers=1))
    out2, _, wire2, _ = compress_tree(grads, init_state(grads), GradCompressConfig(n_layers=2))
    e1 = float(jnp.abs(out1["w"] - grads["w"]).max())
    e2 = float(jnp.abs(out2["w"] - grads["w"]).max())
    assert e2 < e1  # progressive layers refine
    assert int(wire) == (64 * 64 + 64) * 1
    assert int(wire2) == (64 * 64 + 64) * 2
    assert int(raw) == (64 * 64 + 64) * 4


def test_grad_compress_error_feedback_unbiased():
    """With error feedback, repeated compression of a constant gradient
    converges: accumulated output approaches n * g."""
    g = {"w": jnp.full((32,), 0.37)}
    st = init_state(g)
    acc = jnp.zeros((32,))
    n = 20
    for _ in range(n):
        out, st, _, _ = compress_tree(g, st, GradCompressConfig(n_layers=1))
        acc = acc + out["w"]
    np.testing.assert_allclose(np.asarray(acc / n), 0.37, rtol=1e-3)


# ----------------------------------------------------------------- trainer
def test_salient_trainer_end_to_end(tmp_path):
    streams = make_streams(4, height=32, width=32)
    tr = SalientTrainer(streams, str(tmp_path), TrainerConfig(checkpoint_every=2))
    r1 = tr.run_step()
    r2 = tr.run_step(shard_times=[1.0, 1.0, 5.0, 1.0][: tr.cfg.n_shards])
    assert r2.step == 2
    assert np.isfinite(r1.codec_loss)
    assert r1.novel_selected >= 1
    assert r1.archived_streams + r1.novel_selected <= len(streams) + len(streams)
    # checkpoint written at step 2
    assert latest_step(str(tmp_path)) == 2
    # restart resumes from checkpoint
    tr2 = SalientTrainer(streams, str(tmp_path), TrainerConfig(checkpoint_every=2))
    assert tr2.step == 2
    _assert_tree_equal(tr.trainable, tr2.trainable)


def test_trainer_rebalances_on_straggler(tmp_path):
    streams = make_streams(6, height=32, width=32)
    tr = SalientTrainer(streams, str(tmp_path), TrainerConfig(n_shards=2))
    before = dict(tr.placement.assignment)
    rep = None
    for i in range(3):
        rep = tr.run_step(shard_times=[8.0, 1.0])
    assert rep.rebalanced or tr.placement.assignment != before


# ----------------------------------------------------------------- serving
def test_serving_engine_matches_forward_greedy():
    cfg = get_smoke_config("qwen2_0_5b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(max_batch=2, max_len=32))
    prompt = [3, 5, 7]
    eng.submit(Request(rid=0, prompt=prompt, max_new=4))
    out = eng.run_to_completion()[0]
    assert len(out) == len(prompt) + 4

    # greedy reference: iterative full forward
    toks = list(prompt)
    for _ in range(4):
        logits, _ = forward(params, cfg, jnp.asarray([toks], jnp.int32), q_chunk=0)
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert out == toks


def test_serving_engine_batches_multiple_requests():
    cfg = get_smoke_config("mamba2_370m")
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(max_batch=4, max_len=32))
    for r in range(3):
        eng.submit(Request(rid=r, prompt=[2 + r, 4 + r], max_new=3))
    out = eng.run_to_completion()
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 5 for v in out.values())
