"""Chaos-hardened durability tier tests.

Drives the fault-injected CSD fleet (``core/csd/chaos.py``) through the
real storage seams — journal crc32, StragglerMonitor heartbeats, the
background parity scrubber, budget-bounded sharded rebuild, and the
stripe lifecycle — and asserts the acceptance invariant end to end:
every sealed stripe finishes scrub-verified bit-exact, rebuilt
bit-exact, or journaled as retired; zero corruptions go undetected; and
rebuild rounds never exceed their byte budget while replay progresses.

Everything is seed-deterministic: the same ``ChaosConfig.seed`` replays
the same chaos (schedule, findings, rebuilt bytes) bit-for-bit.
"""

import json
import os
import zlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.archival.catalog import (
    CATALOG_PREFIX,
    RETIRE_PREFIX,
    StripeCatalog,
)
from repro.core.archival.pipeline import (
    ArchiveConfig,
    StripeArchive,
    recompute_stripe_parity,
    seal_payload_stripe,
    stripe_manifests,
)
from repro.core.archival.scrub import (
    StripeScrubber,
    plan_retirement,
    retire_stripes,
)
from repro.core.crypto import rlwe
from repro.core.csd.chaos import (
    FAULT_KINDS,
    ChaosConfig,
    ChaosFleet,
    FaultEvent,
    flip_bit,
    torn_commit,
)
from repro.core.csd.failure import Journal, StragglerMonitor
from repro.distributed.archival import plan_rebuild, rebuild_csd_sharded

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------------------ helpers
def _payload_stripe(seed, lens, cfg=None):
    """Seal synthetic int8 payloads as one stripe (no neural codec)."""
    rng = np.random.default_rng(seed)
    cfg = cfg or ArchiveConfig()
    pub, sec = rlwe.keygen(jax.random.PRNGKey(seed + 1))
    flats = [
        jnp.asarray(
            np.clip(np.round(rng.normal(0, 2.0, n)), -128, 127), jnp.int8
        )
        for n in lens
    ]
    mans = [{"n_i8": int(f.shape[0]), "spec": []} for f in flats]
    stripe = seal_payload_stripe(
        pub, flats, mans, jax.random.PRNGKey(seed + 2), cfg
    )
    return stripe, sec, cfg


def _bodies(stripe):
    """Per-shard sealed bodies as numpy uint32 (bit-exactness baseline)."""
    return [
        None if b is None else np.asarray(b.sealed.body, np.uint32).copy()
        for b in stripe.blocks
    ]


def _flip_body_bit(stripe, shard, bit):
    """Flip one bit in shard ``shard``'s sealed body (silent corruption)."""
    body = np.asarray(stripe.blocks[shard].sealed.body, np.uint32).copy()
    u8 = body.view(np.uint8).copy()
    bit = bit % (u8.size * 8)
    u8[bit // 8] ^= 1 << (bit % 8)
    blocks = list(stripe.blocks)
    blocks[shard] = blocks[shard]._replace(
        sealed=blocks[shard].sealed._replace(
            body=jnp.asarray(u8.view(np.uint32))
        )
    )
    return stripe._replace(blocks=blocks)


class _Store:
    """Dict-backed stripe store with the scrubber's get/put interface."""

    def __init__(self, stripes):
        self.stripes = dict(stripes)
        self.puts = []

    def get(self, sid):
        return self.stripes[sid]

    def put(self, sid, stripe):
        self.stripes[sid] = stripe
        self.puts.append(sid)


def _descriptors(n, novelty=None):
    return [
        {
            "stream_id": s,
            "feature": np.full(4, float(s), np.float32),
            "novelty": float(novelty[s]) if novelty is not None else 0.0,
        }
        for s in range(n)
    ]


# ------------------------------------------------------------- chaos fleet
def test_chaos_schedule_deterministic_same_seed():
    cfg = ChaosConfig(n_csds=64, n_rounds=16, seed=7,
                      p_bitflip=0.02, p_loss=0.01, p_restart=0.02,
                      p_dropout=0.05, p_torn=0.01)
    a, b = ChaosFleet(cfg), ChaosFleet(cfg)
    assert a.schedule == b.schedule
    assert np.array_equal(a.step_time_table, b.step_time_table)
    # and a different seed produces a different schedule
    c = ChaosFleet(cfg._replace(seed=8))
    assert c.schedule != a.schedule
    # determinism survives interleaving: tick() order is fixed up front
    ra = [a.tick() for _ in range(cfg.n_rounds)]
    rb = [b.tick() for _ in range(cfg.n_rounds)]
    assert [r.events for r in ra] == [r.events for r in rb]
    assert [r.down for r in ra] == [r.down for r in rb]


def test_chaos_ensure_kinds_backfills_absent_classes():
    # zero probabilities: every event comes from the deterministic backfill
    cfg = ChaosConfig(
        n_csds=16, n_rounds=8, seed=3,
        p_bitflip=0.0, p_loss=0.0, p_restart=0.0, p_dropout=0.0, p_torn=0.0,
        ensure_kinds=FAULT_KINDS,
    )
    fleet = ChaosFleet(cfg)
    for kind in FAULT_KINDS:
        evs = fleet.events_of(kind)
        assert len(evs) == 1, f"{kind} not backfilled"
        assert 0 <= evs[0].round < cfg.n_rounds
        assert 0 <= evs[0].csd < cfg.n_csds
    assert ChaosFleet(cfg).schedule == fleet.schedule


def test_chaos_tick_down_and_loss_semantics():
    cfg = ChaosConfig(
        n_csds=8, n_rounds=6, seed=0, restart_rounds=2,
        p_bitflip=0.0, p_loss=0.0, p_restart=0.0, p_dropout=0.0, p_torn=0.0,
    )
    fleet = ChaosFleet(cfg)
    fleet.schedule[0].append(FaultEvent(0, "loss", 1, 0))
    fleet.schedule[1].append(FaultEvent(1, "restart", 2, 0))
    fleet.schedule[1].append(FaultEvent(1, "dropout", 3, 0))
    r0 = fleet.tick()
    assert r0.down == [1] and r0.lost == [1]
    assert r0.step_times[1] is None and r0.step_times[0] is not None
    r1 = fleet.tick()
    # loss persists; restart + dropout miss this round
    assert r1.down == [1, 2, 3]
    r2 = fleet.tick()
    # dropout was one round; restart_rounds=2 keeps the restart down
    assert r2.down == [1, 2]
    r3 = fleet.tick()
    assert r3.down == [1]  # restart back up; the lost CSD never returns
    fleet.replace(1)
    r4 = fleet.tick()
    assert r4.down == [] and fleet.lost == []
    fleet.tick()
    with pytest.raises(StopIteration):
        fleet.tick()


def test_chaos_rolling_restart_not_declared_dead():
    """The monitor's miss_threshold grace absorbs a rolling restart; a
    permanent loss is still caught within a few rounds."""
    cfg = ChaosConfig(
        n_csds=4, n_rounds=10, seed=5, restart_rounds=2, jitter=0.0,
        p_bitflip=0.0, p_loss=0.0, p_restart=0.0, p_dropout=0.0, p_torn=0.0,
    )
    fleet = ChaosFleet(cfg)
    fleet.schedule[2].append(FaultEvent(2, "restart", 1, 0))
    fleet.schedule[4].append(FaultEvent(4, "loss", 3, 0))
    mon = StragglerMonitor(cfg.n_csds)
    ever_dead_restart, loss_dead_round = False, None
    for r in range(cfg.n_rounds):
        status = mon.update(fleet.tick().step_times)
        if 1 in status.dead:
            ever_dead_restart = True
        if 3 in status.dead and loss_dead_round is None:
            loss_dead_round = r
    assert not ever_dead_restart, "rolling restart was declared dead"
    assert loss_dead_round is not None, "permanent loss never detected"
    assert loss_dead_round <= 4 + mon.miss_threshold


def test_flip_bit_deterministic_single_bit():
    payload = bytes(range(256)) * 4
    ev = FaultEvent(0, "bitflip", 0, 123457)
    out = flip_bit(payload, ev)
    assert out == flip_bit(payload, ev)
    diff = [
        (a ^ b) for a, b in zip(payload, out) if a != b
    ]
    assert len(diff) == 1 and bin(diff[0]).count("1") == 1
    assert flip_bit(b"", ev) == b""


# ------------------------------------------------------------ journal crc32
def test_journal_crc_detects_silent_bitflip(tmp_path):
    j = Journal(str(tmp_path))
    j.commit("a.bin", b"A" * 64, {"k": 1})
    j.commit("b.bin", b"B" * 64)
    # silent corruption: same length, one bit flipped on disk
    with open(os.path.join(j.root, "a.bin"), "r+b") as f:
        f.seek(10)
        byte = f.read(1)[0]
        f.seek(10)
        f.write(bytes([byte ^ 0x10]))
    recs = j.replay()
    assert [r["name"] for r in recs] == ["b.bin"]
    # the scrubber's entry keeps the corrupt record, flagged
    recs = j.replay(verify_crc=False)
    assert [r["name"] for r in recs] == ["a.bin", "b.bin"]
    assert recs[0]["crc_ok"] is False and "crc_ok" not in recs[1]
    with pytest.raises(ValueError, match="crc32"):
        j.read("a.bin", crc32=recs[0]["crc32"])
    assert j.read("b.bin", crc32=recs[1]["crc32"]) == b"B" * 64


def test_journal_pre_crc_records_still_accepted(tmp_path):
    j = Journal(str(tmp_path))
    with open(os.path.join(j.root, "old.bin"), "wb") as f:
        f.write(b"legacy")
    with open(j.path, "a") as f:
        f.write(json.dumps(
            {"name": "old.bin", "bytes": 6, "ts": 0, "meta": {}}
        ) + "\n")
    recs = j.replay()
    assert [r["name"] for r in recs] == ["old.bin"]


def test_torn_commit_discarded_by_replay(tmp_path):
    j = Journal(str(tmp_path))
    j.commit("good.bin", b"x" * 128)
    payload = b"y" * 512
    torn_commit(j, "torn.bin", payload, FaultEvent(0, "torn", 0, 77),
                {"k": 2})
    # the record claims the full size + correct crc, but the body is short
    assert os.path.getsize(os.path.join(j.root, "torn.bin")) == 77 % 512
    for verify in (True, False):
        assert [r["name"] for r in j.replay(verify_crc=verify)] == [
            "good.bin"
        ]
    # a later clean re-commit of the same name replays fine — the old torn
    # record validates again too (body now matches its claimed size/crc),
    # and last-wins name maps resolve to the fresh record
    j.commit("torn.bin", payload)
    recs = {r["name"]: r for r in j.replay()}
    assert set(recs) == {"good.bin", "torn.bin"}
    assert j.read("torn.bin") == payload


def test_journal_compact_preserves_crc_failed_records(tmp_path):
    j = Journal(str(tmp_path))
    j.commit("keep.bin", b"k" * 32)
    j.commit("drop.bin", b"d" * 32)
    j.commit("hurt.bin", b"h" * 32)
    with open(os.path.join(j.root, "hurt.bin"), "r+b") as f:
        f.write(b"H")  # crc now fails (length unchanged)
    dropped = j.compact(["drop.bin"])
    assert dropped == 1
    assert not os.path.exists(os.path.join(j.root, "drop.bin"))
    assert os.path.exists(os.path.join(j.root, "hurt.bin"))
    recs = j.replay(verify_crc=False)
    assert [r["name"] for r in recs] == ["keep.bin", "hurt.bin"]
    assert recs[1]["crc_ok"] is False  # still awaiting scrub repair


# ------------------------------------------------------------------- scrub
def test_scrub_clean_stripe_yields_no_findings():
    stripe, _, _ = _payload_stripe(0, [4096, 5000, 6100])
    store = _Store({"s0": stripe})
    sc = StripeScrubber(store.get, store.put)
    assert sc.scrub_stripe("s0") == []
    assert store.puts == []


@pytest.mark.parametrize("shard", [0, 1, 2, 3])
def test_scrub_locates_and_repairs_any_shard(shard):
    stripe, _, _ = _payload_stripe(10 + shard, [3000, 4096, 2500, 3500])
    want = _bodies(stripe)
    store = _Store({"s0": _flip_body_bit(stripe, shard, 997 + 13 * shard)})
    sc = StripeScrubber(store.get, store.put)
    findings = sc.scrub_stripe("s0")
    assert len(findings) == 1
    f = findings[0]
    assert f.kind == "shard" and f.shard == shard and f.repaired
    got = _bodies(store.stripes["s0"])
    for w, g in zip(want, got):
        assert np.array_equal(w, g)  # bit-exact repair
    assert sc.scrub_stripe("s0") == []  # clean after repair


def test_scrub_repairs_rotted_parity_strips():
    for which in ("p", "q"):
        stripe, _, _ = _payload_stripe(20, [4096, 3000, 5000])
        parity = dict(stripe.parity)
        strip = np.asarray(parity[which], np.uint8).copy()
        strip[7] ^= 0x40
        parity[which] = strip
        store = _Store({"s0": stripe._replace(parity=parity)})
        sc = StripeScrubber(store.get, store.put)
        findings = sc.scrub_stripe("s0")
        assert [f.kind for f in findings] == [which]
        assert findings[0].repaired
        got = recompute_stripe_parity(store.stripes["s0"])
        fixed = store.stripes["s0"].parity
        assert np.array_equal(got["p"], np.asarray(fixed["p"]))
        assert np.array_equal(got["q"], np.asarray(fixed["q"]))


def test_scrub_multi_shard_corruption_unlocatable():
    stripe, _, _ = _payload_stripe(30, [4096, 4096, 4096])
    stripe = _flip_body_bit(stripe, 0, 11)
    stripe = _flip_body_bit(stripe, 2, 5000)
    store = _Store({"s0": stripe})
    sc = StripeScrubber(store.get, store.put)
    findings = sc.scrub_stripe("s0")
    assert [f.kind for f in findings] == ["unlocatable"]
    assert not findings[0].repaired and store.puts == []


def test_scrub_raid5_detects_but_cannot_locate():
    cfg = ArchiveConfig(parity="raid5")
    stripe, _, _ = _payload_stripe(40, [3000, 3500], cfg)
    store = _Store({"s0": _flip_body_bit(stripe, 1, 321)})
    sc = StripeScrubber(store.get, store.put)
    findings = sc.scrub_stripe("s0")
    assert [f.kind for f in findings] == ["unlocatable"]
    assert not findings[0].repaired
    # clean RAID-5 stripe verifies clean
    clean, _, _ = _payload_stripe(41, [3000, 3500], cfg)
    store2 = _Store({"c": clean})
    assert StripeScrubber(store2.get).scrub_stripe("c") == []


def test_scrub_noparity_and_degraded_classified_not_raised():
    cfg = ArchiveConfig(parity="none")
    stripe, _, _ = _payload_stripe(50, [2048, 2048], cfg)
    store = _Store({"s0": stripe})
    sc = StripeScrubber(store.get, store.put)
    assert [f.kind for f in sc.scrub_stripe("s0")] == ["noparity"]
    # degraded stripe (shard out for rebuild): deferred, never a crash
    full, _, _ = _payload_stripe(51, [2048, 2048, 2048])
    blocks = list(full.blocks)
    blocks[1] = None
    store2 = _Store({"d": full._replace(blocks=blocks)})
    sc2 = StripeScrubber(store2.get, store2.put)
    findings = sc2.scrub_stripe("d")
    assert [f.kind for f in findings] == ["degraded"]
    assert not findings[0].repaired


def test_scrub_without_put_is_detect_only():
    stripe, _, _ = _payload_stripe(60, [4096, 3000, 5000])
    corrupt = _flip_body_bit(stripe, 1, 200)
    store = _Store({"s0": corrupt})
    sc = StripeScrubber(store.get)  # no put_stripe
    findings = sc.scrub_stripe("s0")
    assert [(f.kind, f.shard, f.repaired) for f in findings] == [
        ("shard", 1, False)
    ]
    assert np.array_equal(
        _bodies(store.stripes["s0"])[1], _bodies(corrupt)[1]
    )  # untouched


def test_scrub_round_budget_minimum_progress_and_cursor():
    stripes = {
        f"s{i}": _payload_stripe(70 + i, [4096, 4096])[0] for i in range(4)
    }
    store = _Store(stripes)
    sc = StripeScrubber(store.get, store.put)
    ids = sorted(stripes)
    # budget below one stripe: still scans exactly one (minimum progress)
    r = sc.scrub_round(ids, budget_bytes=16)
    assert r.stripes_checked == 1 and r.bytes_scrubbed > 16
    # the persistent cursor covers the whole archive across rounds
    seen = {ids[0]}
    for _ in range(3):
        rnd = sc.scrub_round(ids, budget_bytes=16)
        assert rnd.stripes_checked == 1
        seen.add(ids[(sc._next - 1) % len(ids)])
    assert seen == set(ids)
    # a big budget covers everything in one round; what ships host-side is
    # the P(+Q) strips, accounted separately from the scanned body bytes
    big = sc.scrub_round(ids, budget_bytes=1 << 30)
    assert big.stripes_checked == len(ids)
    assert big.syndrome_bytes > 0 and big.bytes_scrubbed > 0


# ----------------------------------------------------------------- rebuild
def _cataloged_stripes(n_stripes, lens, novelty_by_stripe, journal=None,
                       cfg=None, seed0=100):
    cat = StripeCatalog(journal)
    stripes, manifests = {}, {}
    for i in range(n_stripes):
        sid = f"s{i:02d}"
        stripe, _, _ = _payload_stripe(seed0 + i, lens, cfg)
        stripes[sid] = stripe
        manifests[sid] = stripe_manifests(stripe)
        cat.add_stripe(
            sid, stripe,
            _descriptors(len(lens), novelty=[novelty_by_stripe[i]] * len(lens)),
            sealed_step=i,
        )
    return cat, stripes, manifests


def test_plan_rebuild_orders_by_salience():
    cat, stripes, _ = _cataloged_stripes(3, [2048, 2048, 2048],
                                         novelty_by_stripe=[0.1, 0.9, 0.5])
    items = plan_rebuild(cat, dead_csd=1)
    assert [it.stripe_id for it in items] == ["s01", "s02", "s00"]
    assert all(it.shard == 1 for it in items)
    assert all(it.body_bytes > 0 for it in items)


def test_rebuild_single_loss_bit_exact():
    cat, stripes, manifests = _cataloged_stripes(
        2, [3000, 4096, 2500], novelty_by_stripe=[0.5, 0.9]
    )
    want = {sid: _bodies(s) for sid, s in stripes.items()}
    for sid in stripes:  # CSD 2 dies: shard 2 of every stripe
        blocks = list(stripes[sid].blocks)
        blocks[2] = None
        stripes[sid] = stripes[sid]._replace(blocks=blocks)
    rebuilt = {}
    rnd = rebuild_csd_sharded(
        stripes.__getitem__, manifests.__getitem__,
        plan_rebuild(cat, dead_csd=2),
        budget_bytes=1 << 30,
        put_shard=lambda sid, sh, blk: rebuilt.setdefault(sid, {}).update(
            {sh: blk}
        ),
    )
    assert not rnd.remaining and len(rnd.rebuilt) == 2
    for sid in stripes:
        got = np.asarray(rebuilt[sid][2].sealed.body, np.uint32)
        assert np.array_equal(got, want[sid][2]), sid
        man = manifests[sid][2]
        blk = rebuilt[sid][2]
        assert int(blk.sealed.n_valid_u32) == want[sid][2].size
        assert np.array_equal(
            np.asarray(blk.sealed.nonce), np.asarray(man["nonce"])
        )


def test_rebuild_double_loss_host_recover_path():
    cat, stripes, manifests = _cataloged_stripes(
        1, [3000, 4096, 2500, 3600], novelty_by_stripe=[0.5]
    )
    sid = "s00"
    want = _bodies(stripes[sid])
    blocks = list(stripes[sid].blocks)
    blocks[0] = None  # another shard already missing...
    blocks[3] = None  # ...when CSD 3's rebuild runs: RAID-6 double loss
    stripes[sid] = stripes[sid]._replace(blocks=blocks)
    out = {}
    rnd = rebuild_csd_sharded(
        stripes.__getitem__, manifests.__getitem__,
        plan_rebuild(cat, dead_csd=3),
        budget_bytes=1 << 30,
        put_shard=lambda s, sh, blk: out.__setitem__((s, sh), blk),
    )
    assert len(rnd.rebuilt) == 1
    got = np.asarray(out[(sid, 3)].sealed.body, np.uint32)
    assert np.array_equal(got, want[3])


def test_rebuild_budget_is_strict_and_preserves_priority():
    cat, stripes, manifests = _cataloged_stripes(
        3, [4096, 4096], novelty_by_stripe=[0.2, 0.9, 0.6]
    )
    for sid in stripes:
        blocks = list(stripes[sid].blocks)
        blocks[0] = None
        stripes[sid] = stripes[sid]._replace(blocks=blocks)
    items = plan_rebuild(cat, dead_csd=0)
    assert [it.stripe_id for it in items] == ["s01", "s02", "s00"]
    # budget fits any ONE item but never two (rANS bodies vary slightly)
    one = max(it.body_bytes for it in items)
    assert one < 2 * min(it.body_bytes for it in items)
    out = {}
    # budget fits exactly one: the round must NOT skip ahead to a smaller
    # item (there are none smaller here, but the order assert below would
    # catch reordering) and must never exceed the ceiling
    rnd = rebuild_csd_sharded(
        stripes.__getitem__, manifests.__getitem__, items,
        budget_bytes=one, put_shard=lambda s, sh, b: out.__setitem__(s, b),
    )
    assert [it.stripe_id for it in rnd.rebuilt] == ["s01"]
    assert rnd.bytes_rebuilt <= one
    assert [it.stripe_id for it in rnd.remaining] == ["s02", "s00"]
    # drain over successive rounds, ceiling always respected
    remaining = rnd.remaining
    while remaining:
        rnd = rebuild_csd_sharded(
            stripes.__getitem__, manifests.__getitem__, remaining,
            budget_bytes=one,
            put_shard=lambda s, sh, b: out.__setitem__(s, b),
        )
        assert rnd.bytes_rebuilt <= one
        assert rnd.rebuilt  # minimum progress is the planner's job; budget
        remaining = rnd.remaining
    assert set(out) == set(stripes)


# --------------------------------------------------------- stripe lifecycle
def test_plan_retirement_ttl_and_novelty_gates(tmp_path):
    cat = StripeCatalog(Journal(str(tmp_path)))
    specs = [
        ("old_dull", 0, 0.1),     # aged out, low salience -> retire
        ("old_hot", 0, 0.9),      # aged out but still novel -> keep
        ("young", 90, 0.05),      # inside TTL -> keep
        ("unstamped", -1, 0.0),   # no seal stamp -> never expires
    ]
    for i, (sid, step, nov) in enumerate(specs):
        stripe, _, _ = _payload_stripe(400 + i, [1024, 1024])
        cat.add_stripe(sid, stripe, _descriptors(2, [nov, nov]),
                       sealed_step=step)
    ids = plan_retirement(cat, now_step=100, ttl_steps=50, max_novelty=0.5)
    assert ids == ["old_dull"]
    # no novelty bar: age alone decides, least-salient first
    ids = plan_retirement(cat, now_step=100, ttl_steps=50)
    assert ids == ["old_dull", "old_hot"]
    assert plan_retirement(cat, now_step=100, ttl_steps=50, limit=1) == [
        "old_dull"
    ]
    assert plan_retirement(cat, now_step=10, ttl_steps=50) == []


def test_retire_stripes_crash_safe_order(tmp_path):
    j = Journal(str(tmp_path))
    cat = StripeCatalog(j)
    stripes = {}
    for i in range(2):
        sid = f"s{i}"
        stripe, _, _ = _payload_stripe(200 + i, [2048, 2048])
        stripes[sid] = stripe
        cat.add_stripe(sid, stripe, _descriptors(2, [0.1, 0.1]),
                       sealed_step=i)
        j.commit(f"{sid}.bin", b"body" * 64, {"stripe_id": sid})
    report = retire_stripes(
        cat, ["s0"], journal=j, records_for=lambda sid: [f"{sid}.bin"]
    )
    assert report.retired == ["s0"] and report.keys_recyclable == ["s0"]
    assert report.dropped_entries == 2
    # catalog record AND body dropped; retirement record survives compaction
    names = [r["name"] for r in j.replay()]
    assert f"{CATALOG_PREFIX}s0.json" not in names
    assert "s0.bin" not in names
    assert f"{RETIRE_PREFIX}s0.json" in names
    assert not os.path.exists(os.path.join(j.root, "s0.bin"))
    assert os.path.exists(os.path.join(j.root, "s1.bin"))
    # restart: the retired stripe never comes back
    cat2 = StripeCatalog(Journal(str(tmp_path)))
    cat2.load()
    assert {e.stripe_id for e in cat2.entries} == {"s1"}
    assert cat2.retired == {"s0"}


def test_retirement_record_wins_over_catalog_record(tmp_path):
    """Crash between journaling the retirement and compacting: the catalog
    record (and body) are still on disk, but replay must honor the
    retirement — it is the durable fact."""
    j = Journal(str(tmp_path))
    cat = StripeCatalog(j)
    stripe, _, _ = _payload_stripe(300, [2048, 2048])
    cat.add_stripe("s0", stripe, _descriptors(2, [0.1, 0.1]), sealed_step=0)
    cat.retire_stripe("s0")  # journaled; "crash" before any compaction
    names = [r["name"] for r in j.replay()]
    assert f"{CATALOG_PREFIX}s0.json" in names  # still present...
    assert f"{RETIRE_PREFIX}s0.json" in names
    cat2 = StripeCatalog(Journal(str(tmp_path)))
    cat2.load()
    assert cat2.entries == [] and cat2.retired == {"s0"}  # ...but ignored
    # re-cataloging a retired id is refused in-memory too
    assert "s0" not in cat2._stripe_ids


# ----------------------------------------------------- end-to-end chaos run
def _chaos_e2e(seed):
    """Full durability loop under a fault-injected fleet.

    Builds a cataloged archive of payload stripes, then drives
    ``ChaosConfig.n_rounds`` of chaos with every fault class guaranteed
    present.  Each round: faults apply, heartbeats feed the monitor, a
    byte-budgeted scrub round runs, lost CSDs rebuild under a strict
    budget, and replay (a catalog top-k query) must make progress.
    Returns a summary for determinism comparison.
    """
    n_shards, n_stripes = 4, 4
    lens = [3000, 4096, 2500, 3600]
    cat, stripes, manifests = _cataloged_stripes(
        n_stripes, lens, novelty_by_stripe=[0.2, 0.9, 0.5, 0.7],
        seed0=1000 + seed,
    )
    pristine = {sid: _bodies(s) for sid, s in stripes.items()}
    store = _Store(stripes)
    scrubber = StripeScrubber(store.get, store.put)
    fleet = ChaosFleet(ChaosConfig(
        n_csds=n_shards, n_rounds=12, seed=seed,
        p_bitflip=0.05, p_loss=0.0, p_restart=0.0, p_dropout=0.05,
        p_torn=0.0, restart_rounds=2,
        ensure_kinds=FAULT_KINDS,
    ))
    mon = StragglerMonitor(n_shards)
    injected = 0        # corruptions injected into retained bodies
    escalated = 0       # unlocatable findings restored from the replica tier
    dirty = set()       # stripes corrupted since their last verification
    rebuild_budget = max(it.body_bytes for it in plan_rebuild(cat, 0))
    scrub_budget = 1 << 30  # every round verifies the whole (tiny) archive
    findings_log, rebuilt_bytes_log, replay_log = [], [], []
    lost_csds = set()
    torn_discarded = 0

    def _replica_restore(sid):
        """The documented escalation for unlocatable corruption: restore
        the stripe from a replica (here: the pristine copy)."""
        orig, _, _ = _payload_stripe(
            1000 + seed + int(sid[1:]), lens
        )
        store.stripes[sid] = orig

    import tempfile
    with tempfile.TemporaryDirectory() as td:
        journal = Journal(td)
        journal.commit("seed.bin", b"s" * 64)
        for rnd_i in range(fleet.cfg.n_rounds):
            fr = fleet.tick()
            mon.update(fr.step_times)
            for ev in fr.events:
                csd = ev.csd % n_shards
                if ev.kind == "bitflip":
                    sid = sorted(store.stripes)[ev.param % len(store.stripes)]
                    stripe = store.stripes[sid]
                    # survivors feeding a rebuild must be verified first, so
                    # the harness (like a real scrubber-gated rebuild) only
                    # corrupts whole stripes — degraded ones are mid-rebuild
                    if all(b is not None for b in stripe.blocks):
                        store.stripes[sid] = _flip_body_bit(
                            stripe, csd, ev.param
                        )
                        injected += 1
                        dirty.add(sid)
                elif ev.kind == "loss":
                    if csd not in lost_csds:
                        lost_csds.add(csd)
                        for sid, stripe in store.stripes.items():
                            blocks = list(stripe.blocks)
                            blocks[csd] = None
                            store.stripes[sid] = stripe._replace(
                                blocks=blocks
                            )
                elif ev.kind == "torn":
                    torn_commit(journal, f"torn_{rnd_i}.bin", b"t" * 256, ev)
                    torn_discarded += 1
            # scrub: locate + repair silent flips; degraded stripes defer.
            # The acceptance invariant checked EVERY round: anything
            # corrupted since the last pass must surface as a finding.
            sr = scrubber.scrub_round(sorted(store.stripes), scrub_budget)
            found_sids = {f.stripe_id for f in sr.findings}
            assert dirty <= found_sids, (
                f"round {rnd_i}: undetected corruption in "
                f"{dirty - found_sids}"
            )
            for f in sr.findings:
                findings_log.append((rnd_i,) + tuple(f))
                if f.kind == "unlocatable" or (
                    f.kind == "degraded" and f.stripe_id in dirty
                ):
                    _replica_restore(f.stripe_id)
                    escalated += 1
            dirty.clear()
            # rebuild lost CSDs under a strict per-round budget
            for csd in sorted(lost_csds):
                items = [
                    it for it in plan_rebuild(cat, csd)
                    if it.stripe_id in store.stripes
                    and store.stripes[it.stripe_id].blocks[it.shard] is None
                ]
                rr = rebuild_csd_sharded(
                    store.get, manifests.__getitem__, items,
                    budget_bytes=rebuild_budget,
                    put_shard=lambda sid, sh, blk: store.put(
                        sid,
                        store.stripes[sid]._replace(blocks=[
                            blk if i == sh else b
                            for i, b in enumerate(
                                store.stripes[sid].blocks
                            )
                        ]),
                    ),
                )
                assert rr.bytes_rebuilt <= rebuild_budget
                rebuilt_bytes_log.append(rr.bytes_rebuilt)
                if not rr.remaining:
                    lost_csds.discard(csd)
                    fleet.replace(csd)
            # replay progresses every round regardless of chaos: the
            # catalog answers top-k without touching a payload byte
            top = cat.topk(2)
            assert len(top) == 2
            replay_log.append(tuple(e.stripe_id for e in top))
        # torn commits never replay as data
        live = [r["name"] for r in journal.replay()]
        assert live == ["seed.bin"]

    # retire the least-salient stripe through the lifecycle tier
    retire_ids = plan_retirement(cat, now_step=10 ** 6, ttl_steps=1, limit=1)
    report = retire_stripes(cat, retire_ids)
    for sid in report.keys_recyclable:
        store.stripes.pop(sid)
        pristine.pop(sid)

    # settle: drain any still-lost CSDs, then scrub until clean
    while lost_csds:
        csd = sorted(lost_csds)[0]
        items = [
            it for it in plan_rebuild(cat, csd)
            if it.stripe_id in store.stripes
            and store.stripes[it.stripe_id].blocks[it.shard] is None
        ]
        rr = rebuild_csd_sharded(
            store.get, manifests.__getitem__, items,
            budget_bytes=1 << 30,
            put_shard=lambda sid, sh, blk: store.put(
                sid,
                store.stripes[sid]._replace(blocks=[
                    blk if i == sh else b
                    for i, b in enumerate(store.stripes[sid].blocks)
                ]),
            ),
        )
        assert not rr.remaining
        lost_csds.discard(csd)
    for _ in range(4):
        sr = scrubber.scrub_round(sorted(store.stripes), 1 << 30)
        findings_log.extend((99,) + tuple(f) for f in sr.findings)
        if not sr.findings:
            break

    # ---- acceptance: every retained stripe verified bit-exact ----
    final = scrubber.scrub_round(sorted(store.stripes), 1 << 30)
    assert final.findings == [], final.findings
    for sid, want in pristine.items():
        got = _bodies(store.stripes[sid])
        for w, g in zip(want, got):
            assert np.array_equal(w, g), f"{sid} not bit-exact"
    assert injected > 0, "chaos injected no corruption — test is vacuous"
    assert torn_discarded > 0
    assert report.retired and report.retired[0] not in store.stripes
    return {
        "injected": injected,
        "escalated": escalated,
        "findings": findings_log,
        "rebuilt_bytes": rebuilt_bytes_log,
        "replay": replay_log,
        "retired": report.retired,
    }


def test_chaos_end_to_end_acceptance():
    summary = _chaos_e2e(seed=17)
    # ≥3 fault classes actually fired (ensure_kinds guarantees scheduling;
    # the harness asserts the data-visible ones had effect)
    assert summary["injected"] > 0          # bitflip class
    assert any(b >= 0 for b in summary["rebuilt_bytes"])  # loss class
    assert summary["rebuilt_bytes"], "loss never triggered a rebuild"
    assert summary["retired"], "lifecycle tier never retired"
    assert all(len(r) == 2 for r in summary["replay"])


def test_chaos_end_to_end_deterministic():
    a = _chaos_e2e(seed=23)
    b = _chaos_e2e(seed=23)
    assert a["findings"] == b["findings"]
    assert a["rebuilt_bytes"] == b["rebuilt_bytes"]
    assert a["replay"] == b["replay"]
    assert a["retired"] == b["retired"]
    assert (a["injected"], a["escalated"]) == (b["injected"], b["escalated"])


# ------------------------------------------------- trainer scrub interleave
def test_trainer_scrub_rounds_interleave_cleanly(tmp_path):
    from repro.data.video import make_streams
    from repro.train.trainer import SalientTrainer, TrainerConfig

    streams = make_streams(4, height=32, width=32)
    cfg = TrainerConfig(
        n_shards=2, checkpoint_every=3, replay_every=2,
        scrub_every=2, scrub_budget_bytes=1 << 20,
    )
    tr = SalientTrainer(streams, str(tmp_path), cfg)
    reports = [tr.run_step(shard_times=[1.0, 1.0]) for _ in range(4)]
    assert any(r.scrub_stripes > 0 for r in reports), "scrub never fired"
    assert all(r.scrub_findings == 0 for r in reports)  # clean archive
    assert any(r.replayed_gops for r in reports)  # replay unaffected


def test_trainer_scrub_repairs_journaled_bitflip(tmp_path):
    from repro.data.video import make_streams
    from repro.train.trainer import SalientTrainer, TrainerConfig

    streams = make_streams(4, height=32, width=32)
    cfg = TrainerConfig(
        n_shards=2, checkpoint_every=10, replay_every=2,
        scrub_every=1, scrub_budget_bytes=1 << 22,
    )
    tr = SalientTrainer(streams, str(tmp_path), cfg)
    tr.run_step(shard_times=[1.0, 1.0])
    assert len(tr.catalog) > 0
    # flip one bit in a journaled stripe body on disk (silent corruption)
    j = tr.journal
    recs = {r["name"]: r for r in j.replay()}
    name = sorted(n for n in recs if n.endswith(".bin")
                  and not n.endswith(".parity.bin"))[0]
    path = os.path.join(j.root, name)
    with open(path, "r+b") as f:
        f.seek(40)
        byte = f.read(1)[0]
        f.seek(40)
        f.write(bytes([byte ^ 0x04]))
    tr._stripes.pop(name[: -len(".bin")], None)  # drop the hot copy
    # crc detects: default replay refuses the record now
    assert name not in {r["name"] for r in j.replay()}
    # the scrub stage locates + repairs it from parity and re-commits
    rep = tr.run_step(shard_times=[1.0, 1.0])
    assert rep.scrub_findings >= 1
    assert rep.scrub_repaired >= 1
    recs2 = {r["name"]: r for r in j.replay()}
    assert name in recs2  # crc re-armed by the repair commit
    with open(path, "rb") as f:
        assert (zlib.crc32(f.read()) & 0xFFFFFFFF) == recs2[name]["crc32"]
    # and the archive is clean again for the next scrub pass
    rep2 = tr.run_step(shard_times=[1.0, 1.0])
    assert rep2.scrub_findings == 0
