"""Retrieval datapath tests: salience catalog, query planner, shard-subset
and degraded reads, entropy raw-skip, and the trainer replay loop."""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core.archival.catalog import StripeCatalog
from repro.core.archival.pipeline import (
    ArchiveConfig,
    StripeArchive,
    archive_stripe,
    recover_stripe,
    restore_stripe,
    restore_stripe_payloads,
    seal_payload_stripe,
    stripe_manifests,
    stripe_manifests_from_json,
    stripe_manifests_to_json,
)
from repro.core.codec.layered_codec import CodecConfig, init_codec
from repro.core.crypto import rlwe
from repro.core.csd import costmodel as cm
from repro.core.csd.failure import Journal
from repro.core.csd.retrieval import plan_retrieval
from repro.kernels.entropy import ops as eops

CFG = CodecConfig(n_layers=2, latent_ch=4, feat_ch=16, mv_cond_ch=4)


def _payload_stripe(seed, lens, cfg=None, peaked=True):
    """Seal synthetic int8 payloads as one stripe (no neural codec)."""
    rng = np.random.default_rng(seed)
    cfg = cfg or ArchiveConfig()
    pub, sec = rlwe.keygen(jax.random.PRNGKey(seed + 1))
    flats = []
    for n in lens:
        if peaked:
            x = np.clip(np.round(rng.normal(0, 2.0, n)), -128, 127)
        else:
            x = rng.integers(-128, 128, n)
        flats.append(jnp.asarray(x, jnp.int8))
    mans = [{"n_i8": int(f.shape[0]), "spec": []} for f in flats]
    stripe = seal_payload_stripe(
        pub, flats, mans, jax.random.PRNGKey(seed + 2), cfg
    )
    return stripe, flats, sec, cfg


def _eq(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------- catalog
def test_catalog_add_persist_reload(tmp_path):
    stripe, flats, _, _ = _payload_stripe(0, [4096, 5000, 6100])
    cat = StripeCatalog(Journal(str(tmp_path)))
    rng = np.random.default_rng(1)
    cat.add_stripe(
        "s0", stripe,
        [{"stream_id": i, "feature": rng.normal(size=8), "novelty": 0.5 * i}
         for i in range(3)],
    )
    assert len(cat) == 3 and cat.n_stripes == 1
    assert cat.bytes_indexed == sum(
        4 * int(b.sealed.n_valid_u32) for b in stripe.blocks
    )
    # byte geometry comes from the stripe, not the caller
    assert cat.entries[1].n_comp == stripe.blocks[1].manifest["entropy"]["n_comp"]
    # replay from the journal reproduces the index
    cat2 = StripeCatalog(Journal(str(tmp_path)))
    assert cat2.load() == 1
    assert len(cat2) == 3
    np.testing.assert_allclose(cat2.features(), cat.features())
    assert [e.novelty for e in cat2.entries] == [0.0, 0.5, 1.0]
    # duplicate stripe ids are rejected
    with pytest.raises(ValueError, match="already cataloged"):
        cat.add_stripe("s0", stripe, [{"feature": np.zeros(8)}] * 3)
    # descriptor dimension is locked to the catalog's embedding space
    assert cat.feature_dim == 8
    with pytest.raises(ValueError, match="dim"):
        cat.add_stripe("s1", stripe, [{"feature": np.zeros(16)}] * 3)


def test_catalog_scores_against_current_centroids():
    stripe, _, _, _ = _payload_stripe(3, [4096, 4096])
    cat = StripeCatalog()
    cat.add_stripe(
        "s0", stripe,
        [
            {"stream_id": 0, "feature": np.zeros(4), "novelty": 9.0},
            {"stream_id": 1, "feature": np.full(4, 5.0), "novelty": 0.1},
        ],
    )
    # without centroids: archive-time novelty wins
    assert cat.topk(1)[0].shard == 0
    # with centroids at the origin: the far feature is the novel one
    assert cat.topk(1, centroids=np.zeros((1, 4)))[0].shard == 1
    scores = cat.score(np.zeros((1, 4)))
    np.testing.assert_allclose(scores, [0.0, np.sqrt(4 * 25.0)], atol=1e-5)


# ----------------------------------------------------------------- planner
def _three_stripe_catalog(tmp_path=None):
    cat = StripeCatalog()
    stripes = {}
    rng = np.random.default_rng(7)
    for t in range(3):
        stripe, flats, sec, cfg = _payload_stripe(10 + t, [4096 + 512 * s for s in range(4)])
        descs = [
            {"stream_id": s, "feature": rng.normal(3.0 * t, 0.05, 8)}
            for s in range(4)
        ]
        cat.add_stripe(f"st{t}", stripe, descs)
        stripes[f"st{t}"] = (stripe, flats, sec, cfg)
    return cat, stripes


def test_plan_ranks_by_novelty_and_respects_budget():
    cat, _ = _three_stripe_catalog()
    # known distribution = clusters 0 and 1 -> stripe st2 is the novel one
    cents = np.stack([np.zeros(8), np.full(8, 3.0)]).astype(np.float32)
    plan = plan_retrieval(cat, cents, k=4)
    assert {r.stripe_id for r in plan.reads} == {"st2"}
    assert plan.shards_by_stripe == {"st2": [0, 1, 2, 3]}
    assert plan.bytes_planned == sum(r.body_bytes for r in plan.reads)
    assert plan.bytes_full_restore == cat.bytes_indexed
    assert plan.bytes_planned < plan.bytes_full_restore / 2
    # budget cuts the tail, most-novel reads survive
    tight = plan_retrieval(
        cat, cents, budget_bytes=plan.reads[0].read_bytes + 1, k=4
    )
    assert len(tight.reads) == 1 and tight.skipped == 3
    assert tight.reads[0].novelty >= plan.reads[-1].novelty
    # both decode placements are priced; the plan picks the cheaper
    assert set(plan.costs) == {"host", "csd"}
    assert (
        plan.costs[plan.placement].latency_s
        == min(c.latency_s for c in plan.costs.values())
    )


def test_plan_bills_degraded_reads():
    cat, _ = _three_stripe_catalog()
    cents = np.stack([np.zeros(8), np.full(8, 3.0)]).astype(np.float32)
    normal = plan_retrieval(cat, cents, k=1)
    dead = normal.reads[0].shard
    deg = plan_retrieval(cat, cents, k=1, dead_shards=[dead])
    assert deg.reads[0].degraded
    # rebuild reads the surviving peers + parity: strictly more bytes
    assert deg.bytes_planned > normal.bytes_planned
    # ... and exactly them: the dead body itself is unreadable, parity is
    # sized like the widest body (RAID-6: two strips)
    sid = deg.reads[0].stripe_id
    peers = [e for e in cat.entries if e.stripe_id == sid and e.shard != dead]
    widest = max(e.body_bytes for e in cat.entries if e.stripe_id == sid)
    assert deg.bytes_planned == sum(e.body_bytes for e in peers) + 2 * widest
    # a second read from the same stripe after the rebuild is free
    deg2 = plan_retrieval(cat, cents, k=2, dead_shards=[dead])
    same_stripe = [r for r in deg2.reads if r.stripe_id == deg2.reads[0].stripe_id]
    assert len(same_stripe) >= 2 and same_stripe[1].read_bytes == 0
    # two dead shards in one stripe (both wanted): one rebuild
    # reconstructs both, parity billed once
    other = plan_retrieval(cat, cents, k=2).reads[1].shard
    deg3 = plan_retrieval(cat, cents, k=2, dead_shards=[dead, other])
    dd = [r for r in deg3.reads if r.degraded]
    assert len(dd) == 2 and dd[1].read_bytes == 0
    surv = [e for e in peers if e.shard != other]
    assert deg3.bytes_planned == sum(e.body_bytes for e in surv) + 2 * widest
    # more dead shards than parity strips: the rebuild cannot happen, so
    # the read is dropped from the plan instead of billed as a promise
    deg4 = plan_retrieval(
        cat, cents, k=2, dead_shards=[dead, other], parity_shards=1
    )
    assert not any(r.degraded for r in deg4.reads)
    assert deg4.skipped >= 2


# ------------------------------------------------------- shard-subset reads
def test_partial_read_bit_identical_and_ordered():
    stripe, flats, sec, cfg = _payload_stripe(20, [5000, 4096, 7777, 6000])
    part, blocks = restore_stripe_payloads(sec, stripe, cfg, shards=[2, 0])
    assert _eq(part[0], flats[2]) and _eq(part[1], flats[0])
    assert [int(b.sealed.n_valid_u32) for b in blocks] == [
        int(stripe.blocks[2].sealed.n_valid_u32),
        int(stripe.blocks[0].sealed.n_valid_u32),
    ]


def test_partial_read_rejects_bad_subsets():
    stripe, _, sec, cfg = _payload_stripe(21, [4096, 4096])
    with pytest.raises(ValueError, match="at least one"):
        restore_stripe_payloads(sec, stripe, cfg, shards=[])
    with pytest.raises(ValueError, match="out of range"):
        restore_stripe_payloads(sec, stripe, cfg, shards=[2])
    with pytest.raises(ValueError, match="duplicate"):
        restore_stripe_payloads(sec, stripe, cfg, shards=[1, 1])


def test_degraded_read_single_and_double_loss():
    stripe, flats, sec, cfg = _payload_stripe(22, [5000, 4096, 7777, 6000])
    mfs = stripe_manifests(stripe)
    # one wanted shard missing
    holes = list(stripe.blocks)
    holes[2] = None
    got, _ = restore_stripe_payloads(
        sec, StripeArchive(holes, stripe.parity), cfg,
        shards=[2], manifests=mfs,
    )
    assert _eq(got[0], flats[2])
    # RAID-6 double loss, both wanted
    holes = [None, stripe.blocks[1], None, stripe.blocks[3]]
    got, _ = restore_stripe_payloads(
        sec, StripeArchive(holes, stripe.parity), cfg,
        shards=[0, 2], manifests=mfs,
    )
    assert _eq(got[0], flats[0]) and _eq(got[1], flats[2])
    # missing shard that is NOT wanted requires no rebuild
    holes = [stripe.blocks[0], None, stripe.blocks[2], stripe.blocks[3]]
    got, _ = restore_stripe_payloads(
        sec, StripeArchive(holes, stripe.parity), cfg, shards=[0, 3]
    )
    assert _eq(got[0], flats[0]) and _eq(got[1], flats[3])
    # degraded read without the replicated metadata fails loudly
    holes = [None] + list(stripe.blocks[1:])
    with pytest.raises(ValueError, match="replicated metadata"):
        restore_stripe_payloads(
            sec, StripeArchive(holes, stripe.parity), cfg, shards=[0]
        )


def test_manifest_json_roundtrip_enables_degraded_read():
    """The journaled (JSON) replicated-metadata tier must be enough to
    rebuild and decode a lost shard after a restart."""
    stripe, flats, sec, cfg = _payload_stripe(23, [4444, 6000, 5000])
    mfs = stripe_manifests_from_json(
        json.loads(json.dumps(stripe_manifests_to_json(stripe_manifests(stripe))))
    )
    holes = [stripe.blocks[0], None, stripe.blocks[2]]
    got, _ = restore_stripe_payloads(
        sec, StripeArchive(holes, stripe.parity), cfg,
        shards=[1], manifests=mfs,
    )
    assert _eq(got[0], flats[1])


# ------------------------------ recover_stripe on entropy-coded stripes
def test_recover_stripe_raid6_double_loss_on_rans_stripe():
    """The original recover tests predate the entropy stage: this one loses
    two shards of an rANS-coded stripe (one of them raw-skip flagged) and
    requires bit-exact payloads back through the full restore path."""
    # shard 1 is incompressible -> raw-skip; shards 0, 2, 3 rANS-coded
    rng = np.random.default_rng(30)
    lens = [6000, 5000, 7777, 4096]
    pub, sec = rlwe.keygen(jax.random.PRNGKey(31))
    cfg = ArchiveConfig()
    flats = [
        jnp.asarray(
            rng.integers(-128, 128, lens[i])
            if i == 1
            else np.clip(np.round(rng.normal(0, 2.0, lens[i])), -128, 127),
            jnp.int8,
        )
        for i in range(4)
    ]
    mans = [{"n_i8": int(f.shape[0]), "spec": []} for f in flats]
    stripe = seal_payload_stripe(pub, flats, mans, jax.random.PRNGKey(32), cfg)
    assert stripe.blocks[1].manifest["entropy"].get("raw") is True
    assert not stripe.blocks[0].manifest["entropy"].get("raw")
    mfs = stripe_manifests(stripe)
    lens_w = [m["n_words"] for m in mfs]
    holes = [None, stripe.blocks[1], None, stripe.blocks[3]]
    rebuilt = recover_stripe(holes, stripe.parity, [0, 2], mfs, lens_w)
    got, _ = restore_stripe_payloads(
        sec, StripeArchive(rebuilt, stripe.parity), cfg
    )
    for g, f in zip(got, flats):
        assert _eq(g, f)


# ---------------------------------------------------------------- raw-skip
def test_raw_skip_flagged_and_roundtrips():
    rng = np.random.default_rng(40)
    comp = jnp.asarray(
        np.clip(np.round(rng.normal(0, 2.0, 8000)), -128, 127), jnp.int8
    )
    incomp = jnp.asarray(rng.integers(-128, 128, 8000), jnp.int8)
    tiny = jnp.asarray(rng.integers(-128, 128, 64), jnp.int8)
    comps, metas = eops.encode_payloads([comp, incomp, tiny])
    assert not metas[0].get("raw")
    assert metas[1]["raw"] and metas[1]["n_comp"] == metas[1]["n_raw"]
    assert metas[2]["raw"]  # smaller than the stream header
    assert int(comps[1].shape[0]) == 8000
    back = eops.decode_payloads(comps, metas)
    for b, p in zip(back, [comp, incomp, tiny]):
        assert _eq(b, p)
    # pallas and staged ref agree bit-for-bit, flags included
    comps_r, metas_r = eops.encode_payloads(
        [comp, incomp, tiny], use_pallas=False
    )
    assert metas == metas_r
    for a, b in zip(comps, comps_r):
        assert _eq(a, b)
    # an all-raw stripe decodes without any coded shard
    c2, m2 = eops.encode_payloads([incomp, tiny])
    assert all(m["raw"] for m in m2)
    for b, p in zip(eops.decode_payloads(c2, m2), [incomp, tiny]):
        assert _eq(b, p)


def test_raw_skip_corrupt_meta_rejected():
    rng = np.random.default_rng(41)
    incomp = jnp.asarray(rng.integers(-128, 128, 4096), jnp.int8)
    comps, metas = eops.encode_payloads([incomp])
    bad = [dict(metas[0], n_comp=4095)]
    with pytest.raises(ValueError, match="manifest says"):
        eops.decode_payloads(comps, bad)
    bad = [dict(metas[0], n_raw=4000)]
    with pytest.raises(ValueError, match="raw-skip"):
        eops.decode_payloads(comps, bad)


def test_raw_skip_through_seal_and_zlib_host_codec():
    # rans path through the fused seal datapath
    stripe, flats, sec, cfg = _payload_stripe(
        42, [6000, 6000], peaked=False
    )
    assert all(b.manifest["entropy"]["raw"] for b in stripe.blocks)
    got, _ = restore_stripe_payloads(sec, stripe, cfg)
    for g, f in zip(got, flats):
        assert _eq(g, f)
    # host-codec path flags raw the same way
    cfg_z = ArchiveConfig(codec_name="zlib")
    stripe_z, flats_z, sec_z, _ = _payload_stripe(
        43, [6000, 6000], cfg=cfg_z, peaked=False
    )
    assert all(b.manifest["entropy"]["raw"] for b in stripe_z.blocks)
    got_z, _ = restore_stripe_payloads(sec_z, stripe_z, cfg_z)
    for g, f in zip(got_z, flats_z):
        assert _eq(g, f)


# ------------------------------------------------------------ sharded reads
@pytest.mark.parametrize("d", [2, 4])
def test_sharded_subset_and_rawskip_match_single_device(d):
    if jax.device_count() < d:
        pytest.skip(
            f"need {d} devices, have {jax.device_count()} "
            "(run with XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
    from repro.distributed.archival import restore_stripe_sharded

    mesh = Mesh(np.array(jax.devices()[:d]), ("data",))
    rng = np.random.default_rng(50)
    pub, sec = rlwe.keygen(jax.random.PRNGKey(51))
    cfg = ArchiveConfig()
    # mix compressible and raw-skip shards so the sharded decode path has
    # to honor the manifest flag too
    flats = [
        jnp.asarray(
            rng.integers(-128, 128, 5000)
            if s % 2
            else np.clip(np.round(rng.normal(0, 2.0, 5000 + 64 * s)), -128, 127),
            jnp.int8,
        )
        for s in range(4)
    ]
    mans = [{"n_i8": int(f.shape[0]), "spec": []} for f in flats]
    stripe = seal_payload_stripe(pub, flats, mans, jax.random.PRNGKey(52), cfg)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)

    single, _ = restore_stripe_payloads(sec, stripe, cfg, shards=[1, 3])
    from repro.distributed.archival import (
        entropy_decode_sharded,
        unseal_stripe_sharded,
    )
    import functools

    shard_par, _ = restore_stripe_payloads(
        sec, stripe, cfg, shards=[1, 3],
        unseal_fn=functools.partial(unseal_stripe_sharded, mesh=mesh),
        entropy_decode_fn=functools.partial(entropy_decode_sharded, mesh=mesh),
    )
    for a, b in zip(single, shard_par):
        assert _eq(a, b)
    for a, want in zip(shard_par, [flats[1], flats[3]]):
        assert _eq(a, want)


# -------------------------------------------------------------- cost model
def test_retrieval_placement_tradeoff():
    sys = cm.SystemModel()
    comp, raw = 1e8, 2.5e8
    host = cm.retrieval_placement_cost(sys, comp, raw, "host")
    csd = cm.retrieval_placement_cost(sys, comp, raw, "csd")
    # host decode moves the compressed stream; CSD decode the expanded one
    assert host.moved_bytes == comp and csd.moved_bytes == raw
    # the CSD kernel outruns the host CPU on decode compute
    assert raw / (sys.csd_rate_GBps * 1e9) < raw / (sys.cpu_rate_GBps * 1e9)
    best, costs = cm.best_retrieval_placement(sys, comp, raw)
    assert best in costs
    assert costs[best].latency_s == min(c.latency_s for c in costs.values())
    with pytest.raises(ValueError):
        cm.retrieval_placement_cost(sys, comp, raw, "moon")


# ------------------------------------------------- end-to-end (real codec)
def test_codec_partial_restore_matches_full_and_degraded(tmp_path):
    """Acceptance: top-k retrieval restores only the planned shards, the
    GOPs are bit-identical to a full restore, and one dropped shard still
    succeeds via the parity rebuild."""
    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, sec = rlwe.keygen(jax.random.PRNGKey(1))

    from repro.data.video import VideoStream, render_clip

    frames = [
        render_clip(VideoStream(i, 100 + i, 32, 32, 30.0, 64), 0, 2)[:, None]
        for i in range(3)
    ]
    stripe, _ = archive_stripe(
        codec_params, pub, frames, jax.random.PRNGKey(2), cfg
    )
    cat = StripeCatalog(Journal(str(tmp_path)))
    feats = np.stack([np.zeros(4), np.full(4, 6.0), np.zeros(4)])
    cat.add_stripe(
        "s0", stripe,
        [{"stream_id": i, "feature": feats[i]} for i in range(3)],
    )
    plan = plan_retrieval(cat, np.zeros((1, 4), np.float32), k=1)
    assert plan.shards_by_stripe == {"s0": [1]}
    assert plan.bytes_planned == 4 * int(stripe.blocks[1].sealed.n_valid_u32)

    full = restore_stripe(codec_params, sec, stripe, cfg)
    part = restore_stripe(
        codec_params, sec, stripe, cfg, shards=plan.shards_by_stripe["s0"]
    )
    assert len(part) == 1
    assert _eq(part[0], full[1])

    # degraded: the planned shard's body is gone; parity rebuild, same GOP
    holes = list(stripe.blocks)
    holes[1] = None
    deg = restore_stripe(
        codec_params, sec, StripeArchive(holes, stripe.parity), cfg,
        shards=[1], manifests=stripe_manifests(stripe),
    )
    assert _eq(deg[0], full[1])


# ------------------------------------------------------------ trainer loop
def test_trainer_replay_consumes_planner_output(tmp_path):
    from repro.data.video import make_streams
    from repro.train.trainer import SalientTrainer, TrainerConfig

    streams = make_streams(4, height=32, width=32)
    cfg = TrainerConfig(
        n_shards=2, checkpoint_every=4, replay_every=2, replay_k=2,
    )
    tr = SalientTrainer(streams, str(tmp_path), cfg)
    reports = [tr.run_step(shard_times=[1.0, 1.0]) for _ in range(4)]
    assert len(tr.catalog) > 0
    replayed = [r for r in reports if r.replayed_gops]
    assert replayed, "replay stage never fired"
    for r in replayed:
        assert r.replay_read_bytes <= r.replay_full_bytes
    # subset reads: by the last replay the catalog outgrew the budgeted plan
    assert replayed[-1].replay_read_bytes < replayed[-1].replay_full_bytes

    # restart: centroids come back from the checkpoint meta, the catalog
    # from the journal, and replay still works (stripes reload from disk)
    tr2 = SalientTrainer(streams, str(tmp_path), cfg._replace(replay_every=1))
    assert tr2.known_centroids is not None
    assert len(tr2.catalog) == len(tr.catalog)
    assert tr2._stripes == {}  # nothing hot in memory yet
    rep = tr2.run_step(shard_times=[1.0, 1.0])
    assert rep.replayed_gops > 0
    assert rep.replay_read_bytes > 0


def test_trainer_replay_degraded_on_dead_shard(tmp_path):
    from repro.data.video import make_streams
    from repro.train.trainer import SalientTrainer, TrainerConfig

    streams = make_streams(6, height=32, width=32)
    cfg = TrainerConfig(
        n_shards=4, checkpoint_every=10, replay_every=1, replay_k=2,
    )
    tr = SalientTrainer(streams, str(tmp_path), cfg)
    for _ in range(2):  # seed the archive (stripes of 4 need two steps)
        tr.run_step(shard_times=[1.0, 1.0, 1.0, 1.0])
    assert len(tr.catalog) > 0
    # shard 0's CSD goes dead (>10x the median): the monitor flags it and
    # the next replay must plan (and execute) a parity-degraded read
    rep = None
    for _ in range(4):
        rep = tr.run_step(shard_times=[60.0, 1.0, 1.0, 1.0])
        if rep.replay_degraded:
            break
    assert rep.replay_degraded > 0
    assert rep.replayed_gops > 0


def test_checkpoint_extra_meta_roundtrip(tmp_path):
    from repro.train.checkpoint import (
        load_checkpoint_meta,
        save_checkpoint,
    )

    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(
        str(tmp_path), 3, state, n_shards=2,
        extra_meta={"centroids": [[1.0, 2.0]]},
    )
    meta = load_checkpoint_meta(str(tmp_path))
    assert meta["step"] == 3
    assert meta["extra"]["centroids"] == [[1.0, 2.0]]


# ---------------------------------------------------------- serving ingest
def test_serving_ingest_catalogs_plans_and_restarts(tmp_path):
    from repro.serving.engine import ArchiveIngest, IngestConfig

    cfg = ArchiveConfig(codec=CFG)
    codec_params = init_codec(jax.random.PRNGKey(0), CFG)
    pub, _ = rlwe.keygen(jax.random.PRNGKey(1))
    icfg = IngestConfig(n_shards=2, archive=cfg, feature_dim=4)
    ing = ArchiveIngest(codec_params, pub, icfg, journal=Journal(str(tmp_path)))
    from repro.data.video import VideoStream, render_clip

    def _frames(i):
        return render_clip(
            VideoStream(i, 200 + i, 32, 32, 30.0, 64), 0, 2
        )[:, None]

    for i in range(4):
        ing.submit(
            i, _frames(i),
            feature=np.full(4, 5.0 if i == 3 else 0.0),
            novelty=float(i == 3),
        )
    ing.flush()
    s = ing.stats()
    assert s["catalog_gops"] == 4
    assert s["catalog_bytes"] > 0
    plan = ing.query(np.zeros((1, 4), np.float32), k=1)
    assert plan.reads[0].stream_id == 3
    s = ing.stats()
    assert s["plans_served"] == 1
    assert 0 < s["retrieval_bytes_ratio"] < 1

    # restart on the same journal: the old index is visible again and the
    # stripe id sequence resumes past it (no catalog record overwrite)
    ing2 = ArchiveIngest(
        codec_params, pub, icfg, journal=Journal(str(tmp_path))
    )
    assert ing2.stats()["catalog_gops"] == 4
    old_ids = {e.stripe_id for e in ing2.catalog.entries}
    for i in range(2):
        ing2.submit(i, _frames(i))
    ing2.flush()
    assert ing2.stats()["catalog_gops"] == 6
    new_ids = {e.stripe_id for e in ing2.catalog.entries} - old_ids
    assert new_ids and new_ids.isdisjoint(old_ids)
