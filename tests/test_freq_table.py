"""Edge-case and property tests for the rANS table builders.

``build_freq_table`` invariants (integer-exact normalization): the sum is
exactly PROB_SCALE, every present symbol keeps freq >= 1, and the >= 2^19
downscale path stays exact.  ``build_enc_tables`` reciprocals: both the
Granlund-Montgomery (mprime, shift) fixed-point pair and the
error-repaired f32 reciprocal must reproduce the hardware quotient for
every reachable (x, f) — brute-checked against u64 ground truth here so
the hot loop's division strategies stay interchangeable bit-for-bit.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.kernels.entropy.rans import (
    PROB_BITS,
    PROB_SCALE,
    build_enc_tables,
    build_freq_table,
    slot_to_symbol,
)

_SYM_MASK = 0x1FFF


def _check_invariants(counts):
    f = np.asarray(build_freq_table(jnp.asarray(counts, jnp.int32)))
    assert f.sum() == PROB_SCALE
    assert (f[np.asarray(counts) > 0] >= 1).all()
    assert (f >= 0).all()
    return f


# ----------------------------------------------------- deterministic edges
def test_single_symbol_shard():
    counts = np.zeros(256, np.int64)
    counts[42] = 12345
    f = _check_invariants(counts)
    assert f[42] == PROB_SCALE  # sole symbol owns the whole range


def test_all_256_symbols_present():
    f = _check_invariants(np.full(256, 7))
    assert (f >= 1).all()  # every present symbol survives normalization


@pytest.mark.parametrize(
    "total_exp", [19, 20, 25, 30]
)
def test_large_total_shift_path(total_exp):
    """Totals >= 2^19 take the downscale-then-allocate path; the result
    must still be exact (the shift exists so count*budget < 2^31)."""
    counts = np.zeros(256, np.int64)
    counts[: 4] = (1 << total_exp) // 4
    assert counts.sum() >= 1 << 19
    f = _check_invariants(counts)
    # equal counts, no other symbols: equal freqs modulo the remainder
    assert f[:4].min() >= PROB_SCALE // 4 - 1


def test_huge_single_count_int32_safe():
    counts = np.zeros(256, np.int64)
    counts[3] = 10**9  # near int32 max: the shift keeps products in range
    counts[7] = 1
    f = _check_invariants(counts)
    assert f[3] > f[7] >= 1


def test_empty_payload_degenerate_table():
    f = _check_invariants(np.zeros(256, np.int64))
    assert f[0] == PROB_SCALE  # symbol 0 owns everything; still decodable


def test_slot_table_matches_searchsorted_oracle():
    """The cumulative-bucket fill must agree with the searchsorted
    semantics it replaced, including zero-frequency symbols."""
    rng = np.random.default_rng(3)
    for _ in range(20):
        counts = rng.integers(0, 50, 256) * rng.integers(0, 2, 256)
        f = np.asarray(build_freq_table(jnp.asarray(counts, jnp.int32)))
        got = np.asarray(slot_to_symbol(jnp.asarray(f)))
        want = np.searchsorted(
            np.cumsum(f), np.arange(PROB_SCALE), side="right"
        )
        assert np.array_equal(got, want)


# ----------------------------------------------------- reciprocal exactness
def _table_quotients(f_val, xs):
    """Quotients for symbol-frequency ``f_val`` over u32 samples ``xs``,
    via both precomputed-reciprocal strategies from build_enc_tables."""
    freq = np.zeros(256, np.int64)
    freq[1] = f_val
    freq[0] = PROB_SCALE - f_val
    packed, mprime, rcp = (
        np.asarray(a) for a in build_enc_tables(jnp.asarray(freq, jnp.int32))
    )
    p, m, r = int(packed[1]), int(mprime[1]), np.float32(rcp[1])
    s1 = (p >> 13) & 0x3F
    x = xs.astype(np.uint64)
    # Granlund-Montgomery: t = mulhi(x, mprime); q = (t + (x-t)//2) >> s1
    t = (x * np.uint64(m)) >> np.uint64(32)
    q_gm = (t + ((x - t) >> np.uint64(1))) >> np.uint64(s1)
    if f_val <= 1:
        q_gm = x
    # error-repaired f32 reciprocal
    qh = (xs.astype(np.float32) * r).astype(np.int64)
    rem = xs.astype(np.int64) - qh * f_val
    q_f32 = qh + (rem >= f_val) - (rem < 0)
    return q_gm.astype(np.int64), q_f32


@pytest.mark.parametrize("f_val", [1, 2, 3, 5, 7, 255, 641, 2048, 2731,
                                   4095, 4096])
def test_reciprocal_exact_adversarial(f_val):
    rng = np.random.default_rng(f_val)
    # GM must hold for every x < 2^32; the f32 repair for x < f * 2^20
    # (the renorm invariant bounds post-renorm states by exactly that)
    lim32 = 1 << 32
    lim_f = f_val << 20
    xs = {0, 1, f_val - 1, f_val, f_val + 1, lim_f - 1, lim32 - 1}
    for k in (1, 2, (lim32 - 1) // f_val, (lim_f - 1) // f_val):
        for d in (-1, 0, 1):
            v = k * f_val + d
            if 0 <= v < lim32:
                xs.add(v)
    xs |= {int(v) for v in rng.integers(0, lim32, 300)}
    xs = np.asarray(sorted(xs), np.uint32)
    q_gm, q_f32 = _table_quotients(f_val, xs)
    truth = xs.astype(np.uint64) // np.uint64(f_val)
    assert np.array_equal(q_gm, truth.astype(np.int64))
    in_range = xs < lim_f
    assert np.array_equal(q_f32[in_range], truth.astype(np.int64)[in_range])


# ------------------------------------------------------ hypothesis sweeps
@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(0, 1 << 26), min_size=256, max_size=256))
def test_freq_table_invariants_property(counts):
    _check_invariants(np.asarray(counts, np.int64))


@settings(max_examples=100, deadline=None)
@given(st.integers(1, PROB_SCALE), st.integers(0, (1 << 32) - 1))
def test_reciprocal_exact_property(f_val, x):
    q_gm, q_f32 = _table_quotients(f_val, np.asarray([x], np.uint32))
    assert q_gm[0] == x // f_val
    if x < (f_val << 20):
        assert q_f32[0] == x // f_val
