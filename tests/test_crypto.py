"""Crypto layer tests: polymul kernel vs oracle, R-LWE roundtrips, ChaCha20."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.kernels.polymul import ref as pref
from repro.kernels.polymul.ops import polymul, polymul_fixed
from repro.kernels.polymul.polymul import negacyclic_matmul_pallas
from repro.core.crypto import rlwe
from repro.core.crypto.chacha import chacha20_block, keystream, xor_stream
from repro.core.crypto.hybrid import bytes_to_u32, seal, u32_to_bytes, unseal
from repro.core.crypto.rsa_baseline import (
    rsa_decrypt_blocks,
    rsa_encrypt_blocks,
    rsa_keypair,
)

Q = 12289
N = 256


def np_negacyclic(a, b, q):
    """Independent numpy int64 oracle."""
    n = a.shape[-1]
    full = np.zeros(b.shape[:-1] + (2 * n,), dtype=np.int64)
    for i in range(n):
        full[..., i : i + n] += a[..., i, None].astype(np.int64) * b.astype(np.int64)
    return ((full[..., :n] - full[..., n : 2 * n]) % q).astype(np.int32)


# ---------------------------------------------------------------- polymul
@pytest.mark.parametrize("n", [8, 64, 128, 256, 512])
@pytest.mark.parametrize("batch", [1, 3, 256])
def test_polymul_kernel_matches_oracle_shapes(n, batch):
    rng = np.random.default_rng(n * 1000 + batch)
    a = rng.integers(0, Q, size=(n,), dtype=np.int32)
    b = rng.integers(0, Q, size=(batch, n), dtype=np.int32)
    expect = np_negacyclic(a, b, Q)
    got = np.asarray(polymul_fixed(jnp.asarray(a), jnp.asarray(b), Q))
    np.testing.assert_array_equal(got, expect)


@pytest.mark.parametrize("q", [257, 3329, 7681, 12289])
def test_polymul_kernel_moduli(q):
    rng = np.random.default_rng(q)
    a = rng.integers(0, q, size=(N,), dtype=np.int32)
    b = rng.integers(0, q, size=(4, N), dtype=np.int32)
    expect = np_negacyclic(a, b, q)
    got = np.asarray(polymul_fixed(jnp.asarray(a), jnp.asarray(b), q))
    np.testing.assert_array_equal(got, expect)


def test_polymul_large_q_falls_back_to_ref():
    q = 40961  # > 2^14: int8 limb path invalid, wrapper must fall back
    rng = np.random.default_rng(1)
    a = rng.integers(0, q, size=(N,), dtype=np.int32)
    b = rng.integers(0, q, size=(2, N), dtype=np.int32)
    expect = np_negacyclic(a, b, q)
    got = np.asarray(polymul_fixed(jnp.asarray(a), jnp.asarray(b), q))
    np.testing.assert_array_equal(got, expect)


def test_polymul_kernel_rejects_large_q():
    with pytest.raises(ValueError):
        negacyclic_matmul_pallas(
            jnp.zeros((N, N), jnp.int32), jnp.zeros((N, 8), jnp.int32), 1 << 14
        )


def test_polymul_general_batched():
    rng = np.random.default_rng(7)
    a = rng.integers(0, Q, size=(5, N), dtype=np.int32)
    b = rng.integers(0, Q, size=(5, N), dtype=np.int32)
    expect = np_negacyclic(a, b, Q)
    got = np.asarray(polymul(jnp.asarray(a), jnp.asarray(b), Q))
    np.testing.assert_array_equal(got, expect)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([16, 64, 256]),
)
def test_polymul_ring_properties(seed, n):
    """Commutativity, x^n == -1, and distributivity in the quotient ring."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, Q, size=(n,), dtype=np.int32))
    b = jnp.asarray(rng.integers(0, Q, size=(n,), dtype=np.int32))
    c = jnp.asarray(rng.integers(0, Q, size=(n,), dtype=np.int32))
    ab = polymul(a, b, Q)
    ba = polymul(b, a, Q)
    np.testing.assert_array_equal(np.asarray(ab), np.asarray(ba))
    # multiplying by x n times negates: x^n = -1 in Z_q[x]/(x^n+1)
    x = jnp.zeros((n,), jnp.int32).at[1].set(1)
    out = a
    for _ in range(n):
        out = polymul(out, x, Q)
    np.testing.assert_array_equal(np.asarray(out), np.asarray((Q - a) % Q))
    # distributivity
    lhs = polymul(a, jnp.mod(b + c, Q), Q)
    rhs = jnp.mod(ab + polymul(a, c, Q), Q)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


# ---------------------------------------------------------------- R-LWE
def test_rlwe_roundtrip_batch():
    params = rlwe.RLWEParams()
    key = jax.random.PRNGKey(0)
    kk, km, ke = jax.random.split(key, 3)
    pub, s = rlwe.keygen(kk, params)
    m = jax.random.bernoulli(km, 0.5, (32, params.n)).astype(jnp.int32)
    ct = rlwe.encrypt_bits(pub, m, ke, params)
    dec = rlwe.decrypt_bits(s, ct, params)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(m))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rlwe_roundtrip_property(seed):
    params = rlwe.RLWEParams()
    key = jax.random.PRNGKey(seed)
    kk, km, ke = jax.random.split(key, 3)
    pub, s = rlwe.keygen(kk, params)
    m = jax.random.bernoulli(km, 0.5, (4, params.n)).astype(jnp.int32)
    ct = rlwe.encrypt_bits(pub, m, ke, params)
    dec = rlwe.decrypt_bits(s, ct, params)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(m))


def test_rlwe_ciphertext_differs_from_message():
    params = rlwe.RLWEParams()
    pub, s = rlwe.keygen(jax.random.PRNGKey(3), params)
    m = jnp.ones((1, params.n), jnp.int32)
    ct = rlwe.encrypt_bits(pub, m, jax.random.PRNGKey(4), params)
    # ciphertext coefficients should look uniform, not like the message
    assert np.asarray(ct.c2).std() > 1000


def test_kem_roundtrip():
    params = rlwe.RLWEParams()
    pub, s = rlwe.keygen(jax.random.PRNGKey(5), params)
    ct, shared = rlwe.kem_encapsulate(pub, jax.random.PRNGKey(6), params)
    shared2 = rlwe.kem_decapsulate(s, ct, params)
    np.testing.assert_array_equal(np.asarray(shared), np.asarray(shared2))
    assert shared.shape == (8,) and shared.dtype == jnp.uint32


def test_pack_unpack_bits():
    bits = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (256,)).astype(jnp.int32)
    words = rlwe.pack_bits_u32(bits)
    back = rlwe.unpack_bits_u32(words, 256)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(bits))


# ---------------------------------------------------------------- ChaCha20
def test_chacha_rfc8439_block():
    """RFC 8439 §2.3.2 test vector."""
    key = jnp.asarray(
        np.frombuffer(bytes(range(32)), dtype="<u4").copy(), jnp.uint32
    )
    nonce = jnp.asarray(
        np.frombuffer(bytes.fromhex("000000090000004a00000000"), dtype="<u4").copy(),
        jnp.uint32,
    )
    out = np.asarray(chacha20_block(key, jnp.uint32(1), nonce))[0]
    expect = np.array(
        [
            0xE4E7F110, 0x15593BD1, 0x1FDD0F50, 0xC47120A3,
            0xC7F4D1C7, 0x0368C033, 0x9AAA2204, 0x4E6CD4C3,
            0x466482D2, 0x09AA9F07, 0x05D7C214, 0xA2028BD9,
            0xD19C12B5, 0xB94E16DE, 0xE883D0CB, 0x4E3C50A2,
        ],
        dtype=np.uint32,
    )
    np.testing.assert_array_equal(out, expect)


def test_chacha_involution_and_determinism():
    key = jax.random.randint(jax.random.PRNGKey(0), (8,), 0, 2**31 - 1).astype(
        jnp.uint32
    )
    nonce = jnp.asarray([1, 2, 3], jnp.uint32)
    data = jax.random.randint(jax.random.PRNGKey(1), (1000,), 0, 2**31 - 1).astype(
        jnp.uint32
    )
    enc = xor_stream(key, nonce, data)
    dec = xor_stream(key, nonce, enc)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(data))
    assert not np.array_equal(np.asarray(enc), np.asarray(data))
    # different nonce -> different stream
    enc2 = xor_stream(key, jnp.asarray([9, 9, 9], jnp.uint32), data)
    assert not np.array_equal(np.asarray(enc), np.asarray(enc2))


def test_keystream_single_trace_across_mixed_sizes():
    """xor_stream buckets lengths to powers of two: one jit trace serves a
    whole bucket of mixed GOP sizes instead of retracing per length."""
    if not hasattr(keystream, "_cache_size"):
        pytest.skip("jit cache introspection unavailable")
    key = jnp.arange(8, dtype=jnp.uint32)
    nonce = jnp.ones(3, jnp.uint32)
    keystream._clear_cache()
    outs = {}
    for n in (513, 700, 901, 1024):  # all land in the 1024-word bucket
        data = jnp.arange(n, dtype=jnp.uint32)
        enc = xor_stream(key, nonce, data)
        np.testing.assert_array_equal(
            np.asarray(xor_stream(key, nonce, enc)), np.asarray(data)
        )
        outs[n] = enc
    assert keystream._cache_size() == 1
    # bucketing must not change the stream: same prefix for every length
    np.testing.assert_array_equal(
        np.asarray(outs[513]), np.asarray(outs[1024][:513])
    )


def test_hybrid_seal_mixed_gop_sizes_share_one_trace():
    if not hasattr(keystream, "_cache_size"):
        pytest.skip("jit cache introspection unavailable")
    pub, s = rlwe.keygen(jax.random.PRNGKey(11))
    keystream._clear_cache()
    for i, n_words in enumerate((525, 725, 925, 1024)):  # 1024-word bucket
        words = jnp.arange(n_words, dtype=jnp.uint32)
        block = seal(pub, words, jax.random.PRNGKey(20 + i))
        got = unseal(s, block)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(words))
    assert keystream._cache_size() == 1


def test_chacha_keystream_counter_continuity():
    key = jnp.arange(8, dtype=jnp.uint32)
    nonce = jnp.zeros(3, jnp.uint32)
    full = keystream(key, nonce, 64)
    tail = keystream(key, nonce, 32, counter0=2)
    np.testing.assert_array_equal(np.asarray(full[32:]), np.asarray(tail))


# ---------------------------------------------------------------- hybrid
def test_hybrid_seal_unseal_roundtrip():
    pub, s = rlwe.keygen(jax.random.PRNGKey(7))
    payload = b"salient store archival block" * 33
    words = bytes_to_u32(payload)
    block = seal(pub, words, jax.random.PRNGKey(8))
    got = unseal(s, block)
    assert u32_to_bytes(got, len(payload)) == payload
    assert not np.array_equal(np.asarray(block.body), np.asarray(words))


@settings(max_examples=10, deadline=None)
@given(data=st.binary(min_size=1, max_size=2048), seed=st.integers(0, 2**31 - 1))
def test_hybrid_roundtrip_property(data, seed):
    pub, s = rlwe.keygen(jax.random.PRNGKey(seed))
    words = bytes_to_u32(data)
    block = seal(pub, words, jax.random.PRNGKey(seed + 1))
    got = unseal(s, block)
    assert u32_to_bytes(got, len(data)) == data


# ---------------------------------------------------------------- RSA baseline
def test_rsa_roundtrip():
    pub, priv = rsa_keypair()
    data = b"store now decrypt later" * 7
    blocks = rsa_encrypt_blocks(data, pub)
    assert rsa_decrypt_blocks(blocks, len(data), priv) == data
