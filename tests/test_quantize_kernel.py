"""Quantize kernel: shape/dtype sweeps + allclose vs the pure-jnp oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.kernels.quantize.ops import dequantize_blockwise, quantize_blockwise
from repro.kernels.quantize.quantize import dequantize_pallas, quantize_pallas
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref


@pytest.mark.parametrize("shape", [(8, 128), (16, 512), (8, 1024), (32, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("block", [64, 128])
def test_kernel_matches_ref_shapes_dtypes(shape, dtype, block):
    if shape[1] % block:
        pytest.skip("block must divide N")
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 5).astype(dtype)
    qk, sk = quantize_pallas(x, block)
    qr, sr = quantize_ref(x, block)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    dk = dequantize_pallas(qk, sk, block)
    dr = dequantize_ref(qr, sr, block)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_roundtrip_error_bound(seed, scale):
    """|x - deq(q(x))| <= scale_block / 2 elementwise (half-ULP of int8)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 256)) * scale
    q, s = quantize_blockwise(x, block=128)
    back = dequantize_blockwise(q, s, block=128)
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.repeat(np.asarray(s), 128, axis=-1) * 0.5 + 1e-9
    assert (err <= bound).all()


def test_wrapper_handles_leading_dims_and_ragged():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 256))
    q, s = quantize_blockwise(x, block=128)
    assert q.shape == x.shape and s.shape == (3, 4, 2)
    back = dequantize_blockwise(q, s, block=128)
    assert back.shape == x.shape
    # ragged rows fall back to ref path transparently
    y = jax.random.normal(jax.random.PRNGKey(2), (5, 96))
    q2, s2 = quantize_blockwise(y, block=96)
    back2 = dequantize_blockwise(q2, s2, block=96)
    assert (np.abs(np.asarray(back2 - y)) <= np.repeat(np.asarray(s2), 96, -1) * 0.5 + 1e-9).all()


def test_quantize_preserves_zeros_and_signs():
    x = jnp.asarray([[0.0, -1.0, 1.0, 127.0] * 32])
    x = jnp.tile(x, (8, 1))
    q, s = quantize_blockwise(x, block=128)
    qn = np.asarray(q)
    assert (qn[:, 0] == 0).all()
    assert (qn[:, 1] < 0).all() and (qn[:, 2] > 0).all()
