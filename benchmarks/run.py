"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and records per-bench metrics
(GB/s, launch counts, device count) so the kernel perf trajectory is
machine-readable across PRs.  Fresh metrics are always written to a temp
file under the system tempdir; the committed ``BENCH_kernels.json`` at the
repo root is only replaced — atomically, via ``os.replace`` — on an
explicit ``--update`` run whose gates all pass.  Nothing is ever left
at the repo root otherwise (earlier revisions parked a stray
``BENCH_kernels.json.fresh`` there on gate failure).  Set BENCH_FULL=1
for the longer codec-training variant of the Fig. 8/9 rate-distortion
sweep.

``--check`` turns the committed BENCH_kernels.json into a regression gate:
the fresh run is diffed against it per bench and the process exits nonzero
if any ``us_per_call`` or ``us_decode`` regressed by more than
CHECK_THRESHOLD (2x — the timings are interpret-mode wall clock, so the
gate is deliberately coarse), or any ``gbps`` / ``gbps_decode`` fell below
1/CHECK_THRESHOLD of the committed value.  With today's fixed per-bench
byte counts the throughput floor mirrors the latency ceiling; it exists
so throughput stays gated if a future edit changes how many bytes a
bench pushes per call.
Benches that report ``bytes_moved_ratio`` (the retrieval bench's planned-
bytes / full-restore fraction) are additionally gated on it with the tight
BYTES_THRESHOLD: byte accounting is deterministic, so a retrieval plan that
starts moving more data than the committed baseline fails even when wall
clock looks fine.  ``ABS_GATES`` adds fixed (baseline-free) bounds on the
one-launch archival bench: a launch-count ceiling for its structural claim
and a ``vs_host_speed`` floor.  Gate rows carrying an ``"optional"`` flag
(the BENCH_FULL-only 1024-stream ingest point) gate when the metric is
present and skip — instead of failing — when the quick run did not
produce it.  When any gate fails, a consolidated
full-gate-state table (measured vs effective bound with signed margin,
passing rows included) is printed so the CI log alone answers "how close
was everything else".
"""

from __future__ import annotations

import json
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_JSON_PATH = os.path.join(_REPO_ROOT, "BENCH_kernels.json")
CHECK_THRESHOLD = 2.0  # >2x slower us_per_call fails --check
BYTES_THRESHOLD = 1.1  # >10% more bytes_moved_ratio fails --check (exact metric)

# Absolute gates (fresh run vs a fixed bound, no committed baseline
# needed): the one-launch archival bench must KEEP its structural claim —
# at most one kernel launch per K-stripe batch — and both entropy benches
# must hold the two-phase-encode win (PR 9) from both sides: wall-clock
# ceilings and exactness, plus vs-host floors set from measured
# CPU-interpret runs (entropy ~0.53-0.60, fused ~0.45-0.55, with +-15%
# machine noise), NOT at the >=1.0 TPU target: on a single-core interpret
# runner the bench is compute-bound on the shared rANS loop, so the
# dispatch/HBM savings the fusion buys cannot fully show up in wall clock
# (see the fused row's gap_note).
ABS_GATES = {
    # the standalone coder: >=1.5x over the pre-PR-9 24.7ms committed
    # baseline, holding >=0.5x of host zlib with bit-exact streams
    "entropy_fused": (
        ("us_per_call", "ceiling", 16500.0),
        ("gbps", "floor", 0.0158),
        ("vs_host_speed", "floor", 0.5),
        ("exact", "floor", 1.0),
        ("exact_recip", "floor", 1.0),
    ),
    "entropy_seal_fused": (
        ("launches", "ceiling", 1.0),
        ("launches_per_stripe", "ceiling", 1.0),
        ("us_per_stripe", "ceiling", 22000.0),
        ("vs_host_speed", "floor", 0.3),
    ),
    # Durability tier (scrub + rebuild under chaos): every injected
    # corruption must be detected (the crc/syndrome layers are exact, so
    # the floor is 1.0, not a tolerance), rebuild rounds must never
    # exceed their byte budget, and replay must keep progressing through
    # the chaos rounds.
    "scrub_rebuild": (
        ("detection_rate", "floor", 1.0),
        ("rebuild_budget_frac", "ceiling", 1.0),
        ("replay_progress_ratio", "floor", 0.5),
    ),
    # Telemetry tier: every hot-path obs call site is a single branch when
    # disabled, so enabling spans+ledger+histograms on the seal path may
    # cost at most 3% wall clock (interleaved A/B measurement).
    "obs_overhead": (
        ("overhead_frac", "ceiling", 0.03),
    ),
    # Streaming ingest tier: per stream-count point — a throughput floor,
    # GOP-to-commit latency ceilings (wall-clock on interpret-mode CPU,
    # so both carry 4-5x headroom over measured), an admission-shed
    # ceiling (the shed count is seed-deterministic: schedule and pump
    # cadence are fixed, so the bound is tight), and the structural
    # launches-per-stripe ceiling (<1: same-bucket stripes share a fused
    # launch).  The submit ring must hide at least half the fetch stall
    # (measured: >99% hidden).  The 1024-stream rows are BENCH_FULL-only
    # and marked "optional": absent metrics skip instead of fail.
    "ingest_scale": (
        ("stall_hidden_frac", "floor", 0.5),
        ("stripes_per_s_16", "floor", 0.6),
        ("p50_us_16", "ceiling", 6.0e6),
        ("p99_us_16", "ceiling", 36.0e6),
        ("shed_frac_16", "ceiling", 0.25),
        ("launches_per_stripe_16", "ceiling", 0.9),
        ("stripes_per_s_256", "floor", 1.2),
        ("p50_us_256", "ceiling", 6.0e6),
        ("p99_us_256", "ceiling", 36.0e6),
        ("shed_frac_256", "ceiling", 0.25),
        ("launches_per_stripe_256", "ceiling", 0.9),
        ("stripes_per_s_1024", "floor", 1.2, "optional"),
        ("p50_us_1024", "ceiling", 8.0e6, "optional"),
        ("p99_us_1024", "ceiling", 45.0e6, "optional"),
        ("shed_frac_1024", "ceiling", 0.25, "optional"),
        ("launches_per_stripe_1024", "ceiling", 0.9, "optional"),
    ),
}


def _force_multidevice_host() -> None:
    """Give the bench process an 8-device host platform (before jax init)
    so the sharded_seal bench can build 1/2/8-device storage meshes.

    (The legacy CPU runtime — ``--xla_cpu_use_thunk_runtime=false`` — was
    evaluated for the tiny-op-dominated coding loops and rejected: it
    miscompiles batched ``dot_general`` on forced multi-device hosts,
    returning garbage histogram sums.  Do not re-add it.)"""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8"
        ).strip()


def _dump_fresh(metrics: dict) -> str:
    """Write the fresh metrics to a temp file under the SYSTEM tempdir
    (never the repo root) and return its path.  This is the only copy a
    non-``--update`` run produces, so an aborted or gate-failed run cannot
    litter the checkout."""
    import tempfile

    import jax

    out = {
        "device_count": jax.device_count(),
        "backend": jax.default_backend(),
        "benches": metrics,
    }
    fd, path = tempfile.mkstemp(prefix="BENCH_kernels.", suffix=".json")
    with os.fdopen(fd, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def _commit_kernels_json(fresh_path: str, n_benches: int) -> None:
    """Atomically replace the committed baseline with the fresh metrics:
    copy into a sibling temp file in the repo root, then ``os.replace`` so
    readers never observe a torn BENCH_kernels.json."""
    import shutil

    tmp = _JSON_PATH + ".tmp"
    shutil.copyfile(fresh_path, tmp)
    os.replace(tmp, _JSON_PATH)
    print(f"# wrote {_JSON_PATH} ({n_benches} benches)", flush=True)


def _load_committed() -> dict:
    if not os.path.exists(_JSON_PATH):
        return {}
    with open(_JSON_PATH) as f:
        return json.load(f).get("benches", {})


def _check_regressions(committed: dict, fresh: dict, gate_rows: list) -> int:
    """Print the per-bench delta table; return the number of regressions.

    Per bench (where both sides have the metric), ceilings AND floors:
    ``us_per_call`` and ``us_decode`` may not grow past the coarse
    CHECK_THRESHOLD (an unchecked decode made a decode regression
    invisible before this gate existed), throughput floors ``gbps`` /
    ``gbps_decode`` may not fall below 1/CHECK_THRESHOLD of the committed
    value (so a perf win, once committed, is locked in from both sides),
    and ``bytes_moved_ratio`` is gated against the tight BYTES_THRESHOLD —
    data-movement accounting is deterministic, so the retrieval plan
    growing its byte footprint is a real regression even at identical
    wall clock.
    """
    gates = [
        ("us_per_call", "ceiling", CHECK_THRESHOLD, "{:.1f}"),
        ("us_decode", "ceiling", CHECK_THRESHOLD, "{:.1f}"),
        ("gbps", "floor", CHECK_THRESHOLD, "{:.5f}"),
        ("gbps_decode", "floor", CHECK_THRESHOLD, "{:.5f}"),
        ("bytes_moved_ratio", "ceiling", BYTES_THRESHOLD, "{:.4f}"),
    ]
    print("\n# bench delta vs committed BENCH_kernels.json")
    print("name,metric,old,new,ratio,verdict")
    bad = 0
    for name in sorted(set(committed) & set(fresh)):
        for metric, kind, threshold, fmt in gates:
            old = committed[name].get(metric)
            new = fresh[name].get(metric)
            if not old or new is None or old != old or new != new:
                continue  # missing/NaN/zero baseline
            ratio = new / old
            verdict = "ok"
            bound = old * threshold if kind == "ceiling" else old / threshold
            if kind == "ceiling" and ratio > threshold:
                verdict = f"REGRESSION(>{threshold:g}x)"
                bad += 1
            if kind == "floor" and ratio < 1.0 / threshold:
                verdict = f"REGRESSION(<1/{threshold:g}x)"
                bad += 1
            gate_rows.append((name, metric, kind, new, bound, verdict))
            print(
                f"{name},{metric},{fmt.format(old)},{fmt.format(new)},"
                f"{ratio:.2f},{verdict}"
            )
    if bad:
        print(f"# {bad} bench metric(s) regressed past their threshold")
    return bad


def _check_abs_gates(fresh: dict, gate_rows: list) -> int:
    """Gate fresh metrics against the fixed ABS_GATES bounds; return the
    number of violations.  Unlike ``_check_regressions`` this does not need
    the metric in the committed baseline, so deleting a row from
    BENCH_kernels.json cannot silently disarm a structural claim."""
    print("\n# absolute gates")
    print("bench,metric,bound,value,verdict")
    bad = 0
    for bench, gates in sorted(ABS_GATES.items()):
        metrics = fresh.get(bench)
        for metric, kind, bound, *flags in gates:
            value = metrics.get(metric) if metrics else None
            verdict = "ok"
            if value is None or value != value:
                if "optional" in flags:
                    # BENCH_FULL-only rows (e.g. the 1024-stream ingest
                    # point) gate when present, skip when the quick run
                    # did not produce them
                    print(f"{bench},{metric},{kind}@{bound:g},nan,"
                          f"skip(absent)")
                    continue
                verdict = "FAIL(missing)"
                bad += 1
            elif kind == "ceiling" and value > bound:
                verdict = f"FAIL(>{bound:g})"
                bad += 1
            elif kind == "floor" and value < bound:
                verdict = f"FAIL(<{bound:g})"
                bad += 1
            shown = "nan" if value is None else f"{value:g}"
            gate_rows.append((bench, metric, kind, value, bound, verdict))
            print(f"{bench},{metric},{kind}@{bound:g},{shown},{verdict}")
    if bad:
        print(f"# {bad} absolute gate(s) failed")
    return bad


def _print_gate_state(gate_rows: list) -> None:
    """Consolidated gate-state table, printed when any gate failed.

    One row per evaluated gate — passing AND failing, relative AND
    absolute — with the measured value, the effective bound (for relative
    gates: committed value x threshold, i.e. the number the fresh run had
    to stay inside), and the signed margin as a fraction of the bound
    (positive = headroom, negative = by how much the gate was blown).  A
    failing CI run should need no further decoding: this table IS the
    full gate state.
    """
    print("\n# full gate state (measured vs bound, margin = headroom/bound)")
    print("bench,metric,kind,measured,bound,margin,verdict")
    for bench, metric, kind, measured, bound, verdict in gate_rows:
        if measured is None or measured != measured:
            meas_s, margin_s = "nan", "nan"
        else:
            meas_s = f"{measured:g}"
            if bound:
                head = (bound - measured) if kind == "ceiling" \
                    else (measured - bound)
                margin_s = f"{head / abs(bound):+.1%}"
            else:  # bound == 0: a ceiling at zero has no relative scale
                margin_s = "n/a" if measured else "+0.0%"
        print(f"{bench},{metric},{kind},{meas_s},{bound:g},{margin_s},"
              f"{verdict}")


def main() -> None:
    check = "--check" in sys.argv
    update = "--update" in sys.argv
    _force_multidevice_host()

    from benchmarks import kernels_bench, paper_tables
    from benchmarks.common import fmt_rows

    quick = os.environ.get("BENCH_FULL", "0") != "1"
    suites = [
        ("table1", paper_tables.table1_resource),
        ("table2", paper_tables.table2_placement),
        ("fig4", paper_tables.fig4_workstation),
        ("fig5", paper_tables.fig5_consolidated),
        ("fig6", paper_tables.fig6_multinode),
        ("fig7", paper_tables.fig7_encryption),
        ("fig8/9", lambda: paper_tables.fig8_fig9_codec(quick=quick)),
        ("fig10", paper_tables.fig10_movement_scaling),
        ("fig11", paper_tables.fig11_csd_ratio),
        ("kernels/polymul", kernels_bench.polymul_kernel),
        ("kernels/motion", kernels_bench.motion_kernel),
        ("kernels/quantize", kernels_bench.quantize_kernel),
        ("kernels/entropy", kernels_bench.entropy_coder),
        ("kernels/fused", kernels_bench.entropy_seal_fused),
        ("kernels/seal", kernels_bench.seal_datapath),
        ("kernels/sharded_seal", kernels_bench.sharded_seal),
        ("kernels/retrieval", kernels_bench.retrieval),
        ("kernels/scrub_rebuild", kernels_bench.scrub_rebuild),
        ("kernels/obs_overhead", kernels_bench.obs_overhead),
        ("kernels/ingest_scale", kernels_bench.ingest_scale),
    ]
    committed = _load_committed() if check else {}
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            print(fmt_rows(fn()), flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR: {e!r}", flush=True)
    regressions = 0
    gate_rows: list = []
    if check:
        regressions = _check_regressions(
            committed, kernels_bench.JSON_METRICS, gate_rows
        )
        regressions += _check_abs_gates(kernels_bench.JSON_METRICS, gate_rows)
        if regressions:
            _print_gate_state(gate_rows)
    # fresh metrics always land in the system tempdir (CI can upload them
    # from there); the committed baseline is replaced only on an explicit
    # --update whose gates all passed, so a failed or exploratory run can
    # neither ratchet the baseline down nor leave debris at the repo root
    fresh_path = _dump_fresh(kernels_bench.JSON_METRICS)
    if regressions:
        print(f"# NOT touching {_JSON_PATH} (regression gate failed); "
              f"fresh metrics in {fresh_path}")
    elif update:
        _commit_kernels_json(fresh_path, len(kernels_bench.JSON_METRICS))
    else:
        print(f"# fresh metrics in {fresh_path} "
              f"(pass --update to commit them to {_JSON_PATH})")
    if failures or regressions:
        sys.exit(1)


if __name__ == "__main__":
    main()
