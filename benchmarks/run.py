"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Set BENCH_FULL=1 for the longer
codec-training variant of the Fig. 8/9 rate-distortion sweep.
"""

from __future__ import annotations

import os
import sys


def main() -> None:
    from benchmarks import kernels_bench, paper_tables
    from benchmarks.common import fmt_rows

    quick = os.environ.get("BENCH_FULL", "0") != "1"
    suites = [
        ("table1", paper_tables.table1_resource),
        ("table2", paper_tables.table2_placement),
        ("fig4", paper_tables.fig4_workstation),
        ("fig5", paper_tables.fig5_consolidated),
        ("fig6", paper_tables.fig6_multinode),
        ("fig7", paper_tables.fig7_encryption),
        ("fig8/9", lambda: paper_tables.fig8_fig9_codec(quick=quick)),
        ("fig10", paper_tables.fig10_movement_scaling),
        ("fig11", paper_tables.fig11_csd_ratio),
        ("kernels/polymul", kernels_bench.polymul_kernel),
        ("kernels/motion", kernels_bench.motion_kernel),
        ("kernels/quantize", kernels_bench.quantize_kernel),
        ("kernels/seal", kernels_bench.seal_datapath),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            print(fmt_rows(fn()), flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR: {e!r}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
