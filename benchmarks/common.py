"""Shared benchmark helpers: timing, row format, synthetic content."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (block_until_ready aware)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def smooth_clip(key, t=4, b=1, h=64, w=64):
    """Synthetic video with real temporal structure (drifting blobs)."""
    from repro.data.video import VideoStream, render_clip

    s = VideoStream(0, int(jax.random.randint(key, (), 0, 1 << 30)), h, w, 30.0, 64)
    frames = render_clip(s, 0, t)  # (T, H, W, 3)
    return frames[:, None].repeat(b, axis=1) if b > 1 else frames[:, None]


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(f"{n},{us:.1f},{d}" for n, us, d in rows)
