"""Seed-deterministic multi-stream ingest workload generator.

Drives the ``ingest_scale`` bench (and ``tests/test_ingest_scale.py``)
with N simulated camera streams pushing pre-encoded GOP payloads at the
``StreamIngestFrontend``.  Same determinism contract as
``repro.core.csd.chaos.ChaosFleet``: the ENTIRE arrival schedule — every
(stream, sequence, size, novelty) tuple — is precomputed in ``__init__``
from ``np.random.default_rng(cfg.seed)``, so a given config replays the
identical workload regardless of how the consumer interleaves pumps,
drains, or sheds.  Payload BYTES are derived per arrival from
``default_rng([seed, stream_id, seq])``, so two replays (or the
synchronous-vs-pipelined identity test) see bit-identical payloads
without materializing them all up front.

Edge realism knobs (what the edge-video literature says binds at the
edge — multi-stream admission and tail latency, not single-stream
throughput):

* **heavy-tailed GOP sizes** — lognormal around ``median_bytes`` with
  ``sigma`` fattening the tail, clipped to [min_bytes, max_bytes]; big
  outlier GOPs land in cold coalescer buckets and exercise the
  straggler drain.
* **bursty arrivals** — streams emit in geometric-length bursts (one
  camera spamming motion events), picked by a zipf-skewed stream
  distribution so a few hot cameras dominate, as in real deployments.
* **novelty** — per-GOP uniform [0, 1); the admission controller sheds
  lowest-novelty first, so the shed fraction under pressure is
  deterministic too.
"""

from __future__ import annotations

from typing import List, NamedTuple

import numpy as np

__all__ = ["WorkloadConfig", "Arrival", "IngestWorkload"]


class WorkloadConfig(NamedTuple):
    n_streams: int = 16
    n_gops: int = 128       # total arrivals across every stream
    seed: int = 0
    # heavy-tailed sizes: lognormal(median, sigma) clipped to [min, max]
    min_bytes: int = 1 << 10
    median_bytes: int = 4 << 10
    sigma: float = 0.6
    max_bytes: int = 48 << 10
    # bursts: geometric length (mean ~= 1/burst_p), zipf-skewed streams
    burst_p: float = 0.25
    zipf_a: float = 1.3


class Arrival(NamedTuple):
    """One scheduled GOP arrival (payload bytes derived on demand)."""

    index: int      # global arrival order
    stream_id: int
    seq: int        # per-stream sequence number
    nbytes: int
    novelty: float


class IngestWorkload:
    """Precomputed arrival schedule + per-arrival payload derivation."""

    def __init__(self, cfg: WorkloadConfig = WorkloadConfig()):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        arrivals: List[Arrival] = []
        seqs = [0] * cfg.n_streams
        mu = np.log(cfg.median_bytes)
        while len(arrivals) < cfg.n_gops:
            # zipf-skewed stream pick: hot cameras burst far more often
            sid = int(rng.zipf(cfg.zipf_a) - 1) % cfg.n_streams
            burst = 1 + int(rng.geometric(cfg.burst_p) - 1)
            for _ in range(min(burst, cfg.n_gops - len(arrivals))):
                nbytes = int(
                    np.clip(
                        rng.lognormal(mu, cfg.sigma),
                        cfg.min_bytes, cfg.max_bytes,
                    )
                )
                nbytes -= nbytes % 4  # whole uint32 words, like real codes
                arrivals.append(
                    Arrival(
                        len(arrivals), sid, seqs[sid], nbytes,
                        float(rng.random()),
                    )
                )
                seqs[sid] += 1
        self.arrivals: List[Arrival] = arrivals

    def payload(self, a: Arrival) -> np.ndarray:
        """Derive arrival ``a``'s flat int8 payload (bit-stable per
        (seed, stream, seq) — independent of replay interleaving)."""
        rng = np.random.default_rng([self.cfg.seed, a.stream_id, a.seq])
        # normal-clipped codes: compressible, like real codec output
        return np.clip(
            rng.normal(0.0, 12.0, a.nbytes), -127, 127
        ).astype(np.int8)

    @staticmethod
    def manifest(a: Arrival) -> dict:
        """Minimal packing manifest for a synthetic payload."""
        return {"spec": [], "n_i8": a.nbytes}

    @property
    def total_bytes(self) -> int:
        return sum(a.nbytes for a in self.arrivals)
