"""One benchmark function per paper table/figure (Salient Store §5).

Measured numbers run the real JAX implementations on this host; model-derived
numbers come from the calibrated cost model (core/csd/costmodel.py) whose
parameters reproduce the paper's published ratios — each row's ``derived``
column names the paper target so EXPERIMENTS.md can report model-vs-paper
error.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, smooth_clip, timeit
from repro.core.csd import costmodel as cm

GB = 1e9


# ------------------------------------------------------------------ Table 1
def table1_resource() -> List[Row]:
    """Resource profile of archival algorithms (paper Table 1 analogue):
    measured time per MiB on this host for each pipeline stage."""
    from repro.common import compress as entropy
    from repro.core.archival import raid
    from repro.core.crypto import rlwe
    from repro.core.crypto.chacha import xor_stream
    from repro.core.crypto.hybrid import bytes_to_u32

    rows: List[Row] = []
    rng = np.random.default_rng(0)
    mib = rng.integers(0, 256, 1 << 20, dtype=np.uint8).tobytes()
    words = bytes_to_u32(mib)

    pub, s = rlwe.keygen(jax.random.PRNGKey(0))
    key8 = jnp.arange(8, dtype=jnp.uint32)
    nonce = jnp.ones(3, jnp.uint32)

    us = timeit(lambda: xor_stream(key8, nonce, words))
    rows.append(("table1/encrypt_chacha20_per_MiB", us, "bulk layer of RSA512 row"))
    m = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (64, 256)).astype(jnp.int32)
    us = timeit(lambda: rlwe.encrypt_bits(pub, m, jax.random.PRNGKey(2)))
    rows.append(("table1/rlwe_encrypt_64blk", us, "quantum-safe key layer"))

    us = timeit(lambda: entropy.compress(mib, level=3), warmup=1, iters=3)
    rows.append((f"table1/{entropy.CODEC_NAME}_compress_per_MiB", us, "ZStd row"))
    blob = entropy.compress(mib, level=3)
    us = timeit(lambda: entropy.decompress(blob, max_output_size=len(mib)))
    rows.append((f"table1/{entropy.CODEC_NAME}_inflate_per_MiB", us, "ZStd inflate row"))

    shards = jnp.asarray(rng.integers(0, 256, (4, 1 << 18)), jnp.uint8)
    us = timeit(lambda: raid.raid6_encode(shards))
    rows.append(("table1/raid6_encode_per_MiB", us, "(un)RAID row"))
    return rows


# ------------------------------------------------------------------ Table 2
def table2_placement() -> List[Row]:
    """Data-distribution speedups vs CPU baseline (paper Table 2)."""
    sys = cm.SystemModel()
    base = cm.cpu_on_csd_data(sys, GB).latency_s
    paper = {
        "csd1_only": ((1.0,), 3.9),
        "split_90_10": ((0.9, 0.1), 4.46),
        "split_70_30": ((0.7, 0.3), 5.608),
        "split_60_40": ((0.6, 0.4), 6.67),
        "split_50_50": ((0.5, 0.5), 7.7),
    }
    rows = []
    for name, (split, target) in paper.items():
        got = base / cm.csd_archive(sys, GB, split).latency_s
        err = abs(got - target) / target * 100
        rows.append(
            (f"table2/{name}", cm.csd_archive(sys, GB, split).latency_s * 1e6,
             f"speedup={got:.2f}x paper={target}x err={err:.1f}%")
        )
    return rows


# ------------------------------------------------------------------- Fig. 4
def fig4_workstation() -> List[Row]:
    """Workstation (2 CSDs): Salient Store vs the classical storage path,
    normalized as in Fig. 4 (~1.99x).  The paper normalizes to an Alveo-class
    host accelerator, so the baseline keeps the host-link staging but runs
    the archival kernels ~4x faster than the storage CPU — the residual win
    is pure data-movement avoidance, the paper's thesis."""
    sys = cm.SystemModel()
    sal = cm.csd_archive(sys, GB, (0.5, 0.5)).latency_s
    alveo = cm.SystemModel(cpu_rate_GBps=sys.cpu_rate_GBps * 4.0)
    base = cm.classical_archive(alveo, GB).latency_s
    got = base / sal
    return [("fig4/salient_vs_alveo_host", sal * 1e6,
             f"speedup={got:.2f}x paper~1.99x err={abs(got-1.99)/1.99*100:.1f}%")]


# ------------------------------------------------------------------- Fig. 5
def fig5_consolidated() -> List[Row]:
    """Consolidated edge server: latency vs VSS/classical + data movement."""
    # Fig. 5's platform is an Alveo-class accelerator: csd_speedup 6.3
    sys = cm.SystemModel(csd_speedup=6.33)
    sal = cm.csd_archive(sys, GB).latency_s
    cla = cm.classical_archive(sys, GB)
    vss = cm.vss_archive(sys, GB)
    move = cla.moved_bytes / cm.csd_archive(sys, GB).moved_bytes
    # kernel-measured counterpart to the model-derived row: HBM-byte
    # accounting of the fused seal datapath vs the staged pipeline for a
    # representative 4-shard stripe of 1 MiB bodies (repro.kernels.seal)
    from repro.kernels.seal import datapath_traffic

    t = datapath_traffic(S=4, n_words=(1 << 20) // 4, parity="raid6")
    return [
        ("fig5b/vs_classical", sal * 1e6,
         f"speedup={cla.latency_s / sal:.2f}x paper=6.18x err={abs(cla.latency_s/sal-6.18)/6.18*100:.1f}%"),
        ("fig5b/vs_vss", sal * 1e6,
         f"speedup={vss.latency_s / sal:.2f}x paper=4.49x err={abs(vss.latency_s/sal-4.49)/4.49*100:.1f}%"),
        ("fig5c/data_movement_reduction", 0.0,
         f"reduction={move:.2f}x paper=5.63x err={abs(move-5.63)/5.63*100:.1f}%"),
        ("fig5c/seal_datapath_kernel_traffic", 0.0,
         f"staged={t['staged_bytes']}B fused={t['fused_bytes']}B "
         f"hbm_reduction={t['reduction']:.2f}x launches={t['fused_launches']} "
         f"vs {t['staged_passes']} staged passes"),
    ]


# ------------------------------------------------------------------- Fig. 6
def fig6_multinode() -> List[Row]:
    sys = cm.SystemModel()
    sal = cm.multinode_latency(sys, 8 * GB, 5).latency_s
    cla = cm.classical_multinode_latency(sys, 8 * GB, 5).latency_s
    vss = cla / sys.vss_factor
    return [
        ("fig6/vs_classical_5node", sal * 1e6,
         f"speedup={cla / sal:.2f}x paper=4.77x err={abs(cla/sal-4.77)/4.77*100:.1f}%"),
        ("fig6/vs_vss_5node", sal * 1e6,
         f"speedup={vss / sal:.2f}x paper=3.0x err={abs(vss/sal-3.0)/3.0*100:.1f}%"),
    ]


# ------------------------------------------------------------------- Fig. 7
def fig7_encryption() -> List[Row]:
    """Lattice encryption vs RSA.

    In-kind measured comparison: the accelerated polymul path (Pallas kernel,
    the FPGA/HSPM analogue) vs the software schoolbook path — the paper's
    "FPGA-LBC = 3.2x sw-LBC" claim.  The absolute host wall-clock of RLWE vs
    python RSA is NOT comparable (interpret-mode kernel on CPU), so the RSA
    rows are context + a derived MXU-cycle estimate gives the TPU-side ratio.
    """
    from repro.core.crypto import rlwe
    from repro.core.crypto.rsa_baseline import rsa_encrypt_blocks, rsa_keypair
    from repro.kernels.polymul.ops import polymul_fixed
    from repro.kernels.polymul.ref import negacyclic_matmul_ref

    rows: List[Row] = []
    payload = bytes(range(256)) * 24  # 6 KiB
    pub_rsa, _ = rsa_keypair()
    us_rsa = timeit(lambda: rsa_encrypt_blocks(payload, pub_rsa), warmup=0, iters=3)
    rows.append(("fig7/rsa512_sw_6KiB", us_rsa, "software RSA-512 (host CPU)"))

    rng = np.random.default_rng(0)
    q, n, B = 12289, 256, 192
    a = jnp.asarray(rng.integers(0, q, (n,)), jnp.int32)
    b = jnp.asarray(rng.integers(0, q, (B, n)), jnp.int32)
    us_sw = timeit(lambda: negacyclic_matmul_ref(a, b, q))
    us_hw = timeit(lambda: polymul_fixed(a, b, q))
    ratio = us_sw / us_hw
    rows.append(("fig7/lbc_polymul_sw", us_sw, "software schoolbook (sw-LBC)"))
    rows.append((
        "fig7/lbc_polymul_kernel", us_hw,
        f"accelerated-vs-sw={ratio:.1f}x (paper FPGA-vs-sw-LBC=3.2x)",
    ))
    # derived MXU estimate: 4 int8 limb matmuls of (n,n)@(n,B)
    mxu_flops = 4 * 2 * n * n * B
    est_us = mxu_flops / 197e12 * 1e6 * 4  # ~25% MXU util on small tiles
    rows.append((
        "fig7/lbc_mxu_derived", est_us,
        f"TPU-derived {est_us:.2f}us per 192 ciphertext polys "
        f"(paper: quantum-safe at ~RSA-class cost)",
    ))
    return rows


# ------------------------------------------------------------------- Fig. 8/9
def fig8_fig9_codec(quick: bool = True) -> List[Row]:
    """PSNR rate-distortion + encode latency: neural codec vs h264/hevc-like.

    The neural codec's AE is trained briefly on the content class first
    (the paper trains its codec); classical codecs need no training.
    """
    from repro.core.codec.layered_codec import (
        CodecConfig, encode_gop, init_codec, psnr, serialize_bitstream,
    )
    from repro.core.codec.reference_codecs import h264_like, hevc_like
    from repro.core.codec.training import (
        CodecTrainConfig, codec_pretrain_step, codec_train_step, init_codec_trainer,
    )
    from repro.train.optimizer import adamw_init

    from repro.train.optimizer import AdamWConfig

    rows: List[Row] = []
    cfg = CodecConfig(n_layers=3, latent_ch=6, feat_ch=16, mv_cond_ch=4)
    params = init_codec(jax.random.PRNGKey(0), cfg)
    tcfg = CodecTrainConfig(codec=cfg, opt=AdamWConfig(lr=1e-3, grad_clip=1.0))
    # phase 1: joint pretraining (stands in for the pretrained MobileNet).
    # quick mode is rate-limited by the 1-core CPU host: PSNR here is the
    # *reduced-scale* operating point (~28-31 dB); BENCH_FULL trains longer.
    pre_steps = 60 if quick else 400
    opt_all = adamw_init(params, tcfg.opt)
    for i in range(pre_steps):
        clips = smooth_clip(jax.random.PRNGKey(100 + i), t=3)
        params, opt_all, m = codec_pretrain_step(params, opt_all, tcfg, clips)
    # phase 2: Alg. 2 — freeze extractor, train AE only
    trainable, frozen, opt = init_codec_trainer(params, tcfg)
    steps = 20 if quick else 150
    for i in range(steps):
        clips = smooth_clip(jax.random.PRNGKey(500 + i), t=3)
        trainable, opt, m = codec_train_step(trainable, frozen, opt, tcfg, clips)
    params = dict(frozen, **trainable)

    test = smooth_clip(jax.random.PRNGKey(999), t=4)
    # neural codec at K = 1..3 quality layers (rate points)
    for k in range(1, cfg.n_layers + 1):
        us = timeit(
            lambda k=k: encode_gop(params, cfg, test, n_layers=k)[1], warmup=1, iters=2
        )
        codes, recons = encode_gop(params, cfg, test, n_layers=k)
        blob, _ = serialize_bitstream(codes)
        p = float(psnr(recons, test))
        bpp = len(blob) * 8 / test[:, 0].size * 3
        rows.append(
            (f"fig8/salient_K{k}", us, f"psnr={p:.2f}dB bytes={len(blob)}")
        )
    frames = test[:, 0]
    for name, codec, qp in (
        ("h264_like_q1", h264_like(), 1.0),
        ("h264_like_q4", h264_like(), 4.0),
        ("hevc_like_q1", hevc_like(), 1.0),
        ("hevc_like_q4", hevc_like(), 4.0),
    ):
        us = timeit(lambda c=codec, q=qp: c.encode_gop(frames, qp=q)[1], warmup=1, iters=2)
        coded, recons = codec.encode_gop(frames, qp=qp)
        p = float(psnr(recons, frames))
        blob = codec.bitstream_bytes(coded)
        rows.append((f"fig8/{name}", us, f"psnr={p:.2f}dB bytes={len(blob)}"))
    return rows


# ------------------------------------------------------------------ Fig. 10
def fig10_movement_scaling() -> List[Row]:
    sys = cm.SystemModel()
    rows = []
    prev = None
    for n in (1, 2, 3, 4, 5, 8):
        lat = cm.multinode_movement_latency(sys, 8 * GB, n)
        growth = "" if prev in (None, 0) else f" growth={lat / prev:.2f}x"
        rows.append((f"fig10/nodes_{n}", lat * 1e6, f"super-linear latency{growth}"))
        prev = lat
    return rows


# ------------------------------------------------------------------ Fig. 11
def fig11_csd_ratio() -> List[Row]:
    sys = cm.SystemModel()
    rows = []
    best = (None, -1.0)
    for n_csd in (1, 2, 4, 8, 16):
        sp, eff = cm.csd_ratio_tradeoff(sys, 64 * GB, n_ssd=8, n_csd=n_csd)
        rows.append(
            (f"fig11/ssd8_csd{n_csd}", 0.0, f"speedup={sp:.2f}x cost_eff={eff:.4f}")
        )
        if eff > best[1]:
            best = (n_csd, eff)
    rows.append(
        ("fig11/knee", 0.0, f"best=8:{best[0]} (paper: 8:1 SSD:CSD)")
    )
    return rows
