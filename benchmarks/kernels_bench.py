"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference.

Wall-clock on this CPU host is NOT the perf claim (interpret mode runs the
kernel body in Python); the derived column reports the structural numbers the
TPU roofline uses: MXU-aligned shapes, VMEM working sets, exact-arithmetic
verification against the oracle.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit


def polymul_kernel() -> List[Row]:
    from repro.kernels.polymul.ops import polymul_fixed
    from repro.kernels.polymul.ref import negacyclic_matmul_ref

    rng = np.random.default_rng(0)
    q, n, B = 12289, 256, 256
    a = jnp.asarray(rng.integers(0, q, (n,)), jnp.int32)
    b = jnp.asarray(rng.integers(0, q, (B, n)), jnp.int32)
    us_k = timeit(lambda: polymul_fixed(a, b, q))
    us_r = timeit(lambda: negacyclic_matmul_ref(a, b, q))
    ok = bool(
        np.array_equal(
            np.asarray(polymul_fixed(a, b, q)), np.asarray(negacyclic_matmul_ref(a, b, q))
        )
    )
    flops = 2 * n * n * B * 4  # 4 int8 limb matmuls
    return [
        ("kernel/polymul_pallas_256x256", us_k,
         f"exact={ok} mxu_flops={flops:.2e} vmem_tile=(256,256)x4limb"),
        ("kernel/polymul_ref", us_r, "pure-jnp oracle"),
    ]


def motion_kernel() -> List[Row]:
    from repro.kernels.motion.ops import estimate_motion
    from repro.kernels.motion.ref import block_motion_ref

    rng = np.random.default_rng(1)
    H, W = 128, 128
    cur = jnp.asarray(rng.integers(0, 256, (H, W)), jnp.int32)
    prev = jnp.asarray(rng.integers(0, 256, (H, W)), jnp.int32)
    us_k = timeit(lambda: estimate_motion(cur, prev))
    us_r = timeit(lambda: block_motion_ref(cur, prev))
    mv_k, _ = estimate_motion(cur, prev)
    mv_r, _ = block_motion_ref(cur, prev)
    ok = bool(np.array_equal(np.asarray(mv_k), np.asarray(mv_r)))
    return [
        ("kernel/motion_pallas_128x128", us_k,
         f"exact={ok} offsets=289 halo=triple-fetch"),
        ("kernel/motion_ref", us_r, "pure-jnp oracle"),
    ]


def _count_pallas_launches(fn, *args) -> int:
    """Number of pallas_call primitives in fn's jaxpr (incl. sub-jaxprs)."""
    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    inner = v.jaxpr if hasattr(v.jaxpr, "eqns") else v
                    n += walk(inner if hasattr(inner, "eqns") else inner.jaxpr)
        return n

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def seal_datapath() -> List[Row]:
    """Fused seal (pack+ChaCha20+XOR+RAID P/Q, one launch) vs staged jnp."""
    from repro.kernels.seal import datapath_traffic, seal_stripe
    from repro.kernels.seal import ops as sops
    from repro.kernels.seal import ref as sref

    rng = np.random.default_rng(2)
    S, lens = 4, [16 * 512 - 37, 16 * 512, 15 * 512 + 5, 16 * 512 - 1]
    payloads = [jnp.asarray(rng.integers(-128, 128, n), jnp.int8) for n in lens]
    keys = jnp.asarray(rng.integers(0, 2**32, (S, 8), dtype=np.uint32))
    nonces = jnp.asarray(rng.integers(0, 2**32, (S, 3), dtype=np.uint32))

    us_k = timeit(lambda: seal_stripe(payloads, keys, nonces))
    us_r = timeit(lambda: seal_stripe(payloads, keys, nonces, use_pallas=False))
    fused = seal_stripe(payloads, keys, nonces)
    staged = seal_stripe(payloads, keys, nonces, use_pallas=False)
    ok = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in ((fused.sealed, staged.sealed), (fused.p, staged.p),
                     (fused.q, staged.q))
    )

    codes, n_words, _ = sops._stack_padded(
        [p.reshape(-1).astype(jnp.int8) for p in payloads]
    )
    meta = sops._meta_arrays(keys, nonces, n_words)
    launches = _count_pallas_launches(
        lambda c, k, n, v, q: sops._seal_core(
            c, k, n, v, q, parity="raid6", use_pallas=True, interpret=True
        ),
        codes, *meta,
    )
    t = datapath_traffic(S, fused.pad_words, "raid6")
    gop_kib = fused.pad_words * 4 / 1024
    return [
        ("kernel/seal_fused_4shard", us_k,
         f"exact={ok} launches={launches} hbm_bytes={t['fused_bytes']}"
         f" ({gop_kib:.0f}KiB/shard)"),
        ("kernel/seal_staged_ref", us_r,
         f"passes={sref.N_STAGED_PASSES} hbm_bytes={t['staged_bytes']}"
         f" traffic_reduction={t['reduction']:.1f}x"),
    ]


def quantize_kernel() -> List[Row]:
    from repro.kernels.quantize.ops import dequantize_blockwise, quantize_blockwise
    from repro.kernels.quantize.ref import quantize_ref

    x = jax.random.normal(jax.random.PRNGKey(0), (256, 1024)) * 3
    us_k = timeit(lambda: quantize_blockwise(x))
    us_r = timeit(lambda: quantize_ref(x))
    q, s = quantize_blockwise(x)
    qr, sr = quantize_ref(x)
    ok = bool(np.array_equal(np.asarray(q), np.asarray(qr)))
    return [
        ("kernel/quantize_pallas_256x1024", us_k,
         f"exact={ok} blocks=128 hbm_ratio=4:1 (f32->int8)"),
        ("kernel/quantize_ref", us_r, "pure-jnp oracle"),
    ]
